//! Bench: the simulator hot paths (the §Perf optimization target).
//!
//! Measures (a) the exact cycle-stepped engine in transactions/second
//! on the double-pumped vecadd design, (b) the functional executor,
//! (c) the analytic rate model, and (d) the end-to-end compile
//! pipeline. EXPERIMENTS.md §Perf records before/after.

use temporal_vec::coordinator::{compile, BuildSpec};
use temporal_vec::ir::PumpMode;
use temporal_vec::sim::{rate_model, run_exact, run_functional, Hbm};
use temporal_vec::util::bench::{bench_throughput, black_box, BenchSuite};
use temporal_vec::util::Rng;

fn main() {
    let mut suite = BenchSuite::new("sim_hotpath");
    suite.start();
    let n: i64 = 1 << 16;
    let c_dp = compile(
        BuildSpec::new(temporal_vec::apps::vecadd::build())
            .vectorized("vadd", 8)
            .pumped(2, PumpMode::Resource)
            .bind("N", n),
    )
    .unwrap();
    let c_o = compile(
        BuildSpec::new(temporal_vec::apps::vecadd::build())
            .vectorized("vadd", 8)
            .bind("N", n),
    )
    .unwrap();
    let mut rng = Rng::new(5);
    let x = rng.f32_vec(n as usize);
    let y = rng.f32_vec(n as usize);
    let mk_hbm = || {
        let mut h = Hbm::new();
        h.load("x", x.clone());
        h.load("y", y.clone());
        h
    };

    let txns = (n / 8) as f64;
    suite.add(bench_throughput("exact engine, vecadd DP (txns/s)", 1, 5, txns, || {
        let out = run_exact(&c_dp.design, mk_hbm(), 100_000_000).unwrap();
        black_box(out.stats.slow_cycles);
    }));
    suite.add(bench_throughput("exact engine, vecadd O (txns/s)", 1, 5, txns, || {
        let out = run_exact(&c_o.design, mk_hbm(), 100_000_000).unwrap();
        black_box(out.stats.slow_cycles);
    }));
    suite.add(bench_throughput("functional executor, vecadd DP (elems/s)", 1, 5, n as f64, || {
        let out = run_functional(&c_dp.design, mk_hbm()).unwrap();
        black_box(out.hbm.read("z")[0]);
    }));
    suite.add(bench_throughput("rate model (designs/s)", 10, 50, 1.0, || {
        black_box(rate_model(&c_dp.design).slow_cycles);
    }));
    suite.add(bench_throughput("compile pipeline, vecadd DP (designs/s)", 1, 10, 1.0, || {
        let c = compile(
            BuildSpec::new(temporal_vec::apps::vecadd::build())
                .vectorized("vadd", 8)
                .pumped(2, PumpMode::Resource)
                .bind("N", n),
        )
        .unwrap();
        black_box(c.report.effective_mhz);
    }));
    // FW exact at small n: stresses II/cooldown paths + repeats
    let c_fw = compile(
        BuildSpec::new(temporal_vec::apps::floyd_warshall::build())
            .pumped(2, PumpMode::Throughput)
            .bind("N", 32),
    )
    .unwrap();
    let d = temporal_vec::apps::floyd_warshall::random_graph(32, 3, 0.3);
    suite.add(bench_throughput("exact engine, FW n=32 (relax/s)", 1, 3, 32.0f64.powi(3), || {
        let mut h = Hbm::new();
        h.load("dist", d.clone());
        let out = run_exact(&c_fw.design, h, 200_000_000).unwrap();
        black_box(out.stats.slow_cycles);
    }));
    suite.finish();
}
