//! Bench: regenerate paper Table 4 (Jacobi-3D chains, 8-way vect).

use temporal_vec::coordinator::experiment::table4;
use temporal_vec::util::bench::{bench, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("table4_jacobi");
    suite.start();
    let nx = temporal_vec::apps::stencil::PAPER_NX;
    let r = table4(nx, 1).expect("table4");
    println!("{}", r.rendered);
    let find = |label: &str| r.rows.iter().find(|x| x.label == label).unwrap();
    // DSP halves per fixed S; DSP efficiency gains > 50 %
    for s in [8, 16] {
        let o = find(&format!("S={s} O"));
        let dp = find(&format!("S={s} DP"));
        assert!((dp.util[4] / o.util[4] - 0.5).abs() < 0.02);
        assert!(dp.mops_per_dsp > 1.5 * o.mops_per_dsp);
    }
    // scaling: DP reaches S=40 at full width and outperforms O
    assert!(find("S=40 DP").gops > 1.2 * find("S=40 O").gops);
    suite.add(bench("table4 full regeneration", 0, 3, || {
        let r = table4(nx, 1).unwrap();
        assert_eq!(r.rows.len(), 6);
    }));
    suite.finish();
}
