//! Bench: regenerate paper Table 5 (Diffusion-3D chains, 4-way vect).

use temporal_vec::coordinator::experiment::table5;
use temporal_vec::util::bench::{bench, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("table5_diffusion");
    suite.start();
    let nx = temporal_vec::apps::stencil::PAPER_NX;
    let r = table5(nx, 1).expect("table5");
    println!("{}", r.rendered);
    let find = |label: &str| r.rows.iter().find(|x| x.label == label).unwrap();
    for s in [8, 16] {
        let o = find(&format!("S={s} O"));
        let dp = find(&format!("S={s} DP"));
        assert!((dp.util[4] / o.util[4] - 0.5).abs() < 0.02);
        assert!(dp.mops_per_dsp > 1.5 * o.mops_per_dsp);
    }
    // the original tops out at S=20; only DP reaches S=40, faster
    assert!(find("S=40 DP").gops > 1.2 * find("S=20 O").gops);
    suite.add(bench("table5 full regeneration", 0, 3, || {
        let r = table5(nx, 1).unwrap();
        assert_eq!(r.rows.len(), 6);
    }));
    suite.finish();
}
