//! Bench: regenerate paper Table 2 (vector addition O vs DP, V∈{2,4,8})
//! and time the full compile+estimate+cycle-model pipeline per variant.

use temporal_vec::coordinator::experiment::table2;
use temporal_vec::util::bench::{bench, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("table2_vecadd");
    suite.start();
    let n = temporal_vec::apps::vecadd::PAPER_N;
    let r = table2(n, 1).expect("table2");
    println!("{}", r.rendered);
    suite.add(bench("table2 full regeneration", 1, 5, || {
        let r = table2(n, 1).unwrap();
        assert_eq!(r.rows.len(), 6);
    }));
    suite.finish();
}
