//! Bench: regenerate paper Figure 4 (speedup / DSP-efficiency summary
//! and DP/O resource ratios at fixed configurations).

use temporal_vec::coordinator::report::figure4;
use temporal_vec::util::bench::{bench, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("fig4_summary");
    suite.start();
    let r = figure4(1).expect("fig4");
    println!("{}", r.rendered);
    suite.add(bench("figure4 full regeneration", 0, 2, || {
        figure4(1).unwrap();
    }));
    suite.finish();
}
