//! Bench: event-driven exact engine vs the legacy per-cycle stepper.
//!
//! The tentpole claim of the engine rebuild — slow-cycles/sec on the
//! golden-scale designs the `dse --verify` hot path simulates — with
//! the legacy stepper measured side by side so the speedup is printed,
//! not assumed. `tvec bench --json` emits the same numbers as the
//! machine-readable BENCH_sim.json artifact (DESIGN.md §9).

use temporal_vec::coordinator::{compile, BuildSpec};
use temporal_vec::ir::{PumpMode, StencilKind};
use temporal_vec::sim::{run_exact, run_exact_in, run_exact_reference, Arena, Hbm};
use temporal_vec::util::bench::{bench_throughput, black_box, BenchSuite};
use temporal_vec::util::Rng;
use temporal_vec::{apps, sim};

fn main() {
    let mut suite = BenchSuite::new("sim_engine");
    suite.start();
    let mut rng = Rng::new(9);

    // vecadd V8 R2 at golden scale
    let n = apps::vecadd::GOLDEN_N;
    let c_va = compile(
        BuildSpec::new(apps::vecadd::build())
            .vectorized("vadd", 8)
            .pumped(2, PumpMode::Resource)
            .bind("N", n),
    )
    .unwrap();
    let (x, y) = (rng.f32_vec(n as usize), rng.f32_vec(n as usize));
    let va_hbm = || {
        let mut h = Hbm::new();
        h.load("x", x.clone());
        h.load("y", y.clone());
        h
    };
    let va_cycles =
        run_exact(&c_va.design, va_hbm(), 100_000_000).unwrap().stats.slow_cycles as f64;
    suite.add(bench_throughput("event engine, vecadd V8 R2 (slow cyc/s)", 1, 5, va_cycles, || {
        black_box(run_exact(&c_va.design, va_hbm(), 100_000_000).unwrap().stats.slow_cycles);
    }));
    suite.add(bench_throughput("legacy stepper, vecadd V8 R2 (slow cyc/s)", 1, 5, va_cycles, || {
        black_box(
            run_exact_reference(&c_va.design, va_hbm(), 100_000_000).unwrap().stats.slow_cycles,
        );
    }));
    // the pooled-arena path the DSE verify loop runs: slabs grow once,
    // every later transaction is a recycled slot (DESIGN.md §10)
    let mut va_arena = Arena::new();
    run_exact_in(&c_va.design, va_hbm(), 100_000_000, &mut va_arena).unwrap(); // warm the slabs
    suite.add(bench_throughput(
        "event engine, vecadd V8 R2, pooled arena (slow cyc/s)",
        1,
        5,
        va_cycles,
        || {
            black_box(
                run_exact_in(&c_va.design, va_hbm(), 100_000_000, &mut va_arena)
                    .unwrap()
                    .stats
                    .slow_cycles,
            );
        },
    ));

    // the 16-stage jacobi chain R4 at golden scale — the fill/drain
    // phases are where sleeping blocked processes pay off
    let w = apps::stencil::paper_vec_width(StencilKind::Jacobi3D);
    let (nx, ny, nz) =
        (apps::stencil::GOLDEN_NX, apps::stencil::PAPER_NY, apps::stencil::PAPER_NZ);
    let c_st = compile(
        BuildSpec::new(apps::stencil::build(StencilKind::Jacobi3D, 16, w))
            .pumped(4, PumpMode::Resource)
            .bind("NX", nx)
            .bind("NY", ny)
            .bind("NZ", nz)
            .bind("NZ_v", nz / w as i64),
    )
    .unwrap();
    let v_in = rng.f32_vec((nx * ny * nz) as usize);
    let st_hbm = || {
        let mut h = Hbm::new();
        h.load("v_in", v_in.clone());
        h
    };
    let st_cycles =
        run_exact(&c_st.design, st_hbm(), 100_000_000).unwrap().stats.slow_cycles as f64;
    suite.add(bench_throughput("event engine, stencil S16 R4 (slow cyc/s)", 1, 3, st_cycles, || {
        black_box(run_exact(&c_st.design, st_hbm(), 100_000_000).unwrap().stats.slow_cycles);
    }));
    suite.add(bench_throughput(
        "legacy stepper, stencil S16 R4 (slow cyc/s)",
        1,
        3,
        st_cycles,
        || {
            black_box(
                run_exact_reference(&c_st.design, st_hbm(), 100_000_000)
                    .unwrap()
                    .stats
                    .slow_cycles,
            );
        },
    ));

    // matmul R2 at golden scale
    let nm = apps::matmul::GOLDEN_NMK;
    let mut spec = BuildSpec::new(apps::matmul::build(4)).pumped(2, PumpMode::Resource);
    for (s, v) in apps::matmul::bindings(nm) {
        spec = spec.bind(&s, v);
    }
    let c_mm = compile(spec).unwrap();
    let (a, b) = (rng.f32_vec((nm * nm) as usize), rng.f32_vec((nm * nm) as usize));
    let mm_hbm = || {
        let mut h = Hbm::new();
        h.load("A", a.clone());
        h.load("B", b.clone());
        h
    };
    let mm_cycles =
        run_exact(&c_mm.design, mm_hbm(), 100_000_000).unwrap().stats.slow_cycles as f64;
    suite.add(bench_throughput("event engine, matmul R2 (slow cyc/s)", 1, 3, mm_cycles, || {
        black_box(run_exact(&c_mm.design, mm_hbm(), 100_000_000).unwrap().stats.slow_cycles);
    }));
    suite.add(bench_throughput("legacy stepper, matmul R2 (slow cyc/s)", 1, 3, mm_cycles, || {
        black_box(
            run_exact_reference(&c_mm.design, mm_hbm(), 100_000_000).unwrap().stats.slow_cycles,
        );
    }));

    // rate model for scale: the O(#modules) analytic path the search
    // ranks on, next to the exact engines it is verified against
    suite.add(bench_throughput("rate model, stencil S16 R4 (designs/s)", 10, 50, 1.0, || {
        black_box(sim::rate_model(&c_st.design).slow_cycles);
    }));

    suite.finish();
}
