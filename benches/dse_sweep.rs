//! Bench: design-space exploration search cost on matmul — exhaustive
//! vs greedy, cold vs memoized. The §Perf trajectory tracks search
//! wall-time from here on: the DSE subsystem is the new scaling
//! surface (more candidates, more apps, bigger grids).

use temporal_vec::apps;
use temporal_vec::coordinator::BuildSpec;
use temporal_vec::dse::{
    run_search, Evaluator, Objective, SearchBase, SearchConfig, SpaceOptions,
};
use temporal_vec::hw::Device;
use temporal_vec::util::bench::{bench, BenchSuite};

fn matmul_bases(seed: u64) -> Vec<SearchBase> {
    let n = 1024i64;
    [16usize, 32, 64]
        .iter()
        .map(|&pes| {
            let mut spec = BuildSpec::new(apps::matmul::build(pes)).cl0(270.0).seeded(seed);
            for (s, v) in apps::matmul::bindings(n) {
                spec = spec.bind(&s, v);
            }
            SearchBase { spec, flops: apps::matmul::flops(n, n, n) }
        })
        .collect()
}

fn main() {
    let mut suite = BenchSuite::new("dse_sweep");
    suite.start();
    let device = Device::u280();
    let bases = matmul_bases(1);
    let opts = SpaceOptions::for_device(&device);

    // headline numbers once, so the bench log shows what was searched
    let ev = Evaluator::new();
    let out = run_search(
        &ev,
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )
    .expect("exhaustive search");
    println!(
        "exhaustive: {} candidates evaluated, frontier {}, chosen {}",
        out.evaluated,
        out.frontier.len(),
        out.chosen.as_ref().map(|c| c.label.as_str()).unwrap_or("-")
    );

    suite.add(bench("exhaustive matmul sweep (cold cache)", 1, 5, || {
        let ev = Evaluator::new();
        let out = run_search(
            &ev,
            &bases,
            &device,
            &opts,
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
        assert!(out.frontier.len() >= 6);
    }));

    suite.add(bench("greedy matmul sweep (cold cache)", 1, 5, || {
        let ev = Evaluator::new();
        let out = run_search(
            &ev,
            &bases,
            &device,
            &opts,
            &SearchConfig::greedy(Objective::resource()),
        )
        .unwrap();
        assert!(out.chosen.is_some());
    }));

    // memoized: repeated sweeps are the incremental-retuning path
    let warm = Evaluator::new();
    run_search(
        &warm,
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )
    .unwrap();
    suite.add(bench("exhaustive matmul sweep (warm cache)", 1, 10, || {
        run_search(
            &warm,
            &bases,
            &device,
            &opts,
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
    }));

    suite.add(bench("single candidate evaluation (cold)", 1, 10, || {
        let ev = Evaluator::new();
        let base = &bases[1];
        let point = temporal_vec::dse::DesignPoint {
            pump: Some((2, temporal_vec::ir::PumpMode::Resource)),
            ..temporal_vec::dse::DesignPoint::original()
        };
        ev.evaluate(&base.spec, &point, base.flops).unwrap();
    }));

    // persistent cache: the incremental-CLI path. "cold disk" pays a
    // full sweep plus the flush; "warm disk" loads the store and
    // re-runs the whole sweep without a single compile.
    let cache_dir =
        std::env::temp_dir().join(format!("tvec-dse-sweep-bench-{}", std::process::id()));
    std::fs::create_dir_all(&cache_dir).expect("create bench cache dir");
    suite.add(bench("exhaustive matmul sweep (cold disk cache + flush)", 1, 3, || {
        let _ = std::fs::remove_dir_all(&cache_dir);
        std::fs::create_dir_all(&cache_dir).unwrap();
        let ev = Evaluator::with_cache_dir(&cache_dir);
        run_search(
            &ev,
            &bases,
            &device,
            &opts,
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
        ev.flush().unwrap();
    }));
    suite.add(bench("exhaustive matmul sweep (warm disk cache)", 1, 10, || {
        let ev = Evaluator::with_cache_dir(&cache_dir);
        run_search(
            &ev,
            &bases,
            &device,
            &opts,
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
        assert_eq!(ev.cache_misses(), 0, "warm disk run must not compile");
    }));
    let _ = std::fs::remove_dir_all(&cache_dir);

    // the mixed per-region dimension multiplies the stencil grid: track
    // its sweep cost separately (it is the new largest axis)
    let (stencil_bases, stencil_opts) = {
        let (bases, mut opts) = temporal_vec::coordinator::search_problem(
            "stencil",
            Some(1 << 10),
            1,
            &device,
        )
        .expect("stencil problem");
        opts.mixed_factors = true;
        opts.pump_modes = vec![temporal_vec::ir::PumpMode::Resource];
        opts.max_replicas = 1;
        (bases, opts)
    };
    suite.add(bench("exhaustive stencil sweep with mixed factors (cold)", 1, 3, || {
        let ev = Evaluator::new();
        let out = run_search(
            &ev,
            &stencil_bases,
            &device,
            &stencil_opts,
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
        assert!(out.evaluations.iter().any(|e| e.point.regions.is_some()));
    }));

    suite.finish();
}
