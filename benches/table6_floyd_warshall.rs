//! Bench: regenerate paper Table 6 (Floyd–Warshall, throughput-mode DP).

use temporal_vec::coordinator::experiment::table6;
use temporal_vec::util::bench::{bench, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("table6_floyd_warshall");
    suite.start();
    let n = temporal_vec::apps::floyd_warshall::PAPER_N;
    let r = table6(n, 1).expect("table6");
    println!("{}", r.rendered);
    let (o, dp) = (&r.rows[0], &r.rows[1]);
    // paper shape: similar resources, ~1.3-1.5x speedup from CL1
    let speedup = o.time_s / dp.time_s;
    assert!(speedup > 1.2, "speedup {speedup}");
    assert!((dp.util[3] - o.util[3]).abs() < 2.0, "BRAM similar");
    suite.add(bench("table6 full regeneration", 1, 5, || {
        let r = table6(n, 1).unwrap();
        assert_eq!(r.rows.len(), 2);
    }));
    suite.finish();
}
