//! Bench: regenerate paper Table 3 (systolic GEMM: CA baseline, DaCe
//! original, double-pumped at 32/48/64 PEs, 3-SLR replication).

use temporal_vec::coordinator::experiment::table3;
use temporal_vec::util::bench::{bench, BenchSuite};

fn main() {
    let mut suite = BenchSuite::new("table3_matmul");
    suite.start();
    let n = temporal_vec::apps::matmul::PAPER_NMK;
    let r = table3(n, 1).expect("table3");
    println!("{}", r.rendered);
    // headline checks (paper shapes)
    let find = |label: &str| r.rows.iter().find(|x| x.label == label).unwrap();
    let (ca, o, dp32, dp64) = (find("CA 32"), find("O 32"), find("DP 32"), find("DP 64"));
    assert!((dp32.util[4] / o.util[4] - 0.5).abs() < 0.02, "DSP halving");
    assert!(dp32.util[3] / o.util[3] < 0.65, "BRAM cut");
    assert!(dp64.gops > 1.10 * ca.gops, "DP-64 beats hand-written HLS");
    suite.add(bench("table3 full regeneration", 0, 3, || {
        let r = table3(n, 1).unwrap();
        assert_eq!(r.rows.len(), 6);
    }));
    suite.finish();
}
