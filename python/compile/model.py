"""L2: the golden models AOT-exported to HLO for the Rust runtime.

Each function composes the L1 Pallas kernels into the exact workload a
benchmark runs; `aot.py` lowers them ONCE at build time. The export
shapes below are the verification sizes the Rust integration tests use
(the simulator's functional mode must reproduce these outputs
bit-for-bit up to float tolerance).
"""

from .kernels import floyd_warshall as fw
from .kernels import matmul as mm
from .kernels import stencil as st
from .kernels import vecadd as va

# ---- export shapes (verification-scale; the paper-scale runs use the
# ---- analytic simulator, see DESIGN.md §2) ----
VECADD_N = 4096
GEMM_N, GEMM_M, GEMM_K = 128, 128, 128
STENCIL_NX, STENCIL_NY, STENCIL_NZ = 32, 32, 32
STENCIL_STAGES = 4
FW_N = 64


def vecadd(x, y):
    """z = x + y (paper §3.2 running example; Table 2)."""
    return (va.vecadd(x, y),)


def matmul(a, b):
    """Communication-avoiding GEMM golden model (Table 3)."""
    return (mm.matmul(a, b),)


def jacobi3d(v):
    """S chained Jacobi-3D stages (Table 4)."""
    return (st.stencil_chain(v, STENCIL_STAGES, kind="jacobi3d"),)


def diffusion3d(v):
    """S chained Diffusion-3D stages (Table 5)."""
    return (st.stencil_chain(v, STENCIL_STAGES, kind="diffusion3d"),)


def floyd_warshall(d):
    """All-pairs shortest paths (Table 6)."""
    return (fw.floyd_warshall(d),)


# name -> (fn, arg shapes)
MODELS = {
    "vecadd": (vecadd, [(VECADD_N,), (VECADD_N,)]),
    "matmul": (matmul, [(GEMM_N, GEMM_K), (GEMM_K, GEMM_M)]),
    "jacobi3d": (jacobi3d, [(STENCIL_NX, STENCIL_NY, STENCIL_NZ)]),
    "diffusion3d": (diffusion3d, [(STENCIL_NX, STENCIL_NY, STENCIL_NZ)]),
    "floyd_warshall": (floyd_warshall, [(FW_N, FW_N)]),
}
