# Build-time only: JAX/Pallas authoring + AOT lowering. Never imported
# by the runtime - the rust binary loads the HLO text artifacts.
