"""AOT driver: lower every L2 model to HLO *text* in artifacts/.

HLO text (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the rust
`xla` crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Python runs ONCE here (`make artifacts`); the rust binary is fully
self-contained afterwards.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import MODELS


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", default=None, help="lower a single model")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = []
    for name, (fn, shapes) in MODELS.items():
        if args.only and name != args.only:
            continue
        specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        shape_str = ";".join("x".join(map(str, s)) for s in shapes)
        manifest.append(f"{name} {os.path.basename(path)} {shape_str}")
        print(f"  {name}: {len(text)} chars -> {path}")

    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts + manifest to {args.out}")


if __name__ == "__main__":
    main()
