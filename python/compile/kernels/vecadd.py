"""L1 Pallas kernel: vector addition (paper §3.2's running example).

TPU adaptation of the paper's design (DESIGN.md §Hardware-Adaptation):
the grid dimension plays the role of the temporal axis — one block per
grid step streams HBM→VMEM exactly like the issuer feeds the
multi-pumped adder one narrow transaction per fast cycle. The compute
body is width-agnostic, as in the paper: changing ``block`` rebalances
the "data-path width" without touching the kernel.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls (see /opt/xla-example/README.md).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def vecadd(x, y, block=512):
    """z = x + y over 1-D arrays whose length divides ``block``."""
    n = x.shape[0]
    if n % block != 0:
        block = n  # single block for odd sizes (tests)
    grid = (n // block,)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x, y)
