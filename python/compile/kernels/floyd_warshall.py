"""L1 Pallas kernel: Floyd-Warshall relaxation.

The paper's point (§4.4): FW cannot be traditionally vectorized — every
k iteration depends on the previous one — but it CAN be temporally
vectorized: keep the sequential k loop, feed the matrix wide, pack the
relaxations in time. The TPU mapping keeps the sequential dependency as
a `fori_loop` *around* a Pallas kernel that relaxes the whole matrix
for one k: dependencies preserved, data path wide.
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _relax_kernel(d_ref, k_ref, o_ref):
    d = d_ref[...]
    k = k_ref[0]
    col = lax.dynamic_slice_in_dim(d, k, 1, axis=1)  # (n, 1)
    row = lax.dynamic_slice_in_dim(d, k, 1, axis=0)  # (1, n)
    o_ref[...] = jnp.minimum(d, col + row)


def relax(d, k):
    """One k-iteration of FW over the full (n, n) matrix."""
    n = d.shape[0]
    return pl.pallas_call(
        _relax_kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(d, jnp.array([k], dtype=jnp.int32))


@jax.jit
def floyd_warshall(d):
    """All-pairs shortest paths with the k loop OUTSIDE the kernel —
    the temporal-vectorization structure."""
    n = d.shape[0]

    def body(k, dist):
        return relax(dist, k)

    return lax.fori_loop(0, n, body, d)
