"""L1 Pallas kernels: Jacobi-3D and Diffusion-3D stencil stages.

The FPGA version (StencilFlow) streams the domain through line buffers
sized to two planes of the volume; the TPU analog tiles the volume over
the leading (x) grid dimension with a one-plane halo on each side —
VMEM holds (bx+2)·ny·nz floats per step, the line-buffer working set.
The boundary convention is passthrough, matching `ref.py` and the Rust
simulator's `stencil_point`.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jacobi_body(v):
    s = (
        v[:-2, 1:-1, 1:-1]
        + v[2:, 1:-1, 1:-1]
        + v[1:-1, :-2, 1:-1]
        + v[1:-1, 2:, 1:-1]
        + v[1:-1, 1:-1, :-2]
        + v[1:-1, 1:-1, 2:]
    ) * (1.0 / 6.0)
    return v.at[1:-1, 1:-1, 1:-1].set(s)


def _diffusion_body(v):
    c = v[1:-1, 1:-1, 1:-1]
    s = (
        0.5 * c
        + 0.125 * (v[:-2, 1:-1, 1:-1] + v[2:, 1:-1, 1:-1])
        + 0.0833 * (v[1:-1, :-2, 1:-1] + v[1:-1, 2:, 1:-1])
        + 0.0917 * (v[1:-1, 1:-1, :-2] + v[1:-1, 1:-1, 2:])
    )
    return v.at[1:-1, 1:-1, 1:-1].set(s)


def _make_kernel(body):
    def kernel(x_ref, o_ref):
        o_ref[...] = body(x_ref[...])

    return kernel


@functools.partial(jax.jit, static_argnames=("kind",))
def stencil_step(v, kind="jacobi3d"):
    """One stencil stage over the whole (nx, ny, nz) volume.

    A single grid step keeps the full volume in VMEM — valid for the
    verification sizes (32³ ≈ 128 KiB). For paper-scale domains the
    x-tiled variant `stencil_step_tiled` bounds the footprint.
    """
    body = _jacobi_body if kind == "jacobi3d" else _diffusion_body
    return pl.pallas_call(
        _make_kernel(body),
        out_shape=jax.ShapeDtypeStruct(v.shape, jnp.float32),
        interpret=True,
    )(v)


@functools.partial(jax.jit, static_argnames=("kind", "bx"))
def stencil_step_tiled(v, kind="jacobi3d", bx=8):
    """One stencil stage tiled over x with a ±1-plane halo.

    The halo is delivered as two pre-shifted views (`x-1` and `x+1`
    planes) so every grid step works on aligned (bx, ny, nz) blocks —
    VMEM holds three input tiles plus the output tile, the line-buffer
    working set of the FPGA implementation. Global x-boundary planes
    pass through, selected with an in-kernel iota mask.
    """
    nx, ny, nz = v.shape
    assert nx % bx == 0

    def kernel(vm_ref, vc_ref, vp_ref, o_ref):
        i = pl.program_id(0)
        vm, vc, vp = vm_ref[...], vc_ref[...], vp_ref[...]
        # y/z face neighbours from intra-tile shifts of the centre tile
        ym = jnp.concatenate([vc[:, :1], vc[:, :-1]], axis=1)
        yp = jnp.concatenate([vc[:, 1:], vc[:, -1:]], axis=1)
        zm = jnp.concatenate([vc[:, :, :1], vc[:, :, :-1]], axis=2)
        zp = jnp.concatenate([vc[:, :, 1:], vc[:, :, -1:]], axis=2)
        if kind == "jacobi3d":
            s = (vm + vp + ym + yp + zm + zp) * (1.0 / 6.0)
        else:
            s = 0.5 * vc + 0.125 * (vm + vp) + 0.0833 * (ym + yp) + 0.0917 * (zm + zp)
        # boundary passthrough: global x index of each plane in the tile
        gx = i * bx + jax.lax.broadcasted_iota(jnp.int32, (bx, ny, nz), 0)
        gy = jax.lax.broadcasted_iota(jnp.int32, (bx, ny, nz), 1)
        gz = jax.lax.broadcasted_iota(jnp.int32, (bx, ny, nz), 2)
        interior = (
            (gx > 0)
            & (gx < nx - 1)
            & (gy > 0)
            & (gy < ny - 1)
            & (gz > 0)
            & (gz < nz - 1)
        )
        o_ref[...] = jnp.where(interior, s, vc)

    # pre-shifted x-neighbour views (clamped at the global boundary —
    # those lanes are overwritten by the passthrough mask anyway)
    vxm = jnp.concatenate([v[:1], v[:-1]], axis=0)
    vxp = jnp.concatenate([v[1:], v[-1:]], axis=0)
    spec = pl.BlockSpec((bx, ny, nz), lambda i: (i, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(nx // bx,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((nx, ny, nz), jnp.float32),
        interpret=True,
    )(vxm, v, vxp)


def stencil_chain(v, stages, kind="jacobi3d"):
    """S chained stages — the paper's §4.3 workload."""
    for _ in range(stages):
        v = stencil_step(v, kind=kind)
    return v
