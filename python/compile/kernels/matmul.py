"""L1 Pallas kernel: blocked GEMM with a temporal K-grid.

This is the paper's core insight mapped to the TPU (DESIGN.md
§Hardware-Adaptation): the FPGA version keeps ONE systolic compute
block and feeds it wider data over multiple fast cycles; here we keep
ONE MXU-shaped block computation (``bm×bk @ bk×bn``) and iterate it
over the K grid dimension with a VMEM accumulator — the compute block
is reused temporally while BlockSpecs (the issuer/packer analog)
schedule the HBM→VMEM data movement.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, bm=128, bn=128, bk=128):
    """C = A @ B for f32 A:(n,k), B:(k,m), block sizes dividing shapes.

    MXU-aligned default blocks (128×128). VMEM footprint per grid step:
    bm·bk + bk·bn + bm·bn floats = 192 KiB at the default — comfortably
    under the ~16 MiB VMEM budget, leaving room for double buffering.
    """
    n, k = a.shape
    k2, m = b.shape
    assert k == k2, f"shape mismatch {a.shape} @ {b.shape}"
    bm, bn, bk = min(bm, n), min(bn, m), min(bk, k)
    assert n % bm == 0 and m % bn == 0 and k % bk == 0
    grid = (n // bm, m // bn, k // bk)  # K innermost: temporal reuse
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(a, b)
