from . import floyd_warshall, matmul, ref, stencil, vecadd  # noqa: F401
