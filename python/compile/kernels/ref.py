"""Pure-jnp correctness oracles.

These are the single source of truth for the *numerics* of every
benchmark. Three consumers assert against them:

* pytest checks every Pallas kernel against its oracle
  (``python/tests/test_kernels.py``);
* the AOT models in ``model.py`` call the Pallas kernels, so the HLO
  artifacts inherit the checked semantics;
* the Rust simulator's functional mode reproduces the same formulas
  (``rust/src/sim/process.rs``) and the integration tests compare its
  output against the PJRT-executed artifacts.

The stencil boundary convention is *passthrough* (halo points copy the
input), matching the hardware line-buffer implementation. Floyd-
Warshall uses the finite sentinel ``INF = 1e30`` instead of ``inf`` so
that hardware adders never see non-finite values (paper designs do the
same).
"""

import jax.numpy as jnp
from jax import lax

INF = 1.0e30


def vecadd(x, y):
    """z = x + y."""
    return x + y


def matmul(a, b):
    """Plain f32 GEMM."""
    return jnp.matmul(a, b, preferred_element_type=jnp.float32)


def jacobi3d(v):
    """One Jacobi-3D step: interior = mean of the 6 face neighbours,
    boundary passthrough. v has shape (nx, ny, nz)."""
    v = jnp.asarray(v)
    s = (
        v[:-2, 1:-1, 1:-1]
        + v[2:, 1:-1, 1:-1]
        + v[1:-1, :-2, 1:-1]
        + v[1:-1, 2:, 1:-1]
        + v[1:-1, 1:-1, :-2]
        + v[1:-1, 1:-1, 2:]
    ) * (1.0 / 6.0)
    return v.at[1:-1, 1:-1, 1:-1].set(s)


def diffusion3d(v):
    """One Diffusion-3D step (higher arithmetic intensity), boundary
    passthrough."""
    v = jnp.asarray(v)
    c = v[1:-1, 1:-1, 1:-1]
    s = (
        0.5 * c
        + 0.125 * (v[:-2, 1:-1, 1:-1] + v[2:, 1:-1, 1:-1])
        + 0.0833 * (v[1:-1, :-2, 1:-1] + v[1:-1, 2:, 1:-1])
        + 0.0917 * (v[1:-1, 1:-1, :-2] + v[1:-1, 1:-1, 2:])
    )
    return v.at[1:-1, 1:-1, 1:-1].set(s)


def stencil_chain(v, stages, kind="jacobi3d"):
    """S chained stencil stages (paper §4.3)."""
    step = jacobi3d if kind == "jacobi3d" else diffusion3d
    for _ in range(stages):
        v = step(v)
    return v


def floyd_warshall(d):
    """All-pairs shortest paths; d is (n, n) with INF sentinels."""
    n = d.shape[0]

    def body(k, dist):
        return jnp.minimum(dist, dist[:, k][:, None] + dist[k, :][None, :])

    return lax.fori_loop(0, n, body, d)
