"""L2 model + AOT lowering checks: every exported model lowers to HLO
text that the xla 0.5.1 text parser accepts (smoke: non-empty,
ENTRY present, correct parameter count)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.aot import to_hlo_text
from compile.kernels import ref


@pytest.mark.parametrize("name", list(model.MODELS))
def test_models_lower_to_hlo_text(name):
    fn, shapes = model.MODELS[name]
    specs = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    assert len(text) > 200
    assert "ENTRY" in text
    assert text.count("parameter(") >= len(shapes)


def test_vecadd_model_executes():
    r = np.random.default_rng(0)
    x = r.uniform(-1, 1, model.VECADD_N).astype(np.float32)
    y = r.uniform(-1, 1, model.VECADD_N).astype(np.float32)
    (z,) = model.vecadd(x, y)
    np.testing.assert_allclose(np.asarray(z), x + y, rtol=1e-6)


def test_stencil_model_matches_ref_chain():
    r = np.random.default_rng(1)
    v = r.uniform(-1, 1, (model.STENCIL_NX, model.STENCIL_NY, model.STENCIL_NZ)).astype(
        np.float32
    )
    (out,) = model.jacobi3d(v)
    want = ref.stencil_chain(jnp.asarray(v), model.STENCIL_STAGES, kind="jacobi3d")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_fw_model_executes():
    r = np.random.default_rng(2)
    d = np.full((model.FW_N, model.FW_N), ref.INF, dtype=np.float32)
    np.fill_diagonal(d, 0.0)
    idx = r.integers(0, model.FW_N, size=(200, 2))
    for i, j in idx:
        d[i, j] = min(d[i, j], float(r.uniform(0.1, 5.0)))
    (out,) = model.floyd_warshall(d)
    out = np.asarray(out)
    assert (out <= d + 1e-3).all()
    assert (np.diag(out) == 0.0).all()
