"""Kernel-vs-oracle correctness: the CORE numeric signal of the stack.

Every Pallas kernel (interpret=True) is checked against the pure-jnp
oracle in `ref.py`, with hypothesis sweeping shapes and data.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import floyd_warshall as fw
from compile.kernels import matmul as mm
from compile.kernels import ref
from compile.kernels import stencil as stn
from compile.kernels import vecadd as va

RNG = np.random.default_rng(1234)


def rnd(*shape):
    return RNG.uniform(-1.0, 1.0, size=shape).astype(np.float32)


def assert_close(a, b, rtol=1e-5, atol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=rtol, atol=atol)


# ---------- vecadd ----------

class TestVecAdd:
    def test_basic(self):
        x, y = rnd(4096), rnd(4096)
        assert_close(va.vecadd(x, y), ref.vecadd(x, y))

    @settings(max_examples=20, deadline=None)
    @given(
        n_blocks=st.integers(1, 8),
        block=st.sampled_from([8, 32, 128, 512]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_shapes_and_blocks(self, n_blocks, block, seed):
        r = np.random.default_rng(seed)
        n = n_blocks * block
        x = r.uniform(-10, 10, n).astype(np.float32)
        y = r.uniform(-10, 10, n).astype(np.float32)
        assert_close(va.vecadd(x, y, block=block), x + y)

    def test_non_divisible_length_falls_back(self):
        x, y = rnd(100), rnd(100)
        assert_close(va.vecadd(x, y, block=64), x + y)

    def test_special_values(self):
        x = np.array([0.0, -0.0, 1e30, -1e30], dtype=np.float32)
        y = np.array([0.0, 0.0, 1e30, 1e30], dtype=np.float32)
        assert_close(va.vecadd(x, y), x + y)


# ---------- matmul ----------

class TestMatmul:
    def test_basic_128(self):
        a, b = rnd(128, 128), rnd(128, 128)
        assert_close(mm.matmul(a, b), ref.matmul(a, b), rtol=1e-4, atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(
        n=st.sampled_from([32, 64, 128]),
        m=st.sampled_from([32, 64]),
        k=st.sampled_from([32, 64, 128]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_rectangular(self, n, m, k, seed):
        r = np.random.default_rng(seed)
        a = r.uniform(-1, 1, (n, k)).astype(np.float32)
        b = r.uniform(-1, 1, (k, m)).astype(np.float32)
        got = mm.matmul(a, b, bm=32, bn=32, bk=32)
        assert_close(got, a @ b, rtol=1e-4, atol=1e-4)

    def test_k_grid_accumulation(self):
        # many K blocks: exercises the temporal accumulator
        a, b = rnd(32, 256), rnd(256, 32)
        got = mm.matmul(a, b, bm=32, bn=32, bk=32)
        assert_close(got, a @ b, rtol=1e-4, atol=1e-4)

    def test_identity(self):
        a = rnd(64, 64)
        eye = np.eye(64, dtype=np.float32)
        assert_close(mm.matmul(a, eye, bm=32, bn=32, bk=32), a, rtol=1e-5)


# ---------- stencils ----------

class TestStencil:
    @pytest.mark.parametrize("kind", ["jacobi3d", "diffusion3d"])
    def test_single_step(self, kind):
        v = rnd(16, 12, 8)
        oracle = ref.jacobi3d if kind == "jacobi3d" else ref.diffusion3d
        assert_close(stn.stencil_step(v, kind=kind), oracle(v), rtol=1e-5)

    @pytest.mark.parametrize("kind", ["jacobi3d", "diffusion3d"])
    def test_chain(self, kind):
        v = rnd(8, 8, 8)
        got = stn.stencil_chain(v, 4, kind=kind)
        want = ref.stencil_chain(v, 4, kind=kind)
        assert_close(got, want, rtol=1e-4, atol=1e-5)

    def test_boundary_passthrough(self):
        v = rnd(8, 8, 8)
        out = np.asarray(stn.stencil_step(v, kind="jacobi3d"))
        np.testing.assert_array_equal(out[0], v[0])
        np.testing.assert_array_equal(out[-1], v[-1])
        np.testing.assert_array_equal(out[:, 0], v[:, 0])
        np.testing.assert_array_equal(out[:, :, -1], v[:, :, -1])

    @settings(max_examples=8, deadline=None)
    @given(
        nx=st.sampled_from([8, 16]),
        ny=st.sampled_from([4, 8]),
        nz=st.sampled_from([4, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_shape_sweep(self, nx, ny, nz, seed):
        r = np.random.default_rng(seed)
        v = r.uniform(-1, 1, (nx, ny, nz)).astype(np.float32)
        assert_close(stn.stencil_step(v), ref.jacobi3d(v), rtol=1e-5)

    def test_tiled_matches_untiled(self):
        v = rnd(16, 8, 8)
        tiled = stn.stencil_step_tiled(v, bx=4)
        assert_close(tiled, ref.jacobi3d(v), rtol=1e-5)

    def test_constant_field_is_fixed_point(self):
        v = np.full((8, 8, 8), 3.25, dtype=np.float32)
        assert_close(stn.stencil_step(v, kind="jacobi3d"), v)


# ---------- floyd-warshall ----------

def random_graph(n, seed, density=0.4):
    r = np.random.default_rng(seed)
    d = np.full((n, n), ref.INF, dtype=np.float32)
    np.fill_diagonal(d, 0.0)
    mask = r.uniform(size=(n, n)) < density
    w = r.uniform(0.1, 10.0, size=(n, n)).astype(np.float32)
    d = np.where(mask, np.minimum(d, w), d)
    np.fill_diagonal(d, 0.0)
    return d


def fw_numpy(d):
    d = d.copy()
    n = d.shape[0]
    for k in range(n):
        d = np.minimum(d, d[:, k][:, None] + d[k, :][None, :])
    return d


class TestFloydWarshall:
    def test_small_chain(self):
        inf = ref.INF
        d = np.array(
            [[0.0, 1.0, 9.0], [inf, 0.0, 2.0], [inf, inf, 0.0]], dtype=np.float32
        )
        got = np.asarray(fw.floyd_warshall(jnp.asarray(d)))
        assert got[0, 2] == 3.0

    @settings(max_examples=6, deadline=None)
    @given(n=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31 - 1))
    def test_matches_numpy(self, n, seed):
        d = random_graph(n, seed)
        got = np.asarray(fw.floyd_warshall(jnp.asarray(d)))
        assert_close(got, fw_numpy(d), rtol=1e-5)

    def test_kernel_single_relaxation(self):
        d = random_graph(8, 5)
        got = np.asarray(fw.relax(jnp.asarray(d), 3))
        want = np.minimum(d, d[:, 3][:, None] + d[3, :][None, :])
        assert_close(got, want)

    def test_ref_oracle_agrees_with_numpy(self):
        d = random_graph(12, 9)
        assert_close(np.asarray(ref.floyd_warshall(jnp.asarray(d))), fw_numpy(d))

    def test_triangle_inequality_holds(self):
        d = random_graph(10, 11)
        out = np.asarray(fw.floyd_warshall(jnp.asarray(d)))
        n = out.shape[0]
        for i in range(n):
            for j in range(n):
                for k in range(n):
                    assert out[i, j] <= out[i, k] + out[k, j] + 1e-3
