//! Integration: transformation sequences and their error paths.

use temporal_vec::analysis::scope_movement;
use temporal_vec::ir::builder::vecadd_sdfg;
use temporal_vec::ir::validate::validate;
use temporal_vec::ir::{Node, PumpMode};
use temporal_vec::transforms::{MultiPump, PassManager, StreamingComposition, Transform, Vectorize};

#[test]
fn canonical_sequence_matches_paper_figure3() {
    // Figure 3: vectorize (box 1) → streaming (box 2) → multipump (box 3)
    let mut g = vecadd_sdfg(1);
    let mut pm = PassManager::new();
    pm.run(&mut g, &Vectorize::new("vadd", 4)).unwrap();
    pm.run(&mut g, &StreamingComposition::default()).unwrap();
    pm.run(&mut g, &MultiPump::resource(2)).unwrap();
    validate(&g).unwrap();

    // final graph: 2 readers, 1 writer, 6 CDC modules, compute in CL1
    let count = |f: &dyn Fn(&Node) -> bool| g.node_ids().filter(|i| f(g.node(*i))).count();
    assert_eq!(count(&|n| matches!(n, Node::Reader { .. })), 2);
    assert_eq!(count(&|n| matches!(n, Node::Writer { .. })), 1);
    assert_eq!(count(&|n| n.is_cdc()), 6);
    let entry = g.find_map_entry("vadd").unwrap();
    assert!(g.in_fast_domain(entry));
}

#[test]
fn streaming_is_required_before_pumping() {
    let mut g = vecadd_sdfg(4);
    let err = MultiPump::resource(2).can_apply(&g).unwrap_err();
    assert!(err.contains("not streamed"));
    let mut pm = PassManager::new();
    pm.run(&mut g, &StreamingComposition::default()).unwrap();
    MultiPump::resource(2).can_apply(&g).unwrap();
}

#[test]
fn order_vectorize_after_streaming_rejected() {
    // vectorization requires direct array access; after streaming the
    // scope pops streams, so the rewrite must refuse
    let mut g = vecadd_sdfg(1);
    let mut pm = PassManager::new();
    pm.run(&mut g, &StreamingComposition::default()).unwrap();
    assert!(Vectorize::new("vadd", 4).can_apply(&g).is_err());
}

#[test]
fn throughput_mode_on_scalar_streams() {
    // throughput mode has no divisibility requirement
    let mut g = vecadd_sdfg(1);
    let mut pm = PassManager::new();
    pm.run(&mut g, &StreamingComposition::default()).unwrap();
    pm.run(&mut g, &MultiPump::throughput(2)).unwrap();
    validate(&g).unwrap();
    // external streams widened to 2 lanes
    let wide = g
        .containers
        .values()
        .filter(|d| d.storage.is_stream() && d.vtype.lanes == 2)
        .count();
    assert!(wide >= 3, "expected widened boundary streams, got {wide}");
}

#[test]
fn movement_tracing_after_streaming_sees_streams() {
    let mut g = vecadd_sdfg(2);
    let mut pm = PassManager::new();
    pm.run(&mut g, &StreamingComposition::default()).unwrap();
    let entry = g.find_map_entry("vadd").unwrap();
    let mv = scope_movement(&g, entry).unwrap();
    for acc in mv.all() {
        let decl = g.container(&acc.data).unwrap();
        assert!(decl.storage.is_stream(), "{} not a stream", acc.data);
    }
}

#[test]
fn pumping_factor_three_resource_mode() {
    let mut g = vecadd_sdfg(1);
    let mut pm = PassManager::new();
    pm.run(&mut g, &Vectorize::new("vadd", 6)).unwrap();
    pm.run(&mut g, &StreamingComposition::default()).unwrap();
    pm.run(&mut g, &MultiPump::uniform(3, PumpMode::Resource)).unwrap();
    // fast side = 2 lanes
    let fast = g
        .containers
        .iter()
        .filter(|(n, d)| n.ends_with("_fast") && d.vtype.lanes == 2)
        .count();
    assert_eq!(fast, 3);
}
