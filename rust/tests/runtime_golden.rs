//! Integration: PJRT golden-model execution (requires `make artifacts`).
//!
//! The decisive end-to-end checks: for every application, the Rust
//! simulator's functional output equals the AOT-compiled JAX/Pallas
//! model executed through the PJRT CPU client.

use std::path::Path;

use temporal_vec::apps;
use temporal_vec::coordinator::{compile, BuildSpec};
use temporal_vec::ir::PumpMode;
use temporal_vec::runtime::{artifact, GoldenRunner};
use temporal_vec::sim::{run_functional, Hbm};
use temporal_vec::util::Rng;

/// The golden checks need both the AOT artifacts (`make artifacts`)
/// and the PJRT backend (`--features xla-runtime`). When either is
/// missing the tests skip — the compiler/simulator suites do not
/// depend on them.
fn runner() -> Option<GoldenRunner> {
    let dir = artifact::artifacts_dir();
    if !Path::new(&dir).join("manifest.txt").exists() {
        eprintln!("skipping golden test: artifacts missing (run `make artifacts`)");
        return None;
    }
    match GoldenRunner::new(&dir) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping golden test: {e}");
            None
        }
    }
}

#[test]
fn manifest_lists_all_models() {
    // manifest coverage does not need the PJRT backend — only the
    // artifacts; keep it alive in default (stub) builds
    let dir = artifact::artifacts_dir();
    if !Path::new(&dir).join("manifest.txt").exists() {
        eprintln!("skipping golden test: artifacts missing (run `make artifacts`)");
        return;
    }
    let m = temporal_vec::runtime::Manifest::load(&dir).unwrap();
    for name in ["vecadd", "matmul", "jacobi3d", "diffusion3d", "floyd_warshall"] {
        assert!(m.get(name).is_some(), "missing {name}");
    }
}

#[test]
fn vecadd_sim_equals_golden() {
    let mut r = match runner() {
        Some(r) => r,
        None => return,
    };
    let n = apps::vecadd::GOLDEN_N;
    let c = compile(
        BuildSpec::new(apps::vecadd::build())
            .vectorized("vadd", 8)
            .pumped(2, PumpMode::Resource)
            .bind("N", n),
    )
    .unwrap();
    let mut rng = Rng::new(101);
    let x = rng.f32_vec(n as usize);
    let y = rng.f32_vec(n as usize);
    let mut hbm = Hbm::new();
    hbm.load("x", x.clone());
    hbm.load("y", y.clone());
    let got = run_functional(&c.design, hbm).unwrap();
    let want = r.run("vecadd", &[&x, &y]).unwrap();
    assert_eq!(got.hbm.read("z"), want.as_slice());
}

#[test]
fn matmul_sim_equals_golden() {
    let mut r = match runner() {
        Some(r) => r,
        None => return,
    };
    let n = apps::matmul::GOLDEN_NMK;
    let mut spec = BuildSpec::new(apps::matmul::build(4)).pumped(2, PumpMode::Resource);
    for (s, v) in apps::matmul::bindings(n) {
        spec = spec.bind(&s, v);
    }
    let c = compile(spec).unwrap();
    let mut rng = Rng::new(102);
    let a = rng.f32_vec((n * n) as usize);
    let b = rng.f32_vec((n * n) as usize);
    let mut hbm = Hbm::new();
    hbm.load("A", a.clone());
    hbm.load("B", b.clone());
    let got = run_functional(&c.design, hbm).unwrap();
    let want = r.run("matmul", &[&a, &b]).unwrap();
    for (i, (g, w)) in got.hbm.read("C").iter().zip(&want).enumerate() {
        assert!(
            (g - w).abs() <= 1e-4 * w.abs().max(1.0),
            "elem {i}: {g} vs {w}"
        );
    }
}

#[test]
fn stencil_chains_sim_equal_golden() {
    let mut r = match runner() {
        Some(r) => r,
        None => return,
    };
    for (name, kind) in [
        ("jacobi3d", temporal_vec::ir::StencilKind::Jacobi3D),
        ("diffusion3d", temporal_vec::ir::StencilKind::Diffusion3D),
    ] {
        let w = apps::stencil::paper_vec_width(kind);
        let nx = apps::stencil::GOLDEN_NX;
        let c = compile(
            BuildSpec::new(apps::stencil::build(kind, apps::stencil::GOLDEN_STAGES, w))
                .pumped(2, PumpMode::Resource)
                .bind("NX", nx)
                .bind("NY", 32)
                .bind("NZ", 32)
                .bind("NZ_v", 32 / w as i64),
        )
        .unwrap();
        let mut rng = Rng::new(103);
        let v = rng.f32_vec((nx * 32 * 32) as usize);
        let mut hbm = Hbm::new();
        hbm.load("v_in", v.clone());
        let got = run_functional(&c.design, hbm).unwrap();
        let want = r.run(name, &[&v]).unwrap();
        for (i, (g, wv)) in got.hbm.read("v_out").iter().zip(&want).enumerate() {
            assert!((g - wv).abs() < 1e-4, "{name} elem {i}: {g} vs {wv}");
        }
    }
}

#[test]
fn floyd_warshall_sim_equals_golden() {
    let mut r = match runner() {
        Some(r) => r,
        None => return,
    };
    let n = apps::floyd_warshall::GOLDEN_N;
    let c = compile(
        BuildSpec::new(apps::floyd_warshall::build())
            .pumped(2, PumpMode::Throughput)
            .bind("N", n),
    )
    .unwrap();
    let d = apps::floyd_warshall::random_graph(n as usize, 104, 0.25);
    let mut hbm = Hbm::new();
    hbm.load("dist", d.clone());
    let got = run_functional(&c.design, hbm).unwrap();
    let want = r.run("floyd_warshall", &[&d]).unwrap();
    assert_eq!(got.hbm.read("dist"), want.as_slice());
}
