//! Property-based tests (util::quickcheck) over the compiler's
//! invariants: transformations preserve semantics and resources behave
//! as the paper claims for *any* valid parameter combination.

use temporal_vec::apps;
use temporal_vec::coordinator::{compile, BuildSpec};
use temporal_vec::ir::PumpMode;
use temporal_vec::sim::{run_functional, Hbm};
use temporal_vec::symbolic::{Expr, SymbolTable};
use temporal_vec::util::quickcheck::{assert_allclose, forall};

#[test]
fn prop_affine_algebra_ring_laws() {
    forall("affine-ring", 0xA1, 300, |g| {
        let mk = |g: &mut temporal_vec::util::quickcheck::Gen| {
            let c = g.i64(-50, 50);
            let a = g.i64(-5, 5);
            let b = g.i64(-5, 5);
            Expr::int(c)
                .add(&Expr::sym("i").scale(a))
                .add(&Expr::sym("j").scale(b))
        };
        let (x, y, z) = (mk(g), mk(g), mk(g));
        // commutativity + associativity + distributivity over scale
        if x.add(&y) != y.add(&x) {
            return Err("add not commutative".into());
        }
        if x.add(&y.add(&z)) != x.add(&y).add(&z) {
            return Err("add not associative".into());
        }
        let k = g.i64(-4, 4);
        if x.add(&y).scale(k) != x.scale(k).add(&y.scale(k)) {
            return Err("scale not distributive".into());
        }
        // eval is a homomorphism
        let env = SymbolTable::new().with("i", g.i64(-10, 10)).with("j", g.i64(-10, 10));
        let lhs = x.add(&y).eval(&env).unwrap();
        let rhs = x.eval(&env).unwrap() + y.eval(&env).unwrap();
        if lhs != rhs {
            return Err(format!("eval mismatch {lhs} vs {rhs}"));
        }
        Ok(())
    });
}

#[test]
fn prop_subst_then_eval_equals_eval_extended() {
    forall("subst-eval", 0xA2, 200, |g| {
        let a = g.i64(-6, 6);
        let c = g.i64(-20, 20);
        let e = Expr::sym("i").scale(a).add(&Expr::int(c));
        let inner = Expr::sym("j").scale(g.i64(-4, 4)).add(&Expr::int(g.i64(-9, 9)));
        let j = g.i64(-8, 8);
        let env_j = SymbolTable::new().with("j", j);
        let substituted = e.subst("i", &inner).eval(&env_j).unwrap();
        let i_val = inner.eval(&env_j).unwrap();
        let direct = e.eval(&SymbolTable::new().with("i", i_val)).unwrap();
        if substituted != direct {
            return Err(format!("{substituted} vs {direct}"));
        }
        Ok(())
    });
}

#[test]
fn prop_vecadd_pipeline_correct_for_any_width_and_factor() {
    forall("vecadd-widths", 0xB1, 12, |g| {
        let factor = *g.choose(&[2usize, 4]);
        let lanes = factor * *g.choose(&[1usize, 2, 4]);
        let blocks = g.usize(4, 40) as i64;
        let n = blocks * lanes as i64;
        let c = compile(
            BuildSpec::new(apps::vecadd::build())
                .vectorized("vadd", lanes)
                .pumped(factor, PumpMode::Resource)
                .bind("N", n),
        )
        .map_err(|e| e.to_string())?;
        let x = g.vec_f32(n as usize);
        let y = g.vec_f32(n as usize);
        let mut hbm = Hbm::new();
        hbm.load("x", x.clone());
        hbm.load("y", y.clone());
        let out = run_functional(&c.design, hbm).map_err(|e| e.to_string())?;
        let want: Vec<f32> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
        assert_allclose(out.hbm.read("z"), &want, 0.0, 0.0)
    });
}

#[test]
fn prop_dsp_scales_inversely_with_pump_factor() {
    forall("dsp-inverse", 0xB2, 10, |g| {
        let factor = *g.choose(&[2usize, 4]);
        let lanes = factor * 2;
        let n = 64 * lanes as i64;
        let base = compile(
            BuildSpec::new(apps::vecadd::build()).vectorized("vadd", lanes).bind("N", n),
        )
        .map_err(|e| e.to_string())?;
        let pumped = compile(
            BuildSpec::new(apps::vecadd::build())
                .vectorized("vadd", lanes)
                .pumped(factor, PumpMode::Resource)
                .bind("N", n),
        )
        .map_err(|e| e.to_string())?;
        let want = base.report.resources.dsp / factor as f64;
        if (pumped.report.resources.dsp - want).abs() > 1e-9 {
            return Err(format!(
                "factor {factor}: dsp {} (want {want})",
                pumped.report.resources.dsp
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_fw_pumping_invariant_over_random_graphs() {
    forall("fw-invariance", 0xB3, 6, |g| {
        let n = *g.choose(&[8usize, 12, 16]);
        let density = g.f32(0.15, 0.6) as f64;
        let seed = g.usize(0, 1 << 30) as u64;
        let d = apps::floyd_warshall::random_graph(n, seed, density);
        let mut results = Vec::new();
        for pump in [false, true] {
            let mut spec =
                BuildSpec::new(apps::floyd_warshall::build()).bind("N", n as i64);
            if pump {
                spec = spec.pumped(2, PumpMode::Throughput);
            }
            let c = compile(spec).map_err(|e| e.to_string())?;
            let mut hbm = Hbm::new();
            hbm.load("dist", d.clone());
            let out = run_functional(&c.design, hbm).map_err(|e| e.to_string())?;
            results.push(out.hbm.read("dist").to_vec());
        }
        if results[0] != results[1] {
            return Err("pumped FW diverged from original".into());
        }
        // and both equal the CPU reference
        let want = apps::floyd_warshall::reference(&d, n);
        assert_allclose(&results[0], &want, 0.0, 0.0)
    });
}

#[test]
fn prop_effective_clock_never_exceeds_cl0() {
    forall("eff-clock", 0xB4, 20, |g| {
        let lanes = *g.choose(&[2usize, 4, 8]);
        let n = 128 * lanes as i64;
        let seed = g.usize(0, 1 << 20) as u64;
        let c = compile(
            BuildSpec::new(apps::vecadd::build())
                .vectorized("vadd", lanes)
                .pumped(2, PumpMode::Resource)
                .bind("N", n)
                .seeded(seed),
        )
        .map_err(|e| e.to_string())?;
        let eff = c.report.effective_mhz;
        let cl0 = c.report.cl0.achieved_mhz;
        let cl1 = c.report.cl1.unwrap().achieved_mhz;
        if eff > cl0 + 1e-9 || eff > cl1 / 2.0 + 1e-9 {
            return Err(format!("eff {eff} vs cl0 {cl0} cl1 {cl1}"));
        }
        Ok(())
    });
}

#[test]
fn prop_fifo_preserves_order_and_counts() {
    use temporal_vec::sim::channel::Fifo;
    use temporal_vec::sim::Arena;
    forall("fifo-order", 0xC1, 100, |g| {
        let cap = g.usize(1, 32);
        let lanes = g.usize(1, 4);
        let mut ar = Arena::new();
        let mut f = Fifo::new("q", lanes, cap);
        let n = g.usize(1, 200);
        let mut sent: Vec<f32> = Vec::new();
        let mut got: Vec<f32> = Vec::new();
        let mut next = 0u32;
        for _ in 0..n {
            if g.bool() && !f.is_full() {
                let txn: Vec<f32> = (0..lanes).map(|l| (next + l as u32) as f32).collect();
                sent.extend_from_slice(&txn);
                f.push(ar.alloc_from(&txn)).map_err(|_| "push failed".to_string())?;
                next += lanes as u32;
            } else if let Some(t) = f.pop() {
                got.extend_from_slice(ar.get(t));
                ar.free(t);
            }
            if f.len() > cap {
                return Err("capacity exceeded".into());
            }
        }
        while let Some(t) = f.pop() {
            got.extend_from_slice(ar.get(t));
            ar.free(t);
        }
        if got != sent {
            return Err("order not preserved".into());
        }
        if f.pushed != f.popped {
            return Err("push/pop accounting mismatch".into());
        }
        // every popped slot was freed: the arena must be fully idle,
        // and recycling bounds the slab to the FIFO's live peak
        if ar.stats().live != 0 {
            return Err("arena slots leaked".into());
        }
        if ar.stats().slots > cap as u64 + 1 {
            return Err(format!(
                "slab grew past capacity: {} slots for cap {cap}",
                ar.stats().slots
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_tiny_workloads_never_hang() {
    // degenerate sizes: one transaction end-to-end
    forall("tiny-sizes", 0xC2, 8, |g| {
        let lanes = *g.choose(&[2usize, 4]);
        let n = lanes as i64; // a single wide transaction
        let c = compile(
            BuildSpec::new(apps::vecadd::build())
                .vectorized("vadd", lanes)
                .pumped(2, PumpMode::Resource)
                .bind("N", n),
        )
        .map_err(|e| e.to_string())?;
        let x = g.vec_f32(n as usize);
        let y = g.vec_f32(n as usize);
        let mut hbm = Hbm::new();
        hbm.load("x", x.clone());
        hbm.load("y", y.clone());
        let out = run_functional(&c.design, hbm).map_err(|e| e.to_string())?;
        let want: Vec<f32> = x.iter().zip(&y).map(|(a, b)| a + b).collect();
        assert_allclose(out.hbm.read("z"), &want, 0.0, 0.0)
    });
}

#[test]
fn prop_rng_streams_statistically_distinct() {
    forall("rng-fork", 0xC3, 30, |g| {
        let seed = g.usize(0, 1 << 30) as u64;
        let mut root = temporal_vec::util::Rng::new(seed);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let matches = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        if matches > 0 {
            return Err(format!("forked streams collided {matches} times"));
        }
        Ok(())
    });
}

/// Full-stats equality of the event-driven exact engine against the
/// legacy per-cycle stepper on one design+input — the tentpole's
/// cycle-exactness contract, via the shared library oracle
/// `sim::exact_engines_agree` (one definition for every call site).
fn engines_must_agree(
    design: &temporal_vec::codegen::Design,
    hbm: Hbm,
    out_name: &str,
) -> Result<(), String> {
    temporal_vec::sim::exact_engines_agree(design, hbm, 10_000_000, &[out_name])
}

#[test]
fn prop_event_engine_is_cycle_exact_on_random_pumped_vecadd() {
    // randomized (width, pump mode/factor, size): the event-driven
    // run_exact must match the legacy stepper cycle for cycle
    forall("event-exact-vecadd", 0xD1, 10, |g| {
        let lanes = *g.choose(&[2usize, 4, 8]);
        let pump: Option<(usize, PumpMode)> = match g.usize(0, 4) {
            0 => None,
            1 => Some((2, PumpMode::Resource)),
            2 => Some((2, PumpMode::Throughput)),
            _ => Some((4, PumpMode::Resource)),
        };
        // resource mode must divide the width
        let pump = match pump {
            Some((m, PumpMode::Resource)) if lanes % m != 0 => None,
            p => p,
        };
        let n = (g.usize(6, 48) * lanes.max(4)) as i64;
        let mut spec =
            BuildSpec::new(apps::vecadd::build()).vectorized("vadd", lanes).bind("N", n);
        if let Some((m, mode)) = pump {
            spec = spec.pumped(m, mode);
        }
        // a randomly illegal combination (e.g. a throughput-widened
        // boundary that no longer divides N) is vacuous, not a failure
        let c = match compile(spec) {
            Ok(c) => c,
            Err(_) => return Ok(()),
        };
        let mut hbm = Hbm::new();
        hbm.load("x", g.vec_f32(n as usize));
        hbm.load("y", g.vec_f32(n as usize));
        engines_must_agree(&c.design, hbm, "z")
            .map_err(|e| format!("lanes {lanes} pump {pump:?} n {n}: {e}"))
    });
}

#[test]
fn prop_pooled_exact_outputs_bit_identical_to_functional_streams() {
    // the arena data plane must be invisible in the data: outputs of
    // the pooled exact engine — recycled slots and all — are compared
    // bit for bit (f32::to_bits) against the reference run captured
    // via the unbounded `push_unbounded` functional mode, and a second
    // exact run on the SAME warmed arena (every slot now a recycle
    // hit) must reproduce them again
    use temporal_vec::sim::{run_exact_in, Arena};
    forall("arena-bit-identical", 0xD3, 8, |g| {
        let lanes = *g.choose(&[2usize, 4, 8]);
        let pump = g.bool() && lanes % 2 == 0;
        let n = (g.usize(6, 40) * lanes) as i64;
        let mut spec =
            BuildSpec::new(apps::vecadd::build()).vectorized("vadd", lanes).bind("N", n);
        if pump {
            spec = spec.pumped(2, PumpMode::Resource);
        }
        let c = match compile(spec) {
            Ok(c) => c,
            Err(_) => return Ok(()),
        };
        let x = g.vec_f32(n as usize);
        let y = g.vec_f32(n as usize);
        let mk_hbm = || {
            let mut hbm = Hbm::new();
            hbm.load("x", x.clone());
            hbm.load("y", y.clone());
            hbm
        };
        let reference: Vec<u32> = run_functional(&c.design, mk_hbm())
            .map_err(|e| e.to_string())?
            .hbm
            .read("z")
            .iter()
            .map(|v| v.to_bits())
            .collect();
        let mut arena = Arena::new();
        for round in 0..2 {
            let out = run_exact_in(&c.design, mk_hbm(), 10_000_000, &mut arena)
                .map_err(|e| e.to_string())?;
            let bits: Vec<u32> = out.hbm.read("z").iter().map(|v| v.to_bits()).collect();
            if bits != reference {
                return Err(format!(
                    "round {round}: pooled exact output diverged from the functional \
                     byte stream (lanes {lanes}, pump {pump}, n {n})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_telemetry_on_and_off_runs_are_bit_identical() {
    // the zero-cost-when-disabled contract's other half: ENABLING
    // telemetry must be purely observational — a recorded run returns
    // bit-identical SimStats and output bits to an unrecorded one on
    // any random pumped vecadd
    use temporal_vec::sim::{run_exact_in, run_exact_observed_in, Arena};
    use temporal_vec::telemetry::Recorder;
    forall("telemetry-invisible", 0xD4, 8, |g| {
        let lanes = *g.choose(&[2usize, 4, 8]);
        let pump = g.bool() && lanes % 2 == 0;
        let n = (g.usize(6, 40) * lanes) as i64;
        let mut spec =
            BuildSpec::new(apps::vecadd::build()).vectorized("vadd", lanes).bind("N", n);
        if pump {
            spec = spec.pumped(2, PumpMode::Resource);
        }
        let c = match compile(spec) {
            Ok(c) => c,
            Err(_) => return Ok(()),
        };
        let x = g.vec_f32(n as usize);
        let y = g.vec_f32(n as usize);
        let mk_hbm = || {
            let mut hbm = Hbm::new();
            hbm.load("x", x.clone());
            hbm.load("y", y.clone());
            hbm
        };
        let plain = run_exact_in(&c.design, mk_hbm(), 10_000_000, &mut Arena::new())
            .map_err(|e| e.to_string())?;
        let rec = Recorder::new();
        let observed = run_exact_observed_in(
            &c.design,
            mk_hbm(),
            10_000_000,
            &mut Arena::new(),
            Some(&rec),
        )
        .map_err(|e| e.to_string())?;
        if plain.stats.slow_cycles != observed.stats.slow_cycles
            || plain.stats.fast_cycles != observed.stats.fast_cycles
            || plain.stats.transactions != observed.stats.transactions
            || plain.stats.bottleneck != observed.stats.bottleneck
            || plain.stats.modules != observed.stats.modules
        {
            return Err(format!(
                "SimStats diverged under observation (lanes {lanes}, pump {pump}, n {n}): \
                 {:?} vs {:?}",
                plain.stats, observed.stats
            ));
        }
        let a: Vec<u32> = plain.hbm.read("z").iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = observed.hbm.read("z").iter().map(|v| v.to_bits()).collect();
        if a != b {
            return Err("output bits diverged under observation".into());
        }
        // and the recorder actually saw the run
        if rec.events().is_empty() || rec.counters().is_empty() {
            return Err("observed run recorded nothing".into());
        }
        Ok(())
    });
}

#[test]
fn prop_all_same_mode_per_region_assignment_delegates_to_legacy_uniform() {
    // the refactor's delegation contract: a per-region assignment whose
    // entries all carry the SAME {factor, mode} must produce the exact
    // design the historic uniform path produces — asserted bit for bit
    // on the emitted HLS and RTL text, the widest observable surface
    use temporal_vec::codegen::{hls, rtl};
    use temporal_vec::ir::{RegionPump, StencilKind};
    forall("uniform-delegation", 0xD5, 8, |g| {
        let factor = *g.choose(&[2usize, 4]);
        let mode = *g.choose(&[PumpMode::Resource, PumpMode::Throughput]);
        let stages = g.usize(2, 4);
        let seed = g.usize(0, 1 << 20) as u64;
        let base = || {
            BuildSpec::new(apps::stencil::build(StencilKind::Jacobi3D, stages, 8))
                .bind("NX", 8)
                .bind("NY", 8)
                .bind("NZ", 8)
                .bind("NZ_v", 1)
                .seeded(seed)
        };
        let uniform = compile(base().pumped(factor, mode));
        let per_region = compile(
            base().pumped_per_region(vec![Some(RegionPump::new(factor, mode)); stages]),
        );
        match (uniform, per_region) {
            (Ok(u), Ok(p)) => {
                if hls::emit_hls(&u.design) != hls::emit_hls(&p.design) {
                    return Err(format!(
                        "HLS text diverged (factor {factor}, {mode:?}, {stages} stages)"
                    ));
                }
                let (ur, pr) = (rtl::emit_rtl(&u.design), rtl::emit_rtl(&p.design));
                if ur.core_sv != pr.core_sv || ur.controller_sv != pr.controller_sv {
                    return Err(format!(
                        "RTL text diverged (factor {factor}, {mode:?}, {stages} stages)"
                    ));
                }
                Ok(())
            }
            // both paths must agree on legality too
            (Err(_), Err(_)) => Ok(()),
            (Ok(_), Err(e)) => Err(format!("per-region rejected what uniform accepts: {e}")),
            (Err(e), Ok(_)) => Err(format!("uniform rejected what per-region accepts: {e}")),
        }
    });
}

#[test]
fn prop_telemetry_invisible_on_barefast_and_mode_mixed_designs() {
    // the observational contract again, but over the new region shapes
    // this PR introduces: a gearbox-free bare-fast FW domain, and a
    // stencil chain whose regions disagree on mode (throughput head,
    // resource tail) — both must return bit-identical SimStats and
    // output bits with and without a recorder attached
    use temporal_vec::ir::{RegionPump, StencilKind};
    use temporal_vec::sim::{run_exact_in, run_exact_observed_in, Arena};
    use temporal_vec::telemetry::Recorder;
    forall("telemetry-invisible-modes", 0xD6, 6, |g| {
        let barefast_arm = g.bool();
        let (c, hbm, out_name) = if barefast_arm {
            let n = *g.choose(&[8usize, 12, 16]);
            let c = compile(
                BuildSpec::new(apps::floyd_warshall::build())
                    .bind("N", n as i64)
                    .pumped(2, PumpMode::BareFast),
            )
            .map_err(|e| format!("bare-fast FW must compile: {e}"))?;
            let mut hbm = Hbm::new();
            hbm.load("dist", apps::floyd_warshall::random_graph(n, 7, 0.3));
            (c, hbm, "dist")
        } else {
            let c = compile(
                BuildSpec::new(apps::stencil::build(StencilKind::Jacobi3D, 3, 8))
                    .pumped_per_region(vec![
                        Some(RegionPump::new(2, PumpMode::Throughput)),
                        Some(RegionPump::resource(2)),
                        None,
                    ])
                    .bind("NX", 8)
                    .bind("NY", 8)
                    .bind("NZ", 8)
                    .bind("NZ_v", 1),
            )
            .map_err(|e| format!("mode-mixed stencil must compile: {e}"))?;
            let mut hbm = Hbm::new();
            hbm.load("v_in", g.vec_f32(8 * 8 * 8));
            (c, hbm, "v_out")
        };
        let plain = run_exact_in(&c.design, hbm.clone(), 10_000_000, &mut Arena::new())
            .map_err(|e| e.to_string())?;
        let rec = Recorder::new();
        let observed =
            run_exact_observed_in(&c.design, hbm, 10_000_000, &mut Arena::new(), Some(&rec))
                .map_err(|e| e.to_string())?;
        if plain.stats.slow_cycles != observed.stats.slow_cycles
            || plain.stats.fast_cycles != observed.stats.fast_cycles
            || plain.stats.transactions != observed.stats.transactions
            || plain.stats.bottleneck != observed.stats.bottleneck
            || plain.stats.modules != observed.stats.modules
        {
            return Err(format!(
                "SimStats diverged under observation (barefast_arm {barefast_arm}): \
                 {:?} vs {:?}",
                plain.stats, observed.stats
            ));
        }
        let a: Vec<u32> = plain.hbm.read(out_name).iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = observed.hbm.read(out_name).iter().map(|v| v.to_bits()).collect();
        if a != b {
            return Err("output bits diverged under observation".into());
        }
        // the mode-lettered fast-domain utilization gauge must appear
        let label = if barefast_arm { "sim.domain.cl1_m2b" } else { "sim.domain.cl1_m2" };
        if !rec.gauges().iter().any(|(k, _)| k.starts_with(label)) {
            return Err(format!("no '{label}' fast-domain gauge recorded"));
        }
        Ok(())
    });
}

#[test]
fn prop_checker_clean_designs_never_deadlock() {
    // soundness contract, forward direction: any randomized design the
    // static design-rule checker passes — uniform pumped vecadd, mixed
    // per-region stencil chains, bare-fast FW — must run to completion
    // in the exact simulator, never deadlock
    use temporal_vec::analysis::checker::check;
    use temporal_vec::ir::StencilKind;
    use temporal_vec::sim::{run_exact_in, Arena};
    forall("checker-clean-no-deadlock", 0xE1, 9, |g| {
        let arm = g.usize(0, 3);
        let (c, hbm, tag) = match arm {
            0 => {
                // uniform vecadd: random width and pump mode/factor
                let lanes = *g.choose(&[2usize, 4, 8]);
                let pump: Option<(usize, PumpMode)> = match g.usize(0, 4) {
                    0 => None,
                    1 => Some((2, PumpMode::Resource)),
                    2 => Some((2, PumpMode::Throughput)),
                    _ => Some((4, PumpMode::Resource)),
                };
                let pump = match pump {
                    Some((m, PumpMode::Resource)) if lanes % m != 0 => None,
                    p => p,
                };
                let n = (g.usize(6, 40) * lanes.max(4)) as i64;
                let mut spec = BuildSpec::new(apps::vecadd::build())
                    .vectorized("vadd", lanes)
                    .bind("N", n);
                if let Some((m, mode)) = pump {
                    spec = spec.pumped(m, mode);
                }
                let c = match compile(spec) {
                    Ok(c) => c,
                    Err(_) => return Ok(()), // illegal candidate: vacuous
                };
                let mut hbm = Hbm::new();
                hbm.load("x", g.vec_f32(n as usize));
                hbm.load("y", g.vec_f32(n as usize));
                (c, hbm, format!("vecadd lanes {lanes} pump {pump:?} n {n}"))
            }
            1 => {
                // mixed per-region stencil chain
                let stages = g.usize(2, 4);
                let factors: Vec<Option<usize>> = (0..stages)
                    .map(|_| {
                        let f = *g.choose(&[2usize, 4]);
                        g.option(f)
                    })
                    .collect();
                let mut spec =
                    BuildSpec::new(apps::stencil::build(StencilKind::Jacobi3D, stages, 8))
                        .bind("NX", 8)
                        .bind("NY", 8)
                        .bind("NZ", 8)
                        .bind("NZ_v", 1);
                if factors.iter().any(|f| f.is_some()) {
                    spec = spec.pumped_regions(factors.clone());
                }
                let c = match compile(spec) {
                    Ok(c) => c,
                    Err(_) => return Ok(()),
                };
                let mut hbm = Hbm::new();
                hbm.load("v_in", g.vec_f32(8 * 8 * 8));
                (c, hbm, format!("stencil stages {stages} factors {factors:?}"))
            }
            _ => {
                // gearbox-free bare-fast FW domain
                let n = *g.choose(&[8usize, 12, 16]);
                let c = compile(
                    BuildSpec::new(apps::floyd_warshall::build())
                        .bind("N", n as i64)
                        .pumped(2, PumpMode::BareFast),
                )
                .map_err(|e| format!("bare-fast FW must compile: {e}"))?;
                let mut hbm = Hbm::new();
                hbm.load("dist", apps::floyd_warshall::random_graph(n, 11, 0.3));
                (c, hbm, format!("bare-fast FW n {n}"))
            }
        };
        let report = check(&c.sdfg, &c.design);
        if !report.is_clean() {
            return Err(format!(
                "{tag}: checker rejected a compiled design: {}",
                report.first_error().unwrap()
            ));
        }
        run_exact_in(&c.design, hbm, 10_000_000, &mut Arena::new())
            .map_err(|e| format!("{tag}: checker-clean design failed in run_exact: {e}"))?;
        Ok(())
    });
}

#[test]
fn prop_simulator_deadlocks_carry_checker_errors() {
    // soundness contract, reverse direction: corrupt a compiled design
    // so its steady-state rates cannot balance (the writer demands
    // more transactions than the pipeline produces) — every case the
    // exact simulator reports as deadlocked must carry at least one
    // checker error, and the rate rule must in fact catch the
    // corruption statically
    use temporal_vec::analysis::checker::check;
    use temporal_vec::codegen::design::ModuleSpec;
    use temporal_vec::sim::{run_exact_in, Arena};
    forall("deadlock-implies-error", 0xE2, 8, |g| {
        let lanes = *g.choose(&[2usize, 4, 8]);
        let pump = g.bool() && lanes % 2 == 0;
        let n = (g.usize(6, 30) * lanes) as i64;
        let mut spec =
            BuildSpec::new(apps::vecadd::build()).vectorized("vadd", lanes).bind("N", n);
        if pump {
            spec = spec.pumped(2, PumpMode::Resource);
        }
        let c = match compile(spec) {
            Ok(c) => c,
            Err(_) => return Ok(()),
        };
        let mut design = c.design;
        let mut starved = false;
        for m in &mut design.modules {
            if let ModuleSpec::Writer { elems, .. } = &mut m.spec {
                *elems += 10;
                starved = true;
            }
        }
        if !starved {
            return Err("vecadd design has no writer to corrupt".into());
        }
        let report = check(&c.sdfg, &design);
        let mut hbm = Hbm::new();
        hbm.load("x", g.vec_f32(n as usize));
        hbm.load("y", g.vec_f32(n as usize));
        match run_exact_in(&design, hbm, 100_000, &mut Arena::new()) {
            Ok(_) => {
                return Err(format!(
                    "starved writer ran to completion (lanes {lanes}, pump {pump}, n {n})"
                ))
            }
            Err(_) => {
                // the simulator wedged — the checker must have seen it
                if report.is_clean() {
                    return Err(format!(
                        "simulator deadlocked but the checker was silent \
                         (lanes {lanes}, pump {pump}, n {n})"
                    ));
                }
            }
        }
        // and specifically via the rate-balance rule
        if !report.diags.iter().any(|d| d.code == "TV008") {
            return Err(format!(
                "expected TV008 on the starved writer, got: {:?}",
                report.diags
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_faulted_sweeps_quarantine_any_victim_and_keep_the_frontier() {
    // the supervision layer's property (DESIGN.md §14): for ANY pumped
    // candidate chosen as the fault victim and either fault kind
    // (panic or wedge), the sweep completes, classifies the fault with
    // the right FailKind, reproduces the fault-free frontier over the
    // surviving candidates, and leaves the evaluator healthy — no
    // poisoned mutex, no leaked arena slots, no quarantine retries
    use temporal_vec::dse::{
        frontier, generate, run_search, DesignPoint, Evaluator, FailKind, FaultPlan,
        Objective, SearchBase, SearchConfig, SpaceOptions,
    };
    use temporal_vec::hw::Device;
    forall("faulted-sweeps", 0xF1, 4, |g| {
        let device = Device::u280();
        let n = (g.usize(16, 129) * 8) as i64; // divisible by every width/factor
        let seed = g.usize(0, 1 << 20) as u64;
        let bases = [SearchBase {
            spec: BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(seed),
            flops: apps::vecadd::flops(n),
        }];
        let opts = SpaceOptions {
            vector_widths: vec![2, 4, 8],
            pump_factors: vec![2, 4],
            pump_modes: vec![PumpMode::Resource],
            max_replicas: 1,
            cl0_requests_mhz: vec![],
            mixed_factors: false,
        };
        // white-box ordinal model (matches tests/dse.rs): baselines
        // evaluate first in grid order, then the pumped batch
        let is_baseline = |p: &DesignPoint| {
            p.pump.is_none()
                && p.regions.is_none()
                && p.replicas == 1
                && p.cl0_request_mhz.is_none()
        };
        let grid = generate(&bases[0].spec, &device, &opts);
        let baseline_count = grid.iter().filter(|p| is_baseline(p)).count();
        let batch: Vec<DesignPoint> = grid
            .into_iter()
            .filter(|p| *p != DesignPoint::original() && !is_baseline(p))
            .collect();
        if batch.len() < 2 {
            return Err("space too small to pick a fault victim".into());
        }
        let victim = g.usize(0, batch.len());
        let wedge = g.bool();
        let kind = if wedge { "wedge" } else { "panic" };
        let spec = format!("{kind}@{}", baseline_count + victim);
        let cfg =
            SearchConfig::exhaustive(Objective::resource()).with_limits(Some(1_000), None);

        let clean = run_search(&Evaluator::new(), &bases, &device, &opts, &cfg)
            .map_err(|e| format!("clean sweep (n {n}) failed: {e}"))?;
        if clean.quarantined() != 0 {
            return Err(format!("clean sweep quarantined {} candidates", clean.quarantined()));
        }

        let ev = Evaluator::new().with_faults(FaultPlan::parse(&spec).unwrap());
        let faulted = run_search(&ev, &bases, &device, &opts, &cfg)
            .map_err(|e| format!("faulted sweep ({spec}) died: {e}"))?;
        let (want_panicked, want_timed_out) = if wedge { (0, 1) } else { (1, 0) };
        if faulted.panicked != want_panicked || faulted.timed_out != want_timed_out {
            return Err(format!(
                "{spec}: classified as {} panicked / {} timed-out \
                 (want {want_panicked}/{want_timed_out})",
                faulted.panicked, faulted.timed_out
            ));
        }
        if ev.faults().unwrap().fired() != 1 {
            return Err(format!(
                "{spec}: fired {} injections (want 1)",
                ev.faults().unwrap().fired()
            ));
        }

        // the faulted frontier must equal the fault-free frontier
        // computed over the surviving candidates
        let survivors: Vec<temporal_vec::dse::Evaluation> = clean
            .evaluations
            .iter()
            .filter(|e| e.point != batch[victim])
            .cloned()
            .collect();
        let want: Vec<String> =
            frontier(&survivors).iter().map(|e| e.label.clone()).collect();
        let got: Vec<String> = faulted.frontier.iter().map(|e| e.label.clone()).collect();
        if got != want {
            return Err(format!(
                "{spec}: faulted frontier {got:?} diverged from survivors' {want:?}"
            ));
        }

        // post-fault health: the quarantine memo holds without
        // re-firing, and a fresh evaluation still succeeds (the arena
        // pool and caches survived the unwind)
        let base = &bases[0];
        let again = ev.evaluate(&base.spec, &batch[victim], base.flops);
        let want_kind = if wedge { FailKind::Timeout } else { FailKind::Panic };
        match &again {
            Err(e) if e.kind == want_kind => {}
            other => {
                return Err(format!(
                    "{spec}: quarantined candidate re-evaluated to {other:?} \
                     (want Err({want_kind:?}))"
                ))
            }
        }
        if ev.faults().unwrap().fired() != 1 {
            return Err("a memoized quarantine hit re-fired the injection".into());
        }
        ev.evaluate(&base.spec, &DesignPoint::original(), base.flops)
            .map_err(|e| format!("{spec}: evaluator unhealthy after the fault: {}", e.message))?;
        Ok(())
    });
}

#[test]
fn prop_event_engine_is_cycle_exact_on_random_mixed_stencils() {
    // randomized per-region pump assignments over a small jacobi chain:
    // several fast domains at different strides plus CL0 regions in one
    // design — the hardest scheduling shape the engine supports
    forall("event-exact-mixed", 0xD2, 8, |g| {
        use temporal_vec::ir::StencilKind;
        let stages = g.usize(2, 4);
        let factors: Vec<Option<usize>> = (0..stages)
            .map(|_| {
                let f = *g.choose(&[2usize, 4]);
                g.option(f)
            })
            .collect();
        let mut spec = BuildSpec::new(apps::stencil::build(StencilKind::Jacobi3D, stages, 8))
            .bind("NX", 8)
            .bind("NY", 8)
            .bind("NZ", 8)
            .bind("NZ_v", 1);
        if factors.iter().any(|f| f.is_some()) {
            spec = spec.pumped_regions(factors.clone());
        }
        let c = match compile(spec) {
            Ok(c) => c,
            Err(_) => return Ok(()), // illegal assignment: vacuous case
        };
        let mut hbm = Hbm::new();
        hbm.load("v_in", g.vec_f32(8 * 8 * 8));
        engines_must_agree(&c.design, hbm, "v_out")
            .map_err(|e| format!("stages {stages} factors {factors:?}: {e}"))
    });
}

/// One random compiled design per the `0xE1` arms — uniform pumped
/// vecadd, mixed per-region stencil chain, bare-fast FW — plus its
/// input containers and output name. `Ok(None)` is a vacuous
/// (randomly illegal) candidate.
#[allow(clippy::type_complexity)]
fn random_compiled_arm(
    g: &mut temporal_vec::util::quickcheck::Gen,
) -> Result<
    Option<(temporal_vec::coordinator::Compiled, Vec<(String, Vec<f32>)>, &'static str, String)>,
    String,
> {
    use temporal_vec::ir::StencilKind;
    match g.usize(0, 3) {
        0 => {
            let lanes = *g.choose(&[2usize, 4, 8]);
            let pump: Option<(usize, PumpMode)> = match g.usize(0, 4) {
                0 => None,
                1 => Some((2, PumpMode::Resource)),
                2 => Some((2, PumpMode::Throughput)),
                _ => Some((4, PumpMode::Resource)),
            };
            let pump = match pump {
                Some((m, PumpMode::Resource)) if lanes % m != 0 => None,
                p => p,
            };
            let n = (g.usize(6, 32) * lanes.max(4)) as i64;
            let mut spec =
                BuildSpec::new(apps::vecadd::build()).vectorized("vadd", lanes).bind("N", n);
            if let Some((m, mode)) = pump {
                spec = spec.pumped(m, mode);
            }
            let c = match compile(spec) {
                Ok(c) => c,
                Err(_) => return Ok(None),
            };
            let inputs = vec![
                ("x".to_string(), g.vec_f32(n as usize)),
                ("y".to_string(), g.vec_f32(n as usize)),
            ];
            Ok(Some((c, inputs, "z", format!("vecadd lanes {lanes} pump {pump:?} n {n}"))))
        }
        1 => {
            let stages = g.usize(2, 4);
            let factors: Vec<Option<usize>> = (0..stages)
                .map(|_| {
                    let f = *g.choose(&[2usize, 4]);
                    g.option(f)
                })
                .collect();
            let mut spec =
                BuildSpec::new(apps::stencil::build(StencilKind::Jacobi3D, stages, 8))
                    .bind("NX", 8)
                    .bind("NY", 8)
                    .bind("NZ", 8)
                    .bind("NZ_v", 1);
            if factors.iter().any(|f| f.is_some()) {
                spec = spec.pumped_regions(factors.clone());
            }
            let c = match compile(spec) {
                Ok(c) => c,
                Err(_) => return Ok(None),
            };
            let inputs = vec![("v_in".to_string(), g.vec_f32(8 * 8 * 8))];
            Ok(Some((c, inputs, "v_out", format!("stencil stages {stages} factors {factors:?}"))))
        }
        _ => {
            let n = *g.choose(&[8usize, 12]);
            let c = compile(
                BuildSpec::new(apps::floyd_warshall::build())
                    .bind("N", n as i64)
                    .pumped(2, PumpMode::BareFast),
            )
            .map_err(|e| format!("bare-fast FW must compile: {e}"))?;
            let inputs =
                vec![("dist".to_string(), apps::floyd_warshall::random_graph(n, 11, 0.3))];
            Ok(Some((c, inputs, "dist", format!("bare-fast FW n {n}"))))
        }
    }
}

#[test]
fn prop_sharded_engine_bit_identical_to_reference_on_replicated_designs() {
    // the tentpole's correctness contract: replicate any random design
    // (uniform / mixed / bare-fast) into independent components and the
    // sharded engine must reproduce the legacy reference stepper
    // exactly — slow/fast cycles, transactions, per-module stall
    // counters, bottleneck, and every output byte — at any worker count
    use temporal_vec::sim::{
        replicate_design, replicate_inputs, run_exact_reference, run_exact_sharded_in,
    };
    forall("sharded-bit-identical", 0xE3, 8, |g| {
        let (c, inputs, out, tag) = match random_compiled_arm(g)? {
            Some(v) => v,
            None => return Ok(()),
        };
        let k = *g.choose(&[2usize, 3]);
        let threads = *g.choose(&[2usize, 3, 4]);
        let rep = replicate_design(&c.design, k);
        let serial = run_exact_reference(&rep, replicate_inputs(&inputs, k), 10_000_000)
            .map_err(|e| format!("{tag} x{k}: reference run failed: {e}"))?;
        let mut arenas = Vec::new();
        let sharded = run_exact_sharded_in(
            &rep,
            replicate_inputs(&inputs, k),
            10_000_000,
            threads,
            None,
            &mut arenas,
            None,
        )
        .map_err(|e| format!("{tag} x{k}: sharded run failed: {e}"))?;
        if serial.stats.slow_cycles != sharded.stats.slow_cycles
            || serial.stats.fast_cycles != sharded.stats.fast_cycles
            || serial.stats.transactions != sharded.stats.transactions
            || serial.stats.bottleneck != sharded.stats.bottleneck
            || serial.stats.modules != sharded.stats.modules
        {
            return Err(format!(
                "{tag} x{k} threads {threads}: sharded stats diverged from reference: \
                 {:?} vs {:?}",
                serial.stats, sharded.stats
            ));
        }
        for i in 0..k {
            let name = format!("r{i}__{out}");
            let a: Vec<u32> = serial.hbm.read(&name).iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = sharded.hbm.read(&name).iter().map(|v| v.to_bits()).collect();
            if a != b {
                return Err(format!(
                    "{tag} x{k} threads {threads}: output '{name}' bits diverged"
                ));
            }
        }
        // a clean sharded run must leak no arena slots (the poison-fill
        // canary's accounting side)
        for (i, a) in arenas.iter().enumerate() {
            if a.stats().leaked != 0 {
                return Err(format!(
                    "{tag} x{k}: shard arena {i} leaked {} slot(s) on a clean run",
                    a.stats().leaked
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_telemetry_is_invisible_and_counts_shards() {
    // observation of a sharded run must be purely observational — and
    // the per-shard busy counters must actually appear
    use temporal_vec::sim::{replicate_design, replicate_inputs, run_exact_sharded_in};
    use temporal_vec::telemetry::Recorder;
    forall("sharded-telemetry-invisible", 0xE4, 6, |g| {
        let (c, inputs, out, tag) = match random_compiled_arm(g)? {
            Some(v) => v,
            None => return Ok(()),
        };
        let rep = replicate_design(&c.design, 2);
        let plain = run_exact_sharded_in(
            &rep,
            replicate_inputs(&inputs, 2),
            10_000_000,
            2,
            None,
            &mut Vec::new(),
            None,
        )
        .map_err(|e| format!("{tag}: plain sharded run failed: {e}"))?;
        let rec = Recorder::new();
        let observed = run_exact_sharded_in(
            &rep,
            replicate_inputs(&inputs, 2),
            10_000_000,
            2,
            None,
            &mut Vec::new(),
            Some(&rec),
        )
        .map_err(|e| format!("{tag}: observed sharded run failed: {e}"))?;
        if plain.stats.slow_cycles != observed.stats.slow_cycles
            || plain.stats.fast_cycles != observed.stats.fast_cycles
            || plain.stats.transactions != observed.stats.transactions
            || plain.stats.bottleneck != observed.stats.bottleneck
            || plain.stats.modules != observed.stats.modules
        {
            return Err(format!(
                "{tag}: sharded SimStats diverged under observation: {:?} vs {:?}",
                plain.stats, observed.stats
            ));
        }
        for i in 0..2 {
            let name = format!("r{i}__{out}");
            let a: Vec<u32> = plain.hbm.read(&name).iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = observed.hbm.read(&name).iter().map(|v| v.to_bits()).collect();
            if a != b {
                return Err(format!("{tag}: output '{name}' bits diverged under observation"));
            }
        }
        if rec.counter("sim.shard.0.busy") == 0 || rec.counter("sim.shard.1.busy") == 0 {
            return Err(format!("{tag}: observed sharded run recorded no per-shard busy"));
        }
        Ok(())
    });
}

#[test]
fn prop_chunked_eval_lanes_bit_identical_to_scalar() {
    // the SIMD evaluator's contract on random programs and data —
    // NaN/Inf/±0 payloads, broadcast-narrow inputs, and non-multiple-
    // of-8 lane counts included. Both evaluators are always compiled,
    // so this pins the `simd` feature's bit-identity whether or not
    // the feature is on.
    use temporal_vec::ir::{TaskExpr, Tasklet};
    use temporal_vec::sim::compute::CompiledTasklet;
    use temporal_vec::sim::Arena;

    fn gen_expr(g: &mut temporal_vec::util::quickcheck::Gen, depth: usize) -> TaskExpr {
        if depth == 0 || g.usize(0, 4) == 0 {
            return if g.bool() {
                TaskExpr::input(["a", "b", "c"][g.usize(0, 3)])
            } else {
                TaskExpr::c(g.f32(-4.0, 4.0))
            };
        }
        let a = gen_expr(g, depth - 1);
        let b = gen_expr(g, depth - 1);
        match g.usize(0, 6) {
            0 => a.add(b),
            1 => a.sub(b),
            2 => a.mul(b),
            3 => a.min(b),
            4 => a.max(b),
            _ => TaskExpr::muladd(a, b, gen_expr(g, depth - 1)),
        }
    }

    forall("simd-bit-identical", 0xE5, 24, |g| {
        let expr = gen_expr(g, g.usize(1, 5));
        let conns: Vec<String> = ["a", "b", "c"].iter().map(|s| s.to_string()).collect();
        let t = Tasklet::new("p", vec![("o", expr)]);
        let ct = CompiledTasklet::compile(&t, &conns).map_err(|e| e.to_string())?;
        let lanes = g.usize(1, 40);
        let mut arena = Arena::new();
        let specials = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0, -0.0];
        let popped: Vec<_> = (0..conns.len())
            .map(|_| {
                // narrow inputs exercise the broadcast path
                let w = if g.usize(0, 4) == 0 { 1 } else { lanes };
                let mut v = g.vec_f32(w);
                for x in v.iter_mut() {
                    if g.usize(0, 5) == 0 {
                        *x = *g.choose(&specials);
                    }
                }
                arena.alloc_from(&v)
            })
            .collect();
        let mut vals = vec![0.0f32; conns.len()];
        let mut stack = vec![0.0f32; ct.stack_depth()];
        let mut out_s = vec![0.0f32; lanes];
        let mut out_c = vec![0.0f32; lanes];
        ct.eval_lanes_scalar(&arena, &popped, &mut vals, &mut stack, &mut out_s);
        ct.eval_lanes_chunked(&arena, &popped, &mut vals, &mut stack, &mut out_c);
        for (l, (a, b)) in out_s.iter().zip(&out_c).enumerate() {
            if a.to_bits() != b.to_bits() {
                return Err(format!(
                    "lane {l}/{lanes}: chunked {b:?} ({:#010x}) != scalar {a:?} ({:#010x})",
                    b.to_bits(),
                    a.to_bits()
                ));
            }
        }
        Ok(())
    });
}
