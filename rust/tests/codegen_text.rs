//! Integration: generated HLS/RTL text artifacts for every app.

use temporal_vec::apps;
use temporal_vec::codegen::{hls, rtl};
use temporal_vec::coordinator::{compile, BuildSpec};
use temporal_vec::ir::PumpMode;

#[test]
fn pumped_vecadd_emits_complete_rtl_kernel() {
    let c = compile(
        BuildSpec::new(apps::vecadd::build())
            .vectorized("vadd", 4)
            .pumped(2, PumpMode::Resource)
            .bind("N", 1024),
    )
    .unwrap();
    let k = rtl::emit_rtl(&c.design);
    // paper §3.3's four files + connectivity
    assert!(k.controller_sv.contains("module"));
    assert!(k.core_sv.contains("module"));
    assert!(k.toplevel_v.contains("axis_clock_converter"));
    assert!(k.toplevel_v.contains("axis_dwidth_converter"));
    assert!(k.package_tcl.contains("ipx::package_project"));
    // two clocks from the Vitis shell (paper §3.3 "Enable multiple
    // clock and reset signals")
    assert!(k.link_cfg.contains("[clock]"));
    assert!(k.link_cfg.matches("freqHz").count() == 2);
    // one HBM bank per container
    for bank in ["HBM[0]", "HBM[1]", "HBM[2]"] {
        assert!(k.link_cfg.contains(bank), "missing {bank}");
    }
}

#[test]
fn hls_contains_dataflow_modules_for_each_app() {
    // gemm
    let mut spec = BuildSpec::new(apps::matmul::build(4));
    for (s, v) in apps::matmul::bindings(128) {
        spec = spec.bind(&s, v);
    }
    let c = compile(spec).unwrap();
    let cpp = hls::emit_hls(&c.design);
    assert!(cpp.contains("Systolic array"));
    assert!(cpp.contains("void read_A"));

    // stencil
    let c = compile(
        BuildSpec::new(apps::stencil::build(temporal_vec::ir::StencilKind::Jacobi3D, 2, 8))
            .bind("NX", 32)
            .bind("NY", 32)
            .bind("NZ", 32)
            .bind("NZ_v", 4),
    )
    .unwrap();
    let cpp = hls::emit_hls(&c.design);
    assert!(cpp.contains("line buffers"));

    // fw
    let c = compile(BuildSpec::new(apps::floyd_warshall::build()).bind("N", 32)).unwrap();
    let cpp = hls::emit_hls(&c.design);
    assert!(cpp.contains("Floyd"));
}

#[test]
fn unpumped_kernel_has_no_cdc_ip() {
    let c = compile(
        BuildSpec::new(apps::vecadd::build()).vectorized("vadd", 4).bind("N", 1024),
    )
    .unwrap();
    let k = rtl::emit_rtl(&c.design);
    assert!(!k.toplevel_v.contains("axis_dwidth_converter"));
    assert!(!k.link_cfg.contains("[clock]"));
}
