//! Integration: simulator correctness across apps — functional outputs
//! vs CPU references, exact-mode vs rate-model agreement, and the
//! multi-pumping equivalence guarantee (the transformation must never
//! change results).

use temporal_vec::apps;
use temporal_vec::coordinator::{compile, BuildSpec, Compiled};
use temporal_vec::ir::{PumpMode, StencilKind};
use temporal_vec::sim::{exact_engines_agree, rate_model, run_exact, run_functional, Hbm};
use temporal_vec::util::Rng;

fn gemm_ref(a: &[f32], b: &[f32], n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; n * n];
    for i in 0..n {
        for k in 0..n {
            let av = a[i * n + k];
            for j in 0..n {
                c[i * n + j] += av * b[k * n + j];
            }
        }
    }
    c
}

fn stencil_ref(v: &[f32], kind: StencilKind, nx: usize, ny: usize, nz: usize, s: usize) -> Vec<f32> {
    let mut cur = v.to_vec();
    for _ in 0..s {
        let next: Vec<f32> = (0..cur.len())
            .map(|i| temporal_vec::sim::process::stencil_point(kind, &cur, i, nx, ny, nz))
            .collect();
        cur = next;
    }
    cur
}

fn compile_gemm(pes: usize, n: i64, pump: bool) -> Compiled {
    let mut spec = BuildSpec::new(apps::matmul::build(pes));
    for (s, v) in apps::matmul::bindings(n) {
        spec = spec.bind(&s, v);
    }
    if pump {
        spec = spec.pumped(2, PumpMode::Resource);
    }
    compile(spec).unwrap()
}

#[test]
fn gemm_functional_matches_cpu_reference() {
    let n = 64usize;
    let c = compile_gemm(4, n as i64, true);
    let mut rng = Rng::new(21);
    let a = rng.f32_vec(n * n);
    let b = rng.f32_vec(n * n);
    let mut hbm = Hbm::new();
    hbm.load("A", a.clone());
    hbm.load("B", b.clone());
    let out = run_functional(&c.design, hbm).unwrap();
    let want = gemm_ref(&a, &b, n);
    for (g, w) in out.hbm.read("C").iter().zip(&want) {
        assert!((g - w).abs() < 1e-3 * w.abs().max(1.0), "{g} vs {w}");
    }
}

#[test]
fn stencil_functional_matches_cpu_reference() {
    for kind in [StencilKind::Jacobi3D, StencilKind::Diffusion3D] {
        let w = apps::stencil::paper_vec_width(kind);
        let (nx, ny, nz) = (16i64, 8i64, 8i64);
        let stages = 3usize;
        let c = compile(
            BuildSpec::new(apps::stencil::build(kind, stages, w))
                .pumped(2, PumpMode::Resource)
                .bind("NX", nx)
                .bind("NY", ny)
                .bind("NZ", nz)
                .bind("NZ_v", nz / w as i64),
        )
        .unwrap();
        let mut rng = Rng::new(33);
        let v = rng.f32_vec((nx * ny * nz) as usize);
        let mut hbm = Hbm::new();
        hbm.load("v_in", v.clone());
        let out = run_functional(&c.design, hbm).unwrap();
        let want = stencil_ref(&v, kind, nx as usize, ny as usize, nz as usize, stages);
        for (i, (g, w)) in out.hbm.read("v_out").iter().zip(&want).enumerate() {
            assert!((g - w).abs() < 1e-4, "{kind:?} elem {i}: {g} vs {w}");
        }
    }
}

#[test]
fn fw_functional_matches_cpu_reference() {
    let n = 24usize;
    for pump in [false, true] {
        let mut spec = BuildSpec::new(apps::floyd_warshall::build()).bind("N", n as i64);
        if pump {
            spec = spec.pumped(2, PumpMode::Throughput);
        }
        let c = compile(spec).unwrap();
        let d = apps::floyd_warshall::random_graph(n, 55, 0.3);
        let mut hbm = Hbm::new();
        hbm.load("dist", d.clone());
        let out = run_functional(&c.design, hbm).unwrap();
        let want = apps::floyd_warshall::reference(&d, n);
        assert_eq!(out.hbm.read("dist"), want.as_slice(), "pump={pump}");
    }
}

#[test]
fn pumping_never_changes_results() {
    // the paper's core safety property: the transformation is a pure
    // performance/resource rewrite
    let n = 20usize;
    let d = apps::floyd_warshall::random_graph(n, 77, 0.4);
    let run = |pump: bool| {
        let mut spec = BuildSpec::new(apps::floyd_warshall::build()).bind("N", n as i64);
        if pump {
            spec = spec.pumped(2, PumpMode::Throughput);
        }
        let c = compile(spec).unwrap();
        let mut hbm = Hbm::new();
        hbm.load("dist", d.clone());
        run_functional(&c.design, hbm).unwrap().hbm.read("dist").to_vec()
    };
    assert_eq!(run(false), run(true));
}

#[test]
fn exact_mode_gemm_agrees_with_rate_model() {
    let c = compile_gemm(4, 64, false);
    let mut rng = Rng::new(3);
    let mut hbm = Hbm::new();
    hbm.load("A", rng.f32_vec(64 * 64));
    hbm.load("B", rng.f32_vec(64 * 64));
    let e = run_exact(&c.design, hbm, 50_000_000).unwrap();
    let r = rate_model(&c.design);
    let ratio = r.slow_cycles as f64 / e.stats.slow_cycles as f64;
    assert!((0.7..1.4).contains(&ratio), "rate {} vs exact {}", r.slow_cycles, e.stats.slow_cycles);
}

#[test]
fn exact_mode_fw_agrees_with_rate_model() {
    let n = 16usize;
    let c = compile(
        BuildSpec::new(apps::floyd_warshall::build()).bind("N", n as i64),
    )
    .unwrap();
    let d = apps::floyd_warshall::random_graph(n, 9, 0.3);
    let mut hbm = Hbm::new();
    hbm.load("dist", d);
    let e = run_exact(&c.design, hbm, 50_000_000).unwrap();
    let r = rate_model(&c.design);
    let ratio = r.slow_cycles as f64 / e.stats.slow_cycles as f64;
    assert!((0.8..1.25).contains(&ratio), "rate {} vs exact {}", r.slow_cycles, e.stats.slow_cycles);
}

#[test]
fn resource_mode_preserves_throughput_in_cycles() {
    // same slow-cycle count within tolerance (paper §2.1 waveform 3)
    let n = 1 << 12;
    let mk = |pump| {
        let mut spec =
            BuildSpec::new(apps::vecadd::build()).vectorized("vadd", 8).bind("N", n);
        if pump {
            spec = spec.pumped(2, PumpMode::Resource);
        }
        compile(spec).unwrap()
    };
    let mut rng = Rng::new(12);
    let x = rng.f32_vec(n as usize);
    let y = rng.f32_vec(n as usize);
    let run = |c: &Compiled| {
        let mut hbm = Hbm::new();
        hbm.load("x", x.clone());
        hbm.load("y", y.clone());
        run_exact(&c.design, hbm, 10_000_000).unwrap().stats.slow_cycles
    };
    let (o, dp) = (run(&mk(false)), run(&mk(true)));
    let ratio = dp as f64 / o as f64;
    assert!((0.9..1.25).contains(&ratio), "O {o} vs DP {dp}");
}

#[test]
fn stall_accounting_shows_backpressure() {
    let n = 1 << 12;
    let c = compile(
        BuildSpec::new(apps::vecadd::build())
            .vectorized("vadd", 8)
            .pumped(2, PumpMode::Resource)
            .bind("N", n),
    )
    .unwrap();
    let mut rng = Rng::new(13);
    let mut hbm = Hbm::new();
    hbm.load("x", rng.f32_vec(n as usize));
    hbm.load("y", rng.f32_vec(n as usize));
    let e = run_exact(&c.design, hbm, 10_000_000).unwrap();
    // per-module accounting exists and sums sensibly
    assert!(!e.stats.modules.is_empty());
    let total_busy: u64 = e.stats.modules.iter().map(|(_, b, _)| *b).sum();
    assert!(total_busy > 0);
    assert!(!e.stats.bottleneck.is_empty());
}

// ---- event-driven engine vs the legacy stepper ----

/// Full SimStats + output equality between the two exact engines (the
/// shared oracle `sim::exact_engines_agree`, panicking with context).
fn assert_engines_agree(c: &Compiled, hbm: Hbm, out_name: &str) {
    exact_engines_agree(&c.design, hbm, 50_000_000, &[out_name])
        .unwrap_or_else(|e| panic!("{}: {e}", c.design.name));
}

#[test]
fn event_engine_matches_reference_on_gemm() {
    for pump in [false, true] {
        let c = compile_gemm(4, 64, pump);
        let mut rng = Rng::new(61);
        let mut hbm = Hbm::new();
        hbm.load("A", rng.f32_vec(64 * 64));
        hbm.load("B", rng.f32_vec(64 * 64));
        assert_engines_agree(&c, hbm, "C");
    }
}

#[test]
fn event_engine_matches_reference_on_fw_repeats() {
    // Floyd–Warshall: II = 21 cooldown gaps, throughput-mode fast
    // domain, and N sequential whole-graph repeats — the repeat
    // realignment and long quiescent stretches the skip-ahead must not
    // mis-handle
    let n = 16usize;
    for pump in [false, true] {
        let mut spec = BuildSpec::new(apps::floyd_warshall::build()).bind("N", n as i64);
        if pump {
            spec = spec.pumped(2, PumpMode::Throughput);
        }
        let c = compile(spec).unwrap();
        let d = apps::floyd_warshall::random_graph(n, 9, 0.3);
        let mut hbm = Hbm::new();
        hbm.load("dist", d);
        assert_engines_agree(&c, hbm, "dist");
    }
}

#[test]
fn skip_ahead_never_overshoots_a_domain_tick() {
    // a mixed 4/2/CL0 stencil chain carries three tick strides (1, 2,
    // 4) in one design; if the engine's skip-ahead ever jumped past a
    // scheduled domain tick, that module's busy/stall counters — and
    // with them the cycle count — would diverge from the legacy
    // stepper, which polls every cycle by construction
    let mut spec = BuildSpec::new(apps::stencil::build(StencilKind::Jacobi3D, 3, 8))
        .pumped_regions(vec![Some(4), Some(2), None])
        .bind("NX", 8)
        .bind("NY", 8)
        .bind("NZ", 8)
        .bind("NZ_v", 1);
    spec = spec.seeded(3);
    let c = compile(spec).unwrap();
    let mut rng = Rng::new(71);
    let mut hbm = Hbm::new();
    hbm.load("v_in", rng.f32_vec(8 * 8 * 8));
    assert_engines_agree(&c, hbm, "v_out");
    // sanity: the design really does carry several fast strides
    let factors: Vec<usize> = c
        .design
        .modules
        .iter()
        .map(|m| match m.domain {
            temporal_vec::ir::ClockDomain::Slow => 1,
            temporal_vec::ir::ClockDomain::Fast { factor } => factor,
        })
        .collect();
    assert!(factors.contains(&4) && factors.contains(&2) && factors.contains(&1));
}

// ---- failure injection ----

#[test]
fn corrupted_channel_reference_panics_cleanly() {
    let c = compile(
        BuildSpec::new(apps::vecadd::build()).vectorized("vadd", 4).bind("N", 64),
    )
    .unwrap();
    let mut broken = c.design.clone();
    broken.channels.remove(0); // module now references a missing FIFO
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut hbm = Hbm::new();
        hbm.load("x", vec![0.0; 64]);
        hbm.load("y", vec![0.0; 64]);
        let _ = run_functional(&broken, hbm);
    }));
    assert!(result.is_err(), "missing channel must be detected");
}

#[test]
fn missing_input_container_defaults_to_zeros() {
    // unloaded containers are zero-allocated (defined graceful
    // behaviour: the host API would reject the launch earlier)
    let c = compile(
        BuildSpec::new(apps::vecadd::build()).vectorized("vadd", 4).bind("N", 64),
    )
    .unwrap();
    let mut hbm = Hbm::new();
    hbm.load("x", vec![5.0; 64]); // y missing
    let out = run_functional(&c.design, hbm).unwrap();
    assert_eq!(out.hbm.read("z"), vec![5.0; 64].as_slice());
}

#[test]
fn exact_mode_cycle_budget_enforced() {
    let c = compile(
        BuildSpec::new(apps::vecadd::build()).vectorized("vadd", 4).bind("N", 1 << 12),
    )
    .unwrap();
    let mut rng = Rng::new(88);
    let mut hbm = Hbm::new();
    hbm.load("x", rng.f32_vec(1 << 12));
    hbm.load("y", rng.f32_vec(1 << 12));
    let err = run_exact(&c.design, hbm, 10).unwrap_err();
    assert!(err.contains("exceeded"), "{err}");
}

// ---- bare-fast mode: gearbox-free fast clocking ----

/// Twin II=2 pipelines, identical except for the clock of the compute
/// stage: `bare_fast` places it in a factor-2 fast domain behind plain
/// synchronizers (no issuer/packer — widths are untouched), the twin
/// leaves it in CL0. Hand-built because lowering floors tasklet
/// latency/II for real datapaths; the bare-fast physics under test is
/// purely the engine's per-domain pacing.
fn ii2_pipeline(bare_fast: bool, n: usize) -> temporal_vec::codegen::Design {
    use temporal_vec::codegen::{ChannelSpec, Design, ModuleInst, ModuleSpec};
    use temporal_vec::hw::ResourceVec;
    use temporal_vec::ir::{ClockDomain, TaskExpr, Tasklet};
    let chan = |name: &str, crosses: bool| ChannelSpec {
        name: name.into(),
        lanes: 1,
        depth: 8,
        crosses_domains: crosses,
    };
    let inst = |spec: ModuleSpec, domain: ClockDomain| ModuleInst {
        spec,
        domain,
        resources: ResourceVec::ZERO,
    };
    let compute_domain =
        if bare_fast { ClockDomain::Fast { factor: 2 } } else { ClockDomain::Slow };
    Design {
        name: if bare_fast { "ii2_barefast" } else { "ii2_slow" }.into(),
        modules: vec![
            inst(
                ModuleSpec::Reader {
                    data: "x".into(),
                    stream: "s_in".into(),
                    lanes: 1,
                    elems: n,
                    bytes_per_cycle: 4,
                },
                ClockDomain::Slow,
            ),
            inst(
                ModuleSpec::Sync { input: "s_in".into(), output: "s_in_fast".into() },
                ClockDomain::Slow,
            ),
            inst(
                ModuleSpec::Compute {
                    name: "acc".into(),
                    tasklet: Tasklet::new("acc", vec![("o", TaskExpr::input("a"))]),
                    inputs: vec![("s_in_fast".into(), "a".into())],
                    output: ("s_out".into(), "o".into()),
                    lanes: 1,
                    iterations: n,
                    ii: 2,
                    latency: 6,
                },
                compute_domain,
            ),
            inst(
                ModuleSpec::Sync { input: "s_out".into(), output: "s_out_slow".into() },
                ClockDomain::Slow,
            ),
            inst(
                ModuleSpec::Writer {
                    data: "z".into(),
                    stream: "s_out_slow".into(),
                    lanes: 1,
                    elems: n,
                    bytes_per_cycle: 4,
                },
                ClockDomain::Slow,
            ),
        ],
        channels: vec![
            chan("s_in", false),
            chan("s_in_fast", bare_fast),
            chan("s_out", bare_fast),
            chan("s_out_slow", false),
        ],
        pump: bare_fast.then_some((2, PumpMode::BareFast)),
        domain_modes: if bare_fast { vec![(2, PumpMode::BareFast)] } else { vec![] },
        arrays: vec![("x".into(), n, 0), ("z".into(), n, 1)],
        repeat: 1,
        slr_replicas: 1,
        cl0_request_mhz: None,
    }
}

#[test]
fn bare_fast_recovers_ii2_to_effective_ii1_with_zero_gearboxes() {
    // The PR's acceptance criterion: a bare-fast factor-2 domain around
    // an II=2 pipeline — no issuer, no packer, widths untouched — must
    // simulate at effective II=1: one result per *slow* cycle, half the
    // slow-cycle count of the identical single-clock twin.
    use temporal_vec::codegen::ModuleSpec;
    let n = 1 << 12;
    let mut rng = Rng::new(41);
    let x = rng.f32_vec(n);
    let run = |bare_fast: bool| {
        let d = ii2_pipeline(bare_fast, n);
        assert!(
            !d.modules.iter().any(|m| matches!(
                m.spec,
                ModuleSpec::Issuer { .. } | ModuleSpec::Packer { .. }
            )),
            "bare-fast crossings must be gearbox-free"
        );
        let mut hbm = Hbm::new();
        hbm.load("x", x.clone());
        run_exact(&d, hbm, 10_000_000).unwrap()
    };
    let (bare, slow) = (run(true), run(false));
    // the datapath is untouched, so outputs are identical
    assert_eq!(bare.hbm.read("z"), slow.hbm.read("z"));
    assert_eq!(&bare.hbm.read("z")[..n], &x[..]);
    // effective II=1: ~one txn per slow cycle end to end
    assert!(
        (bare.stats.slow_cycles as f64) < 1.25 * n as f64,
        "bare-fast: {} slow cycles for {n} txns (want ~{n})",
        bare.stats.slow_cycles
    );
    let ratio = slow.stats.slow_cycles as f64 / bare.stats.slow_cycles as f64;
    assert!(
        (1.8..2.2).contains(&ratio),
        "II recovery ratio {ratio:.3} (slow {} vs bare-fast {})",
        slow.stats.slow_cycles,
        bare.stats.slow_cycles
    );
}

#[test]
fn bare_fast_design_agrees_across_exact_engines() {
    // the event engine's skip-ahead must pace a gearbox-free fast
    // domain exactly like the cycle-by-cycle reference stepper
    let n = 1 << 10;
    let mut rng = Rng::new(42);
    let mut hbm = Hbm::new();
    hbm.load("x", rng.f32_vec(n));
    let d = ii2_pipeline(true, n);
    exact_engines_agree(&d, hbm, 10_000_000, &["z"]).unwrap();
}

#[test]
fn fw_bare_fast_compiles_gearbox_free_and_preserves_results() {
    // end-to-end through the real pipeline: Floyd–Warshall (dependent
    // scalar datapath, II = 21) accepts bare-fast pumping, lowers with
    // zero width-converter modules, doubles simulated throughput, and
    // computes bit-identical shortest paths
    use temporal_vec::codegen::ModuleSpec;
    use temporal_vec::ir::ClockDomain;
    let n = 20usize;
    let d = apps::floyd_warshall::random_graph(n, 77, 0.4);
    let build = |pump: Option<PumpMode>| {
        let mut spec = BuildSpec::new(apps::floyd_warshall::build()).bind("N", n as i64);
        if let Some(mode) = pump {
            spec = spec.pumped(2, mode);
        }
        compile(spec).unwrap()
    };
    let bare = build(Some(PumpMode::BareFast));
    assert_eq!(bare.design.pump, Some((2, PumpMode::BareFast)));
    assert_eq!(bare.design.domain_modes, vec![(2, PumpMode::BareFast)]);
    assert!(
        !bare.design.modules.iter().any(|m| matches!(
            m.spec,
            ModuleSpec::Issuer { .. } | ModuleSpec::Packer { .. }
        )),
        "bare-fast FW must carry no issuer/packer gearboxes"
    );
    assert!(
        bare.design
            .modules
            .iter()
            .any(|m| m.domain == ClockDomain::Fast { factor: 2 }),
        "the FW core must sit in the fast domain"
    );
    // throughput mode needs gearboxes for the same factor — the
    // hardware delta bare-fast eliminates
    let throughput = build(Some(PumpMode::Throughput));
    assert!(throughput.design.modules.iter().any(|m| matches!(
        m.spec,
        ModuleSpec::Issuer { .. } | ModuleSpec::Packer { .. }
    )));

    let run = |c: &Compiled| {
        let mut hbm = Hbm::new();
        hbm.load("dist", d.clone());
        run_exact(&c.design, hbm, 50_000_000).unwrap()
    };
    let (base, fast) = (run(&build(None)), run(&bare));
    assert_eq!(base.hbm.read("dist"), fast.hbm.read("dist"));
    assert_eq!(fast.hbm.read("dist"), apps::floyd_warshall::reference(&d, n).as_slice());
    let speedup = base.stats.slow_cycles as f64 / fast.stats.slow_cycles as f64;
    assert!(
        (1.6..2.2).contains(&speedup),
        "bare-fast FW speedup {speedup:.3} (base {} vs fast {})",
        base.stats.slow_cycles,
        fast.stats.slow_cycles
    );
}

#[test]
fn short_input_reads_zero_fill() {
    // reader beyond the loaded data pads with zeros rather than UB
    let c = compile(
        BuildSpec::new(apps::vecadd::build()).vectorized("vadd", 4).bind("N", 64),
    )
    .unwrap();
    let mut hbm = Hbm::new();
    hbm.load("x", vec![1.0; 16]); // shorter than N
    hbm.load("y", vec![2.0; 64]);
    let out = run_functional(&c.design, hbm).unwrap();
    assert_eq!(out.hbm.read("z")[0], 3.0);
    assert_eq!(out.hbm.read("z")[32], 2.0); // x zero-filled
}
