//! Integration: the full compile pipeline across apps and transform
//! combinations.

use temporal_vec::apps;
use temporal_vec::coordinator::{compile, BuildSpec};
use temporal_vec::ir::PumpMode;

#[test]
fn dsl_to_pumped_design() {
    let src = "
program axpy(N):
  x: f32[N] @ hbm
  y: f32[N] @ hbm
  map i in 0:N:
    y[i] = 2.0 * x[i] + y[i]
";
    let sdfg = temporal_vec::frontend::compile(src).unwrap();
    let c = compile(
        BuildSpec::new(sdfg)
            .vectorized("map0", 4)
            .pumped(2, PumpMode::Resource)
            .bind("N", 4096),
    )
    .unwrap();
    assert!(c.report.cl1.is_some());
    // axpy: mul(3) + add(2) = 5 DSP/lane; 2 internal lanes after DP
    assert_eq!(c.report.resources.dsp, 10.0);
}

#[test]
fn all_apps_compile_original_and_pumped() {
    // vecadd
    for pump in [None, Some((2, PumpMode::Resource))] {
        let mut spec =
            BuildSpec::new(apps::vecadd::build()).vectorized("vadd", 8).bind("N", 1 << 14);
        if let Some((f, m)) = pump {
            spec = spec.pumped(f, m);
        }
        compile(spec).unwrap();
    }
    // matmul
    for pump in [None, Some((2, PumpMode::Resource))] {
        let mut spec = BuildSpec::new(apps::matmul::build(8));
        for (s, v) in apps::matmul::bindings(256) {
            spec = spec.bind(&s, v);
        }
        if let Some((f, m)) = pump {
            spec = spec.pumped(f, m);
        }
        compile(spec).unwrap();
    }
    // stencils
    for kind in [
        temporal_vec::ir::StencilKind::Jacobi3D,
        temporal_vec::ir::StencilKind::Diffusion3D,
    ] {
        let w = apps::stencil::paper_vec_width(kind);
        for pump in [None, Some((2, PumpMode::Resource))] {
            let mut spec = BuildSpec::new(apps::stencil::build(kind, 4, w))
                .bind("NX", 64)
                .bind("NY", 32)
                .bind("NZ", 32)
                .bind("NZ_v", 32 / w as i64);
            if let Some((f, m)) = pump {
                spec = spec.pumped(f, m);
            }
            compile(spec).unwrap();
        }
    }
    // floyd-warshall (throughput mode)
    for pump in [None, Some((2, PumpMode::Throughput))] {
        let mut spec = BuildSpec::new(apps::floyd_warshall::build()).bind("N", 32);
        if let Some((f, m)) = pump {
            spec = spec.pumped(f, m);
        }
        compile(spec).unwrap();
    }
}

#[test]
fn deterministic_given_seed() {
    let mk = || {
        compile(
            BuildSpec::new(apps::vecadd::build())
                .vectorized("vadd", 4)
                .pumped(2, PumpMode::Resource)
                .bind("N", 1 << 12)
                .seeded(99),
        )
        .unwrap()
    };
    let (a, b) = (mk(), mk());
    assert_eq!(a.report.cl0.achieved_mhz, b.report.cl0.achieved_mhz);
    assert_eq!(
        a.report.cl1.unwrap().achieved_mhz,
        b.report.cl1.unwrap().achieved_mhz
    );
    assert_eq!(a.report.resources.dsp, b.report.resources.dsp);
}

#[test]
fn unbound_symbol_reported() {
    let err = match compile(BuildSpec::new(apps::vecadd::build()).vectorized("vadd", 4)) {
        Err(e) => e,
        Ok(_) => panic!("expected unbound-symbol error"),
    };
    assert!(err.contains("unbound") || err.contains("N"), "{err}");
}

#[test]
fn quad_pumping_compiles() {
    let c = compile(
        BuildSpec::new(apps::vecadd::build())
            .vectorized("vadd", 8)
            .pumped(4, PumpMode::Resource)
            .bind("N", 1 << 14),
    )
    .unwrap();
    assert_eq!(c.report.pump_factor, 4);
    // internal lanes 8/4 = 2 → 4 DSP
    assert_eq!(c.report.resources.dsp, 4.0);
}

#[test]
fn pass_log_records_transform_sequence() {
    let c = compile(
        BuildSpec::new(apps::vecadd::build())
            .vectorized("vadd", 2)
            .pumped(2, PumpMode::Resource)
            .bind("N", 1 << 10),
    )
    .unwrap();
    assert_eq!(c.pass_log.len(), 3);
    assert!(c.pass_log[0].contains("Vectorize"));
    assert!(c.pass_log[1].contains("Streaming"));
    assert!(c.pass_log[2].contains("MultiPump"));
}
