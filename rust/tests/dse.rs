//! Integration: the design-space exploration subsystem end to end —
//! legality-pruned grids, cached parallel evaluation, Pareto analysis,
//! and the cross-checks against the paper's hand-picked configurations.

use temporal_vec::apps;
use temporal_vec::coordinator::BuildSpec;
use temporal_vec::dse::{
    frontier, generate, run_search, DesignPoint, Evaluator, FaultPlan, Objective, SearchBase,
    SearchConfig, SpaceOptions, Strategy,
};
use temporal_vec::hw::Device;
use temporal_vec::ir::PumpMode;

/// Table 2's grid: V ∈ {2,4,8}, double/quad pumping, one SLR.
fn vecadd_problem(seed: u64) -> (Vec<SearchBase>, SpaceOptions) {
    let n = 1i64 << 20;
    let bases = vec![SearchBase {
        spec: BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(seed),
        flops: apps::vecadd::flops(n),
    }];
    let opts = SpaceOptions {
        vector_widths: vec![2, 4, 8],
        pump_factors: vec![2, 4],
        pump_modes: vec![PumpMode::Resource],
        max_replicas: 1,
        cl0_requests_mhz: vec![],
        mixed_factors: false,
    };
    (bases, opts)
}

#[test]
fn dse_best_resource_vecadd_matches_paper_table2() {
    // The paper's Table 2 best double-pumped configuration is V=8 DP
    // (M=2, resource mode): half the DSPs at unchanged throughput.
    // The search must land there without being told.
    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )
    .unwrap();

    let chosen = out.chosen.as_ref().expect("a configuration is selected");
    assert_eq!(
        chosen.point,
        DesignPoint {
            vectorize: Some(("vadd".into(), 8)),
            pump: Some((2, PumpMode::Resource)),
            ..DesignPoint::original()
        },
        "chosen {} is not the paper's V=8 DP configuration",
        chosen.label
    );

    // Table 2's headline: DSP exactly halved vs the unpumped V=8 run
    let reference = out.reference.as_ref().unwrap();
    assert_eq!(reference.point.vectorize, Some(("vadd".into(), 8)));
    assert!(reference.point.pump.is_none());
    let dsp_ratio = chosen.total_resources.dsp / reference.total_resources.dsp;
    assert!(
        (dsp_ratio - 0.5).abs() < 0.05,
        "DSP ratio {dsp_ratio} (want ~0.5, Table 2)"
    );
    // and throughput held (paper: time unchanged within noise)
    assert!(chosen.gops >= 0.8 * reference.gops);
}

#[test]
fn dse_matmul_frontier_and_automatic_dsp_halving() {
    // The acceptance experiment: sweep the PE counts of Table 3, let
    // the search pick — it must print a rich frontier and select a
    // pumped configuration at ≤ 55 % of the unpumped DSP count while
    // holding iso-throughput. This reproduces the paper's headline
    // ~50 % DSP reduction automatically, not via a hard-coded spec.
    let n = 1024i64;
    let device = Device::u280();
    let bases: Vec<SearchBase> = [16usize, 32, 64]
        .iter()
        .map(|&pes| {
            let mut spec = BuildSpec::new(apps::matmul::build(pes)).cl0(270.0).seeded(5);
            for (s, v) in apps::matmul::bindings(n) {
                spec = spec.bind(&s, v);
            }
            SearchBase { spec, flops: apps::matmul::flops(n, n, n) }
        })
        .collect();
    let opts = SpaceOptions::for_device(&device);
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )
    .unwrap();

    assert!(
        out.frontier.len() >= 6,
        "frontier has {} points, want ≥ 6:\n{:?}",
        out.frontier.len(),
        out.frontier.iter().map(|e| e.label.clone()).collect::<Vec<_>>()
    );
    // frontier is sorted and genuinely non-dominated
    for w in out.frontier.windows(2) {
        assert!(w[0].resource_score <= w[1].resource_score);
        assert!(
            w[0].gops < w[1].gops || w[0].resource_score < w[1].resource_score,
            "dominated pair on frontier: {} vs {}",
            w[0].label,
            w[1].label
        );
    }

    let chosen = out.chosen.as_ref().unwrap();
    let reference = out.reference.as_ref().unwrap();
    assert!(reference.point.pump.is_none(), "reference must be unpumped");
    assert!(
        chosen.point.pump.is_some(),
        "search must select a pumped configuration, got {}",
        chosen.label
    );
    let dsp_ratio = chosen.total_resources.dsp / reference.total_resources.dsp;
    assert!(
        dsp_ratio <= 0.55,
        "chosen {} uses {dsp_ratio:.2} of the unpumped DSP count (want ≤ 0.55)",
        chosen.label
    );
    assert!(
        chosen.gops >= 0.8 * reference.gops,
        "iso-throughput violated: {} vs reference {}",
        chosen.gops,
        reference.gops
    );
}

#[test]
fn dse_floyd_warshall_selects_throughput_mode() {
    // FW cannot be resource-pumped (scalar dependent datapath): the
    // space must contain no resource candidates and the throughput
    // objective must land on a throughput-mode pumped design — the
    // paper's §4.4 configuration, found automatically.
    let n = 128i64;
    let device = Device::u280();
    let bases = vec![SearchBase {
        spec: BuildSpec::new(apps::floyd_warshall::build())
            .bind("N", n)
            .cl0(apps::floyd_warshall::CL0_REQUEST_MHZ)
            .seeded(2),
        flops: apps::floyd_warshall::flops(n),
    }];
    // both modes offered: the *legality analysis* must prune resource
    // mode for FW, not the option list
    let opts = SpaceOptions {
        vector_widths: vec![],
        pump_factors: vec![2, 4],
        pump_modes: vec![PumpMode::Resource, PumpMode::Throughput],
        max_replicas: 1,
        cl0_requests_mhz: vec![],
        mixed_factors: false,
    };
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::throughput()),
    )
    .unwrap();
    for e in &out.evaluations {
        assert!(
            !matches!(e.point.pump, Some((_, PumpMode::Resource))),
            "illegal resource-mode candidate {}",
            e.label
        );
    }
    let chosen = out.chosen.unwrap();
    assert!(
        matches!(chosen.point.pump, Some((_, PumpMode::Throughput))),
        "chosen {} is not throughput-pumped",
        chosen.label
    );
    let reference = out.reference.unwrap();
    assert!(chosen.gops > reference.gops, "pumping must raise FW throughput");
}

#[test]
fn dse_cache_makes_repeated_sweeps_incremental() {
    // Same spec twice through the shared evaluator: the second sweep
    // is served entirely from the content-hashed cache and returns
    // identical reports (cache-hit determinism).
    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let cfg = SearchConfig::exhaustive(Objective::resource());
    let ev = Evaluator::new();
    let first = run_search(&ev, &bases, &device, &opts, &cfg).unwrap();
    let misses = ev.cache_misses();
    let second = run_search(&ev, &bases, &device, &opts, &cfg).unwrap();
    assert_eq!(ev.cache_misses(), misses, "second sweep recompiled something");
    assert!(ev.cache_hits() >= first.evaluations.len());
    let (a, b) = (first.chosen.unwrap(), second.chosen.unwrap());
    assert_eq!(a.point, b.point);
    assert_eq!(a.report.cl0.achieved_mhz, b.report.cl0.achieved_mhz);
    assert_eq!(a.report.resources, b.report.resources);
    assert_eq!(a.gops, b.gops);
}

#[test]
fn dse_greedy_respects_budget_and_stays_sane() {
    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let cfg = SearchConfig {
        strategy: Strategy::Greedy,
        objective: Objective::resource(),
        budget: Some(30),
        seed: 1,
        deadline_ms: None,
        sim_cycle_budget: None,
    };
    let out = run_search(&Evaluator::new(), &bases, &device, &opts, &cfg).unwrap();
    assert!(out.evaluated <= 30);
    let chosen = out.chosen.unwrap();
    // greedy must at least not regress below the unpumped reference
    let reference = out.reference.unwrap();
    assert!(chosen.resource_score <= reference.resource_score + 1e-12);
}

#[test]
fn dse_all_strategies_agree_on_the_small_vecadd_space() {
    // Table 2's space is small enough that every strategy — including
    // the stochastic ones — must land on the same optimum the
    // exhaustive sweep proves is best.
    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let ev = Evaluator::new();
    let mut chosen_points = Vec::new();
    for strategy in [
        Strategy::Exhaustive,
        Strategy::Greedy,
        Strategy::Anneal,
        Strategy::Halving,
    ] {
        let cfg = SearchConfig {
            strategy,
            objective: Objective::resource(),
            budget: None,
            seed: 23,
            deadline_ms: None,
            sim_cycle_budget: None,
        };
        let out = run_search(&ev, &bases, &device, &opts, &cfg).unwrap();
        chosen_points.push((strategy, out.chosen.unwrap().point));
    }
    for (s, p) in &chosen_points[1..] {
        assert_eq!(
            p, &chosen_points[0].1,
            "{} diverged from exhaustive",
            s.name()
        );
    }
}

#[test]
fn dse_persistent_cache_round_trips_across_evaluators() {
    // "two processes" sharing a --cache-dir: the first sweeps and
    // flushes, the second loads and re-runs the identical sweep with
    // zero new compiles and a bit-identical chosen report.
    let dir = std::env::temp_dir().join(format!("tvec-dse-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let cfg = SearchConfig::exhaustive(Objective::resource());

    let first = Evaluator::with_cache_dir(&dir);
    assert_eq!(first.loaded_entries(), 0);
    let out1 = run_search(&first, &bases, &device, &opts, &cfg).unwrap();
    assert!(first.cache_misses() > 0, "cold run must compile");
    let flushed = first.flush().unwrap();
    assert!(flushed >= first.cache_misses());

    let second = Evaluator::with_cache_dir(&dir);
    assert_eq!(second.loaded_entries(), flushed);
    assert!(second.cold_reason().is_none());
    let out2 = run_search(&second, &bases, &device, &opts, &cfg).unwrap();
    assert_eq!(
        second.cache_misses(),
        0,
        "warm run must evaluate 0 uncached candidates"
    );
    assert_eq!(out1.evaluated, out2.evaluated);
    let (a, b) = (out1.chosen.unwrap(), out2.chosen.unwrap());
    assert_eq!(a.point, b.point);
    assert_eq!(a.gops.to_bits(), b.gops.to_bits(), "disk round trip must be bit exact");
    assert_eq!(a.report.cl0.achieved_mhz.to_bits(), b.report.cl0.achieved_mhz.to_bits());
    assert_eq!(a.report.resources, b.report.resources);

    // flushing the second evaluator merges, never shrinks
    let reflushed = second.flush().unwrap();
    assert_eq!(reflushed, flushed);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dse_persistent_cache_survives_corruption_as_cold_start() {
    let dir = std::env::temp_dir().join(format!("tvec-dse-corrupt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(temporal_vec::dse::cache::FILE_NAME);
    std::fs::write(
        &path,
        format!(
            "#tvec-dse-cache v{}\ngarbage line without tabs\n",
            temporal_vec::dse::cache::SCHEMA_VERSION
        ),
    )
    .unwrap();
    let ev = Evaluator::with_cache_dir(&dir);
    assert_eq!(ev.loaded_entries(), 0);
    assert!(ev.cold_reason().is_some(), "corruption must be reported, not ignored");
    // and the evaluator still works end to end
    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let cfg = SearchConfig::exhaustive(Objective::resource());
    let out = run_search(&ev, &bases, &device, &opts, &cfg).unwrap();
    assert!(out.chosen.is_some());
    // a flush repairs the store
    ev.flush().unwrap();
    let repaired = Evaluator::with_cache_dir(&dir);
    assert!(repaired.cold_reason().is_none());
    assert!(repaired.loaded_entries() > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The mixed per-region search problem for the stencil chain. `n`
/// overrides NX (resources and clocks are NX-independent, so the
/// frontier structure is the same at any scale — small NX keeps the
/// sweep fast). Resource mode only, single SLR: the Table-2-style
/// resource study the mixed dimension extends.
fn stencil_mixed_problem(n: i64) -> (Vec<SearchBase>, SpaceOptions) {
    let device = Device::u280();
    let (bases, mut opts) =
        temporal_vec::coordinator::search_problem("stencil", Some(n), 1, &device).unwrap();
    opts.mixed_factors = true;
    opts.pump_modes = vec![PumpMode::Resource];
    opts.max_replicas = 1;
    (bases, opts)
}

#[test]
fn dse_mixed_assignment_reaches_the_frontier_and_beats_best_uniform_resource() {
    // The PR's acceptance criterion: with --mixed-factors on the
    // stencil chain, at least one mixed per-region assignment survives
    // to the Pareto frontier and strictly undercuts the best uniform
    // point (the one the resource objective selects) on the resource
    // axis. The mechanism: at CL0 ≈ 315 MHz a factor-4 domain is capped
    // by the 650 MHz request ceiling, so uniform R4 sacrifices
    // throughput; uniform R2 holds throughput but pays double the
    // compute width everywhere. A 4/2 split keeps part of the chain at
    // quarter width — cheaper than R2 — while its small factor-4
    // domain closes timing at the cap, faster than uniform R4.
    let (bases, opts) = stencil_mixed_problem(1 << 10);
    let device = Device::u280();
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )
    .unwrap();

    let mixed_on_frontier: Vec<_> =
        out.frontier.iter().filter(|e| e.point.regions.is_some()).collect();
    assert!(
        !mixed_on_frontier.is_empty(),
        "no mixed assignment on the frontier: {:?}",
        out.frontier.iter().map(|e| e.label.clone()).collect::<Vec<_>>()
    );

    // best uniform point under the resource objective
    let reference = out.reference.as_ref().unwrap();
    let uniform: Vec<_> = out
        .evaluations
        .iter()
        .filter(|e| e.point.regions.is_none())
        .cloned()
        .collect();
    let best_uniform = Objective::resource()
        .select(&uniform, reference)
        .expect("a uniform point satisfies the objective")
        .clone();
    let cheapest_mixed = mixed_on_frontier
        .iter()
        .map(|e| e.resource_score)
        .fold(f64::INFINITY, f64::min);
    assert!(
        cheapest_mixed < best_uniform.resource_score,
        "mixed frontier points (cheapest score {cheapest_mixed:.3}) do not undercut the \
         best uniform point {} (score {:.3})",
        best_uniform.label,
        best_uniform.resource_score
    );
}

#[test]
fn dse_mixed_frontier_verifies_at_golden_scale() {
    // acceptance: `dse --verify` over the mixed frontier — rebuild
    // mixed frontier points at golden (artifact) scale and demand
    // rate-model vs exact-simulator agreement within the default
    // tolerance. The search already runs at golden scale here, so the
    // verified points are exactly the reported ones.
    use temporal_vec::dse::{verify_frontier, DEFAULT_TOLERANCE};
    let golden_nx = temporal_vec::apps::stencil::GOLDEN_NX;
    let (bases, opts) = stencil_mixed_problem(golden_nx);
    let device = Device::u280();
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )
    .unwrap();
    let mixed: Vec<temporal_vec::dse::Evaluation> = out
        .frontier
        .iter()
        .filter(|e| e.point.regions.is_some())
        .take(3) // bound the exact-sim time; any surviving point qualifies
        .cloned()
        .collect();
    assert!(!mixed.is_empty(), "no mixed frontier point to verify");
    let rig = temporal_vec::coordinator::golden_rig("stencil", 1).unwrap();
    let reports = verify_frontier(&mixed, &rig.bases, &rig.inputs, DEFAULT_TOLERANCE).unwrap();
    assert_eq!(reports.len(), mixed.len());
    for r in &reports {
        assert!(r.skipped.is_none(), "{}: unexpected golden-scale skip", r.label);
        assert!(
            r.within,
            "{}: rate {} vs exact {} (ratio {:.3})",
            r.label, r.rate_cycles, r.exact_cycles, r.ratio
        );
    }
}

#[test]
fn dse_floyd_warshall_barefast_reaches_the_frontier_gearbox_free() {
    // The mode axis extended with bare-fast: FW's dependent scalar
    // datapath (II = 21) is exactly the shape the dace-style "just
    // clock it faster" mode exists for. B2 delivers T2's doubled
    // throughput with no issuer/packer and no widened datapath, so it
    // must survive to the Pareto frontier and undercut T2 on logic.
    let n = 128i64;
    let device = Device::u280();
    let bases = vec![SearchBase {
        spec: BuildSpec::new(apps::floyd_warshall::build())
            .bind("N", n)
            .cl0(apps::floyd_warshall::CL0_REQUEST_MHZ)
            .seeded(2),
        flops: apps::floyd_warshall::flops(n),
    }];
    let opts = SpaceOptions {
        vector_widths: vec![],
        pump_factors: vec![2],
        pump_modes: vec![PumpMode::Throughput, PumpMode::BareFast],
        max_replicas: 1,
        cl0_requests_mhz: vec![],
        mixed_factors: false,
    };
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::throughput()),
    )
    .unwrap();
    let b2 = out
        .frontier
        .iter()
        .find(|e| e.point.pump == Some((2, PumpMode::BareFast)))
        .unwrap_or_else(|| {
            panic!(
                "no bare-fast point on the frontier: {:?}",
                out.frontier.iter().map(|e| e.label.clone()).collect::<Vec<_>>()
            )
        });
    let reference = out.reference.as_ref().unwrap();
    assert!(
        b2.gops > reference.gops,
        "bare-fast must raise FW throughput ({} vs {})",
        b2.gops,
        reference.gops
    );
    let t2 = out
        .evaluations
        .iter()
        .find(|e| e.point.pump == Some((2, PumpMode::Throughput)))
        .expect("throughput mode evaluates in the same sweep");
    assert!(
        b2.total_resources.lut_logic < t2.total_resources.lut_logic,
        "gearbox-free bare-fast must be leaner than throughput mode \
         ({} vs {} LUTs)",
        b2.total_resources.lut_logic,
        t2.total_resources.lut_logic
    );
    assert!(
        b2.resource_score <= t2.resource_score,
        "B2 score {} vs T2 score {}",
        b2.resource_score,
        t2.resource_score
    );
}

#[test]
fn dse_mode_mixed_space_strictly_extends_the_uniform_frontier() {
    // The PR's acceptance criterion for the unified per-region space:
    // with both gearboxed modes on the mode axis and --mixed-factors
    // on, the search must (a) actually evaluate assignments whose
    // regions disagree on *mode*, not just factor, and (b) produce a
    // frontier that strictly extends the uniform-only frontier — some
    // per-region point no uniform configuration dominates.
    let (bases, mut opts) = stencil_mixed_problem(1 << 10);
    opts.pump_modes = vec![PumpMode::Resource, PumpMode::Throughput];
    let device = Device::u280();
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )
    .unwrap();

    let mode_mixed = out.evaluations.iter().filter(|e| {
        e.point.regions.as_ref().is_some_and(|fs| {
            let modes: Vec<_> = fs.iter().flatten().map(|p| p.mode).collect();
            modes.windows(2).any(|w| w[0] != w[1])
        })
    });
    assert!(
        mode_mixed.count() > 0,
        "no mode-mixed per-region assignment survived to evaluation"
    );

    let uniform: Vec<_> =
        out.evaluations.iter().filter(|e| e.point.regions.is_none()).collect();
    assert!(!uniform.is_empty());
    let strictly_new = out
        .frontier
        .iter()
        .filter(|e| e.point.regions.is_some())
        .any(|m| {
            !uniform.iter().any(|u| {
                u.resource_score <= m.resource_score && u.gops >= m.gops
            })
        });
    assert!(
        strictly_new,
        "every per-region frontier point is dominated by a uniform one: {:?}",
        out.frontier.iter().map(|e| e.label.clone()).collect::<Vec<_>>()
    );
}

#[test]
fn dse_cache_compaction_shrinks_a_grown_store() {
    // the append-only growth fix: a run that touches a subset of a big
    // store and flushes with --cache-compact rewrites the file with
    // only the entries it used — the file shrinks instead of merging
    // every stale record back forever
    let dir = std::env::temp_dir().join(format!("tvec-dse-compact-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let device = Device::u280();

    // seed the store with a full sweep
    let (bases, opts) = vecadd_problem(11);
    let seeder = Evaluator::with_cache_dir(&dir);
    run_search(&seeder, &bases, &device, &opts, &SearchConfig::exhaustive(Objective::resource()))
        .unwrap();
    let full = seeder.flush().unwrap();
    assert!(full > 2, "need a non-trivial store to compact, got {full} entries");
    let path = dir.join(temporal_vec::dse::cache::FILE_NAME);
    let bytes_before = std::fs::metadata(&path).unwrap().len();

    // a later run touches only one candidate, then compacts
    let toucher = Evaluator::with_cache_dir(&dir);
    assert_eq!(toucher.loaded_entries(), full);
    let base = &bases[0];
    toucher.evaluate(&base.spec, &DesignPoint::original(), base.flops).unwrap();
    assert_eq!(toucher.cache_misses(), 0, "the touched point must be a cache hit");
    let (before, after) = toucher.flush_compacted().unwrap();
    assert_eq!(before, full);
    assert_eq!(after, 1);
    let bytes_after = std::fs::metadata(&path).unwrap().len();
    assert!(
        bytes_after < bytes_before,
        "compacted file did not shrink ({bytes_before} → {bytes_after} bytes)"
    );

    // the survivor still round-trips
    let reloaded = Evaluator::with_cache_dir(&dir);
    assert!(reloaded.cold_reason().is_none());
    assert_eq!(reloaded.loaded_entries(), 1);
    let again = reloaded.evaluate(&base.spec, &DesignPoint::original(), base.flops).unwrap();
    assert_eq!(reloaded.cache_misses(), 0, "survivor must hit");
    assert!(again.fits);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dse_failure_kinds_are_reported_separately() {
    // an indivisible problem size: the grid prunes width 8 up front,
    // nothing hard-fails compilation, and the outcome's two failure
    // counters stay consistent with the aggregate
    let n = 24i64; // widths 2, 4 divide; 8 does not
    let device = Device::u280();
    let bases = vec![SearchBase {
        spec: BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(2),
        flops: apps::vecadd::flops(n),
    }];
    let opts = SpaceOptions {
        vector_widths: vec![2, 4, 8],
        pump_factors: vec![2],
        pump_modes: vec![PumpMode::Resource],
        max_replicas: 1,
        cl0_requests_mhz: vec![],
        mixed_factors: false,
    };
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )
    .unwrap();
    assert!(
        out.evaluations
            .iter()
            .all(|e| e.point.vectorize.as_ref().map(|(_, w)| *w) != Some(8)),
        "width 8 must be legality-pruned from the grid for N = 24"
    );
    assert_eq!(out.compile_failed, 0, "nothing should hard-fail compilation");
    assert_eq!(out.infeasible(), out.illegal + out.compile_failed);
}

/// The unpumped-single-replica predicate `run_search` uses for its
/// baseline sweep — reproduced white-box so fault tests can compute
/// deterministic evaluation ordinals (baselines are issued first, in
/// grid order; the exhaustive batch follows, baselines excluded).
fn is_baseline(p: &DesignPoint) -> bool {
    p.pump.is_none() && p.regions.is_none() && p.replicas == 1 && p.cl0_request_mhz.is_none()
}

/// Ordinal of the first exhaustive-batch evaluation (== the number of
/// baseline candidates issued before it) plus the grid-ordered pumped
/// batch, for one-base exhaustive sweeps.
fn exhaustive_ordinals(
    bases: &[SearchBase],
    device: &Device,
    opts: &SpaceOptions,
) -> (usize, Vec<DesignPoint>) {
    let grid = generate(&bases[0].spec, device, opts);
    let baseline_count = grid.iter().filter(|p| is_baseline(p)).count();
    let batch: Vec<DesignPoint> = grid
        .into_iter()
        .filter(|p| *p != DesignPoint::original() && !is_baseline(p))
        .collect();
    (baseline_count, batch)
}

#[test]
fn dse_faulted_sweep_completes_and_matches_the_faultless_frontier() {
    // the PR's acceptance test: a sweep with one panicking and one
    // wedging candidate finishes exit-0, classifies both distinctly,
    // and its frontier equals the fault-free frontier computed over
    // the surviving candidates
    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let cfg =
        SearchConfig::exhaustive(Objective::resource()).with_limits(Some(2_000), None);

    let clean = run_search(&Evaluator::new(), &bases, &device, &opts, &cfg).unwrap();
    assert_eq!(clean.quarantined(), 0);

    let (baseline_count, batch) = exhaustive_ordinals(&bases, &device, &opts);
    assert!(batch.len() >= 2, "need two pumped candidates to fault");
    let faulted_points = [batch[0].clone(), batch[1].clone()];
    let faulted_labels: Vec<String> = clean
        .evaluations
        .iter()
        .filter(|e| faulted_points.contains(&e.point))
        .map(|e| e.label.clone())
        .collect();
    assert_eq!(faulted_labels.len(), 2, "both faulted candidates evaluate cleanly unfaulted");

    let spec = format!("panic@{},wedge@{}", baseline_count, baseline_count + 1);
    let ev = Evaluator::new().with_faults(FaultPlan::parse(&spec).unwrap());
    let faulted = run_search(&ev, &bases, &device, &opts, &cfg).unwrap();
    assert_eq!(faulted.panicked, 1, "the injected panic must classify as FailKind::Panic");
    assert_eq!(faulted.timed_out, 1, "the injected wedge must be reaped as FailKind::Timeout");
    assert_eq!(faulted.quarantined(), 2);
    assert_eq!(ev.faults().unwrap().fired(), 2);

    // frontier equality over the survivors
    let survivors: Vec<temporal_vec::dse::Evaluation> = clean
        .evaluations
        .iter()
        .filter(|e| !faulted_labels.contains(&e.label))
        .cloned()
        .collect();
    let expect: Vec<String> = frontier(&survivors).iter().map(|e| e.label.clone()).collect();
    let got: Vec<String> = faulted.frontier.iter().map(|e| e.label.clone()).collect();
    assert_eq!(got, expect, "faulted frontier diverged from the fault-free survivors");

    // the evaluator is still healthy: no poisoned mutex, no leaked
    // arena slots, and a quarantined candidate is never retried
    let base = &bases[0];
    let again = ev.evaluate(&base.spec, &faulted_points[0], base.flops);
    assert!(
        matches!(&again, Err(e) if e.kind == temporal_vec::dse::FailKind::Panic),
        "quarantined candidate must stay quarantined within the run"
    );
    assert_eq!(ev.faults().unwrap().fired(), 2, "a memoized quarantine hit must not re-fire");
    ev.evaluate(&base.spec, &DesignPoint::original(), base.flops).unwrap();
}

#[test]
fn dse_cache_write_faults_retry_then_degrade_without_crashing() {
    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let base = &bases[0];

    // one injected write failure: the bounded retry recovers and the
    // store still lands on disk
    let dir = std::env::temp_dir().join(format!("tvec-dse-iofault-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let ev = Evaluator::with_cache_dir(&dir)
        .with_faults(FaultPlan::parse("cachefail@0").unwrap());
    run_search(&ev, &bases, &device, &opts, &SearchConfig::exhaustive(Objective::resource()))
        .unwrap();
    let flushed = ev.flush().unwrap();
    assert!(flushed > 0, "retried flush must persist the sweep");
    assert!(!ev.degraded());
    assert_eq!(ev.faults().unwrap().fired(), 1);
    let reloaded = Evaluator::with_cache_dir(&dir);
    assert!(reloaded.cold_reason().is_none());
    assert_eq!(reloaded.loaded_entries(), flushed);
    let _ = std::fs::remove_dir_all(&dir);

    // every attempt fails: the evaluator degrades to in-memory-only
    // with a warning — never a crash, and never a failed sweep
    let dir2 = std::env::temp_dir().join(format!("tvec-dse-iofault2-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir2);
    std::fs::create_dir_all(&dir2).unwrap();
    let ev2 = Evaluator::with_cache_dir(&dir2)
        .with_faults(FaultPlan::parse("cachefail@0,cachefail@1,cachefail@2,cachefail@3").unwrap());
    ev2.evaluate(&base.spec, &DesignPoint::original(), base.flops).unwrap();
    assert_eq!(ev2.flush().unwrap(), 0, "exhausted retries must degrade, not error");
    assert!(ev2.degraded());
    // still evaluable after degrading, and later flushes stay quiet
    let pumped = DesignPoint {
        vectorize: Some(("vadd".into(), 4)),
        pump: Some((2, PumpMode::Resource)),
        ..DesignPoint::original()
    };
    ev2.evaluate(&base.spec, &pumped, base.flops).unwrap();
    assert_eq!(ev2.flush().unwrap(), 0);
    let _ = std::fs::remove_dir_all(&dir2);
}

#[test]
fn dse_quarantined_failures_are_not_persisted() {
    // a panic entry is memo-cached for the run (no retry storms) but
    // must never reach the disk store: the next process gets a clean
    // shot at the candidate
    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let cfg = SearchConfig::exhaustive(Objective::resource());
    let dir = std::env::temp_dir().join(format!("tvec-dse-quar-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    let (baseline_count, _) = exhaustive_ordinals(&bases, &device, &opts);
    let ev = Evaluator::with_cache_dir(&dir)
        .with_faults(FaultPlan::parse(&format!("panic@{baseline_count}")).unwrap());
    let faulted = run_search(&ev, &bases, &device, &opts, &cfg).unwrap();
    assert_eq!(faulted.panicked, 1);
    let flushed = ev.flush().unwrap();

    let warm = Evaluator::with_cache_dir(&dir);
    assert_eq!(warm.loaded_entries(), flushed, "quarantined entry must not be persisted");
    let healed = run_search(&warm, &bases, &device, &opts, &cfg).unwrap();
    assert_eq!(healed.panicked, 0);
    assert_eq!(
        warm.cache_misses(),
        1,
        "exactly the formerly quarantined candidate re-compiles on the warm run"
    );
    // and the healed sweep matches a never-faulted one
    let clean = run_search(&Evaluator::new(), &bases, &device, &opts, &cfg).unwrap();
    let healed_front: Vec<String> =
        healed.frontier.iter().map(|e| e.label.clone()).collect();
    let clean_front: Vec<String> = clean.frontier.iter().map(|e| e.label.clone()).collect();
    assert_eq!(healed_front, clean_front);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dse_concurrent_flush_lock_skips_and_recovers() {
    // the flush-race satellite: a live advisory lock makes a merging
    // flush skip (entries stay in memory, nothing is lost) and makes
    // compaction fail loudly; once the lock is gone the same evaluator
    // flushes normally
    let device = Device::u280();
    let (bases, _opts) = vecadd_problem(11);
    let base = &bases[0];
    let dir = std::env::temp_dir().join(format!("tvec-dse-lock-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let store = dir.join(temporal_vec::dse::cache::FILE_NAME);
    let lock = store.with_extension("lock");
    std::fs::write(&lock, b"").unwrap();

    let ev = Evaluator::with_cache_dir(&dir);
    ev.evaluate(&base.spec, &DesignPoint::original(), base.flops).unwrap();
    assert_eq!(ev.flush().unwrap(), 0, "contended merging flush must skip, not fail");
    assert!(!store.exists(), "a skipped flush must not have touched the store");
    assert!(!ev.degraded(), "lock contention is not IO degradation");
    let compact_err = ev.flush_compacted().unwrap_err();
    assert!(compact_err.contains("locked"), "{compact_err}");

    std::fs::remove_file(&lock).unwrap();
    let flushed = ev.flush().unwrap();
    assert!(flushed > 0, "flush must succeed once the lock is released");
    assert!(store.exists());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dse_serve_answers_ndjson_requests_against_one_shared_cache() {
    use std::io::{BufRead, BufReader, Write};
    use std::os::unix::net::UnixStream;
    use temporal_vec::coordinator::{run_serve, ServeOptions};
    use temporal_vec::util::json::Json;

    fn ask(stream: &mut UnixStream, reader: &mut BufReader<UnixStream>, req: &str) -> Json {
        stream.write_all(format!("{req}\n").as_bytes()).unwrap();
        stream.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    }

    let dir = std::env::temp_dir().join(format!("tvec-dse-serve-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let socket = dir.join("tvec.sock");
    let bench = dir.join("BENCH_serve.json");

    let mut sopts = ServeOptions::new(&socket);
    sopts.cache_dir = Some(dir.join("cache"));
    sopts.bench_out = bench.clone();
    sopts.deadline_ms = Some(30_000);
    let server = std::thread::spawn(move || run_serve(sopts));

    let mut stream = None;
    for _ in 0..400 {
        match UnixStream::connect(&socket) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(25)),
        }
    }
    let mut stream = stream.expect("serve daemon did not come up");
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    let pong = ask(&mut stream, &mut reader, r#"{"op":"ping"}"#);
    assert_eq!(pong.get("ok").and_then(Json::as_bool), Some(true));

    let req = r#"{"op":"search","app":"vecadd","budget":8,"seed":9}"#;
    let first = ask(&mut stream, &mut reader, req);
    assert_eq!(first.get("ok").and_then(Json::as_bool), Some(true), "{first:?}");
    assert!(first.get("new_compiles").and_then(Json::as_u64).unwrap() > 0);
    assert!(first.get("chosen").and_then(Json::as_str).is_some());

    // the second identical request runs against the warm shared cache
    let second = ask(&mut stream, &mut reader, req);
    assert_eq!(second.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(
        second.get("new_compiles").and_then(Json::as_u64),
        Some(0),
        "warm request must compile nothing: {second:?}"
    );
    assert!(second.get("cache_hits").and_then(Json::as_u64).unwrap() > 0);
    assert_eq!(second.get("quarantined").and_then(Json::as_u64), Some(0));

    // a malformed request fails that request, not the daemon
    let bad = ask(&mut stream, &mut reader, r#"{"op":"search"}"#);
    assert_eq!(bad.get("ok").and_then(Json::as_bool), Some(false));

    let down = ask(&mut stream, &mut reader, r#"{"op":"shutdown"}"#);
    assert_eq!(down.get("ok").and_then(Json::as_bool), Some(true));
    server.join().unwrap().expect("graceful shutdown is an Ok exit");

    let body = std::fs::read_to_string(&bench).expect("BENCH_serve.json must be written");
    assert!(body.contains("tvec-serve v1"), "{body}");
    assert!(body.contains("\"requests\": 5"), "{body}");
    assert!(!socket.exists(), "the socket file must be cleaned up");
    let warm_store = dir.join("cache").join(temporal_vec::dse::cache::FILE_NAME);
    assert!(warm_store.exists(), "graceful shutdown must flush the shared cache");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dse_threaded_search_and_verify_match_serial_exactly() {
    // `--threads` is a performance knob, never a semantics knob: a
    // serial (--threads 1) sweep and a 4-worker sweep must produce the
    // same frontier and selection, and the pooled frontier verify must
    // return report-identical results at 1 and 4 workers.
    use temporal_vec::dse::{verify_frontier_pooled, ArenaPool, VerifyBudget, DEFAULT_TOLERANCE};
    use temporal_vec::util::Rng;

    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let cfg = SearchConfig::exhaustive(Objective::resource());

    let serial_ev = Evaluator::new();
    serial_ev.set_threads(1);
    let threaded_ev = Evaluator::new();
    threaded_ev.set_threads(4);
    assert_eq!(serial_ev.threads(), 1);
    assert_eq!(threaded_ev.threads(), 4);
    let serial = run_search(&serial_ev, &bases, &device, &opts, &cfg).unwrap();
    let threaded = run_search(&threaded_ev, &bases, &device, &opts, &cfg).unwrap();
    let labels = |o: &temporal_vec::dse::SearchOutcome| -> Vec<String> {
        o.frontier.iter().map(|e| e.label.clone()).collect()
    };
    assert_eq!(labels(&serial), labels(&threaded), "frontier depends on --threads");
    assert_eq!(
        serial.chosen.as_ref().map(|c| c.label.clone()),
        threaded.chosen.as_ref().map(|c| c.label.clone()),
        "selection depends on --threads"
    );
    assert!(!serial.frontier.is_empty());

    let n = apps::vecadd::GOLDEN_N;
    let golden = BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(11);
    let mut rng = Rng::new(2024);
    let inputs = vec![
        ("x".to_string(), rng.f32_vec(n as usize)),
        ("y".to_string(), rng.f32_vec(n as usize)),
    ];
    let one = verify_frontier_pooled(
        &serial.frontier,
        std::slice::from_ref(&golden),
        &inputs,
        DEFAULT_TOLERANCE,
        VerifyBudget::default(),
        &ArenaPool::default(),
        1,
        None,
    )
    .unwrap();
    let four = verify_frontier_pooled(
        &threaded.frontier,
        std::slice::from_ref(&golden),
        &inputs,
        DEFAULT_TOLERANCE,
        VerifyBudget::default(),
        &ArenaPool::default(),
        4,
        None,
    )
    .unwrap();
    assert_eq!(one.len(), four.len());
    for (a, b) in one.iter().zip(&four) {
        assert_eq!(a.label, b.label);
        assert_eq!(a.rate_cycles, b.rate_cycles);
        assert_eq!(a.exact_cycles, b.exact_cycles);
        assert_eq!(a.within, b.within);
        assert_eq!(a.skipped, b.skipped);
    }
}
