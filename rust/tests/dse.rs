//! Integration: the design-space exploration subsystem end to end —
//! legality-pruned grids, cached parallel evaluation, Pareto analysis,
//! and the cross-checks against the paper's hand-picked configurations.

use temporal_vec::apps;
use temporal_vec::coordinator::BuildSpec;
use temporal_vec::dse::{
    run_search, DesignPoint, Evaluator, Objective, SearchBase, SearchConfig, SpaceOptions,
    Strategy,
};
use temporal_vec::hw::Device;
use temporal_vec::ir::PumpMode;

/// Table 2's grid: V ∈ {2,4,8}, double/quad pumping, one SLR.
fn vecadd_problem(seed: u64) -> (Vec<SearchBase>, SpaceOptions) {
    let n = 1i64 << 20;
    let bases = vec![SearchBase {
        spec: BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(seed),
        flops: apps::vecadd::flops(n),
    }];
    let opts = SpaceOptions {
        vector_widths: vec![2, 4, 8],
        pump_factors: vec![2, 4],
        pump_modes: vec![PumpMode::Resource],
        max_replicas: 1,
        cl0_requests_mhz: vec![],
        mixed_factors: false,
    };
    (bases, opts)
}

#[test]
fn dse_best_resource_vecadd_matches_paper_table2() {
    // The paper's Table 2 best double-pumped configuration is V=8 DP
    // (M=2, resource mode): half the DSPs at unchanged throughput.
    // The search must land there without being told.
    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )
    .unwrap();

    let chosen = out.chosen.as_ref().expect("a configuration is selected");
    assert_eq!(
        chosen.point,
        DesignPoint {
            vectorize: Some(("vadd".into(), 8)),
            pump: Some((2, PumpMode::Resource)),
            ..DesignPoint::original()
        },
        "chosen {} is not the paper's V=8 DP configuration",
        chosen.label
    );

    // Table 2's headline: DSP exactly halved vs the unpumped V=8 run
    let reference = out.reference.as_ref().unwrap();
    assert_eq!(reference.point.vectorize, Some(("vadd".into(), 8)));
    assert!(reference.point.pump.is_none());
    let dsp_ratio = chosen.total_resources.dsp / reference.total_resources.dsp;
    assert!(
        (dsp_ratio - 0.5).abs() < 0.05,
        "DSP ratio {dsp_ratio} (want ~0.5, Table 2)"
    );
    // and throughput held (paper: time unchanged within noise)
    assert!(chosen.gops >= 0.8 * reference.gops);
}

#[test]
fn dse_matmul_frontier_and_automatic_dsp_halving() {
    // The acceptance experiment: sweep the PE counts of Table 3, let
    // the search pick — it must print a rich frontier and select a
    // pumped configuration at ≤ 55 % of the unpumped DSP count while
    // holding iso-throughput. This reproduces the paper's headline
    // ~50 % DSP reduction automatically, not via a hard-coded spec.
    let n = 1024i64;
    let device = Device::u280();
    let bases: Vec<SearchBase> = [16usize, 32, 64]
        .iter()
        .map(|&pes| {
            let mut spec = BuildSpec::new(apps::matmul::build(pes)).cl0(270.0).seeded(5);
            for (s, v) in apps::matmul::bindings(n) {
                spec = spec.bind(&s, v);
            }
            SearchBase { spec, flops: apps::matmul::flops(n, n, n) }
        })
        .collect();
    let opts = SpaceOptions::for_device(&device);
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )
    .unwrap();

    assert!(
        out.frontier.len() >= 6,
        "frontier has {} points, want ≥ 6:\n{:?}",
        out.frontier.len(),
        out.frontier.iter().map(|e| e.label.clone()).collect::<Vec<_>>()
    );
    // frontier is sorted and genuinely non-dominated
    for w in out.frontier.windows(2) {
        assert!(w[0].resource_score <= w[1].resource_score);
        assert!(
            w[0].gops < w[1].gops || w[0].resource_score < w[1].resource_score,
            "dominated pair on frontier: {} vs {}",
            w[0].label,
            w[1].label
        );
    }

    let chosen = out.chosen.as_ref().unwrap();
    let reference = out.reference.as_ref().unwrap();
    assert!(reference.point.pump.is_none(), "reference must be unpumped");
    assert!(
        chosen.point.pump.is_some(),
        "search must select a pumped configuration, got {}",
        chosen.label
    );
    let dsp_ratio = chosen.total_resources.dsp / reference.total_resources.dsp;
    assert!(
        dsp_ratio <= 0.55,
        "chosen {} uses {dsp_ratio:.2} of the unpumped DSP count (want ≤ 0.55)",
        chosen.label
    );
    assert!(
        chosen.gops >= 0.8 * reference.gops,
        "iso-throughput violated: {} vs reference {}",
        chosen.gops,
        reference.gops
    );
}

#[test]
fn dse_floyd_warshall_selects_throughput_mode() {
    // FW cannot be resource-pumped (scalar dependent datapath): the
    // space must contain no resource candidates and the throughput
    // objective must land on a throughput-mode pumped design — the
    // paper's §4.4 configuration, found automatically.
    let n = 128i64;
    let device = Device::u280();
    let bases = vec![SearchBase {
        spec: BuildSpec::new(apps::floyd_warshall::build())
            .bind("N", n)
            .cl0(apps::floyd_warshall::CL0_REQUEST_MHZ)
            .seeded(2),
        flops: apps::floyd_warshall::flops(n),
    }];
    // both modes offered: the *legality analysis* must prune resource
    // mode for FW, not the option list
    let opts = SpaceOptions {
        vector_widths: vec![],
        pump_factors: vec![2, 4],
        pump_modes: vec![PumpMode::Resource, PumpMode::Throughput],
        max_replicas: 1,
        cl0_requests_mhz: vec![],
        mixed_factors: false,
    };
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::throughput()),
    )
    .unwrap();
    for e in &out.evaluations {
        assert!(
            !matches!(e.point.pump, Some((_, PumpMode::Resource))),
            "illegal resource-mode candidate {}",
            e.label
        );
    }
    let chosen = out.chosen.unwrap();
    assert!(
        matches!(chosen.point.pump, Some((_, PumpMode::Throughput))),
        "chosen {} is not throughput-pumped",
        chosen.label
    );
    let reference = out.reference.unwrap();
    assert!(chosen.gops > reference.gops, "pumping must raise FW throughput");
}

#[test]
fn dse_cache_makes_repeated_sweeps_incremental() {
    // Same spec twice through the shared evaluator: the second sweep
    // is served entirely from the content-hashed cache and returns
    // identical reports (cache-hit determinism).
    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let cfg = SearchConfig::exhaustive(Objective::resource());
    let ev = Evaluator::new();
    let first = run_search(&ev, &bases, &device, &opts, &cfg).unwrap();
    let misses = ev.cache_misses();
    let second = run_search(&ev, &bases, &device, &opts, &cfg).unwrap();
    assert_eq!(ev.cache_misses(), misses, "second sweep recompiled something");
    assert!(ev.cache_hits() >= first.evaluations.len());
    let (a, b) = (first.chosen.unwrap(), second.chosen.unwrap());
    assert_eq!(a.point, b.point);
    assert_eq!(a.report.cl0.achieved_mhz, b.report.cl0.achieved_mhz);
    assert_eq!(a.report.resources, b.report.resources);
    assert_eq!(a.gops, b.gops);
}

#[test]
fn dse_greedy_respects_budget_and_stays_sane() {
    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let cfg = SearchConfig {
        strategy: Strategy::Greedy,
        objective: Objective::resource(),
        budget: Some(30),
        seed: 1,
    };
    let out = run_search(&Evaluator::new(), &bases, &device, &opts, &cfg).unwrap();
    assert!(out.evaluated <= 30);
    let chosen = out.chosen.unwrap();
    // greedy must at least not regress below the unpumped reference
    let reference = out.reference.unwrap();
    assert!(chosen.resource_score <= reference.resource_score + 1e-12);
}

#[test]
fn dse_all_strategies_agree_on_the_small_vecadd_space() {
    // Table 2's space is small enough that every strategy — including
    // the stochastic ones — must land on the same optimum the
    // exhaustive sweep proves is best.
    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let ev = Evaluator::new();
    let mut chosen_points = Vec::new();
    for strategy in [
        Strategy::Exhaustive,
        Strategy::Greedy,
        Strategy::Anneal,
        Strategy::Halving,
    ] {
        let cfg = SearchConfig {
            strategy,
            objective: Objective::resource(),
            budget: None,
            seed: 23,
        };
        let out = run_search(&ev, &bases, &device, &opts, &cfg).unwrap();
        chosen_points.push((strategy, out.chosen.unwrap().point));
    }
    for (s, p) in &chosen_points[1..] {
        assert_eq!(
            p, &chosen_points[0].1,
            "{} diverged from exhaustive",
            s.name()
        );
    }
}

#[test]
fn dse_persistent_cache_round_trips_across_evaluators() {
    // "two processes" sharing a --cache-dir: the first sweeps and
    // flushes, the second loads and re-runs the identical sweep with
    // zero new compiles and a bit-identical chosen report.
    let dir = std::env::temp_dir().join(format!("tvec-dse-it-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let cfg = SearchConfig::exhaustive(Objective::resource());

    let first = Evaluator::with_cache_dir(&dir);
    assert_eq!(first.loaded_entries(), 0);
    let out1 = run_search(&first, &bases, &device, &opts, &cfg).unwrap();
    assert!(first.cache_misses() > 0, "cold run must compile");
    let flushed = first.flush().unwrap();
    assert!(flushed >= first.cache_misses());

    let second = Evaluator::with_cache_dir(&dir);
    assert_eq!(second.loaded_entries(), flushed);
    assert!(second.cold_reason().is_none());
    let out2 = run_search(&second, &bases, &device, &opts, &cfg).unwrap();
    assert_eq!(
        second.cache_misses(),
        0,
        "warm run must evaluate 0 uncached candidates"
    );
    assert_eq!(out1.evaluated, out2.evaluated);
    let (a, b) = (out1.chosen.unwrap(), out2.chosen.unwrap());
    assert_eq!(a.point, b.point);
    assert_eq!(a.gops.to_bits(), b.gops.to_bits(), "disk round trip must be bit exact");
    assert_eq!(a.report.cl0.achieved_mhz.to_bits(), b.report.cl0.achieved_mhz.to_bits());
    assert_eq!(a.report.resources, b.report.resources);

    // flushing the second evaluator merges, never shrinks
    let reflushed = second.flush().unwrap();
    assert_eq!(reflushed, flushed);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dse_persistent_cache_survives_corruption_as_cold_start() {
    let dir = std::env::temp_dir().join(format!("tvec-dse-corrupt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(temporal_vec::dse::cache::FILE_NAME);
    std::fs::write(
        &path,
        format!(
            "#tvec-dse-cache v{}\ngarbage line without tabs\n",
            temporal_vec::dse::cache::SCHEMA_VERSION
        ),
    )
    .unwrap();
    let ev = Evaluator::with_cache_dir(&dir);
    assert_eq!(ev.loaded_entries(), 0);
    assert!(ev.cold_reason().is_some(), "corruption must be reported, not ignored");
    // and the evaluator still works end to end
    let device = Device::u280();
    let (bases, opts) = vecadd_problem(11);
    let out = run_search(&ev, &bases, &device, &opts, &SearchConfig::exhaustive(Objective::resource()))
        .unwrap();
    assert!(out.chosen.is_some());
    // a flush repairs the store
    ev.flush().unwrap();
    let repaired = Evaluator::with_cache_dir(&dir);
    assert!(repaired.cold_reason().is_none());
    assert!(repaired.loaded_entries() > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The mixed per-region search problem for the stencil chain. `n`
/// overrides NX (resources and clocks are NX-independent, so the
/// frontier structure is the same at any scale — small NX keeps the
/// sweep fast). Resource mode only, single SLR: the Table-2-style
/// resource study the mixed dimension extends.
fn stencil_mixed_problem(n: i64) -> (Vec<SearchBase>, SpaceOptions) {
    let device = Device::u280();
    let (bases, mut opts) =
        temporal_vec::coordinator::search_problem("stencil", Some(n), 1, &device).unwrap();
    opts.mixed_factors = true;
    opts.pump_modes = vec![PumpMode::Resource];
    opts.max_replicas = 1;
    (bases, opts)
}

#[test]
fn dse_mixed_assignment_reaches_the_frontier_and_beats_best_uniform_resource() {
    // The PR's acceptance criterion: with --mixed-factors on the
    // stencil chain, at least one mixed per-region assignment survives
    // to the Pareto frontier and strictly undercuts the best uniform
    // point (the one the resource objective selects) on the resource
    // axis. The mechanism: at CL0 ≈ 315 MHz a factor-4 domain is capped
    // by the 650 MHz request ceiling, so uniform R4 sacrifices
    // throughput; uniform R2 holds throughput but pays double the
    // compute width everywhere. A 4/2 split keeps part of the chain at
    // quarter width — cheaper than R2 — while its small factor-4
    // domain closes timing at the cap, faster than uniform R4.
    let (bases, opts) = stencil_mixed_problem(1 << 10);
    let device = Device::u280();
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )
    .unwrap();

    let mixed_on_frontier: Vec<_> =
        out.frontier.iter().filter(|e| e.point.regions.is_some()).collect();
    assert!(
        !mixed_on_frontier.is_empty(),
        "no mixed assignment on the frontier: {:?}",
        out.frontier.iter().map(|e| e.label.clone()).collect::<Vec<_>>()
    );

    // best uniform point under the resource objective
    let reference = out.reference.as_ref().unwrap();
    let uniform: Vec<_> = out
        .evaluations
        .iter()
        .filter(|e| e.point.regions.is_none())
        .cloned()
        .collect();
    let best_uniform = Objective::resource()
        .select(&uniform, reference)
        .expect("a uniform point satisfies the objective")
        .clone();
    let cheapest_mixed = mixed_on_frontier
        .iter()
        .map(|e| e.resource_score)
        .fold(f64::INFINITY, f64::min);
    assert!(
        cheapest_mixed < best_uniform.resource_score,
        "mixed frontier points (cheapest score {cheapest_mixed:.3}) do not undercut the \
         best uniform point {} (score {:.3})",
        best_uniform.label,
        best_uniform.resource_score
    );
}

#[test]
fn dse_mixed_frontier_verifies_at_golden_scale() {
    // acceptance: `dse --verify` over the mixed frontier — rebuild
    // mixed frontier points at golden (artifact) scale and demand
    // rate-model vs exact-simulator agreement within the default
    // tolerance. The search already runs at golden scale here, so the
    // verified points are exactly the reported ones.
    use temporal_vec::dse::{verify_frontier, DEFAULT_TOLERANCE};
    let golden_nx = temporal_vec::apps::stencil::GOLDEN_NX;
    let (bases, opts) = stencil_mixed_problem(golden_nx);
    let device = Device::u280();
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )
    .unwrap();
    let mixed: Vec<temporal_vec::dse::Evaluation> = out
        .frontier
        .iter()
        .filter(|e| e.point.regions.is_some())
        .take(3) // bound the exact-sim time; any surviving point qualifies
        .cloned()
        .collect();
    assert!(!mixed.is_empty(), "no mixed frontier point to verify");
    let rig = temporal_vec::coordinator::golden_rig("stencil", 1).unwrap();
    let reports = verify_frontier(&mixed, &rig.bases, &rig.inputs, DEFAULT_TOLERANCE).unwrap();
    assert_eq!(reports.len(), mixed.len());
    for r in &reports {
        assert!(r.skipped.is_none(), "{}: unexpected golden-scale skip", r.label);
        assert!(
            r.within,
            "{}: rate {} vs exact {} (ratio {:.3})",
            r.label, r.rate_cycles, r.exact_cycles, r.ratio
        );
    }
}

#[test]
fn dse_floyd_warshall_barefast_reaches_the_frontier_gearbox_free() {
    // The mode axis extended with bare-fast: FW's dependent scalar
    // datapath (II = 21) is exactly the shape the dace-style "just
    // clock it faster" mode exists for. B2 delivers T2's doubled
    // throughput with no issuer/packer and no widened datapath, so it
    // must survive to the Pareto frontier and undercut T2 on logic.
    let n = 128i64;
    let device = Device::u280();
    let bases = vec![SearchBase {
        spec: BuildSpec::new(apps::floyd_warshall::build())
            .bind("N", n)
            .cl0(apps::floyd_warshall::CL0_REQUEST_MHZ)
            .seeded(2),
        flops: apps::floyd_warshall::flops(n),
    }];
    let opts = SpaceOptions {
        vector_widths: vec![],
        pump_factors: vec![2],
        pump_modes: vec![PumpMode::Throughput, PumpMode::BareFast],
        max_replicas: 1,
        cl0_requests_mhz: vec![],
        mixed_factors: false,
    };
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::throughput()),
    )
    .unwrap();
    let b2 = out
        .frontier
        .iter()
        .find(|e| e.point.pump == Some((2, PumpMode::BareFast)))
        .unwrap_or_else(|| {
            panic!(
                "no bare-fast point on the frontier: {:?}",
                out.frontier.iter().map(|e| e.label.clone()).collect::<Vec<_>>()
            )
        });
    let reference = out.reference.as_ref().unwrap();
    assert!(
        b2.gops > reference.gops,
        "bare-fast must raise FW throughput ({} vs {})",
        b2.gops,
        reference.gops
    );
    let t2 = out
        .evaluations
        .iter()
        .find(|e| e.point.pump == Some((2, PumpMode::Throughput)))
        .expect("throughput mode evaluates in the same sweep");
    assert!(
        b2.total_resources.lut_logic < t2.total_resources.lut_logic,
        "gearbox-free bare-fast must be leaner than throughput mode \
         ({} vs {} LUTs)",
        b2.total_resources.lut_logic,
        t2.total_resources.lut_logic
    );
    assert!(
        b2.resource_score <= t2.resource_score,
        "B2 score {} vs T2 score {}",
        b2.resource_score,
        t2.resource_score
    );
}

#[test]
fn dse_mode_mixed_space_strictly_extends_the_uniform_frontier() {
    // The PR's acceptance criterion for the unified per-region space:
    // with both gearboxed modes on the mode axis and --mixed-factors
    // on, the search must (a) actually evaluate assignments whose
    // regions disagree on *mode*, not just factor, and (b) produce a
    // frontier that strictly extends the uniform-only frontier — some
    // per-region point no uniform configuration dominates.
    let (bases, mut opts) = stencil_mixed_problem(1 << 10);
    opts.pump_modes = vec![PumpMode::Resource, PumpMode::Throughput];
    let device = Device::u280();
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )
    .unwrap();

    let mode_mixed = out.evaluations.iter().filter(|e| {
        e.point.regions.as_ref().is_some_and(|fs| {
            let modes: Vec<_> = fs.iter().flatten().map(|p| p.mode).collect();
            modes.windows(2).any(|w| w[0] != w[1])
        })
    });
    assert!(
        mode_mixed.count() > 0,
        "no mode-mixed per-region assignment survived to evaluation"
    );

    let uniform: Vec<_> =
        out.evaluations.iter().filter(|e| e.point.regions.is_none()).collect();
    assert!(!uniform.is_empty());
    let strictly_new = out
        .frontier
        .iter()
        .filter(|e| e.point.regions.is_some())
        .any(|m| {
            !uniform.iter().any(|u| {
                u.resource_score <= m.resource_score && u.gops >= m.gops
            })
        });
    assert!(
        strictly_new,
        "every per-region frontier point is dominated by a uniform one: {:?}",
        out.frontier.iter().map(|e| e.label.clone()).collect::<Vec<_>>()
    );
}

#[test]
fn dse_cache_compaction_shrinks_a_grown_store() {
    // the append-only growth fix: a run that touches a subset of a big
    // store and flushes with --cache-compact rewrites the file with
    // only the entries it used — the file shrinks instead of merging
    // every stale record back forever
    let dir = std::env::temp_dir().join(format!("tvec-dse-compact-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let device = Device::u280();

    // seed the store with a full sweep
    let (bases, opts) = vecadd_problem(11);
    let seeder = Evaluator::with_cache_dir(&dir);
    run_search(&seeder, &bases, &device, &opts, &SearchConfig::exhaustive(Objective::resource()))
        .unwrap();
    let full = seeder.flush().unwrap();
    assert!(full > 2, "need a non-trivial store to compact, got {full} entries");
    let path = dir.join(temporal_vec::dse::cache::FILE_NAME);
    let bytes_before = std::fs::metadata(&path).unwrap().len();

    // a later run touches only one candidate, then compacts
    let toucher = Evaluator::with_cache_dir(&dir);
    assert_eq!(toucher.loaded_entries(), full);
    let base = &bases[0];
    toucher.evaluate(&base.spec, &DesignPoint::original(), base.flops).unwrap();
    assert_eq!(toucher.cache_misses(), 0, "the touched point must be a cache hit");
    let (before, after) = toucher.flush_compacted().unwrap();
    assert_eq!(before, full);
    assert_eq!(after, 1);
    let bytes_after = std::fs::metadata(&path).unwrap().len();
    assert!(
        bytes_after < bytes_before,
        "compacted file did not shrink ({bytes_before} → {bytes_after} bytes)"
    );

    // the survivor still round-trips
    let reloaded = Evaluator::with_cache_dir(&dir);
    assert!(reloaded.cold_reason().is_none());
    assert_eq!(reloaded.loaded_entries(), 1);
    let again = reloaded.evaluate(&base.spec, &DesignPoint::original(), base.flops).unwrap();
    assert_eq!(reloaded.cache_misses(), 0, "survivor must hit");
    assert!(again.fits);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dse_failure_kinds_are_reported_separately() {
    // an indivisible problem size: the grid prunes width 8 up front,
    // nothing hard-fails compilation, and the outcome's two failure
    // counters stay consistent with the aggregate
    let n = 24i64; // widths 2, 4 divide; 8 does not
    let device = Device::u280();
    let bases = vec![SearchBase {
        spec: BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(2),
        flops: apps::vecadd::flops(n),
    }];
    let opts = SpaceOptions {
        vector_widths: vec![2, 4, 8],
        pump_factors: vec![2],
        pump_modes: vec![PumpMode::Resource],
        max_replicas: 1,
        cl0_requests_mhz: vec![],
        mixed_factors: false,
    };
    let out = run_search(
        &Evaluator::new(),
        &bases,
        &device,
        &opts,
        &SearchConfig::exhaustive(Objective::resource()),
    )
    .unwrap();
    assert!(
        out.evaluations
            .iter()
            .all(|e| e.point.vectorize.as_ref().map(|(_, w)| *w) != Some(8)),
        "width 8 must be legality-pruned from the grid for N = 24"
    );
    assert_eq!(out.compile_failed, 0, "nothing should hard-fail compilation");
    assert_eq!(out.infeasible(), out.illegal + out.compile_failed);
}
