//! Integration: application-level experiment shapes at reduced scale
//! (fast enough for CI; paper-scale shapes are checked by the benches).

use temporal_vec::coordinator::experiment::{table2, table3, table4, table5, table6};

#[test]
fn table2_dsp_halving_and_time_parity() {
    let r = table2(1 << 18, 7).unwrap();
    for pair in r.rows.chunks(2) {
        let (o, dp) = (&pair[0], &pair[1]);
        assert!((dp.util[4] - o.util[4] / 2.0).abs() < 0.02, "{}", o.label);
        assert!((dp.time_s / o.time_s - 1.0).abs() < 0.15, "{}", o.label);
        // LUT/register overhead below 1 % of the pool (paper §4.1)
        assert!(dp.util[0] - o.util[0] < 1.0);
        assert!(dp.util[2] - o.util[2] < 1.0);
    }
}

#[test]
fn table3_full_shape() {
    let r = table3(2048, 7).unwrap();
    let find = |l: &str| r.rows.iter().find(|x| x.label == l).unwrap();
    let (ca, o32, dp32, dp48, dp64) =
        (find("CA 32"), find("O 32"), find("DP 32"), find("DP 48"), find("DP 64"));
    // DaCe original on par with hand-written (paper: "perform on par")
    assert!((o32.gops / ca.gops - 1.0).abs() < 0.15);
    // DSP halving and BRAM cut at equal PEs
    assert!((dp32.util[4] / o32.util[4] - 0.5).abs() < 0.02);
    assert!(dp32.util[3] < 0.65 * o32.util[3]);
    // DP runs at lower effective clock → slightly lower perf at 32 PEs
    assert!(dp32.gops < o32.gops);
    // freed resources scale to 48/64 PEs with net speedup
    assert!(dp48.gops > o32.gops);
    assert!(dp64.gops > dp48.gops * 0.95);
    assert!(dp64.gops > 1.10 * ca.gops, "dp64 {} vs ca {}", dp64.gops, ca.gops);
    // CL1 decreases with congestion as PEs grow
    let (c32, c48, c64) =
        (dp32.cl1_mhz.unwrap(), dp48.cl1_mhz.unwrap(), dp64.cl1_mhz.unwrap());
    assert!(c32 > c48 && c48 > c64, "{c32} {c48} {c64}");
    // DSP efficiency roughly doubles at same PE count
    assert!(dp32.mops_per_dsp > 1.5 * o32.mops_per_dsp);
}

#[test]
fn table4_scaling_story() {
    let r = table4(4096, 7).unwrap();
    let find = |l: &str| r.rows.iter().find(|x| x.label == l).unwrap();
    for s in [8, 16] {
        let o = find(&format!("S={s} O"));
        let dp = find(&format!("S={s} DP"));
        assert!((dp.util[4] / o.util[4] - 0.5).abs() < 0.02);
        assert!(dp.gops < o.gops * 1.02); // DP slightly slower at fixed S
        assert!(dp.mops_per_dsp > 1.5 * o.mops_per_dsp);
    }
    // S=40: O only fits at halved width → DP wins decisively
    let (o40, dp40) = (find("S=40 O"), find("S=40 DP"));
    assert!((o40.util[4] - dp40.util[4]).abs() < 0.1, "same DSP budget");
    assert!(dp40.gops > 1.2 * o40.gops);
}

#[test]
fn table5_diffusion_tops_out_at_20_stages() {
    let r = table5(4096, 7).unwrap();
    let labels: Vec<&str> = r.rows.iter().map(|x| x.label.as_str()).collect();
    assert!(labels.contains(&"S=20 O"));
    assert!(labels.contains(&"S=40 DP"));
    assert!(!labels.contains(&"S=40 O"), "O cannot reach 40 stages");
    let find = |l: &str| r.rows.iter().find(|x| x.label == l).unwrap();
    assert!(find("S=40 DP").gops > 1.2 * find("S=20 O").gops);
}

#[test]
fn table6_throughput_mode_speedup() {
    let r = table6(128, 7).unwrap();
    let (o, dp) = (&r.rows[0], &r.rows[1]);
    let speedup = o.time_s / dp.time_s;
    assert!(speedup > 1.2 && speedup < 2.0, "speedup {speedup}");
    // resources similar: no reduction in throughput mode (paper §4.4)
    assert!((dp.util[3] - o.util[3]).abs() < 2.0);
    assert!(dp.util[0] - o.util[0] < 1.0);
}
