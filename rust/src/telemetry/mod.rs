//! Structured observability: spans, counters, gauges, bounded sampled
//! time-series, and two exporters (Chrome trace-event JSON and a flat
//! `TELEMETRY.json` summary). DESIGN.md §11 documents the architecture.
//!
//! The contract with the rest of the crate is the *nullable handle*:
//! instrumented code paths accept an `Option<&Recorder>` and do all
//! recording under `if let Some(rec) = …` / `rec.map(…)`. With `None`
//! the instrumentation compiles down to a branch on a null handle — no
//! allocation, no formatting, no locking — which is what keeps the
//! simulator's hot loop and the DSE evaluator at full speed when no
//! `--trace-out` was requested. The enabled path must be purely
//! observational: the telemetry-on/off property test pins that
//! `SimStats` and simulation outputs are bit-identical either way.

pub mod chrome;
pub mod recorder;
pub mod summary;

pub use chrome::to_chrome_trace;
pub use recorder::{ActivityGrid, Event, Recorder, Series, Span, SERIES_CAP};
pub use summary::{to_summary_json, top_stalls, SUMMARY_SCHEMA};
