//! The recording core: spans, counters, gauges, bounded time-series
//! and the optional per-tick activity grid.
//!
//! Everything funnels through [`Recorder`], which call sites hold as an
//! `Option<&Recorder>`: the disabled path is a branch on `None` — no
//! allocation, no formatting, no lock. The recorder itself is `Sync`
//! (one mutex around all state) because the DSE evaluator fans
//! candidates out over scoped threads and every worker records into the
//! same instance.

use std::collections::hash_map::DefaultHasher;
use std::collections::BTreeMap;
use std::hash::{Hash, Hasher};
use std::sync::Mutex;
use std::time::Instant;

/// One recorded trace event, in Chrome trace-event terms: span begin,
/// span end (carrying the span's accumulated args), or an instant.
#[derive(Clone, Debug)]
pub enum Event {
    Begin { name: String, tid: u64, ts_us: f64 },
    End { tid: u64, ts_us: f64, args: Vec<(String, String)> },
    Instant { name: String, tid: u64, ts_us: f64 },
}

/// Hard cap on retained points per series (bounded memory).
pub const SERIES_CAP: usize = 512;

/// A sampled time-series with bounded memory: once [`SERIES_CAP`]
/// points are retained, every other point is dropped and the accept
/// stride doubles, so an arbitrarily long run keeps a uniformly-spaced
/// window of at most `SERIES_CAP` samples.
#[derive(Clone, Debug, Default)]
pub struct Series {
    /// Retained `(t, value)` samples, in arrival order.
    pub points: Vec<(u64, f64)>,
    stride: u64,
    seen: u64,
}

impl Series {
    fn record(&mut self, t: u64, v: f64) {
        if self.stride == 0 {
            self.stride = 1;
        }
        let accept = self.seen % self.stride == 0;
        self.seen += 1;
        if !accept {
            return;
        }
        self.points.push((t, v));
        if self.points.len() >= SERIES_CAP {
            let mut i = 0usize;
            self.points.retain(|_| {
                let keep = i % 2 == 0;
                i += 1;
                keep
            });
            self.stride *= 2;
        }
    }
}

/// Dense per-tick module activity, recorded only when the recorder was
/// built via [`Recorder::with_activity`]. This is the shared capture
/// the text waveform (`sim::trace`) renders from — one source of truth
/// instead of a second per-tick loop.
#[derive(Clone, Debug, Default)]
pub struct ActivityGrid {
    /// Module labels, in simulator process order.
    pub labels: Vec<String>,
    /// `(module index, fast tick)` pairs for every progressing tick.
    pub fires: Vec<(u32, u64)>,
    /// Ticks at or beyond this bound are not recorded.
    pub max_ticks: u64,
}

#[derive(Default)]
struct Inner {
    events: Vec<Event>,
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    series: BTreeMap<String, Series>,
    grid: Option<ActivityGrid>,
}

/// The telemetry sink. Cheap to create; all recording methods take
/// `&self` so one instance can be shared across worker threads.
pub struct Recorder {
    start: Instant,
    inner: Mutex<Inner>,
}

impl Default for Recorder {
    fn default() -> Self {
        Self::new()
    }
}

impl Recorder {
    pub fn new() -> Self {
        Recorder { start: Instant::now(), inner: Mutex::new(Inner::default()) }
    }

    /// A recorder that additionally keeps a dense activity grid for up
    /// to `max_ticks` fast ticks (used by waveform tracing).
    pub fn with_activity(max_ticks: u64) -> Self {
        let r = Self::new();
        r.inner.lock().unwrap().grid =
            Some(ActivityGrid { max_ticks, ..ActivityGrid::default() });
        r
    }

    /// Microseconds since the recorder was created (trace timebase).
    pub fn elapsed_us(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e6
    }

    fn tid() -> u64 {
        let mut h = DefaultHasher::new();
        std::thread::current().id().hash(&mut h);
        h.finish()
    }

    /// Open a span; it closes (records its end event) on drop, so
    /// nesting follows lexical scope per thread.
    pub fn span(&self, name: &str) -> Span<'_> {
        let tid = Self::tid();
        let ts_us = self.elapsed_us();
        self.inner
            .lock()
            .unwrap()
            .events
            .push(Event::Begin { name: name.to_string(), tid, ts_us });
        Span { rec: self, tid, args: Vec::new() }
    }

    /// Record a zero-duration instant event (e.g. a prefix-cache hit).
    pub fn instant(&self, name: &str) {
        let tid = Self::tid();
        let ts_us = self.elapsed_us();
        self.inner
            .lock()
            .unwrap()
            .events
            .push(Event::Instant { name: name.to_string(), tid, ts_us });
    }

    /// Bump a monotone counter by `delta`.
    pub fn add(&self, name: &str, delta: u64) {
        *self.inner.lock().unwrap().counters.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Set a gauge (last write wins).
    pub fn gauge(&self, name: &str, value: f64) {
        self.inner.lock().unwrap().gauges.insert(name.to_string(), value);
    }

    /// Append a `(t, value)` sample to a bounded series.
    pub fn sample(&self, name: &str, t: u64, v: f64) {
        self.inner.lock().unwrap().series.entry(name.to_string()).or_default().record(t, v);
    }

    /// Record that activity-grid module `module` progressed at fast
    /// tick `t`. No-op unless built via [`Recorder::with_activity`].
    pub fn fire(&self, module: u32, t: u64) {
        if let Some(g) = self.inner.lock().unwrap().grid.as_mut() {
            if t < g.max_ticks {
                g.fires.push((module, t));
            }
        }
    }

    /// Install the module labels for the activity grid.
    pub fn set_activity_labels(&self, labels: Vec<String>) {
        if let Some(g) = self.inner.lock().unwrap().grid.as_mut() {
            g.labels = labels;
        }
    }

    // -- query side (exporters, reports, tests) --

    pub fn events(&self) -> Vec<Event> {
        self.inner.lock().unwrap().events.clone()
    }

    pub fn counters(&self) -> BTreeMap<String, u64> {
        self.inner.lock().unwrap().counters.clone()
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauges(&self) -> BTreeMap<String, f64> {
        self.inner.lock().unwrap().gauges.clone()
    }

    pub fn series(&self) -> BTreeMap<String, Series> {
        self.inner.lock().unwrap().series.clone()
    }

    pub fn activity(&self) -> Option<ActivityGrid> {
        self.inner.lock().unwrap().grid.clone()
    }
}

/// RAII span guard returned by [`Recorder::span`]. Arguments attached
/// via [`Span::note`] land on the end event; Chrome/Perfetto merge a
/// slice's begin and end args, so notes show on the span itself.
pub struct Span<'a> {
    rec: &'a Recorder,
    tid: u64,
    args: Vec<(String, String)>,
}

impl Span<'_> {
    pub fn note(&mut self, key: &str, value: impl std::fmt::Display) {
        self.args.push((key.to_string(), value.to_string()));
    }
}

impl Drop for Span<'_> {
    fn drop(&mut self) {
        let ts_us = self.rec.elapsed_us();
        self.rec
            .inner
            .lock()
            .unwrap()
            .events
            .push(Event::End { tid: self.tid, ts_us, args: std::mem::take(&mut self.args) });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_nest_in_lexical_order() {
        let rec = Recorder::new();
        {
            let _outer = rec.span("outer");
            {
                let mut inner = rec.span("inner");
                inner.note("k", 42);
            }
        }
        let ev = rec.events();
        assert_eq!(ev.len(), 4);
        match (&ev[0], &ev[1], &ev[2], &ev[3]) {
            (
                Event::Begin { name: a, .. },
                Event::Begin { name: b, .. },
                Event::End { args, .. },
                Event::End { args: outer_args, .. },
            ) => {
                assert_eq!(a, "outer");
                assert_eq!(b, "inner");
                assert_eq!(args, &[("k".to_string(), "42".to_string())]);
                assert!(outer_args.is_empty());
            }
            other => panic!("unexpected event order: {other:?}"),
        }
    }

    #[test]
    fn disabled_handle_is_a_noop_branch() {
        // the call-site idiom: everything hangs off Option::map, so a
        // None handle touches no recorder state at all
        let rec: Option<&Recorder> = None;
        let mut sp = rec.map(|r| r.span("never"));
        if let Some(s) = sp.as_mut() {
            s.note("unreachable", 1);
        }
        if let Some(r) = rec {
            r.add("never", 1);
        }
        // and an enabled handle records exactly once
        let live = Recorder::new();
        let on: Option<&Recorder> = Some(&live);
        if let Some(r) = on {
            r.add("hits", 2);
        }
        assert_eq!(live.counter("hits"), 2);
        assert_eq!(live.counter("never"), 0);
    }

    #[test]
    fn series_memory_is_bounded_and_coverage_uniform() {
        let rec = Recorder::new();
        let n = 100_000u64;
        for t in 0..n {
            rec.sample("busy", t, t as f64);
        }
        let s = &rec.series()["busy"];
        assert!(s.points.len() <= SERIES_CAP, "series grew to {}", s.points.len());
        assert!(s.points.len() > SERIES_CAP / 4, "decimation dropped too much");
        // first sample survives every decimation round (even index 0)
        assert_eq!(s.points[0], (0, 0.0));
        // samples stay in time order and span most of the run
        assert!(s.points.windows(2).all(|w| w[0].0 < w[1].0));
        assert!(s.points.last().unwrap().0 > n / 2);
    }

    #[test]
    fn counters_gauges_and_instants_accumulate() {
        let rec = Recorder::new();
        rec.add("c", 1);
        rec.add("c", 4);
        rec.gauge("g", 0.25);
        rec.gauge("g", 0.75); // last write wins
        rec.instant("blip");
        assert_eq!(rec.counter("c"), 5);
        assert_eq!(rec.gauges()["g"], 0.75);
        assert!(matches!(rec.events().as_slice(), [Event::Instant { name, .. }] if name == "blip"));
    }

    #[test]
    fn activity_grid_respects_its_tick_bound() {
        let rec = Recorder::with_activity(10);
        rec.set_activity_labels(vec!["a".into(), "b".into()]);
        rec.fire(0, 3);
        rec.fire(1, 9);
        rec.fire(1, 10); // at the bound: dropped
        rec.fire(0, 99); // far past: dropped
        let g = rec.activity().unwrap();
        assert_eq!(g.labels, vec!["a", "b"]);
        assert_eq!(g.fires, vec![(0, 3), (1, 9)]);
        // a plain recorder has no grid at all
        assert!(Recorder::new().activity().is_none());
    }
}
