//! Flat metrics summary export (`TELEMETRY.json`) plus the top-stall
//! extraction used by `tvec top`.
//!
//! Counters/gauges/series are stored in `BTreeMap`s, so export order is
//! deterministic — the golden-schema test relies on that.

use super::chrome::esc;
use super::recorder::Recorder;

/// Schema tag written into every summary export.
pub const SUMMARY_SCHEMA: &str = "tvec-telemetry v1";

/// Render the recorder's aggregate state as a flat JSON document:
/// `{schema, counters: {name: int}, gauges: {name: float},
///   series: {name: [[t, value], ...]}}`.
pub fn to_summary_json(rec: &Recorder) -> String {
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"schema\": \"{SUMMARY_SCHEMA}\",\n"));

    out.push_str("  \"counters\": {\n");
    let counters = rec.counters();
    let rows: Vec<String> =
        counters.iter().map(|(k, v)| format!("    \"{}\": {}", esc(k), v)).collect();
    out.push_str(&rows.join(",\n"));
    if !rows.is_empty() {
        out.push('\n');
    }
    out.push_str("  },\n");

    out.push_str("  \"gauges\": {\n");
    let gauges = rec.gauges();
    let rows: Vec<String> =
        gauges.iter().map(|(k, v)| format!("    \"{}\": {:.6}", esc(k), v)).collect();
    out.push_str(&rows.join(",\n"));
    if !rows.is_empty() {
        out.push('\n');
    }
    out.push_str("  },\n");

    out.push_str("  \"series\": {\n");
    let series = rec.series();
    let rows: Vec<String> = series
        .iter()
        .map(|(k, s)| {
            let pts: Vec<String> =
                s.points.iter().map(|(t, v)| format!("[{t}, {v:.6}]")).collect();
            format!("    \"{}\": [{}]", esc(k), pts.join(", "))
        })
        .collect();
    out.push_str(&rows.join(",\n"));
    if !rows.is_empty() {
        out.push('\n');
    }
    out.push_str("  }\n}\n");
    out
}

/// Extract the top-`k` stall sources from a recorded run: module stall
/// totals (`sim.module.*.stalls`) and per-channel stall causes
/// (`sim.fifo.*.full_on_push` — backpressure, `sim.fifo.*.empty_on_pop`
/// — starvation), sorted by count descending (name ascending on ties
/// for determinism).
pub fn top_stalls(rec: &Recorder, k: usize) -> Vec<(String, u64)> {
    let mut rows: Vec<(String, u64)> = rec
        .counters()
        .into_iter()
        .filter(|(name, _)| {
            (name.starts_with("sim.module.") && name.ends_with(".stalls"))
                || (name.starts_with("sim.fifo.")
                    && (name.ends_with(".full_on_push") || name.ends_with(".empty_on_pop")))
        })
        .collect();
    rows.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
    rows.truncate(k);
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_export_has_golden_shape() {
        let rec = Recorder::new();
        rec.add("dse.cache.hits", 7);
        rec.gauge("sim.domain.cl1_m2.utilization", 0.875);
        rec.sample("sim.module.vadd.busy", 0, 1.0);
        rec.sample("sim.module.vadd.busy", 8, 5.0);
        let json = to_summary_json(&rec);
        for needle in [
            "\"schema\": \"tvec-telemetry v1\"",
            "\"counters\": {",
            "\"dse.cache.hits\": 7",
            "\"gauges\": {",
            "\"sim.domain.cl1_m2.utilization\": 0.875000",
            "\"series\": {",
            "\"sim.module.vadd.busy\": [[0, 1.000000], [8, 5.000000]]",
        ] {
            assert!(json.contains(needle), "missing {needle} in:\n{json}");
        }
        // balanced braces/brackets outside strings
        let opens = json.matches('{').count();
        let closes = json.matches('}').count();
        assert_eq!(opens, closes, "unbalanced braces:\n{json}");
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn empty_recorder_still_exports_valid_schema() {
        let json = to_summary_json(&Recorder::new());
        assert!(json.contains("\"schema\": \"tvec-telemetry v1\""));
        assert!(json.contains("\"counters\": {"));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }

    #[test]
    fn top_stalls_ranks_modules_and_stall_causes() {
        let rec = Recorder::new();
        rec.add("sim.module.read_x.stalls", 5);
        rec.add("sim.module.vadd.stalls", 40);
        rec.add("sim.module.vadd.busy", 1000); // not a stall source
        rec.add("sim.fifo.q_issue.empty_on_pop", 40); // tie with vadd
        rec.add("sim.fifo.q_pack.full_on_push", 12);
        rec.add("dse.cache.hits", 99); // unrelated namespace
        let top = top_stalls(&rec, 3);
        assert_eq!(
            top,
            vec![
                ("sim.fifo.q_issue.empty_on_pop".to_string(), 40),
                ("sim.module.vadd.stalls".to_string(), 40),
                ("sim.fifo.q_pack.full_on_push".to_string(), 12),
            ]
        );
    }
}
