//! Chrome trace-event JSON export (`chrome://tracing` / Perfetto).
//!
//! Emits the JSON-object flavor of the trace-event format: a
//! `traceEvents` array of `B`/`E`/`i`/`C` phase records plus an
//! `otherData.schema` tag so downstream tooling can detect drift, the
//! same versioning discipline as the DSE disk cache.

use super::recorder::{Event, Recorder};

/// Minimal JSON string escape (quotes, backslash, control chars).
pub(crate) fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn args_json(args: &[(String, String)]) -> String {
    let body: Vec<String> =
        args.iter().map(|(k, v)| format!("\"{}\": \"{}\"", esc(k), esc(v))).collect();
    format!("{{{}}}", body.join(", "))
}

/// Render the recorder's contents as a Chrome trace-event JSON string.
/// Raw thread ids are compressed to small dense integers in order of
/// first appearance so the trace viewer shows `tid 1, 2, …` lanes.
pub fn to_chrome_trace(rec: &Recorder) -> String {
    let events = rec.events();
    let mut dense: Vec<u64> = Vec::new();
    let mut tid_of = |raw: u64| -> usize {
        if let Some(i) = dense.iter().position(|&t| t == raw) {
            i + 1
        } else {
            dense.push(raw);
            dense.len()
        }
    };

    let mut rows: Vec<String> = Vec::new();
    for e in &events {
        match e {
            Event::Begin { name, tid, ts_us } => rows.push(format!(
                "    {{\"name\": \"{}\", \"ph\": \"B\", \"pid\": 1, \"tid\": {}, \"ts\": {:.3}}}",
                esc(name),
                tid_of(*tid),
                ts_us
            )),
            Event::End { tid, ts_us, args } => rows.push(format!(
                "    {{\"ph\": \"E\", \"pid\": 1, \"tid\": {}, \"ts\": {:.3}, \"args\": {}}}",
                tid_of(*tid),
                ts_us,
                args_json(args)
            )),
            Event::Instant { name, tid, ts_us } => rows.push(format!(
                "    {{\"name\": \"{}\", \"ph\": \"i\", \"s\": \"t\", \"pid\": 1, \"tid\": {}, \"ts\": {:.3}}}",
                esc(name),
                tid_of(*tid),
                ts_us
            )),
        }
    }

    // counters and gauges as one 'C' sample each at export time — the
    // trace viewer draws them as a bar per name
    let ts_end = rec.elapsed_us();
    for (name, v) in rec.counters() {
        rows.push(format!(
            "    {{\"name\": \"{}\", \"ph\": \"C\", \"pid\": 1, \"tid\": 0, \"ts\": {:.3}, \"args\": {{\"value\": {}}}}}",
            esc(&name),
            ts_end,
            v
        ));
    }
    for (name, v) in rec.gauges() {
        rows.push(format!(
            "    {{\"name\": \"{}\", \"ph\": \"C\", \"pid\": 1, \"tid\": 0, \"ts\": {:.3}, \"args\": {{\"value\": {:.6}}}}}",
            esc(&name),
            ts_end,
            v
        ));
    }

    let mut out = String::from("{\n  \"traceEvents\": [\n");
    out.push_str(&rows.join(",\n"));
    out.push_str("\n  ],\n");
    out.push_str("  \"displayTimeUnit\": \"ms\",\n");
    out.push_str("  \"otherData\": {\"schema\": \"tvec-trace v1\"}\n");
    out.push_str("}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn balanced(s: &str) -> bool {
        let mut depth = 0i64;
        let mut brackets = 0i64;
        let mut in_str = false;
        let mut prev = ' ';
        for c in s.chars() {
            if in_str {
                if c == '"' && prev != '\\' {
                    in_str = false;
                }
            } else {
                match c {
                    '"' => in_str = true,
                    '{' => depth += 1,
                    '}' => depth -= 1,
                    '[' => brackets += 1,
                    ']' => brackets -= 1,
                    _ => {}
                }
            }
            prev = c;
        }
        depth == 0 && brackets == 0 && !in_str
    }

    #[test]
    fn chrome_export_has_golden_shape() {
        let rec = Recorder::new();
        {
            let mut sp = rec.span("pump");
            sp.note("factor", 2);
            rec.instant("prefix-cache-hit");
        }
        rec.add("dse.cache.hits", 3);
        rec.gauge("sim.domain.cl0.utilization", 0.5);
        let json = to_chrome_trace(&rec);
        for needle in [
            "\"traceEvents\": [",
            "\"name\": \"pump\", \"ph\": \"B\"",
            "\"ph\": \"E\"",
            "\"factor\": \"2\"",
            "\"name\": \"prefix-cache-hit\", \"ph\": \"i\"",
            "\"name\": \"dse.cache.hits\", \"ph\": \"C\"",
            "\"name\": \"sim.domain.cl0.utilization\", \"ph\": \"C\"",
            "\"displayTimeUnit\": \"ms\"",
            "\"otherData\": {\"schema\": \"tvec-trace v1\"}",
        ] {
            assert!(json.contains(needle), "missing {needle} in:\n{json}");
        }
        assert!(balanced(&json), "unbalanced JSON:\n{json}");
    }

    #[test]
    fn strings_are_escaped() {
        let rec = Recorder::new();
        {
            let mut sp = rec.span("weird \"name\"\n");
            sp.note("path", "a\\b");
        }
        let json = to_chrome_trace(&rec);
        assert!(json.contains("weird \\\"name\\\"\\n"));
        assert!(json.contains("a\\\\b"));
        assert!(balanced(&json));
    }

    #[test]
    fn thread_ids_are_densely_renumbered() {
        let rec = Recorder::new();
        let _ = rec.span("main-thread");
        std::thread::scope(|s| {
            s.spawn(|| {
                let _ = rec.span("worker-thread");
            });
        });
        let json = to_chrome_trace(&rec);
        assert!(json.contains("\"tid\": 1,"));
        assert!(json.contains("\"tid\": 2,"));
    }
}
