//! # temporal-vec — Temporal Vectorization / Automatic Multi-Pumping
//!
//! A reproduction of *"Temporal Vectorization: A Compiler Approach to
//! Automatic Multi-Pumping"* (Johnsen et al., 2022) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The paper's contribution — multi-pumping as an automatic compiler
//! optimization over a data-centric IR — is implemented in full:
//!
//! * [`symbolic`] — affine index expressions, ranges, intersection tests
//!   (the machinery memlets are made of);
//! * [`ir`] — an SDFG-like dataflow IR (containers, maps, tasklets,
//!   streams, memlets) with a builder API and validation;
//! * [`frontend`] — a tiny Python-like DSL lowered onto the IR;
//! * [`analysis`] — data-movement tracing, streamability and (temporal)
//!   vectorizability checks;
//! * [`transforms`] — `Vectorize`, `StreamingComposition`, `MultiPump`
//!   (resource & throughput modes, uniform or mixed per-region
//!   factors) and supporting rewrites;
//! * [`hw`] — the hardware substrate the paper ran on, as a model:
//!   Alveo U280 SLR resource pools, per-op cost model, congestion-based
//!   frequency model, clock domains;
//! * [`codegen`] — design netlists plus HLS-C++/SystemVerilog/TCL text
//!   emission (the paper's §3.3 four-file RTL kernels);
//! * [`telemetry`] — zero-cost-when-disabled structured observability:
//!   spans, counters, gauges and bounded time-series behind a nullable
//!   `Option<&Recorder>` handle, exported as Chrome trace-event JSON
//!   (`--trace-out`) and a flat `TELEMETRY.json` summary;
//! * [`sim`] — a cycle-level multi-clock-domain simulator of generated
//!   designs (FIFOs with backpressure, CDC plumbing, real f32 data);
//! * [`runtime`] — PJRT execution of the AOT JAX/Pallas golden models;
//! * [`coordinator`] — config system, compilation pipeline, experiment
//!   registry regenerating every table and figure of the paper;
//! * [`dse`] — automatic design-space exploration: enumerates, prunes,
//!   evaluates and ranks candidate build configurations over the
//!   resource-vs-throughput Pareto frontier, generalizing the paper's
//!   hand-picked per-app configurations into a search — with four
//!   strategies (exhaustive / greedy / seeded annealing / successive
//!   halving), a persistent cross-process evaluation cache
//!   (`--cache-dir`), and exact-simulator verification of frontier
//!   points (`--verify`);
//! * [`apps`] — the four evaluated applications (vector addition,
//!   systolic matrix multiplication, Jacobi-3D / Diffusion-3D stencil
//!   chains, Floyd–Warshall).
//!
//! See `DESIGN.md` for the substitution table (what the paper ran on
//! physical hardware vs. what this repo models), the experiment index,
//! and the `dse` subsystem's architecture and search objectives.

pub mod util;
pub mod symbolic;
pub mod ir;
pub mod frontend;
pub mod analysis;
pub mod transforms;
pub mod hw;
pub mod codegen;
pub mod telemetry;
pub mod sim;
pub mod runtime;
pub mod coordinator;
pub mod dse;
pub mod apps;
