//! The DSE experiment: autotune all four applications and report the
//! chosen configuration next to the paper's hand-picked one.
//!
//! This is the ROADMAP's "pick fast configurations automatically"
//! milestone: instead of replaying the hard-coded specs of Tables 2–6,
//! the [`crate::dse`] subsystem searches the legal (spatial × temporal)
//! space per application and the table below shows whether the search
//! lands on (or beats) the paper's configuration.

use crate::apps;
use crate::dse::{
    run_search, Evaluator, Objective, SearchBase, SearchConfig, SearchOutcome, SpaceOptions,
};
use crate::hw::Device;
use crate::ir::{PumpMode, StencilKind};
use crate::util::table::{fnum, Table};

use super::experiment::ExperimentResult;
use super::pipeline::BuildSpec;

/// One application's autotuning outcome.
pub struct DseChoice {
    pub app: &'static str,
    /// The paper's hand-picked configuration for this objective.
    pub paper: &'static str,
    /// Label of the configuration the search selected.
    pub chosen: String,
    /// Label of the best unpumped reference.
    pub reference: String,
    /// chosen DSP count / reference DSP count.
    pub dsp_ratio: f64,
    /// chosen throughput / reference throughput.
    pub gops_ratio: f64,
    pub frontier_len: usize,
    pub evaluated: usize,
}

fn choice(
    app: &'static str,
    paper: &'static str,
    outcome: &SearchOutcome,
) -> Result<DseChoice, String> {
    let chosen = outcome
        .chosen
        .as_ref()
        .ok_or_else(|| format!("{app}: search selected nothing"))?;
    let reference = outcome
        .reference
        .as_ref()
        .ok_or_else(|| format!("{app}: no unpumped reference"))?;
    let ref_dsp = reference.total_resources.dsp.max(1e-9);
    Ok(DseChoice {
        app,
        paper,
        chosen: chosen.label.clone(),
        reference: reference.label.clone(),
        dsp_ratio: chosen.total_resources.dsp / ref_dsp,
        gops_ratio: chosen.gops / reference.gops.max(1e-12),
        frontier_len: outcome.frontier.len(),
        evaluated: outcome.evaluated,
    })
}

/// Autotune all four applications; shared evaluator, exhaustive search.
pub fn autotune_all(seed: u64) -> Result<Vec<DseChoice>, String> {
    let device = Device::u280();
    let evaluator = Evaluator::new();
    let mut out = Vec::new();

    // vecadd — Table 2's grid (V ∈ {2,4,8}, M = 2), resource objective
    {
        let n = apps::vecadd::PAPER_N;
        let bases = [SearchBase {
            spec: BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(seed),
            flops: apps::vecadd::flops(n),
        }];
        let opts = SpaceOptions {
            vector_widths: vec![2, 4, 8],
            pump_factors: vec![2, 4],
            pump_modes: vec![PumpMode::Resource],
            max_replicas: 1,
            cl0_requests_mhz: vec![],
        };
        let cfg = SearchConfig::exhaustive(Objective::resource());
        let o = run_search(&evaluator, &bases, &device, &opts, &cfg)?;
        out.push(choice("vecadd", "V=8 DP (Table 2)", &o)?);
    }

    // matmul — PE sweep × pump grid × replicas, resource objective
    {
        let n = apps::matmul::PAPER_NMK;
        let bases: Vec<SearchBase> = [16usize, 32, 64]
            .iter()
            .map(|&pes| {
                let mut spec = BuildSpec::new(apps::matmul::build(pes)).cl0(270.0).seeded(seed);
                for (s, v) in apps::matmul::bindings(n) {
                    spec = spec.bind(&s, v);
                }
                SearchBase { spec, flops: apps::matmul::flops(n, n, n) }
            })
            .collect();
        let opts = SpaceOptions::for_device(&device);
        let cfg = SearchConfig::exhaustive(Objective::resource());
        let o = run_search(&evaluator, &bases, &device, &opts, &cfg)?;
        out.push(choice("matmul", "DP 32 (Table 3)", &o)?);
    }

    // jacobi3d — S = 16 chain, resource objective
    {
        let (nx, ny, nz) = (apps::stencil::PAPER_NX, apps::stencil::PAPER_NY, apps::stencil::PAPER_NZ);
        let w = apps::stencil::paper_vec_width(StencilKind::Jacobi3D);
        let stages = 16usize;
        let spec = BuildSpec::new(apps::stencil::build(StencilKind::Jacobi3D, stages, w))
            .bind("NX", nx)
            .bind("NY", ny)
            .bind("NZ", nz)
            .bind("NZ_v", nz / w as i64)
            .cl0(315.0)
            .seeded(seed);
        let bases = [SearchBase {
            spec,
            flops: apps::stencil::flops(StencilKind::Jacobi3D, nx, ny, nz, stages),
        }];
        let opts = SpaceOptions {
            vector_widths: vec![],
            pump_factors: vec![2, 4],
            pump_modes: vec![PumpMode::Resource],
            max_replicas: 1,
            cl0_requests_mhz: vec![],
        };
        let cfg = SearchConfig::exhaustive(Objective::resource());
        let o = run_search(&evaluator, &bases, &device, &opts, &cfg)?;
        out.push(choice("jacobi3d", "S=16 DP (Table 4)", &o)?);
    }

    // floyd_warshall — throughput objective (the paper's §4.4 mode)
    {
        let n = apps::floyd_warshall::PAPER_N;
        let bases = [SearchBase {
            spec: BuildSpec::new(apps::floyd_warshall::build())
                .bind("N", n)
                .cl0(apps::floyd_warshall::CL0_REQUEST_MHZ)
                .seeded(seed),
            flops: apps::floyd_warshall::flops(n),
        }];
        let opts = SpaceOptions {
            vector_widths: vec![],
            pump_factors: vec![2, 4],
            pump_modes: vec![PumpMode::Throughput],
            max_replicas: 1,
            cl0_requests_mhz: vec![],
        };
        let cfg = SearchConfig::exhaustive(Objective::throughput());
        let o = run_search(&evaluator, &bases, &device, &opts, &cfg)?;
        out.push(choice("floyd_warshall", "DP throughput (Table 6)", &o)?);
    }

    Ok(out)
}

/// Render the chosen-vs-paper comparison as an experiment result.
pub fn dse_experiment(seed: u64) -> Result<ExperimentResult, String> {
    let choices = autotune_all(seed)?;
    let mut t = Table::new(
        "DSE: autotuned configuration vs the paper's hand-picked one",
        &[
            "app",
            "paper config",
            "DSE chosen",
            "unpumped ref",
            "DSP vs ref",
            "GOp/s vs ref",
            "frontier",
            "evals",
        ],
    );
    for c in &choices {
        t.row(vec![
            c.app.to_string(),
            c.paper.to_string(),
            c.chosen.clone(),
            c.reference.clone(),
            fnum(c.dsp_ratio, 2),
            fnum(c.gops_ratio, 2),
            c.frontier_len.to_string(),
            c.evaluated.to_string(),
        ]);
    }
    t.footnote(
        "resource objective: min DSP-weighted score at iso-throughput (±20 %); \
         fw uses the throughput objective — the paper's two modes as search goals",
    );
    Ok(ExperimentResult { id: "dse".into(), rendered: t.render(), rows: vec![] })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dse_experiment_autotunes_all_four_apps() {
        let r = dse_experiment(1).unwrap();
        for app in ["vecadd", "matmul", "jacobi3d", "floyd_warshall"] {
            assert!(r.rendered.contains(app), "missing {app}:\n{}", r.rendered);
        }
        assert_eq!(r.id, "dse");
    }

    #[test]
    fn autotuned_matmul_halves_dsp() {
        let choices = autotune_all(1).unwrap();
        let mm = choices.iter().find(|c| c.app == "matmul").unwrap();
        assert!(
            mm.dsp_ratio <= 0.55,
            "matmul DSE must reproduce the ~50 % DSP cut, got {}",
            mm.dsp_ratio
        );
        assert!(mm.gops_ratio >= 0.8, "iso-throughput violated: {}", mm.gops_ratio);
        assert!(mm.frontier_len >= 6, "frontier too small: {}", mm.frontier_len);
    }
}
