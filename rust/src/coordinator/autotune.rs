//! The DSE experiment: autotune all four applications and report the
//! chosen configuration next to the paper's hand-picked one.
//!
//! This is the ROADMAP's "pick fast configurations automatically"
//! milestone: instead of replaying the hard-coded specs of Tables 2–6,
//! the [`crate::dse`] subsystem searches the legal (spatial × temporal)
//! space per application and the table below shows whether the search
//! lands on (or beats) the paper's configuration.

use crate::apps;
use crate::dse::{
    run_search, Evaluator, Objective, SearchBase, SearchConfig, SearchOutcome, SpaceOptions,
};
use crate::hw::Device;
use crate::ir::{PumpMode, StencilKind};
use crate::util::table::{fnum, Table};
use crate::util::Rng;

use super::experiment::ExperimentResult;
use super::pipeline::BuildSpec;

/// The PE counts the matmul sweep explores (Table 3's columns).
const MATMUL_PES: [usize; 3] = [16, 32, 64];
/// Clock requests and chain length shared by search and verify bases.
const MATMUL_CL0_MHZ: f64 = 270.0;
const STENCIL_CL0_MHZ: f64 = 315.0;
const STENCIL_STAGES: usize = 16;

/// One app's base specs at a given problem scale. This is the single
/// source of truth the CLI search (paper scale) and the `--verify`
/// golden rig (artifact scale) both build from, so the two stay
/// aligned index for index.
fn app_bases(app: &str, n: i64, seed: u64) -> Result<Vec<BuildSpec>, String> {
    match app {
        "vecadd" => Ok(vec![BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(seed)]),
        "matmul" => {
            if n % 16 != 0 {
                return Err(format!("matmul size {n} must be a multiple of 16"));
            }
            Ok(MATMUL_PES
                .iter()
                .map(|&pes| {
                    let mut spec = BuildSpec::new(apps::matmul::build(pes))
                        .cl0(MATMUL_CL0_MHZ)
                        .seeded(seed);
                    for (s, v) in apps::matmul::bindings(n) {
                        spec = spec.bind(&s, v);
                    }
                    spec
                })
                .collect())
        }
        // "stencil" is the chain alias `--mixed-factors` smoke runs use
        "jacobi" | "diffusion" | "stencil" => {
            let kind = stencil_kind(app);
            let w = apps::stencil::paper_vec_width(kind);
            let (ny, nz) = (apps::stencil::PAPER_NY, apps::stencil::PAPER_NZ);
            Ok(vec![BuildSpec::new(apps::stencil::build(kind, STENCIL_STAGES, w))
                .bind("NX", n)
                .bind("NY", ny)
                .bind("NZ", nz)
                .bind("NZ_v", nz / w as i64)
                .cl0(STENCIL_CL0_MHZ)
                .seeded(seed)])
        }
        "fw" | "floyd_warshall" => Ok(vec![BuildSpec::new(apps::floyd_warshall::build())
            .bind("N", n)
            .cl0(apps::floyd_warshall::CL0_REQUEST_MHZ)
            .seeded(seed)]),
        other => Err(format!(
            "unknown app '{other}' (vecadd|matmul|jacobi|diffusion|stencil|fw)"
        )),
    }
}

fn stencil_kind(app: &str) -> StencilKind {
    if app == "jacobi" || app == "stencil" {
        StencilKind::Jacobi3D
    } else {
        StencilKind::Diffusion3D
    }
}

/// Default (paper-scale) problem size of a DSE app.
fn paper_n(app: &str) -> i64 {
    match app {
        "vecadd" => apps::vecadd::PAPER_N,
        "matmul" => apps::matmul::PAPER_NMK,
        "jacobi" | "diffusion" | "stencil" => apps::stencil::PAPER_NX,
        _ => apps::floyd_warshall::PAPER_N,
    }
}

/// Workload flops of one app at size `n` (the throughput axis).
fn app_flops(app: &str, n: i64) -> f64 {
    match app {
        "vecadd" => apps::vecadd::flops(n),
        "matmul" => apps::matmul::flops(n, n, n),
        "jacobi" | "diffusion" | "stencil" => {
            let kind = stencil_kind(app);
            apps::stencil::flops(
                kind,
                n,
                apps::stencil::PAPER_NY,
                apps::stencil::PAPER_NZ,
                STENCIL_STAGES,
            )
        }
        _ => apps::floyd_warshall::flops(n),
    }
}

/// Default `dse --verify` (and `bench` drift-gate) tolerance per app.
/// Each app's rate model has its own validated envelope — the engine's
/// cross-validation tests bound vecadd at ±15 %, FW at ±25 %, GEMM at
/// ±40 % — so one global ±0.40 was simultaneously too loose for vecadd
/// (real drift hid under it) and the binding constraint for GEMM. An
/// explicit CLI `--tolerance` always wins; unknown apps fall back to
/// the conservative [`crate::dse::DEFAULT_TOLERANCE`].
pub fn verify_tolerance(app: &str) -> f64 {
    match app {
        "vecadd" => 0.20,
        "matmul" => 0.40,
        "jacobi" | "diffusion" | "stencil" => 0.40,
        "fw" | "floyd_warshall" => 0.25,
        _ => crate::dse::DEFAULT_TOLERANCE,
    }
}

/// The search problem `tvec dse` runs for one app: paper-scale bases
/// (or `n_override`) plus the device-bounded candidate-space options.
pub fn search_problem(
    app: &str,
    n_override: Option<i64>,
    seed: u64,
    device: &Device,
) -> Result<(Vec<SearchBase>, SpaceOptions), String> {
    let n = n_override.unwrap_or_else(|| paper_n(app));
    let flops = app_flops(app, n);
    let bases = app_bases(app, n, seed)?
        .into_iter()
        .map(|spec| SearchBase { spec, flops })
        .collect();
    Ok((bases, SpaceOptions::for_device(device)))
}

/// Everything `tvec dse --verify` needs to exact-simulate one app's
/// frontier points at golden (artifact) scale: base specs aligned
/// index-for-index with the search's [`SearchBase`] list, plus the
/// input containers the exact run reads.
pub struct GoldenRig {
    pub bases: Vec<BuildSpec>,
    pub inputs: Vec<(String, Vec<f32>)>,
}

/// Build the golden-scale verification rig for a DSE app name (the
/// names `tvec dse --app` accepts). The bases come from the same
/// [`app_bases`] constructor as [`search_problem`] — same SDFG
/// structure and base count, golden-scale bindings — so any frontier
/// `DesignPoint` can be re-applied to its base by index.
pub fn golden_rig(app: &str, seed: u64) -> Result<GoldenRig, String> {
    let mut rng = Rng::new(seed ^ 0x601de5ca1e);
    let (golden_n, inputs): (i64, Vec<(String, Vec<f32>)>) = match app {
        "vecadd" => {
            let n = apps::vecadd::GOLDEN_N;
            (
                n,
                vec![
                    ("x".to_string(), rng.f32_vec(n as usize)),
                    ("y".to_string(), rng.f32_vec(n as usize)),
                ],
            )
        }
        "matmul" => {
            let n = apps::matmul::GOLDEN_NMK;
            (
                n,
                vec![
                    ("A".to_string(), rng.f32_vec((n * n) as usize)),
                    ("B".to_string(), rng.f32_vec((n * n) as usize)),
                ],
            )
        }
        "jacobi" | "diffusion" | "stencil" => {
            // same chain length as the search bases (app_bases): only
            // the domain shrinks, the design structure stays identical
            let nx = apps::stencil::GOLDEN_NX;
            let points = nx * apps::stencil::PAPER_NY * apps::stencil::PAPER_NZ;
            (nx, vec![("v_in".to_string(), rng.f32_vec(points as usize))])
        }
        "fw" | "floyd_warshall" => {
            let n = apps::floyd_warshall::GOLDEN_N;
            (
                n,
                vec![(
                    "dist".to_string(),
                    apps::floyd_warshall::random_graph(n as usize, seed, 0.25),
                )],
            )
        }
        other => {
            return Err(format!(
                "no golden verification rig for app '{other}' \
                 (vecadd|matmul|jacobi|diffusion|stencil|fw)"
            ))
        }
    };
    Ok(GoldenRig { bases: app_bases(app, golden_n, seed)?, inputs })
}

/// One application's autotuning outcome.
pub struct DseChoice {
    pub app: &'static str,
    /// The paper's hand-picked configuration for this objective.
    pub paper: &'static str,
    /// Label of the configuration the search selected.
    pub chosen: String,
    /// Label of the best unpumped reference.
    pub reference: String,
    /// chosen DSP count / reference DSP count.
    pub dsp_ratio: f64,
    /// chosen throughput / reference throughput.
    pub gops_ratio: f64,
    pub frontier_len: usize,
    pub evaluated: usize,
}

fn choice(
    app: &'static str,
    paper: &'static str,
    outcome: &SearchOutcome,
) -> Result<DseChoice, String> {
    let chosen = outcome
        .chosen
        .as_ref()
        .ok_or_else(|| format!("{app}: search selected nothing"))?;
    let reference = outcome
        .reference
        .as_ref()
        .ok_or_else(|| format!("{app}: no unpumped reference"))?;
    let ref_dsp = reference.total_resources.dsp.max(1e-9);
    Ok(DseChoice {
        app,
        paper,
        chosen: chosen.label.clone(),
        reference: reference.label.clone(),
        dsp_ratio: chosen.total_resources.dsp / ref_dsp,
        gops_ratio: chosen.gops / reference.gops.max(1e-12),
        frontier_len: outcome.frontier.len(),
        evaluated: outcome.evaluated,
    })
}

/// Autotune all four applications; shared evaluator, exhaustive search.
pub fn autotune_all(seed: u64) -> Result<Vec<DseChoice>, String> {
    let device = Device::u280();
    let evaluator = Evaluator::new();
    let mut out = Vec::new();

    // vecadd — Table 2's grid (V ∈ {2,4,8}, M = 2), resource objective
    {
        let n = apps::vecadd::PAPER_N;
        let bases = [SearchBase {
            spec: BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(seed),
            flops: apps::vecadd::flops(n),
        }];
        let opts = SpaceOptions {
            vector_widths: vec![2, 4, 8],
            pump_factors: vec![2, 4],
            pump_modes: vec![PumpMode::Resource],
            max_replicas: 1,
            cl0_requests_mhz: vec![],
            mixed_factors: false,
        };
        let cfg = SearchConfig::exhaustive(Objective::resource());
        let o = run_search(&evaluator, &bases, &device, &opts, &cfg)?;
        out.push(choice("vecadd", "V=8 DP (Table 2)", &o)?);
    }

    // matmul — PE sweep × pump grid × replicas, resource objective
    {
        let n = apps::matmul::PAPER_NMK;
        let bases: Vec<SearchBase> = [16usize, 32, 64]
            .iter()
            .map(|&pes| {
                let mut spec = BuildSpec::new(apps::matmul::build(pes)).cl0(270.0).seeded(seed);
                for (s, v) in apps::matmul::bindings(n) {
                    spec = spec.bind(&s, v);
                }
                SearchBase { spec, flops: apps::matmul::flops(n, n, n) }
            })
            .collect();
        let opts = SpaceOptions::for_device(&device);
        let cfg = SearchConfig::exhaustive(Objective::resource());
        let o = run_search(&evaluator, &bases, &device, &opts, &cfg)?;
        out.push(choice("matmul", "DP 32 (Table 3)", &o)?);
    }

    // jacobi3d — S = 16 chain, resource objective
    {
        let (nx, ny, nz) = (apps::stencil::PAPER_NX, apps::stencil::PAPER_NY, apps::stencil::PAPER_NZ);
        let w = apps::stencil::paper_vec_width(StencilKind::Jacobi3D);
        let stages = 16usize;
        let spec = BuildSpec::new(apps::stencil::build(StencilKind::Jacobi3D, stages, w))
            .bind("NX", nx)
            .bind("NY", ny)
            .bind("NZ", nz)
            .bind("NZ_v", nz / w as i64)
            .cl0(315.0)
            .seeded(seed);
        let bases = [SearchBase {
            spec,
            flops: apps::stencil::flops(StencilKind::Jacobi3D, nx, ny, nz, stages),
        }];
        let opts = SpaceOptions {
            vector_widths: vec![],
            pump_factors: vec![2, 4],
            pump_modes: vec![PumpMode::Resource],
            max_replicas: 1,
            cl0_requests_mhz: vec![],
            mixed_factors: false,
        };
        let cfg = SearchConfig::exhaustive(Objective::resource());
        let o = run_search(&evaluator, &bases, &device, &opts, &cfg)?;
        out.push(choice("jacobi3d", "S=16 DP (Table 4)", &o)?);
    }

    // floyd_warshall — throughput objective (the paper's §4.4 mode)
    {
        let n = apps::floyd_warshall::PAPER_N;
        let bases = [SearchBase {
            spec: BuildSpec::new(apps::floyd_warshall::build())
                .bind("N", n)
                .cl0(apps::floyd_warshall::CL0_REQUEST_MHZ)
                .seeded(seed),
            flops: apps::floyd_warshall::flops(n),
        }];
        let opts = SpaceOptions {
            vector_widths: vec![],
            pump_factors: vec![2, 4],
            pump_modes: vec![PumpMode::Throughput],
            max_replicas: 1,
            cl0_requests_mhz: vec![],
            mixed_factors: false,
        };
        let cfg = SearchConfig::exhaustive(Objective::throughput());
        let o = run_search(&evaluator, &bases, &device, &opts, &cfg)?;
        out.push(choice("floyd_warshall", "DP throughput (Table 6)", &o)?);
    }

    Ok(out)
}

/// Render the chosen-vs-paper comparison as an experiment result.
pub fn dse_experiment(seed: u64) -> Result<ExperimentResult, String> {
    let choices = autotune_all(seed)?;
    let mut t = Table::new(
        "DSE: autotuned configuration vs the paper's hand-picked one",
        &[
            "app",
            "paper config",
            "DSE chosen",
            "unpumped ref",
            "DSP vs ref",
            "GOp/s vs ref",
            "frontier",
            "evals",
        ],
    );
    for c in &choices {
        t.row(vec![
            c.app.to_string(),
            c.paper.to_string(),
            c.chosen.clone(),
            c.reference.clone(),
            fnum(c.dsp_ratio, 2),
            fnum(c.gops_ratio, 2),
            c.frontier_len.to_string(),
            c.evaluated.to_string(),
        ]);
    }
    t.footnote(
        "resource objective: min DSP-weighted score at iso-throughput (±20 %); \
         fw uses the throughput objective — the paper's two modes as search goals",
    );
    Ok(ExperimentResult { id: "dse".into(), rendered: t.render(), rows: vec![] })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dse_experiment_autotunes_all_four_apps() {
        let r = dse_experiment(1).unwrap();
        for app in ["vecadd", "matmul", "jacobi3d", "floyd_warshall"] {
            assert!(r.rendered.contains(app), "missing {app}:\n{}", r.rendered);
        }
        assert_eq!(r.id, "dse");
    }

    #[test]
    fn golden_rig_bases_align_with_search_bases() {
        // the rig must mirror the search bases index for index (both
        // are built by app_bases, but the invariant is load-bearing
        // for --verify's Evaluation.base → golden base mapping)
        let device = Device::u280();
        for app in ["vecadd", "matmul", "jacobi", "diffusion", "stencil", "fw"] {
            let (search_bases, _) = search_problem(app, None, 1, &device).unwrap();
            let rig = golden_rig(app, 1).unwrap();
            assert_eq!(rig.bases.len(), search_bases.len(), "{app}");
            assert!(!rig.inputs.is_empty(), "{app}");
            for (s, g) in search_bases.iter().zip(&rig.bases) {
                assert_eq!(s.spec.sdfg.name, g.sdfg.name, "{app}: SDFG structure differs");
            }
        }
        assert_eq!(golden_rig("matmul", 1).unwrap().bases.len(), 3);
        assert!(golden_rig("nonsense", 1).is_err());
        assert!(search_problem("nonsense", None, 1, &device).is_err());
    }

    #[test]
    fn per_app_tolerance_tightens_vecadd_and_keeps_gemm_loose() {
        // the satellite's contract: GEMM's envelope is looser than
        // vecadd's, every known app has a finite non-negative default,
        // unknown apps fall back to the global DEFAULT_TOLERANCE
        assert!(verify_tolerance("vecadd") < verify_tolerance("matmul"));
        for app in ["vecadd", "matmul", "jacobi", "diffusion", "stencil", "fw", "floyd_warshall"]
        {
            let t = verify_tolerance(app);
            assert!(t.is_finite() && t > 0.0 && t <= 1.0, "{app}: {t}");
        }
        assert_eq!(verify_tolerance("unknown"), crate::dse::DEFAULT_TOLERANCE);
        // the per-app envelopes never exceed the global fallback
        assert!(verify_tolerance("vecadd") <= crate::dse::DEFAULT_TOLERANCE);
    }

    #[test]
    fn vecadd_frontier_verifies_against_exact_sim() {
        // the full --verify path in miniature: search at paper-ish
        // scale, then exact-sim-check every frontier point at golden
        // scale and demand rate-model agreement
        use crate::dse::{verify_frontier, SearchBase, SpaceOptions, DEFAULT_TOLERANCE};
        let n = 1i64 << 20;
        let device = Device::u280();
        let bases = [SearchBase {
            spec: BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(1),
            flops: apps::vecadd::flops(n),
        }];
        let opts = SpaceOptions {
            vector_widths: vec![2, 4, 8],
            pump_factors: vec![2],
            pump_modes: vec![PumpMode::Resource],
            max_replicas: 1,
            cl0_requests_mhz: vec![],
            mixed_factors: false,
        };
        let out = run_search(
            &Evaluator::new(),
            &bases,
            &device,
            &opts,
            &SearchConfig::exhaustive(Objective::resource()),
        )
        .unwrap();
        assert!(!out.frontier.is_empty());
        let rig = golden_rig("vecadd", 1).unwrap();
        let reports =
            verify_frontier(&out.frontier, &rig.bases, &rig.inputs, DEFAULT_TOLERANCE)
                .unwrap();
        assert_eq!(reports.len(), out.frontier.len());
        for r in &reports {
            assert!(r.skipped.is_none(), "{}: unexpected skip", r.label);
            assert!(
                r.within,
                "{}: rate {} vs exact {} (ratio {:.3})",
                r.label, r.rate_cycles, r.exact_cycles, r.ratio
            );
        }
    }

    #[test]
    fn autotuned_matmul_halves_dsp() {
        let choices = autotune_all(1).unwrap();
        let mm = choices.iter().find(|c| c.app == "matmul").unwrap();
        assert!(
            mm.dsp_ratio <= 0.55,
            "matmul DSE must reproduce the ~50 % DSP cut, got {}",
            mm.dsp_ratio
        );
        assert!(mm.gops_ratio >= 0.8, "iso-throughput violated: {}", mm.gops_ratio);
        assert!(mm.frontier_len >= 6, "frontier too small: {}", mm.frontier_len);
    }
}
