//! `tvec bench` — measured throughput of the exact-simulator engines
//! and the DSE sweep path, with a machine-readable `BENCH_sim.json`
//! artifact.
//!
//! Three golden-scale designs (vecadd V8 R2, matmul R2, the 16-stage
//! jacobi chain R4) run through both the event-driven
//! [`run_exact_in`] and the legacy stepper [`run_exact_reference_in`],
//! every run inside ONE shared transaction arena (the pooled data
//! plane of DESIGN.md §10, measured as the DSE loop deploys it); the
//! report carries slow-cycles/sec for each plus the speedup, the
//! arena's slot/recycling counters with a per-app flat-high-water
//! check, and cross-checks the analytic rate model against the exact
//! count under each app's per-app verify tolerance — the CI drift
//! gate (`--smoke` shrinks the problem sizes for that job). A
//! cold-vs-warm DSE sweep over a throwaway cache directory rounds out
//! the report.
//!
//! Schema v4 (DESIGN.md §15) adds the parallel rows: per-app
//! sharded-vs-serial slow-cycles/sec over replicated designs
//! ([`crate::sim::run_exact_sharded_in`]), a scalar-vs-chunked
//! `eval_lanes` micro-benchmark (both evaluators are always compiled;
//! the `simd` feature only changes which one `eval_lanes` dispatches
//! to), and the pooled frontier-verification wall clock at the bench's
//! `--threads` worker count. The JSON schema history is in DESIGN.md
//! §9 (v2 arena block, v3 dse_cache block) and §15 (v4).

use std::time::Instant;

use crate::apps;
use crate::dse::evaluate::evaluate_point;
use crate::dse::{
    run_search, verify_frontier_pooled, ArenaPool, DesignPoint, Evaluation, Evaluator, Objective,
    SearchBase, SearchConfig, SpaceOptions, VerifyBudget, DEFAULT_TOLERANCE,
};
use crate::hw::Device;
use crate::ir::{PumpMode, StencilKind, TaskExpr, Tasklet};
use crate::sim::compute::CompiledTasklet;
use crate::sim::{
    exact_engines_agree_in, rate_model, replicate_design, replicate_inputs, resolve_threads,
    run_exact_in, run_exact_reference_in, run_exact_sharded_in, Arena, ArenaStats, Hbm,
    SimOutcome, Txn,
};
use crate::util::Rng;

use super::autotune::verify_tolerance;
use super::pipeline::{compile, BuildSpec};

/// One design's exact-simulator measurement.
pub struct SimBench {
    /// App key (matches `verify_tolerance` / `tvec dse --app` names).
    pub app: String,
    /// Candidate label, e.g. `V8 R2`.
    pub config: String,
    /// Slow cycles one exact run takes (identical across engines —
    /// the property tests enforce it; asserted again here).
    pub slow_cycles: u64,
    /// Best-of-iters wall-clock of the event-driven engine.
    pub event_secs: f64,
    /// Best-of-iters wall-clock of the legacy stepper.
    pub reference_secs: f64,
    /// Analytic rate-model slow-cycle count for the same design.
    pub rate_cycles: u64,
    /// Per-app drift tolerance the gate applies.
    pub tolerance: f64,
    /// Did the shared arena's slot count and high-water mark stay flat
    /// across this app's repeated timed runs (after the warmup run
    /// established them)? A growing mark means the pool is leaking or
    /// re-growing instead of recycling.
    pub arena_flat: bool,
}

impl SimBench {
    pub fn event_cycles_per_sec(&self) -> f64 {
        self.slow_cycles as f64 / self.event_secs.max(1e-12)
    }

    pub fn reference_cycles_per_sec(&self) -> f64 {
        self.slow_cycles as f64 / self.reference_secs.max(1e-12)
    }

    /// Event-engine speedup over the legacy stepper.
    pub fn speedup(&self) -> f64 {
        self.reference_secs / self.event_secs.max(1e-12)
    }

    /// `rate_cycles / exact_cycles` (1.0 = perfect agreement).
    pub fn drift_ratio(&self) -> f64 {
        self.rate_cycles as f64 / self.slow_cycles.max(1) as f64
    }

    pub fn within_tolerance(&self) -> bool {
        (self.drift_ratio() - 1.0).abs() <= self.tolerance
    }
}

/// One replicated design's sharded-vs-serial measurement. The sharded
/// engine runs the same netlist, bit-identical (checked before timing
/// — a mismatch voids the benchmark), so the speedup is pure
/// parallelism.
pub struct ShardBench {
    pub app: String,
    /// Independent replicas the design was widened to (= shard count).
    pub replicas: usize,
    /// Worker threads the sharded engine ran with.
    pub threads: usize,
    /// Slow cycles of one run (identical across engines; asserted).
    pub slow_cycles: u64,
    /// Best-of-iters wall-clock of the serial event engine.
    pub serial_secs: f64,
    /// Best-of-iters wall-clock of the sharded engine.
    pub sharded_secs: f64,
}

impl ShardBench {
    pub fn serial_cycles_per_sec(&self) -> f64 {
        self.slow_cycles as f64 / self.serial_secs.max(1e-12)
    }

    pub fn sharded_cycles_per_sec(&self) -> f64 {
        self.slow_cycles as f64 / self.sharded_secs.max(1e-12)
    }

    /// Sharded-engine speedup over the serial event engine.
    pub fn speedup(&self) -> f64 {
        self.serial_secs / self.sharded_secs.max(1e-12)
    }
}

/// Scalar-vs-chunked `eval_lanes` micro-benchmark. Both evaluators are
/// always compiled; `active` names the one `eval_lanes` dispatches to
/// in this build (`chunked` under the `simd` feature, else `scalar`).
pub struct SimdBench {
    pub active: &'static str,
    /// Lanes per evaluation (inner repeats make the timing readable).
    pub lanes: usize,
    pub scalar_secs: f64,
    pub chunked_secs: f64,
}

impl SimdBench {
    /// Chunked-evaluator speedup over the lane-at-a-time scalar loop.
    pub fn speedup(&self) -> f64 {
        self.scalar_secs / self.chunked_secs.max(1e-12)
    }
}

/// Pooled frontier-verification wall clock (`verify_frontier_pooled`
/// at the bench's worker count).
pub struct VerifyBench {
    pub app: String,
    /// Frontier points re-checked at golden scale per run.
    pub points: usize,
    /// Worker threads the pooled verifier fanned across.
    pub threads: usize,
    /// Best-of-iters wall-clock of one pooled verification pass.
    pub secs: f64,
}

/// Cold-vs-warm DSE sweep wall-clock over a throwaway cache directory.
pub struct DseBench {
    pub app: String,
    pub cold_secs: f64,
    pub warm_secs: f64,
    pub cold_new_compiles: usize,
    pub warm_new_compiles: usize,
    /// Memo-cache hits during the cold sweep (same-run re-evaluations).
    pub cold_hits: usize,
    /// Cache hits during the warm sweep (served by the persistent
    /// store loaded at construction).
    pub warm_hits: usize,
}

impl DseBench {
    /// Warm-sweep cache hit rate: `hits / (hits + new compiles)`. The
    /// CI smoke gate requires 1.0 — a second run over the flushed
    /// store must compile nothing. An idle sweep (0 + 0) counts as
    /// 1.0: nothing compiled is exactly the contract.
    pub fn warm_hit_rate(&self) -> f64 {
        let total = self.warm_hits + self.warm_new_compiles;
        if total == 0 {
            1.0
        } else {
            self.warm_hits as f64 / total as f64
        }
    }
}

/// The full `tvec bench` outcome.
pub struct BenchReport {
    pub smoke: bool,
    /// Resolved worker-thread count the parallel rows ran with (the
    /// CLI's `--threads`, 0 resolved to available parallelism).
    pub threads: usize,
    pub sims: Vec<SimBench>,
    pub sharded: Vec<ShardBench>,
    pub simd: SimdBench,
    pub verify: VerifyBench,
    /// Final counters of the one arena every sim bench (both engines,
    /// warmup + timed iterations) ran inside.
    pub arena: ArenaStats,
    pub dse: DseBench,
}

impl BenchReport {
    /// Every app's repeated runs kept the arena's high-water mark flat.
    pub fn arena_flat(&self) -> bool {
        self.sims.iter().all(|s| s.arena_flat)
    }

    /// Render as `BENCH_sim.json` (schema: DESIGN.md §9; v2 added the
    /// `arena` block, v3 the `dse_cache` block with the warm hit rate,
    /// v4 the `threads` field plus the `sharded`/`simd`/`verify`
    /// parallel rows — DESIGN.md §15).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str("  \"schema\": \"tvec-bench-sim v4\",\n");
        out.push_str(&format!("  \"smoke\": {},\n", self.smoke));
        out.push_str(&format!("  \"threads\": {},\n", self.threads));
        out.push_str("  \"sim\": [\n");
        for (i, s) in self.sims.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"app\": \"{}\", \"config\": \"{}\", \"slow_cycles\": {}, \
                 \"event_secs\": {:.6}, \"event_cycles_per_sec\": {:.1}, \
                 \"reference_secs\": {:.6}, \"reference_cycles_per_sec\": {:.1}, \
                 \"speedup\": {:.3}, \"rate_cycles\": {}, \"drift_ratio\": {:.4}, \
                 \"tolerance\": {:.2}, \"within_tolerance\": {}}}{}\n",
                s.app,
                s.config,
                s.slow_cycles,
                s.event_secs,
                s.event_cycles_per_sec(),
                s.reference_secs,
                s.reference_cycles_per_sec(),
                s.speedup(),
                s.rate_cycles,
                s.drift_ratio(),
                s.tolerance,
                s.within_tolerance(),
                if i + 1 < self.sims.len() { "," } else { "" },
            ));
        }
        out.push_str("  ],\n");
        out.push_str("  \"sharded\": [\n");
        for (i, s) in self.sharded.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"app\": \"{}\", \"replicas\": {}, \"threads\": {}, \
                 \"slow_cycles\": {}, \"serial_secs\": {:.6}, \
                 \"serial_cycles_per_sec\": {:.1}, \"sharded_secs\": {:.6}, \
                 \"sharded_cycles_per_sec\": {:.1}, \"speedup\": {:.3}}}{}\n",
                s.app,
                s.replicas,
                s.threads,
                s.slow_cycles,
                s.serial_secs,
                s.serial_cycles_per_sec(),
                s.sharded_secs,
                s.sharded_cycles_per_sec(),
                s.speedup(),
                if i + 1 < self.sharded.len() { "," } else { "" },
            ));
        }
        out.push_str("  ],\n");
        out.push_str(&format!(
            "  \"simd\": {{\"active\": \"{}\", \"lanes\": {}, \"scalar_secs\": {:.6}, \
             \"chunked_secs\": {:.6}, \"speedup\": {:.3}}},\n",
            self.simd.active,
            self.simd.lanes,
            self.simd.scalar_secs,
            self.simd.chunked_secs,
            self.simd.speedup(),
        ));
        out.push_str(&format!(
            "  \"verify\": {{\"app\": \"{}\", \"points\": {}, \"threads\": {}, \
             \"secs\": {:.6}}},\n",
            self.verify.app, self.verify.points, self.verify.threads, self.verify.secs,
        ));
        out.push_str(&format!(
            "  \"arena\": {{\"classes\": {}, \"slots\": {}, \"peak_live\": {}, \
             \"recycle_hits\": {}, \"resets\": {}, \"leaked\": {}, \
             \"flat_high_water\": {}}},\n",
            self.arena.classes,
            self.arena.slots,
            self.arena.peak_live,
            self.arena.recycle_hits,
            self.arena.resets,
            self.arena.leaked,
            self.arena_flat(),
        ));
        out.push_str(&format!(
            "  \"dse\": {{\"app\": \"{}\", \"cold_secs\": {:.6}, \"warm_secs\": {:.6}, \
             \"warm_speedup\": {:.3}, \"cold_new_compiles\": {}, \"warm_new_compiles\": {}}},\n",
            self.dse.app,
            self.dse.cold_secs,
            self.dse.warm_secs,
            self.dse.cold_secs / self.dse.warm_secs.max(1e-12),
            self.dse.cold_new_compiles,
            self.dse.warm_new_compiles,
        ));
        out.push_str(&format!(
            "  \"dse_cache\": {{\"cold_hits\": {}, \"warm_hits\": {}, \
             \"warm_new_compiles\": {}, \"warm_hit_rate\": {:.4}}}\n",
            self.dse.cold_hits,
            self.dse.warm_hits,
            self.dse.warm_new_compiles,
            self.dse.warm_hit_rate(),
        ));
        out.push('}');
        out.push('\n');
        out
    }

    /// Apps whose exact-vs-rate drift exceeds their tolerance (the CI
    /// gate fails on any).
    pub fn drift_failures(&self) -> Vec<String> {
        self.sims
            .iter()
            .filter(|s| !s.within_tolerance())
            .map(|s| {
                format!(
                    "{} {}: rate {} vs exact {} (ratio {:.3}, tolerance ±{})",
                    s.app,
                    s.config,
                    s.rate_cycles,
                    s.slow_cycles,
                    s.drift_ratio(),
                    s.tolerance
                )
            })
            .collect()
    }
}

/// Best-of-`iters` wall-clock of `f` in seconds.
fn time_best<F: FnMut()>(iters: u32, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

const SIM_BUDGET: u64 = 100_000_000;

fn bench_design(
    app: &str,
    config: &str,
    spec: BuildSpec,
    inputs: Vec<(String, Vec<f32>)>,
    iters: u32,
    tolerance_override: Option<f64>,
    arena: &mut Arena,
) -> Result<SimBench, String> {
    let c = compile(spec)?;
    let mk_hbm = || {
        let mut h = Hbm::new();
        for (name, data) in &inputs {
            h.load(name, data.clone());
        }
        h
    };
    // the shared oracle up front: the engines must be cycle-exact
    // before the timings mean anything (this also serves as warmup —
    // for the engines and for the shared arena, whose slabs it grows
    // to this design's high-water mark)
    exact_engines_agree_in(&c.design, mk_hbm(), SIM_BUDGET, &[], arena)
        .map_err(|e| format!("{app} {config}: engines disagree — benchmark void: {e}"))?;
    let warm = arena.stats();
    let mut slow_cycles = 0u64;
    let event_secs = time_best(iters, || {
        let out: SimOutcome =
            run_exact_in(&c.design, mk_hbm(), SIM_BUDGET, arena).expect("checked above");
        slow_cycles = out.stats.slow_cycles;
    });
    let reference_secs = time_best(iters, || {
        run_exact_reference_in(&c.design, mk_hbm(), SIM_BUDGET, arena).expect("checked above");
    });
    // repeated runs of a design the warmup already simulated must be
    // served entirely from recycled slots
    let after = arena.stats();
    let arena_flat = after.slots == warm.slots && after.peak_live == warm.peak_live;
    Ok(SimBench {
        app: app.to_string(),
        config: config.to_string(),
        slow_cycles,
        event_secs,
        reference_secs,
        rate_cycles: rate_model(&c.design).slow_cycles,
        tolerance: tolerance_override.unwrap_or_else(|| verify_tolerance(app)),
        arena_flat,
    })
}

/// Replicate a compiled design `k` ways and time the serial event
/// engine against the sharded engine at `threads` workers. The two
/// runs are checked cycle-identical before any timing counts.
fn bench_sharded(
    app: &str,
    spec: BuildSpec,
    inputs: &[(String, Vec<f32>)],
    k: usize,
    threads: usize,
    iters: u32,
) -> Result<ShardBench, String> {
    let c = compile(spec)?;
    let rep = replicate_design(&c.design, k);
    let mk_hbm = || replicate_inputs(inputs, k);
    let mut arena = Arena::new();
    let mut shard_arenas: Vec<Arena> = Vec::new();
    // warmup both engines (grows their arenas) and pin equivalence
    let serial = run_exact_in(&rep, mk_hbm(), SIM_BUDGET, &mut arena)
        .map_err(|e| format!("{app} x{k}: serial run failed: {e}"))?;
    let sharded =
        run_exact_sharded_in(&rep, mk_hbm(), SIM_BUDGET, threads, None, &mut shard_arenas, None)
            .map_err(|e| format!("{app} x{k}: sharded run failed: {e}"))?;
    if sharded.stats.slow_cycles != serial.stats.slow_cycles {
        return Err(format!(
            "{app} x{k}: sharded engine diverged — benchmark void: serial {} vs sharded {} \
             slow cycles",
            serial.stats.slow_cycles, sharded.stats.slow_cycles
        ));
    }
    let slow_cycles = serial.stats.slow_cycles;
    let serial_secs = time_best(iters, || {
        run_exact_in(&rep, mk_hbm(), SIM_BUDGET, &mut arena).expect("checked above");
    });
    let sharded_secs = time_best(iters, || {
        run_exact_sharded_in(&rep, mk_hbm(), SIM_BUDGET, threads, None, &mut shard_arenas, None)
            .expect("checked above");
    });
    Ok(ShardBench {
        app: app.to_string(),
        replicas: k,
        threads,
        slow_cycles,
        serial_secs,
        sharded_secs,
    })
}

/// Micro-benchmark `eval_lanes_scalar` vs `eval_lanes_chunked` on a
/// muladd+add program (the shape the stencil chains run hottest).
/// Outputs are checked bit-identical before timing.
fn bench_simd(smoke: bool, rng: &mut Rng, iters: u32) -> SimdBench {
    let lanes = if smoke { 1024 } else { 4096 };
    let reps = if smoke { 16 } else { 64 };
    let expr = TaskExpr::muladd(
        TaskExpr::input("a"),
        TaskExpr::input("b"),
        TaskExpr::input("c"),
    )
    .add(TaskExpr::input("d"));
    let t = Tasklet::new("bench_simd", vec![("o", expr)]);
    let conns: Vec<String> = ["a", "b", "c", "d"].iter().map(|s| s.to_string()).collect();
    let ct = CompiledTasklet::compile(&t, &conns).expect("static program compiles");
    let mut arena = Arena::new();
    let popped: Vec<Txn> =
        (0..conns.len()).map(|_| arena.alloc_from(&rng.f32_vec(lanes))).collect();
    let mut vals = vec![0.0f32; conns.len()];
    let mut stack = vec![0.0f32; ct.stack_depth()];
    let mut out_s = vec![0.0f32; lanes];
    let mut out_c = vec![0.0f32; lanes];
    ct.eval_lanes_scalar(&arena, &popped, &mut vals, &mut stack, &mut out_s);
    ct.eval_lanes_chunked(&arena, &popped, &mut vals, &mut stack, &mut out_c);
    debug_assert!(
        out_s.iter().zip(&out_c).all(|(a, b)| a.to_bits() == b.to_bits()),
        "chunked eval_lanes diverged from scalar"
    );
    let scalar_secs = time_best(iters, || {
        for _ in 0..reps {
            ct.eval_lanes_scalar(&arena, &popped, &mut vals, &mut stack, &mut out_s);
        }
    });
    let chunked_secs = time_best(iters, || {
        for _ in 0..reps {
            ct.eval_lanes_chunked(&arena, &popped, &mut vals, &mut stack, &mut out_c);
        }
    });
    SimdBench {
        active: if cfg!(feature = "simd") { "chunked" } else { "scalar" },
        lanes,
        scalar_secs,
        chunked_secs,
    }
}

/// Time a pooled golden-scale re-verification of a small vecadd
/// frontier at `threads` workers (the `tvec dse --verify` hot path).
fn bench_verify(
    smoke: bool,
    seed: u64,
    threads: usize,
    iters: u32,
) -> Result<VerifyBench, String> {
    let paper_n = 1i64 << 20;
    let base = BuildSpec::new(apps::vecadd::build()).bind("N", paper_n).seeded(seed);
    let flops = apps::vecadd::flops(paper_n);
    let widths: &[usize] = if smoke { &[4, 8] } else { &[2, 4, 8, 8] };
    let mut frontier: Vec<Evaluation> = Vec::new();
    for (i, &w) in widths.iter().enumerate() {
        let point = DesignPoint {
            vectorize: Some(("vadd".into(), w)),
            // alternate pumping so the points exercise distinct designs
            pump: if i % 2 == 1 { Some((2, PumpMode::Resource)) } else { None },
            ..DesignPoint::original()
        };
        frontier.push(
            evaluate_point(&base, &point, flops)
                .map_err(|e| format!("verify bench: evaluating V{w}: {}", e.message))?,
        );
    }
    let n = apps::vecadd::GOLDEN_N;
    let golden = BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(seed);
    let mut rng = Rng::new(seed ^ 0x5eed);
    let inputs = vec![
        ("x".to_string(), rng.f32_vec(n as usize)),
        ("y".to_string(), rng.f32_vec(n as usize)),
    ];
    let pool = ArenaPool::default();
    let run = || {
        verify_frontier_pooled(
            &frontier,
            std::slice::from_ref(&golden),
            &inputs,
            DEFAULT_TOLERANCE,
            VerifyBudget::default(),
            &pool,
            threads,
            None,
        )
    };
    run().map_err(|e| format!("verify bench warmup failed: {e}"))?; // warm the pool
    let secs = time_best(iters, || {
        run().expect("checked above");
    });
    Ok(VerifyBench {
        app: "vecadd".to_string(),
        points: frontier.len(),
        threads,
        secs,
    })
}

/// Run the full bench suite. `smoke` shrinks problem sizes and
/// iteration counts to CI scale; `seed` feeds the input generators;
/// `tolerance_override` (the CLI's `--tolerance`) replaces every
/// app's default drift envelope when given; `threads` drives the
/// sharded/verify parallel rows (0 = available parallelism).
pub fn run_bench(
    smoke: bool,
    seed: u64,
    tolerance_override: Option<f64>,
    threads: usize,
) -> Result<BenchReport, String> {
    let iters = if smoke { 2 } else { 5 };
    let workers = resolve_threads(threads);
    let mut rng = Rng::new(seed ^ 0xbe9c);
    let mut sims = Vec::new();
    let mut sharded = Vec::new();
    // one arena across every engine run of every app: the pooled data
    // plane the DSE evaluation loop uses, measured as deployed
    let mut arena = Arena::new();

    // vecadd V8 R2 at golden scale
    {
        let n = apps::vecadd::GOLDEN_N;
        let spec = BuildSpec::new(apps::vecadd::build())
            .vectorized("vadd", 8)
            .pumped(2, PumpMode::Resource)
            .bind("N", n)
            .seeded(seed);
        let inputs = vec![
            ("x".to_string(), rng.f32_vec(n as usize)),
            ("y".to_string(), rng.f32_vec(n as usize)),
        ];
        sims.push(bench_design(
            "vecadd",
            "V8 R2",
            spec.clone(),
            inputs.clone(),
            iters,
            tolerance_override,
            &mut arena,
        )?);
        let k = if smoke { 2 } else { 4 };
        sharded.push(bench_sharded("vecadd", spec, &inputs, k, workers, iters)?);
    }

    // matmul R2 at golden scale (smoke: a quarter-size problem)
    {
        let n = if smoke { 64 } else { apps::matmul::GOLDEN_NMK };
        let mut spec = BuildSpec::new(apps::matmul::build(4))
            .pumped(2, PumpMode::Resource)
            .seeded(seed);
        for (s, v) in apps::matmul::bindings(n) {
            spec = spec.bind(&s, v);
        }
        let inputs = vec![
            ("A".to_string(), rng.f32_vec((n * n) as usize)),
            ("B".to_string(), rng.f32_vec((n * n) as usize)),
        ];
        sims.push(bench_design(
            "matmul",
            "R2",
            spec.clone(),
            inputs.clone(),
            iters,
            tolerance_override,
            &mut arena,
        )?);
        sharded.push(bench_sharded("matmul", spec, &inputs, 2, workers, iters)?);
    }

    // the 16-stage jacobi chain, R4 — the tentpole's headline design
    {
        let stages = 16usize;
        let w = apps::stencil::paper_vec_width(StencilKind::Jacobi3D);
        let (nx, ny, nz) = if smoke {
            (8i64, 16i64, 16i64)
        } else {
            (apps::stencil::GOLDEN_NX, apps::stencil::PAPER_NY, apps::stencil::PAPER_NZ)
        };
        let spec = BuildSpec::new(apps::stencil::build(StencilKind::Jacobi3D, stages, w))
            .pumped(4, PumpMode::Resource)
            .bind("NX", nx)
            .bind("NY", ny)
            .bind("NZ", nz)
            .bind("NZ_v", nz / w as i64)
            .seeded(seed);
        let inputs =
            vec![("v_in".to_string(), rng.f32_vec((nx * ny * nz) as usize))];
        sims.push(bench_design(
            "stencil",
            "S16 R4",
            spec,
            inputs,
            iters,
            tolerance_override,
            &mut arena,
        )?);
    }

    let simd = bench_simd(smoke, &mut rng, iters);
    let verify = bench_verify(smoke, seed, workers, iters)?;

    // cold vs warm DSE sweep over a throwaway persistent cache
    let dse = {
        let dir = std::env::temp_dir().join(format!("tvec-bench-dse-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
        let n = 1i64 << 14;
        let bases = vec![SearchBase {
            spec: BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(seed),
            flops: apps::vecadd::flops(n),
        }];
        let device = Device::u280();
        let opts = SpaceOptions {
            vector_widths: vec![2, 4, 8],
            pump_factors: vec![2, 4],
            pump_modes: vec![PumpMode::Resource],
            max_replicas: 1,
            cl0_requests_mhz: vec![],
            mixed_factors: false,
        };
        let cfg = SearchConfig::exhaustive(Objective::resource());

        let cold_ev = Evaluator::with_cache_dir(&dir);
        let t0 = Instant::now();
        run_search(&cold_ev, &bases, &device, &opts, &cfg)?;
        let cold_secs = t0.elapsed().as_secs_f64();
        let cold_new_compiles = cold_ev.cache_misses();
        let cold_hits = cold_ev.cache_hits();
        cold_ev.flush()?;

        let warm_ev = Evaluator::with_cache_dir(&dir);
        let t0 = Instant::now();
        run_search(&warm_ev, &bases, &device, &opts, &cfg)?;
        let warm_secs = t0.elapsed().as_secs_f64();
        let warm_new_compiles = warm_ev.cache_misses();
        let warm_hits = warm_ev.cache_hits();
        let _ = std::fs::remove_dir_all(&dir);
        DseBench {
            app: "vecadd".to_string(),
            cold_secs,
            warm_secs,
            cold_new_compiles,
            warm_new_compiles,
            cold_hits,
            warm_hits,
        }
    };

    Ok(BenchReport {
        smoke,
        threads: workers,
        sims,
        sharded,
        simd,
        verify,
        arena: arena.stats(),
        dse,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_bench_report_is_well_formed() {
        let r = run_bench(true, 1, None, 2).unwrap();
        assert_eq!(r.sims.len(), 3);
        assert!(r.sims.iter().any(|s| s.app == "stencil"));
        for s in &r.sims {
            assert!(s.slow_cycles > 0, "{}: no cycles simulated", s.app);
            assert!(s.event_secs > 0.0 && s.reference_secs > 0.0);
            assert!(s.rate_cycles > 0);
        }
        assert_eq!(r.threads, 2);
        assert_eq!(r.sharded.len(), 2);
        for s in &r.sharded {
            assert!(s.slow_cycles > 0, "{}: no cycles simulated sharded", s.app);
            assert!(s.serial_secs > 0.0 && s.sharded_secs > 0.0);
            assert_eq!(s.threads, 2);
        }
        assert!(r.simd.scalar_secs > 0.0 && r.simd.chunked_secs > 0.0);
        assert_eq!(r.simd.active, if cfg!(feature = "simd") { "chunked" } else { "scalar" });
        assert_eq!(r.verify.points, 2);
        assert!(r.verify.secs > 0.0);
        assert_eq!(r.arena.leaked, 0, "clean bench runs must leak no arena slots");
        assert_eq!(r.dse.warm_new_compiles, 0, "warm DSE sweep must compile nothing");
        assert!(r.dse.cold_new_compiles > 0);
        assert!(r.dse.warm_hits > 0, "warm sweep must be served from the store");
        assert_eq!(r.dse.warm_hit_rate(), 1.0, "warm hit rate must be perfect");
        // the shared arena must be alive (recycling) and flat across
        // each app's repeated runs — the CI smoke gate's contract
        assert!(r.arena.slots > 0 && r.arena.recycle_hits > 0, "arena wired but dead");
        assert_eq!(r.arena.live, 0, "all transactions must be freed after the runs");
        assert!(r.arena_flat(), "arena high-water mark grew across repeated runs");
        let json = r.to_json();
        for key in [
            "\"schema\": \"tvec-bench-sim v4\"",
            "\"threads\": 2",
            "\"sim\": [",
            "\"event_cycles_per_sec\"",
            "\"speedup\"",
            "\"drift_ratio\"",
            "\"sharded\": [",
            "\"sharded_cycles_per_sec\"",
            "\"serial_cycles_per_sec\"",
            "\"simd\": {",
            "\"verify\": {",
            "\"arena\": {",
            "\"recycle_hits\"",
            "\"leaked\": 0",
            "\"flat_high_water\": true",
            "\"dse\": {",
            "\"warm_new_compiles\": 0",
            "\"dse_cache\": {",
            "\"warm_hit_rate\": 1.0000",
        ] {
            assert!(json.contains(key), "missing {key} in:\n{json}");
        }
        // crude structural validity: balanced braces/brackets
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn json_marks_drift_failures() {
        let row = SimBench {
            app: "vecadd".into(),
            config: "V8 R2".into(),
            slow_cycles: 100,
            event_secs: 0.001,
            reference_secs: 0.01,
            rate_cycles: 200, // 2x drift: outside any sane tolerance
            tolerance: 0.2,
            arena_flat: true,
        };
        assert!(!row.within_tolerance());
        assert!((row.speedup() - 10.0).abs() < 1e-9);
        let report = BenchReport {
            smoke: true,
            threads: 1,
            sims: vec![row],
            sharded: vec![ShardBench {
                app: "vecadd".into(),
                replicas: 2,
                threads: 1,
                slow_cycles: 100,
                serial_secs: 0.002,
                sharded_secs: 0.001,
            }],
            simd: SimdBench {
                active: "scalar",
                lanes: 1024,
                scalar_secs: 0.002,
                chunked_secs: 0.001,
            },
            verify: VerifyBench { app: "vecadd".into(), points: 2, threads: 1, secs: 0.01 },
            arena: ArenaStats::default(),
            dse: DseBench {
                app: "vecadd".into(),
                cold_secs: 1.0,
                warm_secs: 0.1,
                cold_new_compiles: 5,
                warm_new_compiles: 0,
                cold_hits: 0,
                warm_hits: 5,
            },
        };
        let failures = report.drift_failures();
        assert_eq!(failures.len(), 1);
        assert!(failures[0].contains("vecadd"), "{}", failures[0]);
        assert!(report.to_json().contains("\"within_tolerance\": false"));
    }
}
