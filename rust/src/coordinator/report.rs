//! Figure 4 and the paper-vs-measured comparison report.

use crate::apps;
use crate::util::table::{fnum, Table};

use super::experiment::{table2, table3, table4, table5, table6, ExperimentResult};

/// Figure 4: speedup + DSP-efficiency summary (first row) and resource
/// ratios DP/O at fixed configuration (second row; MMM 32 PEs, stencils
/// S=16).
pub fn figure4(seed: u64) -> Result<ExperimentResult, String> {
    let (van, mmn, snx, fwn) = super::experiment::paper_sizes();
    let t2 = table2(van, seed)?;
    let t3 = table3(mmn, seed)?;
    let t4 = table4(snx, seed)?;
    let t5 = table5(snx, seed)?;
    let t6 = table6(fwn, seed)?;

    let find = |r: &ExperimentResult, label: &str| {
        r.rows
            .iter()
            .find(|x| x.label == label)
            .cloned()
            .ok_or_else(|| format!("row '{label}' missing in {}", r.id))
    };

    // best-performing original vs best double-pumped per app
    let mut top = Table::new(
        "Figure 4 (first row): best-performing speedup and DSP efficiency",
        &["app", "best O GOp/s", "best DP GOp/s", "speedup", "O MOp/s/DSP", "DP MOp/s/DSP"],
    );
    let mut rows = Vec::new();
    {
        // MMM: O-32 vs DP-64
        let o = find(&t3, "O 32")?;
        let dp = find(&t3, "DP 64")?;
        top.row(vec![
            "matmul".into(),
            fnum(o.gops, 1),
            fnum(dp.gops, 1),
            fnum(dp.gops / o.gops, 2),
            fnum(o.mops_per_dsp, 1),
            fnum(dp.mops_per_dsp, 1),
        ]);
        rows.push(dp.clone());
        // Jacobi: O-40 vs DP-40
        let o = find(&t4, "S=40 O")?;
        let dp = find(&t4, "S=40 DP")?;
        top.row(vec![
            "jacobi3d".into(),
            fnum(o.gops, 1),
            fnum(dp.gops, 1),
            fnum(dp.gops / o.gops, 2),
            fnum(o.mops_per_dsp, 1),
            fnum(dp.mops_per_dsp, 1),
        ]);
        // Diffusion: O-20 vs DP-40
        let o = find(&t5, "S=20 O")?;
        let dp = find(&t5, "S=40 DP")?;
        top.row(vec![
            "diffusion3d".into(),
            fnum(o.gops, 1),
            fnum(dp.gops, 1),
            fnum(dp.gops / o.gops, 2),
            fnum(o.mops_per_dsp, 1),
            fnum(dp.mops_per_dsp, 1),
        ]);
        // FW: time-based speedup
        let o = find(&t6, "O")?;
        let dp = find(&t6, "DP")?;
        top.row(vec![
            "floyd_warshall".into(),
            fnum(1.0 / o.time_s, 3),
            fnum(1.0 / dp.time_s, 3),
            fnum(o.time_s / dp.time_s, 2),
            "-".into(),
            "-".into(),
        ]);
    }

    // resource ratios DP/O at the same configuration
    let mut bottom = Table::new(
        "Figure 4 (second row): resource ratio DP/O at fixed configuration",
        &["app", "LUT L", "LUT M", "Regs", "BRAM", "DSP"],
    );
    let ratio_row = |name: &str, o: &super::experiment::Row, dp: &super::experiment::Row| {
        vec![
            name.to_string(),
            fnum(dp.util[0] / o.util[0], 2),
            fnum(dp.util[1] / o.util[1], 2),
            fnum(dp.util[2] / o.util[2], 2),
            fnum(dp.util[3] / o.util[3], 2),
            fnum(dp.util[4] / o.util[4], 2),
        ]
    };
    {
        let o = find(&t2, "V=8 O")?;
        let dp = find(&t2, "V=8 DP")?;
        bottom.row(ratio_row("vecadd (V=8)", &o, &dp));
        let o = find(&t3, "O 32")?;
        let dp = find(&t3, "DP 32")?;
        bottom.row(ratio_row("matmul (32 PE)", &o, &dp));
        let o = find(&t4, "S=16 O")?;
        let dp = find(&t4, "S=16 DP")?;
        bottom.row(ratio_row("jacobi3d (S=16)", &o, &dp));
        let o = find(&t5, "S=16 O")?;
        let dp = find(&t5, "S=16 DP")?;
        bottom.row(ratio_row("diffusion3d (S=16)", &o, &dp));
    }

    let rendered = format!("{}\n{}", top.render(), bottom.render());
    Ok(ExperimentResult { id: "fig4".into(), rendered, rows })
}

/// Paper-vs-measured side-by-side for EXPERIMENTS.md (Table 6 example;
/// the full comparison is assembled by `tvec experiment all`).
pub fn paper_comparison_fw(measured: &ExperimentResult) -> String {
    let mut t = Table::new(
        "Floyd–Warshall: paper vs measured",
        &["variant", "paper CL0", "ours CL0", "paper time", "ours time"],
    );
    for (i, (label, cl0, _cl1, time, ..)) in apps::floyd_warshall::PAPER_TABLE6
        .iter()
        .map(|r| (r.0, r.1, r.2, r.3, r.4))
        .enumerate()
    {
        if let Some(m) = measured.rows.get(i) {
            t.row(vec![
                label.to_string(),
                fnum(cl0, 1),
                fnum(m.cl0_mhz, 1),
                fnum(time, 2),
                fnum(m.time_s, 2),
            ]);
        }
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure4_renders_both_rows() {
        let f = figure4(5).unwrap();
        assert!(f.rendered.contains("speedup"));
        assert!(f.rendered.contains("resource ratio"));
        for app in ["matmul", "jacobi3d", "diffusion3d", "floyd_warshall", "vecadd"] {
            assert!(f.rendered.contains(app), "missing {app}\n{}", f.rendered);
        }
    }
}
