//! Figure 4, the paper-vs-measured comparison report, and the
//! `tvec top` stall-source report over captured telemetry.

use crate::apps;
use crate::telemetry::{top_stalls, Recorder};
use crate::util::table::{fnum, Table};

use super::experiment::{table2, table3, table4, table5, table6, ExperimentResult};

/// Figure 4: speedup + DSP-efficiency summary (first row) and resource
/// ratios DP/O at fixed configuration (second row; MMM 32 PEs, stencils
/// S=16).
pub fn figure4(seed: u64) -> Result<ExperimentResult, String> {
    let (van, mmn, snx, fwn) = super::experiment::paper_sizes();
    let t2 = table2(van, seed)?;
    let t3 = table3(mmn, seed)?;
    let t4 = table4(snx, seed)?;
    let t5 = table5(snx, seed)?;
    let t6 = table6(fwn, seed)?;

    let find = |r: &ExperimentResult, label: &str| {
        r.rows
            .iter()
            .find(|x| x.label == label)
            .cloned()
            .ok_or_else(|| format!("row '{label}' missing in {}", r.id))
    };

    // best-performing original vs best double-pumped per app
    let mut top = Table::new(
        "Figure 4 (first row): best-performing speedup and DSP efficiency",
        &["app", "best O GOp/s", "best DP GOp/s", "speedup", "O MOp/s/DSP", "DP MOp/s/DSP"],
    );
    let mut rows = Vec::new();
    {
        // MMM: O-32 vs DP-64
        let o = find(&t3, "O 32")?;
        let dp = find(&t3, "DP 64")?;
        top.row(vec![
            "matmul".into(),
            fnum(o.gops, 1),
            fnum(dp.gops, 1),
            fnum(dp.gops / o.gops, 2),
            fnum(o.mops_per_dsp, 1),
            fnum(dp.mops_per_dsp, 1),
        ]);
        rows.push(dp.clone());
        // Jacobi: O-40 vs DP-40
        let o = find(&t4, "S=40 O")?;
        let dp = find(&t4, "S=40 DP")?;
        top.row(vec![
            "jacobi3d".into(),
            fnum(o.gops, 1),
            fnum(dp.gops, 1),
            fnum(dp.gops / o.gops, 2),
            fnum(o.mops_per_dsp, 1),
            fnum(dp.mops_per_dsp, 1),
        ]);
        // Diffusion: O-20 vs DP-40
        let o = find(&t5, "S=20 O")?;
        let dp = find(&t5, "S=40 DP")?;
        top.row(vec![
            "diffusion3d".into(),
            fnum(o.gops, 1),
            fnum(dp.gops, 1),
            fnum(dp.gops / o.gops, 2),
            fnum(o.mops_per_dsp, 1),
            fnum(dp.mops_per_dsp, 1),
        ]);
        // FW: time-based speedup
        let o = find(&t6, "O")?;
        let dp = find(&t6, "DP")?;
        top.row(vec![
            "floyd_warshall".into(),
            fnum(1.0 / o.time_s, 3),
            fnum(1.0 / dp.time_s, 3),
            fnum(o.time_s / dp.time_s, 2),
            "-".into(),
            "-".into(),
        ]);
    }

    // resource ratios DP/O at the same configuration
    let mut bottom = Table::new(
        "Figure 4 (second row): resource ratio DP/O at fixed configuration",
        &["app", "LUT L", "LUT M", "Regs", "BRAM", "DSP"],
    );
    let ratio_row = |name: &str, o: &super::experiment::Row, dp: &super::experiment::Row| {
        vec![
            name.to_string(),
            fnum(dp.util[0] / o.util[0], 2),
            fnum(dp.util[1] / o.util[1], 2),
            fnum(dp.util[2] / o.util[2], 2),
            fnum(dp.util[3] / o.util[3], 2),
            fnum(dp.util[4] / o.util[4], 2),
        ]
    };
    {
        let o = find(&t2, "V=8 O")?;
        let dp = find(&t2, "V=8 DP")?;
        bottom.row(ratio_row("vecadd (V=8)", &o, &dp));
        let o = find(&t3, "O 32")?;
        let dp = find(&t3, "DP 32")?;
        bottom.row(ratio_row("matmul (32 PE)", &o, &dp));
        let o = find(&t4, "S=16 O")?;
        let dp = find(&t4, "S=16 DP")?;
        bottom.row(ratio_row("jacobi3d (S=16)", &o, &dp));
        let o = find(&t5, "S=16 O")?;
        let dp = find(&t5, "S=16 DP")?;
        bottom.row(ratio_row("diffusion3d (S=16)", &o, &dp));
    }

    let rendered = format!("{}\n{}", top.render(), bottom.render());
    Ok(ExperimentResult { id: "fig4".into(), rendered, rows })
}

/// Paper-vs-measured side-by-side for EXPERIMENTS.md (Table 6 example;
/// the full comparison is assembled by `tvec experiment all`).
pub fn paper_comparison_fw(measured: &ExperimentResult) -> String {
    let mut t = Table::new(
        "Floyd–Warshall: paper vs measured",
        &["variant", "paper CL0", "ours CL0", "paper time", "ours time"],
    );
    for (i, (label, cl0, _cl1, time, ..)) in apps::floyd_warshall::PAPER_TABLE6
        .iter()
        .map(|r| (r.0, r.1, r.2, r.3, r.4))
        .enumerate()
    {
        if let Some(m) = measured.rows.get(i) {
            t.row(vec![
                label.to_string(),
                fnum(cl0, 1),
                fnum(m.cl0_mhz, 1),
                fnum(time, 2),
                fnum(m.time_s, 2),
            ]);
        }
    }
    t.render()
}

/// `tvec top`: render the top-`k` stall sources captured by an
/// observed exact simulation — module stall counters and per-channel
/// FIFO stall causes (backpressure vs starvation), ranked by count —
/// followed by the per-clock-domain utilization gauges, which show
/// which domain the stalls are starving.
pub fn stall_report(rec: &Recorder, k: usize) -> String {
    let mut t = Table::new(
        format!("top {k} stall sources"),
        &["source", "kind", "count"],
    );
    let ranked = top_stalls(rec, k);
    if ranked.is_empty() {
        t.row(vec!["(no stalls recorded)".into(), "-".into(), "0".into()]);
    }
    for (name, count) in ranked {
        let kind = if name.ends_with(".full_on_push") {
            "backpressure (full on push)"
        } else if name.ends_with(".empty_on_pop") {
            "starvation (empty on pop)"
        } else {
            "module stall"
        };
        let source = name
            .trim_start_matches("sim.module.")
            .trim_start_matches("sim.fifo.")
            .trim_end_matches(".stalls")
            .trim_end_matches(".full_on_push")
            .trim_end_matches(".empty_on_pop")
            .to_string();
        t.row(vec![source, kind.into(), count.to_string()]);
    }
    let mut out = t.render();
    let domains: Vec<(String, f64)> = rec
        .gauges()
        .into_iter()
        .filter(|(name, _)| name.starts_with("sim.domain.") && name.ends_with(".utilization"))
        .collect();
    if !domains.is_empty() {
        let mut dt = Table::new("per-clock-domain utilization", &["domain", "busy"]);
        for (name, v) in domains {
            let label = name
                .trim_start_matches("sim.domain.")
                .trim_end_matches(".utilization")
                .to_string();
            dt.row(vec![label, format!("{}%", fnum(v * 100.0, 1))]);
        }
        out.push('\n');
        out.push_str(&dt.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stall_report_ranks_sources_and_shows_domains() {
        let rec = Recorder::new();
        rec.add("sim.module.vadd.stalls", 7);
        rec.add("sim.fifo.s_x.empty_on_pop", 40);
        rec.add("sim.fifo.s_z.full_on_push", 12);
        rec.gauge("sim.domain.cl0.utilization", 0.5);
        rec.gauge("sim.domain.cl1_m2.utilization", 0.25);
        let r = stall_report(&rec, 2);
        assert!(r.contains("s_x"), "{r}");
        assert!(r.contains("starvation"), "{r}");
        // k = 2 truncates: the module stall (count 7) is cut
        assert!(!r.contains("module stall"), "{r}");
        assert!(r.contains("cl0"), "{r}");
        assert!(r.contains("cl1_m2"), "{r}");
    }

    #[test]
    fn stall_report_is_defined_on_an_empty_recorder() {
        let r = stall_report(&Recorder::new(), 5);
        assert!(r.contains("no stalls recorded"), "{r}");
    }

    #[test]
    fn figure4_renders_both_rows() {
        let f = figure4(5).unwrap();
        assert!(f.rendered.contains("speedup"));
        assert!(f.rendered.contains("resource ratio"));
        for app in ["matmul", "jacobi3d", "diffusion3d", "floyd_warshall", "vecadd"] {
            assert!(f.rendered.contains(app), "missing {app}\n{}", f.rendered);
        }
    }
}
