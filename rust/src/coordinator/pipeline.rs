//! The compilation pipeline: SDFG → transforms → netlist → pricing.
//!
//! The single entry point every experiment, example and test drives.

use std::sync::Arc;

use crate::codegen::{estimate, lower, Design, DesignReport};
use crate::hw::cost::CostModel;
use crate::hw::{Device, TimingModel};
use crate::ir::{printer, PumpMode, RegionPump, Sdfg};
use crate::symbolic::SymbolTable;
use crate::transforms::pass::TransformReport;
use crate::transforms::{MultiPump, PassManager, StreamingComposition, Vectorize};
use crate::util::{fnv1a, FNV_OFFSET};

/// What to build and how.
///
/// The base graph is `Arc`-shared: cloning a spec — which the dse
/// evaluator does once per candidate, per halving fidelity seed, per
/// grid generation — bumps a reference count instead of deep-copying
/// the SDFG. The only full-graph clone left on a candidate's path is
/// the one the (cached, shared) transform prefix hands to
/// [`compile_from_prefix`], because transforms mutate in place.
#[derive(Clone)]
pub struct BuildSpec {
    /// The shared base graph. Crate-visible only: swapping it after
    /// construction would leave the cached `sdfg_fnv` stale and poison
    /// every content-hash key (fingerprints, the prefix cache) — build
    /// a fresh spec via [`BuildSpec::new`]/[`BuildSpec::shared`]
    /// instead. External callers read it through [`BuildSpec::sdfg`].
    pub(crate) sdfg: Arc<Sdfg>,
    /// Apply traditional vectorization to a named map first.
    pub vectorize: Option<(String, usize)>,
    /// Apply the streaming composition (required before pumping).
    pub stream: bool,
    /// Apply multi-pumping (factor, mode) over the whole streamed
    /// subgraph — the paper's §3.4 choice.
    pub pump: Option<(usize, PumpMode)>,
    /// Apply *mixed* multi-pumping: one `{factor, mode}` pump per
    /// streamable region (partition order; `None` entries stay in
    /// CL0). Mutually exclusive with `pump`.
    pub pump_regions: Option<Vec<Option<RegionPump>>>,
    /// Concrete symbol bindings.
    pub bindings: Vec<(String, i64)>,
    /// Shell clock request override (MHz).
    pub cl0_request_mhz: Option<f64>,
    /// Replicate the design across SLRs (paper §4.2's 3-SLR run).
    pub slr_replicas: usize,
    /// P&R jitter seed.
    pub seed: u64,
    /// FNV-1a of the printed base graph, computed once at
    /// construction. Content-hash keys (the dse fingerprint, the
    /// prefix cache) chain from this instead of re-printing the whole
    /// SDFG per candidate — printing dominated warm-cache sweeps.
    sdfg_fnv: u64,
}

impl BuildSpec {
    pub fn new(sdfg: Sdfg) -> Self {
        BuildSpec::shared(Arc::new(sdfg))
    }

    /// Build a spec over an already-shared graph (several bases over
    /// one SDFG share both the graph and its print hash).
    pub fn shared(sdfg: Arc<Sdfg>) -> Self {
        let sdfg_fnv = fnv1a(FNV_OFFSET, printer::to_text(&sdfg).as_bytes());
        BuildSpec {
            sdfg,
            vectorize: None,
            stream: true,
            pump: None,
            pump_regions: None,
            bindings: Vec::new(),
            cl0_request_mhz: None,
            slr_replicas: 1,
            seed: 1,
            sdfg_fnv,
        }
    }

    /// The shared base graph.
    pub fn sdfg(&self) -> &Sdfg {
        &self.sdfg
    }

    /// Content hash of the printed base graph (see the field docs).
    pub fn sdfg_fnv(&self) -> u64 {
        self.sdfg_fnv
    }

    pub fn vectorized(mut self, map: &str, factor: usize) -> Self {
        self.vectorize = Some((map.to_string(), factor));
        self
    }

    pub fn pumped(mut self, factor: usize, mode: PumpMode) -> Self {
        self.pump = Some((factor, mode));
        self
    }

    /// Mixed per-region resource-mode pumping (one factor per
    /// streamable region, `None` = stay in CL0) — the historic
    /// convenience; see [`BuildSpec::pumped_per_region`] for modes.
    pub fn pumped_regions(mut self, factors: Vec<Option<usize>>) -> Self {
        self.pump_regions =
            Some(factors.into_iter().map(|f| f.map(RegionPump::resource)).collect());
        self
    }

    /// Fully general mixed pumping: one `{factor, mode}` per
    /// streamable region, `None` = stay in CL0.
    pub fn pumped_per_region(mut self, pumps: Vec<Option<RegionPump>>) -> Self {
        self.pump_regions = Some(pumps);
        self
    }

    pub fn bind(mut self, sym: &str, v: i64) -> Self {
        self.bindings.push((sym.to_string(), v));
        self
    }

    pub fn cl0(mut self, mhz: f64) -> Self {
        self.cl0_request_mhz = Some(mhz);
        self
    }

    pub fn replicas(mut self, n: usize) -> Self {
        self.slr_replicas = n;
        self
    }

    pub fn seeded(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// A fully compiled and priced design.
pub struct Compiled {
    pub sdfg: Sdfg,
    pub design: Design,
    pub report: DesignReport,
    pub env: SymbolTable,
    pub pass_log: Vec<String>,
}

/// Which pipeline stage rejected a spec. Transform and Bind failures
/// are *legality* rejections (an illegal candidate, e.g. a factor that
/// does not divide); Lower failures are genuine compile errors. The
/// `dse` evaluator caches failures under this classification.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    Transform,
    Bind,
    Lower,
}

/// A pipeline failure tagged with the stage that produced it.
#[derive(Clone, Debug, PartialEq)]
pub struct StagedError {
    pub stage: Stage,
    pub message: String,
}

impl std::fmt::Display for StagedError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

/// The transformed-but-unpumped front of the pipeline: the base graph
/// after vectorization and streaming. Every candidate that agrees on
/// those two choices lowers from the same prefix — the dse evaluator
/// caches these behind an `Arc` so a sweep re-runs the (expensive)
/// vectorize/stream rewrites once per distinct prefix instead of once
/// per candidate.
pub struct StagedPrefix {
    pub sdfg: Sdfg,
    pub reports: Vec<TransformReport>,
}

/// Run the vectorize + streaming front of the pipeline on a base
/// graph. Clones the graph once (transforms mutate in place).
pub fn stage_prefix(
    sdfg: &Sdfg,
    vectorize: &Option<(String, usize)>,
    stream: bool,
) -> Result<StagedPrefix, StagedError> {
    stage_prefix_observed(sdfg, vectorize, stream, None)
}

/// [`stage_prefix`] with an optional telemetry recorder: each applied
/// transform gets its own span (`vectorize`, `stream`).
pub fn stage_prefix_observed(
    sdfg: &Sdfg,
    vectorize: &Option<(String, usize)>,
    stream: bool,
    rec: Option<&crate::telemetry::Recorder>,
) -> Result<StagedPrefix, StagedError> {
    let err = |stage: Stage| move |message: String| StagedError { stage, message };
    let mut g = sdfg.clone();
    let mut pm = PassManager::new();
    if let Some((map, factor)) = vectorize {
        let mut sp = rec.map(|r| r.span("vectorize"));
        if let Some(s) = sp.as_mut() {
            s.note("map", map);
            s.note("width", factor);
        }
        pm.run(&mut g, &Vectorize::new(map, *factor)).map_err(err(Stage::Transform))?;
    }
    if stream {
        let _sp = rec.map(|r| r.span("stream"));
        pm.run(&mut g, &StreamingComposition::default()).map_err(err(Stage::Transform))?;
    }
    Ok(StagedPrefix { sdfg: g, reports: pm.reports })
}

/// Finish the pipeline from a shared prefix: pump, bind, lower, price.
/// `compile_staged(spec)` ≡ `compile_from_prefix(&stage_prefix(..), &spec)`
/// by construction — the two entry points share this body.
pub fn compile_from_prefix(
    prefix: &StagedPrefix,
    spec: &BuildSpec,
) -> Result<Compiled, StagedError> {
    compile_from_prefix_observed(prefix, spec, None)
}

/// [`compile_from_prefix`] with an optional telemetry recorder: one
/// span per stage (`pump` when pumping is requested, then `bind`,
/// `lower`, `estimate`).
pub fn compile_from_prefix_observed(
    prefix: &StagedPrefix,
    spec: &BuildSpec,
    rec: Option<&crate::telemetry::Recorder>,
) -> Result<Compiled, StagedError> {
    let err = |stage: Stage| move |message: String| StagedError { stage, message };
    let device = Device::u280();
    let tm = TimingModel::default();
    let cost = CostModel::default();
    let mut g = prefix.sdfg.clone();
    let mut pm = PassManager::new();
    pm.reports = prefix.reports.clone();

    if let Some(factors) = &spec.pump_regions {
        if spec.pump.is_some() {
            return Err(StagedError {
                stage: Stage::Transform,
                message: "both uniform and per-region pumping requested".into(),
            });
        }
        if !spec.stream {
            return Err(StagedError {
                stage: Stage::Transform,
                message: "multi-pumping requires streaming".into(),
            });
        }
        let mut sp = rec.map(|r| r.span("pump"));
        if let Some(s) = sp.as_mut() {
            s.note("regions", factors.len());
        }
        pm.run(&mut g, &MultiPump::per_region(factors.clone()))
            .map_err(err(Stage::Transform))?;
    } else if let Some((factor, mode)) = spec.pump {
        if !spec.stream {
            return Err(StagedError {
                stage: Stage::Transform,
                message: "multi-pumping requires streaming".into(),
            });
        }
        let mut sp = rec.map(|r| r.span("pump"));
        if let Some(s) = sp.as_mut() {
            s.note("factor", factor);
            s.note("mode", format!("{mode:?}"));
        }
        pm.run(&mut g, &MultiPump::uniform(factor, mode)).map_err(err(Stage::Transform))?;
    }

    let base: Vec<(&str, i64)> = spec.bindings.iter().map(|(s, v)| (s.as_str(), *v)).collect();
    let env = {
        let _sp = rec.map(|r| r.span("bind"));
        g.bind(&base).map_err(err(Stage::Bind))?
    };
    let mut design = {
        let _sp = rec.map(|r| r.span("lower"));
        lower(&g, &env, &cost).map_err(err(Stage::Lower))?
    };
    design.cl0_request_mhz = spec.cl0_request_mhz;
    design.slr_replicas = spec.slr_replicas;
    let report = {
        let _sp = rec.map(|r| r.span("estimate"));
        estimate(&design, &device, &tm, spec.seed)
    };
    let pass_log = pm.reports.iter().map(|r| format!("{}: {}", r.transform, r.summary)).collect();
    Ok(Compiled { sdfg: g, design, report, env, pass_log })
}

/// Run the pipeline.
pub fn compile(spec: BuildSpec) -> Result<Compiled, String> {
    compile_staged(spec).map_err(|e| e.message)
}

/// Run the pipeline, reporting *which stage* rejected the spec.
pub fn compile_staged(spec: BuildSpec) -> Result<Compiled, StagedError> {
    compile_staged_observed(spec, None)
}

/// [`compile_staged`] with an optional telemetry recorder: the full
/// stage-span set (`vectorize`/`stream`/`pump`/`bind`/`lower`/
/// `estimate`) on one uncached compile.
pub fn compile_staged_observed(
    spec: BuildSpec,
    rec: Option<&crate::telemetry::Recorder>,
) -> Result<Compiled, StagedError> {
    let prefix = stage_prefix_observed(&spec.sdfg, &spec.vectorize, spec.stream, rec)?;
    compile_from_prefix_observed(&prefix, &spec, rec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;

    #[test]
    fn full_pipeline_vecadd_dp() {
        let c = compile(
            BuildSpec::new(apps::vecadd::build())
                .vectorized("vadd", 8)
                .pumped(2, PumpMode::Resource)
                .bind("N", 1 << 16),
        )
        .unwrap();
        assert_eq!(c.report.pump_factor, 2);
        assert!(c.report.cl1.is_some());
        assert_eq!(c.pass_log.len(), 3);
        assert!(c.design.pump.is_some());
    }

    #[test]
    fn pump_without_stream_rejected() {
        let err = compile(
            BuildSpec::new(apps::vecadd::build())
                .vectorized("vadd", 4)
                .pumped(2, PumpMode::Resource)
                .bind("N", 1024),
        );
        // stream defaults to true; explicitly disable
        let mut spec = BuildSpec::new(apps::vecadd::build()).vectorized("vadd", 4);
        spec.stream = false;
        spec = spec.pumped(2, PumpMode::Resource).bind("N", 1024);
        assert!(compile(spec).is_err());
        assert!(err.is_ok());
    }

    #[test]
    fn fw_pipeline_throughput_mode() {
        let c = compile(
            BuildSpec::new(apps::floyd_warshall::build())
                .pumped(2, PumpMode::Throughput)
                .bind("N", 64)
                .cl0(apps::floyd_warshall::CL0_REQUEST_MHZ),
        )
        .unwrap();
        assert_eq!(c.design.repeat, 64);
        let cl1 = c.report.cl1.unwrap();
        assert!(cl1.achieved_mhz > c.report.cl0.achieved_mhz);
    }

    #[test]
    fn mixed_region_pipeline_builds_two_fast_domains() {
        // 4-stage jacobi chain, first half at M=4, second half at M=2:
        // the report carries the largest factor, CL1 exists, and the
        // effective clock is bounded by the slowest domain ratio
        let spec = BuildSpec::new(apps::stencil::build(
            crate::ir::StencilKind::Jacobi3D,
            4,
            8,
        ))
        .pumped_regions(vec![Some(4), Some(4), Some(2), Some(2)])
        .bind("NX", 64)
        .bind("NY", 32)
        .bind("NZ", 32)
        .bind("NZ_v", 4);
        let c = compile(spec).unwrap();
        assert_eq!(c.report.pump_factor, 4);
        assert!(c.report.cl1.is_some());
        let cl1 = c.report.cl1.unwrap();
        assert!(c.report.effective_mhz <= cl1.achieved_mhz / 2.0 + 1e-9);
        assert!(c.design.modules.iter().any(|m| {
            m.domain == crate::ir::ClockDomain::Fast { factor: 4 }
        }));
        assert!(c.design.modules.iter().any(|m| {
            m.domain == crate::ir::ClockDomain::Fast { factor: 2 }
        }));
    }

    #[test]
    fn uniform_and_per_region_pumping_are_exclusive() {
        let mut spec = BuildSpec::new(apps::stencil::build(
            crate::ir::StencilKind::Jacobi3D,
            2,
            8,
        ))
        .pumped(2, PumpMode::Resource)
        .bind("NX", 64)
        .bind("NY", 32)
        .bind("NZ", 32)
        .bind("NZ_v", 4);
        spec.pump_regions = Some(vec![Some(RegionPump::resource(2)), Some(RegionPump::resource(2))]);
        let err = compile_staged(spec).unwrap_err();
        assert_eq!(err.stage, Stage::Transform);
        assert!(err.message.contains("both uniform and per-region"), "{}", err.message);
    }

    #[test]
    fn prefix_split_is_equivalent_to_full_compile() {
        let spec = BuildSpec::new(apps::vecadd::build())
            .vectorized("vadd", 8)
            .pumped(2, PumpMode::Resource)
            .bind("N", 1 << 12);
        let prefix = stage_prefix(&spec.sdfg, &spec.vectorize, spec.stream).unwrap();
        let split = compile_from_prefix(&prefix, &spec).unwrap();
        let whole = compile_staged(spec).unwrap();
        assert_eq!(
            crate::ir::printer::to_text(&whole.sdfg),
            crate::ir::printer::to_text(&split.sdfg),
            "prefix-split compile produced a different graph"
        );
        assert_eq!(whole.pass_log, split.pass_log);
        assert_eq!(whole.report.resources, split.report.resources);
        assert_eq!(whole.report.cl0.achieved_mhz, split.report.cl0.achieved_mhz);
        assert_eq!(whole.report.effective_mhz, split.report.effective_mhz);
    }

    #[test]
    fn cloned_specs_share_one_base_graph() {
        // zero-copy invariant: a spec clone (one per dse candidate)
        // bumps the Arc instead of deep-copying the SDFG
        let spec = BuildSpec::new(apps::vecadd::build()).bind("N", 64);
        let clone = spec.clone();
        assert!(std::sync::Arc::ptr_eq(&spec.sdfg, &clone.sdfg));
        assert_eq!(spec.sdfg_fnv(), clone.sdfg_fnv());
        // content-identical graphs built twice still hash identically
        let rebuilt = BuildSpec::new(apps::vecadd::build());
        assert_eq!(spec.sdfg_fnv(), rebuilt.sdfg_fnv());
    }

    #[test]
    fn gemm_pipeline_resource_mode() {
        let n = 256i64;
        let c = compile(
            BuildSpec::new(apps::matmul::build(4))
                .pumped(2, PumpMode::Resource)
                .bind("N", n)
                .bind("M", n)
                .bind("K", n)
                .bind("K_v", n / 16)
                .bind("M_v", n / 16),
        )
        .unwrap();
        // resource mode halves the systolic lanes: DSP halved vs unpumped
        let o = compile(
            BuildSpec::new(apps::matmul::build(4))
                .bind("N", n)
                .bind("M", n)
                .bind("K", n)
                .bind("K_v", n / 16)
                .bind("M_v", n / 16),
        )
        .unwrap();
        let ratio = c.report.resources.dsp / o.report.resources.dsp;
        assert!((ratio - 0.5).abs() < 0.05, "dsp ratio {ratio}");
    }
}
