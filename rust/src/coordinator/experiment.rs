//! Experiment runner: regenerates every table and figure of the
//! paper's evaluation section (per-experiment index in DESIGN.md §5).

use crate::apps;
use crate::hw::Device;
use crate::ir::{PumpMode, StencilKind};
use crate::sim::rate_model;
use crate::util::table::{fnum, pct, Table};

use super::pipeline::{compile, BuildSpec, Compiled};

/// One measured variant row.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub cl0_mhz: f64,
    pub cl1_mhz: Option<f64>,
    pub effective_mhz: f64,
    pub time_s: f64,
    pub gops: f64,
    /// LUT logic, LUT memory, registers, BRAM, DSP percentages.
    pub util: [f64; 5],
    pub dsp_count: f64,
    pub mops_per_dsp: f64,
}

/// A regenerated table/figure.
#[derive(Clone, Debug)]
pub struct ExperimentResult {
    pub id: String,
    pub rendered: String,
    pub rows: Vec<Row>,
}

fn mk_row(label: &str, c: &Compiled, flops: f64, extra_replicas: usize) -> Row {
    let stats = rate_model(&c.design);
    let eff = c.report.effective_mhz;
    let time = stats.seconds_at(eff);
    let replicas = extra_replicas.max(1) as f64;
    let gops = flops * replicas / time / 1e9;
    let dsp_count = c.report.resources.dsp;
    Row {
        label: label.to_string(),
        cl0_mhz: c.report.cl0.achieved_mhz,
        cl1_mhz: c.report.cl1.map(|r| r.achieved_mhz),
        effective_mhz: eff,
        time_s: time,
        gops,
        util: c.report.util_percent(),
        dsp_count,
        mops_per_dsp: if dsp_count > 0.0 { gops * 1000.0 / dsp_count } else { 0.0 },
    }
}

fn freq_cell(v: Option<f64>) -> String {
    v.map(|x| fnum(x, 1)).unwrap_or_else(|| "-".into())
}

/// Table 1: resources of a single SLR (device model ground truth).
pub fn table1() -> ExperimentResult {
    let d = Device::u280();
    let p = d.slr0_pool();
    let mut t = Table::new(
        "Table 1: Resources available for a single SLR (SLR0) of the Xilinx U280",
        &["LUT Logic", "LUT Memory", "Registers", "BRAM", "DSPs"],
    );
    t.row(vec![
        format!("{:.0} K", p.lut_logic / 1000.0),
        format!("{:.0} K", p.lut_memory / 1000.0),
        format!("{:.0} K", p.registers / 1000.0),
        format!("{:.0}", p.bram),
        format!("{:.0}", p.dsp),
    ]);
    ExperimentResult { id: "table1".into(), rendered: t.render(), rows: vec![] }
}

/// Table 2: vector addition, V ∈ {2, 4, 8}, Original vs Double-Pumped.
pub fn table2(n: i64, seed: u64) -> Result<ExperimentResult, String> {
    let mut rows = Vec::new();
    for &v in &[2usize, 4, 8] {
        let o = compile(
            BuildSpec::new(apps::vecadd::build())
                .vectorized("vadd", v)
                .bind("N", n)
                .seeded(seed),
        )?;
        rows.push(mk_row(&format!("V={v} O"), &o, apps::vecadd::flops(n), 1));
        let dp = compile(
            BuildSpec::new(apps::vecadd::build())
                .vectorized("vadd", v)
                .pumped(2, PumpMode::Resource)
                .bind("N", n)
                .seeded(seed),
        )?;
        rows.push(mk_row(&format!("V={v} DP"), &dp, apps::vecadd::flops(n), 1));
    }
    let mut t = Table::new(
        format!("Table 2: Vector addition (N = 2^{})", (n as f64).log2() as u32),
        &["", "Freq CL0", "Freq CL1", "Time [s]", "LUT L%", "LUT M%", "Regs%", "BRAM%", "DSP%"],
    );
    for r in &rows {
        t.row(vec![
            r.label.clone(),
            fnum(r.cl0_mhz, 1),
            freq_cell(r.cl1_mhz),
            fnum(r.time_s, 4),
            pct(r.util[0]),
            pct(r.util[1]),
            pct(r.util[2]),
            pct(r.util[3]),
            pct(r.util[4]),
        ]);
    }
    t.footnote("paper: DSP halves under DP; LUT/Reg overhead < 1 %; time unchanged");
    Ok(ExperimentResult { id: "table2".into(), rendered: t.render(), rows })
}

/// Table 3: matrix multiplication — CA baseline, DaCe original, and
/// double-pumped at 32/48/64 PEs, plus the 3-SLR replication row.
pub fn table3(nmk: i64, seed: u64) -> Result<ExperimentResult, String> {
    let flops = apps::matmul::flops(nmk, nmk, nmk);
    let mut rows = Vec::new();

    // hand-written HLS baseline [10]: same netlist, 250 MHz request
    let mut ca_spec = BuildSpec::new(apps::matmul::ca_baseline(32)).cl0(255.0).seeded(seed);
    for (s, v) in apps::matmul::bindings(nmk) {
        ca_spec = ca_spec.bind(&s, v);
    }
    let ca = compile(ca_spec)?;
    rows.push(mk_row("CA 32", &ca, flops, 1));

    let mut o_spec = BuildSpec::new(apps::matmul::build(32)).cl0(270.0).seeded(seed);
    for (s, v) in apps::matmul::bindings(nmk) {
        o_spec = o_spec.bind(&s, v);
    }
    let o = compile(o_spec)?;
    rows.push(mk_row("O 32", &o, flops, 1));

    for &pes in &[32usize, 48, 64] {
        let mut spec = BuildSpec::new(apps::matmul::build(pes))
            .pumped(2, PumpMode::Resource)
            .cl0(270.0)
            .seeded(seed);
        for (s, v) in apps::matmul::bindings(nmk) {
            spec = spec.bind(&s, v);
        }
        let dp = compile(spec)?;
        rows.push(mk_row(&format!("DP {pes}"), &dp, flops, 1));
    }

    // 3-SLR replication of the 64-PE DP version (§4.2)
    let mut spec3 = BuildSpec::new(apps::matmul::build(64))
        .pumped(2, PumpMode::Resource)
        .cl0(270.0)
        .replicas(3)
        .seeded(seed);
    for (s, v) in apps::matmul::bindings(nmk) {
        spec3 = spec3.bind(&s, v);
    }
    let dp3 = compile(spec3)?;
    rows.push(mk_row("DP 64 x3SLR", &dp3, flops, 3));

    let mut t = Table::new(
        format!("Table 3: Matrix multiplication ({nmk}^3, f32, vec width 16)"),
        &[
            "", "Freq CL0", "Freq CL1", "Perf GOp/s", "LUT L%", "LUT M%", "Regs%", "BRAM%",
            "DSP%", "MOp/s/DSP",
        ],
    );
    for r in &rows {
        t.row(vec![
            r.label.clone(),
            fnum(r.cl0_mhz, 1),
            freq_cell(r.cl1_mhz),
            fnum(r.gops, 1),
            pct(r.util[0]),
            pct(r.util[1]),
            pct(r.util[2]),
            pct(r.util[3]),
            pct(r.util[4]),
            fnum(r.mops_per_dsp, 1),
        ]);
    }
    t.footnote("paper: DP-32 ≈50 % DSP / ≈58 % BRAM of O-32; DP-64 +15 % over CA");
    Ok(ExperimentResult { id: "table3".into(), rendered: t.render(), rows })
}

fn stencil_table(
    kind: StencilKind,
    // (S, O vec width or 0 to skip, DP vec width or 0 to skip).
    // Large chains only fit the SLR for the ORIGINAL version at halved
    // vectorization width — the DSP columns of Tables 4/5 (S=40 at
    // 72.2 % / 83.3 %) only close that way; the double-pumped version
    // keeps the full external width. This is precisely the paper's
    // "freed resources allow further scaling" mechanism.
    stages_list: &[(usize, usize, usize)],
    nx: i64,
    seed: u64,
    id: &str,
    title: &str,
) -> Result<ExperimentResult, String> {
    let (ny, nz) = (apps::stencil::PAPER_NY, apps::stencil::PAPER_NZ);
    let mut rows = Vec::new();
    for &(s, w_o, w_dp) in stages_list {
        let flops = apps::stencil::flops(kind, nx, ny, nz, s);
        if w_o > 0 {
            let c = compile(
                BuildSpec::new(apps::stencil::build(kind, s, w_o))
                    .bind("NX", nx)
                    .bind("NY", ny)
                    .bind("NZ", nz)
                    .bind("NZ_v", nz / w_o as i64)
                    .cl0(315.0)
                    .seeded(seed),
            )?;
            rows.push(mk_row(&format!("S={s} O"), &c, flops, 1));
        }
        if w_dp > 0 {
            let c = compile(
                BuildSpec::new(apps::stencil::build(kind, s, w_dp))
                    .pumped(2, PumpMode::Resource)
                    .bind("NX", nx)
                    .bind("NY", ny)
                    .bind("NZ", nz)
                    .bind("NZ_v", nz / w_dp as i64)
                    .cl0(315.0)
                    .seeded(seed),
            )?;
            rows.push(mk_row(&format!("S={s} DP"), &c, flops, 1));
        }
    }
    let mut t = Table::new(
        title.to_string(),
        &[
            "", "Freq CL0", "Freq CL1", "Perf GOp/s", "LUT L%", "LUT M%", "Regs%", "BRAM%",
            "DSP%", "MOp/s/DSP",
        ],
    );
    for r in &rows {
        t.row(vec![
            r.label.clone(),
            fnum(r.cl0_mhz, 1),
            freq_cell(r.cl1_mhz),
            fnum(r.gops, 1),
            pct(r.util[0]),
            pct(r.util[1]),
            pct(r.util[2]),
            pct(r.util[3]),
            pct(r.util[4]),
            fnum(r.mops_per_dsp, 1),
        ]);
    }
    t.footnote("paper: DP halves DSP per fixed S; MOp/s-per-DSP gains > 50 %");
    Ok(ExperimentResult { id: id.into(), rendered: t.render(), rows })
}

/// Table 4: Jacobi-3D chains (8-way vectorized; S=40 original only
/// fits at 4-way — see `stencil_table`).
pub fn table4(nx: i64, seed: u64) -> Result<ExperimentResult, String> {
    stencil_table(
        StencilKind::Jacobi3D,
        &[(8, 8, 8), (16, 8, 8), (40, 4, 8)],
        nx,
        seed,
        "table4",
        &format!("Table 4: Jacobi 3D stencil chains ({nx}x32x32, 8-way vect)"),
    )
}

/// Table 5: Diffusion-3D chains (4-way vectorized; the original tops
/// out at S=20, only the double-pumped version reaches S=40).
pub fn table5(nx: i64, seed: u64) -> Result<ExperimentResult, String> {
    stencil_table(
        StencilKind::Diffusion3D,
        &[(8, 4, 4), (16, 4, 4), (20, 4, 0), (40, 0, 4)],
        nx,
        seed,
        "table5",
        &format!("Table 5: Diffusion 3D stencil chains ({nx}x32x32, 4-way vect)"),
    )
}

/// Table 6: Floyd–Warshall (throughput-mode double pumping).
pub fn table6(n: i64, seed: u64) -> Result<ExperimentResult, String> {
    let flops = apps::floyd_warshall::flops(n);
    let o = compile(
        BuildSpec::new(apps::floyd_warshall::build())
            .bind("N", n)
            .cl0(apps::floyd_warshall::CL0_REQUEST_MHZ)
            .seeded(seed),
    )?;
    let dp = compile(
        BuildSpec::new(apps::floyd_warshall::build())
            .pumped(2, PumpMode::Throughput)
            .bind("N", n)
            .cl0(apps::floyd_warshall::CL0_REQUEST_MHZ)
            .seeded(seed),
    )?;
    let rows = vec![
        mk_row("O", &o, flops, 1),
        mk_row("DP", &dp, flops, 1),
    ];
    let mut t = Table::new(
        format!("Table 6: Floyd–Warshall ({n} nodes)"),
        &["", "Freq CL0", "Freq CL1", "Time [s]", "LUT L%", "LUT M%", "Regs%", "BRAM%", "DSP%"],
    );
    for r in &rows {
        t.row(vec![
            r.label.clone(),
            fnum(r.cl0_mhz, 1),
            freq_cell(r.cl1_mhz),
            fnum(r.time_s, 2),
            pct(r.util[0]),
            pct(r.util[1]),
            pct(r.util[2]),
            pct(r.util[3]),
            pct(r.util[4]),
        ]);
    }
    t.footnote("paper: similar resources, ~1.5x speedup (we: speedup = CL1/CL0)");
    Ok(ExperimentResult { id: "table6".into(), rendered: t.render(), rows })
}

/// Which paper-scale size each experiment uses.
pub fn paper_sizes() -> (i64, i64, i64, i64) {
    (
        apps::vecadd::PAPER_N,
        apps::matmul::PAPER_NMK,
        apps::stencil::PAPER_NX,
        apps::floyd_warshall::PAPER_N,
    )
}

/// Run an experiment by id ("table1".."table6", "fig4") at paper scale.
pub fn run_experiment(id: &str, seed: u64) -> Result<ExperimentResult, String> {
    run_experiment_with(id, seed, None)
}

/// Run an experiment with sizes optionally overridden by a config file
/// (see `configs/*.toml`): `[tableN] n / nmk / nx` keys.
pub fn run_experiment_with(
    id: &str,
    seed: u64,
    cfg: Option<&super::config::Config>,
) -> Result<ExperimentResult, String> {
    let (van, mmn, snx, fwn) = paper_sizes();
    let seed = cfg.map(|c| c.int("", "seed", seed as i64) as u64).unwrap_or(seed);
    let size = |section: &str, key: &str, default: i64| {
        cfg.map(|c| c.int(section, key, default)).unwrap_or(default)
    };
    match id {
        "table1" => Ok(table1()),
        "table2" => table2(size("table2", "n", van), seed),
        "table3" => table3(size("table3", "nmk", mmn), seed),
        "table4" => table4(size("table4", "nx", snx), seed),
        "table5" => table5(size("table5", "nx", snx), seed),
        "table6" => table6(size("table6", "n", fwn), seed),
        "fig4" => super::report::figure4(seed),
        "dse" => super::autotune::dse_experiment(seed),
        other => Err(format!(
            "unknown experiment '{other}' (try table1..table6, fig4, dse)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper() {
        let r = table1();
        assert!(r.rendered.contains("439 K"));
        assert!(r.rendered.contains("2880"));
    }

    #[test]
    fn table2_small_scale_shape() {
        let r = table2(1 << 16, 3).unwrap();
        assert_eq!(r.rows.len(), 6);
        // per width: DSP halves under DP, time roughly unchanged
        for pair in r.rows.chunks(2) {
            let (o, dp) = (&pair[0], &pair[1]);
            assert!((dp.util[4] - o.util[4] / 2.0).abs() < 0.01, "{}", o.label);
            let dt = (dp.time_s - o.time_s).abs() / o.time_s;
            assert!(dt < 0.12, "{}: time drift {dt}", o.label);
            assert!(dp.cl1_mhz.unwrap() > 1.7 * dp.cl0_mhz);
        }
    }

    #[test]
    fn table6_small_scale_shape() {
        let r = table6(64, 3).unwrap();
        let (o, dp) = (&r.rows[0], &r.rows[1]);
        // similar resources, meaningful speedup
        let speedup = o.time_s / dp.time_s;
        assert!(speedup > 1.15, "speedup {speedup}");
        assert!((dp.util[3] - o.util[3]).abs() < 3.0); // BRAM similar
        // DSP may grow slightly (wider feed), never shrink below O
        assert!(dp.util[4] >= o.util[4] - 1e-9);
    }

    #[test]
    fn unknown_experiment_is_error() {
        assert!(run_experiment("table9", 1).is_err());
    }
}
