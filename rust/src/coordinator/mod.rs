//! The coordinator: configuration, the compile pipeline, and the
//! experiment runner that regenerates every table and figure of the
//! paper's evaluation.
//!
//! This is the L3 entry layer the CLI (`tvec`) and the benches drive.

pub mod autotune;
pub mod bench;
pub mod config;
pub mod experiment;
pub mod pipeline;
pub mod report;
pub mod serve;

pub use autotune::{
    autotune_all, dse_experiment, golden_rig, search_problem, verify_tolerance, DseChoice,
    GoldenRig,
};
pub use bench::{run_bench, BenchReport};
pub use config::Config;
pub use experiment::{run_experiment, ExperimentResult};
pub use pipeline::{
    compile, compile_from_prefix, compile_from_prefix_observed, compile_staged,
    compile_staged_observed, stage_prefix, stage_prefix_observed, BuildSpec, Compiled, Stage,
    StagedError, StagedPrefix,
};
pub use report::stall_report;
pub use serve::{run_serve, ServeOptions};
