//! `tvec dse --serve`: a first-cut DSE serving daemon.
//!
//! ROADMAP item "DSE-as-a-service": a long-running process owning one
//! shared [`Evaluator`] (memo cache + arena pool + optional disk cache
//! directory) that answers search requests over a Unix domain socket.
//! The protocol is newline-delimited JSON (NDJSON) — one request
//! object per line, one response object per line, FIFO per connection
//! and across connections (the daemon is deliberately single-threaded
//! at the request level: candidate evaluation inside a request is
//! already parallel, and serialized requests share the warm cache
//! perfectly). See DESIGN.md §14 for the protocol and a worked
//! example.
//!
//! Requests:
//!
//! ```text
//! {"op":"search","app":"vecadd","strategy":"exhaustive","budget":30,
//!  "n":1048576,"seed":9,"deadline_ms":2000,"sim_cycle_budget":50000000}
//! {"op":"ping"}
//! {"op":"shutdown"}
//! ```
//!
//! Only `app` is required for `search`; everything else defaults to the
//! daemon's own options. Responses carry the full supervision outcome
//! (`panicked`, `timed_out`, `quarantined` counts) so a client can see
//! degraded answers for what they are.
//!
//! Robustness contract: a panicking request (anywhere outside the
//! already-supervised candidate evaluations) fails *that request*, not
//! the daemon; a wedged candidate is reaped by the per-candidate
//! deadline; SIGTERM or an `{"op":"shutdown"}` request drains, flushes
//! the disk cache (merging, never compacting) and writes the
//! `BENCH_serve.json` summary artifact before exiting 0.

use std::io::{ErrorKind, Read, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use crate::dse::{Evaluator, FaultPlan, Objective, SearchConfig, Strategy};
use crate::hw::Device;
use crate::util::json::{escape, Json};

/// How often the accept loop polls for shutdown while idle.
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Per-read timeout on an open connection: bounds how long a silent
/// client can delay the daemon's reaction to SIGTERM.
const READ_TIMEOUT: Duration = Duration::from_secs(1);

/// Daemon configuration (`tvec dse --serve <socket>` plus the flags it
/// shares with one-shot sweeps).
pub struct ServeOptions {
    /// Unix socket path to listen on.
    pub socket: PathBuf,
    /// Persistent cache directory shared by every request.
    pub cache_dir: Option<PathBuf>,
    /// Default per-candidate wall deadline for requests that don't set
    /// their own.
    pub deadline_ms: Option<u64>,
    /// Default per-candidate slow-cycle budget.
    pub sim_cycle_budget: Option<u64>,
    /// Deterministic fault injection (`--inject-faults`).
    pub faults: Option<FaultPlan>,
    /// Where the shutdown summary artifact goes.
    pub bench_out: PathBuf,
    /// Default RNG seed for requests that don't set their own.
    pub seed: u64,
    /// Evaluation/verification worker threads (`--threads`; None keeps
    /// the evaluator default of available parallelism).
    pub threads: Option<usize>,
}

impl ServeOptions {
    pub fn new(socket: impl Into<PathBuf>) -> ServeOptions {
        ServeOptions {
            socket: socket.into(),
            cache_dir: None,
            deadline_ms: None,
            sim_cycle_budget: None,
            faults: None,
            bench_out: PathBuf::from("BENCH_serve.json"),
            seed: 9,
            threads: None,
        }
    }
}

/// Rolled-up daemon counters for `BENCH_serve.json`.
#[derive(Default)]
struct ServeStats {
    requests: usize,
    ok: usize,
    failed: usize,
    panicked: usize,
    timed_out: usize,
}

/// Set by the SIGTERM/SIGINT handler and the `shutdown` op.
static SHUTDOWN: AtomicBool = AtomicBool::new(false);

extern "C" fn on_terminate(_sig: i32) {
    SHUTDOWN.store(true, Ordering::SeqCst);
}

const SIGINT: i32 = 2;
const SIGTERM: i32 = 15;

extern "C" {
    // libc's signal(2); declared directly so the crate stays
    // dependency-free. The handler fn pointer is passed as-is — the
    // C ABI of `extern "C" fn(i32)` matches `void (*)(int)`.
    fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
}

/// Bind the daemon socket. A path left behind by a crashed daemon is
/// detected by a connect probe: nobody answering ⇒ stale ⇒ remove and
/// rebind; somebody answering ⇒ refuse to double-serve.
fn bind_socket(path: &Path) -> Result<UnixListener, String> {
    if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(parent)
            .map_err(|e| format!("cannot create socket directory {parent:?}: {e}"))?;
    }
    if path.exists() {
        match UnixStream::connect(path) {
            Ok(_) => {
                return Err(format!(
                    "socket {path:?} is already being served; refusing to double-bind"
                ))
            }
            Err(_) => {
                // stale socket from a dead daemon
                let _ = std::fs::remove_file(path);
            }
        }
    }
    UnixListener::bind(path).map_err(|e| format!("cannot bind {path:?}: {e}"))
}

/// Run the serving daemon until SIGTERM/SIGINT or a `shutdown` request.
/// Returns only after the cache is flushed and `BENCH_serve.json` is
/// written — a graceful shutdown is an exit-0 path.
pub fn run_serve(opts: ServeOptions) -> Result<(), String> {
    let mut opts = opts;
    SHUTDOWN.store(false, Ordering::SeqCst);
    unsafe {
        signal(SIGTERM, on_terminate);
        signal(SIGINT, on_terminate);
    }

    let evaluator = match &opts.cache_dir {
        Some(dir) => {
            let ev = Evaluator::with_cache_dir(dir);
            match ev.cold_reason() {
                Some(reason) => println!("cache: {reason}"),
                None => println!(
                    "cache: loaded {} entries from {}",
                    ev.loaded_entries(),
                    dir.display()
                ),
            }
            ev
        }
        None => Evaluator::new(),
    };
    let evaluator = match opts.faults.take() {
        Some(p) => evaluator.with_faults(p),
        None => evaluator,
    };
    if let Some(t) = opts.threads {
        evaluator.set_threads(t);
    }
    let device = Device::u280();

    let listener = bind_socket(&opts.socket)?;
    listener
        .set_nonblocking(true)
        .map_err(|e| format!("cannot set the listener non-blocking: {e}"))?;
    println!("serve: listening on {}", opts.socket.display());

    let mut stats = ServeStats::default();
    while !SHUTDOWN.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _addr)) => {
                if let Err(e) = serve_connection(stream, &evaluator, &device, &opts, &mut stats)
                {
                    eprintln!("serve: connection error: {e}");
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => {
                let _ = std::fs::remove_file(&opts.socket);
                return Err(format!("accept failed: {e}"));
            }
        }
    }

    // graceful shutdown: flush (merging — a daemon must never truncate
    // the store it shares with one-shot sweeps), summarize, clean up
    if opts.cache_dir.is_some() {
        match evaluator.flush() {
            Ok(flushed) => println!("cache: flushed {flushed} entries"),
            Err(e) => eprintln!("warning: cache flush failed: {e}"),
        }
    }
    if let Some(plan) = evaluator.faults() {
        println!("faults: {}", plan.summary());
    }
    write_bench(&opts.bench_out, &stats, &evaluator)?;
    let _ = std::fs::remove_file(&opts.socket);
    println!(
        "serve: handled {} request(s) ({} ok, {} failed); shutting down",
        stats.requests, stats.ok, stats.failed
    );
    Ok(())
}

/// Handle one client connection: NDJSON lines in, NDJSON lines out,
/// until the client disconnects or asks for shutdown. A `Vec<u8>`
/// accumulator does the framing — a read timeout mid-line must not
/// drop the partial line a buffered reader would have consumed.
fn serve_connection(
    stream: UnixStream,
    evaluator: &Evaluator,
    device: &Device,
    opts: &ServeOptions,
    stats: &mut ServeStats,
) -> Result<(), String> {
    let mut stream = stream;
    stream
        .set_read_timeout(Some(READ_TIMEOUT))
        .map_err(|e| format!("cannot set the read timeout: {e}"))?;
    let mut pending: Vec<u8> = Vec::new();
    let mut buf = [0u8; 4096];
    loop {
        // drain complete lines before reading more
        while let Some(nl) = pending.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = pending.drain(..=nl).collect();
            let line = String::from_utf8_lossy(&line).trim().to_string();
            if line.is_empty() {
                continue;
            }
            stats.requests += 1;
            let (response, shutdown) = handle_request(&line, evaluator, device, opts, stats);
            stream
                .write_all(format!("{response}\n").as_bytes())
                .and_then(|_| stream.flush())
                .map_err(|e| format!("cannot write the response: {e}"))?;
            if shutdown {
                SHUTDOWN.store(true, Ordering::SeqCst);
                return Ok(());
            }
        }
        if SHUTDOWN.load(Ordering::SeqCst) {
            return Ok(());
        }
        match stream.read(&mut buf) {
            Ok(0) => return Ok(()), // client hung up
            Ok(n) => pending.extend_from_slice(&buf[..n]),
            Err(e)
                if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
            {
                // idle client; keep polling so SIGTERM stays responsive
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(format!("read failed: {e}")),
        }
    }
}

/// Dispatch one request line. Returns `(response_json, shutdown)`.
fn handle_request(
    line: &str,
    evaluator: &Evaluator,
    device: &Device,
    opts: &ServeOptions,
    stats: &mut ServeStats,
) -> (String, bool) {
    let req = match Json::parse(line) {
        Ok(j) => j,
        Err(e) => {
            stats.failed += 1;
            return (fail("parse", &format!("bad request JSON: {e}")), false);
        }
    };
    match req.get("op").and_then(Json::as_str) {
        Some("ping") => {
            stats.ok += 1;
            (r#"{"ok":true,"op":"ping"}"#.to_string(), false)
        }
        Some("shutdown") => {
            stats.ok += 1;
            (r#"{"ok":true,"op":"shutdown"}"#.to_string(), true)
        }
        Some("search") => {
            let resp = handle_search(&req, evaluator, device, opts, stats);
            match resp {
                Ok(r) => {
                    stats.ok += 1;
                    (r, false)
                }
                Err(e) => {
                    stats.failed += 1;
                    (fail("search", &e), false)
                }
            }
        }
        Some(other) => {
            stats.failed += 1;
            (
                fail("unknown", &format!("unknown op '{other}' (search|ping|shutdown)")),
                false,
            )
        }
        None => {
            stats.failed += 1;
            (fail("unknown", "request has no \"op\" field"), false)
        }
    }
}

fn fail(op: &str, error: &str) -> String {
    format!(r#"{{"ok":false,"op":"{}","error":"{}"}}"#, escape(op), escape(error))
}

/// Run one search request against the shared evaluator. The whole
/// request body sits under `catch_unwind`: candidate evaluations are
/// already individually supervised, but a panic anywhere else (grid
/// generation, frontier selection) must fail the request, not the
/// daemon.
fn handle_search(
    req: &Json,
    evaluator: &Evaluator,
    device: &Device,
    opts: &ServeOptions,
    stats: &mut ServeStats,
) -> Result<String, String> {
    let app = req
        .get("app")
        .and_then(Json::as_str)
        .ok_or("search request needs an \"app\" field")?
        .to_string();
    let strategy = match req.get("strategy").and_then(Json::as_str) {
        Some(name) => Strategy::from_name(name)
            .ok_or_else(|| format!("unknown strategy '{name}'"))?,
        None => Strategy::Exhaustive,
    };
    let objective = match req.get("objective").and_then(Json::as_str) {
        Some("throughput") => Objective::throughput(),
        Some("resource") | None => Objective::resource(),
        Some(other) => return Err(format!("unknown objective '{other}'")),
    };
    let budget = req.get("budget").and_then(Json::as_u64).map(|b| b as usize);
    let seed = req.get("seed").and_then(Json::as_u64).unwrap_or(opts.seed);
    let n = req.get("n").and_then(Json::as_u64).map(|v| v as i64);
    let deadline_ms =
        req.get("deadline_ms").and_then(Json::as_u64).or(opts.deadline_ms);
    let sim_cycle_budget =
        req.get("sim_cycle_budget").and_then(Json::as_u64).or(opts.sim_cycle_budget);
    let cfg = SearchConfig {
        strategy,
        objective,
        budget,
        seed,
        deadline_ms,
        sim_cycle_budget,
    };

    let hits_before = evaluator.cache_hits();
    let misses_before = evaluator.cache_misses();
    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let (bases, space) =
            crate::coordinator::search_problem(&app, n, seed, device)?;
        crate::dse::run_search(evaluator, &bases, device, &space, &cfg)
    }));
    let outcome = match run {
        Ok(r) => r?,
        Err(payload) => {
            let msg = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "opaque panic payload".into());
            return Err(format!("request panicked: {msg}"));
        }
    };

    stats.panicked += outcome.panicked;
    stats.timed_out += outcome.timed_out;
    let frontier: Vec<String> = outcome
        .frontier
        .iter()
        .map(|e| format!("\"{}\"", escape(&e.label)))
        .collect();
    let chosen = match &outcome.chosen {
        Some(c) => format!("\"{}\"", escape(&c.label)),
        None => "null".to_string(),
    };
    let reference = match &outcome.reference {
        Some(r) => format!("\"{}\"", escape(&r.label)),
        None => "null".to_string(),
    };
    Ok(format!(
        concat!(
            r#"{{"ok":true,"op":"search","app":"{}","strategy":"{}","chosen":{},"#,
            r#""reference":{},"frontier":[{}],"evaluated":{},"cache_hits":{},"#,
            r#""new_compiles":{},"illegal":{},"compile_failed":{},"checker_rejected":{},"#,
            r#""panicked":{},"timed_out":{},"quarantined":{},"truncated":{}}}"#
        ),
        escape(&app),
        cfg.strategy.name(),
        chosen,
        reference,
        frontier.join(","),
        outcome.evaluated,
        evaluator.cache_hits() - hits_before,
        evaluator.cache_misses() - misses_before,
        outcome.illegal,
        outcome.compile_failed,
        outcome.checker_rejected,
        outcome.panicked,
        outcome.timed_out,
        outcome.quarantined(),
        outcome.truncated,
    ))
}

/// Write the shutdown summary artifact (schema `tvec-serve v1`).
fn write_bench(
    path: &Path,
    stats: &ServeStats,
    evaluator: &Evaluator,
) -> Result<(), String> {
    let hits = evaluator.cache_hits();
    let new = evaluator.cache_misses();
    let body = format!(
        concat!(
            "{{\n",
            "  \"schema\": \"tvec-serve v1\",\n",
            "  \"requests\": {},\n",
            "  \"ok\": {},\n",
            "  \"failed\": {},\n",
            "  \"cache_hits\": {},\n",
            "  \"new_compiles\": {},\n",
            "  \"hit_rate\": {:.4},\n",
            "  \"panicked\": {},\n",
            "  \"timed_out\": {},\n",
            "  \"degraded\": {}\n",
            "}}\n"
        ),
        stats.requests,
        stats.ok,
        stats.failed,
        hits,
        new,
        hits as f64 / (hits + new).max(1) as f64,
        stats.panicked,
        stats.timed_out,
        evaluator.degraded(),
    );
    std::fs::write(path, body)
        .map_err(|e| format!("cannot write {}: {e}", path.display()))
}
