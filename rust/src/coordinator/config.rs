//! Configuration system: a TOML-subset parser (the `toml` crate is not
//! in the offline cache — DESIGN.md §4).
//!
//! Supported: `[section]` headers, `key = value` with string, integer,
//! float, boolean and flat-array values, `#` comments. That covers the
//! experiment configs in `configs/`.

use std::collections::BTreeMap;

/// A configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(v) => Some(*v),
            Value::Int(v) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_int_array(&self) -> Option<Vec<i64>> {
        match self {
            Value::Array(xs) => xs.iter().map(|x| x.as_int()).collect(),
            _ => None,
        }
    }
}

/// Parsed configuration: `section.key` → value ("" section for
/// top-level keys).
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<(String, String), Value>,
}

fn parse_scalar(s: &str) -> Result<Value, String> {
    let s = s.trim();
    if let Some(stripped) = s.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string: {s}"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Ok(v) = s.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = s.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    Err(format!("cannot parse value: {s}"))
}

impl Config {
    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Config, String> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                section = name
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: bad section header", lineno + 1))?
                    .trim()
                    .to_string();
                continue;
            }
            let (key, val) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim().to_string();
            let val = val.trim();
            let value = if let Some(inner) = val.strip_prefix('[') {
                let inner = inner
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated array", lineno + 1))?;
                let items: Result<Vec<Value>, String> = inner
                    .split(',')
                    .filter(|s| !s.trim().is_empty())
                    .map(parse_scalar)
                    .collect();
                Value::Array(items?)
            } else {
                parse_scalar(val).map_err(|e| format!("line {}: {e}", lineno + 1))?
            };
            cfg.values.insert((section.clone(), key), value);
        }
        Ok(cfg)
    }

    pub fn load(path: &std::path::Path) -> Result<Config, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        Config::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.values.get(&(section.to_string(), key.to_string()))
    }

    pub fn int(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(|v| v.as_int()).unwrap_or(default)
    }

    pub fn float(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_float()).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn bool(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn sections(&self) -> Vec<&str> {
        let mut out: Vec<&str> = self.values.keys().map(|(s, _)| s.as_str()).collect();
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
seed = 42
device = "u280"

[table3]
pes = [32, 48, 64]
vec_width = 16
pump = true
target_mhz = 300.5
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.int("", "seed", 0), 42);
        assert_eq!(c.str_or("", "device", "?"), "u280");
        assert_eq!(
            c.get("table3", "pes").unwrap().as_int_array().unwrap(),
            vec![32, 48, 64]
        );
        assert_eq!(c.int("table3", "vec_width", 0), 16);
        assert!(c.bool("table3", "pump", false));
        assert!((c.float("table3", "target_mhz", 0.0) - 300.5).abs() < 1e-9);
    }

    #[test]
    fn defaults_apply() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.int("x", "y", 7), 7);
        assert_eq!(c.str_or("x", "y", "z"), "z");
    }

    #[test]
    fn errors_are_located() {
        assert!(Config::parse("[broken").unwrap_err().contains("line 1"));
        assert!(Config::parse("novalue").unwrap_err().contains("key = value"));
        assert!(Config::parse("x = @?!").unwrap_err().contains("cannot parse"));
    }

    #[test]
    fn int_vs_float_coercion() {
        let c = Config::parse("a = 3").unwrap();
        assert_eq!(c.float("", "a", 0.0), 3.0);
    }
}
