//! Simulation statistics.

use super::arena::ArenaStats;

/// Outcome counters of a simulated execution.
#[derive(Clone, Debug, Default)]
pub struct SimStats {
    /// Total slow-domain (CL0) cycles.
    pub slow_cycles: u64,
    /// Fast-domain cycles (= slow_cycles × M when pumped).
    pub fast_cycles: u64,
    /// The module limiting throughput.
    pub bottleneck: String,
    /// Per-module (label, busy cycles, stall cycles).
    pub modules: Vec<(String, u64, u64)>,
    /// Transactions through the design (writer side).
    pub transactions: u64,
    /// Transaction-arena counters of the run (DESIGN.md §10). *Not*
    /// part of the engine-equality contract: a run inside a warmed
    /// shared arena legitimately reports more recycle hits than a cold
    /// one while being cycle-identical.
    pub arena: ArenaStats,
}

impl SimStats {
    /// Wall-clock seconds at an effective clock in MHz.
    pub fn seconds_at(&self, effective_mhz: f64) -> f64 {
        self.slow_cycles as f64 / (effective_mhz * 1e6)
    }

    /// Throughput in GOp/s given total flops and an effective clock.
    pub fn gops_at(&self, total_flops: f64, effective_mhz: f64) -> f64 {
        total_flops / self.seconds_at(effective_mhz) / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_and_gops() {
        let s = SimStats { slow_cycles: 300_000_000, ..Default::default() };
        let secs = s.seconds_at(300.0);
        assert!((secs - 1.0).abs() < 1e-9);
        let gops = s.gops_at(2e9, 300.0);
        assert!((gops - 2.0).abs() < 1e-9);
    }
}
