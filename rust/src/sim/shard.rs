//! Domain-sharded exact simulation: the parallel counterpart of
//! [`super::engine::run_exact`].
//!
//! The lowered netlist is partitioned into weakly-connected components
//! ([`shard_partition`]): two modules land in one shard when they touch
//! the same channel *or* the same HBM container (a reader and writer of
//! one bank must observe each other's bytes in program order, so a
//! container is never split across threads). Each shard then runs the
//! event-driven scheduler — the exact per-cycle body of
//! [`super::engine::run_exact_deadline_in`], minus rep-end settlement —
//! on its own worker thread, and the shards synchronize only at rep
//! boundaries (DESIGN.md §15):
//!
//! * Within one rep, shards share no channels and no HBM banks, so a
//!   shard's event sequence is exactly the serial engine's sequence
//!   restricted to that shard's modules. Cross-shard `Fifo` activity
//!   counters therefore never race — they are the only synchronization
//!   points *between* reps, read at the barrier.
//! * A cleanly completing shard reports its local break cycle
//!   (`final_t0`); the barrier takes the **max** over shards — the
//!   serial engine's quiescence cycle, since its quiet predicate is
//!   state-based and its gap path returns the last progress cycle + 1.
//! * Sleeping processes settle their stall counters **at the barrier**
//!   with the *global* `final_t0` (the serial engine ticked every
//!   scheduled sleeper through the global break cycle), and every shard
//!   re-arms the next rep from the agreed `fast_t = final_t0 + 1`.
//!
//! Error parity: a slow-cycle budget error has one deterministic
//! message, so a shard-local budget hit is returned directly. A
//! wall-deadline error embeds a nondeterministic elapsed time in both
//! engines, so it is returned directly too. A *deadlock*, however,
//! reports a cycle number and stuck-module list that depend on
//! cross-shard last-progress timing — on any local deadlock the sharded
//! run is discarded and the whole design re-runs on the serial engine,
//! reproducing the diagnostic byte for byte.
//!
//! Designs that lower to a single component (every real app: one
//! pipeline from readers to writers) honestly delegate to the serial
//! engine, as does `threads == 1`. Genuine multi-shard inputs come from
//! [`replicate_design`], which stamps k independent copies of a
//! netlist — the bench's sharded-vs-serial rows and the property suite
//! run on those. Cycle-exactness against [`super::engine::run_exact_reference`]
//! is pinned by `rust/tests/properties.rs` and
//! [`super::engine::exact_engines_agree_in`], which runs this engine at
//! two threads alongside both serial engines.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, PoisonError};
use std::time::{Duration, Instant};

use super::arena::{Arena, ArenaStats};
use super::channel::{Channels, Fifo};
use super::engine::{fast_time_base, run_exact_deadline_in, SimOutcome, WALL_DEADLINE_MARK};
use super::memory::Hbm;
use super::process::Proc;
use super::stats::SimStats;
use crate::codegen::design::{ChannelSpec, Design, ModuleInst, ModuleSpec};
use crate::ir::ClockDomain;

/// Resolve a `--threads` request: `0` means "whatever the machine
/// offers" (the CLI default), anything else is taken literally. Shared
/// by the sharded engine, the DSE evaluator and the parallel verify
/// path so every layer agrees on what "default parallelism" means.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    } else {
        threads
    }
}

/// Is this module excluded from simulation (the engines build no
/// process for `__ctrl` synchronizers)? Mirrors `build_procs`.
fn is_ctrl_sync(spec: &ModuleSpec) -> bool {
    matches!(spec, ModuleSpec::Sync { input, .. } if input.starts_with("__ctrl"))
}

/// The HBM container a module reads or writes, if any. Only the memory
/// endpoints touch HBM (`Proc::tick` calls `fetch`/`store` exclusively
/// from readers and writers); cores keep their state internal.
fn hbm_container(spec: &ModuleSpec) -> Option<&str> {
    match spec {
        ModuleSpec::Reader { data, .. } | ModuleSpec::Writer { data, .. } => Some(data),
        _ => None,
    }
}

fn find(parent: &mut [usize], mut i: usize) -> usize {
    while parent[i] != i {
        parent[i] = parent[parent[i]]; // path halving
        i = parent[i];
    }
    i
}

fn union(parent: &mut [usize], a: usize, b: usize) {
    let (ra, rb) = (find(parent, a), find(parent, b));
    if ra != rb {
        parent[ra.max(rb)] = ra.min(rb);
    }
}

/// Weakly-connected-component partition of a design's simulated
/// modules. Two modules share a component when they touch the same
/// channel or the same HBM container. Returns groups of indices into
/// `design.modules` (`__ctrl` syncs excluded), each group ascending,
/// groups ordered by their first module — so concatenating the groups
/// of a single-component design reproduces the serial proc order.
pub fn shard_partition(design: &Design) -> Vec<Vec<usize>> {
    let n = design.modules.len();
    let mut parent: Vec<usize> = (0..n).collect();
    let mut owner: HashMap<String, usize> = HashMap::new();
    for (i, m) in design.modules.iter().enumerate() {
        if is_ctrl_sync(&m.spec) {
            continue;
        }
        let mut keys: Vec<String> = m.spec.inputs();
        keys.extend(m.spec.outputs());
        if let Some(data) = hbm_container(&m.spec) {
            // prefixed so a container and a channel of one name never merge
            keys.push(format!("hbm:{data}"));
        }
        for k in keys {
            match owner.get(&k) {
                Some(&j) => union(&mut parent, i, j),
                None => {
                    owner.insert(k, i);
                }
            }
        }
    }
    let mut groups: Vec<Vec<usize>> = Vec::new();
    let mut of_root: HashMap<usize, usize> = HashMap::new();
    for (i, m) in design.modules.iter().enumerate() {
        if is_ctrl_sync(&m.spec) {
            continue;
        }
        let r = find(&mut parent, i);
        let g = *of_root.entry(r).or_insert_with(|| {
            groups.push(Vec::new());
            groups.len() - 1
        });
        groups[g].push(i);
    }
    groups
}

/// Stamp `k` independent copies of a netlist: modules, channels and
/// arrays are cloned per replica with an `r{i}__` name prefix (control
/// channels keep their `__ctrl` marker prefix so the engines' sync
/// filter still recognizes them). The replicas share no channel and no
/// HBM container, so [`shard_partition`] finds exactly `k × ` the
/// original component count — the multi-shard workload the bench's
/// sharded-vs-serial rows and the property suite run on. Replica 0 of
/// every name comes first, so the serial engine's module order is the
/// concatenation of the replicas'.
pub fn replicate_design(design: &Design, k: usize) -> Design {
    assert!(k >= 1, "replicate_design wants k >= 1");
    let mut modules = Vec::with_capacity(design.modules.len() * k);
    let mut channels = Vec::with_capacity(design.channels.len() * k);
    let mut arrays = Vec::with_capacity(design.arrays.len() * k);
    for i in 0..k {
        let p = format!("r{i}__");
        for m in &design.modules {
            modules.push(ModuleInst {
                spec: rename_spec(&m.spec, &p),
                domain: m.domain,
                resources: m.resources,
            });
        }
        for c in &design.channels {
            channels.push(ChannelSpec { name: rename(&c.name, &p), ..c.clone() });
        }
        for (name, elems, bank) in &design.arrays {
            arrays.push((rename(name, &p), *elems, *bank));
        }
    }
    Design {
        name: format!("{}_x{k}", design.name),
        modules,
        channels,
        pump: design.pump,
        domain_modes: design.domain_modes.clone(),
        arrays,
        repeat: design.repeat,
        slr_replicas: design.slr_replicas,
        cl0_request_mhz: design.cl0_request_mhz,
    }
}

/// Load one input set once per replica under [`replicate_design`]'s
/// naming scheme.
pub fn replicate_inputs(inputs: &[(String, Vec<f32>)], k: usize) -> Hbm {
    let mut hbm = Hbm::new();
    for i in 0..k {
        for (name, data) in inputs {
            hbm.load(&format!("r{i}__{name}"), data.clone());
        }
    }
    hbm
}

/// Prefix a name, preserving the `__ctrl` marker prefix the engines
/// and checker key on.
fn rename(name: &str, p: &str) -> String {
    match name.strip_prefix("__ctrl") {
        Some(rest) => format!("__ctrl_{p}{rest}"),
        None => format!("{p}{name}"),
    }
}

fn rename_spec(spec: &ModuleSpec, p: &str) -> ModuleSpec {
    let r = |s: &str| rename(s, p);
    match spec {
        ModuleSpec::Reader { data, stream, lanes, elems, bytes_per_cycle } => {
            ModuleSpec::Reader {
                data: r(data),
                stream: r(stream),
                lanes: *lanes,
                elems: *elems,
                bytes_per_cycle: *bytes_per_cycle,
            }
        }
        ModuleSpec::Writer { data, stream, lanes, elems, bytes_per_cycle } => {
            ModuleSpec::Writer {
                data: r(data),
                stream: r(stream),
                lanes: *lanes,
                elems: *elems,
                bytes_per_cycle: *bytes_per_cycle,
            }
        }
        ModuleSpec::Compute { name, tasklet, inputs, output, lanes, iterations, ii, latency } => {
            ModuleSpec::Compute {
                name: r(name),
                tasklet: tasklet.clone(),
                inputs: inputs.iter().map(|(s, c)| (r(s), c.clone())).collect(),
                output: (r(&output.0), output.1.clone()),
                lanes: *lanes,
                iterations: *iterations,
                ii: *ii,
                latency: *latency,
            }
        }
        ModuleSpec::Sync { input, output } => {
            ModuleSpec::Sync { input: r(input), output: r(output) }
        }
        ModuleSpec::Issuer { input, output, factor } => {
            ModuleSpec::Issuer { input: r(input), output: r(output), factor: *factor }
        }
        ModuleSpec::Packer { input, output, factor } => {
            ModuleSpec::Packer { input: r(input), output: r(output), factor: *factor }
        }
        ModuleSpec::GemmCore { name, a, b, c, n, m, k, pes, lanes, tile_m, tile_n } => {
            ModuleSpec::GemmCore {
                name: r(name),
                a: r(a),
                b: r(b),
                c: r(c),
                n: *n,
                m: *m,
                k: *k,
                pes: *pes,
                lanes: *lanes,
                tile_m: *tile_m,
                tile_n: *tile_n,
            }
        }
        ModuleSpec::StencilCore { name, kind, input, output, nx, ny, nz, lanes } => {
            ModuleSpec::StencilCore {
                name: r(name),
                kind: kind.clone(),
                input: r(input),
                output: r(output),
                nx: *nx,
                ny: *ny,
                nz: *nz,
                lanes: *lanes,
            }
        }
        ModuleSpec::FwCore { name, input, output, n, lanes, ii } => ModuleSpec::FwCore {
            name: r(name),
            input: r(input),
            output: r(output),
            n: *n,
            lanes: *lanes,
            ii: *ii,
        },
    }
}

/// One shard's complete event-loop state. Fields mirror the serial
/// engine's locals; the scheduling arrays persist across reps (they are
/// fully re-armed at each rep start, exactly as the serial engine
/// re-arms its own).
struct Shard {
    /// Each local proc's position in the serial engine's proc order —
    /// merged stats are reassembled in this order so bottleneck
    /// tie-breaking and the `modules` list match the oracle exactly.
    global: Vec<usize>,
    procs: Vec<Proc>,
    ch: Channels,
    hbm: Hbm,
    arena: Arena,
    stride: Vec<u64>,
    push_subs: Vec<Vec<usize>>,
    pop_subs: Vec<Vec<usize>>,
    own_ch: Vec<Vec<usize>>,
    scratch: Vec<u64>,
    awake: Vec<bool>,
    next_tick: Vec<u64>,
    sleep_at: Vec<u64>,
    sleep_done: Vec<bool>,
}

/// How one shard's rep ended (settlement not yet applied).
enum RepEnd {
    /// The shard's local break cycle.
    Clean { final_t0: u64 },
    /// Slow-cycle budget exhausted — the error string is deterministic
    /// and identical to the serial engine's, so it is returned directly.
    Budget,
    /// Wall-clock deadline hit (message carries the elapsed time).
    Wall(String),
    /// Local deadlock: diagnostics depend on cross-shard timing, so the
    /// coordinator discards the sharded run and re-runs serially.
    Deadlock,
}

/// Asleep with no armed wake (mirrors the serial engine).
const IDLE: u64 = u64::MAX;

/// First scheduled cycle of stride `s` at or after `t`.
fn align(t: u64, s: u64) -> u64 {
    let r = t % s;
    if r == 0 {
        t
    } else {
        t + (s - r)
    }
}

/// Arm a sleeping local process `j` after an event at cycle `t` fired
/// by local process `cur`. Local order preserves the serial module
/// order (shard member lists ascend), so the same-cycle `j > cur` rule
/// is equivalent to the serial engine's global-index comparison.
fn wake_proc(j: usize, t: u64, cur: usize, stride: &[u64], awake: &[bool], next_tick: &mut [u64]) {
    if awake[j] {
        return;
    }
    let s = stride[j];
    let at = if j > cur && t % s == 0 { t } else { (t / s + 1) * s };
    if at < next_tick[j] {
        next_tick[j] = at;
    }
}

/// Run one rep of one shard from the globally agreed `fast_t`. The
/// cycle body is the serial engine's verbatim; the rep-end stall
/// settlement is *omitted* — it needs the global break cycle, which
/// only the barrier knows.
#[allow(clippy::too_many_arguments)]
fn run_rep(
    s: &mut Shard,
    rep: usize,
    fast_t: u64,
    budget: u64,
    factor: u64,
    deadline: Option<(Instant, Duration)>,
    design_name: &str,
) -> RepEnd {
    let Shard {
        procs,
        ch,
        hbm,
        arena,
        stride,
        push_subs,
        pop_subs,
        own_ch,
        scratch,
        awake,
        next_tick,
        sleep_at,
        sleep_done,
        ..
    } = s;
    let n = procs.len();
    if rep > 0 {
        for p in procs.iter_mut() {
            p.reset_for_repeat();
        }
    }
    for i in 0..n {
        awake[i] = true;
        next_tick[i] = align(fast_t, stride[i]);
    }
    let mut deadlock_t0 = fast_t + 8 * factor;
    let mut break_t0 = fast_t;
    let mut wall_tick = 0u32;
    loop {
        wall_tick = wall_tick.wrapping_add(1);
        if wall_tick & 0xff == 0 {
            if let Some((t0, limit)) = deadline {
                if t0.elapsed() > limit {
                    return RepEnd::Wall(format!(
                        "exact simulation of '{design_name}' {WALL_DEADLINE_MARK} \
                         ({}ms limit, {}ms elapsed)",
                        limit.as_millis(),
                        t0.elapsed().as_millis()
                    ));
                }
            }
        }
        let t = next_tick.iter().copied().min().unwrap_or(IDLE);
        if t > break_t0 {
            let quiet = procs.iter().all(|p| p.done(ch)) && ch.all_empty();
            if quiet {
                if break_t0 + 1 > budget {
                    return RepEnd::Budget;
                }
                return RepEnd::Clean { final_t0: break_t0 };
            }
            let gap = deadlock_t0.min(budget);
            if t > gap {
                if budget <= deadlock_t0 {
                    return RepEnd::Budget;
                }
                return RepEnd::Deadlock;
            }
        }
        let mut progress = false;
        for i in 0..n {
            if next_tick[i] != t {
                continue;
            }
            if !awake[i] && !sleep_done[i] {
                procs[i].stalls += ((t - sleep_at[i]) / stride[i]).saturating_sub(1);
            }
            let chans = &own_ch[i];
            for (k, &c) in chans.iter().enumerate() {
                scratch[k] = ch.fifos[c].activity();
            }
            let prog = procs[i].tick(t, ch, arena, hbm);
            if prog {
                progress = true;
                awake[i] = true;
                next_tick[i] = t + stride[i];
            } else {
                awake[i] = false;
                sleep_at[i] = t;
                sleep_done[i] = procs[i].done(ch);
                next_tick[i] = match procs[i].next_retire_time() {
                    Some(ready) if ready > t => align(ready, stride[i]),
                    _ => IDLE,
                };
            }
            for (k, &c) in chans.iter().enumerate() {
                if ch.fifos[c].activity() != scratch[k] {
                    for &j in push_subs[c].iter().chain(pop_subs[c].iter()) {
                        wake_proc(j, t, i, stride, awake, next_tick);
                    }
                }
            }
        }
        if t + 1 > budget {
            return RepEnd::Budget;
        }
        if !progress {
            let quiet = procs.iter().all(|p| p.done(ch)) && ch.all_empty();
            if quiet {
                return RepEnd::Clean { final_t0: t };
            }
            if t >= deadlock_t0 {
                return RepEnd::Deadlock;
            }
        } else {
            deadlock_t0 = t + 8 * factor + 1;
            break_t0 = t + 1;
        }
    }
}

/// [`run_exact_sharded_in`] with a private arena pool and no deadline.
pub fn run_exact_sharded(
    design: &Design,
    hbm: Hbm,
    max_cycles: u64,
    threads: usize,
) -> Result<SimOutcome, String> {
    run_exact_sharded_in(design, hbm, max_cycles, threads, None, &mut Vec::new(), None)
}

/// Sharded exact simulation: cycle-exact and output-bit-identical to
/// [`super::engine::run_exact`] (see the module docs for the barrier
/// argument). `threads == 0` means available parallelism; `threads ==
/// 1` — or a design that lowers to a single component — delegates to
/// the serial engine. `arenas` is the per-shard arena pool: it is grown
/// to the shard count on first use and every arena is returned (in
/// shard order) before this function exits, so repeated runs reuse the
/// slabs the first run established, whatever the outcome.
///
/// With a recorder attached the run is wrapped in a `sim.sharded` span
/// (shards/workers noted) and emits per-shard `sim.shard.<i>.busy` and
/// `sim.shard.<i>.steals` counters — a steal being a rep dispatch a
/// worker picked up outside its home slice of the shard queue. The
/// recorder is only touched from the coordinator thread, after the
/// barrier, so instrumentation is purely observational.
pub fn run_exact_sharded_in(
    design: &Design,
    mut hbm: Hbm,
    max_cycles: u64,
    threads: usize,
    wall: Option<Duration>,
    arenas: &mut Vec<Arena>,
    rec: Option<&crate::telemetry::Recorder>,
) -> Result<SimOutcome, String> {
    let groups = shard_partition(design);
    let workers = resolve_threads(threads).min(groups.len().max(1));
    if groups.len() < 2 || workers < 2 {
        if arenas.is_empty() {
            arenas.push(Arena::default());
        }
        return run_exact_deadline_in(design, hbm, max_cycles, wall, &mut arenas[0], rec);
    }

    let deadline = wall.map(|limit| (Instant::now(), limit));
    for (name, elems, _) in &design.arrays {
        hbm.alloc(name, *elems);
    }
    let factor = fast_time_base(design);
    let budget = max_cycles.saturating_mul(factor);
    let exceeded = || {
        format!("exact simulation of '{}' exceeded {max_cycles} slow cycles", design.name)
    };

    // channel name → shard of its attached modules (consistent by the
    // union construction); unattached channels (`__ctrl`) ride shard 0
    let mut chan_shard: HashMap<String, usize> = HashMap::new();
    for (s, group) in groups.iter().enumerate() {
        for &mi in group {
            let spec = &design.modules[mi].spec;
            for name in spec.inputs().into_iter().chain(spec.outputs()) {
                chan_shard.insert(name, s);
            }
        }
    }
    // each module's position in the serial engine's proc order — merged
    // stats reassemble in this order
    let serial_pos: HashMap<usize, usize> = design
        .modules
        .iter()
        .enumerate()
        .filter(|(_, m)| !is_ctrl_sync(&m.spec))
        .enumerate()
        .map(|(pos, (mi, _))| (mi, pos))
        .collect();

    while arenas.len() < groups.len() {
        arenas.push(Arena::default());
    }
    let mut pool: Vec<Arena> = std::mem::take(arenas);
    // build shards: per-shard channels in design order, procs in module
    // order (local order therefore preserves global relative order),
    // per-shard HBM holding a copy of the shard's containers — the
    // original stays pristine for the deadlock fallback path
    let mut shards: Vec<Mutex<Shard>> = Vec::with_capacity(groups.len());
    for (s, group) in groups.iter().enumerate() {
        let mut ch = Channels::default();
        for c in &design.channels {
            if chan_shard.get(c.name.as_str()).copied().unwrap_or(0) == s {
                ch.add(Fifo::new(&c.name, c.lanes, c.depth));
            }
        }
        let mut local = Hbm::new();
        let mut procs = Vec::with_capacity(group.len());
        let mut global = Vec::with_capacity(group.len());
        for &mi in group {
            let m = &design.modules[mi];
            if let Some(data) = hbm_container(&m.spec) {
                if !local.contains(data) {
                    local.load(data, hbm.read(data).to_vec());
                }
            }
            procs.push(Proc::build(&m.spec, m.domain, &ch));
            global.push(serial_pos[&mi]);
        }
        let stride: Vec<u64> = procs
            .iter()
            .map(|p| match p.domain {
                ClockDomain::Slow => factor,
                ClockDomain::Fast { factor: f } => (factor / (f as u64)).max(1),
            })
            .collect();
        let mut push_subs: Vec<Vec<usize>> = vec![Vec::new(); ch.fifos.len()];
        let mut pop_subs: Vec<Vec<usize>> = vec![Vec::new(); ch.fifos.len()];
        let own_ch: Vec<Vec<usize>> = procs
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let ins = p.input_channels();
                let outs = p.output_channels();
                for &c in &ins {
                    push_subs[c].push(i);
                }
                for &c in &outs {
                    pop_subs[c].push(i);
                }
                ins.into_iter().chain(outs).collect()
            })
            .collect();
        let max_own = own_ch.iter().map(|c| c.len()).max().unwrap_or(0);
        let n = procs.len();
        let mut arena = pool.remove(0);
        arena.reset();
        shards.push(Mutex::new(Shard {
            global,
            procs,
            ch,
            hbm: local,
            arena,
            stride,
            push_subs,
            pop_subs,
            own_ch,
            scratch: vec![0; max_own],
            awake: vec![true; n],
            next_tick: vec![0; n],
            sleep_at: vec![0; n],
            sleep_done: vec![false; n],
        }));
    }

    let mut span = rec.map(|r| r.span("sim.sharded"));
    if let Some(sp) = span.as_mut() {
        sp.note("shards", groups.len() as u64);
        sp.note("workers", workers as u64);
    }
    let steals: Vec<AtomicU64> = (0..shards.len()).map(|_| AtomicU64::new(0)).collect();

    let result = drive(
        design,
        hbm,
        &mut shards,
        workers,
        budget,
        factor,
        deadline,
        max_cycles,
        &exceeded,
        &steals,
        wall,
        rec,
    );

    if let Some(r) = rec {
        for (i, m) in shards.iter_mut().enumerate() {
            let sh = m.get_mut().unwrap_or_else(PoisonError::into_inner);
            r.add(
                &format!("sim.shard.{i}.busy"),
                sh.procs.iter().map(|p| p.busy).sum::<u64>(),
            );
            r.add(&format!("sim.shard.{i}.steals"), steals[i].load(Ordering::Relaxed));
        }
    }
    // return every arena to the caller's pool, in shard order, on every
    // outcome — the next run reuses the established slabs; a caller
    // pool larger than the shard count keeps its extras at the tail
    for m in shards {
        let sh = m.into_inner().unwrap_or_else(PoisonError::into_inner);
        arenas.push(sh.arena);
    }
    arenas.append(&mut pool);
    result
}

/// The rep-barrier coordinator: dispatch every shard's rep across the
/// worker pool, classify the outcomes, settle stalls with the global
/// break cycle, and assemble the merged outcome in serial proc order.
#[allow(clippy::too_many_arguments)]
fn drive(
    design: &Design,
    hbm: Hbm,
    shards: &mut Vec<Mutex<Shard>>,
    workers: usize,
    budget: u64,
    factor: u64,
    deadline: Option<(Instant, Duration)>,
    max_cycles: u64,
    exceeded: &dyn Fn() -> String,
    steals: &[AtomicU64],
    wall: Option<Duration>,
    rec: Option<&crate::telemetry::Recorder>,
) -> Result<SimOutcome, String> {
    let nshards = shards.len();
    let mut fast_t: u64 = 0;
    for rep in 0..design.repeat {
        if let Some((t0, limit)) = deadline {
            if t0.elapsed() > limit {
                return Err(format!(
                    "exact simulation of '{}' {WALL_DEADLINE_MARK} ({}ms limit, {}ms elapsed)",
                    design.name,
                    limit.as_millis(),
                    t0.elapsed().as_millis()
                ));
            }
        }
        let next = AtomicUsize::new(0);
        let slots: Mutex<Vec<Option<RepEnd>>> = Mutex::new((0..nshards).map(|_| None).collect());
        std::thread::scope(|scope| {
            for w in 0..workers {
                let next = &next;
                let slots = &slots;
                let shards = &*shards;
                let name = design.name.as_str();
                scope.spawn(move || loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= nshards {
                        break;
                    }
                    if i % workers != w {
                        steals[i].fetch_add(1, Ordering::Relaxed);
                    }
                    let mut sh =
                        shards[i].lock().unwrap_or_else(PoisonError::into_inner);
                    let end = run_rep(&mut sh, rep, fast_t, budget, factor, deadline, name);
                    slots.lock().unwrap_or_else(PoisonError::into_inner)[i] = Some(end);
                });
            }
        });
        let ends: Vec<RepEnd> = slots
            .into_inner()
            .unwrap_or_else(PoisonError::into_inner)
            .into_iter()
            .map(|e| e.expect("every shard ran its rep"))
            .collect();
        if ends.iter().any(|e| matches!(e, RepEnd::Deadlock)) {
            // deadlock diagnostics (cycle number, stuck-module list)
            // span shards — discard and reproduce them serially on the
            // pristine input state
            let sh0 = shards[0].get_mut().unwrap_or_else(PoisonError::into_inner);
            return run_exact_deadline_in(design, hbm, max_cycles, wall, &mut sh0.arena, rec);
        }
        for e in &ends {
            if let RepEnd::Wall(msg) = e {
                return Err(msg.clone());
            }
        }
        if ends.iter().any(|e| matches!(e, RepEnd::Budget)) {
            return Err(exceeded());
        }
        let final_t0 = ends
            .iter()
            .map(|e| match e {
                RepEnd::Clean { final_t0 } => *final_t0,
                _ => unreachable!("error reps returned above"),
            })
            .max()
            .expect("at least one shard");
        debug_assert!(final_t0 + 1 <= budget, "clean shards imply an in-budget rep");
        // settle sleepers with the *global* break cycle — the serial
        // engine ticked every scheduled sleeping process through it
        for m in shards.iter_mut() {
            let sh = m.get_mut().unwrap_or_else(PoisonError::into_inner);
            for i in 0..sh.procs.len() {
                if !sh.awake[i] && !sh.sleep_done[i] {
                    sh.procs[i].stalls +=
                        final_t0 / sh.stride[i] - sh.sleep_at[i] / sh.stride[i];
                }
            }
        }
        fast_t = final_t0 + 1;
    }

    // assemble the merged outcome in serial proc order
    let total: usize = shards
        .iter_mut()
        .map(|m| m.get_mut().unwrap_or_else(PoisonError::into_inner).procs.len())
        .sum();
    let mut modules: Vec<(String, u64, u64)> = vec![(String::new(), 0, 0); total];
    let mut transactions = 0u64;
    let mut arena_stats = ArenaStats::default();
    let mut out_hbm = hbm;
    for m in shards.iter_mut() {
        let sh = m.get_mut().unwrap_or_else(PoisonError::into_inner);
        for (local, p) in sh.procs.iter().enumerate() {
            modules[sh.global[local]] = (p.label.clone(), p.busy, p.stalls);
        }
        transactions += sh.ch.fifos.iter().map(|f| f.pushed).sum::<u64>();
        debug_assert_eq!(sh.arena.stats().live, 0, "transaction slots leaked");
        arena_stats.accumulate(&sh.arena.stats());
        out_hbm.absorb(std::mem::take(&mut sh.hbm));
    }
    // the serial engine's bottleneck is `max_by_key(busy)` over procs
    // in module order — the *last* maximum on ties
    let bottleneck = modules
        .iter()
        .max_by_key(|(_, busy, _)| *busy)
        .map(|(label, _, _)| label.clone())
        .unwrap_or_default();
    let slow_cycles = fast_t / factor;
    Ok(SimOutcome {
        stats: SimStats {
            slow_cycles,
            fast_cycles: fast_t,
            bottleneck,
            modules,
            transactions,
            arena: arena_stats,
        },
        hbm: out_hbm,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::lower::lower;
    use crate::hw::cost::CostModel;
    use crate::ir::builder::vecadd_sdfg;
    use crate::sim::engine::{run_exact, run_exact_reference};
    use crate::transforms::{MultiPump, PassManager, StreamingComposition, Vectorize};
    use crate::util::Rng;

    fn vecadd_design(n: i64, lanes: usize, pump: bool) -> Design {
        let mut g = vecadd_sdfg(1);
        let mut pm = PassManager::new();
        if lanes > 1 {
            pm.run(&mut g, &Vectorize::new("vadd", lanes)).unwrap();
        }
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        if pump {
            pm.run(&mut g, &MultiPump::resource(2)).unwrap();
        }
        let env = g.bind(&[("N", n)]).unwrap();
        lower(&g, &env, &CostModel::default()).unwrap()
    }

    fn inputs(n: usize, seed: u64) -> Vec<(String, Vec<f32>)> {
        let mut rng = Rng::new(seed);
        vec![("x".into(), rng.f32_vec(n)), ("y".into(), rng.f32_vec(n))]
    }

    fn outcomes_equal(a: &SimOutcome, b: &SimOutcome, outputs: &[String]) {
        assert_eq!(a.stats.slow_cycles, b.stats.slow_cycles, "slow cycles");
        assert_eq!(a.stats.fast_cycles, b.stats.fast_cycles, "fast cycles");
        assert_eq!(a.stats.transactions, b.stats.transactions, "transactions");
        assert_eq!(a.stats.bottleneck, b.stats.bottleneck, "bottleneck");
        assert_eq!(a.stats.modules, b.stats.modules, "per-module counters");
        for o in outputs {
            assert_eq!(a.hbm.read(o), b.hbm.read(o), "output '{o}'");
        }
    }

    #[test]
    fn single_pipeline_is_one_component() {
        let d = vecadd_design(256, 4, true);
        assert_eq!(shard_partition(&d).len(), 1, "one pipeline, one shard");
    }

    #[test]
    fn replication_multiplies_components_and_keeps_ctrl_prefix() {
        let d = vecadd_design(256, 4, true);
        let base = shard_partition(&d).len();
        let r = replicate_design(&d, 3);
        assert_eq!(shard_partition(&r).len(), 3 * base);
        assert_eq!(r.modules.len(), 3 * d.modules.len());
        assert_eq!(r.channels.len(), 3 * d.channels.len());
        for c in &r.channels {
            let was_ctrl = c.name.contains("__ctrl");
            let starts_ctrl = c.name.starts_with("__ctrl");
            assert_eq!(was_ctrl, starts_ctrl, "ctrl marker must stay a prefix: {}", c.name);
        }
    }

    #[test]
    fn sharded_replicated_vecadd_matches_reference_exactly() {
        for k in [2usize, 3] {
            let d = replicate_design(&vecadd_design(512, 4, true), k);
            let hbm = replicate_inputs(&inputs(512, 21), k);
            let outs: Vec<String> = (0..k).map(|i| format!("r{i}__z")).collect();
            let s = run_exact_sharded(&d, hbm.clone(), 10_000_000, 2).unwrap();
            let r = run_exact_reference(&d, hbm, 10_000_000).unwrap();
            outcomes_equal(&s, &r, &outs);
        }
    }

    #[test]
    fn sharded_single_component_delegates_and_matches_serial() {
        let d = vecadd_design(512, 4, true);
        let mut hbm = Hbm::new();
        for (name, data) in inputs(512, 22) {
            hbm.load(&name, data);
        }
        let s = run_exact_sharded(&d, hbm.clone(), 10_000_000, 4).unwrap();
        let e = run_exact(&d, hbm, 10_000_000).unwrap();
        outcomes_equal(&s, &e, &["z".into()]);
    }

    #[test]
    fn threads_one_forces_the_serial_engine() {
        let d = replicate_design(&vecadd_design(256, 4, false), 2);
        let hbm = replicate_inputs(&inputs(256, 23), 2);
        let s = run_exact_sharded(&d, hbm.clone(), 10_000_000, 1).unwrap();
        let e = run_exact(&d, hbm, 10_000_000).unwrap();
        outcomes_equal(&s, &e, &["r0__z".into(), "r1__z".into()]);
    }

    #[test]
    fn sharded_deadlock_reproduces_the_serial_report_verbatim() {
        let mut d = replicate_design(&vecadd_design(64, 4, true), 2);
        // wedge ONE replica: its writer expects more than its reader
        // produces, so one shard deadlocks while the other completes
        for m in &mut d.modules {
            if let ModuleSpec::Writer { data, elems, .. } = &mut m.spec {
                if data.starts_with("r1__") {
                    *elems += 10;
                }
            }
        }
        let hbm = replicate_inputs(&inputs(64, 24), 2);
        let s = run_exact_sharded(&d, hbm.clone(), 100_000, 2).unwrap_err();
        let r = run_exact(&d, hbm, 100_000).unwrap_err();
        assert!(r.contains("deadlock"), "{r}");
        assert_eq!(s, r, "deadlock diagnostics must match byte for byte");
    }

    #[test]
    fn sharded_budget_error_matches_serial_verbatim() {
        let d = replicate_design(&vecadd_design(4096, 4, true), 2);
        let hbm = replicate_inputs(&inputs(4096, 25), 2);
        let s = run_exact_sharded(&d, hbm.clone(), 10, 2).unwrap_err();
        let r = run_exact(&d, hbm, 10).unwrap_err();
        assert_eq!(s, r);
        assert!(s.contains("exceeded"), "{s}");
    }

    #[test]
    fn shard_arenas_are_returned_and_reused_across_runs() {
        let d = replicate_design(&vecadd_design(512, 8, true), 2);
        let mk = || replicate_inputs(&inputs(512, 26), 2);
        let mut arenas = Vec::new();
        run_exact_sharded_in(&d, mk(), 10_000_000, 2, None, &mut arenas, None).unwrap();
        assert_eq!(arenas.len(), 2, "one arena per shard, returned in order");
        let slots: Vec<u64> = arenas.iter().map(|a| a.stats().slots).collect();
        run_exact_sharded_in(&d, mk(), 10_000_000, 2, None, &mut arenas, None).unwrap();
        assert_eq!(arenas.len(), 2, "pool must not grow across runs");
        let again: Vec<u64> = arenas.iter().map(|a| a.stats().slots).collect();
        assert_eq!(slots, again, "steady-state sharded runs allocate no new slots");
        assert!(arenas.iter().all(|a| a.stats().recycle_hits > 0));
    }

    #[test]
    fn observed_sharded_run_is_bit_identical_and_counts_shards() {
        let d = replicate_design(&vecadd_design(512, 4, true), 2);
        let mk = || replicate_inputs(&inputs(512, 27), 2);
        let plain = run_exact_sharded(&d, mk(), 10_000_000, 2).unwrap();
        let rec = crate::telemetry::Recorder::new();
        let obs = run_exact_sharded_in(
            &d,
            mk(),
            10_000_000,
            2,
            None,
            &mut Vec::new(),
            Some(&rec),
        )
        .unwrap();
        outcomes_equal(&plain, &obs, &["r0__z".into(), "r1__z".into()]);
        let counters = rec.counters();
        assert!(counters.contains_key("sim.shard.0.busy"));
        assert!(counters.contains_key("sim.shard.1.busy"));
        assert!(counters.contains_key("sim.shard.0.steals"));
    }
}
