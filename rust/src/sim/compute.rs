//! Compiled tasklet programs for the simulator hot loop.
//!
//! `TaskExpr::eval` walks a tree and looks connectors up in a
//! `BTreeMap<String, f32>` — fine for validation, far too slow for the
//! per-lane inner loop of the exact engine (§Perf log in
//! EXPERIMENTS.md). [`CompiledTasklet`] flattens the expression into a
//! postorder stack program over *positional* inputs once at process
//! build time; evaluation is then a branch-predictable loop with no
//! allocation and no hashing.

use super::arena::{Arena, Txn};
use crate::ir::{BinOp, TaskExpr, Tasklet, UnOp};

/// One stack-machine instruction.
#[derive(Clone, Copy, Debug)]
pub enum TOp {
    Const(f32),
    /// Push input value at position `i` (position = index into the
    /// module's input-connector list).
    Load(usize),
    Add,
    Sub,
    Mul,
    Div,
    Min,
    Max,
    Neg,
    Abs,
    /// Pops c, b, a; pushes a*b + c.
    MulAdd,
}

/// A compiled single-output tasklet.
#[derive(Clone, Debug)]
pub struct CompiledTasklet {
    ops: Vec<TOp>,
    /// Maximum stack depth, precomputed so eval can use a fixed buffer.
    depth: usize,
}

fn flatten(e: &TaskExpr, conns: &[String], out: &mut Vec<TOp>) -> Result<(), String> {
    match e {
        TaskExpr::In(name) => {
            let pos = conns
                .iter()
                .position(|c| c == name)
                .ok_or_else(|| format!("connector '{name}' not wired"))?;
            out.push(TOp::Load(pos));
        }
        TaskExpr::Const(v) => out.push(TOp::Const(*v)),
        TaskExpr::Bin(op, a, b) => {
            flatten(a, conns, out)?;
            flatten(b, conns, out)?;
            out.push(match op {
                BinOp::Add => TOp::Add,
                BinOp::Sub => TOp::Sub,
                BinOp::Mul => TOp::Mul,
                BinOp::Div => TOp::Div,
                BinOp::Min => TOp::Min,
                BinOp::Max => TOp::Max,
            });
        }
        TaskExpr::Un(op, a) => {
            flatten(a, conns, out)?;
            out.push(match op {
                UnOp::Neg => TOp::Neg,
                UnOp::Abs => TOp::Abs,
            });
        }
        TaskExpr::MulAdd(a, b, c) => {
            flatten(a, conns, out)?;
            flatten(b, conns, out)?;
            flatten(c, conns, out)?;
            out.push(TOp::MulAdd);
        }
    }
    Ok(())
}

impl CompiledTasklet {
    /// Compile the first output of `t` against the positional
    /// connector list `conns`.
    pub fn compile(t: &Tasklet, conns: &[String]) -> Result<CompiledTasklet, String> {
        let expr = &t
            .outputs
            .first()
            .ok_or_else(|| format!("tasklet '{}' has no outputs", t.name))?
            .1;
        let mut ops = Vec::new();
        flatten(expr, conns, &mut ops)?;
        // max stack depth
        let mut depth = 0usize;
        let mut cur = 0usize;
        for op in &ops {
            match op {
                TOp::Const(_) | TOp::Load(_) => {
                    cur += 1;
                    depth = depth.max(cur);
                }
                TOp::Neg | TOp::Abs => {}
                TOp::MulAdd => cur -= 2,
                _ => cur -= 1,
            }
        }
        Ok(CompiledTasklet { ops, depth: depth.max(1) })
    }

    pub fn stack_depth(&self) -> usize {
        self.depth
    }

    /// Evaluate on positional inputs using the caller-provided stack
    /// buffer (len ≥ `stack_depth()`).
    #[inline]
    pub fn eval(&self, inputs: &[f32], stack: &mut [f32]) -> f32 {
        let mut sp = 0usize;
        for op in &self.ops {
            match *op {
                TOp::Const(v) => {
                    stack[sp] = v;
                    sp += 1;
                }
                TOp::Load(i) => {
                    stack[sp] = inputs[i];
                    sp += 1;
                }
                TOp::Add => {
                    sp -= 1;
                    stack[sp - 1] += stack[sp];
                }
                TOp::Sub => {
                    sp -= 1;
                    stack[sp - 1] -= stack[sp];
                }
                TOp::Mul => {
                    sp -= 1;
                    stack[sp - 1] *= stack[sp];
                }
                TOp::Div => {
                    sp -= 1;
                    stack[sp - 1] /= stack[sp];
                }
                TOp::Min => {
                    sp -= 1;
                    stack[sp - 1] = stack[sp - 1].min(stack[sp]);
                }
                TOp::Max => {
                    sp -= 1;
                    stack[sp - 1] = stack[sp - 1].max(stack[sp]);
                }
                TOp::Neg => stack[sp - 1] = -stack[sp - 1],
                TOp::Abs => stack[sp - 1] = stack[sp - 1].abs(),
                TOp::MulAdd => {
                    sp -= 2;
                    stack[sp - 1] = stack[sp - 1] * stack[sp] + stack[sp + 1];
                }
            }
        }
        debug_assert_eq!(sp, 1);
        stack[0]
    }

    /// Evaluate the program across `out.len()` lanes, gathering each
    /// lane's positional inputs from the popped arena transactions
    /// (`vals` and `stack` are the caller's reusable scratch buffers;
    /// `vals.len()` must equal `popped.len()`). A narrower input
    /// broadcasts its last lane, matching the pre-arena gather. Results
    /// are staged into `out` so the caller can free the inputs before
    /// allocating the output slot — the pop-to-push recycling step.
    #[inline]
    pub fn eval_lanes(
        &self,
        arena: &Arena,
        popped: &[Txn],
        vals: &mut [f32],
        stack: &mut [f32],
        out: &mut [f32],
    ) {
        for (lane, o) in out.iter_mut().enumerate() {
            for (pos, t) in popped.iter().enumerate() {
                let s = arena.get(*t);
                vals[pos] = s[lane.min(s.len() - 1)];
            }
            *o = self.eval(vals, stack);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::TaskExpr;
    use crate::util::Rng;
    use std::collections::BTreeMap;

    fn conns(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn compiled_matches_tree_eval() {
        let exprs = vec![
            TaskExpr::input("a").add(TaskExpr::input("b")),
            TaskExpr::input("a")
                .mul(TaskExpr::c(2.5))
                .sub(TaskExpr::input("b"))
                .min(TaskExpr::input("c")),
            TaskExpr::muladd(
                TaskExpr::input("a"),
                TaskExpr::input("b"),
                TaskExpr::input("c"),
            )
            .max(TaskExpr::c(-1.0)),
            TaskExpr::Un(crate::ir::UnOp::Abs, Box::new(TaskExpr::input("a").sub(TaskExpr::input("c")))),
        ];
        let cs = conns(&["a", "b", "c"]);
        let mut rng = Rng::new(5);
        for e in exprs {
            let t = Tasklet::new("t", vec![("o", e.clone())]);
            let compiled = CompiledTasklet::compile(&t, &cs).unwrap();
            let mut stack = vec![0.0f32; compiled.stack_depth()];
            for _ in 0..100 {
                let vals = [rng.f32_range(-9.0, 9.0), rng.f32_range(-9.0, 9.0), rng.f32_range(-9.0, 9.0)];
                let mut env = BTreeMap::new();
                env.insert("a".to_string(), vals[0]);
                env.insert("b".to_string(), vals[1]);
                env.insert("c".to_string(), vals[2]);
                let want = e.eval(&env);
                let got = compiled.eval(&vals, &mut stack);
                assert!(
                    (got - want).abs() < 1e-6 || (got.is_nan() && want.is_nan()),
                    "{e:?}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn unwired_connector_rejected() {
        let t = Tasklet::new("t", vec![("o", TaskExpr::input("ghost"))]);
        assert!(CompiledTasklet::compile(&t, &conns(&["a"])).is_err());
    }

    #[test]
    fn stack_depth_is_sufficient_and_tight() {
        // deep right-leaning chain: a + (b + (c + const))
        let e = TaskExpr::input("a").add(
            TaskExpr::input("b").add(TaskExpr::input("c").add(TaskExpr::c(1.0))),
        );
        let t = Tasklet::new("t", vec![("o", e)]);
        let c = CompiledTasklet::compile(&t, &conns(&["a", "b", "c"])).unwrap();
        assert_eq!(c.stack_depth(), 4);
        let mut stack = vec![0.0; c.stack_depth()];
        assert_eq!(c.eval(&[1.0, 2.0, 3.0], &mut stack), 7.0);
    }
}
