//! Compiled tasklet programs for the simulator hot loop.
//!
//! `TaskExpr::eval` walks a tree and looks connectors up in a
//! `BTreeMap<String, f32>` — fine for validation, far too slow for the
//! per-lane inner loop of the exact engine (§Perf log in
//! EXPERIMENTS.md). [`CompiledTasklet`] flattens the expression into a
//! postorder stack program over *positional* inputs once at process
//! build time; evaluation is then a branch-predictable loop with no
//! allocation and no hashing.

use super::arena::{Arena, Txn};
use crate::ir::{BinOp, TaskExpr, Tasklet, UnOp};

/// One stack-machine instruction.
#[derive(Clone, Copy, Debug)]
pub enum TOp {
    Const(f32),
    /// Push input value at position `i` (position = index into the
    /// module's input-connector list).
    Load(usize),
    Add,
    Sub,
    Mul,
    Div,
    Min,
    Max,
    Neg,
    Abs,
    /// Pops c, b, a; pushes a*b + c.
    MulAdd,
}

/// A compiled single-output tasklet.
#[derive(Clone, Debug)]
pub struct CompiledTasklet {
    ops: Vec<TOp>,
    /// Maximum stack depth, precomputed so eval can use a fixed buffer.
    depth: usize,
}

fn flatten(e: &TaskExpr, conns: &[String], out: &mut Vec<TOp>) -> Result<(), String> {
    match e {
        TaskExpr::In(name) => {
            let pos = conns
                .iter()
                .position(|c| c == name)
                .ok_or_else(|| format!("connector '{name}' not wired"))?;
            out.push(TOp::Load(pos));
        }
        TaskExpr::Const(v) => out.push(TOp::Const(*v)),
        TaskExpr::Bin(op, a, b) => {
            flatten(a, conns, out)?;
            flatten(b, conns, out)?;
            out.push(match op {
                BinOp::Add => TOp::Add,
                BinOp::Sub => TOp::Sub,
                BinOp::Mul => TOp::Mul,
                BinOp::Div => TOp::Div,
                BinOp::Min => TOp::Min,
                BinOp::Max => TOp::Max,
            });
        }
        TaskExpr::Un(op, a) => {
            flatten(a, conns, out)?;
            out.push(match op {
                UnOp::Neg => TOp::Neg,
                UnOp::Abs => TOp::Abs,
            });
        }
        TaskExpr::MulAdd(a, b, c) => {
            flatten(a, conns, out)?;
            flatten(b, conns, out)?;
            flatten(c, conns, out)?;
            out.push(TOp::MulAdd);
        }
    }
    Ok(())
}

impl CompiledTasklet {
    /// Compile the first output of `t` against the positional
    /// connector list `conns`.
    pub fn compile(t: &Tasklet, conns: &[String]) -> Result<CompiledTasklet, String> {
        let expr = &t
            .outputs
            .first()
            .ok_or_else(|| format!("tasklet '{}' has no outputs", t.name))?
            .1;
        let mut ops = Vec::new();
        flatten(expr, conns, &mut ops)?;
        // max stack depth
        let mut depth = 0usize;
        let mut cur = 0usize;
        for op in &ops {
            match op {
                TOp::Const(_) | TOp::Load(_) => {
                    cur += 1;
                    depth = depth.max(cur);
                }
                TOp::Neg | TOp::Abs => {}
                TOp::MulAdd => cur -= 2,
                _ => cur -= 1,
            }
        }
        Ok(CompiledTasklet { ops, depth: depth.max(1) })
    }

    pub fn stack_depth(&self) -> usize {
        self.depth
    }

    /// Evaluate on positional inputs using the caller-provided stack
    /// buffer (len ≥ `stack_depth()`).
    #[inline]
    pub fn eval(&self, inputs: &[f32], stack: &mut [f32]) -> f32 {
        let mut sp = 0usize;
        for op in &self.ops {
            match *op {
                TOp::Const(v) => {
                    stack[sp] = v;
                    sp += 1;
                }
                TOp::Load(i) => {
                    stack[sp] = inputs[i];
                    sp += 1;
                }
                TOp::Add => {
                    sp -= 1;
                    stack[sp - 1] += stack[sp];
                }
                TOp::Sub => {
                    sp -= 1;
                    stack[sp - 1] -= stack[sp];
                }
                TOp::Mul => {
                    sp -= 1;
                    stack[sp - 1] *= stack[sp];
                }
                TOp::Div => {
                    sp -= 1;
                    stack[sp - 1] /= stack[sp];
                }
                TOp::Min => {
                    sp -= 1;
                    stack[sp - 1] = stack[sp - 1].min(stack[sp]);
                }
                TOp::Max => {
                    sp -= 1;
                    stack[sp - 1] = stack[sp - 1].max(stack[sp]);
                }
                TOp::Neg => stack[sp - 1] = -stack[sp - 1],
                TOp::Abs => stack[sp - 1] = stack[sp - 1].abs(),
                TOp::MulAdd => {
                    sp -= 2;
                    stack[sp - 1] = stack[sp - 1] * stack[sp] + stack[sp + 1];
                }
            }
        }
        debug_assert_eq!(sp, 1);
        stack[0]
    }

    /// Evaluate the program across `out.len()` lanes, gathering each
    /// lane's positional inputs from the popped arena transactions
    /// (`vals` and `stack` are the caller's reusable scratch buffers;
    /// `vals.len()` must equal `popped.len()`). A narrower input
    /// broadcasts its last lane, matching the pre-arena gather. Results
    /// are staged into `out` so the caller can free the inputs before
    /// allocating the output slot — the pop-to-push recycling step.
    ///
    /// Dispatches to the chunked 8-lane evaluator when the crate is
    /// built with the `simd` feature, and to the lane-at-a-time scalar
    /// loop otherwise. The two are bit-identical (NaN payloads and
    /// signed zeros included) — property-pinned by this module's tests
    /// and `rust/tests/properties.rs` — so the feature is purely a
    /// performance switch.
    #[inline]
    pub fn eval_lanes(
        &self,
        arena: &Arena,
        popped: &[Txn],
        vals: &mut [f32],
        stack: &mut [f32],
        out: &mut [f32],
    ) {
        #[cfg(feature = "simd")]
        self.eval_lanes_chunked(arena, popped, vals, stack, out);
        #[cfg(not(feature = "simd"))]
        self.eval_lanes_scalar(arena, popped, vals, stack, out);
    }

    /// The lane-at-a-time reference evaluator (the pre-SIMD
    /// `eval_lanes` body, kept verbatim as the oracle the chunked path
    /// is tested against and the baseline `tvec bench` measures).
    #[inline]
    pub fn eval_lanes_scalar(
        &self,
        arena: &Arena,
        popped: &[Txn],
        vals: &mut [f32],
        stack: &mut [f32],
        out: &mut [f32],
    ) {
        for (lane, o) in out.iter_mut().enumerate() {
            for (pos, t) in popped.iter().enumerate() {
                let s = arena.get(*t);
                vals[pos] = s[lane.min(s.len() - 1)];
            }
            *o = self.eval(vals, stack);
        }
    }

    /// Superword evaluator: runs the stack program op-major over
    /// 8-lane chunks of the contiguous arena slabs (fixed-size lane
    /// groups on the stack, no allocation). Falls back to
    /// [`Self::eval_lanes_scalar`] for programs deeper than
    /// [`MAX_SIMD_DEPTH`] or wider than [`MAX_SIMD_INS`] inputs, and
    /// finishes a non-multiple-of-8 lane count with the scalar loop
    /// (the DESIGN.md §15 fallback matrix). Every lane op uses the same
    /// scalar f32 primitive as [`Self::eval`] — `a*b + c` stays two
    /// roundings, `min`/`max` keep `f32::min`/`f32::max` NaN semantics
    /// — so results are bit-identical to the scalar path; the x86-64
    /// AVX fast path under the `simd` feature only accelerates
    /// add/sub/mul/div, the four ops IEEE 754 fixes exactly.
    #[inline]
    pub fn eval_lanes_chunked(
        &self,
        arena: &Arena,
        popped: &[Txn],
        vals: &mut [f32],
        stack: &mut [f32],
        out: &mut [f32],
    ) {
        if popped.len() > MAX_SIMD_INS || self.depth > MAX_SIMD_DEPTH {
            return self.eval_lanes_scalar(arena, popped, vals, stack, out);
        }
        let lanes = out.len();
        let full = lanes - lanes % CHUNK;
        let mut vals8 = [[0.0f32; CHUNK]; MAX_SIMD_INS];
        let mut stack8 = [[0.0f32; CHUNK]; MAX_SIMD_DEPTH];
        let mut base = 0usize;
        while base < full {
            for (pos, t) in popped.iter().enumerate() {
                let s = arena.get(*t);
                let last = s.len() - 1;
                for (l, v) in vals8[pos].iter_mut().enumerate() {
                    *v = s[(base + l).min(last)];
                }
            }
            let mut sp = 0usize;
            for op in &self.ops {
                match *op {
                    TOp::Const(v) => {
                        stack8[sp] = [v; CHUNK];
                        sp += 1;
                    }
                    TOp::Load(i) => {
                        stack8[sp] = vals8[i];
                        sp += 1;
                    }
                    TOp::Add => {
                        sp -= 1;
                        let (lo, hi) = stack8.split_at_mut(sp);
                        add8(&mut lo[sp - 1], &hi[0]);
                    }
                    TOp::Sub => {
                        sp -= 1;
                        let (lo, hi) = stack8.split_at_mut(sp);
                        sub8(&mut lo[sp - 1], &hi[0]);
                    }
                    TOp::Mul => {
                        sp -= 1;
                        let (lo, hi) = stack8.split_at_mut(sp);
                        mul8(&mut lo[sp - 1], &hi[0]);
                    }
                    TOp::Div => {
                        sp -= 1;
                        let (lo, hi) = stack8.split_at_mut(sp);
                        div8(&mut lo[sp - 1], &hi[0]);
                    }
                    TOp::Min => {
                        sp -= 1;
                        let (lo, hi) = stack8.split_at_mut(sp);
                        let (a, b) = (&mut lo[sp - 1], &hi[0]);
                        for l in 0..CHUNK {
                            a[l] = a[l].min(b[l]);
                        }
                    }
                    TOp::Max => {
                        sp -= 1;
                        let (lo, hi) = stack8.split_at_mut(sp);
                        let (a, b) = (&mut lo[sp - 1], &hi[0]);
                        for l in 0..CHUNK {
                            a[l] = a[l].max(b[l]);
                        }
                    }
                    TOp::Neg => {
                        for v in stack8[sp - 1].iter_mut() {
                            *v = -*v;
                        }
                    }
                    TOp::Abs => {
                        for v in stack8[sp - 1].iter_mut() {
                            *v = v.abs();
                        }
                    }
                    TOp::MulAdd => {
                        sp -= 2;
                        let (lo, hi) = stack8.split_at_mut(sp);
                        let (a, b, c) = (&mut lo[sp - 1], &hi[0], &hi[1]);
                        for l in 0..CHUNK {
                            // two roundings, like the scalar eval — not fma
                            a[l] = a[l] * b[l] + c[l];
                        }
                    }
                }
            }
            debug_assert_eq!(sp, 1);
            out[base..base + CHUNK].copy_from_slice(&stack8[0]);
            base += CHUNK;
        }
        // scalar tail: same primitives, bit-identical results
        for (lane, o) in out.iter_mut().enumerate().skip(base) {
            for (pos, t) in popped.iter().enumerate() {
                let s = arena.get(*t);
                vals[pos] = s[lane.min(s.len() - 1)];
            }
            *o = self.eval(vals, stack);
        }
    }
}

/// Lane-group width of the chunked evaluator (one AVX `f32x8`).
pub const CHUNK: usize = 8;
/// Deepest stack program the chunked evaluator handles in its
/// fixed-size lane-group stack; deeper programs fall back to scalar.
pub const MAX_SIMD_DEPTH: usize = 16;
/// Widest input list the chunked evaluator gathers into its fixed-size
/// lane-group buffer; wider modules fall back to scalar.
pub const MAX_SIMD_INS: usize = 8;

#[inline]
fn add8(a: &mut [f32; CHUNK], b: &[f32; CHUNK]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if avx_usable() {
        // SAFETY: AVX support runtime-checked above
        unsafe { avx::add8(a, b) };
        return;
    }
    for l in 0..CHUNK {
        a[l] += b[l];
    }
}

#[inline]
fn sub8(a: &mut [f32; CHUNK], b: &[f32; CHUNK]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if avx_usable() {
        // SAFETY: AVX support runtime-checked above
        unsafe { avx::sub8(a, b) };
        return;
    }
    for l in 0..CHUNK {
        a[l] -= b[l];
    }
}

#[inline]
fn mul8(a: &mut [f32; CHUNK], b: &[f32; CHUNK]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if avx_usable() {
        // SAFETY: AVX support runtime-checked above
        unsafe { avx::mul8(a, b) };
        return;
    }
    for l in 0..CHUNK {
        a[l] *= b[l];
    }
}

#[inline]
fn div8(a: &mut [f32; CHUNK], b: &[f32; CHUNK]) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if avx_usable() {
        // SAFETY: AVX support runtime-checked above
        unsafe { avx::div8(a, b) };
        return;
    }
    for l in 0..CHUNK {
        a[l] /= b[l];
    }
}

/// Cached runtime AVX probe: the chunked evaluator stays portable on
/// x86-64 machines without AVX (the scalar lane loops take over).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn avx_usable() -> bool {
    use std::sync::OnceLock;
    static AVX: OnceLock<bool> = OnceLock::new();
    *AVX.get_or_init(|| is_x86_feature_detected!("avx"))
}

/// The `std::arch` fast path: only add/sub/mul/div, the four lane ops
/// IEEE 754 defines exactly (so vector and scalar results are
/// bit-identical, NaN payloads included). `min`/`max` stay scalar on
/// purpose — `vminps`/`vmaxps` NaN and signed-zero semantics differ
/// from `f32::min`/`f32::max`.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx {
    use super::CHUNK;
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx")]
    pub unsafe fn add8(a: &mut [f32; CHUNK], b: &[f32; CHUNK]) {
        let v = _mm256_add_ps(_mm256_loadu_ps(a.as_ptr()), _mm256_loadu_ps(b.as_ptr()));
        _mm256_storeu_ps(a.as_mut_ptr(), v);
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn sub8(a: &mut [f32; CHUNK], b: &[f32; CHUNK]) {
        let v = _mm256_sub_ps(_mm256_loadu_ps(a.as_ptr()), _mm256_loadu_ps(b.as_ptr()));
        _mm256_storeu_ps(a.as_mut_ptr(), v);
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn mul8(a: &mut [f32; CHUNK], b: &[f32; CHUNK]) {
        let v = _mm256_mul_ps(_mm256_loadu_ps(a.as_ptr()), _mm256_loadu_ps(b.as_ptr()));
        _mm256_storeu_ps(a.as_mut_ptr(), v);
    }

    #[target_feature(enable = "avx")]
    pub unsafe fn div8(a: &mut [f32; CHUNK], b: &[f32; CHUNK]) {
        let v = _mm256_div_ps(_mm256_loadu_ps(a.as_ptr()), _mm256_loadu_ps(b.as_ptr()));
        _mm256_storeu_ps(a.as_mut_ptr(), v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::TaskExpr;
    use crate::util::Rng;
    use std::collections::BTreeMap;

    fn conns(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn compiled_matches_tree_eval() {
        let exprs = vec![
            TaskExpr::input("a").add(TaskExpr::input("b")),
            TaskExpr::input("a")
                .mul(TaskExpr::c(2.5))
                .sub(TaskExpr::input("b"))
                .min(TaskExpr::input("c")),
            TaskExpr::muladd(
                TaskExpr::input("a"),
                TaskExpr::input("b"),
                TaskExpr::input("c"),
            )
            .max(TaskExpr::c(-1.0)),
            TaskExpr::Un(crate::ir::UnOp::Abs, Box::new(TaskExpr::input("a").sub(TaskExpr::input("c")))),
        ];
        let cs = conns(&["a", "b", "c"]);
        let mut rng = Rng::new(5);
        for e in exprs {
            let t = Tasklet::new("t", vec![("o", e.clone())]);
            let compiled = CompiledTasklet::compile(&t, &cs).unwrap();
            let mut stack = vec![0.0f32; compiled.stack_depth()];
            for _ in 0..100 {
                let vals = [rng.f32_range(-9.0, 9.0), rng.f32_range(-9.0, 9.0), rng.f32_range(-9.0, 9.0)];
                let mut env = BTreeMap::new();
                env.insert("a".to_string(), vals[0]);
                env.insert("b".to_string(), vals[1]);
                env.insert("c".to_string(), vals[2]);
                let want = e.eval(&env);
                let got = compiled.eval(&vals, &mut stack);
                assert!(
                    (got - want).abs() < 1e-6 || (got.is_nan() && want.is_nan()),
                    "{e:?}: {got} vs {want}"
                );
            }
        }
    }

    #[test]
    fn unwired_connector_rejected() {
        let t = Tasklet::new("t", vec![("o", TaskExpr::input("ghost"))]);
        assert!(CompiledTasklet::compile(&t, &conns(&["a"])).is_err());
    }

    /// Build an arena transaction of `lanes` values.
    fn txn(arena: &mut Arena, vals: &[f32]) -> Txn {
        arena.alloc_from(vals)
    }

    fn chunked_equals_scalar(
        c: &CompiledTasklet,
        arena: &Arena,
        popped: &[Txn],
        lanes: usize,
    ) {
        let mut vals = vec![0.0f32; popped.len()];
        let mut stack = vec![0.0f32; c.stack_depth()];
        let mut a = vec![0.0f32; lanes];
        let mut b = vec![0.0f32; lanes];
        c.eval_lanes_scalar(arena, popped, &mut vals, &mut stack, &mut a);
        c.eval_lanes_chunked(arena, popped, &mut vals, &mut stack, &mut b);
        let abits: Vec<u32> = a.iter().map(|v| v.to_bits()).collect();
        let bbits: Vec<u32> = b.iter().map(|v| v.to_bits()).collect();
        assert_eq!(abits, bbits, "chunked and scalar lanes must be bit-identical");
    }

    #[test]
    fn chunked_lanes_bit_identical_incl_nan_inf_and_tails() {
        let exprs = vec![
            TaskExpr::input("a").add(TaskExpr::input("b")),
            TaskExpr::input("a").sub(TaskExpr::input("b")).mul(TaskExpr::input("c")),
            TaskExpr::Bin(
                BinOp::Div,
                Box::new(TaskExpr::input("a")),
                Box::new(TaskExpr::input("b")),
            ),
            TaskExpr::input("a").min(TaskExpr::input("b")).max(TaskExpr::input("c")),
            TaskExpr::muladd(
                TaskExpr::input("a"),
                TaskExpr::input("b"),
                TaskExpr::input("c"),
            ),
            TaskExpr::Un(
                crate::ir::UnOp::Abs,
                Box::new(TaskExpr::Un(
                    crate::ir::UnOp::Neg,
                    Box::new(TaskExpr::input("a").sub(TaskExpr::c(0.5))),
                )),
            ),
        ];
        let cs = conns(&["a", "b", "c"]);
        let mut rng = Rng::new(77);
        // special values stress the IEEE edge cases the fast path must
        // preserve: NaN propagation, ±0, infinities, 0/0
        let special = [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 0.0, -0.0];
        for e in exprs {
            let t = Tasklet::new("t", vec![("o", e)]);
            let c = CompiledTasklet::compile(&t, &cs).unwrap();
            for lanes in [1usize, 5, 8, 13, 16, 20] {
                let mut arena = Arena::new();
                let mk = |rng: &mut Rng, arena: &mut Arena| {
                    let data: Vec<f32> = (0..lanes)
                        .map(|_| {
                            if rng.below(5) == 0 {
                                special[rng.below(special.len() as u64) as usize]
                            } else {
                                rng.f32_range(-9.0, 9.0)
                            }
                        })
                        .collect();
                    txn(arena, &data)
                };
                let popped =
                    vec![mk(&mut rng, &mut arena), mk(&mut rng, &mut arena), mk(&mut rng, &mut arena)];
                chunked_equals_scalar(&c, &arena, &popped, lanes);
            }
        }
    }

    #[test]
    fn chunked_broadcasts_narrow_inputs_like_scalar() {
        let e = TaskExpr::input("a").add(TaskExpr::input("b"));
        let t = Tasklet::new("t", vec![("o", e)]);
        let c = CompiledTasklet::compile(&t, &conns(&["a", "b"])).unwrap();
        let mut arena = Arena::new();
        let wide = txn(&mut arena, &(0..16).map(|i| i as f32).collect::<Vec<_>>());
        let narrow = txn(&mut arena, &[100.0]); // broadcasts its last lane
        chunked_equals_scalar(&c, &arena, &[wide, narrow], 16);
    }

    #[test]
    fn deep_programs_fall_back_to_scalar_and_still_match() {
        // right-leaning chain deeper than MAX_SIMD_DEPTH
        let mut e = TaskExpr::c(1.0);
        for _ in 0..(MAX_SIMD_DEPTH + 4) {
            e = TaskExpr::input("a").add(e);
        }
        let t = Tasklet::new("t", vec![("o", e)]);
        let c = CompiledTasklet::compile(&t, &conns(&["a"])).unwrap();
        assert!(c.stack_depth() > MAX_SIMD_DEPTH);
        let mut arena = Arena::new();
        let a = txn(&mut arena, &(0..8).map(|i| 0.25 * i as f32).collect::<Vec<_>>());
        chunked_equals_scalar(&c, &arena, &[a], 8);
    }

    #[test]
    fn stack_depth_is_sufficient_and_tight() {
        // deep right-leaning chain: a + (b + (c + const))
        let e = TaskExpr::input("a").add(
            TaskExpr::input("b").add(TaskExpr::input("c").add(TaskExpr::c(1.0))),
        );
        let t = Tasklet::new("t", vec![("o", e)]);
        let c = CompiledTasklet::compile(&t, &conns(&["a", "b", "c"])).unwrap();
        assert_eq!(c.stack_depth(), 4);
        let mut stack = vec![0.0; c.stack_depth()];
        assert_eq!(c.eval(&[1.0, 2.0, 3.0], &mut stack), 7.0);
    }
}
