//! The three execution modes over a design netlist.

use super::channel::{Channels, Fifo};
use super::memory::Hbm;
use super::process::Proc;
use super::stats::SimStats;
use crate::codegen::design::{Design, ModuleSpec};
use crate::ir::ClockDomain;

/// Result of a functional or exact run.
#[derive(Debug)]
pub struct SimOutcome {
    pub stats: SimStats,
    /// Final HBM state (output containers hold the computed results).
    pub hbm: Hbm,
}

fn build_channels(design: &Design) -> Channels {
    let mut ch = Channels::default();
    for c in &design.channels {
        ch.fifos.push(Fifo::new(&c.name, c.lanes, c.depth));
    }
    ch
}

fn build_procs(design: &Design, ch: &Channels) -> Vec<Proc> {
    design
        .modules
        .iter()
        .filter(|m| !matches!(&m.spec, ModuleSpec::Sync { input, .. } if input.starts_with("__ctrl")))
        .map(|m| Proc::build(&m.spec, m.domain, ch))
        .collect()
}

/// The fast time base: the largest clock ratio in the design. Mixed
/// per-region designs carry several fast domains; every factor divides
/// this one (enforced by `MultiPump::can_apply`), so a domain at
/// factor f ticks every `base / f` fast cycles and the slow domain
/// every `base`.
fn fast_time_base(design: &Design) -> u64 {
    design
        .modules
        .iter()
        .map(|m| m.domain.factor() as u64)
        .max()
        .unwrap_or(1)
        .max(design.pump.map(|(m, _)| m as u64).unwrap_or(1))
}

/// Functional execution: dataflow order, unbounded queues, real data.
/// `hbm` must hold every input container; output containers are
/// allocated automatically.
pub fn run_functional(design: &Design, mut hbm: Hbm) -> Result<SimOutcome, String> {
    for (name, elems, _) in &design.arrays {
        hbm.alloc(name, *elems);
    }
    let mut ch = build_channels(design);
    let mut procs = build_procs(design, &ch);

    let mut transactions = 0u64;
    for rep in 0..design.repeat {
        if rep > 0 {
            for p in procs.iter_mut() {
                p.reset_for_repeat();
            }
        }
        // drain to fixpoint
        let mut rounds = 0usize;
        loop {
            let mut any = false;
            for p in procs.iter_mut() {
                if p.drain_functional(&mut ch, &mut hbm) {
                    any = true;
                }
            }
            if !any {
                break;
            }
            rounds += 1;
            if rounds > 1_000_000 {
                return Err(format!("functional run of '{}' did not converge", design.name));
            }
        }
        // every process must have finished its work
        for p in &procs {
            if !p.done(&ch) {
                return Err(format!(
                    "functional deadlock in '{}': module '{}' incomplete (repeat {rep})",
                    design.name, p.label
                ));
            }
        }
        transactions += ch.fifos.iter().map(|f| f.popped).sum::<u64>();
    }
    if !ch.all_empty() {
        let leftover: Vec<&str> = ch
            .fifos
            .iter()
            .filter(|f| !f.is_empty())
            .map(|f| f.name.as_str())
            .collect();
        return Err(format!("tokens left in channels: {leftover:?}"));
    }
    Ok(SimOutcome {
        stats: SimStats { transactions, ..Default::default() },
        hbm,
    })
}

/// Exact cycle-stepped execution with bounded FIFOs and backpressure.
/// Intended for small instances (tests validating the rate model).
pub fn run_exact(design: &Design, mut hbm: Hbm, max_cycles: u64) -> Result<SimOutcome, String> {
    for (name, elems, _) in &design.arrays {
        hbm.alloc(name, *elems);
    }
    let factor = fast_time_base(design);
    let mut ch = build_channels(design);
    let mut procs = build_procs(design, &ch);

    let mut fast_t: u64 = 0;
    for rep in 0..design.repeat {
        if rep > 0 {
            for p in procs.iter_mut() {
                p.reset_for_repeat();
            }
        }
        let mut idle_streak = 0u32;
        loop {
            let mut any = false;
            for p in procs.iter_mut() {
                let ticks_now = match p.domain {
                    ClockDomain::Slow => fast_t % factor == 0,
                    ClockDomain::Fast { factor: f } => {
                        fast_t % (factor / (f as u64)).max(1) == 0
                    }
                };
                if ticks_now && p.tick(fast_t, &mut ch, &mut hbm) {
                    any = true;
                }
            }
            fast_t += 1;
            if fast_t > max_cycles * factor {
                return Err(format!(
                    "exact simulation of '{}' exceeded {max_cycles} slow cycles",
                    design.name
                ));
            }
            if any {
                idle_streak = 0;
            } else {
                idle_streak += 1;
                let all_done = procs.iter().all(|p| p.done(&ch));
                if all_done && ch.all_empty() {
                    break;
                }
                if idle_streak > 8 * factor as u32 {
                    let stuck: Vec<&str> = procs
                        .iter()
                        .filter(|p| !p.done(&ch))
                        .map(|p| p.label.as_str())
                        .collect();
                    return Err(format!(
                        "deadlock in '{}' at fast cycle {fast_t}: stuck modules {stuck:?}",
                        design.name
                    ));
                }
            }
        }
    }

    let slow_cycles = fast_t / factor;
    let bottleneck = procs
        .iter()
        .max_by_key(|p| p.busy)
        .map(|p| p.label.clone())
        .unwrap_or_default();
    let modules = procs.iter().map(|p| (p.label.clone(), p.busy, p.stalls)).collect();
    let transactions = ch.fifos.iter().map(|f| f.pushed).sum();
    Ok(SimOutcome {
        stats: SimStats {
            slow_cycles,
            fast_cycles: fast_t,
            bottleneck,
            modules,
            transactions,
        },
        hbm,
    })
}

/// Steady-state rate analysis: cycle count for arbitrarily large
/// workloads in O(#modules). The bottleneck is the module with the
/// largest total service time; pipeline-fill latencies are added along
/// the module list (designs here are feed-forward chains).
pub fn rate_model(design: &Design) -> SimStats {
    let factor = fast_time_base(design);
    let mut worst: (f64, String) = (0.0, String::new());
    let mut fill: f64 = 0.0;
    let mut modules = Vec::new();

    for m in &design.modules {
        let dom = match m.domain {
            ClockDomain::Slow => 1u64,
            ClockDomain::Fast { factor } => factor as u64,
        };
        // (total transactions, cycles per txn in own domain, extra fill)
        let (txns, cpt, lat) = match &m.spec {
            ModuleSpec::Reader { elems, lanes, bytes_per_cycle, .. }
            | ModuleSpec::Writer { elems, lanes, bytes_per_cycle, .. } => {
                let cpt = ((lanes * 4 + bytes_per_cycle - 1) / bytes_per_cycle).max(1) as u64;
                (*elems as u64, cpt, 64.0)
            }
            ModuleSpec::Compute { iterations, ii, latency, .. } => {
                (*iterations as u64, *ii, *latency as f64)
            }
            ModuleSpec::Sync { input, .. } => {
                if input.starts_with("__ctrl") {
                    continue;
                }
                (0, 1, 3.0) // syncs never bottleneck; they add latency
            }
            ModuleSpec::Issuer { .. } | ModuleSpec::Packer { .. } => (0, 1, 1.0),
            ModuleSpec::GemmCore { n, m: mm, k, pes, lanes, .. } => {
                let work = (*n as u64) * (*mm as u64) * (*k as u64);
                let cycles = work / ((pes * lanes) as u64).max(1);
                // drain of C adds n*m/lanes cycles
                let drain = (*n as u64) * (*mm as u64) / (*lanes as u64).max(1);
                (cycles + drain, 1, 512.0)
            }
            ModuleSpec::StencilCore { nx, ny, nz, lanes, .. } => {
                let txns = (nx * ny * nz / lanes.max(&1)) as u64;
                // warmup: one plane + one row before the first output
                let warm = ((ny * nz + nz) / lanes.max(&1)) as f64;
                // chained stages are independent kernels with
                // synchronization steps between them (paper §4.3);
                // the handshake costs ~15 % steady-state slack
                (txns + txns / 7, 1, warm)
            }
            ModuleSpec::FwCore { n, ii, lanes, .. } => {
                let txns = ((n * n) as u64) / (*lanes as u64).max(1);
                (txns, *ii, 32.0)
            }
        };
        // service time in slow cycles
        let service = (txns as f64) * (cpt as f64) / (dom as f64);
        modules.push((m.spec.label(), service as u64, 0));
        if service > worst.0 {
            worst = (service, m.spec.label());
        }
        // fill: memory/burst latencies overlap across parallel
        // readers/writers (count the max once, below); pipeline fills of
        // chained modules accumulate along the path
        match &m.spec {
            ModuleSpec::Reader { .. } | ModuleSpec::Writer { .. } | ModuleSpec::GemmCore { .. } => {
                fill = fill.max(lat / dom as f64);
            }
            _ => fill += lat / dom as f64,
        }
    }

    let per_rep = worst.0 + fill + 16.0; // 16: kernel start handshake
    let slow_cycles = (per_rep * design.repeat as f64) as u64;
    SimStats {
        slow_cycles,
        fast_cycles: slow_cycles * factor,
        bottleneck: worst.1,
        modules,
        transactions: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::lower::lower;
    use crate::hw::cost::CostModel;
    use crate::ir::builder::vecadd_sdfg;
    use crate::transforms::{MultiPump, PassManager, StreamingComposition, Vectorize};
    use crate::util::Rng;

    fn vecadd_design(n: i64, lanes: usize, pump: bool) -> Design {
        let mut g = vecadd_sdfg(1);
        let mut pm = PassManager::new();
        if lanes > 1 {
            pm.run(&mut g, &Vectorize::new("vadd", lanes)).unwrap();
        }
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        if pump {
            pm.run(&mut g, &MultiPump::resource(2)).unwrap();
        }
        let env = g.bind(&[("N", n)]).unwrap();
        lower(&g, &env, &CostModel::default()).unwrap()
    }

    fn input_hbm(n: usize, seed: u64) -> Hbm {
        let mut rng = Rng::new(seed);
        let mut hbm = Hbm::new();
        hbm.load("x", rng.f32_vec(n));
        hbm.load("y", rng.f32_vec(n));
        hbm
    }

    #[test]
    fn functional_vecadd_is_correct() {
        let n = 256usize;
        let d = vecadd_design(n as i64, 4, false);
        let hbm = input_hbm(n, 1);
        let (x, y) = (hbm.read("x").to_vec(), hbm.read("y").to_vec());
        let out = run_functional(&d, hbm).unwrap();
        let z = out.hbm.read("z");
        for i in 0..n {
            assert_eq!(z[i], x[i] + y[i], "element {i}");
        }
    }

    #[test]
    fn functional_vecadd_double_pumped_matches_original() {
        let n = 512usize;
        let d_o = vecadd_design(n as i64, 4, false);
        let d_dp = vecadd_design(n as i64, 4, true);
        let hbm = input_hbm(n, 2);
        let z_o = run_functional(&d_o, hbm.clone()).unwrap().hbm.read("z").to_vec();
        let z_dp = run_functional(&d_dp, hbm).unwrap().hbm.read("z").to_vec();
        assert_eq!(z_o, z_dp, "multi-pumping must not change results");
    }

    #[test]
    fn exact_vecadd_runs_and_matches_functional() {
        let n = 256usize;
        let d = vecadd_design(n as i64, 4, false);
        let hbm = input_hbm(n, 3);
        let f = run_functional(&d, hbm.clone()).unwrap();
        let e = run_exact(&d, hbm, 1_000_000).unwrap();
        assert_eq!(e.hbm.read("z"), f.hbm.read("z"));
        // ~n/lanes cycles + overheads
        assert!(e.stats.slow_cycles >= (n / 4) as u64);
        assert!(e.stats.slow_cycles < 3 * (n as u64), "{}", e.stats.slow_cycles);
    }

    #[test]
    fn exact_double_pumped_matches_functional_data() {
        let n = 256usize;
        let d = vecadd_design(n as i64, 4, true);
        let hbm = input_hbm(n, 4);
        let f = run_functional(&d, hbm.clone()).unwrap();
        let e = run_exact(&d, hbm, 1_000_000).unwrap();
        assert_eq!(e.hbm.read("z"), f.hbm.read("z"));
    }

    #[test]
    fn rate_model_agrees_with_exact_on_vecadd() {
        for pump in [false, true] {
            let n = 4096usize;
            let d = vecadd_design(n as i64, 4, pump);
            let hbm = input_hbm(n, 5);
            let e = run_exact(&d, hbm, 10_000_000).unwrap();
            let r = rate_model(&d);
            let ratio = r.slow_cycles as f64 / e.stats.slow_cycles as f64;
            assert!(
                (0.85..1.15).contains(&ratio),
                "pump={pump}: rate {} vs exact {} (ratio {ratio:.3})",
                r.slow_cycles,
                e.stats.slow_cycles
            );
        }
    }

    #[test]
    fn double_pumping_preserves_throughput_resource_mode() {
        // resource mode: same throughput (per paper §2.1) — cycle counts
        // within a few percent of each other
        let n = 4096usize;
        let e_o = run_exact(&vecadd_design(n as i64, 4, false), input_hbm(n, 6), 10_000_000)
            .unwrap();
        let e_dp = run_exact(&vecadd_design(n as i64, 4, true), input_hbm(n, 6), 10_000_000)
            .unwrap();
        let ratio = e_dp.stats.slow_cycles as f64 / e_o.stats.slow_cycles as f64;
        assert!((0.9..1.25).contains(&ratio), "DP/O cycle ratio {ratio}");
    }

    #[test]
    fn deadlock_detected() {
        // a design whose writer expects more than the reader produces
        let mut d = vecadd_design(64, 1, false);
        for m in &mut d.modules {
            if let ModuleSpec::Writer { elems, .. } = &mut m.spec {
                *elems += 10;
            }
        }
        let err = run_exact(&d, input_hbm(64, 7), 100_000).unwrap_err();
        assert!(err.contains("deadlock"), "{err}");
    }
}
