//! The three execution modes over a design netlist.
//!
//! All modes move their transactions through a pooled [`Arena`]
//! (DESIGN.md §10). The plain entry points create a private arena; the
//! `_in` variants run inside a caller-provided one, so repeated runs —
//! a DSE verify sweep, the bench's timed iterations, the two engines
//! inside [`exact_engines_agree`] — reuse the slabs the first run
//! established and allocate nothing in steady state. Every `_in` entry
//! performs a high-water-mark [`Arena::reset`] on entry (slabs and
//! peaks persist; live slots from an aborted previous run are
//! reclaimed).

use std::time::{Duration, Instant};

use super::arena::{Arena, ArenaStats};
use super::channel::{Channels, Fifo};
use super::memory::Hbm;
use super::process::Proc;
use super::stats::SimStats;
use crate::codegen::design::{Design, ModuleSpec};
use crate::ir::ClockDomain;

/// Marker embedded in every wall-deadline error message, so callers
/// (the DSE supervision layer) can classify a reaped simulation without
/// string-matching incidental wording.
pub const WALL_DEADLINE_MARK: &str = "exceeded its wall-clock deadline";

/// Is this simulation error a budget exhaustion (wall-clock deadline or
/// slow-cycle ceiling), as opposed to a genuine deadlock or misbuild?
/// The DSE verify path maps these to `FailKind::Timeout`.
pub fn is_timeout_error(msg: &str) -> bool {
    msg.contains(WALL_DEADLINE_MARK)
        || (msg.contains("exceeded") && msg.contains("slow cycles"))
}

/// Result of a functional or exact run.
#[derive(Debug)]
pub struct SimOutcome {
    pub stats: SimStats,
    /// Final HBM state (output containers hold the computed results).
    pub hbm: Hbm,
}

fn build_channels(design: &Design) -> Channels {
    let mut ch = Channels::default();
    for c in &design.channels {
        ch.add(Fifo::new(&c.name, c.lanes, c.depth));
    }
    ch
}

fn build_procs(design: &Design, ch: &Channels) -> Vec<Proc> {
    design
        .modules
        .iter()
        .filter(|m| !matches!(&m.spec, ModuleSpec::Sync { input, .. } if input.starts_with("__ctrl")))
        .map(|m| Proc::build(&m.spec, m.domain, ch))
        .collect()
}

/// The stepper-verbatim deadlock report (cycle number is the legacy
/// post-increment `fast_t`, i.e. the triggering cycle + 1). One
/// definition for both of the event engine's detection paths, so the
/// byte-identical-message contract with [`run_exact_reference`] cannot
/// drift per call site.
fn deadlock_report(design: &Design, procs: &[Proc], ch: &Channels, t0: u64) -> String {
    let stuck: Vec<&str> =
        procs.iter().filter(|p| !p.done(ch)).map(|p| p.label.as_str()).collect();
    format!(
        "deadlock in '{}' at fast cycle {}: stuck modules {stuck:?}",
        design.name,
        t0 + 1
    )
}

/// The fast time base: the largest clock ratio in the design. Mixed
/// per-region designs carry several fast domains; every factor divides
/// this one (enforced by `MultiPump::can_apply`), so a domain at
/// factor f ticks every `base / f` fast cycles and the slow domain
/// every `base`.
pub(crate) fn fast_time_base(design: &Design) -> u64 {
    design
        .modules
        .iter()
        .map(|m| m.domain.factor() as u64)
        .max()
        .unwrap_or(1)
        .max(design.pump.map(|(m, _)| m as u64).unwrap_or(1))
}

/// Functional execution: dataflow order, unbounded queues, real data.
/// `hbm` must hold every input container; output containers are
/// allocated automatically.
pub fn run_functional(design: &Design, hbm: Hbm) -> Result<SimOutcome, String> {
    run_functional_in(design, hbm, &mut Arena::new())
}

/// [`run_functional`] inside a caller-provided transaction arena (one
/// high-water-mark reset on entry, slabs reused across runs).
pub fn run_functional_in(
    design: &Design,
    mut hbm: Hbm,
    arena: &mut Arena,
) -> Result<SimOutcome, String> {
    arena.reset();
    for (name, elems, _) in &design.arrays {
        hbm.alloc(name, *elems);
    }
    let mut ch = build_channels(design);
    let mut procs = build_procs(design, &ch);

    let mut transactions = 0u64;
    for rep in 0..design.repeat {
        if rep > 0 {
            for p in procs.iter_mut() {
                p.reset_for_repeat();
            }
        }
        // drain to fixpoint
        let mut rounds = 0usize;
        loop {
            let mut any = false;
            for p in procs.iter_mut() {
                if p.drain_functional(&mut ch, arena, &mut hbm) {
                    any = true;
                }
            }
            if !any {
                break;
            }
            rounds += 1;
            if rounds > 1_000_000 {
                return Err(format!("functional run of '{}' did not converge", design.name));
            }
        }
        // every process must have finished its work
        for p in &procs {
            if !p.done(&ch) {
                return Err(format!(
                    "functional deadlock in '{}': module '{}' incomplete (repeat {rep})",
                    design.name, p.label
                ));
            }
        }
        transactions += ch.fifos.iter().map(|f| f.popped).sum::<u64>();
    }
    if !ch.all_empty() {
        let leftover: Vec<&str> = ch
            .fifos
            .iter()
            .filter(|f| !f.is_empty())
            .map(|f| f.name.as_str())
            .collect();
        return Err(format!("tokens left in channels: {leftover:?}"));
    }
    debug_assert_eq!(arena.stats().live, 0, "transaction slots leaked");
    Ok(SimOutcome {
        stats: SimStats { transactions, arena: arena.stats(), ..Default::default() },
        hbm,
    })
}

/// Exact cycle-accurate execution with bounded FIFOs and backpressure,
/// on the event-driven scheduler: processes sleep when blocked and are
/// woken by the channel push/pop that unblocks them, each clock domain
/// ticks at its own stride, and quiescent stretches are skipped to the
/// next wake time instead of being polled cycle by cycle. Cycle
/// semantics, stall/busy accounting and error messages are identical
/// to the legacy stepper ([`run_exact_reference`]) — asserted by the
/// property tests in `rust/tests/properties.rs`.
pub fn run_exact(design: &Design, hbm: Hbm, max_cycles: u64) -> Result<SimOutcome, String> {
    run_exact_in(design, hbm, max_cycles, &mut Arena::new())
}

/// [`run_exact`] inside a caller-provided transaction arena (one
/// high-water-mark reset on entry, slabs reused across runs — the DSE
/// evaluation loop's zero-steady-state-allocation path).
pub fn run_exact_in(
    design: &Design,
    hbm: Hbm,
    max_cycles: u64,
    arena: &mut Arena,
) -> Result<SimOutcome, String> {
    run_exact_observed_in(design, hbm, max_cycles, arena, None)
}

/// [`run_exact_in`] with an optional telemetry recorder attached. With
/// `Some`, the run is wrapped in a `sim.exact` span and emits: windowed
/// per-module busy/stall time-series (bounded memory), end-of-run
/// per-module and per-channel stall-cause counters, FIFO occupancy
/// high-water gauges, per-clock-domain utilization gauges, and — when
/// the recorder carries an activity grid — per-tick module fires for
/// waveform rendering. The instrumentation is purely observational:
/// `SimStats` and outputs are bit-identical to the `None` path (pinned
/// by a property test in `rust/tests/properties.rs`).
pub fn run_exact_observed_in(
    design: &Design,
    hbm: Hbm,
    max_cycles: u64,
    arena: &mut Arena,
    rec: Option<&crate::telemetry::Recorder>,
) -> Result<SimOutcome, String> {
    run_exact_deadline_in(design, hbm, max_cycles, None, arena, rec)
}

/// [`run_exact_observed_in`] with an optional wall-clock deadline. The
/// deadline is checked at every rep boundary and amortized over the
/// event loop (every 256 scheduler iterations), so a wedged or
/// pathologically slow simulation is reaped within milliseconds of the
/// limit without putting an `Instant::now()` on every cycle. With
/// `wall: None` the run is bit-identical to [`run_exact_observed_in`].
/// A reaped run returns an error carrying [`WALL_DEADLINE_MARK`], which
/// [`is_timeout_error`] classifies.
pub fn run_exact_deadline_in(
    design: &Design,
    mut hbm: Hbm,
    max_cycles: u64,
    wall: Option<Duration>,
    arena: &mut Arena,
    rec: Option<&crate::telemetry::Recorder>,
) -> Result<SimOutcome, String> {
    let deadline = wall.map(|limit| (Instant::now(), limit));
    let reaped = |elapsed: Duration, limit: Duration| {
        format!(
            "exact simulation of '{}' {WALL_DEADLINE_MARK} ({}ms limit, {}ms elapsed)",
            design.name,
            limit.as_millis(),
            elapsed.as_millis()
        )
    };
    arena.reset();
    for (name, elems, _) in &design.arrays {
        hbm.alloc(name, *elems);
    }
    let factor = fast_time_base(design);
    // the legacy stepper errors once its (post-increment) fast_t
    // exceeds this, idle cycles included
    let budget = max_cycles.saturating_mul(factor);
    let exceeded = || {
        format!("exact simulation of '{}' exceeded {max_cycles} slow cycles", design.name)
    };
    let mut ch = build_channels(design);
    let mut procs = build_procs(design, &ch);
    let n = procs.len();

    let mut sim_span = rec.map(|r| r.span("sim.exact"));
    if let Some(r) = rec {
        r.set_activity_labels(procs.iter().map(|p| p.label.clone()).collect());
    }

    // per-process tick stride in fast cycles (the legacy `ticks_now`
    // modulo, precomputed)
    let stride: Vec<u64> = procs
        .iter()
        .map(|p| match p.domain {
            ClockDomain::Slow => factor,
            ClockDomain::Fast { factor: f } => (factor / (f as u64)).max(1),
        })
        .collect();
    // wake subscriptions per fifo: consumers wake on a push, producers
    // on a pop. Spurious wakes are harmless — a woken process executes
    // a tick the legacy stepper also executed — only *missed* wakes
    // would diverge, so a changed fifo wakes both sides.
    let mut push_subs: Vec<Vec<usize>> = vec![Vec::new(); ch.fifos.len()];
    let mut pop_subs: Vec<Vec<usize>> = vec![Vec::new(); ch.fifos.len()];
    let own_ch: Vec<Vec<usize>> = procs
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let ins = p.input_channels();
            let outs = p.output_channels();
            for &c in &ins {
                push_subs[c].push(i);
            }
            for &c in &outs {
                pop_subs[c].push(i);
            }
            ins.into_iter().chain(outs).collect()
        })
        .collect();
    let max_own = own_ch.iter().map(|c| c.len()).max().unwrap_or(0);
    let mut scratch: Vec<u64> = vec![0; max_own];
    // busy/stall time-series cadence; the Series cap bounds memory for
    // arbitrarily long runs, this just keeps the lock off the hot loop
    let sample_every = factor * 64;
    let mut next_sample = 0u64;

    /// Asleep with no armed wake.
    const IDLE: u64 = u64::MAX;
    /// First scheduled cycle of stride `s` at or after `t`.
    fn align(t: u64, s: u64) -> u64 {
        let r = t % s;
        if r == 0 {
            t
        } else {
            t + (s - r)
        }
    }
    /// Arm a sleeping process `j` after an event at cycle `t` fired by
    /// process `cur`: same cycle if `j` is scheduled now and comes
    /// after `cur` in module order (the legacy stepper would tick it
    /// later this very cycle), else its next scheduled cycle.
    fn wake_proc(
        j: usize,
        t: u64,
        cur: usize,
        stride: &[u64],
        awake: &[bool],
        next_tick: &mut [u64],
    ) {
        if awake[j] {
            return; // ticking every scheduled cycle already
        }
        let s = stride[j];
        let at = if j > cur && t % s == 0 { t } else { (t / s + 1) * s };
        if at < next_tick[j] {
            next_tick[j] = at;
        }
    }

    // scheduling state
    let mut awake: Vec<bool> = vec![true; n];
    let mut next_tick: Vec<u64> = vec![0; n];
    let mut sleep_at: Vec<u64> = vec![0; n];
    let mut sleep_done: Vec<bool> = vec![false; n];

    let mut fast_t: u64 = 0; // the legacy stepper's fast_t at rep boundaries
    let mut wall_tick = 0u32; // amortizes the deadline check over iterations
    for rep in 0..design.repeat {
        if let Some((t0, limit)) = deadline {
            if t0.elapsed() > limit {
                return Err(reaped(t0.elapsed(), limit));
            }
        }
        if rep > 0 {
            for p in procs.iter_mut() {
                p.reset_for_repeat();
            }
        }
        for i in 0..n {
            awake[i] = true;
            next_tick[i] = align(fast_t, stride[i]);
        }
        // the cycle at which the legacy idle streak would exceed
        // 8·factor this rep (its fast_t error message quotes t0 + 1)
        let mut deadlock_t0 = fast_t + 8 * factor;
        // first cycle the legacy stepper would test quiescence at
        let mut break_t0 = fast_t;

        let final_t0: u64; // the rep's last legacy cycle (break cycle)
        loop {
            wall_tick = wall_tick.wrapping_add(1);
            if wall_tick & 0xff == 0 {
                if let Some((t0, limit)) = deadline {
                    if t0.elapsed() > limit {
                        return Err(reaped(t0.elapsed(), limit));
                    }
                }
            }
            let t = next_tick.iter().copied().min().unwrap_or(IDLE);
            if t > break_t0 {
                // a gap: the legacy stepper had an idle cycle at
                // break_t0. State is static across the gap (nothing
                // ticks), so the quiescence predicate — computed here
                // lazily, never on busy cycles — decides termination,
                // then the stepper's budget/deadlock countdowns apply.
                let quiet = procs.iter().all(|p| p.done(&ch)) && ch.all_empty();
                if quiet {
                    if break_t0 + 1 > budget {
                        return Err(exceeded());
                    }
                    final_t0 = break_t0;
                    break;
                }
                let gap = deadlock_t0.min(budget);
                if t > gap {
                    if budget <= deadlock_t0 {
                        return Err(exceeded());
                    }
                    return Err(deadlock_report(design, &procs, &ch, deadlock_t0));
                }
            }

            if let Some(r) = rec {
                if t >= next_sample {
                    for p in procs.iter() {
                        r.sample(&format!("sim.module.{}.busy", p.label), t, p.busy as f64);
                        r.sample(&format!("sim.module.{}.stalls", p.label), t, p.stalls as f64);
                    }
                    next_sample = t + sample_every;
                }
            }

            // execute cycle t in module order; wakes fired during the
            // cycle can only add later-indexed processes at t itself
            let mut progress = false;
            for i in 0..n {
                if next_tick[i] != t {
                    continue;
                }
                if !awake[i] && !sleep_done[i] {
                    // the legacy stepper stalled this process at every
                    // scheduled cycle we skipped while it slept
                    procs[i].stalls += ((t - sleep_at[i]) / stride[i]).saturating_sub(1);
                }
                let chans = &own_ch[i];
                for (k, &c) in chans.iter().enumerate() {
                    scratch[k] = ch.fifos[c].activity();
                }
                let prog = procs[i].tick(t, &mut ch, arena, &mut hbm);
                if prog {
                    if let Some(r) = rec {
                        r.fire(i as u32, t);
                    }
                    progress = true;
                    awake[i] = true;
                    next_tick[i] = t + stride[i];
                } else {
                    awake[i] = false;
                    sleep_at[i] = t;
                    sleep_done[i] = procs[i].done(&ch);
                    next_tick[i] = match procs[i].next_retire_time() {
                        // a future retirement needs a timed wake; one
                        // already due is waiting on output space and
                        // the pop subscription covers it
                        Some(ready) if ready > t => align(ready, stride[i]),
                        _ => IDLE,
                    };
                }
                for (k, &c) in chans.iter().enumerate() {
                    if ch.fifos[c].activity() != scratch[k] {
                        for &j in push_subs[c].iter().chain(pop_subs[c].iter()) {
                            wake_proc(j, t, i, &stride, &awake, &mut next_tick);
                        }
                    }
                }
            }

            // post-cycle checks, in the legacy stepper's order: cycle
            // budget first, then termination, then the idle streak.
            // The quiescence predicate is only computed on no-progress
            // cycles — exactly when the stepper computed it — so busy
            // steady-state cycles pay no O(modules + fifos) scan.
            if t + 1 > budget {
                return Err(exceeded());
            }
            if !progress {
                let quiet = procs.iter().all(|p| p.done(&ch)) && ch.all_empty();
                if quiet {
                    final_t0 = t;
                    break;
                }
                if t >= deadlock_t0 {
                    return Err(deadlock_report(design, &procs, &ch, t));
                }
            } else {
                deadlock_t0 = t + 8 * factor + 1;
                break_t0 = t + 1;
            }
        }

        // the legacy stepper ticked every scheduled sleeping process
        // through the rep's break cycle — settle their stall counters
        for i in 0..n {
            if !awake[i] && !sleep_done[i] {
                procs[i].stalls += final_t0 / stride[i] - sleep_at[i] / stride[i];
            }
        }
        fast_t = final_t0 + 1;
    }

    if let Some(r) = rec {
        record_sim_metrics(r, design, &procs, &ch, &stride, fast_t);
    }
    let slow_cycles = fast_t / factor;
    if let Some(s) = sim_span.as_mut() {
        s.note("slow_cycles", slow_cycles);
        s.note("fast_cycles", fast_t);
    }
    let bottleneck = procs
        .iter()
        .max_by_key(|p| p.busy)
        .map(|p| p.label.clone())
        .unwrap_or_default();
    let modules = procs.iter().map(|p| (p.label.clone(), p.busy, p.stalls)).collect();
    let transactions = ch.fifos.iter().map(|f| f.pushed).sum();
    debug_assert_eq!(arena.stats().live, 0, "transaction slots leaked");
    Ok(SimOutcome {
        stats: SimStats {
            slow_cycles,
            fast_cycles: fast_t,
            bottleneck,
            modules,
            transactions,
            arena: arena.stats(),
        },
        hbm,
    })
}

/// End-of-run aggregate telemetry: per-module busy/stall totals,
/// per-channel stall causes (backpressure vs starvation) and occupancy
/// high-water marks, and per-clock-domain utilization — Σ busy over
/// Σ scheduled slots per domain, the signal that shows which fast
/// domain of a mixed-factor design is starved. Fast-domain labels
/// carry the region's pump-mode letter (`cl1_m2r`, `cl1_m4t`,
/// `cl1_m2b`) from [`Design::domain_modes`].
fn record_sim_metrics(
    rec: &crate::telemetry::Recorder,
    design: &Design,
    procs: &[Proc],
    ch: &Channels,
    stride: &[u64],
    fast_t: u64,
) {
    use std::collections::BTreeMap;
    let mode_letter = |f: usize| -> String {
        design
            .domain_modes
            .iter()
            .find(|(df, _)| *df == f)
            .map(|(_, m)| m.letter().to_string())
            .unwrap_or_default()
    };
    let mut domains: BTreeMap<String, (u64, u64)> = BTreeMap::new();
    for (i, p) in procs.iter().enumerate() {
        rec.add(&format!("sim.module.{}.busy", p.label), p.busy);
        rec.add(&format!("sim.module.{}.stalls", p.label), p.stalls);
        let label = match p.domain {
            ClockDomain::Slow => "cl0".to_string(),
            ClockDomain::Fast { factor } => {
                format!("cl1_m{factor}{}", mode_letter(factor))
            }
        };
        let e = domains.entry(label).or_insert((0, 0));
        e.0 += p.busy;
        e.1 += fast_t / stride[i].max(1);
    }
    for (label, (busy, slots)) in domains {
        rec.gauge(
            &format!("sim.domain.{label}.utilization"),
            busy as f64 / slots.max(1) as f64,
        );
    }
    for f in ch.fifos.iter() {
        rec.add(&format!("sim.fifo.{}.full_on_push", f.name), f.full_on_push);
        rec.add(&format!("sim.fifo.{}.empty_on_pop", f.name), f.empty_on_pop);
        rec.gauge(&format!("sim.fifo.{}.high_water", f.name), f.high_water as f64);
    }
}

/// Run every exact engine — event-driven, sharded (two threads), and
/// the legacy reference stepper — on one design + input and demand full
/// equivalence: slow/fast cycle counts, transactions, bottleneck,
/// per-module busy/stall counters, and every named output container.
/// The single definition of the cycle-exactness oracle — the property
/// tests, integration tests and `tvec bench` all call this, so the
/// contract cannot drift per call site.
pub fn exact_engines_agree(
    design: &Design,
    hbm: Hbm,
    max_cycles: u64,
    outputs: &[&str],
) -> Result<(), String> {
    exact_engines_agree_in(design, hbm, max_cycles, outputs, &mut Arena::new())
}

/// [`exact_engines_agree`] with both engines sharing one caller-owned
/// arena — like for like: the event engine and the oracle stepper move
/// their transactions through the same slabs, and the slot identities a
/// recycling data plane hands out provably never influence cycle
/// counts, counters or outputs. (Arena counters themselves are *not*
/// part of the equality contract: the second engine inherits the
/// first's warmed free lists, so its recycle hits legitimately differ.)
pub fn exact_engines_agree_in(
    design: &Design,
    hbm: Hbm,
    max_cycles: u64,
    outputs: &[&str],
    arena: &mut Arena,
) -> Result<(), String> {
    let e = run_exact_in(design, hbm.clone(), max_cycles, arena)
        .map_err(|err| format!("event: {err}"))?;
    let s = super::shard::run_exact_sharded_in(
        design,
        hbm.clone(),
        max_cycles,
        2,
        None,
        &mut Vec::new(),
        None,
    )
    .map_err(|err| format!("sharded: {err}"))?;
    let r = run_exact_reference_in(design, hbm, max_cycles, arena)
        .map_err(|err| format!("reference: {err}"))?;
    if (s.stats.slow_cycles, s.stats.fast_cycles, s.stats.transactions)
        != (e.stats.slow_cycles, e.stats.fast_cycles, e.stats.transactions)
    {
        return Err(format!(
            "sharded cycle counters diverged: sharded ({}, {}, {}) vs event ({}, {}, {})",
            s.stats.slow_cycles,
            s.stats.fast_cycles,
            s.stats.transactions,
            e.stats.slow_cycles,
            e.stats.fast_cycles,
            e.stats.transactions
        ));
    }
    if s.stats.bottleneck != e.stats.bottleneck || s.stats.modules != e.stats.modules {
        return Err(format!(
            "sharded per-module counters diverged:\n  sharded {:?} '{}'\n  event   {:?} '{}'",
            s.stats.modules, s.stats.bottleneck, e.stats.modules, e.stats.bottleneck
        ));
    }
    for out in outputs {
        if s.hbm.read(out) != e.hbm.read(out) {
            return Err(format!("output '{out}' differs between sharded and event engines"));
        }
    }
    if e.stats.slow_cycles != r.stats.slow_cycles {
        return Err(format!(
            "slow cycles: event {} vs reference {}",
            e.stats.slow_cycles, r.stats.slow_cycles
        ));
    }
    if e.stats.fast_cycles != r.stats.fast_cycles {
        return Err(format!(
            "fast cycles: event {} vs reference {}",
            e.stats.fast_cycles, r.stats.fast_cycles
        ));
    }
    if e.stats.transactions != r.stats.transactions {
        return Err(format!(
            "transactions: event {} vs reference {}",
            e.stats.transactions, r.stats.transactions
        ));
    }
    if e.stats.bottleneck != r.stats.bottleneck {
        return Err(format!(
            "bottleneck: event '{}' vs reference '{}'",
            e.stats.bottleneck, r.stats.bottleneck
        ));
    }
    if e.stats.modules != r.stats.modules {
        return Err(format!(
            "per-module busy/stall counters diverged:\n  event     {:?}\n  reference {:?}",
            e.stats.modules, r.stats.modules
        ));
    }
    for out in outputs {
        if e.hbm.read(out) != r.hbm.read(out) {
            return Err(format!("output '{out}' differs between engines"));
        }
    }
    Ok(())
}

/// The legacy cycle-stepped stepper: polls every module on every fast
/// cycle. Kept verbatim as the oracle the event-driven [`run_exact`]
/// is property-tested against (and the baseline `benches/sim_engine.rs`
/// and `tvec bench` measure the speedup over). Prefer [`run_exact`]
/// everywhere else.
pub fn run_exact_reference(
    design: &Design,
    hbm: Hbm,
    max_cycles: u64,
) -> Result<SimOutcome, String> {
    run_exact_reference_in(design, hbm, max_cycles, &mut Arena::new())
}

/// [`run_exact_reference`] inside a caller-provided transaction arena.
pub fn run_exact_reference_in(
    design: &Design,
    mut hbm: Hbm,
    max_cycles: u64,
    arena: &mut Arena,
) -> Result<SimOutcome, String> {
    arena.reset();
    for (name, elems, _) in &design.arrays {
        hbm.alloc(name, *elems);
    }
    let factor = fast_time_base(design);
    let mut ch = build_channels(design);
    let mut procs = build_procs(design, &ch);

    let mut fast_t: u64 = 0;
    for rep in 0..design.repeat {
        if rep > 0 {
            for p in procs.iter_mut() {
                p.reset_for_repeat();
            }
        }
        let mut idle_streak = 0u32;
        loop {
            let mut any = false;
            for p in procs.iter_mut() {
                let ticks_now = match p.domain {
                    ClockDomain::Slow => fast_t % factor == 0,
                    ClockDomain::Fast { factor: f } => {
                        fast_t % (factor / (f as u64)).max(1) == 0
                    }
                };
                if ticks_now && p.tick(fast_t, &mut ch, arena, &mut hbm) {
                    any = true;
                }
            }
            fast_t += 1;
            if fast_t > max_cycles * factor {
                return Err(format!(
                    "exact simulation of '{}' exceeded {max_cycles} slow cycles",
                    design.name
                ));
            }
            if any {
                idle_streak = 0;
            } else {
                idle_streak += 1;
                let all_done = procs.iter().all(|p| p.done(&ch));
                if all_done && ch.all_empty() {
                    break;
                }
                if idle_streak > 8 * factor as u32 {
                    let stuck: Vec<&str> = procs
                        .iter()
                        .filter(|p| !p.done(&ch))
                        .map(|p| p.label.as_str())
                        .collect();
                    return Err(format!(
                        "deadlock in '{}' at fast cycle {fast_t}: stuck modules {stuck:?}",
                        design.name
                    ));
                }
            }
        }
    }

    let slow_cycles = fast_t / factor;
    let bottleneck = procs
        .iter()
        .max_by_key(|p| p.busy)
        .map(|p| p.label.clone())
        .unwrap_or_default();
    let modules = procs.iter().map(|p| (p.label.clone(), p.busy, p.stalls)).collect();
    let transactions = ch.fifos.iter().map(|f| f.pushed).sum();
    debug_assert_eq!(arena.stats().live, 0, "transaction slots leaked");
    Ok(SimOutcome {
        stats: SimStats {
            slow_cycles,
            fast_cycles: fast_t,
            bottleneck,
            modules,
            transactions,
            arena: arena.stats(),
        },
        hbm,
    })
}

/// Steady-state rate analysis: cycle count for arbitrarily large
/// workloads in O(#modules). The bottleneck is the module with the
/// largest total service time; pipeline-fill latencies are added along
/// the module list (designs here are feed-forward chains).
pub fn rate_model(design: &Design) -> SimStats {
    let factor = fast_time_base(design);
    let mut worst: (f64, String) = (0.0, String::new());
    let mut fill: f64 = 0.0;
    let mut modules = Vec::new();

    for m in &design.modules {
        let dom = match m.domain {
            ClockDomain::Slow => 1u64,
            ClockDomain::Fast { factor } => factor as u64,
        };
        // (total transactions, cycles per txn in own domain, extra fill)
        let (txns, cpt, lat) = match &m.spec {
            ModuleSpec::Reader { elems, lanes, bytes_per_cycle, .. }
            | ModuleSpec::Writer { elems, lanes, bytes_per_cycle, .. } => {
                let cpt = ((lanes * 4 + bytes_per_cycle - 1) / bytes_per_cycle).max(1) as u64;
                (*elems as u64, cpt, 64.0)
            }
            ModuleSpec::Compute { iterations, ii, latency, .. } => {
                (*iterations as u64, *ii, *latency as f64)
            }
            ModuleSpec::Sync { input, .. } => {
                if input.starts_with("__ctrl") {
                    continue;
                }
                (0, 1, 3.0) // syncs never bottleneck; they add latency
            }
            ModuleSpec::Issuer { .. } | ModuleSpec::Packer { .. } => (0, 1, 1.0),
            ModuleSpec::GemmCore { n, m: mm, k, pes, lanes, .. } => {
                let work = (*n as u64) * (*mm as u64) * (*k as u64);
                let cycles = work / ((pes * lanes) as u64).max(1);
                // drain of C adds n*m/lanes cycles
                let drain = (*n as u64) * (*mm as u64) / (*lanes as u64).max(1);
                (cycles + drain, 1, 512.0)
            }
            ModuleSpec::StencilCore { nx, ny, nz, lanes, .. } => {
                let txns = (nx * ny * nz / lanes.max(&1)) as u64;
                // warmup: one plane + one row before the first output
                let warm = ((ny * nz + nz) / lanes.max(&1)) as f64;
                // chained stages are independent kernels with
                // synchronization steps between them (paper §4.3);
                // the handshake costs ~15 % steady-state slack
                (txns + txns / 7, 1, warm)
            }
            ModuleSpec::FwCore { n, ii, lanes, .. } => {
                let txns = ((n * n) as u64) / (*lanes as u64).max(1);
                (txns, *ii, 32.0)
            }
        };
        // service time in slow cycles
        let service = (txns as f64) * (cpt as f64) / (dom as f64);
        modules.push((m.spec.label(), service as u64, 0));
        if service > worst.0 {
            worst = (service, m.spec.label());
        }
        // fill: memory/burst latencies overlap across parallel
        // readers/writers (count the max once, below); pipeline fills of
        // chained modules accumulate along the path
        match &m.spec {
            ModuleSpec::Reader { .. } | ModuleSpec::Writer { .. } | ModuleSpec::GemmCore { .. } => {
                fill = fill.max(lat / dom as f64);
            }
            _ => fill += lat / dom as f64,
        }
    }

    let per_rep = worst.0 + fill + 16.0; // 16: kernel start handshake
    let slow_cycles = (per_rep * design.repeat as f64) as u64;
    SimStats {
        slow_cycles,
        fast_cycles: slow_cycles * factor,
        bottleneck: worst.1,
        modules,
        transactions: 0,
        arena: ArenaStats::default(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::lower::lower;
    use crate::hw::cost::CostModel;
    use crate::ir::builder::vecadd_sdfg;
    use crate::transforms::{MultiPump, PassManager, StreamingComposition, Vectorize};
    use crate::util::Rng;

    fn vecadd_design(n: i64, lanes: usize, pump: bool) -> Design {
        let mut g = vecadd_sdfg(1);
        let mut pm = PassManager::new();
        if lanes > 1 {
            pm.run(&mut g, &Vectorize::new("vadd", lanes)).unwrap();
        }
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        if pump {
            pm.run(&mut g, &MultiPump::resource(2)).unwrap();
        }
        let env = g.bind(&[("N", n)]).unwrap();
        lower(&g, &env, &CostModel::default()).unwrap()
    }

    fn input_hbm(n: usize, seed: u64) -> Hbm {
        let mut rng = Rng::new(seed);
        let mut hbm = Hbm::new();
        hbm.load("x", rng.f32_vec(n));
        hbm.load("y", rng.f32_vec(n));
        hbm
    }

    #[test]
    fn functional_vecadd_is_correct() {
        let n = 256usize;
        let d = vecadd_design(n as i64, 4, false);
        let hbm = input_hbm(n, 1);
        let (x, y) = (hbm.read("x").to_vec(), hbm.read("y").to_vec());
        let out = run_functional(&d, hbm).unwrap();
        let z = out.hbm.read("z");
        for i in 0..n {
            assert_eq!(z[i], x[i] + y[i], "element {i}");
        }
    }

    #[test]
    fn functional_vecadd_double_pumped_matches_original() {
        let n = 512usize;
        let d_o = vecadd_design(n as i64, 4, false);
        let d_dp = vecadd_design(n as i64, 4, true);
        let hbm = input_hbm(n, 2);
        let z_o = run_functional(&d_o, hbm.clone()).unwrap().hbm.read("z").to_vec();
        let z_dp = run_functional(&d_dp, hbm).unwrap().hbm.read("z").to_vec();
        assert_eq!(z_o, z_dp, "multi-pumping must not change results");
    }

    #[test]
    fn exact_vecadd_runs_and_matches_functional() {
        let n = 256usize;
        let d = vecadd_design(n as i64, 4, false);
        let hbm = input_hbm(n, 3);
        let f = run_functional(&d, hbm.clone()).unwrap();
        let e = run_exact(&d, hbm, 1_000_000).unwrap();
        assert_eq!(e.hbm.read("z"), f.hbm.read("z"));
        // ~n/lanes cycles + overheads
        assert!(e.stats.slow_cycles >= (n / 4) as u64);
        assert!(e.stats.slow_cycles < 3 * (n as u64), "{}", e.stats.slow_cycles);
    }

    #[test]
    fn exact_double_pumped_matches_functional_data() {
        let n = 256usize;
        let d = vecadd_design(n as i64, 4, true);
        let hbm = input_hbm(n, 4);
        let f = run_functional(&d, hbm.clone()).unwrap();
        let e = run_exact(&d, hbm, 1_000_000).unwrap();
        assert_eq!(e.hbm.read("z"), f.hbm.read("z"));
    }

    #[test]
    fn rate_model_agrees_with_exact_on_vecadd() {
        for pump in [false, true] {
            let n = 4096usize;
            let d = vecadd_design(n as i64, 4, pump);
            let hbm = input_hbm(n, 5);
            let e = run_exact(&d, hbm, 10_000_000).unwrap();
            let r = rate_model(&d);
            let ratio = r.slow_cycles as f64 / e.stats.slow_cycles as f64;
            assert!(
                (0.85..1.15).contains(&ratio),
                "pump={pump}: rate {} vs exact {} (ratio {ratio:.3})",
                r.slow_cycles,
                e.stats.slow_cycles
            );
        }
    }

    #[test]
    fn double_pumping_preserves_throughput_resource_mode() {
        // resource mode: same throughput (per paper §2.1) — cycle counts
        // within a few percent of each other
        let n = 4096usize;
        let e_o = run_exact(&vecadd_design(n as i64, 4, false), input_hbm(n, 6), 10_000_000)
            .unwrap();
        let e_dp = run_exact(&vecadd_design(n as i64, 4, true), input_hbm(n, 6), 10_000_000)
            .unwrap();
        let ratio = e_dp.stats.slow_cycles as f64 / e_o.stats.slow_cycles as f64;
        assert!((0.9..1.25).contains(&ratio), "DP/O cycle ratio {ratio}");
    }

    #[test]
    fn deadlock_detected() {
        // a design whose writer expects more than the reader produces
        let mut d = vecadd_design(64, 1, false);
        for m in &mut d.modules {
            if let ModuleSpec::Writer { elems, .. } = &mut m.spec {
                *elems += 10;
            }
        }
        let err = run_exact(&d, input_hbm(64, 7), 100_000).unwrap_err();
        assert!(err.contains("deadlock"), "{err}");
    }

    #[test]
    fn event_engine_matches_reference_on_vecadd() {
        for (lanes, pump) in [(1usize, false), (4, false), (4, true), (8, true)] {
            let n = 512usize;
            let d = vecadd_design(n as i64, lanes, pump);
            exact_engines_agree(&d, input_hbm(n, 40 + lanes as u64), 10_000_000, &["z"])
                .unwrap_or_else(|e| panic!("lanes {lanes} pump {pump}: {e}"));
        }
    }

    #[test]
    fn event_engine_reproduces_reference_deadlock_verbatim() {
        // the deadlock detection (ready queue empty with work
        // outstanding) must report the same fast cycle and stuck list
        // the legacy idle-streak scan did
        for pump in [false, true] {
            let mut d = vecadd_design(64, 4, pump);
            for m in &mut d.modules {
                if let ModuleSpec::Writer { elems, .. } = &mut m.spec {
                    *elems += 10;
                }
            }
            let e = run_exact(&d, input_hbm(64, 7), 100_000).unwrap_err();
            let r = run_exact_reference(&d, input_hbm(64, 7), 100_000).unwrap_err();
            assert_eq!(e, r, "deadlock reports diverged (pump={pump})");
        }
    }

    #[test]
    fn event_engine_reproduces_reference_cycle_budget_error() {
        let d = vecadd_design(4096, 4, true);
        let e = run_exact(&d, input_hbm(4096, 8), 10).unwrap_err();
        let r = run_exact_reference(&d, input_hbm(4096, 8), 10).unwrap_err();
        assert_eq!(e, r);
        assert!(e.contains("exceeded"), "{e}");
    }

    #[test]
    fn wall_deadline_reaps_a_run_and_classifies_as_timeout() {
        let d = vecadd_design(4096, 4, true);
        // a zero deadline is already elapsed at the first rep boundary
        let e = run_exact_deadline_in(
            &d,
            input_hbm(4096, 8),
            10_000_000,
            Some(Duration::ZERO),
            &mut Arena::new(),
            None,
        )
        .unwrap_err();
        assert!(e.contains(WALL_DEADLINE_MARK), "{e}");
        assert!(is_timeout_error(&e), "{e}");
        // the slow-cycle ceiling message classifies as a timeout too...
        let budget = run_exact(&d, input_hbm(4096, 8), 10).unwrap_err();
        assert!(is_timeout_error(&budget), "{budget}");
        // ...but a deadlock report does not
        assert!(!is_timeout_error("deadlock at fast cycle 42: stuck [pe0]"));
    }

    #[test]
    fn deadline_none_path_is_bit_identical() {
        let n = 512usize;
        let d = vecadd_design(n as i64, 4, true);
        let plain = run_exact(&d, input_hbm(n, 11), 10_000_000).unwrap();
        let gated = run_exact_deadline_in(
            &d,
            input_hbm(n, 11),
            10_000_000,
            // a generous live deadline must not perturb the run either
            Some(Duration::from_secs(600)),
            &mut Arena::new(),
            None,
        )
        .unwrap();
        assert_eq!(plain.stats.slow_cycles, gated.stats.slow_cycles);
        assert_eq!(plain.stats.fast_cycles, gated.stats.fast_cycles);
        assert_eq!(plain.hbm.read("z"), gated.hbm.read("z"));
    }

    #[test]
    fn arena_steady_state_allocates_nothing_across_runs() {
        // the allocation-regression gate: a golden-scale vecadd run
        // establishes the arena's slabs; an identical second run on the
        // same arena must be served entirely from recycled slots —
        // identical slab/slot counts and high-water mark, with every
        // allocation a recycle hit
        let n = 4096usize; // apps::vecadd::GOLDEN_N
        let d = vecadd_design(n as i64, 8, true);
        let mut arena = Arena::new();
        let first = run_exact_in(&d, input_hbm(n, 9), 10_000_000, &mut arena).unwrap();
        let s1 = arena.stats();
        assert!(s1.slots > 0 && s1.peak_live > 0);
        assert!(s1.recycle_hits > 0, "pop-to-push hops must recycle slots mid-run");
        let second = run_exact_in(&d, input_hbm(n, 9), 10_000_000, &mut arena).unwrap();
        let s2 = arena.stats();
        assert_eq!(s2.classes, s1.classes, "no new lane classes in steady state");
        assert_eq!(s2.slots, s1.slots, "no new slots in steady state");
        assert_eq!(s2.peak_live, s1.peak_live, "high-water mark must stay flat");
        // flat slots + flat peak ⇒ every second-run allocation was
        // served from a free list (slab growth is the only other path)
        assert!(s2.recycle_hits > s1.recycle_hits);
        // and the pooled run is semantically identical to a fresh one
        let fresh = run_exact(&d, input_hbm(n, 9), 10_000_000).unwrap();
        assert_eq!(first.stats.slow_cycles, fresh.stats.slow_cycles);
        assert_eq!(second.hbm.read("z"), fresh.hbm.read("z"));
    }

    #[test]
    fn observed_run_is_bit_identical_and_records_metrics() {
        use crate::telemetry::{Event, Recorder};
        let n = 512usize;
        let d = vecadd_design(n as i64, 4, true);
        let plain = run_exact(&d, input_hbm(n, 11), 10_000_000).unwrap();
        let rec = Recorder::new();
        let mut arena = Arena::new();
        let obs =
            run_exact_observed_in(&d, input_hbm(n, 11), 10_000_000, &mut arena, Some(&rec))
                .unwrap();
        // telemetry must be purely observational
        assert_eq!(plain.stats.slow_cycles, obs.stats.slow_cycles);
        assert_eq!(plain.stats.fast_cycles, obs.stats.fast_cycles);
        assert_eq!(plain.stats.transactions, obs.stats.transactions);
        assert_eq!(plain.stats.bottleneck, obs.stats.bottleneck);
        assert_eq!(plain.stats.modules, obs.stats.modules);
        assert_eq!(plain.hbm.read("z"), obs.hbm.read("z"));
        // and the recorder saw the run: span, module/fifo counters,
        // both clock domains' utilization gauges, sampled series
        let ev = rec.events();
        assert!(ev
            .iter()
            .any(|e| matches!(e, Event::Begin { name, .. } if name == "sim.exact")));
        let counters = rec.counters();
        assert!(counters.keys().any(|k| k.starts_with("sim.module.") && k.ends_with(".busy")));
        assert!(counters
            .keys()
            .any(|k| k.starts_with("sim.fifo.") && k.ends_with(".empty_on_pop")));
        let gauges = rec.gauges();
        assert!(gauges.contains_key("sim.domain.cl0.utilization"));
        assert!(gauges.keys().any(|k| k.starts_with("sim.domain.cl1_m2")));
        assert!(gauges.values().all(|v| (0.0..=1.0).contains(v) || v.is_finite()));
        assert!(!rec.series().is_empty(), "busy/stall series must be sampled");
    }

    #[test]
    fn shared_arena_engines_agree_and_report_stats() {
        let n = 512usize;
        let d = vecadd_design(n as i64, 4, true);
        let mut arena = Arena::new();
        exact_engines_agree_in(&d, input_hbm(n, 10), 10_000_000, &["z"], &mut arena)
            .unwrap();
        let s = arena.stats();
        assert!(s.slots > 0 && s.recycle_hits > 0 && s.live == 0);
        // the outcome snapshots the arena counters for stats surfacing
        let out = run_exact_in(&d, input_hbm(n, 10), 10_000_000, &mut arena).unwrap();
        assert_eq!(out.stats.arena.slots, s.slots);
        assert!(out.stats.arena.recycle_hits > s.recycle_hits);
    }
}
