//! Cycle-level activity tracing: the machinery behind the Figure-2
//! waveform reproduction (`examples/waveforms.rs`).
//!
//! Runs the event-driven exact engine with a telemetry recorder whose
//! activity grid captures, for each fast-domain tick, which modules
//! made progress — then renders the result as a text waveform in the
//! style of the paper's Figure 2. Capture is the telemetry sampler
//! itself (`Recorder::with_activity`), not a second per-tick loop:
//! cycles the scheduler skips (sleeping or quiescent stretches) simply
//! record no fires and render as idle columns, and the time base is
//! the design's largest clock ratio, so mixed per-region factors get
//! correct per-domain strides.

use super::arena::Arena;
use super::engine::{fast_time_base, run_exact_observed_in};
use super::memory::Hbm;
use crate::codegen::design::Design;
use crate::telemetry::Recorder;

/// Per-module activity over the traced window.
#[derive(Debug)]
pub struct Trace {
    /// Module labels in design order.
    pub modules: Vec<String>,
    /// `activity[m][t]` — did module `m` fire at fast tick `t`?
    pub activity: Vec<Vec<bool>>,
    /// Pumping factor (fast ticks per slow cycle).
    pub factor: usize,
}

impl Trace {
    /// Render as a text waveform: one row per module, `▮` for an
    /// active cycle, `·` idle, with a slow-clock ruler on top.
    pub fn render(&self) -> String {
        let ticks = self.activity.first().map(|a| a.len()).unwrap_or(0);
        let width = self.modules.iter().map(|m| m.len()).max().unwrap_or(8).max(8);
        let mut out = String::new();
        // ruler: slow-cycle boundaries
        out.push_str(&format!("{:width$}  ", "clk0"));
        for t in 0..ticks {
            out.push(if t % self.factor == 0 { '|' } else { ' ' });
        }
        out.push('\n');
        for (m, acts) in self.modules.iter().zip(&self.activity) {
            out.push_str(&format!("{m:width$}  "));
            for &a in acts {
                out.push(if a { '▮' } else { '·' });
            }
            out.push('\n');
        }
        out
    }
}

/// Run the event-driven exact engine for up to `max_fast_ticks`,
/// recording module activity through the telemetry activity grid. A
/// run that overruns the tick budget or deadlocks still yields the
/// partial waveform captured up to that point (exactly what a stuck
/// design's trace is for).
pub fn run_traced(design: &Design, hbm: Hbm, max_fast_ticks: usize) -> Result<Trace, String> {
    let factor = fast_time_base(design) as usize;
    let rec = Recorder::with_activity(max_fast_ticks as u64);
    // the engine's budget is in slow cycles; round up so the grid can
    // fill its full fast-tick window
    let max_cycles = ((max_fast_ticks + factor - 1) / factor).max(1) as u64;
    let _ = run_exact_observed_in(design, hbm, max_cycles, &mut Arena::new(), Some(&rec));

    let grid = rec.activity().expect("recorder built with an activity grid");
    let modules = grid.labels.clone();
    // dense matrix over the observed window: ticks with no recorded
    // fire — including whole stretches the scheduler skipped — are
    // idle columns
    let ticks = grid
        .fires
        .iter()
        .map(|&(_, t)| t as usize + 1)
        .max()
        .unwrap_or(0)
        .min(max_fast_ticks);
    let mut activity: Vec<Vec<bool>> = vec![vec![false; ticks]; modules.len()];
    for &(m, t) in &grid.fires {
        let (m, t) = (m as usize, t as usize);
        if m < activity.len() && t < ticks {
            activity[m][t] = true;
        }
    }
    Ok(Trace { modules, activity, factor })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{compile, BuildSpec};
    use crate::ir::PumpMode;
    use crate::util::Rng;

    fn traced(pump: bool) -> Trace {
        let n = 32i64;
        let mut spec = BuildSpec::new(crate::apps::vecadd::build())
            .vectorized("vadd", 2)
            .bind("N", n);
        if pump {
            spec = spec.pumped(2, PumpMode::Resource);
        }
        let c = compile(spec).unwrap();
        let mut rng = Rng::new(1);
        let mut hbm = Hbm::new();
        hbm.load("x", rng.f32_vec(n as usize));
        hbm.load("y", rng.f32_vec(n as usize));
        run_traced(&c.design, hbm, 200).unwrap()
    }

    #[test]
    fn trace_records_all_modules() {
        let t = traced(true);
        assert!(t.modules.iter().any(|m| m.starts_with("read_")));
        assert!(t.modules.iter().any(|m| m.starts_with("issue")));
        assert!(t.modules.iter().any(|m| m.starts_with("pack")));
        assert_eq!(t.factor, 2);
        // every module fired at least once
        for (m, acts) in t.modules.iter().zip(&t.activity) {
            assert!(acts.iter().any(|&a| a), "module {m} never fired");
        }
    }

    #[test]
    fn fast_domain_fires_more_often_than_slow_when_pumped() {
        let t = traced(true);
        let count = |name: &str| {
            t.modules
                .iter()
                .position(|m| m.contains(name))
                .map(|i| t.activity[i].iter().filter(|&&a| a).count())
                .unwrap_or(0)
        };
        // the double-pumped compute (narrow txns) fires ~2x as often
        // as the slow-domain reader (wide txns)
        let compute = count("vadd");
        let reader = count("read_x");
        assert!(
            compute > reader + reader / 2,
            "compute {compute} vs reader {reader}"
        );
    }

    #[test]
    fn render_produces_waveform_rows() {
        let t = traced(false);
        let r = t.render();
        assert!(r.contains("▮"));
        assert!(r.lines().count() >= t.modules.len());
    }

    #[test]
    fn skipped_quiet_cycles_render_as_idle_columns() {
        let t = traced(true);
        // the matrix is dense and rectangular over the observed window:
        // ticks the event scheduler skipped are explicit idle columns,
        // not dropped samples
        let ticks = t.activity.first().map(|r| r.len()).unwrap_or(0);
        assert!(ticks > 0, "trace captured nothing");
        assert!(t.activity.iter().all(|row| row.len() == ticks));
        // the slow-domain reader only ticks every `factor` fast cycles,
        // so its row must contain idle columns
        assert!(t.render().contains('·'));
    }
}
