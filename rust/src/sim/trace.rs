//! Cycle-level activity tracing: the machinery behind the Figure-2
//! waveform reproduction (`examples/waveforms.rs`).
//!
//! Runs the exact engine while recording, for each fast-domain tick,
//! which modules made progress — then renders the result as a text
//! waveform in the style of the paper's Figure 2.

use super::arena::Arena;
use super::channel::{Channels, Fifo};
use super::memory::Hbm;
use super::process::Proc;
use crate::codegen::design::{Design, ModuleSpec};
use crate::ir::ClockDomain;

/// Per-module activity over the traced window.
#[derive(Debug)]
pub struct Trace {
    /// Module labels in design order.
    pub modules: Vec<String>,
    /// `activity[m][t]` — did module `m` fire at fast tick `t`?
    pub activity: Vec<Vec<bool>>,
    /// Pumping factor (fast ticks per slow cycle).
    pub factor: usize,
}

impl Trace {
    /// Render as a text waveform: one row per module, `▮` for an
    /// active cycle, `·` idle, with a slow-clock ruler on top.
    pub fn render(&self) -> String {
        let ticks = self.activity.first().map(|a| a.len()).unwrap_or(0);
        let width = self.modules.iter().map(|m| m.len()).max().unwrap_or(8).max(8);
        let mut out = String::new();
        // ruler: slow-cycle boundaries
        out.push_str(&format!("{:width$}  ", "clk0"));
        for t in 0..ticks {
            out.push(if t % self.factor == 0 { '|' } else { ' ' });
        }
        out.push('\n');
        for (m, acts) in self.modules.iter().zip(&self.activity) {
            out.push_str(&format!("{m:width$}  "));
            for &a in acts {
                out.push(if a { '▮' } else { '·' });
            }
            out.push('\n');
        }
        out
    }
}

/// Run the exact engine for up to `max_fast_ticks`, recording module
/// activity. The design should be small (tracing is per-tick).
pub fn run_traced(design: &Design, mut hbm: Hbm, max_fast_ticks: usize) -> Result<Trace, String> {
    for (name, elems, _) in &design.arrays {
        hbm.alloc(name, *elems);
    }
    let factor = design.pump.map(|(m, _)| m).unwrap_or(1);
    let mut arena = Arena::new();
    let mut ch = Channels::default();
    for c in &design.channels {
        ch.add(Fifo::new(&c.name, c.lanes, c.depth));
    }
    let mut procs: Vec<Proc> = design
        .modules
        .iter()
        .filter(|m| !matches!(&m.spec, ModuleSpec::Sync { input, .. } if input.starts_with("__ctrl")))
        .map(|m| Proc::build(&m.spec, m.domain, &ch))
        .collect();

    let modules: Vec<String> = procs.iter().map(|p| p.label.clone()).collect();
    let mut activity: Vec<Vec<bool>> = vec![Vec::with_capacity(max_fast_ticks); procs.len()];

    for t in 0..max_fast_ticks as u64 {
        let mut all_done = true;
        for (i, p) in procs.iter_mut().enumerate() {
            let ticks_now = match p.domain {
                ClockDomain::Slow => t % factor as u64 == 0,
                ClockDomain::Fast { .. } => true,
            };
            let fired = ticks_now && p.tick(t, &mut ch, &mut arena, &mut hbm);
            activity[i].push(fired);
            if !p.done(&ch) {
                all_done = false;
            }
        }
        if all_done && ch.all_empty() {
            break;
        }
    }
    Ok(Trace { modules, activity, factor })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{compile, BuildSpec};
    use crate::ir::PumpMode;
    use crate::util::Rng;

    fn traced(pump: bool) -> Trace {
        let n = 32i64;
        let mut spec = BuildSpec::new(crate::apps::vecadd::build())
            .vectorized("vadd", 2)
            .bind("N", n);
        if pump {
            spec = spec.pumped(2, PumpMode::Resource);
        }
        let c = compile(spec).unwrap();
        let mut rng = Rng::new(1);
        let mut hbm = Hbm::new();
        hbm.load("x", rng.f32_vec(n as usize));
        hbm.load("y", rng.f32_vec(n as usize));
        run_traced(&c.design, hbm, 200).unwrap()
    }

    #[test]
    fn trace_records_all_modules() {
        let t = traced(true);
        assert!(t.modules.iter().any(|m| m.starts_with("read_")));
        assert!(t.modules.iter().any(|m| m.starts_with("issue")));
        assert!(t.modules.iter().any(|m| m.starts_with("pack")));
        assert_eq!(t.factor, 2);
        // every module fired at least once
        for (m, acts) in t.modules.iter().zip(&t.activity) {
            assert!(acts.iter().any(|&a| a), "module {m} never fired");
        }
    }

    #[test]
    fn fast_domain_fires_more_often_than_slow_when_pumped() {
        let t = traced(true);
        let count = |name: &str| {
            t.modules
                .iter()
                .position(|m| m.contains(name))
                .map(|i| t.activity[i].iter().filter(|&&a| a).count())
                .unwrap_or(0)
        };
        // the double-pumped compute (narrow txns) fires ~2x as often
        // as the slow-domain reader (wide txns)
        let compute = count("vadd");
        let reader = count("read_x");
        assert!(
            compute > reader + reader / 2,
            "compute {compute} vs reader {reader}"
        );
    }

    #[test]
    fn render_produces_waveform_rows() {
        let t = traced(false);
        let r = t.render();
        assert!(r.contains("▮"));
        assert!(r.lines().count() >= t.modules.len());
    }
}
