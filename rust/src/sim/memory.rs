//! HBM memory model: one exclusive bank per container (paper §4).

use std::collections::BTreeMap;

/// Off-chip memory state: named containers of f32 data.
#[derive(Clone, Debug, Default)]
pub struct Hbm {
    banks: BTreeMap<String, Vec<f32>>,
}

impl Hbm {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install a container's initial contents.
    pub fn load(&mut self, name: &str, data: Vec<f32>) {
        self.banks.insert(name.to_string(), data);
    }

    /// Allocate a zeroed output container.
    pub fn alloc(&mut self, name: &str, elems: usize) {
        self.banks.entry(name.to_string()).or_insert_with(|| vec![0.0; elems]);
    }

    pub fn read(&self, name: &str) -> &[f32] {
        self.banks
            .get(name)
            .unwrap_or_else(|| panic!("HBM container '{name}' not loaded"))
    }

    pub fn read_mut(&mut self, name: &str) -> &mut Vec<f32> {
        self.banks
            .get_mut(name)
            .unwrap_or_else(|| panic!("HBM container '{name}' not loaded"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.banks.contains_key(name)
    }

    /// Fill `dst` from `name[base..]`, zero-filling reads past the end
    /// of the container — the reader datapath's gather, centralised so
    /// the short-input padding semantics live in one place. Panics on a
    /// missing container, like [`Hbm::read`].
    pub fn fetch(&self, name: &str, base: usize, dst: &mut [f32]) {
        let mem = self.read(name);
        for (l, d) in dst.iter_mut().enumerate() {
            *d = mem.get(base + l).copied().unwrap_or(0.0);
        }
    }

    /// Store `src` at `name[base..]`, silently clamping writes past the
    /// end of the container — the writer datapath's scatter.
    pub fn store(&mut self, name: &str, base: usize, src: &[f32]) {
        let mem = self.read_mut(name);
        for (l, v) in src.iter().enumerate() {
            if base + l < mem.len() {
                mem[base + l] = *v;
            }
        }
    }

    /// Container names, in bank order.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.banks.keys().map(|s| s.as_str())
    }

    /// Merge every container of `other` into this memory, replacing any
    /// container of the same name — the sharded engine's merge-back
    /// after a run on per-shard bank copies.
    pub fn absorb(&mut self, other: Hbm) {
        self.banks.extend(other.banks);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_read_roundtrip() {
        let mut h = Hbm::new();
        h.load("x", vec![1.0, 2.0]);
        h.alloc("z", 4);
        assert_eq!(h.read("x"), &[1.0, 2.0]);
        assert_eq!(h.read("z").len(), 4);
        h.read_mut("z")[1] = 9.0;
        assert_eq!(h.read("z")[1], 9.0);
        assert!(h.contains("x") && !h.contains("y"));
    }

    #[test]
    fn alloc_does_not_clobber() {
        let mut h = Hbm::new();
        h.load("z", vec![5.0]);
        h.alloc("z", 3);
        assert_eq!(h.read("z"), &[5.0]);
    }

    #[test]
    #[should_panic(expected = "not loaded")]
    fn missing_container_panics() {
        Hbm::new().read("ghost");
    }

    #[test]
    fn absorb_replaces_matching_containers() {
        let mut a = Hbm::new();
        a.load("x", vec![1.0]);
        a.load("z", vec![0.0, 0.0]);
        let mut b = Hbm::new();
        b.load("z", vec![7.0, 8.0]);
        a.absorb(b);
        assert_eq!(a.read("x"), &[1.0]);
        assert_eq!(a.read("z"), &[7.0, 8.0]);
        assert_eq!(a.names().collect::<Vec<_>>(), vec!["x", "z"]);
    }

    #[test]
    fn fetch_zero_fills_and_store_clamps() {
        let mut h = Hbm::new();
        h.load("x", vec![1.0, 2.0, 3.0]);
        let mut dst = [0.0f32; 2];
        h.fetch("x", 2, &mut dst);
        assert_eq!(dst, [3.0, 0.0], "reads past the end zero-fill");
        h.load("z", vec![0.0; 2]);
        h.store("z", 1, &[7.0, 8.0]); // second value falls off the end
        assert_eq!(h.read("z"), &[0.0, 7.0]);
    }
}
