//! HBM memory model: one exclusive bank per container (paper §4).

use std::collections::BTreeMap;

/// Off-chip memory state: named containers of f32 data.
#[derive(Clone, Debug, Default)]
pub struct Hbm {
    banks: BTreeMap<String, Vec<f32>>,
}

impl Hbm {
    pub fn new() -> Self {
        Self::default()
    }

    /// Install a container's initial contents.
    pub fn load(&mut self, name: &str, data: Vec<f32>) {
        self.banks.insert(name.to_string(), data);
    }

    /// Allocate a zeroed output container.
    pub fn alloc(&mut self, name: &str, elems: usize) {
        self.banks.entry(name.to_string()).or_insert_with(|| vec![0.0; elems]);
    }

    pub fn read(&self, name: &str) -> &[f32] {
        self.banks
            .get(name)
            .unwrap_or_else(|| panic!("HBM container '{name}' not loaded"))
    }

    pub fn read_mut(&mut self, name: &str) -> &mut Vec<f32> {
        self.banks
            .get_mut(name)
            .unwrap_or_else(|| panic!("HBM container '{name}' not loaded"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.banks.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_read_roundtrip() {
        let mut h = Hbm::new();
        h.load("x", vec![1.0, 2.0]);
        h.alloc("z", 4);
        assert_eq!(h.read("x"), &[1.0, 2.0]);
        assert_eq!(h.read("z").len(), 4);
        h.read_mut("z")[1] = 9.0;
        assert_eq!(h.read("z")[1], 9.0);
        assert!(h.contains("x") && !h.contains("y"));
    }

    #[test]
    fn alloc_does_not_clobber() {
        let mut h = Hbm::new();
        h.load("z", vec![5.0]);
        h.alloc("z", 3);
        assert_eq!(h.read("z"), &[5.0]);
    }

    #[test]
    #[should_panic(expected = "not loaded")]
    fn missing_container_panics() {
        Hbm::new().read("ghost");
    }
}
