//! Bounded FIFO channels carrying wide transactions.

use std::collections::VecDeque;

/// One transaction: `lanes` f32 values.
pub type Txn = Box<[f32]>;

/// A FIFO with bounded capacity (transactions).
#[derive(Debug)]
pub struct Fifo {
    pub name: String,
    pub lanes: usize,
    pub capacity: usize,
    q: VecDeque<Txn>,
    pub pushed: u64,
    pub popped: u64,
}

impl Fifo {
    pub fn new(name: &str, lanes: usize, capacity: usize) -> Self {
        Fifo {
            name: name.to_string(),
            lanes,
            capacity: capacity.max(1),
            q: VecDeque::with_capacity(capacity.max(1)),
            pushed: 0,
            popped: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.q.len() >= self.capacity
    }

    /// Space for one more transaction?
    pub fn can_push(&self) -> bool {
        !self.is_full()
    }

    pub fn push(&mut self, t: Txn) -> Result<(), Txn> {
        if self.is_full() {
            return Err(t);
        }
        debug_assert_eq!(t.len(), self.lanes, "channel {} lane mismatch", self.name);
        self.q.push_back(t);
        self.pushed += 1;
        Ok(())
    }

    pub fn pop(&mut self) -> Option<Txn> {
        let t = self.q.pop_front();
        if t.is_some() {
            self.popped += 1;
        }
        t
    }

    pub fn peek(&self) -> Option<&Txn> {
        self.q.front()
    }

    /// Unbounded push for the functional mode.
    pub fn push_unbounded(&mut self, t: Txn) {
        debug_assert_eq!(t.len(), self.lanes);
        self.q.push_back(t);
        self.pushed += 1;
    }

    /// Monotone activity counter: bumps on every push *and* every pop.
    /// The event-driven engine snapshots this around a process tick to
    /// decide which blocked endpoints to wake — a change means the
    /// fifo's occupancy moved, so a consumer may now have data or a
    /// producer may now have space. (A process never has the same fifo
    /// as both input and output, so a push and a pop can't cancel.)
    pub fn activity(&self) -> u64 {
        self.pushed + self.popped
    }
}

/// The pool of channels of a running design, indexed by id; modules
/// hold pre-resolved indices so the hot loop never hashes names.
#[derive(Debug, Default)]
pub struct Channels {
    pub fifos: Vec<Fifo>,
}

impl Channels {
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.fifos.iter().position(|f| f.name == name)
    }

    pub fn by_name(&mut self, name: &str) -> &mut Fifo {
        let i = self.index_of(name).unwrap_or_else(|| panic!("no channel '{name}'"));
        &mut self.fifos[i]
    }

    pub fn all_empty(&self) -> bool {
        self.fifos.iter().all(|f| f.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_capacity() {
        let mut f = Fifo::new("s", 2, 2);
        assert!(f.push(vec![1.0, 2.0].into()).is_ok());
        assert!(f.push(vec![3.0, 4.0].into()).is_ok());
        assert!(f.is_full());
        assert!(f.push(vec![5.0, 6.0].into()).is_err());
        assert_eq!(&*f.pop().unwrap(), &[1.0, 2.0]);
        assert_eq!(f.pushed, 2);
        assert_eq!(f.popped, 1);
        assert_eq!(f.activity(), 3);
    }

    #[test]
    fn channels_lookup() {
        let mut ch = Channels::default();
        ch.fifos.push(Fifo::new("a", 1, 4));
        ch.fifos.push(Fifo::new("b", 1, 4));
        assert_eq!(ch.index_of("b"), Some(1));
        ch.by_name("a").push_unbounded(vec![7.0].into());
        assert!(!ch.all_empty());
    }

    #[test]
    #[should_panic(expected = "no channel")]
    fn unknown_channel_panics() {
        let mut ch = Channels::default();
        ch.by_name("ghost");
    }
}
