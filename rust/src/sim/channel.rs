//! Bounded FIFO channels carrying wide transactions.
//!
//! Since the arena refactor (DESIGN.md §10) a [`Txn`] is a `Copy`
//! handle into the per-simulation [`super::arena::Arena`]; FIFOs queue
//! handles by value and never touch the payload, so a push/pop hop
//! moves 8 bytes instead of reallocating a `Box<[f32]>`.

use std::collections::{HashMap, VecDeque};

pub use super::arena::Txn;

/// A FIFO with bounded capacity (transactions).
#[derive(Debug)]
pub struct Fifo {
    pub name: String,
    pub lanes: usize,
    pub capacity: usize,
    q: VecDeque<Txn>,
    pub pushed: u64,
    pub popped: u64,
    /// Deepest occupancy ever observed (transactions).
    pub high_water: u64,
    /// Stall cause: producer found the FIFO full (backpressure).
    pub full_on_push: u64,
    /// Stall cause: consumer found the FIFO empty (starvation).
    pub empty_on_pop: u64,
}

impl Fifo {
    pub fn new(name: &str, lanes: usize, capacity: usize) -> Self {
        Fifo {
            name: name.to_string(),
            lanes,
            capacity: capacity.max(1),
            q: VecDeque::with_capacity(capacity.max(1)),
            pushed: 0,
            popped: 0,
            high_water: 0,
            full_on_push: 0,
            empty_on_pop: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.q.len() >= self.capacity
    }

    /// Space for one more transaction?
    pub fn can_push(&self) -> bool {
        !self.is_full()
    }

    /// [`Fifo::can_push`] at a producer's stall-decision point: a
    /// `false` answer is counted as a full-on-push stall cause. Use
    /// this (not `can_push`) where a process decides whether to block.
    pub fn ready_push(&mut self) -> bool {
        let ok = self.can_push();
        if !ok {
            self.full_on_push += 1;
        }
        ok
    }

    /// Non-empty check at a consumer's stall-decision point: a `false`
    /// answer is counted as an empty-on-pop stall cause.
    pub fn ready_pop(&mut self) -> bool {
        let ok = !self.is_empty();
        if !ok {
            self.empty_on_pop += 1;
        }
        ok
    }

    /// The channel invariant: every transaction entering this FIFO is
    /// exactly `lanes` wide. One shared check so the bounded and
    /// unbounded push paths cannot drift apart.
    fn check_lanes(&self, t: Txn) {
        debug_assert_eq!(t.lanes(), self.lanes, "channel {} lane mismatch", self.name);
    }

    pub fn push(&mut self, t: Txn) -> Result<(), Txn> {
        if self.is_full() {
            return Err(t);
        }
        self.check_lanes(t);
        self.q.push_back(t);
        self.pushed += 1;
        self.high_water = self.high_water.max(self.q.len() as u64);
        Ok(())
    }

    pub fn pop(&mut self) -> Option<Txn> {
        let t = self.q.pop_front();
        if t.is_some() {
            self.popped += 1;
        }
        t
    }

    pub fn peek(&self) -> Option<Txn> {
        self.q.front().copied()
    }

    /// Unbounded push for the functional mode. Enforces the same lane
    /// invariant as [`Fifo::push`].
    pub fn push_unbounded(&mut self, t: Txn) {
        self.check_lanes(t);
        self.q.push_back(t);
        self.pushed += 1;
        self.high_water = self.high_water.max(self.q.len() as u64);
    }

    /// Monotone activity counter: bumps on every push *and* every pop.
    /// The event-driven engine snapshots this around a process tick to
    /// decide which blocked endpoints to wake — a change means the
    /// fifo's occupancy moved, so a consumer may now have data or a
    /// producer may now have space. (A process never has the same fifo
    /// as both input and output, so a push and a pop can't cancel.)
    pub fn activity(&self) -> u64 {
        self.pushed + self.popped
    }
}

/// The pool of channels of a running design, indexed by id; modules
/// hold pre-resolved indices so the hot loop never hashes names, and
/// name lookups go through a map built at construction instead of an
/// O(n) string scan per call.
#[derive(Debug, Default)]
pub struct Channels {
    /// Indexed FIFO storage. `pub(crate)` so external code cannot push
    /// past [`Channels::add`] and leave the name index stale (the same
    /// footgun class PR 4 closed for `BuildSpec.sdfg`); in-crate code
    /// indexes it directly on the hot path.
    pub(crate) fifos: Vec<Fifo>,
    index: HashMap<String, usize>,
}

impl Channels {
    /// Register a channel, recording its index under its name (first
    /// registration wins on a duplicate name, matching the old linear
    /// scan's first-match semantics).
    pub fn add(&mut self, f: Fifo) {
        self.index.entry(f.name.clone()).or_insert(self.fifos.len());
        self.fifos.push(f);
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    pub fn by_name(&mut self, name: &str) -> &mut Fifo {
        let i = self.index_of(name).unwrap_or_else(|| panic!("no channel '{name}'"));
        &mut self.fifos[i]
    }

    pub fn all_empty(&self) -> bool {
        self.fifos.iter().all(|f| f.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::arena::Arena;

    #[test]
    fn fifo_order_and_capacity() {
        let mut ar = Arena::new();
        let mut f = Fifo::new("s", 2, 2);
        assert!(f.push(ar.alloc_from(&[1.0, 2.0])).is_ok());
        assert!(f.push(ar.alloc_from(&[3.0, 4.0])).is_ok());
        assert!(f.is_full());
        let overflow = ar.alloc_from(&[5.0, 6.0]);
        assert!(f.push(overflow).is_err());
        ar.free(overflow);
        let t = f.pop().unwrap();
        assert_eq!(ar.get(t), &[1.0, 2.0]);
        ar.free(t);
        assert_eq!(f.pushed, 2);
        assert_eq!(f.popped, 1);
        assert_eq!(f.activity(), 3);
    }

    #[test]
    fn stall_causes_and_high_water_are_counted() {
        let mut ar = Arena::new();
        let mut f = Fifo::new("s", 1, 2);
        assert!(!f.ready_pop(), "empty fifo must report starvation");
        assert_eq!(f.empty_on_pop, 1);
        assert!(f.ready_push());
        f.push(ar.alloc_from(&[1.0])).unwrap();
        f.push(ar.alloc_from(&[2.0])).unwrap();
        assert_eq!(f.high_water, 2);
        assert!(!f.ready_push(), "full fifo must report backpressure");
        assert_eq!(f.full_on_push, 1);
        let t = f.pop().unwrap();
        ar.free(t);
        assert!(f.ready_pop());
        // high water is a peak, not the current depth
        assert_eq!(f.high_water, 2);
        assert_eq!(f.empty_on_pop, 1);
        assert_eq!(f.full_on_push, 1);
    }

    #[test]
    fn channels_lookup() {
        let mut ar = Arena::new();
        let mut ch = Channels::default();
        ch.add(Fifo::new("a", 1, 4));
        ch.add(Fifo::new("b", 1, 4));
        assert_eq!(ch.index_of("b"), Some(1));
        assert_eq!(ch.index_of("a"), Some(0));
        assert_eq!(ch.index_of("ghost"), None);
        ch.by_name("a").push_unbounded(ar.alloc_from(&[7.0]));
        assert!(!ch.all_empty());
    }

    #[test]
    #[should_panic(expected = "no channel")]
    fn unknown_channel_panics() {
        let mut ch = Channels::default();
        ch.by_name("ghost");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "lane mismatch")]
    fn bounded_push_rejects_mismatched_lane_width() {
        let mut ar = Arena::new();
        let mut f = Fifo::new("s", 2, 4);
        let _ = f.push(ar.alloc_from(&[1.0, 2.0, 3.0])); // 3 lanes into a 2-lane channel
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "lane mismatch")]
    fn unbounded_push_rejects_mismatched_lane_width() {
        let mut ar = Arena::new();
        let mut f = Fifo::new("s", 2, 4);
        f.push_unbounded(ar.alloc_from(&[1.0])); // 1 lane into a 2-lane channel
    }

    #[test]
    fn peek_returns_the_front_handle() {
        let mut ar = Arena::new();
        let mut f = Fifo::new("s", 1, 4);
        assert_eq!(f.peek(), None);
        let t = ar.alloc_from(&[42.0]);
        f.push_unbounded(t);
        assert_eq!(f.peek(), Some(t));
        assert_eq!(f.len(), 1, "peek must not consume");
    }
}
