//! Runtime process state machines, one per netlist module.
//!
//! Each process exposes two entry points used by the two execution
//! modes: [`Proc::tick`] advances one cycle in the process's own clock
//! domain (exact mode, respecting FIFO capacity), and
//! [`Proc::drain_functional`] processes everything available with
//! unbounded queues (functional mode). Both share the same data path
//! code, so they cannot diverge functionally.
//!
//! Transactions live in the caller's [`Arena`] (DESIGN.md §10): a
//! datapath pops handles, reads payloads through the arena, frees what
//! it consumed and allocates what it produces — the free-then-alloc
//! order on every pop-to-push hop recycles the just-freed slot, so the
//! steady state allocates nothing. Every slot is fully written before
//! its handle is pushed; capacity checks precede allocations so a
//! blocked push never strands a fresh slot.

use super::arena::Arena;
use super::channel::{Channels, Txn};
use super::memory::Hbm;
use crate::codegen::design::ModuleSpec;
use crate::ir::{ClockDomain, StencilKind};

/// Per-process runtime state.
pub struct Proc {
    pub label: String,
    pub domain: ClockDomain,
    pub state: ProcState,
    /// Cycles this process spent stalled (exact mode).
    pub stalls: u64,
    /// Cycles this process did useful work (exact mode).
    pub busy: u64,
}

/// The behavioural state per module kind.
pub enum ProcState {
    Reader {
        data: String,
        out: usize,
        lanes: usize,
        elems: usize,
        pos: usize,
        /// Slow-cycles per transaction (≥1 when the port is wider than
        /// the HBM bus).
        cycles_per_txn: u64,
        credit: u64,
    },
    Writer {
        data: String,
        input: usize,
        lanes: usize,
        elems: usize,
        pos: usize,
        cycles_per_txn: u64,
        credit: u64,
    },
    Compute {
        /// Tasklet compiled to a stack program over positional inputs
        /// (§Perf: the tree-walking eval with string lookups dominated
        /// the exact engine's profile).
        program: super::compute::CompiledTasklet,
        inputs: Vec<usize>,
        output: usize,
        lanes: usize,
        iterations: usize,
        fired: usize,
        ii: u64,
        cooldown: u64,
        /// In-flight pipeline: (ready_at_tick, txn handle).
        pipe: std::collections::VecDeque<(u64, Txn)>,
        latency: u64,
        /// Scratch buffers reused across firings (no hot-loop allocs).
        stack: Vec<f32>,
        vals: Vec<f32>,
        /// Popped input handles of the current firing.
        popped: Vec<Txn>,
        /// Per-lane evaluation results staged before the output slot is
        /// allocated (inputs must be read — and freed — first so the
        /// output allocation recycles one of their slots).
        outbuf: Vec<f32>,
    },
    Sync {
        input: usize,
        output: usize,
    },
    Issuer {
        input: usize,
        output: usize,
        factor: usize,
        /// Partially issued wide transaction.
        hold: Option<(Txn, usize)>,
    },
    Packer {
        input: usize,
        output: usize,
        factor: usize,
        accum: Vec<f32>,
        wide_lanes: usize,
    },
    Gemm {
        a_in: usize,
        b_in: usize,
        c_out: usize,
        n: usize,
        m: usize,
        k: usize,
        macs_per_cycle: usize,
        lanes: usize,
        a_buf: Vec<f32>,
        b_buf: Vec<f32>,
        work_done: u64,
        total_work: u64,
        c_buf: Option<Vec<f32>>,
        c_pos: usize,
    },
    Stencil {
        kind: StencilKind,
        input: usize,
        output: usize,
        nx: usize,
        ny: usize,
        nz: usize,
        lanes: usize,
        /// Full input plane history needed for the 3-D neighbourhood:
        /// ring of 3 planes (prev, curr, next as it streams).
        ring: Vec<f32>,
        in_count: usize,
        out_count: usize,
        total: usize,
    },
    Fw {
        input: usize,
        output: usize,
        n: usize,
        k: usize,
        row_cur: Vec<f32>,
        col_cur: Vec<f32>,
        row_next: Vec<f32>,
        col_next: Vec<f32>,
        pos: usize,
        ii: u64,
        cooldown: u64,
    },
}

impl Proc {
    /// Build the runtime process for a module spec.
    pub fn build(spec: &ModuleSpec, domain: ClockDomain, ch: &Channels) -> Proc {
        let idx = |name: &str| {
            ch.index_of(name)
                .unwrap_or_else(|| panic!("module references unknown channel '{name}'"))
        };
        let state = match spec {
            ModuleSpec::Reader { data, stream, lanes, elems, bytes_per_cycle } => {
                ProcState::Reader {
                    data: data.clone(),
                    out: idx(stream),
                    lanes: *lanes,
                    elems: *elems,
                    pos: 0,
                    cycles_per_txn: ((lanes * 4 + bytes_per_cycle - 1) / bytes_per_cycle).max(1)
                        as u64,
                    credit: 0,
                }
            }
            ModuleSpec::Writer { data, stream, lanes, elems, bytes_per_cycle } => {
                ProcState::Writer {
                    data: data.clone(),
                    input: idx(stream),
                    lanes: *lanes,
                    elems: *elems,
                    pos: 0,
                    cycles_per_txn: ((lanes * 4 + bytes_per_cycle - 1) / bytes_per_cycle).max(1)
                        as u64,
                    credit: 0,
                }
            }
            ModuleSpec::Compute { tasklet, inputs, output, lanes, iterations, ii, latency, .. } => {
                let conns: Vec<String> = inputs.iter().map(|(_, c)| c.clone()).collect();
                let program = super::compute::CompiledTasklet::compile(tasklet, &conns)
                    .expect("validated tasklet compiles");
                let stack = vec![0.0f32; program.stack_depth()];
                ProcState::Compute {
                    program,
                    inputs: inputs.iter().map(|(s, _)| idx(s)).collect(),
                    output: idx(&output.0),
                    lanes: *lanes,
                    iterations: *iterations,
                    fired: 0,
                    ii: *ii,
                    cooldown: 0,
                    pipe: Default::default(),
                    latency: *latency,
                    stack,
                    vals: vec![0.0f32; inputs.len()],
                    popped: Vec::with_capacity(inputs.len()),
                    outbuf: vec![0.0f32; *lanes],
                }
            }
            ModuleSpec::Sync { input, output } => {
                ProcState::Sync { input: idx(input), output: idx(output) }
            }
            ModuleSpec::Issuer { input, output, factor } => ProcState::Issuer {
                input: idx(input),
                output: idx(output),
                factor: *factor,
                hold: None,
            },
            ModuleSpec::Packer { input, output, factor } => {
                let wide_lanes = ch.fifos[idx(output)].lanes;
                ProcState::Packer {
                    input: idx(input),
                    output: idx(output),
                    factor: *factor,
                    accum: Vec::with_capacity(wide_lanes),
                    wide_lanes,
                }
            }
            ModuleSpec::GemmCore { a, b, c, n, m, k, pes, lanes, .. } => ProcState::Gemm {
                a_in: idx(a),
                b_in: idx(b),
                c_out: idx(c),
                n: *n,
                m: *m,
                k: *k,
                macs_per_cycle: pes * lanes,
                lanes: *lanes,
                a_buf: Vec::new(),
                b_buf: Vec::new(),
                work_done: 0,
                total_work: (*n as u64) * (*m as u64) * (*k as u64),
                c_buf: None,
                c_pos: 0,
            },
            ModuleSpec::StencilCore { kind, input, output, nx, ny, nz, lanes, .. } => {
                ProcState::Stencil {
                    kind: *kind,
                    input: idx(input),
                    output: idx(output),
                    nx: *nx,
                    ny: *ny,
                    nz: *nz,
                    lanes: *lanes,
                    ring: Vec::new(),
                    in_count: 0,
                    out_count: 0,
                    total: nx * ny * nz,
                }
            }
            ModuleSpec::FwCore { input, output, n, lanes: _, ii, .. } => ProcState::Fw {
                input: idx(input),
                output: idx(output),
                n: *n,
                k: 0,
                row_cur: vec![f32::INFINITY; *n],
                col_cur: vec![f32::INFINITY; *n],
                row_next: vec![f32::INFINITY; *n],
                col_next: vec![f32::INFINITY; *n],
                pos: 0,
                ii: *ii,
                cooldown: 0,
                // lanes kept for throughput-mode accounting
            },
        };
        let _ = match spec {
            ModuleSpec::FwCore { lanes, .. } => *lanes,
            _ => 1,
        };
        Proc { label: spec.label(), domain, state, stalls: 0, busy: 0 }
    }

    /// Channel indices this process pops from. A blocked process can
    /// only unblock when one of these receives a push (or one of
    /// [`Proc::output_channels`] is popped, or its pipeline retires) —
    /// the event-driven engine's wake conditions.
    pub fn input_channels(&self) -> Vec<usize> {
        match &self.state {
            ProcState::Reader { .. } => vec![],
            ProcState::Writer { input, .. }
            | ProcState::Sync { input, .. }
            | ProcState::Issuer { input, .. }
            | ProcState::Packer { input, .. }
            | ProcState::Stencil { input, .. }
            | ProcState::Fw { input, .. } => vec![*input],
            ProcState::Compute { inputs, .. } => inputs.clone(),
            ProcState::Gemm { a_in, b_in, .. } => vec![*a_in, *b_in],
        }
    }

    /// Channel indices this process pushes into (see
    /// [`Proc::input_channels`]).
    pub fn output_channels(&self) -> Vec<usize> {
        match &self.state {
            ProcState::Reader { out, .. } => vec![*out],
            ProcState::Writer { .. } => vec![],
            ProcState::Compute { output, .. }
            | ProcState::Sync { output, .. }
            | ProcState::Issuer { output, .. }
            | ProcState::Packer { output, .. }
            | ProcState::Stencil { output, .. }
            | ProcState::Fw { output, .. } => vec![*output],
            ProcState::Gemm { c_out, .. } => vec![*c_out],
        }
    }

    /// Fast-time at which the earliest in-flight pipelined result can
    /// retire, for processes with a latency pipe. A process blocked
    /// with work in flight needs a *timed* wake at this tick even when
    /// no channel event arrives.
    pub fn next_retire_time(&self) -> Option<u64> {
        match &self.state {
            ProcState::Compute { pipe, .. } => pipe.front().map(|(ready, _)| *ready),
            _ => None,
        }
    }

    /// Does `done()` never regress for this process kind? True for
    /// stateful endpoints (their work counters only grow); false for
    /// flow-through modules whose doneness depends on upstream pushes.
    pub fn monotone_done(&self) -> bool {
        !matches!(
            self.state,
            ProcState::Sync { .. } | ProcState::Issuer { .. } | ProcState::Packer { .. }
        )
    }

    /// Is the process finished with all its work?
    pub fn done(&self, ch: &Channels) -> bool {
        match &self.state {
            ProcState::Reader { pos, elems, .. } => *pos >= *elems,
            ProcState::Writer { pos, elems, .. } => *pos >= *elems,
            ProcState::Compute { fired, iterations, pipe, .. } => {
                *fired >= *iterations && pipe.is_empty()
            }
            ProcState::Sync { input, .. }
            | ProcState::Issuer { input, hold: None, .. }
            | ProcState::Packer { input, .. } => ch.fifos[*input].is_empty(),
            ProcState::Issuer { .. } => false,
            ProcState::Gemm { work_done, total_work, c_buf, .. } => {
                *work_done >= *total_work && c_buf.is_none()
            }
            ProcState::Stencil { out_count, total, lanes, .. } => *out_count >= total / lanes,
            ProcState::Fw { pos, n, .. } => *pos >= n * n,
        }
    }

    /// Reset per-repeat state (sequential outer loop): processes start
    /// a fresh pass over the data.
    pub fn reset_for_repeat(&mut self) {
        match &mut self.state {
            ProcState::Reader { pos, .. } | ProcState::Writer { pos, .. } => *pos = 0,
            ProcState::Compute { fired, .. } => *fired = 0,
            ProcState::Gemm { work_done, c_pos, a_buf, b_buf, c_buf, .. } => {
                *work_done = 0;
                *c_pos = 0;
                a_buf.clear();
                b_buf.clear();
                *c_buf = None;
            }
            ProcState::Stencil { in_count, out_count, ring, .. } => {
                *in_count = 0;
                *out_count = 0;
                ring.clear();
            }
            ProcState::Fw { pos, k, row_cur, col_cur, row_next, col_next, .. } => {
                *pos = 0;
                *k += 1;
                std::mem::swap(row_cur, row_next);
                std::mem::swap(col_cur, col_next);
            }
            _ => {}
        }
    }

    /// One cycle in this process's clock domain. Returns true if the
    /// process made progress.
    pub fn tick(&mut self, now: u64, ch: &mut Channels, arena: &mut Arena, hbm: &mut Hbm) -> bool {
        let progressed = self.step(now, ch, arena, hbm, false);
        if progressed {
            self.busy += 1;
        } else if !self.done(ch) {
            self.stalls += 1;
        }
        progressed
    }

    /// Functional mode: loop steps until nothing more can be done.
    pub fn drain_functional(
        &mut self,
        ch: &mut Channels,
        arena: &mut Arena,
        hbm: &mut Hbm,
    ) -> bool {
        let mut any = false;
        while self.step(0, ch, arena, hbm, true) {
            any = true;
        }
        any
    }

    /// Shared datapath. `unbounded` disables capacity/II/latency
    /// modelling (functional mode).
    fn step(
        &mut self,
        now: u64,
        ch: &mut Channels,
        arena: &mut Arena,
        hbm: &mut Hbm,
        unbounded: bool,
    ) -> bool {
        match &mut self.state {
            ProcState::Reader { data, out, lanes, elems, pos, cycles_per_txn, credit } => {
                if *pos >= *elems {
                    return false;
                }
                if !unbounded {
                    *credit += 1;
                    if *credit < *cycles_per_txn {
                        return true; // burst in progress
                    }
                    if !ch.fifos[*out].ready_push() {
                        *credit = *cycles_per_txn; // hold the beat
                        return false;
                    }
                    *credit = 0;
                }
                let txn = arena.alloc(*lanes);
                hbm.fetch(data, *pos * *lanes, arena.get_mut(txn));
                if unbounded {
                    ch.fifos[*out].push_unbounded(txn);
                } else {
                    ch.fifos[*out].push(txn).expect("checked can_push");
                }
                *pos += 1;
                true
            }
            ProcState::Writer { data, input, lanes, elems, pos, cycles_per_txn, credit } => {
                if *pos >= *elems {
                    return false;
                }
                if !unbounded {
                    *credit += 1;
                    if *credit < *cycles_per_txn {
                        return true;
                    }
                }
                if !ch.fifos[*input].ready_pop() {
                    return false;
                }
                let txn = ch.fifos[*input].pop().expect("checked ready_pop");
                if !unbounded {
                    *credit = 0;
                }
                hbm.store(data, *pos * *lanes, arena.get(txn));
                arena.free(txn);
                *pos += 1;
                true
            }
            ProcState::Compute {
                program,
                inputs,
                output,
                lanes,
                iterations,
                fired,
                ii,
                cooldown,
                pipe,
                latency,
                stack,
                vals,
                popped,
                outbuf,
            } => {
                let mut progressed = false;
                // retire finished transactions
                if !unbounded {
                    if let Some((ready, _)) = pipe.front() {
                        if *ready <= now && ch.fifos[*output].ready_push() {
                            let (_, txn) = pipe.pop_front().unwrap();
                            ch.fifos[*output].push(txn).expect("checked");
                            progressed = true;
                        }
                    }
                    if *cooldown > 0 {
                        *cooldown -= 1;
                        return true; // pipeline advancing
                    }
                }
                if *fired >= *iterations {
                    return progressed;
                }
                // need one txn on every input (checking all of them so
                // each starved channel records its empty-on-pop cause)
                let mut starved = false;
                for i in inputs.iter() {
                    if !ch.fifos[*i].ready_pop() {
                        starved = true;
                    }
                }
                if starved {
                    return progressed;
                }
                popped.clear();
                for i in inputs.iter() {
                    popped.push(ch.fifos[*i].pop().unwrap());
                }
                // evaluate per lane with the compiled stack program,
                // staging results so the inputs can be freed before the
                // output slot is allocated (recycling their slots)
                program.eval_lanes(arena, popped, vals, stack, outbuf);
                for t in popped.drain(..) {
                    arena.free(t);
                }
                let txn = arena.alloc(*lanes);
                arena.get_mut(txn).copy_from_slice(outbuf);
                *fired += 1;
                if unbounded {
                    ch.fifos[*output].push_unbounded(txn);
                } else {
                    pipe.push_back((now + *latency, txn));
                    *cooldown = ii.saturating_sub(1);
                }
                true
            }
            ProcState::Sync { input, output } => {
                if !ch.fifos[*input].ready_pop() {
                    return false;
                }
                if !unbounded && !ch.fifos[*output].ready_push() {
                    return false;
                }
                // same lane width on both sides: the handle moves
                // through untouched — no copy, no allocation
                let t = ch.fifos[*input].pop().unwrap();
                if unbounded {
                    ch.fifos[*output].push_unbounded(t);
                } else {
                    ch.fifos[*output].push(t).expect("checked");
                }
                true
            }
            ProcState::Issuer { input, output, factor, hold } => {
                if hold.is_none() {
                    if !ch.fifos[*input].ready_pop() {
                        return false;
                    }
                    let t = ch.fifos[*input].pop().expect("checked ready_pop");
                    *hold = Some((t, 0));
                }
                if !unbounded && !ch.fifos[*output].ready_push() {
                    return false;
                }
                let narrow_lanes = ch.fifos[*output].lanes;
                let (wide, idx) = hold.as_mut().unwrap();
                let wide = *wide;
                let base = *idx * narrow_lanes;
                let txn = arena.alloc_copy_sub(wide, base, narrow_lanes);
                if unbounded {
                    ch.fifos[*output].push_unbounded(txn);
                } else {
                    ch.fifos[*output].push(txn).expect("checked");
                }
                *idx += 1;
                if *idx >= *factor {
                    arena.free(wide);
                    *hold = None;
                }
                true
            }
            ProcState::Packer { input, output, factor, accum, wide_lanes } => {
                let _ = factor;
                if accum.len() < *wide_lanes {
                    if !ch.fifos[*input].ready_pop() {
                        return false;
                    }
                    let t = ch.fifos[*input].pop().expect("checked ready_pop");
                    accum.extend_from_slice(arena.get(t));
                    arena.free(t);
                }
                if accum.len() >= *wide_lanes {
                    if !unbounded && !ch.fifos[*output].ready_push() {
                        return false;
                    }
                    let txn = arena.alloc(*wide_lanes);
                    arena.get_mut(txn).copy_from_slice(&accum[..*wide_lanes]);
                    accum.drain(..*wide_lanes);
                    if unbounded {
                        ch.fifos[*output].push_unbounded(txn);
                    } else {
                        ch.fifos[*output].push(txn).expect("checked");
                    }
                }
                true
            }
            ProcState::Gemm {
                a_in,
                b_in,
                c_out,
                n,
                m,
                k,
                macs_per_cycle,
                lanes,
                a_buf,
                b_buf,
                work_done,
                total_work,
                c_buf,
                c_pos,
            } => {
                let mut progressed = false;
                // ingest at most one txn per input per cycle
                if a_buf.len() < *n * *k {
                    if ch.fifos[*a_in].ready_pop() {
                        let t = ch.fifos[*a_in].pop().expect("checked ready_pop");
                        a_buf.extend_from_slice(arena.get(t));
                        arena.free(t);
                        progressed = true;
                    }
                }
                if b_buf.len() < *k * *m {
                    if ch.fifos[*b_in].ready_pop() {
                        let t = ch.fifos[*b_in].pop().expect("checked ready_pop");
                        b_buf.extend_from_slice(arena.get(t));
                        arena.free(t);
                        progressed = true;
                    }
                }
                // compute: cannot run ahead of delivered input fraction
                if *work_done < *total_work {
                    let frac =
                        (a_buf.len() as f64 / (*n * *k) as f64).min(b_buf.len() as f64 / (*k * *m) as f64);
                    let allowed = (*total_work as f64 * frac) as u64;
                    if *work_done < allowed {
                        let step = if unbounded {
                            allowed - *work_done
                        } else {
                            (*macs_per_cycle as u64).min(allowed - *work_done)
                        };
                        *work_done += step;
                        progressed = true;
                    }
                }
                // drain C
                if *work_done >= *total_work {
                    if c_buf.is_none() && a_buf.len() >= *n * *k && b_buf.len() >= *k * *m {
                        // functional matmul
                        let mut c = vec![0.0f32; *n * *m];
                        for i in 0..*n {
                            for kk in 0..*k {
                                let a = a_buf[i * *k + kk];
                                if a == 0.0 {
                                    continue;
                                }
                                let brow = &b_buf[kk * *m..(kk + 1) * *m];
                                let crow = &mut c[i * *m..(i + 1) * *m];
                                for j in 0..*m {
                                    crow[j] += a * brow[j];
                                }
                            }
                        }
                        *c_buf = Some(c);
                    }
                    if let Some(c) = c_buf {
                        let total_txns = *n * *m / *lanes;
                        while *c_pos < total_txns {
                            if !unbounded && !ch.fifos[*c_out].ready_push() {
                                break;
                            }
                            let base = *c_pos * *lanes;
                            let txn = arena.alloc(*lanes);
                            arena.get_mut(txn).copy_from_slice(&c[base..base + *lanes]);
                            if unbounded {
                                ch.fifos[*c_out].push_unbounded(txn);
                            } else {
                                ch.fifos[*c_out].push(txn).expect("checked");
                            }
                            *c_pos += 1;
                            progressed = true;
                            if !unbounded {
                                break; // one txn per cycle
                            }
                        }
                        if *c_pos >= total_txns {
                            *c_buf = None;
                            *work_done = *total_work; // done
                        }
                    }
                }
                progressed
            }
            ProcState::Stencil {
                kind,
                input,
                output,
                nx,
                ny,
                nz,
                lanes,
                ring,
                in_count,
                out_count,
                total,
            } => {
                let mut progressed = false;
                // ingest one txn
                if *in_count < *total / *lanes {
                    if ch.fifos[*input].ready_pop() {
                        let t = ch.fifos[*input].pop().expect("checked ready_pop");
                        ring.extend_from_slice(arena.get(t));
                        arena.free(t);
                        *in_count += 1;
                        progressed = true;
                    }
                }
                // emit once the neighbourhood is available: output txn
                // t requires input up to (t*lanes + plane + row + 1)
                let plane = *ny * *nz;
                let have = ring.len();
                let want_out = *out_count * *lanes;
                if want_out < *total && have >= (want_out + plane + *nz + 1).min(*total) {
                    if !unbounded && !ch.fifos[*output].ready_push() {
                        return progressed;
                    }
                    let txn = arena.alloc(*lanes);
                    {
                        let dst = arena.get_mut(txn);
                        for (l, d) in dst.iter_mut().enumerate() {
                            *d = stencil_point(*kind, ring, want_out + l, *nx, *ny, *nz);
                        }
                    }
                    if unbounded {
                        ch.fifos[*output].push_unbounded(txn);
                    } else {
                        ch.fifos[*output].push(txn).expect("checked");
                    }
                    *out_count += 1;
                    progressed = true;
                }
                progressed
            }
            ProcState::Fw {
                input,
                output,
                n,
                k,
                row_cur,
                col_cur,
                row_next,
                col_next,
                pos,
                ii,
                cooldown,
            } => {
                if *pos >= *n * *n {
                    return false;
                }
                if !unbounded && *cooldown > 0 {
                    *cooldown -= 1;
                    return true;
                }
                if !unbounded && !ch.fifos[*output].ready_push() {
                    return false;
                }
                if !ch.fifos[*input].ready_pop() {
                    return false;
                }
                let t = ch.fifos[*input].pop().expect("checked ready_pop");
                let d = arena.get(t)[0];
                arena.free(t);
                let i = *pos / *n;
                let j = *pos % *n;
                // k=0 first pass: row/col 0 not yet buffered; capture
                // directly (d[0][j] and d[i][0] stream before use only
                // for i==0/j==0 — handle by capturing on the fly)
                if i == *k {
                    row_cur[j] = d;
                }
                if j == *k {
                    col_cur[i] = d;
                }
                let relaxed = if row_cur[j].is_finite() && col_cur[i].is_finite() {
                    d.min(col_cur[i] + row_cur[j])
                } else {
                    d
                };
                // capture next iteration's row/col from the *relaxed*
                // values
                let kn = *k + 1;
                if i == kn {
                    row_next[j] = relaxed;
                }
                if j == kn {
                    col_next[i] = relaxed;
                }
                let txn = arena.alloc(1);
                arena.get_mut(txn)[0] = relaxed;
                if unbounded {
                    ch.fifos[*output].push_unbounded(txn);
                } else {
                    ch.fifos[*output].push(txn).expect("checked");
                    *cooldown = ii.saturating_sub(1);
                }
                *pos += 1;
                true
            }
        }
    }
}

/// Evaluate one stencil output point from the flat input array.
/// Boundary points pass through unchanged (halo copy), matching the
/// golden models in `python/compile/kernels/ref.py`.
pub fn stencil_point(
    kind: StencilKind,
    data: &[f32],
    idx: usize,
    nx: usize,
    ny: usize,
    nz: usize,
) -> f32 {
    let plane = ny * nz;
    let x = idx / plane;
    let y = (idx % plane) / nz;
    let z = idx % nz;
    let at = |xx: usize, yy: usize, zz: usize| data[xx * plane + yy * nz + zz];
    if x == 0 || x + 1 >= nx || y == 0 || y + 1 >= ny || z == 0 || z + 1 >= nz {
        return data[idx];
    }
    let (xm, xp) = (at(x - 1, y, z), at(x + 1, y, z));
    let (ym, yp) = (at(x, y - 1, z), at(x, y + 1, z));
    let (zm, zp) = (at(x, y, z - 1), at(x, y, z + 1));
    let c = data[idx];
    match kind {
        // w * (sum of 6 neighbours): 5 adds + 1 mul
        StencilKind::Jacobi3D => (xm + xp + ym + yp + zm + zp) * (1.0 / 6.0),
        // c0*center + cx*(x neighbours) + cy*(y) + cz*(z): 6 adds + 4 muls
        StencilKind::Diffusion3D => {
            0.5 * c + 0.125 * (xm + xp) + 0.0833 * (ym + yp) + 0.0917 * (zm + zp)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::Tasklet;
    use crate::sim::channel::{Channels, Fifo};

    fn chans(names: &[(&str, usize, usize)]) -> Channels {
        let mut ch = Channels::default();
        for (n, lanes, cap) in names {
            ch.add(Fifo::new(n, *lanes, *cap));
        }
        ch
    }

    #[test]
    fn reader_streams_memory() {
        let mut ch = chans(&[("s", 2, 8)]);
        let mut ar = Arena::new();
        let mut hbm = Hbm::new();
        hbm.load("x", vec![1.0, 2.0, 3.0, 4.0]);
        let spec = ModuleSpec::Reader {
            data: "x".into(),
            stream: "s".into(),
            lanes: 2,
            elems: 2,
            bytes_per_cycle: 32,
        };
        let mut p = Proc::build(&spec, ClockDomain::Slow, &ch);
        while !p.done(&ch) {
            p.tick(0, &mut ch, &mut ar, &mut hbm);
        }
        let t = ch.by_name("s").pop().unwrap();
        assert_eq!(ar.get(t), &[1.0, 2.0]);
        ar.free(t);
        let t = ch.by_name("s").pop().unwrap();
        assert_eq!(ar.get(t), &[3.0, 4.0]);
        ar.free(t);
    }

    #[test]
    fn issuer_splits_packer_packs() {
        let mut ch = chans(&[("w", 4, 4), ("n", 2, 8), ("w2", 4, 4)]);
        let mut ar = Arena::new();
        let mut hbm = Hbm::new();
        let wide = ar.alloc_from(&[1.0, 2.0, 3.0, 4.0]);
        ch.by_name("w").push_unbounded(wide);
        let mut issuer = Proc::build(
            &ModuleSpec::Issuer { input: "w".into(), output: "n".into(), factor: 2 },
            ClockDomain::Fast { factor: 2 },
            &ch,
        );
        issuer.drain_functional(&mut ch, &mut ar, &mut hbm);
        assert_eq!(ch.by_name("n").len(), 2);
        let mut packer = Proc::build(
            &ModuleSpec::Packer { input: "n".into(), output: "w2".into(), factor: 2 },
            ClockDomain::Fast { factor: 2 },
            &ch,
        );
        packer.drain_functional(&mut ch, &mut ar, &mut hbm);
        let t = ch.by_name("w2").pop().unwrap();
        assert_eq!(ar.get(t), &[1.0, 2.0, 3.0, 4.0]);
        ar.free(t);
        // the wide input and the two narrow intermediates were all
        // freed along the way: only the repacked wide txn was live
        assert_eq!(ar.stats().live, 0);
        assert!(ar.stats().recycle_hits > 0, "split→pack must recycle slots");
    }

    #[test]
    fn compute_applies_tasklet_per_lane() {
        use crate::ir::TaskExpr;
        let mut ch = chans(&[("a", 2, 8), ("b", 2, 8), ("o", 2, 8)]);
        let mut ar = Arena::new();
        let mut hbm = Hbm::new();
        let ta = ar.alloc_from(&[1.0, 2.0]);
        let tb = ar.alloc_from(&[10.0, 20.0]);
        ch.by_name("a").push_unbounded(ta);
        ch.by_name("b").push_unbounded(tb);
        let spec = ModuleSpec::Compute {
            name: "add".into(),
            tasklet: Tasklet::new("add", vec![("o", TaskExpr::input("x").add(TaskExpr::input("y")))]),
            inputs: vec![("a".into(), "x".into()), ("b".into(), "y".into())],
            output: ("o".into(), "o".into()),
            lanes: 2,
            iterations: 1,
            ii: 1,
            latency: 8,
        };
        let mut p = Proc::build(&spec, ClockDomain::Slow, &ch);
        p.drain_functional(&mut ch, &mut ar, &mut hbm);
        let t = ch.by_name("o").pop().unwrap();
        assert_eq!(ar.get(t), &[11.0, 22.0]);
        ar.free(t);
        assert_eq!(ar.stats().live, 0, "consumed inputs must be freed");
    }

    #[test]
    fn compute_exact_mode_respects_latency() {
        use crate::ir::TaskExpr;
        let mut ch = chans(&[("a", 1, 8), ("o", 1, 8)]);
        let mut ar = Arena::new();
        let mut hbm = Hbm::new();
        let t = ar.alloc_from(&[5.0]);
        ch.by_name("a").push_unbounded(t);
        let spec = ModuleSpec::Compute {
            name: "id".into(),
            tasklet: Tasklet::new("id", vec![("o", TaskExpr::input("x"))]),
            inputs: vec![("a".into(), "x".into())],
            output: ("o".into(), "o".into()),
            lanes: 1,
            iterations: 1,
            ii: 1,
            latency: 5,
        };
        let mut p = Proc::build(&spec, ClockDomain::Slow, &ch);
        p.tick(0, &mut ch, &mut ar, &mut hbm); // accepted into pipe
        assert!(ch.by_name("o").is_empty()); // latency not elapsed
        for t in 1..=5 {
            p.tick(t, &mut ch, &mut ar, &mut hbm);
        }
        assert_eq!(ch.by_name("o").len(), 1);
    }

    #[test]
    fn stencil_point_jacobi_interior() {
        // 3×3×3 cube of ones: interior average = 1
        let data = vec![1.0f32; 27];
        let v = stencil_point(StencilKind::Jacobi3D, &data, 13, 3, 3, 3);
        assert!((v - 1.0).abs() < 1e-6);
        // boundary passes through
        assert_eq!(stencil_point(StencilKind::Jacobi3D, &data, 0, 3, 3, 3), 1.0);
    }

    #[test]
    fn fw_core_relaxes_small_graph() {
        // 3-node graph: 0→1 (1.0), 1→2 (2.0), 0→2 (9.0); after FW the
        // 0→2 distance becomes 3.0
        let inf = 1e30f32;
        let n = 3usize;
        #[rustfmt::skip]
        let mut dist = vec![
            0.0, 1.0, 9.0,
            inf, 0.0, 2.0,
            inf, inf, 0.0,
        ];
        // run n sequential passes through the core
        for k in 0..n {
            let mut ch = chans(&[("in", 1, 64), ("out", 1, 64)]);
            let mut ar = Arena::new();
            let mut hbm = Hbm::new();
            for v in &dist {
                let t = ar.alloc_from(&[*v]);
                ch.by_name("in").push_unbounded(t);
            }
            let spec = ModuleSpec::FwCore {
                name: "fw".into(),
                input: "in".into(),
                output: "out".into(),
                n,
                lanes: 1,
                ii: 21,
            };
            let mut p = Proc::build(&spec, ClockDomain::Slow, &ch);
            // preload row/col buffers for pass k (captured in pass k-1
            // on hardware; equivalently compute from current matrix)
            if let ProcState::Fw { row_cur, col_cur, k: kk, .. } = &mut p.state {
                *kk = k;
                for j in 0..n {
                    row_cur[j] = dist[k * n + j];
                    col_cur[j] = dist[j * n + k];
                }
            }
            p.drain_functional(&mut ch, &mut ar, &mut hbm);
            for v in dist.iter_mut() {
                let t = ch.by_name("out").pop().unwrap();
                *v = ar.get(t)[0];
                ar.free(t);
            }
        }
        assert_eq!(dist[2], 3.0);
    }
}
