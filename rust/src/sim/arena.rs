//! Pooled transaction arena: the simulator data plane's slot allocator.
//!
//! Before this module every transaction travelling a FIFO was an owned
//! `Box<[f32]>` — one heap allocation per push, one free per
//! consumption, in the innermost loop of both exact engines (ROADMAP
//! "Simulator performance"). The arena replaces that with per-lane-class
//! slabs and free lists: a [`Txn`] is now a lightweight `Copy` handle
//! (slot index + lane class) that FIFOs enqueue by value and processes
//! read/write through [`Arena::get`]/[`Arena::get_mut`]. A pop-to-push
//! hop along a pipeline frees the consumed slot and immediately
//! recycles it for the produced one (the free list is LIFO), so a
//! steady-state simulation performs **zero** per-transaction heap
//! allocation — the slabs grow to the design's high-water mark on the
//! first run and are then reused forever.
//!
//! Lifecycle contract:
//! * every [`Arena::alloc`] is fully initialised by its producer before
//!   the handle is pushed (readers fill from HBM, computes copy their
//!   evaluated lanes, issuers/packers copy and zero-pad) — recycled
//!   slot contents can never leak into results;
//! * every consumed handle is [`Arena::free`]d exactly once (a debug
//!   build asserts against double frees and use-after-free);
//! * [`Arena::reset`] is a *high-water-mark reset*: live slots drop to
//!   zero and every slot returns to its free list, but slabs, slot
//!   counts and the peak-live statistic are retained — the reset an
//!   engine performs on entry and the DSE evaluator's
//!   [`crate::dse::evaluate::ArenaPool`] performs between candidates,
//!   so repeated evaluations tear nothing down and allocate nothing.
//!
//! Both exact engines ([`super::engine::run_exact`] and the oracle
//! [`super::engine::run_exact_reference`]) share one arena through the
//! `_in` entry points, keeping the cycle-exactness property suite
//! comparing like for like (DESIGN.md §10).

/// Handle to one pooled transaction: `lanes` f32 values living in the
/// arena's lane class `class` at slot `slot`. 8 bytes, `Copy` — FIFOs
/// move these by value; only the arena touches the payload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Txn {
    class: u16,
    lanes: u16,
    slot: u32,
}

impl Txn {
    /// Lane width of the payload — carried in the handle so a FIFO can
    /// enforce its lane invariant without an arena reference.
    pub fn lanes(&self) -> usize {
        self.lanes as usize
    }
}

/// One lane-width class: a contiguous slab of `slots × lanes` values
/// plus the free list of recyclable slot indices. Liveness is counted
/// arena-wide (a single simultaneous high-water mark across classes).
#[derive(Debug, Default)]
struct LaneClass {
    lanes: usize,
    data: Vec<f32>,
    free: Vec<u32>,
    /// Slot liveness, for double-free/use-after-free debug assertions.
    live_flag: Vec<bool>,
    slots: u32,
}

impl LaneClass {
    fn new(lanes: usize) -> LaneClass {
        LaneClass { lanes, ..LaneClass::default() }
    }
}

/// Aggregate arena counters, surfaced through
/// [`super::stats::SimStats`], the `BENCH_sim.json` `arena` block and
/// the `dse --verify` report.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Distinct lane-width classes (slabs).
    pub classes: usize,
    /// Slots ever carved across all slabs — flat across repeated runs
    /// of the same design once the first run established the peak.
    pub slots: u64,
    /// Slots currently checked out.
    pub live: u64,
    /// High-water mark of simultaneously live slots.
    pub peak_live: u64,
    /// Allocations served from a free list instead of slab growth.
    pub recycle_hits: u64,
    /// High-water-mark resets performed.
    pub resets: u64,
    /// Slots still checked out when a reset reclaimed them, summed over
    /// all resets. Nonzero is legitimate only after an aborted or
    /// errored run (the engines reset on entry and reclaim whatever a
    /// previous failure left live); across *clean* runs it must stay 0,
    /// which the property suite asserts — the leak-on-reset canary.
    pub leaked: u64,
}

impl ArenaStats {
    /// Fold another arena's counters in (pool-level aggregation):
    /// capacity and activity counters sum, but `classes` takes the max
    /// — pool members simulating the same workloads carry the *same*
    /// lane classes, so summing would overcount the distinct widths.
    pub fn accumulate(&mut self, other: &ArenaStats) {
        self.classes = self.classes.max(other.classes);
        self.slots += other.slots;
        self.live += other.live;
        self.peak_live += other.peak_live;
        self.recycle_hits += other.recycle_hits;
        self.resets += other.resets;
        self.leaked += other.leaked;
    }
}

/// Debug-build fill pattern for freshly checked-out slots: a signaling
/// bit pattern (a quiet NaN with a recognizable payload) that makes an
/// uninitialized-lane bug — a producer publishing a slot it didn't
/// fully write — surface as NaNs in outputs instead of stale values
/// from the previous tenant silently passing tests.
pub const POISON: f32 = f32::from_bits(0x7FC0_DEAD);

/// The per-simulation transaction slab allocator.
#[derive(Debug, Default)]
pub struct Arena {
    classes: Vec<LaneClass>,
    /// O(1) lane-width → class lookup (class index + 1; 0 = unmapped),
    /// indexed by lane width — `alloc` sits in the engines' innermost
    /// loop, so no per-transaction scan of the class list.
    class_by_lanes: Vec<u32>,
    /// Currently live slots, across all classes.
    live: u64,
    /// True high-water mark of *simultaneously* live slots.
    peak_live: u64,
    recycle_hits: u64,
    resets: u64,
    /// Live slots reclaimed by resets (see [`Arena::reset`]).
    leaked: u64,
    /// Staging buffer for intra-arena copies (issuer wide→narrow
    /// splits), reused so the hot loop never allocates.
    scratch: Vec<f32>,
}

impl Arena {
    pub fn new() -> Arena {
        Arena::default()
    }

    /// Class index for a lane width, creating the class on first use.
    fn class_for(&mut self, lanes: usize) -> usize {
        assert!(lanes <= u16::MAX as usize, "arena lane width limit exceeded");
        if lanes >= self.class_by_lanes.len() {
            self.class_by_lanes.resize(lanes + 1, 0);
        }
        let mapped = self.class_by_lanes[lanes];
        if mapped != 0 {
            return (mapped - 1) as usize;
        }
        assert!(self.classes.len() < u16::MAX as usize, "arena lane-class limit exceeded");
        self.classes.push(LaneClass::new(lanes));
        self.class_by_lanes[lanes] = self.classes.len() as u32;
        self.classes.len() - 1
    }

    /// Check out a `lanes`-wide slot. Served from the lane class's free
    /// list when possible (a recycle hit); slab growth otherwise. The
    /// caller must fully initialise the payload before publishing the
    /// handle.
    pub fn alloc(&mut self, lanes: usize) -> Txn {
        let class = self.class_for(lanes);
        let c = &mut self.classes[class];
        let slot = match c.free.pop() {
            Some(s) => {
                self.recycle_hits += 1;
                s
            }
            None => {
                let s = c.slots;
                c.slots += 1;
                c.data.resize(c.data.len() + lanes, 0.0);
                c.live_flag.push(false);
                s
            }
        };
        debug_assert!(!c.live_flag[slot as usize], "allocated a live arena slot");
        c.live_flag[slot as usize] = true;
        // poison the payload in debug builds — growth and recycle paths
        // alike — so a producer that publishes a partially written slot
        // leaks NaNs into outputs instead of the previous tenant's data
        if cfg!(debug_assertions) {
            let base = slot as usize * lanes;
            c.data[base..base + lanes].fill(POISON);
        }
        self.live += 1;
        self.peak_live = self.peak_live.max(self.live);
        Txn { class: class as u16, lanes: lanes as u16, slot }
    }

    /// Check out a slot pre-filled from `values`.
    pub fn alloc_from(&mut self, values: &[f32]) -> Txn {
        let t = self.alloc(values.len());
        self.get_mut(t).copy_from_slice(values);
        t
    }

    /// Check out a `lanes`-wide slot holding `src[offset ..
    /// offset+lanes]` of an existing slot, zero-filled past the
    /// source's end — the issuer's wide→narrow split. Staged through
    /// the arena's scratch buffer because source and destination may
    /// share a slab.
    pub fn alloc_copy_sub(&mut self, src: Txn, offset: usize, lanes: usize) -> Txn {
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        {
            let s = self.get(src);
            for l in 0..lanes {
                scratch.push(s.get(offset + l).copied().unwrap_or(0.0));
            }
        }
        let t = self.alloc(lanes);
        self.get_mut(t).copy_from_slice(&scratch);
        self.scratch = scratch;
        t
    }

    /// Return a consumed slot to its free list, making it the next
    /// allocation's recycle hit.
    pub fn free(&mut self, t: Txn) {
        let c = &mut self.classes[t.class as usize];
        debug_assert_eq!(c.lanes, t.lanes as usize, "handle/class lane mismatch");
        debug_assert!(c.live_flag[t.slot as usize], "double free of arena slot");
        c.live_flag[t.slot as usize] = false;
        self.live -= 1;
        c.free.push(t.slot);
    }

    /// The payload of a live slot.
    pub fn get(&self, t: Txn) -> &[f32] {
        let c = &self.classes[t.class as usize];
        debug_assert!(c.live_flag[t.slot as usize], "read of a freed arena slot");
        let base = t.slot as usize * c.lanes;
        &c.data[base..base + c.lanes]
    }

    /// Mutable payload of a live slot.
    pub fn get_mut(&mut self, t: Txn) -> &mut [f32] {
        let c = &mut self.classes[t.class as usize];
        debug_assert!(c.live_flag[t.slot as usize], "write to a freed arena slot");
        let base = t.slot as usize * c.lanes;
        &mut c.data[base..base + c.lanes]
    }

    /// High-water-mark reset: every slot returns to its free list and
    /// the live count drops to zero, but slabs, slot counts and
    /// `peak_live` persist — the next run reuses the established
    /// capacity and allocates nothing in steady state.
    ///
    /// Reclaiming slots that are still live is *accounted*, not
    /// asserted: an engine that errored mid-run legitimately leaves
    /// live slots for the next run's entry reset to sweep up. The
    /// [`ArenaStats::leaked`] counter records every such slot; across
    /// clean runs the property suite holds it at zero.
    pub fn reset(&mut self) {
        self.leaked += self.live;
        for c in &mut self.classes {
            c.free.clear();
            c.free.extend((0..c.slots).rev());
            c.live_flag.fill(false);
        }
        self.live = 0;
        self.resets += 1;
    }

    /// Counter snapshot across all lane classes.
    pub fn stats(&self) -> ArenaStats {
        let mut s = ArenaStats {
            classes: self.classes.len(),
            live: self.live,
            peak_live: self.peak_live,
            recycle_hits: self.recycle_hits,
            resets: self.resets,
            leaked: self.leaked,
            ..ArenaStats::default()
        };
        for c in &self.classes {
            s.slots += c.slots as u64;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_recycles_the_slot() {
        let mut a = Arena::new();
        let t1 = a.alloc_from(&[1.0, 2.0]);
        assert_eq!(a.get(t1), &[1.0, 2.0]);
        assert_eq!(t1.lanes(), 2);
        a.free(t1);
        let t2 = a.alloc(2);
        // LIFO free list: the freed slot comes straight back
        assert_eq!(a.stats().slots, 1);
        assert_eq!(a.stats().recycle_hits, 1);
        a.get_mut(t2).copy_from_slice(&[3.0, 4.0]);
        assert_eq!(a.get(t2), &[3.0, 4.0]);
    }

    #[test]
    fn lane_classes_are_segregated() {
        let mut a = Arena::new();
        let w = a.alloc_from(&[1.0, 2.0, 3.0, 4.0]);
        let n = a.alloc_from(&[9.0]);
        assert_eq!(a.stats().classes, 2);
        assert_eq!(a.get(w).len(), 4);
        assert_eq!(a.get(n).len(), 1);
        a.free(w);
        // freeing the wide slot cannot satisfy a narrow allocation
        let n2 = a.alloc(1);
        assert_eq!(a.stats().slots, 3, "narrow alloc must not recycle the wide slot");
        assert_eq!(a.get(n2).len(), 1);
    }

    #[test]
    fn peak_live_tracks_the_high_water_mark() {
        let mut a = Arena::new();
        let ts: Vec<Txn> = (0..5).map(|i| a.alloc_from(&[i as f32])).collect();
        assert_eq!(a.stats().peak_live, 5);
        for t in ts {
            a.free(t);
        }
        assert_eq!(a.stats().live, 0);
        assert_eq!(a.stats().peak_live, 5, "peak survives frees");
    }

    #[test]
    fn reset_keeps_slabs_and_peak_but_zeroes_live() {
        let mut a = Arena::new();
        let t = a.alloc_from(&[1.0, 2.0]);
        let _leaked = a.alloc_from(&[3.0, 4.0]); // deliberately not freed
        a.free(t);
        a.reset();
        let s = a.stats();
        assert_eq!(s.live, 0);
        assert_eq!(s.slots, 2);
        assert_eq!(s.peak_live, 2);
        assert_eq!(s.resets, 1);
        // post-reset allocations reuse the established slabs
        let _r1 = a.alloc(2);
        let _r2 = a.alloc(2);
        assert_eq!(a.stats().slots, 2, "reset must not grow slabs");
        assert!(a.stats().recycle_hits >= 2);
    }

    #[test]
    fn copy_sub_zero_pads_past_the_source() {
        let mut a = Arena::new();
        let wide = a.alloc_from(&[1.0, 2.0, 3.0, 4.0]);
        let lo = a.alloc_copy_sub(wide, 0, 2);
        let hi = a.alloc_copy_sub(wide, 2, 2);
        let off_end = a.alloc_copy_sub(wide, 3, 2);
        assert_eq!(a.get(lo), &[1.0, 2.0]);
        assert_eq!(a.get(hi), &[3.0, 4.0]);
        assert_eq!(a.get(off_end), &[4.0, 0.0], "out-of-range lanes zero-fill");
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "double free")]
    fn double_free_asserts_in_debug() {
        let mut a = Arena::new();
        let t = a.alloc_from(&[1.0]);
        a.free(t);
        a.free(t);
    }

    #[test]
    #[cfg(debug_assertions)]
    fn fresh_slots_are_poisoned_in_debug_builds() {
        let mut a = Arena::new();
        let t = a.alloc(4);
        assert!(
            a.get(t).iter().all(|v| v.to_bits() == POISON.to_bits()),
            "growth-path slot must be poison-filled"
        );
        a.get_mut(t).copy_from_slice(&[1.0; 4]);
        a.free(t);
        let r = a.alloc(4);
        assert!(
            a.get(r).iter().all(|v| v.to_bits() == POISON.to_bits()),
            "recycled slot must be re-poisoned, not hold the previous tenant's data"
        );
        assert!(POISON.is_nan(), "poison must propagate through arithmetic");
    }

    #[test]
    fn reset_accounts_leaked_slots() {
        let mut a = Arena::new();
        let t = a.alloc_from(&[1.0]);
        a.free(t);
        a.reset();
        assert_eq!(a.stats().leaked, 0, "clean runs leak nothing");
        let _still_live = a.alloc_from(&[2.0]);
        let _also_live = a.alloc_from(&[3.0]);
        a.reset();
        assert_eq!(a.stats().leaked, 2, "reset must count reclaimed live slots");
        a.reset();
        assert_eq!(a.stats().leaked, 2, "leak counter is cumulative, not per-reset");
    }

    #[test]
    fn stats_accumulate_sums_capacity_and_maxes_classes() {
        let mut a = Arena::new();
        let mut b = Arena::new();
        let t = a.alloc_from(&[1.0]);
        a.free(t);
        let _ = a.alloc(1);
        let _b1 = b.alloc_from(&[1.0]);
        let _b2 = b.alloc_from(&[1.0, 2.0]);
        let mut sum = a.stats();
        sum.accumulate(&b.stats());
        // capacity/activity counters sum; classes take the max (pool
        // members over the same workloads share their lane widths)
        assert_eq!(sum.classes, 2);
        assert_eq!(sum.slots, 3);
        assert_eq!(sum.live, 3);
        assert_eq!(sum.recycle_hits, 1);
    }
}
