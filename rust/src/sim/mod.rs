//! Multi-clock-domain simulator for generated designs.
//!
//! Three complementary execution modes over the same netlist
//! ([`crate::codegen::Design`]):
//!
//! * **Functional** ([`engine::run_functional`]) — executes the design
//!   on real `f32` data in dataflow order with unbounded queues: the
//!   output containers end up with exactly the values the hardware
//!   would produce. Checked against the PJRT-executed JAX/Pallas
//!   golden models by the integration tests and examples.
//! * **Exact** ([`engine::run_exact`]) — cycle-accurate simulation with
//!   bounded FIFOs, backpressure, per-domain clocking (fast domain
//!   ticks M× per slow tick), CDC transfer latency, pipeline fill and
//!   initiation intervals. Used on small instances to validate the
//!   rate model; counts stalls per module. Since the event-driven
//!   rebuild (DESIGN.md §9) blocked processes sleep until the channel
//!   push/pop that unblocks them and quiescent stretches are skipped;
//!   the legacy per-cycle stepper survives as
//!   [`engine::run_exact_reference`], the oracle the property tests
//!   compare against.
//! * **Analytic** ([`engine::rate_model`]) — steady-state rate analysis
//!   giving the cycle count of arbitrarily large workloads in O(1):
//!   the bottleneck service rate over all modules plus fill latency.
//!   Exact and analytic agree within a few percent on the designs the
//!   paper evaluates (asserted by tests).
//!
//! Hardware wall-clock time is then `cycles / effective_clock` with the
//! effective clock from the timing model — the quantity the paper's
//! Time/Perf rows report.
//!
//! All modes move transactions through the pooled [`arena::Arena`]
//! (slot handles + per-lane-class free lists, DESIGN.md §10): the
//! `*_in` engine variants share a caller-owned arena across runs so a
//! DSE evaluation loop performs zero steady-state heap allocation.
//!
//! Exact simulation additionally parallelizes across threads
//! ([`shard::run_exact_sharded`], DESIGN.md §15): the netlist is
//! partitioned into weakly-connected components that synchronize only
//! at rep barriers, cycle-exact and bit-identical to the serial engine
//! by construction and by property test.

pub mod arena;
pub mod channel;
pub mod compute;
pub mod engine;
pub mod memory;
pub mod process;
pub mod shard;
pub mod stats;
pub mod trace;

pub use arena::{Arena, ArenaStats, Txn};
pub use engine::{
    exact_engines_agree, exact_engines_agree_in, is_timeout_error, rate_model, run_exact,
    run_exact_deadline_in, run_exact_in, run_exact_observed_in, run_exact_reference,
    run_exact_reference_in, run_functional, run_functional_in, SimOutcome,
};
pub use memory::Hbm;
pub use shard::{
    replicate_design, replicate_inputs, resolve_threads, run_exact_sharded,
    run_exact_sharded_in, shard_partition,
};
pub use stats::SimStats;
pub use trace::{run_traced, Trace};
