//! PJRT runtime: loads and executes the AOT JAX/Pallas golden models.
//!
//! Python never runs on this path — `make artifacts` lowered the L2
//! models to HLO text once; here the `xla` crate compiles them on the
//! PJRT CPU client and executes them with concrete inputs. The
//! simulator's functional outputs are cross-checked against these
//! golden results by the integration tests and the end-to-end
//! examples.

pub mod artifact;
pub mod pjrt;

pub use artifact::{Manifest, ManifestEntry};
pub use pjrt::GoldenRunner;
