//! Artifact manifest: what `python/compile/aot.py` produced.

use std::path::{Path, PathBuf};

/// One exported model.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub file: PathBuf,
    /// Input shapes, e.g. [[4096], [4096]].
    pub shapes: Vec<Vec<usize>>,
}

impl ManifestEntry {
    /// Total element count per input.
    pub fn input_sizes(&self) -> Vec<usize> {
        self.shapes.iter().map(|s| s.iter().product()).collect()
    }
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub entries: Vec<ManifestEntry>,
    pub dir: PathBuf,
}

impl Manifest {
    /// Parse `artifacts/manifest.txt` (format: `name file sh1;sh2` with
    /// shapes as `d0xd1x...`).
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e} (run `make artifacts`)", path.display()))?;
        let mut entries = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 3 {
                return Err(format!("manifest line {}: expected 3 fields", lineno + 1));
            }
            let shapes = parts[2]
                .split(';')
                .map(|s| {
                    s.split('x')
                        .map(|d| d.parse::<usize>().map_err(|e| format!("bad dim {d}: {e}")))
                        .collect::<Result<Vec<_>, _>>()
                })
                .collect::<Result<Vec<_>, _>>()?;
            entries.push(ManifestEntry {
                name: parts[0].to_string(),
                file: dir.join(parts[1]),
                shapes,
            });
        }
        Ok(Manifest { entries, dir: dir.to_path_buf() })
    }

    pub fn get(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

/// Default artifacts directory: `$TVEC_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("TVEC_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_format() {
        let dir = std::env::temp_dir().join("tvec_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "vecadd vecadd.hlo.txt 4096;4096\nmatmul matmul.hlo.txt 128x64;64x32\n",
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 2);
        let mm = m.get("matmul").unwrap();
        assert_eq!(mm.shapes, vec![vec![128, 64], vec![64, 32]]);
        assert_eq!(mm.input_sizes(), vec![128 * 64, 64 * 32]);
        assert!(m.get("nope").is_none());
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.contains("make artifacts"), "{err}");
    }
}
