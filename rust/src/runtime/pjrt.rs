//! PJRT CPU execution of HLO-text artifacts.
//!
//! Follows the /opt/xla-example/load_hlo pattern: text → HloModuleProto
//! → XlaComputation → compile → execute. Executables are cached per
//! model name (compile once, run many — the "AOT, python never on the
//! request path" contract).
//!
//! The real backend needs the offline `xla` crate, which the build
//! image does not ship; it is gated behind the `xla-runtime` feature
//! (see Cargo.toml). The default build compiles a stub whose
//! constructor fails with a clear message, so the compiler, simulator
//! and DSE layers — none of which need PJRT — stay fully usable and
//! the golden-model integration tests skip gracefully.

#[cfg(feature = "xla-runtime")]
mod backend {
    use std::collections::BTreeMap;
    use std::path::Path;

    use super::super::artifact::{Manifest, ManifestEntry};

    /// Loads artifacts and runs golden computations on the PJRT CPU client.
    pub struct GoldenRunner {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: BTreeMap<String, xla::PjRtLoadedExecutable>,
    }

    impl GoldenRunner {
        /// Create a runner over an artifacts directory.
        pub fn new(dir: &Path) -> Result<GoldenRunner, String> {
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu().map_err(|e| format!("PJRT cpu client: {e}"))?;
            Ok(GoldenRunner { client, manifest, cache: BTreeMap::new() })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        fn compile(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable, String> {
            if !self.cache.contains_key(name) {
                let entry = self
                    .manifest
                    .get(name)
                    .ok_or_else(|| format!("no artifact '{name}' in manifest"))?
                    .clone();
                let proto = xla::HloModuleProto::from_text_file(
                    entry.file.to_str().ok_or("non-utf8 path")?,
                )
                .map_err(|e| format!("parse {}: {e}", entry.file.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .map_err(|e| format!("compile '{name}': {e}"))?;
                self.cache.insert(name.to_string(), exe);
            }
            Ok(&self.cache[name])
        }

        /// Execute model `name` on f32 inputs (shapes from the manifest).
        /// Returns the flattened f32 output of the (single-output) model.
        pub fn run(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>, String> {
            let entry: ManifestEntry = self
                .manifest
                .get(name)
                .ok_or_else(|| format!("no artifact '{name}'"))?
                .clone();
            if inputs.len() != entry.shapes.len() {
                return Err(format!(
                    "'{name}' expects {} inputs, got {}",
                    entry.shapes.len(),
                    inputs.len()
                ));
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, shape) in inputs.iter().zip(&entry.shapes) {
                let expect: usize = shape.iter().product();
                if data.len() != expect {
                    return Err(format!(
                        "'{name}': input length {} != shape {:?}",
                        data.len(),
                        shape
                    ));
                }
                let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| format!("reshape: {e}"))?;
                literals.push(lit);
            }
            let exe = self.compile(name)?;
            let result = exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| format!("execute '{name}': {e}"))?;
            let out = result[0][0]
                .to_literal_sync()
                .map_err(|e| format!("fetch result: {e}"))?;
            // models are lowered with return_tuple=True → 1-tuple
            let tuple = out.to_tuple1().map_err(|e| format!("untuple: {e}"))?;
            tuple.to_vec::<f32>().map_err(|e| format!("to_vec: {e}"))
        }
    }
}

#[cfg(not(feature = "xla-runtime"))]
mod backend {
    use std::path::Path;

    use super::super::artifact::Manifest;

    /// Stub golden runner: the `xla` crate is absent from this build.
    /// Construction always fails with an actionable message, so callers
    /// (the `tvec run` subcommand, the golden integration tests, the
    /// quickstart example) degrade gracefully instead of failing to
    /// link.
    pub struct GoldenRunner {
        #[allow(dead_code)] // never constructed: new() always errors
        manifest: Manifest,
    }

    impl GoldenRunner {
        pub fn new(dir: &Path) -> Result<GoldenRunner, String> {
            // still surface a missing-artifacts problem first — it is
            // the more fundamental one
            let _ = Manifest::load(dir)?;
            Err("PJRT golden runtime unavailable in this build: the offline `xla` crate \
                 is not present. Vendor it and build with `--features xla-runtime`."
                .to_string())
        }

        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn run(&mut self, name: &str, _inputs: &[&[f32]]) -> Result<Vec<f32>, String> {
            Err(format!(
                "cannot execute golden model '{name}': PJRT runtime unavailable \
                 (build with `--features xla-runtime`)"
            ))
        }
    }
}

pub use backend::GoldenRunner;

// NOTE: integration coverage for this module lives in
// rust/tests/runtime_golden.rs (requires `make artifacts` and the
// `xla-runtime` feature); those tests skip when either is missing.
