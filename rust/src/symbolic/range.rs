//! Strided symbolic ranges `begin : end : step` (end exclusive).
//!
//! Map scopes iterate over ranges; memlet subsets are per-dimension
//! ranges. Vectorization rewrites ranges (`0:N:1` → `0:N/V:1` with the
//! element index scaled), so ranges carry symbolic begin/end and a
//! constant step.

use super::expr::{Expr, SymbolTable};

/// `begin : end : step`, end exclusive, step a positive constant.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct Range {
    pub begin: Expr,
    pub end: Expr,
    pub step: i64,
}

impl Range {
    pub fn new(begin: Expr, end: Expr, step: i64) -> Self {
        assert!(step > 0, "only positive steps are supported");
        Range { begin, end, step }
    }

    /// `0 : n : 1` for a constant extent.
    pub fn upto(n: i64) -> Self {
        Range::new(Expr::int(0), Expr::int(n), 1)
    }

    /// `0 : sym : 1`.
    pub fn upto_sym(s: &str) -> Self {
        Range::new(Expr::int(0), Expr::sym(s), 1)
    }

    /// A degenerate single-index range `e : e+1 : 1`.
    pub fn index(e: Expr) -> Self {
        let end = e.add(&Expr::int(1));
        Range::new(e, end, 1)
    }

    /// Is this a single index (`end == begin + 1`)?
    pub fn is_index(&self) -> bool {
        self.end.sub(&self.begin).as_const() == Some(1)
    }

    /// Symbolic element count `(end - begin) / step` if exact.
    pub fn extent(&self) -> Option<Expr> {
        self.end.sub(&self.begin).div_exact(self.step)
    }

    /// Concrete element count under bindings.
    pub fn count(&self, env: &SymbolTable) -> Option<i64> {
        let b = self.begin.eval(env)?;
        let e = self.end.eval(env)?;
        if e <= b {
            return Some(0);
        }
        Some((e - b + self.step - 1) / self.step)
    }

    /// Substitute a symbol throughout.
    pub fn subst(&self, s: &str, e: &Expr) -> Range {
        Range { begin: self.begin.subst(s, e), end: self.end.subst(s, e), step: self.step }
    }

    /// Divide the extent by `v` (vectorization): `0:N:1` → `0:N/v:1`.
    /// Only applies when begin is unchanged and the extent divides.
    pub fn divide_extent(&self, v: i64) -> Option<Range> {
        let extent = self.extent()?;
        let new_extent = extent.div_exact(v)?;
        let end = self.begin.add(&new_extent.scale(self.step));
        Some(Range { begin: self.begin.clone(), end, step: self.step })
    }

    /// Do two concrete ranges overlap under `env`?
    pub fn overlaps(&self, other: &Range, env: &SymbolTable) -> Option<bool> {
        let (b1, e1) = (self.begin.eval(env)?, self.end.eval(env)?);
        let (b2, e2) = (other.begin.eval(env)?, other.end.eval(env)?);
        if e1 <= b2 || e2 <= b1 {
            return Some(false);
        }
        if self.step == 1 || other.step == 1 {
            return Some(true);
        }
        // strided: walk the shorter one (ranges here are small in tests;
        // analyses use the symbolic paths in practice)
        let (wb, we, ws, ob, oe, os) = if (e1 - b1) / self.step <= (e2 - b2) / other.step {
            (b1, e1, self.step, b2, e2, other.step)
        } else {
            (b2, e2, other.step, b1, e1, self.step)
        };
        let mut x = wb;
        while x < we {
            if x >= ob && x < oe && (x - ob) % os == 0 {
                return Some(true);
            }
            x += ws;
        }
        Some(false)
    }

    /// Iterate concrete values under `env` (for the simulator/tests).
    pub fn iter_concrete(&self, env: &SymbolTable) -> Option<Vec<i64>> {
        let b = self.begin.eval(env)?;
        let e = self.end.eval(env)?;
        let mut out = Vec::new();
        let mut x = b;
        while x < e {
            out.push(x);
            x += self.step;
        }
        Some(out)
    }
}

impl std::fmt::Display for Range {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_index() {
            write!(f, "{}", self.begin)
        } else if self.step == 1 {
            write!(f, "{}:{}", self.begin, self.end)
        } else {
            write!(f, "{}:{}:{}", self.begin, self.end, self.step)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn extent_and_count() {
        let r = Range::upto_sym("N");
        assert_eq!(r.extent().unwrap(), Expr::sym("N"));
        let env = SymbolTable::new().with("N", 10);
        assert_eq!(r.count(&env), Some(10));
    }

    #[test]
    fn strided_count() {
        let r = Range::new(Expr::int(0), Expr::int(10), 3); // 0,3,6,9
        assert_eq!(r.count(&SymbolTable::new()), Some(4));
        assert_eq!(r.iter_concrete(&SymbolTable::new()).unwrap(), vec![0, 3, 6, 9]);
    }

    #[test]
    fn index_range() {
        let r = Range::index(Expr::sym("i"));
        assert!(r.is_index());
        assert_eq!(format!("{r}"), "i");
    }

    #[test]
    fn divide_extent_for_vectorization() {
        // concrete extent divides
        let rc = Range::upto(16);
        let dc = rc.divide_extent(4).unwrap();
        assert_eq!(dc.count(&SymbolTable::new()), Some(4));
        // symbolic extent N (coefficient 1) does not divide by 4
        assert!(Range::upto_sym("N").divide_extent(4).is_none());
    }

    #[test]
    fn symbolic_divide_requires_divisible_coeffs() {
        // 0 : 4*T : 1 divides by 4 → 0 : T : 1
        let r = Range::new(Expr::int(0), Expr::sym("T").scale(4), 1);
        let d = r.divide_extent(4).unwrap();
        assert_eq!(d.end, Expr::sym("T"));
        // 0 : N : 1 does not divide by 4 symbolically
        assert!(Range::upto_sym("N").divide_extent(4).is_none());
    }

    #[test]
    fn overlap_detection() {
        let env = SymbolTable::new();
        let a = Range::upto(10);
        let b = Range::new(Expr::int(10), Expr::int(20), 1);
        assert_eq!(a.overlaps(&b, &env), Some(false));
        let c = Range::new(Expr::int(5), Expr::int(15), 1);
        assert_eq!(a.overlaps(&c, &env), Some(true));
        // disjoint strided: evens vs odds
        let evens = Range::new(Expr::int(0), Expr::int(20), 2);
        let odds = Range::new(Expr::int(1), Expr::int(20), 2);
        assert_eq!(evens.overlaps(&odds, &env), Some(false));
    }

    #[test]
    fn overlap_unknown_with_unbound_symbols() {
        let env = SymbolTable::new();
        let a = Range::upto_sym("N");
        let b = Range::upto(4);
        assert_eq!(a.overlaps(&b, &env), None);
    }
}
