//! Symbolic integer algebra for memlets.
//!
//! DaCe memlets describe data movement with symbolic index expressions
//! (`i`, `2*i+1`, `i*V .. i*V+V`). The streamability and vectorizability
//! analyses in [`crate::analysis`] reason about these expressions:
//! equality of access order, disjointness of write sets, divisibility of
//! ranges by a vectorization factor. This module provides exactly the
//! machinery needed: affine expressions over named symbols
//! ([`expr::Expr`]), strided ranges ([`range::Range`]) and
//! multi-dimensional subsets ([`subset::Subset`]) with intersection and
//! containment tests, plus concrete evaluation under symbol bindings.

pub mod expr;
pub mod range;
pub mod subset;

pub use expr::{Expr, SymbolTable};
pub use range::Range;
pub use subset::Subset;
