//! Affine integer expressions over named symbols.
//!
//! Expressions are kept in a canonical linear form
//! `c0 + c1*s1 + c2*s2 + ...` (constant plus integer-scaled symbols),
//! which makes equality, substitution, and divisibility checks exact —
//! the operations the transformation feasibility checks rely on.
//! Non-affine constructs (e.g. data-dependent indices) are represented
//! by [`Expr::Opaque`] and conservatively fail all structural checks,
//! which is precisely the paper's restriction: "the participating
//! operations must not involve data-dependent external memory I/O".

use std::collections::BTreeMap;
use std::fmt;

/// Interned symbol name (cheap clone; names are short and few).
pub type Sym = String;

/// An integer expression in canonical affine form, or an opaque
/// (unanalyzable) term.
#[derive(Clone, PartialEq, Eq, Hash)]
pub enum Expr {
    /// `constant + Σ coeff·symbol`, with zero coefficients removed and
    /// symbols ordered (BTreeMap) so equal expressions compare equal.
    Affine { constant: i64, terms: BTreeMap<Sym, i64> },
    /// A term the analysis cannot reason about (data-dependent index,
    /// modulo, division with remainder...). Carries a display string.
    Opaque(String),
}

impl Expr {
    pub fn int(c: i64) -> Expr {
        Expr::Affine { constant: c, terms: BTreeMap::new() }
    }

    pub fn sym(name: &str) -> Expr {
        let mut terms = BTreeMap::new();
        terms.insert(name.to_string(), 1);
        Expr::Affine { constant: 0, terms }
    }

    pub fn opaque(desc: impl Into<String>) -> Expr {
        Expr::Opaque(desc.into())
    }

    pub fn zero() -> Expr {
        Expr::int(0)
    }

    pub fn is_opaque(&self) -> bool {
        matches!(self, Expr::Opaque(_))
    }

    /// The constant value if the expression has no symbolic part.
    pub fn as_const(&self) -> Option<i64> {
        match self {
            Expr::Affine { constant, terms } if terms.is_empty() => Some(*constant),
            _ => None,
        }
    }

    /// Coefficient of `s` (0 if absent); None for opaque.
    pub fn coeff(&self, s: &str) -> Option<i64> {
        match self {
            Expr::Affine { terms, .. } => Some(terms.get(s).copied().unwrap_or(0)),
            Expr::Opaque(_) => None,
        }
    }

    /// Free symbols of the expression.
    pub fn symbols(&self) -> Vec<Sym> {
        match self {
            Expr::Affine { terms, .. } => terms.keys().cloned().collect(),
            Expr::Opaque(_) => Vec::new(),
        }
    }

    /// Whether the expression mentions `s`.
    pub fn uses(&self, s: &str) -> bool {
        match self {
            Expr::Affine { terms, .. } => terms.contains_key(s),
            // conservative: opaque may depend on anything
            Expr::Opaque(_) => true,
        }
    }

    pub fn add(&self, other: &Expr) -> Expr {
        match (self, other) {
            (
                Expr::Affine { constant: c1, terms: t1 },
                Expr::Affine { constant: c2, terms: t2 },
            ) => {
                let mut terms = t1.clone();
                for (s, c) in t2 {
                    let e = terms.entry(s.clone()).or_insert(0);
                    *e += c;
                    if *e == 0 {
                        terms.remove(s);
                    }
                }
                Expr::Affine { constant: c1 + c2, terms }
            }
            _ => Expr::Opaque(format!("({self} + {other})")),
        }
    }

    pub fn sub(&self, other: &Expr) -> Expr {
        self.add(&other.scale(-1))
    }

    pub fn scale(&self, k: i64) -> Expr {
        match self {
            Expr::Affine { constant, terms } => {
                if k == 0 {
                    return Expr::zero();
                }
                Expr::Affine {
                    constant: constant * k,
                    terms: terms.iter().map(|(s, c)| (s.clone(), c * k)).collect(),
                }
            }
            Expr::Opaque(d) => Expr::Opaque(format!("({k} * {d})")),
        }
    }

    /// Multiply two expressions; affine only if one side is constant.
    pub fn mul(&self, other: &Expr) -> Expr {
        match (self.as_const(), other.as_const()) {
            (Some(k), _) => other.scale(k),
            (_, Some(k)) => self.scale(k),
            _ => Expr::Opaque(format!("({self} * {other})")),
        }
    }

    /// Exact division by a constant: all coefficients and the constant
    /// must be divisible. This is the vectorization-divisibility check.
    pub fn div_exact(&self, k: i64) -> Option<Expr> {
        assert!(k != 0);
        match self {
            Expr::Affine { constant, terms } => {
                if constant % k != 0 || terms.values().any(|c| c % k != 0) {
                    return None;
                }
                Some(Expr::Affine {
                    constant: constant / k,
                    terms: terms.iter().map(|(s, c)| (s.clone(), c / k)).collect(),
                })
            }
            Expr::Opaque(_) => None,
        }
    }

    /// Substitute symbol `s` with expression `e`.
    pub fn subst(&self, s: &str, e: &Expr) -> Expr {
        match self {
            Expr::Affine { constant, terms } => {
                let mut out = Expr::int(*constant);
                for (name, c) in terms {
                    let term = if name == s { e.scale(*c) } else { Expr::sym(name).scale(*c) };
                    out = out.add(&term);
                }
                out
            }
            Expr::Opaque(d) => Expr::Opaque(format!("{d}[{s}:={e}]")),
        }
    }

    /// Evaluate under a symbol binding; None if a symbol is unbound or
    /// the expression is opaque.
    pub fn eval(&self, env: &SymbolTable) -> Option<i64> {
        match self {
            Expr::Affine { constant, terms } => {
                let mut acc = *constant;
                for (s, c) in terms {
                    acc += c * env.get(s)?;
                }
                Some(acc)
            }
            Expr::Opaque(_) => None,
        }
    }

    /// Structural equality of the difference to zero: `self == other`
    /// exactly (None for opaque operands — unknown).
    pub fn eq_exact(&self, other: &Expr) -> Option<bool> {
        if self.is_opaque() || other.is_opaque() {
            return None;
        }
        Some(self.sub(other).as_const() == Some(0))
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::Affine { constant, terms } => {
                let mut parts: Vec<String> = Vec::new();
                for (s, c) in terms {
                    parts.push(match *c {
                        1 => s.clone(),
                        -1 => format!("-{s}"),
                        c => format!("{c}*{s}"),
                    });
                }
                if *constant != 0 || parts.is_empty() {
                    parts.push(constant.to_string());
                }
                let mut out = String::new();
                for (i, p) in parts.iter().enumerate() {
                    if i > 0 && !p.starts_with('-') {
                        out.push_str(" + ");
                    } else if i > 0 {
                        out.push_str(" ");
                    }
                    out.push_str(p);
                }
                write!(f, "{out}")
            }
            Expr::Opaque(d) => write!(f, "⟨{d}⟩"),
        }
    }
}

impl fmt::Debug for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

/// Concrete bindings for symbols (map-scope parameters, program sizes).
#[derive(Clone, Debug, Default)]
pub struct SymbolTable {
    bindings: BTreeMap<Sym, i64>,
}

impl SymbolTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with(mut self, s: &str, v: i64) -> Self {
        self.set(s, v);
        self
    }

    pub fn set(&mut self, s: &str, v: i64) {
        self.bindings.insert(s.to_string(), v);
    }

    pub fn get(&self, s: &str) -> Option<i64> {
        self.bindings.get(s).copied()
    }

    pub fn symbols(&self) -> impl Iterator<Item = (&Sym, &i64)> {
        self.bindings.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_form_equality() {
        // i + 2 + i == 2*i + 2
        let a = Expr::sym("i").add(&Expr::int(2)).add(&Expr::sym("i"));
        let b = Expr::sym("i").scale(2).add(&Expr::int(2));
        assert_eq!(a, b);
        assert_eq!(a.eq_exact(&b), Some(true));
    }

    #[test]
    fn zero_coefficients_removed() {
        let a = Expr::sym("i").sub(&Expr::sym("i"));
        assert_eq!(a.as_const(), Some(0));
        assert!(a.symbols().is_empty());
    }

    #[test]
    fn mul_constant_folds() {
        let e = Expr::sym("i").add(&Expr::int(1)).mul(&Expr::int(4));
        assert_eq!(e.coeff("i"), Some(4));
        assert_eq!(e, Expr::sym("i").scale(4).add(&Expr::int(4)));
    }

    #[test]
    fn mul_symbols_is_opaque() {
        let e = Expr::sym("i").mul(&Expr::sym("j"));
        assert!(e.is_opaque());
        assert_eq!(e.eq_exact(&e.clone()), None);
    }

    #[test]
    fn div_exact_checks_divisibility() {
        let e = Expr::sym("i").scale(8).add(&Expr::int(4));
        assert_eq!(e.div_exact(4).unwrap(), Expr::sym("i").scale(2).add(&Expr::int(1)));
        assert!(e.div_exact(3).is_none());
    }

    #[test]
    fn subst_replaces() {
        // (2*i + 1)[i := 4*j] = 8*j + 1
        let e = Expr::sym("i").scale(2).add(&Expr::int(1));
        let r = e.subst("i", &Expr::sym("j").scale(4));
        assert_eq!(r, Expr::sym("j").scale(8).add(&Expr::int(1)));
    }

    #[test]
    fn eval_with_bindings() {
        let e = Expr::sym("i").scale(3).add(&Expr::sym("j")).add(&Expr::int(-2));
        let env = SymbolTable::new().with("i", 5).with("j", 7);
        assert_eq!(e.eval(&env), Some(20));
        let partial = SymbolTable::new().with("i", 5);
        assert_eq!(e.eval(&partial), None);
    }

    #[test]
    fn opaque_is_contagious() {
        let o = Expr::opaque("A[i]");
        assert!(o.add(&Expr::int(1)).is_opaque());
        assert!(Expr::sym("i").mul(&o).is_opaque());
        assert!(o.uses("anything"));
    }

    #[test]
    fn display_roundtrip_readable() {
        let e = Expr::sym("i").scale(2).add(&Expr::sym("j").scale(-1)).add(&Expr::int(3));
        let s = format!("{e}");
        assert!(s.contains("2*i") && s.contains("-j") && s.contains('3'), "{s}");
    }
}
