//! Multi-dimensional subsets: the index sets memlets move.
//!
//! A subset is one [`Range`] per dimension (e.g. `A[i, 0:K]`). The
//! streamability analysis compares subsets *as functions of the map
//! parameter* to decide whether two modules touch memory in the same
//! order (streamable) or overlap incompatibly (not streamable).

use super::expr::{Expr, SymbolTable};
use super::range::Range;

/// One range per dimension.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
pub struct Subset {
    pub dims: Vec<Range>,
}

impl Subset {
    pub fn new(dims: Vec<Range>) -> Self {
        Subset { dims }
    }

    /// Single-index subset `[e0, e1, ...]`.
    pub fn indices(es: Vec<Expr>) -> Self {
        Subset { dims: es.into_iter().map(Range::index).collect() }
    }

    /// 1-D single index.
    pub fn index1(e: Expr) -> Self {
        Subset::indices(vec![e])
    }

    /// 1-D covering `[0, n)`.
    pub fn all1(n: i64) -> Self {
        Subset::new(vec![Range::upto(n)])
    }

    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Total element count under bindings.
    pub fn volume(&self, env: &SymbolTable) -> Option<i64> {
        let mut v = 1i64;
        for d in &self.dims {
            v = v.checked_mul(d.count(env)?)?;
        }
        Some(v)
    }

    /// Symbolic element count (product of extents) if all are affine and
    /// the product stays affine (i.e. at most one symbolic extent).
    pub fn volume_sym(&self) -> Expr {
        let mut acc = Expr::int(1);
        for d in &self.dims {
            match d.extent() {
                Some(e) => acc = acc.mul(&e),
                None => return Expr::opaque(format!("volume({self})")),
            }
        }
        acc
    }

    /// Substitute a symbol in every dimension.
    pub fn subst(&self, s: &str, e: &Expr) -> Subset {
        Subset { dims: self.dims.iter().map(|d| d.subst(s, e)).collect() }
    }

    /// Do the subsets coincide exactly (same begin/end/step per dim)?
    /// None if any component is opaque.
    pub fn same_as(&self, other: &Subset) -> Option<bool> {
        if self.rank() != other.rank() {
            return Some(false);
        }
        let mut all = true;
        for (a, b) in self.dims.iter().zip(&other.dims) {
            if a.step != b.step {
                return Some(false);
            }
            match (a.begin.eq_exact(&b.begin), a.end.eq_exact(&b.end)) {
                (Some(x), Some(y)) => all &= x && y,
                _ => return None,
            }
        }
        Some(all)
    }

    /// Conservative concrete intersection test: Some(false) only when
    /// provably disjoint in at least one dimension.
    pub fn intersects(&self, other: &Subset, env: &SymbolTable) -> Option<bool> {
        if self.rank() != other.rank() {
            return None;
        }
        let mut unknown = false;
        for (a, b) in self.dims.iter().zip(&other.dims) {
            match a.overlaps(b, env) {
                Some(false) => return Some(false),
                Some(true) => {}
                None => unknown = true,
            }
        }
        if unknown {
            None
        } else {
            Some(true)
        }
    }

    /// Does the access order induced by iterating `param` over its range
    /// advance linearly with unit progression in the innermost dimension?
    /// This is the contiguity condition the streaming transformation
    /// needs: module reads element `f(p)` at step `p`, with
    /// `f(p+1) - f(p) == 1` in flattened order. We check the common case
    /// where the innermost dim is `param`-affine with coefficient `c>0`
    /// and outer dims do not depend on `param`.
    pub fn linear_in(&self, param: &str) -> Option<i64> {
        if self.dims.is_empty() {
            return None;
        }
        let inner = self.dims.last().unwrap();
        if !inner.is_index() {
            return None;
        }
        let c = inner.begin.coeff(param)?;
        if c <= 0 {
            return None;
        }
        for outer in &self.dims[..self.dims.len() - 1] {
            if outer.begin.uses(param) || outer.end.uses(param) {
                return None;
            }
        }
        Some(c)
    }
}

impl std::fmt::Display for Subset {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let parts: Vec<String> = self.dims.iter().map(|d| d.to_string()).collect();
        write!(f, "[{}]", parts.join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn volume_concrete_and_symbolic() {
        let s = Subset::new(vec![Range::upto(4), Range::upto_sym("K")]);
        let env = SymbolTable::new().with("K", 8);
        assert_eq!(s.volume(&env), Some(32));
        let vs = s.volume_sym();
        assert_eq!(vs.eval(&env), Some(32));
    }

    #[test]
    fn same_as_exact() {
        let a = Subset::index1(Expr::sym("i"));
        let b = Subset::index1(Expr::sym("i"));
        let c = Subset::index1(Expr::sym("i").add(&Expr::int(1)));
        assert_eq!(a.same_as(&b), Some(true));
        assert_eq!(a.same_as(&c), Some(false));
    }

    #[test]
    fn same_as_opaque_is_unknown() {
        let a = Subset::index1(Expr::opaque("A[i]"));
        let b = Subset::index1(Expr::sym("i"));
        assert_eq!(a.same_as(&b), None);
    }

    #[test]
    fn intersects_disjoint_dim_wins() {
        let env = SymbolTable::new();
        let a = Subset::new(vec![Range::upto(4), Range::upto(10)]);
        let b = Subset::new(vec![Range::new(Expr::int(4), Expr::int(8), 1), Range::upto(10)]);
        assert_eq!(a.intersects(&b, &env), Some(false));
    }

    #[test]
    fn linear_in_detects_streaming_order() {
        // A[i] iterated by i → linear with stride 1
        assert_eq!(Subset::index1(Expr::sym("i")).linear_in("i"), Some(1));
        // A[2*i] → stride 2 (vectorized access)
        assert_eq!(Subset::index1(Expr::sym("i").scale(2)).linear_in("i"), Some(2));
        // A[j, i] with outer j independent of i → linear in i
        let s = Subset::indices(vec![Expr::sym("j"), Expr::sym("i")]);
        assert_eq!(s.linear_in("i"), Some(1));
        // ...but iterating j strides by whole rows → not innermost-linear
        assert_eq!(s.linear_in("j"), None);
        let t = Subset::indices(vec![Expr::sym("i"), Expr::sym("j")]);
        assert_eq!(t.linear_in("i"), None);
        // reversed access → not linear
        assert_eq!(Subset::index1(Expr::sym("i").scale(-1)).linear_in("i"), None);
    }

    #[test]
    fn subst_applies_everywhere() {
        let s = Subset::indices(vec![Expr::sym("i"), Expr::sym("i").add(&Expr::int(1))]);
        let r = s.subst("i", &Expr::sym("v").scale(4));
        assert_eq!(r.dims[0].begin, Expr::sym("v").scale(4));
        assert_eq!(r.dims[1].begin, Expr::sym("v").scale(4).add(&Expr::int(1)));
    }

    #[test]
    fn display_readable() {
        let s = Subset::new(vec![Range::index(Expr::sym("i")), Range::upto(8)]);
        assert_eq!(format!("{s}"), "[i, 0:8]");
    }
}
