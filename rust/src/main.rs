//! `tvec` — the temporal-vectorization coordinator CLI.
//!
//! Subcommands:
//! * `experiment <id>` — regenerate a paper table/figure
//!   (`table1`..`table6`, `fig4`, or `all`);
//! * `compile <file.tv>` — compile a DSL program through the full
//!   pipeline (vectorize → stream → multi-pump) and print the design
//!   report + generated HLS/RTL artifacts;
//! * `run <app>` — functionally simulate an app design on real data
//!   and cross-check against the AOT golden model via PJRT;
//! * `report` — print the device model (Table 1).

use temporal_vec::coordinator::{compile, BuildSpec};
use temporal_vec::ir::PumpMode;
use temporal_vec::runtime::{artifact, GoldenRunner};
use temporal_vec::sim::{run_functional, Hbm};
use temporal_vec::util::cli::Cli;
use temporal_vec::util::Rng;
use temporal_vec::{apps, codegen};

fn main() {
    let cli = Cli::new("tvec", "temporal vectorization / automatic multi-pumping")
        .subcommand("experiment", "regenerate a paper table or figure")
        .subcommand("compile", "compile a DSL program and print reports")
        .subcommand("run", "simulate an app and check against the golden model")
        .subcommand("dse", "autotune an app over the design space")
        .subcommand("bench", "measure simulator/DSE throughput (BENCH_sim.json)")
        .subcommand("top", "print the top-k stall sources of an app (observed exact sim)")
        .subcommand("check", "static design-rule check (CDC + deadlock freedom) of an app")
        .subcommand("report", "print the device model (Table 1)")
        .opt_default("seed", "P&R jitter seed", "1")
        .opt(
            "trace-out",
            "dse/run/top: write a Chrome trace-event JSON here (+ TELEMETRY.json alongside)",
        )
        .opt_default("topk", "top: stall sources to print", "8")
        .opt(
            "clamp-depth",
            "check: clamp every data channel's FIFO depth (deliberate undersizing fixture)",
        )
        .opt("config", "experiment config file (see configs/)")
        .opt("pump", "pumping factor for compile/run (e.g. 2)")
        .opt_default("mode", "pump mode: resource|throughput|barefast", "resource")
        .opt("n", "problem size override")
        .opt(
            "app",
            "dse: application (vecadd|matmul|jacobi|diffusion|stencil|fw|all)",
        )
        .opt_default("objective", "dse: resource|throughput", "resource")
        .opt_default("strategy", "dse: exhaustive|greedy|anneal|halving", "exhaustive")
        .opt("budget", "dse: max new compiles (early cutoff; cache hits are free)")
        .opt("cache-dir", "dse: directory for the persistent evaluation cache")
        .opt(
            "tolerance",
            "dse --verify / bench: rate-vs-exact tolerance (default: per app)",
        )
        .flag("verify", "dse: exact-sim-check every frontier point at golden scale")
        .flag(
            "mixed-factors",
            "dse: search mixed per-region pump assignments (any enabled mode)",
        )
        .opt(
            "pump-modes",
            "dse: comma list of pump modes to search (resource|throughput|barefast)",
        )
        .flag(
            "cache-compact",
            "dse: evicting flush — keep ONLY the entries this run used",
        )
        .opt(
            "deadline-ms",
            "dse: per-candidate wall-clock budget in ms (over-budget ⇒ quarantined)",
        )
        .opt(
            "sim-cycle-budget",
            "dse: per-candidate exact-sim slow-cycle ceiling for --verify",
        )
        .opt(
            "inject-faults",
            "dse: deterministic fault spec, e.g. panic@2,slow@4 (see DESIGN.md §14)",
        )
        .opt(
            "serve",
            "dse: serve NDJSON search requests on this Unix socket instead of sweeping",
        )
        .opt(
            "threads",
            "dse/bench: worker threads (1 = serial engines; default: available parallelism)",
        )
        .flag("json", "bench: write the BENCH_sim.json artifact")
        .flag("smoke", "bench: CI-scale problem sizes and iteration counts")
        .flag("emit", "write generated HLS/RTL text files to ./generated")
        .flag("verbose", "print pass logs");
    let args = cli.parse_env();
    // a typo'd --seed used to silently fall back to 1; reject it loudly
    let seed = match args.get("seed").map(str::parse::<u64>) {
        None => 1,
        Some(Ok(s)) => s,
        Some(Err(_)) => {
            eprintln!(
                "error: invalid --seed '{}' (want an unsigned integer)",
                args.get("seed").unwrap()
            );
            std::process::exit(2);
        }
    };

    let result = match args.subcommand.as_deref() {
        Some("experiment") => cmd_experiment(&args, seed),
        Some("compile") => cmd_compile(&args, seed),
        Some("run") => cmd_run(&args, seed),
        Some("dse") => cmd_dse(&args, seed),
        Some("bench") => cmd_bench(&args, seed),
        Some("top") => cmd_top(&args, seed),
        Some("check") => cmd_check(&args, seed),
        Some("report") => {
            println!("{}", temporal_vec::coordinator::experiment::table1().rendered);
            Ok(())
        }
        _ => {
            eprintln!("{}", cli.help_text());
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn cmd_experiment(args: &temporal_vec::util::cli::Parsed, seed: u64) -> Result<(), String> {
    let id = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or("usage: tvec experiment <table1..table6|fig4|all>")?;
    let ids: Vec<&str> = if id == "all" {
        vec!["table1", "table2", "table3", "table4", "table5", "table6", "fig4"]
    } else {
        vec![id]
    };
    let cfg = match args.get("config") {
        Some(path) => Some(temporal_vec::coordinator::Config::load(std::path::Path::new(path))?),
        None => None,
    };
    for id in ids {
        let r = temporal_vec::coordinator::experiment::run_experiment_with(id, seed, cfg.as_ref())?;
        println!("{}", r.rendered);
    }
    Ok(())
}

fn cmd_compile(args: &temporal_vec::util::cli::Parsed, seed: u64) -> Result<(), String> {
    let path = args
        .positional
        .first()
        .ok_or("usage: tvec compile <file.tv> [--pump 2] [--emit]")?;
    let source = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    let sdfg = temporal_vec::frontend::compile(&source)?;
    println!("parsed program '{}':", sdfg.name);
    println!("{}", temporal_vec::ir::printer::to_text(&sdfg));

    let mut spec = BuildSpec::new(sdfg).seeded(seed);
    if let Some(factor) = args.get_usize("pump") {
        let mode = parse_mode(args.get_or("mode", "resource"))?;
        spec = spec.pumped(factor, mode);
    }
    let n = args.get_u64("n").unwrap_or(1 << 16) as i64;
    spec = spec.bind("N", n);
    let c = compile(spec)?;
    if args.flag("verbose") {
        for line in &c.pass_log {
            println!("pass: {line}");
        }
    }
    println!(
        "design '{}': CL0 {:.1} MHz{}, effective {:.1} MHz",
        c.design.name,
        c.report.cl0.achieved_mhz,
        c.report
            .cl1
            .map(|r| format!(", CL1 {:.1} MHz", r.achieved_mhz))
            .unwrap_or_default(),
        c.report.effective_mhz
    );
    let u = c.report.util_percent();
    println!(
        "utilization: LUT {:.2}% / LUTMem {:.2}% / Regs {:.2}% / BRAM {:.2}% / DSP {:.2}%",
        u[0], u[1], u[2], u[3], u[4]
    );
    if args.flag("emit") {
        std::fs::create_dir_all("generated").map_err(|e| e.to_string())?;
        let cpp = codegen::hls::emit_hls(&c.design);
        std::fs::write(format!("generated/{}.cpp", c.design.name), cpp)
            .map_err(|e| e.to_string())?;
        let rtl = codegen::rtl::emit_rtl(&c.design);
        for (name, text) in [
            ("controller.sv", &rtl.controller_sv),
            ("core.sv", &rtl.core_sv),
            ("top.v", &rtl.toplevel_v),
            ("package.tcl", &rtl.package_tcl),
            ("link.cfg", &rtl.link_cfg),
        ] {
            std::fs::write(format!("generated/{}_{name}", c.design.name), text)
                .map_err(|e| e.to_string())?;
        }
        println!("generated/ written (HLS C++ + 4 RTL kernel files + link.cfg)");
    }
    Ok(())
}

fn cmd_run(args: &temporal_vec::util::cli::Parsed, seed: u64) -> Result<(), String> {
    let app = args
        .positional
        .first()
        .map(|s| s.as_str())
        .ok_or("usage: tvec run <vecadd|matmul|floyd_warshall> [--pump 2] [--trace-out t.json]")?;
    let pump = args.get_usize("pump");
    let mut rng = Rng::new(seed);
    // --trace-out: observed compile (per-stage spans) plus one observed
    // exact simulation before the functional golden check
    let recorder = args.get("trace-out").map(|_| temporal_vec::telemetry::Recorder::new());
    let rec = recorder.as_ref();
    let build = |spec: BuildSpec| -> Result<temporal_vec::coordinator::Compiled, String> {
        temporal_vec::coordinator::compile_staged_observed(spec, rec).map_err(|e| e.message)
    };

    // build at golden (artifact) scale, simulate functionally, compare
    let (c, inputs, out_name): (_, Vec<(String, Vec<f32>)>, &str) = match app {
        "vecadd" => {
            let n = apps::vecadd::GOLDEN_N;
            let mut spec =
                BuildSpec::new(apps::vecadd::build()).vectorized("vadd", 8).bind("N", n);
            if let Some(f) = pump {
                spec = spec.pumped(f, PumpMode::Resource);
            }
            let c = build(spec.seeded(seed))?;
            let x = rng.f32_vec(n as usize);
            let y = rng.f32_vec(n as usize);
            (c, vec![("x".into(), x), ("y".into(), y)], "z")
        }
        "matmul" => {
            let n = apps::matmul::GOLDEN_NMK;
            let mut spec = BuildSpec::new(apps::matmul::build(4));
            for (s, v) in apps::matmul::bindings(n) {
                spec = spec.bind(&s, v);
            }
            if let Some(f) = pump {
                spec = spec.pumped(f, PumpMode::Resource);
            }
            let c = build(spec.seeded(seed))?;
            let a = rng.f32_vec((n * n) as usize);
            let b = rng.f32_vec((n * n) as usize);
            (c, vec![("A".into(), a), ("B".into(), b)], "C")
        }
        "floyd_warshall" => {
            let n = apps::floyd_warshall::GOLDEN_N;
            let mut spec = BuildSpec::new(apps::floyd_warshall::build()).bind("N", n);
            if let Some(f) = pump {
                spec = spec.pumped(f, PumpMode::Throughput);
            }
            let c = build(spec.seeded(seed))?;
            let d = apps::floyd_warshall::random_graph(n as usize, seed, 0.25);
            (c, vec![("dist".into(), d)], "dist")
        }
        other => return Err(format!("app '{other}' not runnable here (see examples/)")),
    };
    let load = |inputs: &[(String, Vec<f32>)]| {
        let mut hbm = Hbm::new();
        for (name, data) in inputs {
            hbm.load(name, data.clone());
        }
        hbm
    };

    if let Some(r) = rec {
        println!("simulating '{}' exactly (observed)...", c.design.name);
        let _ = temporal_vec::sim::run_exact_observed_in(
            &c.design,
            load(&inputs),
            temporal_vec::dse::verify::MAX_VERIFY_CYCLES,
            &mut temporal_vec::sim::Arena::new(),
            Some(r),
        )?;
    }

    println!("simulating '{}' functionally...", c.design.name);
    let out = run_functional(&c.design, load(&inputs))?;
    let got = out.hbm.read(out_name);

    println!("executing golden model via PJRT...");
    let mut runner = GoldenRunner::new(&artifact::artifacts_dir())?;
    let input_refs: Vec<&[f32]> = inputs.iter().map(|(_, v)| v.as_slice()).collect();
    let want = runner.run(app, &input_refs)?;

    if got.len() != want.len() {
        return Err(format!("length mismatch: sim {} vs golden {}", got.len(), want.len()));
    }
    let mut worst = 0.0f32;
    for (g, w) in got.iter().zip(&want) {
        let err = (g - w).abs() / w.abs().max(1.0);
        worst = worst.max(err);
    }
    println!(
        "simulated output matches golden model: {} elements, max rel err {worst:.2e}",
        got.len()
    );
    if worst > 1e-4 {
        return Err(format!("numeric mismatch: max rel err {worst}"));
    }
    if let (Some(r), Some(path)) = (rec, args.get("trace-out")) {
        write_telemetry(r, path)?;
    }
    println!("OK");
    Ok(())
}

/// Write both telemetry exports: the Chrome trace-event JSON at `path`
/// and the flat metrics summary as `TELEMETRY.json` next to it.
fn write_telemetry(rec: &temporal_vec::telemetry::Recorder, path: &str) -> Result<(), String> {
    std::fs::write(path, temporal_vec::telemetry::to_chrome_trace(rec))
        .map_err(|e| format!("write {path}: {e}"))?;
    let summary = std::path::Path::new(path)
        .parent()
        .filter(|d| !d.as_os_str().is_empty())
        .map(|d| d.join("TELEMETRY.json"))
        .unwrap_or_else(|| std::path::PathBuf::from("TELEMETRY.json"));
    std::fs::write(&summary, temporal_vec::telemetry::to_summary_json(rec))
        .map_err(|e| format!("write {}: {e}", summary.display()))?;
    println!(
        "wrote {path} (Chrome trace, load in chrome://tracing or Perfetto) and {} (metrics)",
        summary.display()
    );
    Ok(())
}

/// `tvec top <app>`: compile the app's golden-scale base observed, run
/// one observed exact simulation, and print the ranked stall-source
/// report (module stalls, per-channel backpressure vs starvation, and
/// per-clock-domain utilization).
fn cmd_top(args: &temporal_vec::util::cli::Parsed, seed: u64) -> Result<(), String> {
    let app = args
        .positional
        .first()
        .map(|s| s.as_str())
        .or_else(|| args.get("app"))
        .ok_or("usage: tvec top <app> [--pump 2] [--topk 8] [--trace-out t.json]")?;
    let k = args.get_usize("topk").unwrap_or(8);
    let rig = temporal_vec::coordinator::golden_rig(app, seed)?;
    let mut spec = rig.bases.first().cloned().ok_or("golden rig has no base spec")?;
    if let Some(f) = args.get_usize("pump") {
        let mode = parse_mode(args.get_or("mode", "resource"))?;
        spec = spec.pumped(f, mode);
    }
    let rec = temporal_vec::telemetry::Recorder::new();
    let c = temporal_vec::coordinator::compile_staged_observed(spec, Some(&rec))
        .map_err(|e| e.message)?;
    let mut hbm = Hbm::new();
    for (name, data) in &rig.inputs {
        hbm.load(name, data.clone());
    }
    let out = temporal_vec::sim::run_exact_observed_in(
        &c.design,
        hbm,
        temporal_vec::dse::verify::MAX_VERIFY_CYCLES,
        &mut temporal_vec::sim::Arena::new(),
        Some(&rec),
    )?;
    let domains = if c.design.domain_modes.is_empty() {
        String::new()
    } else {
        format!(
            ", fast domains: {}",
            c.design
                .domain_modes
                .iter()
                .map(|(f, m)| format!("cl1_m{f}{} [{}]", m.letter(), m.name()))
                .collect::<Vec<_>>()
                .join(", ")
        )
    };
    println!(
        "=== top: {app} ('{}', {} slow cycles, bottleneck {}{domains}) ===",
        c.design.name, out.stats.slow_cycles, out.stats.bottleneck
    );
    println!("{}", temporal_vec::coordinator::stall_report(&rec, k));
    if let Some(path) = args.get("trace-out") {
        write_telemetry(&rec, path)?;
    }
    Ok(())
}

/// `tvec check <app>`: compile the app's golden-scale base and run the
/// static design-rule checker over the transformed graph and its
/// lowered design, printing the diagnostics table. Exits nonzero when
/// any error-severity rule fires. `--clamp-depth N` caps every data
/// channel's FIFO at N post-lowering — a deliberate undersizing
/// fixture that must trip `TV011` (CI greps for it).
fn cmd_check(args: &temporal_vec::util::cli::Parsed, seed: u64) -> Result<(), String> {
    let app = args
        .positional
        .first()
        .map(|s| s.as_str())
        .or_else(|| args.get("app"))
        .ok_or("usage: tvec check <app> [--pump 2] [--mode resource] [--clamp-depth 1]")?;
    let rig = temporal_vec::coordinator::golden_rig(app, seed)?;
    let mut spec = rig.bases.first().cloned().ok_or("golden rig has no base spec")?;
    if let Some(f) = args.get_usize("pump") {
        let mode = parse_mode(args.get_or("mode", "resource"))?;
        spec = spec.pumped(f, mode);
    }
    let c = temporal_vec::coordinator::compile_staged(spec).map_err(|e| e.message)?;
    let mut design = c.design;
    if let Some(d) = args.get_usize("clamp-depth") {
        for ch in design.channels.iter_mut().filter(|ch| !ch.name.starts_with("__ctrl")) {
            ch.depth = ch.depth.min(d);
        }
    }
    let report = temporal_vec::analysis::checker::check(&c.sdfg, &design);
    println!("{}", report.render(&format!("design-rule check: {} ({app})", design.name)));
    if !report.is_clean() {
        return Err(format!("{} design-rule error(s)", report.errors()));
    }
    Ok(())
}

fn cmd_dse(args: &temporal_vec::util::cli::Parsed, seed: u64) -> Result<(), String> {
    use temporal_vec::dse::{Evaluator, Objective, SearchConfig, Strategy};
    use temporal_vec::hw::Device;

    let app = args
        .get("app")
        .map(|s| s.to_string())
        .or_else(|| args.positional.first().cloned())
        .unwrap_or_else(|| "all".to_string());
    let objective = match args.get_or("objective", "resource") {
        "throughput" => Objective::throughput(),
        "resource" => Objective::resource(),
        other => return Err(format!("unknown objective '{other}' (resource|throughput)")),
    };
    let strategy = Strategy::from_name(args.get_or("strategy", "exhaustive")).ok_or_else(
        || {
            format!(
                "unknown strategy '{}' (exhaustive|greedy|anneal|halving)",
                args.get_or("strategy", "exhaustive")
            )
        },
    )?;
    // --budget: parse failures used to be swallowed by get_usize (a
    // typo silently meant "no budget"); reject them instead
    let budget = match args.get("budget") {
        None => None,
        Some(raw) => Some(raw.parse::<usize>().map_err(|_| {
            format!("invalid --budget '{raw}' (want a non-negative integer)")
        })?),
    };
    // --deadline-ms / --sim-cycle-budget: the per-candidate supervision
    // budgets (DESIGN.md §14); typos rejected like --budget
    let deadline_ms = match args.get("deadline-ms") {
        None => None,
        Some(raw) => Some(raw.parse::<u64>().map_err(|_| {
            format!("invalid --deadline-ms '{raw}' (want milliseconds)")
        })?),
    };
    let sim_cycle_budget = match args.get("sim-cycle-budget") {
        None => None,
        Some(raw) => Some(raw.parse::<u64>().map_err(|_| {
            format!("invalid --sim-cycle-budget '{raw}' (want a slow-cycle count)")
        })?),
    };
    // --threads: worker count for batch evaluation and the pooled
    // frontier verify; 1 forces the serial engines, absent means
    // available parallelism. Typos (and 0) rejected like --budget.
    let threads = match args.get("threads") {
        None => None,
        Some(raw) => Some(parse_threads(raw)?),
    };
    // --inject-faults: a deterministic fault schedule for exercising
    // the supervision paths (CI greps the classified outcomes)
    let faults = match args.get("inject-faults") {
        None => None,
        Some(spec) => Some(
            temporal_vec::dse::FaultPlan::parse(spec)
                .map_err(|e| format!("--inject-faults: {e}"))?,
        ),
    };
    let cfg = SearchConfig { strategy, objective, budget, seed, deadline_ms, sim_cycle_budget };

    // --serve: hand everything to the daemon instead of sweeping
    if let Some(socket) = args.get("serve") {
        let mut sopts = temporal_vec::coordinator::ServeOptions::new(socket);
        sopts.cache_dir = args.get("cache-dir").map(std::path::PathBuf::from);
        sopts.deadline_ms = deadline_ms;
        sopts.sim_cycle_budget = sim_cycle_budget;
        sopts.faults = faults;
        sopts.seed = seed;
        sopts.threads = threads;
        return temporal_vec::coordinator::run_serve(sopts);
    }
    // --tolerance: a NaN parses fine but fails every |ratio − 1| ≤ tol
    // comparison (and a negative one fails all, a huge one passes all)
    // without any hint of the bad flag — demand a finite non-negative
    // value up front. Left unset, each app verifies under its own
    // default envelope (coordinator::verify_tolerance).
    let cli_tolerance = match args.get("tolerance") {
        Some(raw) => Some(parse_tolerance(raw)?),
        None => None,
    };
    // --pump-modes: override the default mode axis (resource+throughput)
    let pump_modes = match args.get("pump-modes") {
        Some(raw) => Some(parse_pump_modes(raw)?),
        None => None,
    };
    let device = Device::u280();
    let names: Vec<&str> = match app.as_str() {
        "all" => vec!["vecadd", "matmul", "jacobi", "diffusion", "fw"],
        other => vec![other],
    };
    let n_override = args.get_u64("n").map(|v| v as i64);
    // one evaluator across apps: the content-hashed cache dedups
    // shared substructure between sweeps; with --cache-dir the cache
    // additionally persists across processes
    let evaluator = match args.get("cache-dir") {
        Some(dir) => {
            let ev = Evaluator::with_cache_dir(std::path::Path::new(dir));
            match ev.cold_reason() {
                Some(reason) => println!("cache: {reason}"),
                None => println!("cache: loaded {} entries from {dir}", ev.loaded_entries()),
            }
            ev
        }
        None => Evaluator::new(),
    };
    let evaluator = match faults {
        Some(plan) => evaluator.with_faults(plan),
        None => evaluator,
    };
    // --trace-out: attach a recorder — per-candidate spans, compile
    // stage spans, search-round cache counters, observed exact sims
    let recorder = args
        .get("trace-out")
        .map(|_| std::sync::Arc::new(temporal_vec::telemetry::Recorder::new()));
    let evaluator = match &recorder {
        Some(rec) => evaluator.observed(rec.clone()),
        None => evaluator,
    };
    if let Some(t) = threads {
        evaluator.set_threads(t);
    }
    let mut verify_failures: Vec<String> = Vec::new();

    // a fatal error still flushes the cache first — nothing already
    // compiled is lost to a late failure. The same holds for a panic
    // escaping the sweep itself: the supervision layer catches
    // per-candidate panics, but a defect in reporting or selection
    // would unwind right through here, so flush (merging — never
    // compacting off a poisoned run) before letting the process die.
    let sweep = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        for name in names {
            let step = run_dse_app(
                name,
                n_override,
                seed,
                &device,
                &cfg,
                &evaluator,
                args.flag("verify"),
                args.flag("mixed-factors"),
                pump_modes.as_deref(),
                cli_tolerance,
                &mut verify_failures,
            );
            if let Err(e) = step {
                return Some(e);
            }
        }
        None
    }));
    let fatal: Option<String> = match sweep {
        Ok(f) => f,
        Err(payload) => {
            if args.get("cache-dir").is_some() {
                match evaluator.flush() {
                    Ok(n) => eprintln!("cache: flushed {n} entries before unwinding"),
                    Err(e) => eprintln!("warning: cache flush during unwind failed: {e}"),
                }
            }
            std::panic::resume_unwind(payload);
        }
    };
    if let Some(plan) = evaluator.faults() {
        println!("faults: {}", plan.summary());
    }

    // export the trace even after a fatal step — a partial trace is
    // exactly what debugging that failure wants
    if let (Some(rec), Some(path)) = (&recorder, args.get("trace-out")) {
        rec.add("dse.arena_pool.checkouts", evaluator.arenas().checkouts() as u64);
        rec.gauge(
            "dse.arena_pool.peak_in_flight",
            evaluator.arenas().peak_in_flight() as f64,
        );
        if let Err(e) = write_telemetry(rec, path) {
            eprintln!("warning: {e}");
        }
    }

    let mut flush_err: Option<String> = None;
    if args.flag("cache-compact") && args.get("cache-dir").is_none() {
        eprintln!("warning: --cache-compact does nothing without --cache-dir");
    }
    if args.get("cache-dir").is_some() {
        // compaction keeps only the entries this run touched — after a
        // fatal mid-run abort that set would be an arbitrary prefix of
        // the sweep, so an aborted run falls back to the merging flush
        // rather than truncating months of untouched records
        if args.flag("cache-compact") && fatal.is_none() {
            match evaluator.flush_compacted() {
                Ok((before, after)) => {
                    println!("cache: compacted {before} → {after} entries")
                }
                Err(e) => flush_err = Some(e),
            }
        } else {
            if args.flag("cache-compact") && fatal.is_some() {
                eprintln!("warning: run failed — merging flush instead of compaction");
            }
            match evaluator.flush() {
                Ok(flushed) => println!("cache: flushed {flushed} entries"),
                Err(e) => flush_err = Some(e),
            }
        }
    }
    if let Some(e) = fatal {
        // the root-cause error outranks a flush failure; still surface both
        if let Some(f) = flush_err {
            eprintln!("warning: cache flush also failed: {f}");
        }
        return Err(e);
    }
    if let Some(f) = flush_err {
        return Err(format!("cache flush failed: {f}"));
    }
    if !verify_failures.is_empty() {
        return Err(format!(
            "rate model disagrees with the exact simulator beyond tolerance on {} \
             frontier point(s):\n  {}",
            verify_failures.len(),
            verify_failures.join("\n  ")
        ));
    }
    Ok(())
}

/// `tvec bench`: measure both exact-simulator engines and the DSE
/// sweep path; `--json` writes the BENCH_sim.json artifact and the
/// command fails when exact-vs-rate drift exceeds an app's tolerance
/// (the CI drift gate).
fn cmd_bench(args: &temporal_vec::util::cli::Parsed, seed: u64) -> Result<(), String> {
    let smoke = args.flag("smoke");
    // an explicit --tolerance overrides every app's drift envelope,
    // mirroring dse --verify
    let tolerance_override = match args.get("tolerance") {
        Some(raw) => Some(parse_tolerance(raw)?),
        None => None,
    };
    // --threads drives the sharded/verify rows; absent = available
    // parallelism, 0 and typos rejected loudly
    let threads = match args.get("threads") {
        None => 0,
        Some(raw) => parse_threads(raw)?,
    };
    let report = temporal_vec::coordinator::run_bench(smoke, seed, tolerance_override, threads)?;
    println!(
        "== tvec bench ({}) ==",
        if smoke { "smoke scale" } else { "golden scale" }
    );
    for s in &report.sims {
        println!(
            "  {:<8} {:<8} {:>9} slow cycles   event {:>12.1} cyc/s   legacy {:>12.1} cyc/s   \
             speedup {:>6.2}x   drift {:>6.3} (±{})",
            s.app,
            s.config,
            s.slow_cycles,
            s.event_cycles_per_sec(),
            s.reference_cycles_per_sec(),
            s.speedup(),
            s.drift_ratio(),
            s.tolerance
        );
    }
    for s in &report.sharded {
        println!(
            "  {:<8} x{:<7} {:>9} slow cycles   serial {:>11.1} cyc/s   sharded {:>11.1} \
             cyc/s   speedup {:>6.2}x   ({} threads)",
            s.app,
            s.replicas,
            s.slow_cycles,
            s.serial_cycles_per_sec(),
            s.sharded_cycles_per_sec(),
            s.speedup(),
            s.threads
        );
    }
    println!(
        "  simd     {} lanes: scalar {:.6}s vs chunked {:.6}s   speedup {:>6.2}x   \
         (eval_lanes dispatches {})",
        report.simd.lanes,
        report.simd.scalar_secs,
        report.simd.chunked_secs,
        report.simd.speedup(),
        report.simd.active
    );
    println!(
        "  verify   {} point(s) via {} worker(s) in {:.3}s ({})",
        report.verify.points, report.verify.threads, report.verify.secs, report.verify.app
    );
    println!(
        "  arena    {} class(es), {} slots, peak live {}, {} recycle hits, {} leaked, \
         high-water {}",
        report.arena.classes,
        report.arena.slots,
        report.arena.peak_live,
        report.arena.recycle_hits,
        report.arena.leaked,
        if report.arena_flat() { "flat" } else { "GREW" }
    );
    println!(
        "  dse {:<12} cold {:.3}s ({} compiles, {} hits)   warm {:.3}s ({} compiles, \
         {} hits, hit rate {:.4})",
        report.dse.app,
        report.dse.cold_secs,
        report.dse.cold_new_compiles,
        report.dse.cold_hits,
        report.dse.warm_secs,
        report.dse.warm_new_compiles,
        report.dse.warm_hits,
        report.dse.warm_hit_rate()
    );
    if args.flag("json") {
        std::fs::write("BENCH_sim.json", report.to_json())
            .map_err(|e| format!("write BENCH_sim.json: {e}"))?;
        println!("wrote BENCH_sim.json");
    }
    let failures = report.drift_failures();
    if !failures.is_empty() {
        return Err(format!(
            "exact-sim vs rate-model drift beyond per-app tolerance:\n  {}",
            failures.join("\n  ")
        ));
    }
    Ok(())
}

/// Parse one `--mode` value; unknown names are rejected loudly rather
/// than silently falling back to resource mode.
fn parse_mode(raw: &str) -> Result<PumpMode, String> {
    match raw {
        "resource" => Ok(PumpMode::Resource),
        "throughput" => Ok(PumpMode::Throughput),
        "barefast" => Ok(PumpMode::BareFast),
        other => Err(format!("unknown pump mode '{other}' (resource|throughput|barefast)")),
    }
}

/// Parse `--pump-modes resource,barefast` into the DSE mode axis.
/// Duplicates are folded; an empty list (or any unknown name) errors.
fn parse_pump_modes(raw: &str) -> Result<Vec<PumpMode>, String> {
    let mut out: Vec<PumpMode> = Vec::new();
    for part in raw.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let m = parse_mode(part).map_err(|e| format!("--pump-modes: {e}"))?;
        if !out.contains(&m) {
            out.push(m);
        }
    }
    if out.is_empty() {
        return Err("--pump-modes: need at least one of resource|throughput|barefast".into());
    }
    Ok(out)
}

/// Parse `--threads`: a positive worker count (`1` forces the serial
/// engines). `0` and non-numbers are rejected loudly — a typo must not
/// silently change the parallelism.
fn parse_threads(raw: &str) -> Result<usize, String> {
    match raw.parse::<usize>() {
        Ok(t) if t >= 1 => Ok(t),
        _ => Err(format!(
            "invalid --threads '{raw}' (want a positive integer; 1 = serial, omit for \
             available parallelism)"
        )),
    }
}

/// Reject non-finite or negative `--tolerance` values: they would make
/// every `dse --verify` comparison silently fail (NaN/negative) or
/// silently pass (∞) with no hint of the bad flag.
fn parse_tolerance(raw: &str) -> Result<f64, String> {
    let t: f64 = raw
        .parse()
        .map_err(|_| format!("invalid --tolerance '{raw}' (want a number, e.g. 0.4)"))?;
    if !t.is_finite() || t < 0.0 {
        return Err(format!(
            "invalid --tolerance '{raw}': must be a finite non-negative number"
        ));
    }
    Ok(t)
}

/// Search (and optionally verify) one DSE app through the shared
/// evaluator, printing the frontier/selection/evaluation report.
#[allow(clippy::too_many_arguments)]
fn run_dse_app(
    name: &str,
    n_override: Option<i64>,
    seed: u64,
    device: &temporal_vec::hw::Device,
    cfg: &temporal_vec::dse::SearchConfig,
    evaluator: &temporal_vec::dse::Evaluator,
    verify: bool,
    mixed_factors: bool,
    pump_modes: Option<&[PumpMode]>,
    cli_tolerance: Option<f64>,
    verify_failures: &mut Vec<String>,
) -> Result<(), String> {
    use temporal_vec::dse::{run_search, verify_frontier_supervised};
    use temporal_vec::util::table::{fnum, pct, Table};

    // per-app default envelope; an explicit --tolerance always wins
    let tolerance =
        cli_tolerance.unwrap_or_else(|| temporal_vec::coordinator::verify_tolerance(name));

    // per-app bases: the matmul PE sweep supplies several — built by
    // the same constructor the --verify golden rig uses, so frontier
    // points always map back to a golden base by index
    let (bases, mut opts) =
        temporal_vec::coordinator::search_problem(name, n_override, seed, device)?;
    opts.mixed_factors = mixed_factors;
    if let Some(modes) = pump_modes {
        opts.pump_modes = modes.to_vec();
    }
    // one partition per app: every base of an app shares the SDFG
    // structure, so region count and order are identical across them
    let regions = mixed_factors
        .then(|| temporal_vec::analysis::partition_streamable(bases[0].spec.sdfg()));
    if let Some(regions) = &regions {
        println!(
            "mixed factors: {} streamable region(s) in '{name}'{}",
            regions.len(),
            if regions.len() < 2 { " — single region, uniform axis only" } else { "" }
        );
    }

    let hits_before = evaluator.cache_hits();
    let misses_before = evaluator.cache_misses();
    let outcome = run_search(evaluator, &bases, device, &opts, cfg)?;
    println!(
        "=== dse: {name} — {} base config(s), {:?}, {} ===",
        bases.len(),
        cfg.strategy,
        cfg.objective.name()
    );
    println!(
        "Pareto frontier ({} non-dominated design points):",
        outcome.frontier.len()
    );
    let mut t = Table::new(
        "resource-vs-throughput frontier (ascending resource score)",
        &["config", "SLRs", "DSPs", "DSP%", "BRAM%", "eff MHz", "GOp/s", "score"],
    );
    for e in &outcome.frontier {
        let u = e.report.util_percent();
        t.row(vec![
            e.label.clone(),
            e.point.replicas.to_string(),
            fnum(e.total_resources.dsp, 0),
            pct(u[4]),
            pct(u[3]),
            fnum(e.report.effective_mhz, 1),
            fnum(e.gops, 1),
            fnum(e.resource_score, 3),
        ]);
    }
    println!("{}", t.render());
    let reference = outcome.reference.as_ref().expect("search produced a reference");
    println!(
        "reference (best unpumped): {} — {} DSPs, {:.1} GOp/s",
        reference.label, reference.total_resources.dsp, reference.gops
    );
    if let Some(chosen) = &outcome.chosen {
        let dsp_pct =
            chosen.total_resources.dsp / reference.total_resources.dsp.max(1e-9) * 100.0;
        let gops_pct = chosen.gops / reference.gops.max(1e-12) * 100.0;
        println!(
            "chosen: {} — {} DSPs = {:.1}% of the unpumped DSP count, at {:.1}% of \
             reference throughput",
            chosen.label, chosen.total_resources.dsp, dsp_pct, gops_pct
        );
        if let (Some(fs), Some(regions)) = (&chosen.point.regions, &regions) {
            let detail: Vec<String> = regions
                .iter()
                .zip(fs)
                .map(|(r, p)| {
                    let tag = p
                        .map(|p| format!("{}{}", p.mode.letter().to_ascii_uppercase(), p.factor))
                        .unwrap_or_else(|| "CL0".into());
                    format!("{}={tag}", r.label)
                })
                .collect();
            println!("chosen per-region pumps: {}", detail.join(", "));
        }
    }
    println!(
        "evaluations: {} issued ({} cache hits, {} new compiles, {} legality-pruned, \
         {} compile failures, {} checker-rejected, {} panicked, {} timed-out{})",
        outcome.evaluated,
        evaluator.cache_hits() - hits_before,
        evaluator.cache_misses() - misses_before,
        outcome.illegal,
        outcome.compile_failed,
        outcome.checker_rejected,
        outcome.panicked,
        outcome.timed_out,
        if outcome.truncated { ", budget hit" } else { "" }
    );

    if !verify {
        // --trace-out without --verify still wants simulator telemetry:
        // run the chosen point once, observed, at golden scale (skips
        // that are illegal at golden scale are fine — the trace simply
        // carries no sim spans for this app)
        if let (Some(rec), Some(chosen)) = (evaluator.probe(), outcome.chosen.as_ref()) {
            let rig = temporal_vec::coordinator::golden_rig(name, seed)?;
            if let Some(base) = rig.bases.get(chosen.base) {
                let _ = evaluator.arenas().run(|arena| {
                    temporal_vec::dse::verify::verify_point_observed(
                        base,
                        chosen,
                        &rig.inputs,
                        tolerance,
                        arena,
                        Some(rec),
                    )
                });
            }
        }
    } else {
        let rig = temporal_vec::coordinator::golden_rig(name, seed)?;
        // exact sims run inside the evaluator's arena pool: every
        // frontier point after the first recycles the same slabs.
        // Supervised: the same --deadline-ms / --sim-cycle-budget that
        // bounded candidate evaluation bounds each re-check, so one
        // wedged frontier point degrades to a visible skip
        let reports = verify_frontier_supervised(
            &outcome.frontier,
            &rig.bases,
            &rig.inputs,
            tolerance,
            evaluator,
            evaluator.probe(),
        )?;
        let mut vt = Table::new(
            format!("--verify: rate model vs exact simulator at golden scale (±{tolerance})"),
            &["config", "rate cycles", "exact cycles", "ratio", "status"],
        );
        for r in &reports {
            let status = match &r.skipped {
                Some(reason) => format!("SKIP ({reason})"),
                None if r.within => "ok".to_string(),
                None => "FAIL".to_string(),
            };
            vt.row(vec![
                r.label.clone(),
                r.rate_cycles.to_string(),
                r.exact_cycles.to_string(),
                fnum(r.ratio, 3),
                status,
            ]);
        }
        println!("{}", vt.render());
        let checked = reports.iter().filter(|r| r.skipped.is_none()).count();
        let skipped = reports.len() - checked;
        let ok = reports.iter().filter(|r| r.skipped.is_none() && r.within).count();
        println!(
            "verify: {ok}/{checked} frontier points within tolerance \
             ({skipped} skipped at golden scale)"
        );
        let a = evaluator.arenas().stats();
        println!(
            "verify arena: {} pooled arena(s), {} slots, peak live {}, {} recycle hits",
            evaluator.arenas().pooled(),
            a.slots,
            a.peak_live,
            a.recycle_hits
        );
        for r in temporal_vec::dse::verify::failures(&reports) {
            verify_failures.push(format!(
                "{}: rate {} vs exact {} (ratio {:.3}, tolerance ±{tolerance})",
                r.label, r.rate_cycles, r.exact_cycles, r.ratio
            ));
        }
    }
    println!();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::{parse_mode, parse_pump_modes, parse_tolerance, PumpMode};

    #[test]
    fn mode_parsing_covers_all_three_modes_and_rejects_typos() {
        assert_eq!(parse_mode("resource").unwrap(), PumpMode::Resource);
        assert_eq!(parse_mode("throughput").unwrap(), PumpMode::Throughput);
        assert_eq!(parse_mode("barefast").unwrap(), PumpMode::BareFast);
        assert!(parse_mode("fast").unwrap_err().contains("barefast"));
    }

    #[test]
    fn pump_modes_list_parses_dedups_and_rejects_empty() {
        assert_eq!(
            parse_pump_modes("throughput, barefast,throughput").unwrap(),
            vec![PumpMode::Throughput, PumpMode::BareFast]
        );
        assert!(parse_pump_modes("").is_err());
        assert!(parse_pump_modes(" , ").is_err());
        assert!(parse_pump_modes("resource|barefast").is_err());
    }

    #[test]
    fn tolerance_validation_rejects_degenerate_values() {
        assert_eq!(parse_tolerance("0.4").unwrap(), 0.4);
        assert_eq!(parse_tolerance("0").unwrap(), 0.0);
        for bad in ["NaN", "nan", "-0.1", "inf", "-inf", "not-a-number"] {
            let err = parse_tolerance(bad).unwrap_err();
            assert!(err.contains("tolerance"), "{bad}: {err}");
        }
    }
}
