//! Memlets: data-movement edges.
//!
//! A memlet names the container it moves data of, the symbolic subset
//! accessed *per iteration of the surrounding scope*, and the connector
//! names on both endpoints. All feasibility checks of the paper's
//! transformation are phrased over memlets.

use crate::symbolic::{Expr, Subset, SymbolTable};

/// A data-movement edge annotation.
#[derive(Clone, Debug)]
pub struct Memlet {
    /// Name of the data container being moved (or the stream).
    pub data: String,
    /// Subset accessed (per innermost scope iteration).
    pub subset: Subset,
    /// Source connector name (None for plain access-node endpoints).
    pub src_conn: Option<String>,
    /// Destination connector name.
    pub dst_conn: Option<String>,
    /// Dynamic (data-dependent) access — poisons vectorizability.
    pub dynamic: bool,
}

impl Memlet {
    pub fn new(data: &str, subset: Subset) -> Self {
        Memlet { data: data.to_string(), subset, src_conn: None, dst_conn: None, dynamic: false }
    }

    /// Simple 1-D element memlet `data[idx]`.
    pub fn element(data: &str, idx: Expr) -> Self {
        Memlet::new(data, Subset::index1(idx))
    }

    pub fn with_dst(mut self, conn: &str) -> Self {
        self.dst_conn = Some(conn.to_string());
        self
    }

    pub fn with_src(mut self, conn: &str) -> Self {
        self.src_conn = Some(conn.to_string());
        self
    }

    pub fn dynamic(mut self) -> Self {
        self.dynamic = true;
        self
    }

    /// Volume in elements per scope iteration (concrete).
    pub fn volume(&self, env: &SymbolTable) -> Option<i64> {
        self.subset.volume(env)
    }

    pub fn label(&self) -> String {
        format!("{}{}", self.data, self.subset)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn element_memlet() {
        let m = Memlet::element("x", Expr::sym("i")).with_dst("x_in");
        assert_eq!(m.label(), "x[i]");
        assert_eq!(m.dst_conn.as_deref(), Some("x_in"));
        assert!(!m.dynamic);
    }

    #[test]
    fn volume() {
        let m = Memlet::new("A", Subset::all1(64));
        assert_eq!(m.volume(&SymbolTable::new()), Some(64));
    }

    #[test]
    fn dynamic_flag() {
        let m = Memlet::element("x", Expr::opaque("p[i]")).dynamic();
        assert!(m.dynamic);
    }
}
