//! IR node kinds.

use super::tasklet::Tasklet;
use crate::symbolic::Range;

/// How a map scope is scheduled onto hardware.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MapSchedule {
    /// One deep pipeline iterating the range (II=1 when feasible).
    Pipeline,
    /// Fully unrolled: one hardware instance per iteration (PEs).
    Unroll,
    /// Sequential loop (no pipelining) — dependent iterations.
    Sequential,
}

/// Stencil flavors used by the evaluation (StencilFlow §4.3).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum StencilKind {
    /// 7-point Jacobi: `w * (sum of 6 face neighbours + center)`-style
    /// update (5 adds + 1 const mul per output in our calibration).
    Jacobi3D,
    /// Diffusion: weighted center + neighbour terms (higher intensity).
    Diffusion3D,
}

impl StencilKind {
    pub fn name(&self) -> &'static str {
        match self {
            StencilKind::Jacobi3D => "jacobi3d",
            StencilKind::Diffusion3D => "diffusion3d",
        }
    }
}

/// Structured library nodes. DaCe expands library nodes during lowering;
/// we do the same in `codegen::expand`. They let the evaluation express
/// the two big accelerators without hand-drawing hundreds of IR nodes.
#[derive(Clone, Debug)]
pub enum LibraryOp {
    /// 1-D systolic array for communication-avoiding GEMM [10]:
    /// `pes` processing elements, each `vec_width` lanes wide, with
    /// memory tiles of `tile_m × tile_n`. Feeders/drainers at the ends.
    SystolicGemm { pes: usize, vec_width: usize, tile_m: usize, tile_n: usize },
    /// One stencil stage of a StencilFlow chain, spatially vectorized
    /// `vec_width` ways over a `nx × ny × nz` domain.
    StencilStage { kind: StencilKind, vec_width: usize },
    /// Streaming Floyd–Warshall datapath (paper §4.4): the program that
    /// cannot be traditionally vectorized. `lanes` is the external feed
    /// width (raised by throughput-mode multi-pumping).
    FloydWarshall { lanes: usize },
}

impl LibraryOp {
    pub fn name(&self) -> String {
        match self {
            LibraryOp::SystolicGemm { pes, vec_width, .. } => {
                format!("systolic_gemm_p{pes}_w{vec_width}")
            }
            LibraryOp::StencilStage { kind, vec_width } => {
                format!("{}_w{vec_width}", kind.name())
            }
            LibraryOp::FloydWarshall { lanes } => format!("floyd_warshall_w{lanes}"),
        }
    }
}

/// The three AXI4-Stream infrastructure module types the transformation
/// injects at clock-domain crossings (paper §3.2, "plumbing" modules).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum CdcKind {
    /// Synchronizes a stream between the two clock domains.
    Synchronizer,
    /// Divides one wide transaction into `factor` narrow ones
    /// (entering the multi-pumped domain).
    Issuer,
    /// Packs `factor` narrow transactions into one wide one
    /// (leaving the multi-pumped domain).
    Packer,
}

impl CdcKind {
    pub fn name(&self) -> &'static str {
        match self {
            CdcKind::Synchronizer => "axis_clock_converter",
            CdcKind::Issuer => "axis_dwidth_issuer",
            CdcKind::Packer => "axis_dwidth_packer",
        }
    }
}

/// A node of the dataflow graph.
#[derive(Clone, Debug)]
pub enum Node {
    /// Reference to a declared data container.
    Access { data: String },
    /// Opens a parametric scope: `params[i]` ranges over `ranges[i]`.
    MapEntry { name: String, params: Vec<String>, ranges: Vec<Range>, schedule: MapSchedule },
    /// Closes the matching scope.
    MapExit { entry: String },
    /// Computational leaf.
    Tasklet(Tasklet),
    /// Structured accelerator (expanded by codegen).
    Library { name: String, op: LibraryOp },
    /// Reader module injected by the streaming transformation: reads
    /// `data` in linear order and pushes to `stream`.
    Reader { name: String, data: String, stream: String },
    /// Writer module: pops from `stream` and writes `data` linearly.
    Writer { name: String, data: String, stream: String },
    /// Clock-domain-crossing plumbing between two stream containers.
    Cdc { name: String, kind: CdcKind, input: String, output: String, factor: usize },
}

impl Node {
    pub fn label(&self) -> String {
        match self {
            Node::Access { data } => data.clone(),
            Node::MapEntry { name, .. } => format!("{name}[entry]"),
            Node::MapExit { entry } => format!("{entry}[exit]"),
            Node::Tasklet(t) => t.name.clone(),
            Node::Library { name, .. } => name.clone(),
            Node::Reader { name, .. } => name.clone(),
            Node::Writer { name, .. } => name.clone(),
            Node::Cdc { name, .. } => name.clone(),
        }
    }

    pub fn is_access(&self) -> bool {
        matches!(self, Node::Access { .. })
    }

    pub fn is_compute(&self) -> bool {
        matches!(self, Node::Tasklet(_) | Node::Library { .. })
    }

    pub fn is_io_module(&self) -> bool {
        matches!(self, Node::Reader { .. } | Node::Writer { .. })
    }

    pub fn is_cdc(&self) -> bool {
        matches!(self, Node::Cdc { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::tasklet::TaskExpr;

    #[test]
    fn labels() {
        let a = Node::Access { data: "x".into() };
        assert_eq!(a.label(), "x");
        assert!(a.is_access());
        let t = Node::Tasklet(Tasklet::new("add", vec![("z", TaskExpr::input("x"))]));
        assert!(t.is_compute());
        assert_eq!(t.label(), "add");
        let l = Node::Library {
            name: "g".into(),
            op: LibraryOp::SystolicGemm { pes: 32, vec_width: 16, tile_m: 256, tile_n: 512 },
        };
        assert!(l.is_compute());
        assert_eq!(
            match &l {
                Node::Library { op, .. } => op.name(),
                _ => unreachable!(),
            },
            "systolic_gemm_p32_w16"
        );
    }
}
