//! Tasklets: the computational leaves of the IR.
//!
//! A tasklet owns an expression AST per output connector. The AST is
//! (a) evaluated on real `f32` lanes by the simulator, (b) priced by the
//! resource cost model (`hw::cost` counts adds/muls/...), and (c)
//! pretty-printed by the HLS code generator. Keeping one representation
//! for all three uses guarantees the simulated design, the resource
//! estimate, and the emitted code never drift apart.

use std::collections::BTreeMap;

/// Binary operations the cost model knows how to price on DSPs/LUTs.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
    Min,
    Max,
}

/// Unary operations.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum UnOp {
    Neg,
    Abs,
}

/// Expression AST over input connector names.
#[derive(Clone, PartialEq, Debug)]
pub enum TaskExpr {
    /// Value read from an input connector.
    In(String),
    /// f32 literal.
    Const(f32),
    Bin(BinOp, Box<TaskExpr>, Box<TaskExpr>),
    Un(UnOp, Box<TaskExpr>),
    /// Fused multiply-add a*b + c (one DSP cascade on the fabric).
    MulAdd(Box<TaskExpr>, Box<TaskExpr>, Box<TaskExpr>),
}

impl TaskExpr {
    pub fn input(name: &str) -> TaskExpr {
        TaskExpr::In(name.to_string())
    }

    pub fn c(v: f32) -> TaskExpr {
        TaskExpr::Const(v)
    }

    pub fn add(self, rhs: TaskExpr) -> TaskExpr {
        TaskExpr::Bin(BinOp::Add, Box::new(self), Box::new(rhs))
    }

    pub fn sub(self, rhs: TaskExpr) -> TaskExpr {
        TaskExpr::Bin(BinOp::Sub, Box::new(self), Box::new(rhs))
    }

    pub fn mul(self, rhs: TaskExpr) -> TaskExpr {
        TaskExpr::Bin(BinOp::Mul, Box::new(self), Box::new(rhs))
    }

    pub fn min(self, rhs: TaskExpr) -> TaskExpr {
        TaskExpr::Bin(BinOp::Min, Box::new(self), Box::new(rhs))
    }

    pub fn max(self, rhs: TaskExpr) -> TaskExpr {
        TaskExpr::Bin(BinOp::Max, Box::new(self), Box::new(rhs))
    }

    pub fn muladd(a: TaskExpr, b: TaskExpr, c: TaskExpr) -> TaskExpr {
        TaskExpr::MulAdd(Box::new(a), Box::new(b), Box::new(c))
    }

    /// Evaluate on scalar f32 inputs.
    pub fn eval(&self, inputs: &BTreeMap<String, f32>) -> f32 {
        match self {
            TaskExpr::In(name) => *inputs
                .get(name)
                .unwrap_or_else(|| panic!("tasklet input '{name}' not bound")),
            TaskExpr::Const(v) => *v,
            TaskExpr::Bin(op, a, b) => {
                let (x, y) = (a.eval(inputs), b.eval(inputs));
                match op {
                    BinOp::Add => x + y,
                    BinOp::Sub => x - y,
                    BinOp::Mul => x * y,
                    BinOp::Div => x / y,
                    BinOp::Min => x.min(y),
                    BinOp::Max => x.max(y),
                }
            }
            TaskExpr::Un(op, a) => {
                let x = a.eval(inputs);
                match op {
                    UnOp::Neg => -x,
                    UnOp::Abs => x.abs(),
                }
            }
            TaskExpr::MulAdd(a, b, c) => a.eval(inputs) * b.eval(inputs) + c.eval(inputs),
        }
    }

    /// Count of (adds, muls, divs, minmax) — consumed by the cost model
    /// and the GOp/s accounting. MulAdd counts one add + one mul.
    pub fn op_counts(&self) -> OpCounts {
        let mut c = OpCounts::default();
        self.count_into(&mut c);
        c
    }

    fn count_into(&self, c: &mut OpCounts) {
        match self {
            TaskExpr::In(_) | TaskExpr::Const(_) => {}
            TaskExpr::Bin(op, a, b) => {
                a.count_into(c);
                b.count_into(c);
                match op {
                    BinOp::Add | BinOp::Sub => c.adds += 1,
                    BinOp::Mul => c.muls += 1,
                    BinOp::Div => c.divs += 1,
                    BinOp::Min | BinOp::Max => c.minmax += 1,
                }
            }
            TaskExpr::Un(_, a) => {
                a.count_into(c);
                c.adds += 1; // neg/abs ≈ one adder-class op
            }
            TaskExpr::MulAdd(a, b, cc) => {
                a.count_into(c);
                b.count_into(c);
                cc.count_into(c);
                c.adds += 1;
                c.muls += 1;
            }
        }
    }

    /// Input connectors referenced by this expression.
    pub fn inputs(&self) -> Vec<String> {
        let mut v = Vec::new();
        self.inputs_into(&mut v);
        v.sort();
        v.dedup();
        v
    }

    fn inputs_into(&self, v: &mut Vec<String>) {
        match self {
            TaskExpr::In(n) => v.push(n.clone()),
            TaskExpr::Const(_) => {}
            TaskExpr::Bin(_, a, b) => {
                a.inputs_into(v);
                b.inputs_into(v);
            }
            TaskExpr::Un(_, a) => a.inputs_into(v),
            TaskExpr::MulAdd(a, b, c) => {
                a.inputs_into(v);
                b.inputs_into(v);
                c.inputs_into(v);
            }
        }
    }

    /// C expression string for HLS emission.
    pub fn to_c(&self) -> String {
        match self {
            TaskExpr::In(n) => n.clone(),
            TaskExpr::Const(v) => format!("{v:?}f"),
            TaskExpr::Bin(op, a, b) => {
                let (x, y) = (a.to_c(), b.to_c());
                match op {
                    BinOp::Add => format!("({x} + {y})"),
                    BinOp::Sub => format!("({x} - {y})"),
                    BinOp::Mul => format!("({x} * {y})"),
                    BinOp::Div => format!("({x} / {y})"),
                    BinOp::Min => format!("hlslib::min({x}, {y})"),
                    BinOp::Max => format!("hlslib::max({x}, {y})"),
                }
            }
            TaskExpr::Un(op, a) => {
                let x = a.to_c();
                match op {
                    UnOp::Neg => format!("(-{x})"),
                    UnOp::Abs => format!("hlslib::abs({x})"),
                }
            }
            TaskExpr::MulAdd(a, b, c) => {
                format!("({} * {} + {})", a.to_c(), b.to_c(), c.to_c())
            }
        }
    }
}

/// Operation counts of one tasklet evaluation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    pub adds: usize,
    pub muls: usize,
    pub divs: usize,
    pub minmax: usize,
}

impl OpCounts {
    pub fn total_flops(&self) -> usize {
        self.adds + self.muls + self.divs + self.minmax
    }
}

/// A tasklet: named input/output connectors and one expression per
/// output connector.
#[derive(Clone, Debug)]
pub struct Tasklet {
    pub name: String,
    pub outputs: Vec<(String, TaskExpr)>,
}

impl Tasklet {
    pub fn new(name: &str, outputs: Vec<(&str, TaskExpr)>) -> Self {
        Tasklet {
            name: name.to_string(),
            outputs: outputs.into_iter().map(|(n, e)| (n.to_string(), e)).collect(),
        }
    }

    /// All referenced input connectors across outputs.
    pub fn input_connectors(&self) -> Vec<String> {
        let mut v: Vec<String> = self.outputs.iter().flat_map(|(_, e)| e.inputs()).collect();
        v.sort();
        v.dedup();
        v
    }

    pub fn output_connectors(&self) -> Vec<String> {
        self.outputs.iter().map(|(n, _)| n.clone()).collect()
    }

    /// Aggregate op counts over all outputs.
    pub fn op_counts(&self) -> OpCounts {
        let mut acc = OpCounts::default();
        for (_, e) in &self.outputs {
            let c = e.op_counts();
            acc.adds += c.adds;
            acc.muls += c.muls;
            acc.divs += c.divs;
            acc.minmax += c.minmax;
        }
        acc
    }

    /// Evaluate all outputs given scalar inputs.
    pub fn eval(&self, inputs: &BTreeMap<String, f32>) -> BTreeMap<String, f32> {
        self.outputs.iter().map(|(n, e)| (n.clone(), e.eval(inputs))).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(pairs: &[(&str, f32)]) -> BTreeMap<String, f32> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn eval_vecadd() {
        let t = Tasklet::new("add", vec![("z", TaskExpr::input("x").add(TaskExpr::input("y")))]);
        let out = t.eval(&env(&[("x", 2.0), ("y", 3.0)]));
        assert_eq!(out["z"], 5.0);
    }

    #[test]
    fn eval_muladd_and_minmax() {
        let e = TaskExpr::muladd(
            TaskExpr::input("a"),
            TaskExpr::input("b"),
            TaskExpr::input("c"),
        )
        .min(TaskExpr::c(10.0));
        assert_eq!(e.eval(&env(&[("a", 2.0), ("b", 3.0), ("c", 4.0)])), 10.0);
        assert_eq!(e.eval(&env(&[("a", 1.0), ("b", 2.0), ("c", 3.0)])), 5.0);
    }

    #[test]
    fn op_counts_accumulate() {
        // FW relax: min(d_ij, d_ik + d_kj) = 1 add + 1 minmax
        let relax = TaskExpr::input("dij")
            .min(TaskExpr::input("dik").add(TaskExpr::input("dkj")));
        let c = relax.op_counts();
        assert_eq!(c.adds, 1);
        assert_eq!(c.minmax, 1);
        assert_eq!(c.total_flops(), 2);
        // MAC: 1 add + 1 mul
        let mac = TaskExpr::muladd(
            TaskExpr::input("a"),
            TaskExpr::input("b"),
            TaskExpr::input("acc"),
        );
        assert_eq!(mac.op_counts(), OpCounts { adds: 1, muls: 1, divs: 0, minmax: 0 });
    }

    #[test]
    fn inputs_deduplicated() {
        let e = TaskExpr::input("x").add(TaskExpr::input("x")).mul(TaskExpr::input("y"));
        assert_eq!(e.inputs(), vec!["x".to_string(), "y".to_string()]);
    }

    #[test]
    fn c_emission() {
        let e = TaskExpr::input("x").add(TaskExpr::c(1.0)).min(TaskExpr::input("y"));
        let s = e.to_c();
        assert!(s.contains("hlslib::min"), "{s}");
        assert!(s.contains("(x + 1.0f)"), "{s}");
    }

    #[test]
    #[should_panic(expected = "not bound")]
    fn unbound_input_panics() {
        TaskExpr::input("missing").eval(&BTreeMap::new());
    }
}
