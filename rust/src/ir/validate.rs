//! Structural validation of SDFGs.
//!
//! Run before and after every transformation (the pass manager calls
//! [`validate`]) so a rewrite can never silently corrupt the graph.
//!
//! Failures carry the stable `TV1xx` codes from
//! [`crate::analysis::checker::diag`] and render through the same
//! [`Diagnostic`] shape as `tvec check`, so validator and checker
//! output is uniform and tests match on code, never on prose.

use super::graph::{NodeId, Sdfg};
use super::node::Node;
use crate::analysis::checker::diag::{
    Diagnostic, TV101_DANGLING_EDGE, TV102_UNDECLARED_CONTAINER, TV103_MAP_ARITY,
    TV104_MAP_PAIRING, TV105_UNCONNECTED_CONNECTOR, TV106_FOREIGN_CONTAINER, TV107_GRAPH_CYCLE,
    TV108_PARAM_SHADOWING,
};

/// A validation failure with its stable code and location.
///
/// (Hand-rolled `Display`/`Error` impls — `thiserror` is unavailable in
/// the offline build environment, DESIGN.md §4.)
#[derive(Clone, Debug)]
pub struct ValidationError {
    pub sdfg: String,
    /// Stable `TV1xx` diagnostic code — what tests match on.
    pub code: &'static str,
    pub loc: String,
    pub reason: String,
}

impl ValidationError {
    /// The shared diagnostic shape (always an error: structural
    /// validation has no advisory findings).
    pub fn diagnostic(&self) -> Diagnostic {
        Diagnostic::error(self.code, self.loc.clone(), self.reason.clone())
    }
}

impl std::fmt::Display for ValidationError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "validation of '{}' failed: {}", self.sdfg, self.diagnostic())
    }
}

impl std::error::Error for ValidationError {}

fn err(
    g: &Sdfg,
    code: &'static str,
    loc: impl Into<String>,
    reason: impl Into<String>,
) -> ValidationError {
    ValidationError { sdfg: g.name.clone(), code, loc: loc.into(), reason: reason.into() }
}

/// Validate graph structure. Checks:
/// 1. every edge endpoint exists (`TV101`) and every memlet names a
///    declared container (`TV102`);
/// 2. every map entry has exactly one matching exit and vice versa
///    (`TV103`/`TV104`);
/// 3. tasklet input/output connectors are all connected (`TV105`);
/// 4. access nodes to `Array` containers are sources/sinks of memlets
///    naming that container (`TV106`);
/// 5. the graph is acyclic (`TV107`);
/// 6. map parameters do not shadow program symbols (`TV108`).
pub fn validate(g: &Sdfg) -> Result<(), ValidationError> {
    // 1. memlets name declared containers
    for (i, e) in g.edges.iter().enumerate() {
        if e.src.0 >= g.nodes.len() || e.dst.0 >= g.nodes.len() {
            return Err(err(g, TV101_DANGLING_EDGE, format!("edge {i}"), "dangling endpoint"));
        }
        if !g.containers.contains_key(&e.memlet.data) {
            return Err(err(
                g,
                TV102_UNDECLARED_CONTAINER,
                format!("edge {i}"),
                format!("memlet names undeclared container '{}'", e.memlet.data),
            ));
        }
    }

    // 2. map entry/exit pairing
    for id in g.node_ids() {
        match g.node(id) {
            Node::MapEntry { name, params, ranges, .. } => {
                if params.len() != ranges.len() {
                    return Err(err(
                        g,
                        TV103_MAP_ARITY,
                        format!("map '{name}'"),
                        "params/ranges arity mismatch",
                    ));
                }
                let exits: Vec<NodeId> = g
                    .node_ids()
                    .filter(|n| matches!(g.node(*n), Node::MapExit { entry } if entry == name))
                    .collect();
                if exits.len() != 1 {
                    return Err(err(
                        g,
                        TV104_MAP_PAIRING,
                        format!("map '{name}'"),
                        format!("{} exits (expected 1)", exits.len()),
                    ));
                }
                // 6. parameter shadowing
                for p in params {
                    if g.symbols.contains(p) {
                        return Err(err(
                            g,
                            TV108_PARAM_SHADOWING,
                            format!("map '{name}'"),
                            format!("parameter '{p}' shadows a program symbol"),
                        ));
                    }
                }
            }
            Node::MapExit { entry } => {
                if g.find_map_entry(entry).is_none() {
                    return Err(err(
                        g,
                        TV104_MAP_PAIRING,
                        format!("exit of '{entry}'"),
                        "no matching map entry",
                    ));
                }
            }
            _ => {}
        }
    }

    // 3. tasklet connectors fully wired
    for id in g.node_ids() {
        if let Node::Tasklet(t) = g.node(id) {
            let in_conns: Vec<String> = g
                .in_edges(id)
                .iter()
                .filter_map(|e| g.edge(*e).memlet.dst_conn.clone())
                .collect();
            for need in t.input_connectors() {
                if !in_conns.contains(&need) {
                    return Err(err(
                        g,
                        TV105_UNCONNECTED_CONNECTOR,
                        format!("tasklet '{}'", t.name),
                        format!("input connector '{need}' unconnected"),
                    ));
                }
            }
            let out_conns: Vec<String> = g
                .out_edges(id)
                .iter()
                .filter_map(|e| g.edge(*e).memlet.src_conn.clone())
                .collect();
            for need in t.output_connectors() {
                if !out_conns.contains(&need) {
                    return Err(err(
                        g,
                        TV105_UNCONNECTED_CONNECTOR,
                        format!("tasklet '{}'", t.name),
                        format!("output connector '{need}' unconnected"),
                    ));
                }
            }
        }
    }

    // 4. access nodes move their own container
    for id in g.node_ids() {
        if let Node::Access { data } = g.node(id) {
            for e in g.out_edges(id).into_iter().chain(g.in_edges(id)) {
                let m = &g.edge(e).memlet;
                if &m.data != data {
                    // streams may be written through foreign memlets after
                    // streaming transformation; allow only stream decls
                    let is_stream = g
                        .container(&m.data)
                        .map(|d| d.storage.is_stream())
                        .unwrap_or(false);
                    if !is_stream {
                        return Err(err(
                            g,
                            TV106_FOREIGN_CONTAINER,
                            format!("access '{data}'"),
                            format!("edge moves foreign container '{}'", m.data),
                        ));
                    }
                }
            }
        }
    }

    // 5. acyclic
    g.topo_order().map_err(|m| err(g, TV107_GRAPH_CYCLE, "graph", m))?;

    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::{vecadd_sdfg, GraphBuilder};
    use crate::ir::memlet::Memlet;
    use crate::ir::node::MapSchedule;
    use crate::ir::tasklet::TaskExpr;
    use crate::symbolic::{Expr, Range, Subset};

    #[test]
    fn vecadd_validates() {
        validate(&vecadd_sdfg(1)).unwrap();
        validate(&vecadd_sdfg(8)).unwrap();
    }

    #[test]
    fn unconnected_tasklet_input_caught() {
        let mut b = GraphBuilder::new("bad");
        b.array_f32("x", vec![Expr::sym("N")]);
        b.array_f32("z", vec![Expr::sym("N")]);
        let x = b.access("x");
        let z = b.access("z");
        let (me, mx) = b.map("m", &["i"], vec![Range::upto_sym("N")], MapSchedule::Pipeline);
        // tasklet needs "a" and "b" but only "a" is wired
        let t = b.tasklet1("add", "out", TaskExpr::input("a").add(TaskExpr::input("b")));
        let all = Subset::new(vec![Range::upto_sym("N")]);
        let elem = Subset::index1(Expr::sym("i"));
        b.feed(x, me, t, "x", all.clone(), elem.clone(), "a");
        b.drain(t, mx, z, "z", elem, all, "out");
        let g = b.finish();
        let e = validate(&g).unwrap_err();
        assert_eq!(e.code, TV105_UNCONNECTED_CONNECTOR, "{e}");
    }

    #[test]
    fn undeclared_memlet_container_caught() {
        let mut g = vecadd_sdfg(1);
        let first = g.edges[0].clone();
        g.edges[0] = crate::ir::graph::Edge {
            memlet: Memlet::new("ghost", first.memlet.subset.clone()),
            ..first
        };
        let e = validate(&g).unwrap_err();
        assert_eq!(e.code, TV102_UNDECLARED_CONTAINER, "{e}");
    }

    #[test]
    fn missing_map_exit_caught() {
        let mut b = GraphBuilder::new("noexit");
        b.array_f32("x", vec![Expr::sym("N")]);
        let _ = b.access("x");
        let mut g = b.finish();
        g.add_node(crate::ir::node::Node::MapEntry {
            name: "m".into(),
            params: vec!["i".into()],
            ranges: vec![Range::upto_sym("N")],
            schedule: MapSchedule::Pipeline,
        });
        let e = validate(&g).unwrap_err();
        assert_eq!(e.code, TV104_MAP_PAIRING, "{e}");
    }

    #[test]
    fn param_shadowing_caught() {
        let mut b = GraphBuilder::new("shadow");
        b.array_f32("x", vec![Expr::sym("N")]);
        let mut g = b.finish();
        g.add_node(crate::ir::node::Node::MapEntry {
            name: "m".into(),
            params: vec!["N".into()],
            ranges: vec![Range::upto(4)],
            schedule: MapSchedule::Pipeline,
        });
        g.add_node(crate::ir::node::Node::MapExit { entry: "m".into() });
        let e = validate(&g).unwrap_err();
        assert_eq!(e.code, TV108_PARAM_SHADOWING, "{e}");
    }

    #[test]
    fn validation_error_renders_as_diagnostic() {
        let mut g = vecadd_sdfg(1);
        let first = g.edges[0].clone();
        g.edges[0] = crate::ir::graph::Edge {
            memlet: Memlet::new("ghost", first.memlet.subset.clone()),
            ..first
        };
        let e = validate(&g).unwrap_err();
        let d = e.diagnostic();
        assert!(d.is_error());
        assert_eq!(d.code, "TV102");
        // uniform rendering: the Display string embeds the diagnostic
        assert!(format!("{e}").contains(&format!("{d}")), "{e}");
    }
}
