//! SDFG-like data-centric intermediate representation.
//!
//! The IR mirrors the subset of DaCe the paper relies on: *data
//! containers* (random-access arrays, streams, scalars) referenced by
//! *access nodes*, *map scopes* expressing parametric parallelism,
//! *tasklets* holding the computation as an evaluable expression AST,
//! *library nodes* for the two structured accelerators the evaluation
//! uses (systolic GEMM chains, stencil stages), and *memlets* — edges
//! annotated with symbolic subsets describing every byte that moves.
//!
//! Transformations ([`crate::transforms`]) are checked graph rewrites
//! over this IR; code generation ([`crate::codegen`]) lowers it to a
//! design netlist that the hardware model prices and the simulator
//! executes.

pub mod builder;
pub mod graph;
pub mod memlet;
pub mod node;
pub mod printer;
pub mod tasklet;
pub mod types;
pub mod validate;

pub use builder::GraphBuilder;
pub use graph::{EdgeId, MultipumpInfo, NodeId, PumpMode, PumpedRegion, RegionPump, Sdfg};
pub use memlet::Memlet;
pub use node::{CdcKind, LibraryOp, MapSchedule, Node, StencilKind};
pub use tasklet::{BinOp, TaskExpr, Tasklet, UnOp};
pub use types::{ClockDomain, ContainerKind, DType, DataDecl, Storage, VecType};
