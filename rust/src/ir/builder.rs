//! Fluent builder for constructing SDFGs.
//!
//! The application definitions in [`crate::apps`] and the tests use this
//! API; the tiny DSL frontend ([`crate::frontend`]) lowers onto it too.

use super::graph::{NodeId, Sdfg};
use super::memlet::Memlet;
use super::node::{LibraryOp, MapSchedule, Node};
use super::tasklet::{TaskExpr, Tasklet};
use super::types::{ContainerKind, DType, DataDecl, Storage, VecType};
use crate::symbolic::{Expr, Range, Subset};

/// Builder wrapping an [`Sdfg`] under construction.
pub struct GraphBuilder {
    g: Sdfg,
    next_bank: usize,
}

impl GraphBuilder {
    pub fn new(name: &str) -> Self {
        GraphBuilder { g: Sdfg::new(name), next_bank: 0 }
    }

    /// Declare a 1-D f32 array in its own HBM bank (the paper's §4
    /// configuration: one container per bank).
    pub fn array_f32(&mut self, name: &str, shape: Vec<Expr>) -> &mut Self {
        self.array(name, VecType::scalar(DType::F32), shape)
    }

    /// Declare an array of the given vector type in a fresh HBM bank.
    pub fn array(&mut self, name: &str, vtype: VecType, shape: Vec<Expr>) -> &mut Self {
        let bank = self.next_bank;
        self.next_bank += 1;
        for d in &shape {
            for s in d.symbols() {
                self.g.add_symbol(&s);
            }
        }
        self.g.declare(DataDecl {
            name: name.into(),
            kind: ContainerKind::Array,
            vtype,
            shape,
            storage: Storage::Hbm { bank },
            transient: false,
        });
        self
    }

    /// Declare an on-chip transient buffer.
    pub fn bram(&mut self, name: &str, vtype: VecType, shape: Vec<Expr>) -> &mut Self {
        self.g.declare(DataDecl {
            name: name.into(),
            kind: ContainerKind::Array,
            vtype,
            shape,
            storage: Storage::Bram,
            transient: true,
        });
        self
    }

    /// Declare a stream (FIFO) container.
    pub fn stream(&mut self, name: &str, vtype: VecType, depth: usize) -> &mut Self {
        self.g.declare(DataDecl {
            name: name.into(),
            kind: ContainerKind::Stream,
            vtype,
            shape: vec![],
            storage: Storage::Stream { depth },
            transient: true,
        });
        self
    }

    pub fn access(&mut self, data: &str) -> NodeId {
        assert!(
            self.g.containers.contains_key(data),
            "access to undeclared container '{data}'"
        );
        self.g.add_node(Node::Access { data: data.into() })
    }

    /// Open a map scope; returns (entry, exit).
    pub fn map(
        &mut self,
        name: &str,
        params: &[&str],
        ranges: Vec<Range>,
        schedule: MapSchedule,
    ) -> (NodeId, NodeId) {
        assert_eq!(params.len(), ranges.len());
        for r in &ranges {
            for s in r.begin.symbols().into_iter().chain(r.end.symbols()) {
                if !params.contains(&s.as_str()) {
                    self.g.add_symbol(&s);
                }
            }
        }
        let entry = self.g.add_node(Node::MapEntry {
            name: name.into(),
            params: params.iter().map(|s| s.to_string()).collect(),
            ranges,
            schedule,
        });
        let exit = self.g.add_node(Node::MapExit { entry: name.into() });
        (entry, exit)
    }

    pub fn tasklet(&mut self, t: Tasklet) -> NodeId {
        self.g.add_node(Node::Tasklet(t))
    }

    /// Shorthand: single-output tasklet.
    pub fn tasklet1(&mut self, name: &str, out_conn: &str, expr: TaskExpr) -> NodeId {
        self.tasklet(Tasklet::new(name, vec![(out_conn, expr)]))
    }

    pub fn library(&mut self, name: &str, op: LibraryOp) -> NodeId {
        self.g.add_node(Node::Library { name: name.into(), op })
    }

    pub fn edge(&mut self, src: NodeId, dst: NodeId, m: Memlet) -> &mut Self {
        self.g.add_edge(src, dst, m);
        self
    }

    /// Connect an access node through a map entry to a tasklet input:
    /// the outer memlet carries the full per-map subset, the inner one
    /// the per-iteration element.
    pub fn feed(
        &mut self,
        access: NodeId,
        entry: NodeId,
        tasklet: NodeId,
        data: &str,
        outer: Subset,
        inner: Subset,
        conn: &str,
    ) -> &mut Self {
        self.g.add_edge(access, entry, Memlet::new(data, outer));
        self.g
            .add_edge(entry, tasklet, Memlet { ..Memlet::new(data, inner).with_dst(conn) });
        self
    }

    /// Connect a tasklet output through a map exit to an access node.
    pub fn drain(
        &mut self,
        tasklet: NodeId,
        exit: NodeId,
        access: NodeId,
        data: &str,
        inner: Subset,
        outer: Subset,
        conn: &str,
    ) -> &mut Self {
        self.g.add_edge(tasklet, exit, Memlet::new(data, inner).with_src(conn));
        self.g.add_edge(exit, access, Memlet::new(data, outer));
        self
    }

    /// Wrap the whole graph in an outer sequential loop.
    pub fn repeat(&mut self, param: &str, range: Range) -> &mut Self {
        self.g.repeat = Some(super::graph::SequentialRepeat {
            param: param.to_string(),
            range,
        });
        self
    }

    pub fn finish(self) -> Sdfg {
        self.g
    }

    pub fn graph(&self) -> &Sdfg {
        &self.g
    }
}

/// Convenience constructor for the canonical running example of the
/// paper (§3.2): `z = x + y` over N elements, pipelined map. Used by
/// tests, the quickstart example and Table 2.
pub fn vecadd_sdfg(lanes: usize) -> Sdfg {
    let mut b = GraphBuilder::new(if lanes == 1 { "vecadd" } else { "vecadd_vec" });
    let vt = VecType::of(DType::F32, lanes);
    b.array("x", vt, vec![Expr::sym("N")]);
    b.array("y", vt, vec![Expr::sym("N")]);
    b.array("z", vt, vec![Expr::sym("N")]);
    let x = b.access("x");
    let y = b.access("y");
    let z = b.access("z");
    let (me, mx) = b.map("vadd", &["i"], vec![Range::upto_sym("N")], MapSchedule::Pipeline);
    let t = b.tasklet1("add", "out", TaskExpr::input("a").add(TaskExpr::input("b")));
    let all = Subset::new(vec![Range::upto_sym("N")]);
    let elem = Subset::index1(Expr::sym("i"));
    b.feed(x, me, t, "x", all.clone(), elem.clone(), "a");
    b.feed(y, me, t, "y", all.clone(), elem.clone(), "b");
    b.drain(t, mx, z, "z", elem, all, "out");
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vecadd_shape() {
        let g = vecadd_sdfg(1);
        assert_eq!(g.nodes.len(), 6); // 3 access + entry + tasklet + exit
        assert_eq!(g.edges.len(), 6);
        assert_eq!(g.symbols, vec!["N".to_string()]);
        assert!(g.topo_order().is_ok());
        assert_eq!(g.external_accesses().len(), 3);
    }

    #[test]
    fn vectorized_vecadd_types() {
        let g = vecadd_sdfg(4);
        assert_eq!(g.container("x").unwrap().vtype.lanes, 4);
        // distinct HBM banks per container (paper §4 configuration)
        let banks: Vec<usize> = ["x", "y", "z"]
            .iter()
            .map(|n| match g.container(n).unwrap().storage {
                Storage::Hbm { bank } => bank,
                _ => panic!(),
            })
            .collect();
        assert_eq!(banks, vec![0, 1, 2]);
    }

    #[test]
    #[should_panic(expected = "undeclared container")]
    fn undeclared_access_panics() {
        let mut b = GraphBuilder::new("bad");
        b.access("nope");
    }

    #[test]
    fn stream_decl() {
        let mut b = GraphBuilder::new("s");
        b.stream("q", VecType::scalar(DType::F32), 16);
        let g = b.finish();
        let d = g.container("q").unwrap();
        assert!(d.storage.is_stream());
        assert!(d.transient);
    }
}
