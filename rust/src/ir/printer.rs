//! Text and Graphviz rendering of SDFGs (debugging / documentation).

use super::graph::Sdfg;
use super::node::Node;

/// Compact textual dump: containers, then nodes, then edges.
pub fn to_text(g: &Sdfg) -> String {
    let mut s = format!("sdfg {} {{\n", g.name);
    if !g.symbols.is_empty() {
        s.push_str(&format!("  symbols: {}\n", g.symbols.join(", ")));
    }
    if let Some(r) = &g.repeat {
        s.push_str(&format!("  repeat {} in {}\n", r.param, r.range));
    }
    for (name, d) in &g.containers {
        s.push_str(&format!(
            "  {} {}: {}x{} lanes={} @{:?}{}\n",
            match d.kind {
                super::types::ContainerKind::Array => "array",
                super::types::ContainerKind::Stream => "stream",
                super::types::ContainerKind::Scalar => "scalar",
            },
            name,
            d.shape.iter().map(|e| e.to_string()).collect::<Vec<_>>().join("x"),
            d.vtype.base.name(),
            d.vtype.lanes,
            d.storage,
            if d.transient { " transient" } else { "" },
        ));
    }
    for id in g.node_ids() {
        s.push_str(&format!("  n{}: {}\n", id.0, describe(g.node(id))));
    }
    for eid in g.edge_ids() {
        let e = g.edge(eid);
        s.push_str(&format!(
            "  n{} -> n{} : {}{}{}\n",
            e.src.0,
            e.dst.0,
            e.memlet.label(),
            e.memlet
                .src_conn
                .as_ref()
                .map(|c| format!(" src={c}"))
                .unwrap_or_default(),
            e.memlet
                .dst_conn
                .as_ref()
                .map(|c| format!(" dst={c}"))
                .unwrap_or_default(),
        ));
    }
    s.push_str("}\n");
    s
}

fn describe(n: &Node) -> String {
    match n {
        Node::Access { data } => format!("access {data}"),
        Node::MapEntry { name, params, ranges, schedule } => format!(
            "map {name} [{}] {:?}",
            params
                .iter()
                .zip(ranges)
                .map(|(p, r)| format!("{p}={r}"))
                .collect::<Vec<_>>()
                .join(", "),
            schedule
        ),
        Node::MapExit { entry } => format!("endmap {entry}"),
        Node::Tasklet(t) => format!(
            "tasklet {} ({} -> {})",
            t.name,
            t.input_connectors().join(","),
            t.output_connectors().join(",")
        ),
        Node::Library { name, op } => format!("library {name} ({})", op.name()),
        Node::Reader { name, data, stream } => format!("reader {name}: {data} -> {stream}"),
        Node::Writer { name, data, stream } => format!("writer {name}: {stream} -> {data}"),
        Node::Cdc { name, kind, input, output, factor } => {
            format!("cdc {name} ({}, M={factor}): {input} -> {output}", kind.name())
        }
    }
}

/// Graphviz dot output.
pub fn to_dot(g: &Sdfg) -> String {
    let mut s = format!("digraph \"{}\" {{\n  rankdir=TB;\n", g.name);
    for id in g.node_ids() {
        let (shape, label) = match g.node(id) {
            Node::Access { data } => ("ellipse", data.clone()),
            Node::MapEntry { name, .. } => ("trapezium", format!("{name} entry")),
            Node::MapExit { entry } => ("invtrapezium", format!("{entry} exit")),
            Node::Tasklet(t) => ("box", t.name.clone()),
            Node::Library { name, .. } => ("component", name.clone()),
            Node::Reader { name, .. } => ("cds", name.clone()),
            Node::Writer { name, .. } => ("cds", name.clone()),
            Node::Cdc { name, .. } => ("hexagon", name.clone()),
        };
        s.push_str(&format!("  n{} [shape={shape}, label=\"{label}\"];\n", id.0));
    }
    for eid in g.edge_ids() {
        let e = g.edge(eid);
        s.push_str(&format!(
            "  n{} -> n{} [label=\"{}\"];\n",
            e.src.0,
            e.dst.0,
            e.memlet.label().replace('"', "'")
        ));
    }
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::vecadd_sdfg;

    #[test]
    fn text_mentions_everything() {
        let t = to_text(&vecadd_sdfg(2));
        for needle in ["sdfg vecadd_vec", "array x", "map vadd", "tasklet add", "z[i]"] {
            assert!(t.contains(needle), "missing {needle} in:\n{t}");
        }
    }

    #[test]
    fn dot_is_wellformed() {
        let d = to_dot(&vecadd_sdfg(1));
        assert!(d.starts_with("digraph"));
        assert!(d.contains("trapezium"));
        assert!(d.trim_end().ends_with('}'));
    }
}
