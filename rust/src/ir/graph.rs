//! The dataflow graph (single-state SDFG analog).

use std::collections::BTreeMap;

use super::memlet::Memlet;
use super::node::Node;
use super::types::DataDecl;
use crate::symbolic::Range;

/// Typed node index.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct NodeId(pub usize);

/// Typed edge index.
#[derive(Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord, Debug)]
pub struct EdgeId(pub usize);

/// A directed edge with its memlet annotation.
#[derive(Clone, Debug)]
pub struct Edge {
    pub src: NodeId,
    pub dst: NodeId,
    pub memlet: Memlet,
}

/// Outer sequential loop wrapper (e.g. the `k` loop of Floyd–Warshall):
/// the whole dataflow graph executes once per value of `param`.
#[derive(Clone, Debug)]
pub struct SequentialRepeat {
    pub param: String,
    pub range: Range,
}

/// A symbol derived from another by exact division, introduced by the
/// vectorization / multi-pumping rewrites when a symbolic extent is
/// divided (`N` → `N_div_4` with the invariant `N_div_4 = N / 4`).
/// [`Sdfg::bind`] resolves these automatically.
#[derive(Clone, Debug)]
pub struct DerivedSymbol {
    pub name: String,
    pub base: String,
    pub divisor: i64,
}

/// How a fast clock domain relates to the data widths around it (§2.1
/// plus the dace exemplar's third scenario).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum PumpMode {
    /// Inwards: internal width ÷ M, same throughput, resources cut
    /// (waveform ③).
    Resource,
    /// Outwards: external width × M, M× throughput, same compute
    /// (waveform ②).
    Throughput,
    /// Gearbox-free fast clocking (the dace exemplar's TODO'd
    /// "approach 3"): no width change on either side, zero
    /// packer/issuer modules — the fast clock recovers the initiation
    /// interval of a dependent pipeline, so an II = 2 region behaves
    /// as II = 1 seen from the slow domain at M = 2.
    BareFast,
}

impl PumpMode {
    /// Long name used in CLI flags and error messages.
    pub fn name(&self) -> &'static str {
        match self {
            PumpMode::Resource => "resource",
            PumpMode::Throughput => "throughput",
            PumpMode::BareFast => "barefast",
        }
    }

    /// Single-letter tag used in design-point labels, fingerprints and
    /// telemetry domain labels.
    pub fn letter(&self) -> char {
        match self {
            PumpMode::Resource => 'r',
            PumpMode::Throughput => 't',
            PumpMode::BareFast => 'b',
        }
    }
}

/// One region's pump assignment: clock ratio plus the width mode the
/// region's crossings are built for. The unified per-region currency —
/// the DSE space, `BuildSpec`, the transform and `MultipumpInfo` all
/// carry `RegionPump`s rather than a global mode + bare factors.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub struct RegionPump {
    pub factor: usize,
    pub mode: PumpMode,
}

impl RegionPump {
    pub fn resource(factor: usize) -> RegionPump {
        RegionPump { factor, mode: PumpMode::Resource }
    }

    pub fn new(factor: usize, mode: PumpMode) -> RegionPump {
        RegionPump { factor, mode }
    }

    /// Label fragment: resource factors stay bare (`4`) for continuity
    /// with the pre-mode encodings; other modes prefix their letter
    /// (`t4`, `b2`).
    pub fn tag(&self) -> String {
        match self.mode {
            PumpMode::Resource => format!("{}", self.factor),
            m => format!("{}{}", m.letter(), self.factor),
        }
    }
}

/// One pumped region: a set of nodes sharing a fast clock domain at
/// `factor` × CL0, in `mode`. The whole-graph transformation produces
/// a single region (the paper's §3.4 largest-streamable-subgraph
/// choice); the per-region transformation produces one region per
/// distinct `RegionPump` assignment.
#[derive(Clone, Debug)]
pub struct PumpedRegion {
    pub factor: usize,
    pub mode: PumpMode,
    /// Nodes placed in this region's fast clock domain.
    pub nodes: Vec<NodeId>,
}

/// Record of an applied multi-pumping transformation: the list of
/// pumped regions, each with its own factor and mode. Uniform
/// (whole-graph) pumping is the single-region special case.
#[derive(Clone, Debug)]
pub struct MultipumpInfo {
    pub regions: Vec<PumpedRegion>,
}

impl MultipumpInfo {
    /// A single region covering the whole compute subgraph — the
    /// legacy whole-graph transformation's shape.
    pub fn uniform(factor: usize, mode: PumpMode, fast_nodes: Vec<NodeId>) -> MultipumpInfo {
        MultipumpInfo { regions: vec![PumpedRegion { factor, mode, nodes: fast_nodes }] }
    }

    /// The largest pump factor across regions — the ratio of the
    /// fastest fast clock to CL0 (drives the global fast time base of
    /// the exact simulator and the reported `pump_factor`).
    pub fn max_factor(&self) -> usize {
        self.regions.iter().map(|r| r.factor).max().unwrap_or(1)
    }

    /// The mode of the largest-factor region — the representative tag
    /// a whole-design `pump` field reports. Per-node decisions must use
    /// [`MultipumpInfo::mode_of`] instead.
    pub fn representative_mode(&self) -> PumpMode {
        self.regions
            .iter()
            .max_by_key(|r| r.factor)
            .map(|r| r.mode)
            .unwrap_or(PumpMode::Resource)
    }

    /// The pump factor of the region containing `id`, if any.
    pub fn factor_of(&self, id: NodeId) -> Option<usize> {
        self.regions.iter().find(|r| r.nodes.contains(&id)).map(|r| r.factor)
    }

    /// The pump mode of the region containing `id`, if any.
    pub fn mode_of(&self, id: NodeId) -> Option<PumpMode> {
        self.regions.iter().find(|r| r.nodes.contains(&id)).map(|r| r.mode)
    }

    /// More than one fast clock domain?
    pub fn is_mixed(&self) -> bool {
        self.regions.len() > 1
    }
}

/// The dataflow program: containers, symbols, nodes, edges, and an
/// optional outer sequential repetition.
#[derive(Clone, Debug, Default)]
pub struct Sdfg {
    pub name: String,
    pub containers: BTreeMap<String, DataDecl>,
    /// Free symbols (problem sizes) with optional documentation.
    pub symbols: Vec<String>,
    pub nodes: Vec<Node>,
    pub edges: Vec<Edge>,
    pub repeat: Option<SequentialRepeat>,
    /// Division-derived symbols introduced by transformations.
    pub derived: Vec<DerivedSymbol>,
    /// Set when the multi-pumping transformation has been applied.
    pub multipump: Option<MultipumpInfo>,
}

impl Sdfg {
    pub fn new(name: &str) -> Self {
        Sdfg { name: name.to_string(), ..Default::default() }
    }

    pub fn add_node(&mut self, node: Node) -> NodeId {
        self.nodes.push(node);
        NodeId(self.nodes.len() - 1)
    }

    pub fn add_edge(&mut self, src: NodeId, dst: NodeId, memlet: Memlet) -> EdgeId {
        assert!(src.0 < self.nodes.len() && dst.0 < self.nodes.len());
        self.edges.push(Edge { src, dst, memlet });
        EdgeId(self.edges.len() - 1)
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id.0]
    }

    pub fn edge(&self, id: EdgeId) -> &Edge {
        &self.edges[id.0]
    }

    pub fn edge_mut(&mut self, id: EdgeId) -> &mut Edge {
        &mut self.edges[id.0]
    }

    pub fn node_ids(&self) -> impl Iterator<Item = NodeId> {
        (0..self.nodes.len()).map(NodeId)
    }

    pub fn edge_ids(&self) -> impl Iterator<Item = EdgeId> {
        (0..self.edges.len()).map(EdgeId)
    }

    /// Remove edges not satisfying the predicate. Invalidates all
    /// previously-held [`EdgeId`]s (node ids stay stable — nodes are
    /// never removed; rewrites orphan them instead).
    pub fn retain_edges<F: FnMut(&Edge) -> bool>(&mut self, f: F) {
        self.edges.retain(f);
    }

    pub fn in_edges(&self, id: NodeId) -> Vec<EdgeId> {
        self.edge_ids().filter(|e| self.edge(*e).dst == id).collect()
    }

    pub fn out_edges(&self, id: NodeId) -> Vec<EdgeId> {
        self.edge_ids().filter(|e| self.edge(*e).src == id).collect()
    }

    pub fn container(&self, name: &str) -> Option<&DataDecl> {
        self.containers.get(name)
    }

    pub fn declare(&mut self, decl: DataDecl) {
        assert!(
            !self.containers.contains_key(&decl.name),
            "container '{}' already declared",
            decl.name
        );
        self.containers.insert(decl.name.clone(), decl);
    }

    pub fn add_symbol(&mut self, s: &str) {
        if !self.symbols.iter().any(|x| x == s) {
            self.symbols.push(s.to_string());
        }
    }

    /// Find the access nodes referring to non-transient containers —
    /// the program's external interface.
    pub fn external_accesses(&self) -> Vec<NodeId> {
        self.node_ids()
            .filter(|id| match self.node(*id) {
                Node::Access { data } => {
                    self.containers.get(data).map(|d| !d.transient).unwrap_or(false)
                }
                _ => false,
            })
            .collect()
    }

    /// Map-entry node for a named map.
    pub fn find_map_entry(&self, name: &str) -> Option<NodeId> {
        self.node_ids().find(|id| match self.node(*id) {
            Node::MapEntry { name: n, .. } => n == name,
            _ => false,
        })
    }

    /// Matching exit for a map entry.
    pub fn find_map_exit(&self, entry_name: &str) -> Option<NodeId> {
        self.node_ids().find(|id| match self.node(*id) {
            Node::MapExit { entry } => entry == entry_name,
            _ => false,
        })
    }

    /// Nodes strictly inside a map scope (between entry and exit),
    /// found by forward reachability from the entry without passing the
    /// exit.
    pub fn scope_nodes(&self, entry: NodeId) -> Vec<NodeId> {
        let exit = match self.node(entry) {
            Node::MapEntry { name, .. } => self.find_map_exit(name),
            _ => None,
        };
        let mut seen = vec![false; self.nodes.len()];
        let mut stack = vec![entry];
        seen[entry.0] = true;
        let mut out = Vec::new();
        while let Some(n) = stack.pop() {
            for e in self.out_edges(n) {
                let d = self.edge(e).dst;
                if Some(d) == exit || seen[d.0] {
                    continue;
                }
                seen[d.0] = true;
                out.push(d);
                stack.push(d);
            }
        }
        out.sort();
        out
    }

    /// Build a full symbol table from base bindings, resolving derived
    /// symbols (errors if a derived division is not exact).
    pub fn bind(&self, base: &[(&str, i64)]) -> Result<crate::symbolic::SymbolTable, String> {
        let mut env = crate::symbolic::SymbolTable::new();
        for (s, v) in base {
            env.set(s, *v);
        }
        // derived symbols may chain; iterate to fixpoint
        let mut remaining: Vec<&DerivedSymbol> = self.derived.iter().collect();
        while !remaining.is_empty() {
            let before = remaining.len();
            remaining.retain(|d| {
                if let Some(b) = env.get(&d.base) {
                    if b % d.divisor != 0 {
                        // leave in place; reported below
                        return true;
                    }
                    env.set(&d.name, b / d.divisor);
                    false
                } else {
                    true
                }
            });
            if remaining.len() == before {
                let d = remaining[0];
                return Err(match env.get(&d.base) {
                    Some(b) => format!(
                        "derived symbol {}: {} = {b} not divisible by {}",
                        d.name, d.base, d.divisor
                    ),
                    None => format!("derived symbol {}: base '{}' unbound", d.name, d.base),
                });
            }
        }
        Ok(env)
    }

    /// Is a node in a fast (multi-pumped) clock domain?
    pub fn in_fast_domain(&self, id: NodeId) -> bool {
        self.fast_factor_of(id).is_some()
    }

    /// The pump factor of the fast domain containing `id`, if any.
    pub fn fast_factor_of(&self, id: NodeId) -> Option<usize> {
        self.multipump.as_ref().and_then(|mp| mp.factor_of(id))
    }

    /// The pump mode of the fast domain containing `id`, if any.
    pub fn fast_mode_of(&self, id: NodeId) -> Option<PumpMode> {
        self.multipump.as_ref().and_then(|mp| mp.mode_of(id))
    }

    /// Topological order of all nodes (errors on cycles).
    pub fn topo_order(&self) -> Result<Vec<NodeId>, String> {
        let n = self.nodes.len();
        let mut indeg = vec![0usize; n];
        for e in &self.edges {
            indeg[e.dst.0] += 1;
        }
        let mut queue: Vec<NodeId> =
            (0..n).filter(|i| indeg[*i] == 0).map(NodeId).collect();
        let mut out = Vec::with_capacity(n);
        while let Some(id) = queue.pop() {
            out.push(id);
            for e in self.out_edges(id) {
                let d = self.edge(e).dst;
                indeg[d.0] -= 1;
                if indeg[d.0] == 0 {
                    queue.push(d);
                }
            }
        }
        if out.len() == n {
            Ok(out)
        } else {
            Err(format!("graph '{}' contains a cycle", self.name))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::node::MapSchedule;
    use crate::ir::tasklet::{TaskExpr, Tasklet};
    use crate::ir::types::{ContainerKind, DType, Storage, VecType};
    use crate::symbolic::{Expr, Subset};

    fn decl(name: &str) -> DataDecl {
        DataDecl {
            name: name.into(),
            kind: ContainerKind::Array,
            vtype: VecType::scalar(DType::F32),
            shape: vec![Expr::sym("N")],
            storage: Storage::Hbm { bank: 0 },
            transient: false,
        }
    }

    /// x --> map_entry --> tasklet --> map_exit --> z
    fn tiny() -> (Sdfg, NodeId, NodeId, NodeId, NodeId, NodeId) {
        let mut g = Sdfg::new("tiny");
        g.declare(decl("x"));
        g.declare(decl("z"));
        g.add_symbol("N");
        let x = g.add_node(Node::Access { data: "x".into() });
        let z = g.add_node(Node::Access { data: "z".into() });
        let me = g.add_node(Node::MapEntry {
            name: "m".into(),
            params: vec!["i".into()],
            ranges: vec![crate::symbolic::Range::upto_sym("N")],
            schedule: MapSchedule::Pipeline,
        });
        let t = g.add_node(Node::Tasklet(Tasklet::new(
            "copy",
            vec![("out", TaskExpr::input("in"))],
        )));
        let mx = g.add_node(Node::MapExit { entry: "m".into() });
        g.add_edge(x, me, Memlet::new("x", Subset::new(vec![crate::symbolic::Range::upto_sym("N")])));
        g.add_edge(me, t, Memlet::element("x", Expr::sym("i")).with_dst("in"));
        g.add_edge(t, mx, Memlet::element("z", Expr::sym("i")).with_src("out"));
        g.add_edge(mx, z, Memlet::new("z", Subset::new(vec![crate::symbolic::Range::upto_sym("N")])));
        (g, x, z, me, t, mx)
    }

    #[test]
    fn edges_and_queries() {
        let (g, x, z, me, t, mx) = tiny();
        assert_eq!(g.out_edges(x).len(), 1);
        assert_eq!(g.in_edges(z).len(), 1);
        assert_eq!(g.find_map_entry("m"), Some(me));
        assert_eq!(g.find_map_exit("m"), Some(mx));
        assert_eq!(g.scope_nodes(me), vec![t]);
        assert_eq!(g.external_accesses(), vec![x, z]);
    }

    #[test]
    fn topo_order_linear() {
        let (g, x, z, me, t, mx) = tiny();
        let order = g.topo_order().unwrap();
        let pos = |id: NodeId| order.iter().position(|n| *n == id).unwrap();
        assert!(pos(x) < pos(me));
        assert!(pos(me) < pos(t));
        assert!(pos(t) < pos(mx));
        assert!(pos(mx) < pos(z));
    }

    #[test]
    fn cycle_detected() {
        let (mut g, x, _, me, _, _) = tiny();
        g.add_edge(me, x, Memlet::new("x", Subset::all1(1)));
        assert!(g.topo_order().is_err());
    }

    #[test]
    #[should_panic(expected = "already declared")]
    fn duplicate_container_panics() {
        let mut g = Sdfg::new("dup");
        g.declare(decl("x"));
        g.declare(decl("x"));
    }
}
