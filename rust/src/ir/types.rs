//! Data types, container declarations and storage locations.

use crate::symbolic::Expr;

/// Element data types used by the evaluation (f32 everywhere in the
/// paper; integers appear in index computations and tests).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum DType {
    F32,
    I32,
    U8,
}

impl DType {
    pub fn bytes(&self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::U8 => 1,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DType::F32 => "float",
            DType::I32 => "int",
            DType::U8 => "unsigned char",
        }
    }
}

/// A (possibly) vectorized element type: `lanes` elements of `base` per
/// transaction. Traditional vectorization raises `lanes`; multi-pumping
/// in resource mode *lowers* the internal lanes while the external
/// lanes stay wide.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub struct VecType {
    pub base: DType,
    pub lanes: usize,
}

impl VecType {
    pub fn scalar(base: DType) -> Self {
        VecType { base, lanes: 1 }
    }

    pub fn of(base: DType, lanes: usize) -> Self {
        assert!(lanes >= 1);
        VecType { base, lanes }
    }

    pub fn bits(&self) -> usize {
        self.base.bytes() * 8 * self.lanes
    }

    pub fn bytes(&self) -> usize {
        self.base.bytes() * self.lanes
    }

    pub fn cpp_name(&self) -> String {
        if self.lanes == 1 {
            self.base.name().to_string()
        } else {
            format!("hlslib::DataPack<{}, {}>", self.base.name(), self.lanes)
        }
    }
}

/// Where a container lives. The paper's configuration maps each global
/// array to its own HBM bank (§4: "Direct access to HBM banks").
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum Storage {
    /// Off-chip HBM; `bank` is the exclusive bank index.
    Hbm { bank: usize },
    /// On-chip block RAM (line buffers, tiles).
    Bram,
    /// FIFO stream between modules.
    Stream { depth: usize },
    /// Single register value.
    Register,
}

impl Storage {
    pub fn is_stream(&self) -> bool {
        matches!(self, Storage::Stream { .. })
    }

    pub fn is_offchip(&self) -> bool {
        matches!(self, Storage::Hbm { .. })
    }
}

/// Random-access array vs. FIFO vs. scalar — the container kind
/// determines which access patterns are legal.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum ContainerKind {
    Array,
    Stream,
    Scalar,
}

/// Declaration of a named data container.
#[derive(Clone, Debug)]
pub struct DataDecl {
    pub name: String,
    pub kind: ContainerKind,
    pub vtype: VecType,
    /// Symbolic shape (elements of `vtype`, i.e. vectors not scalars).
    pub shape: Vec<Expr>,
    pub storage: Storage,
    /// Is this container visible outside the SDFG (kernel argument)?
    pub transient: bool,
}

impl DataDecl {
    /// Total bytes under concrete bindings (None if symbolic).
    pub fn bytes(&self, env: &crate::symbolic::SymbolTable) -> Option<usize> {
        let mut n: i64 = 1;
        for d in &self.shape {
            n = n.checked_mul(d.eval(env)?)?;
        }
        Some(n as usize * self.vtype.bytes())
    }
}

/// Clock domain tag on modules of a design. `Slow` is the shell clock
/// CL0; `Fast { factor }` is the multi-pumped domain CL1 = factor·CL0.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum ClockDomain {
    Slow,
    Fast { factor: usize },
}

impl ClockDomain {
    pub fn factor(&self) -> usize {
        match self {
            ClockDomain::Slow => 1,
            ClockDomain::Fast { factor } => *factor,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::symbolic::SymbolTable;

    #[test]
    fn vectype_sizes() {
        let v = VecType::of(DType::F32, 16);
        assert_eq!(v.bits(), 512);
        assert_eq!(v.bytes(), 64);
        assert_eq!(VecType::scalar(DType::U8).bits(), 8);
    }

    #[test]
    fn cpp_names() {
        assert_eq!(VecType::scalar(DType::F32).cpp_name(), "float");
        assert!(VecType::of(DType::F32, 4).cpp_name().contains("DataPack<float, 4>"));
    }

    #[test]
    fn decl_bytes() {
        let d = DataDecl {
            name: "x".into(),
            kind: ContainerKind::Array,
            vtype: VecType::of(DType::F32, 4),
            shape: vec![Expr::sym("N")],
            storage: Storage::Hbm { bank: 0 },
            transient: false,
        };
        let env = SymbolTable::new().with("N", 100);
        assert_eq!(d.bytes(&env), Some(100 * 16));
        assert_eq!(d.bytes(&SymbolTable::new()), None);
    }

    #[test]
    fn clock_domain_factor() {
        assert_eq!(ClockDomain::Slow.factor(), 1);
        assert_eq!(ClockDomain::Fast { factor: 2 }.factor(), 2);
    }
}
