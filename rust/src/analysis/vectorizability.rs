//! Vectorizability: traditional SIMD vs. relaxed temporal conditions.
//!
//! Paper §3.2: *"we build upon techniques used by compiler
//! auto-vectorizers. Therefore, the same conditions that apply to
//! SIMD-capable code apply to temporally vectorizable [...] Moreover,
//! temporal vectorization is slightly more relaxed than the traditional
//! vectorization — as the instructions run in sequence (albeit faster),
//! internal sequential dependencies across data are allowed. The only
//! restriction is that the participating operations must not involve
//! data-dependent external memory I/O based on previous operations."*
//!
//! [`check_traditional`] enforces the strict SIMD conditions (linear
//! unit-stride accesses, divisible extent, **no loop-carried
//! dependencies**). [`check_temporal`] drops the dependency condition —
//! exactly the relaxation that lets Floyd–Warshall be multi-pumped.

use super::movement::ScopeMovement;
use super::streamability::{streamable_access, Streamability};
use crate::ir::{MapSchedule, Node, Sdfg};
use crate::symbolic::SymbolTable;

/// Verdict with the reasons collected.
#[derive(Clone, Debug, PartialEq)]
pub enum Vectorizability {
    Ok,
    Rejected(Vec<String>),
}

impl Vectorizability {
    pub fn is_ok(&self) -> bool {
        matches!(self, Vectorizability::Ok)
    }

    pub fn reasons(&self) -> &[String] {
        match self {
            Vectorizability::Ok => &[],
            Vectorizability::Rejected(r) => r,
        }
    }
}

/// Detect loop-carried dependencies in a scope: some container is both
/// read and written by the scope with subsets that can touch different
/// iterations (e.g. FW reads `dist[i,k]` while writing `dist[i,j]`, or
/// a scan reads `x[i-1]` and writes `x[i]`).
pub fn has_loop_carried_dependency(mv: &ScopeMovement, env: &SymbolTable) -> bool {
    for w in &mv.writes {
        for r in &mv.reads {
            if w.data != r.data {
                continue;
            }
            // identical subset every iteration (pure elementwise) is fine
            if let Some(true) = w.subset.same_as(&r.subset) {
                continue;
            }
            // provably disjoint at every pair of iterations is fine only
            // if disjoint for the *whole* range; we check the subsets as
            // whole-range footprints when concrete, else conservative.
            match w.subset.intersects(&r.subset, env) {
                Some(false) => continue,
                _ => return true,
            }
        }
    }
    false
}

fn common_checks(g: &Sdfg, mv: &ScopeMovement, v: usize, reasons: &mut Vec<String>) {
    let param = mv.inner_param();

    // all external accesses must be linear (parallelizable source/dest);
    // stream (FIFO) accesses are in-order by construction
    for acc in mv.all() {
        let is_stream = g
            .container(&acc.data)
            .map(|d| d.kind == crate::ir::ContainerKind::Stream)
            .unwrap_or(false);
        if is_stream {
            if acc.dynamic {
                reasons.push(format!("stream access to '{}' is data-dependent", acc.data));
            }
            continue;
        }
        if let Streamability::Blocked(r) = streamable_access(acc, param) {
            reasons.push(r);
        }
    }

    // the map range must be divisible by the factor
    if let Node::MapEntry { ranges, schedule, .. } = g.node(mv.entry) {
        if *schedule == MapSchedule::Sequential {
            reasons.push("scope is scheduled sequentially".into());
        }
        let inner = ranges.last().expect("map without ranges");
        if inner.step != 1 {
            reasons.push(format!("inner range has non-unit step {}", inner.step));
        }
        if v > 1 && inner.divide_extent(v as i64).is_none() {
            reasons.push(format!(
                "extent of {inner} not divisible by factor {v} (symbolically)"
            ));
        }
    } else {
        reasons.push("scope entry is not a map".into());
    }

    // no data-dependent external memory I/O — the one restriction that
    // also applies to the temporal case
    if mv.any_dynamic() {
        reasons.push("scope performs data-dependent external memory I/O".into());
    }
}

/// Traditional SIMD vectorization check with factor `v`.
pub fn check_traditional(
    g: &Sdfg,
    mv: &ScopeMovement,
    v: usize,
    env: &SymbolTable,
) -> Vectorizability {
    let mut reasons = Vec::new();
    common_checks(g, mv, v, &mut reasons);
    if has_loop_carried_dependency(mv, env) {
        reasons.push("loop-carried dependency between iterations".into());
    }
    if reasons.is_empty() {
        Vectorizability::Ok
    } else {
        Vectorizability::Rejected(reasons)
    }
}

/// Relaxed *temporal* vectorization check with factor `v`: identical to
/// the traditional one except loop-carried dependencies are allowed
/// (the computation runs sequentially inside the fast domain). Note the
/// sequential-schedule rejection is also lifted: a dependent pipeline
/// can still be fed temporally.
pub fn check_temporal(g: &Sdfg, mv: &ScopeMovement, v: usize) -> Vectorizability {
    let mut reasons = Vec::new();
    common_checks(g, mv, v, &mut reasons);
    // drop the sequential-schedule objection: temporal vectorization
    // tolerates dependent computations (paper §2.1, §4.4)
    reasons.retain(|r| r != "scope is scheduled sequentially");
    if reasons.is_empty() {
        Vectorizability::Ok
    } else {
        Vectorizability::Rejected(reasons)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::movement::{scope_movement, ScopeMovement, TracedAccess};
    use crate::ir::builder::vecadd_sdfg;
    use crate::ir::NodeId;
    use crate::symbolic::{Expr, Subset};

    #[test]
    fn vecadd_passes_both() {
        let g = vecadd_sdfg(1);
        let entry = g.find_map_entry("vadd").unwrap();
        let mv = scope_movement(&g, entry).unwrap();
        let env = SymbolTable::new().with("N", 1024);
        // factor 1 trivially OK; factor 4 requires divisible extent —
        // symbolic N is rejected (strict), so test with a concrete graph
        assert!(check_traditional(&g, &mv, 1, &env).is_ok());
        assert!(check_temporal(&g, &mv, 1).is_ok());
    }

    fn scan_movement() -> ScopeMovement {
        // x[i] = x[i] + x[i-1]: read x[i-1] & x[i], write x[i]
        ScopeMovement {
            entry: NodeId(0),
            params: vec!["i".into()],
            reads: vec![
                TracedAccess {
                    data: "x".into(),
                    subset: Subset::index1(Expr::sym("i").sub(&Expr::int(1))),
                    is_read: true,
                    dynamic: false,
                },
                TracedAccess {
                    data: "x".into(),
                    subset: Subset::index1(Expr::sym("i")),
                    is_read: true,
                    dynamic: false,
                },
            ],
            writes: vec![TracedAccess {
                data: "x".into(),
                subset: Subset::index1(Expr::sym("i")),
                is_read: false,
                dynamic: false,
            }],
        }
    }

    #[test]
    fn loop_carried_dependency_detected() {
        let env = SymbolTable::new();
        assert!(has_loop_carried_dependency(&scan_movement(), &env));
        // pure elementwise is not loop-carried
        let elementwise = ScopeMovement {
            entry: NodeId(0),
            params: vec!["i".into()],
            reads: vec![TracedAccess {
                data: "x".into(),
                subset: Subset::index1(Expr::sym("i")),
                is_read: true,
                dynamic: false,
            }],
            writes: vec![TracedAccess {
                data: "x".into(),
                subset: Subset::index1(Expr::sym("i")),
                is_read: false,
                dynamic: false,
            }],
        };
        assert!(!has_loop_carried_dependency(&elementwise, &env));
    }

    #[test]
    fn temporal_relaxes_dependencies_but_not_dynamic_io() {
        // build a tiny graph whose map hosts the scan scope
        use crate::ir::{GraphBuilder, MapSchedule, Memlet, TaskExpr};
        use crate::symbolic::Range;
        let mut b = GraphBuilder::new("scan");
        b.array_f32("x", vec![Expr::sym("N")]);
        let xr = b.access("x");
        let xw = b.access("x");
        let (me, mx) = b.map("s", &["i"], vec![Range::new(Expr::int(1), Expr::sym("N"), 1)], MapSchedule::Pipeline);
        let t = b.tasklet1("acc", "out", TaskExpr::input("a").add(TaskExpr::input("b")));
        let all = Subset::new(vec![Range::upto_sym("N")]);
        b.edge(xr, me, Memlet::new("x", all.clone()));
        b.edge(me, t, Memlet::new("x", Subset::index1(Expr::sym("i"))).with_dst("a"));
        b.edge(me, t, Memlet::new("x", Subset::index1(Expr::sym("i").sub(&Expr::int(1)))).with_dst("b"));
        b.drain(t, mx, xw, "x", Subset::index1(Expr::sym("i")), all, "out");
        let g = b.finish();
        let mv = scope_movement(&g, g.find_map_entry("s").unwrap()).unwrap();
        let env = SymbolTable::new().with("N", 64);

        let trad = check_traditional(&g, &mv, 1, &env);
        assert!(!trad.is_ok());
        assert!(trad.reasons().iter().any(|r| r.contains("loop-carried")), "{trad:?}");

        // temporal: the dependency objection disappears
        assert!(check_temporal(&g, &mv, 1).is_ok());
    }

    #[test]
    fn dynamic_io_rejected_by_both() {
        let mut mv = scan_movement();
        mv.reads[0].dynamic = true;
        let g = vecadd_sdfg(1);
        // entry points at an access node; patch to the real map for the check
        let entry = g.find_map_entry("vadd").unwrap();
        mv.entry = entry;
        let env = SymbolTable::new();
        assert!(!check_traditional(&g, &mv, 1, &env).is_ok());
        let temporal = check_temporal(&g, &mv, 1);
        assert!(!temporal.is_ok());
        assert!(temporal
            .reasons()
            .iter()
            .any(|r| r.contains("data-dependent")));
    }

    #[test]
    fn divisibility_required_for_factor() {
        let g = vecadd_sdfg(1);
        let mv = scope_movement(&g, g.find_map_entry("vadd").unwrap()).unwrap();
        // symbolic N, factor 4 → rejected symbolically
        let v = check_temporal(&g, &mv, 4);
        assert!(!v.is_ok());
        assert!(v.reasons().iter().any(|r| r.contains("divisible")));
    }
}
