//! Data-movement tracing per computational scope.

use crate::ir::{Node, NodeId, Sdfg};
use crate::symbolic::Subset;

/// One traced external access of a scope.
#[derive(Clone, Debug)]
pub struct TracedAccess {
    /// The container accessed.
    pub data: String,
    /// Subset as a function of the scope parameter(s).
    pub subset: Subset,
    /// True for reads into the scope, false for writes out of it.
    pub is_read: bool,
    /// Dynamic (data-dependent) access.
    pub dynamic: bool,
}

/// All data movement of one map scope.
#[derive(Clone, Debug)]
pub struct ScopeMovement {
    pub entry: NodeId,
    pub params: Vec<String>,
    pub reads: Vec<TracedAccess>,
    pub writes: Vec<TracedAccess>,
}

impl ScopeMovement {
    /// All accesses (reads then writes).
    pub fn all(&self) -> impl Iterator<Item = &TracedAccess> {
        self.reads.iter().chain(self.writes.iter())
    }

    /// Innermost scope parameter (the pipelined iteration variable).
    pub fn inner_param(&self) -> &str {
        self.params.last().expect("scope has no parameters")
    }

    /// Does any access involve data-dependent addressing?
    pub fn any_dynamic(&self) -> bool {
        self.all().any(|a| a.dynamic)
    }
}

/// Trace the data movement of the map scope rooted at `entry`:
/// every memlet crossing the entry (reads) or the matching exit
/// (writes), with its symbolic subset.
pub fn scope_movement(g: &Sdfg, entry: NodeId) -> Result<ScopeMovement, String> {
    let (name, params) = match g.node(entry) {
        Node::MapEntry { name, params, .. } => (name.clone(), params.clone()),
        other => return Err(format!("node {entry:?} is not a map entry ({other:?})")),
    };
    let exit = g
        .find_map_exit(&name)
        .ok_or_else(|| format!("map '{name}' has no exit"))?;

    let mut reads = Vec::new();
    for e in g.out_edges(entry) {
        let m = &g.edge(e).memlet;
        reads.push(TracedAccess {
            data: m.data.clone(),
            subset: m.subset.clone(),
            is_read: true,
            dynamic: m.dynamic || m.subset.dims.iter().any(|d| d.begin.is_opaque() || d.end.is_opaque()),
        });
    }
    let mut writes = Vec::new();
    for e in g.in_edges(exit) {
        let m = &g.edge(e).memlet;
        writes.push(TracedAccess {
            data: m.data.clone(),
            subset: m.subset.clone(),
            is_read: false,
            dynamic: m.dynamic || m.subset.dims.iter().any(|d| d.begin.is_opaque() || d.end.is_opaque()),
        });
    }
    Ok(ScopeMovement { entry, params, reads, writes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::vecadd_sdfg;

    #[test]
    fn traces_vecadd_movement() {
        let g = vecadd_sdfg(1);
        let entry = g.find_map_entry("vadd").unwrap();
        let mv = scope_movement(&g, entry).unwrap();
        assert_eq!(mv.params, vec!["i"]);
        assert_eq!(mv.reads.len(), 2);
        assert_eq!(mv.writes.len(), 1);
        let names: Vec<&str> = mv.reads.iter().map(|r| r.data.as_str()).collect();
        assert!(names.contains(&"x") && names.contains(&"y"));
        assert_eq!(mv.writes[0].data, "z");
        assert!(!mv.any_dynamic());
        assert_eq!(mv.inner_param(), "i");
    }

    #[test]
    fn dynamic_accesses_detected() {
        use crate::ir::{GraphBuilder, MapSchedule, Memlet, TaskExpr};
        use crate::symbolic::{Expr, Range, Subset};
        let mut b = GraphBuilder::new("gather");
        b.array_f32("idx", vec![Expr::sym("N")]);
        b.array_f32("x", vec![Expr::sym("N")]);
        b.array_f32("y", vec![Expr::sym("N")]);
        let xi = b.access("idx");
        let x = b.access("x");
        let y = b.access("y");
        let (me, mx) = b.map("g", &["i"], vec![Range::upto_sym("N")], MapSchedule::Pipeline);
        let t = b.tasklet1("copy", "out", TaskExpr::input("v"));
        let all = Subset::new(vec![Range::upto_sym("N")]);
        b.edge(xi, me, Memlet::new("idx", all.clone()));
        b.edge(x, me, Memlet::new("x", all.clone()));
        // data-dependent read x[idx[i]]
        b.edge(
            me,
            t,
            Memlet::new("x", Subset::index1(Expr::opaque("idx[i]")))
                .with_dst("v")
                .dynamic(),
        );
        b.drain(t, mx, y, "y", Subset::index1(Expr::sym("i")), all, "out");
        let g = b.finish();
        let entry = g.find_map_entry("g").unwrap();
        let mv = scope_movement(&g, entry).unwrap();
        assert!(mv.any_dynamic());
    }

    #[test]
    fn non_map_node_is_an_error() {
        let g = vecadd_sdfg(1);
        // node 0 is an access node
        assert!(scope_movement(&g, crate::ir::NodeId(0)).is_err());
    }
}
