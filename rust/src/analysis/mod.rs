//! Data-movement analyses.
//!
//! The paper's transformation is driven purely by data movement (§3.2):
//! *"Our automatic multi-pumping transformation applies to programs
//! regardless of their computational contents, but rather by tracing
//! and mutating their data movement properties."* This module holds the
//! three analyses it describes:
//!
//! * [`movement`] — trace all memlets into/out of each computational
//!   scope (the "capturing all data movement" step);
//! * [`streamability`] — can the memory between two connected modules
//!   be pipelined into a FIFO? (order-preserving linear access check,
//!   the "intersection check on each pair of connected modules"), and
//!   the decomposition into streamable regions — the atoms of a
//!   per-subgraph pump-factor assignment;
//! * [`vectorizability`] — the traditional SIMD conditions and the
//!   *relaxed temporal* conditions (internal sequential dependencies
//!   allowed; only data-dependent external I/O is disqualifying).
//!
//! Plus the post-transform design-rule checker:
//!
//! * [`checker`] — static CDC-structure + deadlock-freedom rules over
//!   a transformed graph and its lowered design, with stable `TVxxx`
//!   diagnostics (`tvec check`, and the dse pre-simulation gate).

pub mod checker;
pub mod movement;
pub mod streamability;
pub mod vectorizability;

pub use checker::{check, CheckReport, Diagnostic, Severity};
pub use movement::{scope_movement, ScopeMovement};
pub use streamability::{partition_streamable, streamable_between, StreamRegion, Streamability};
pub use vectorizability::{check_temporal, check_traditional, Vectorizability};
