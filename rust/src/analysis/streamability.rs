//! Streamability: can module-to-module memory become a FIFO?
//!
//! Paper §3.2: *"We identify where to apply the optimization by greedily
//! taking the entire application in its DaCe IR form and finding the
//! largest subgraph that can be streamed, that is, when data
//! dependencies between two components can be converted to queue-based
//! access. [...] By performing an intersection check on each pair of
//! connected modules, we can determine if pipelining the memory between
//! two modules can be performed."*
//!
//! A container access is *streamable from a scope* when the scope
//! touches it in a linear, order-preserving sequence — formally, when
//! its subset is innermost-linear in the scope's pipelined parameter
//! ([`Subset::linear_in`]). Two connected modules can stream *between*
//! each other when the producer's write order equals the consumer's
//! read order (identical subsets as functions of their parameters).

use super::movement::{ScopeMovement, TracedAccess};
use crate::ir::{ContainerKind, LibraryOp, MapSchedule, Node, NodeId, PumpMode, RegionPump, Sdfg};
use crate::symbolic::Expr;

/// Verdict for one access or one producer/consumer pair.
#[derive(Clone, Debug, PartialEq)]
pub enum Streamability {
    /// Access order is linear with the given stride — a reader/writer
    /// module can feed it through a FIFO.
    Streamable { stride: i64 },
    /// Not convertible to queue access, with the reason.
    Blocked(String),
}

impl Streamability {
    pub fn is_streamable(&self) -> bool {
        matches!(self, Streamability::Streamable { .. })
    }
}

/// Can a single traced access be converted to a stream, given the
/// scope's pipelined (innermost) parameter?
pub fn streamable_access(acc: &TracedAccess, inner_param: &str) -> Streamability {
    if acc.dynamic {
        return Streamability::Blocked(format!(
            "access to '{}' is data-dependent (dynamic memlet)",
            acc.data
        ));
    }
    match acc.subset.linear_in(inner_param) {
        Some(stride) => Streamability::Streamable { stride },
        None => Streamability::Blocked(format!(
            "access {}{} is not linear in pipeline parameter '{inner_param}'",
            acc.data, acc.subset
        )),
    }
}

/// Can the memory between a producer scope (writing `data`) and a
/// consumer scope (reading `data`) be pipelined into a FIFO? Both must
/// access `data` linearly, with the same stride, and the subsets must
/// coincide under renaming of their respective parameters.
pub fn streamable_between(
    g: &Sdfg,
    producer: &ScopeMovement,
    consumer: &ScopeMovement,
    data: &str,
) -> Streamability {
    // streams are already streams
    if let Some(decl) = g.container(data) {
        if decl.kind == ContainerKind::Stream {
            return Streamability::Streamable { stride: 1 };
        }
    }
    let w = match producer.writes.iter().find(|a| a.data == data) {
        Some(w) => w,
        None => return Streamability::Blocked(format!("producer does not write '{data}'")),
    };
    let r = match consumer.reads.iter().find(|a| a.data == data) {
        Some(r) => r,
        None => return Streamability::Blocked(format!("consumer does not read '{data}'")),
    };
    let sw = streamable_access(w, producer.inner_param());
    if let Streamability::Blocked(reason) = sw {
        return Streamability::Blocked(format!("producer: {reason}"));
    }
    let sr = streamable_access(r, consumer.inner_param());
    if let Streamability::Blocked(reason) = sr {
        return Streamability::Blocked(format!("consumer: {reason}"));
    }
    // order intersection check: writer subset as f(p) must equal reader
    // subset as f(q) under p := q (same position in the sequence)
    let canon = Expr::sym("__seq");
    let wsub = w.subset.subst(producer.inner_param(), &canon);
    let rsub = r.subset.subst(consumer.inner_param(), &canon);
    match wsub.same_as(&rsub) {
        Some(true) => {
            let stride = match sw {
                Streamability::Streamable { stride } => stride,
                _ => unreachable!(),
            };
            Streamability::Streamable { stride }
        }
        Some(false) => Streamability::Blocked(format!(
            "write order {wsub} differs from read order {rsub} for '{data}'"
        )),
        None => Streamability::Blocked(format!(
            "cannot prove write/read order equality for '{data}' (opaque index)"
        )),
    }
}

/// One streamable region: a compute module (map scope or library node)
/// that must share a single clock domain internally. Module-to-module
/// links are streams (or transient buffers the streaming composition
/// fuses into streams), i.e. exactly the places where clock-domain
/// crossings can legally be inserted — so regions are the atoms of a
/// per-subgraph pump-factor assignment. The paper's §3.4 choice (pump
/// the largest streamable subgraph as a whole) is the assignment that
/// gives every region the same factor; mixed assignments split the
/// subgraph at region boundaries instead.
#[derive(Clone, Debug, PartialEq)]
pub struct StreamRegion {
    /// Anchor node: the map entry or library node.
    pub module: NodeId,
    /// Human-readable label, e.g. `jacobi3d_stage3`.
    pub label: String,
    /// Narrowest stream/datapath lane count the region carries — a
    /// resource-mode pump factor must divide this width.
    pub width: usize,
    /// Does the region touch an external (non-transient, or
    /// reader/writer-fed) container? Throughput mode widens the
    /// external interface, so it is only meaningful — and only legal —
    /// on boundary regions.
    pub external: bool,
    /// Does the region pipeline at II > 1 (a sequential schedule or a
    /// dependent library datapath like Floyd–Warshall's in-place
    /// relaxation)? Bare-fast mode clocks such a region faster without
    /// gearboxes so the fast clock recovers the II; on an II = 1
    /// region it buys nothing and is rejected.
    pub dependent: bool,
}

impl StreamRegion {
    /// The subset of `candidates` that are legal resource-mode factors
    /// for this region (≥ 2 and dividing the region's width).
    pub fn legal_factors(&self, candidates: &[usize]) -> Vec<usize> {
        candidates
            .iter()
            .copied()
            .filter(|&f| f >= 2 && self.width % f == 0)
            .collect()
    }

    /// Per-mode legality of one `RegionPump` on this region:
    /// * resource — the factor must divide the region's narrowest
    ///   internal width (the gearboxes repack M narrow beats per wide
    ///   transaction);
    /// * throughput — the region must own a widenable boundary stream
    ///   (an interior region's feed cannot be widened, so the fast
    ///   clock would only starve);
    /// * bare-fast — the region must be dependent (II > 1), since
    ///   without gearboxes the fast clock can only recover II.
    pub fn allows(&self, pump: RegionPump) -> bool {
        if pump.factor < 2 {
            return false;
        }
        match pump.mode {
            PumpMode::Resource => self.width % pump.factor == 0,
            PumpMode::Throughput => self.external,
            PumpMode::BareFast => self.dependent,
        }
    }

    /// All legal `RegionPump`s drawn from `factors` × `modes`, in
    /// (mode, factor) enumeration order.
    pub fn legal_pumps(&self, factors: &[usize], modes: &[PumpMode]) -> Vec<RegionPump> {
        let mut out = Vec::new();
        for &mode in modes {
            for &factor in factors {
                let p = RegionPump { factor, mode };
                if self.allows(p) {
                    out.push(p);
                }
            }
        }
        out
    }

    /// The reason `pump` is illegal on this region, for transform
    /// error messages (None when legal).
    pub fn rejects(&self, pump: RegionPump) -> Option<String> {
        if self.allows(pump) {
            return None;
        }
        Some(match pump.mode {
            PumpMode::Resource => format!(
                "region '{}': width {} not divisible by resource-mode factor {}",
                self.label, self.width, pump.factor
            ),
            PumpMode::Throughput => format!(
                "region '{}': touches no external stream, so throughput-mode \
                 widening has nothing to feed it",
                self.label
            ),
            PumpMode::BareFast => format!(
                "region '{}': pipelines at II = 1, so gearbox-free fast \
                 clocking recovers nothing",
                self.label
            ),
        })
    }
}

/// Boundary nodes data flows into / out of for a compute module:
/// (entry, exit) for maps, (self, self) for library nodes. Shared with
/// the mixed multi-pumping transform so both sides of the "space and
/// transform agree by construction" invariant use one definition.
pub(crate) fn module_io(g: &Sdfg, id: NodeId) -> (NodeId, NodeId) {
    match g.node(id) {
        Node::MapEntry { name, .. } => {
            (id, g.find_map_exit(name).expect("validated map has an exit"))
        }
        _ => (id, id),
    }
}

/// Decompose an SDFG into its streamable regions, in deterministic
/// (node-id, i.e. construction) order. Works identically on the
/// pre-streamed graph (transient chain buffers are region boundaries)
/// and the streamed graph (the fused inter-module streams are region
/// boundaries), so the candidate space and the transformation agree on
/// region count and order by construction.
pub fn partition_streamable(g: &Sdfg) -> Vec<StreamRegion> {
    // streams plumbed by reader/writer IO modules: after streaming
    // composition the external arrays sit behind these, so a region fed
    // by one is a boundary region exactly like a region reading the
    // array directly pre-streaming (keeps the before/after partition
    // agreement the mixed-assignment machinery relies on)
    let mut io_streams: Vec<&str> = Vec::new();
    for id in g.node_ids() {
        match g.node(id) {
            Node::Reader { stream, .. } | Node::Writer { stream, .. } => {
                io_streams.push(stream.as_str());
            }
            _ => {}
        }
    }
    let mut out = Vec::new();
    for id in g.node_ids() {
        let is_module = matches!(g.node(id), Node::MapEntry { .. } | Node::Library { .. });
        if !is_module {
            continue;
        }
        let (inflow, outflow) = module_io(g, id);
        // narrowest lane count across every container the module
        // touches, plus boundary detection
        let mut width = usize::MAX;
        let mut external = false;
        let mut touch = |data: &str| {
            if let Some(decl) = g.container(data) {
                width = width.min(decl.vtype.lanes);
                if !decl.transient || io_streams.contains(&data) {
                    external = true;
                }
            }
        };
        for e in g.in_edges(inflow) {
            touch(&g.edge(e).memlet.data);
        }
        for e in g.out_edges(outflow) {
            touch(&g.edge(e).memlet.data);
        }
        // II > 1 sources: a sequential map schedule, or a library
        // datapath with a loop-carried update (Floyd–Warshall's
        // in-place relaxation; the feed-forward systolic/stencil cores
        // pipeline at II = 1)
        let dependent = match g.node(id) {
            Node::MapEntry { schedule, .. } => *schedule == MapSchedule::Sequential,
            Node::Library { op: LibraryOp::FloydWarshall { .. }, .. } => true,
            _ => false,
        };
        // the datapath width of library nodes bounds the region too;
        // Floyd–Warshall's dependent scalar datapath reports width 1,
        // which legalizes no resource-mode factor — the §4.4 argument
        // at region granularity.
        if let Node::Library { op, .. } = g.node(id) {
            width = width.min(match op {
                LibraryOp::SystolicGemm { vec_width, .. }
                | LibraryOp::StencilStage { vec_width, .. } => *vec_width,
                LibraryOp::FloydWarshall { .. } => 1,
            });
        }
        if width == usize::MAX {
            width = 1;
        }
        out.push(StreamRegion { module: id, label: g.node(id).label(), width, external, dependent });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::movement::scope_movement;
    use crate::ir::builder::vecadd_sdfg;
    use crate::symbolic::Subset;

    #[test]
    fn vecadd_accesses_streamable() {
        let g = vecadd_sdfg(1);
        let entry = g.find_map_entry("vadd").unwrap();
        let mv = scope_movement(&g, entry).unwrap();
        for acc in mv.all() {
            assert!(streamable_access(acc, "i").is_streamable(), "{acc:?}");
        }
    }

    #[test]
    fn reversed_access_blocked() {
        use crate::analysis::movement::TracedAccess;
        use crate::symbolic::Expr;
        // A[N-1-i] is not linear-increasing in i
        let acc = TracedAccess {
            data: "A".into(),
            subset: Subset::index1(Expr::sym("N").sub(&Expr::int(1)).sub(&Expr::sym("i"))),
            is_read: true,
            dynamic: false,
        };
        assert!(!streamable_access(&acc, "i").is_streamable());
    }

    #[test]
    fn dynamic_access_blocked_with_reason() {
        use crate::analysis::movement::TracedAccess;
        use crate::symbolic::Expr;
        let acc = TracedAccess {
            data: "A".into(),
            subset: Subset::index1(Expr::sym("i")),
            is_read: true,
            dynamic: true,
        };
        match streamable_access(&acc, "i") {
            Streamability::Blocked(r) => assert!(r.contains("data-dependent"), "{r}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn producer_consumer_same_order_streams() {
        use crate::analysis::movement::{ScopeMovement, TracedAccess};
        use crate::ir::NodeId;
        use crate::symbolic::Expr;
        let g = vecadd_sdfg(1); // supplies container decls only
        let producer = ScopeMovement {
            entry: NodeId(0),
            params: vec!["p".into()],
            reads: vec![],
            writes: vec![TracedAccess {
                data: "z".into(),
                subset: Subset::index1(Expr::sym("p")),
                is_read: false,
                dynamic: false,
            }],
        };
        let consumer = ScopeMovement {
            entry: NodeId(1),
            params: vec!["q".into()],
            reads: vec![TracedAccess {
                data: "z".into(),
                subset: Subset::index1(Expr::sym("q")),
                is_read: true,
                dynamic: false,
            }],
            writes: vec![],
        };
        assert!(streamable_between(&g, &producer, &consumer, "z").is_streamable());
        // mismatched order: consumer reads z[2*q]
        let consumer2 = ScopeMovement {
            reads: vec![TracedAccess {
                data: "z".into(),
                subset: Subset::index1(Expr::sym("q").scale(2)),
                is_read: true,
                dynamic: false,
            }],
            ..consumer
        };
        match streamable_between(&g, &producer, &consumer2, "z") {
            Streamability::Blocked(r) => assert!(r.contains("order"), "{r}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn vecadd_is_a_single_region() {
        let g = vecadd_sdfg(4);
        let regions = partition_streamable(&g);
        assert_eq!(regions.len(), 1);
        assert_eq!(regions[0].module, g.find_map_entry("vadd").unwrap());
        assert_eq!(regions[0].width, 4);
        assert_eq!(regions[0].legal_factors(&[2, 3, 4, 8]), vec![2, 4]);
    }

    #[test]
    fn stencil_chain_partitions_into_one_region_per_stage() {
        let g = crate::apps::stencil::build(crate::ir::StencilKind::Jacobi3D, 4, 8);
        let regions = partition_streamable(&g);
        assert_eq!(regions.len(), 4);
        for (i, r) in regions.iter().enumerate() {
            assert_eq!(r.label, format!("jacobi3d_stage{i}"), "regions must be in chain order");
            assert_eq!(r.width, 8);
            assert!(!r.dependent, "feed-forward stencil stages pipeline at II = 1");
        }
        // only the chain ends touch the external arrays
        assert!(regions[0].external && regions[3].external);
        assert!(!regions[1].external && !regions[2].external);
    }

    #[test]
    fn per_mode_legality_follows_region_shape() {
        use crate::ir::PumpMode;
        let g = crate::apps::stencil::build(crate::ir::StencilKind::Jacobi3D, 4, 8);
        let regions = partition_streamable(&g);
        // boundary stage: resource factors divide width 8; throughput
        // legal (external feed); bare-fast illegal (II = 1)
        let b = &regions[0];
        assert!(b.allows(RegionPump::new(4, PumpMode::Resource)));
        assert!(!b.allows(RegionPump::new(3, PumpMode::Resource)));
        assert!(b.allows(RegionPump::new(2, PumpMode::Throughput)));
        assert!(!b.allows(RegionPump::new(2, PumpMode::BareFast)));
        // interior stage: throughput has nothing to widen
        let i = &regions[1];
        assert!(!i.allows(RegionPump::new(2, PumpMode::Throughput)));
        assert!(i.rejects(RegionPump::new(2, PumpMode::Throughput))
            .unwrap()
            .contains("external"));
        assert_eq!(
            b.legal_pumps(&[2, 3, 4], &[PumpMode::Resource, PumpMode::Throughput]),
            vec![
                RegionPump::new(2, PumpMode::Resource),
                RegionPump::new(4, PumpMode::Resource),
                RegionPump::new(2, PumpMode::Throughput),
                RegionPump::new(3, PumpMode::Throughput),
                RegionPump::new(4, PumpMode::Throughput),
            ]
        );
    }

    #[test]
    fn partition_agrees_before_and_after_streaming() {
        // the candidate space partitions the pre-streamed base graph;
        // the transformation partitions the streamed one — count, order
        // and widths must match or per-region assignments dangle
        use crate::transforms::{pass::PassManager, StreamingComposition};
        let mut g = crate::apps::stencil::build(crate::ir::StencilKind::Diffusion3D, 6, 4);
        let before = partition_streamable(&g);
        let mut pm = PassManager::new();
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        let after = partition_streamable(&g);
        assert_eq!(before.len(), after.len());
        for (b, a) in before.iter().zip(&after) {
            assert_eq!(b.module, a.module);
            assert_eq!(b.label, a.label);
            assert_eq!(b.width, a.width);
            assert_eq!(b.external, a.external, "{}", b.label);
            assert_eq!(b.dependent, a.dependent, "{}", b.label);
        }
    }

    #[test]
    fn floyd_warshall_region_legalizes_no_resource_factor() {
        use crate::ir::PumpMode;
        let g = crate::apps::floyd_warshall::build();
        let regions = partition_streamable(&g);
        assert_eq!(regions.len(), 1);
        assert!(regions[0].legal_factors(&[2, 4, 8]).is_empty());
        // ... but its dependent II = 21 datapath legalizes bare-fast,
        // and its external feed legalizes throughput (§4.4 at region
        // granularity, now per mode)
        assert!(regions[0].dependent && regions[0].external);
        assert!(regions[0].allows(RegionPump::new(2, PumpMode::BareFast)));
        assert!(regions[0].allows(RegionPump::new(2, PumpMode::Throughput)));
        assert!(!regions[0].allows(RegionPump::new(2, PumpMode::Resource)));
    }
}
