//! Streamability: can module-to-module memory become a FIFO?
//!
//! Paper §3.2: *"We identify where to apply the optimization by greedily
//! taking the entire application in its DaCe IR form and finding the
//! largest subgraph that can be streamed, that is, when data
//! dependencies between two components can be converted to queue-based
//! access. [...] By performing an intersection check on each pair of
//! connected modules, we can determine if pipelining the memory between
//! two modules can be performed."*
//!
//! A container access is *streamable from a scope* when the scope
//! touches it in a linear, order-preserving sequence — formally, when
//! its subset is innermost-linear in the scope's pipelined parameter
//! ([`Subset::linear_in`]). Two connected modules can stream *between*
//! each other when the producer's write order equals the consumer's
//! read order (identical subsets as functions of their parameters).

use super::movement::{ScopeMovement, TracedAccess};
use crate::ir::{ContainerKind, Sdfg};
use crate::symbolic::Expr;

/// Verdict for one access or one producer/consumer pair.
#[derive(Clone, Debug, PartialEq)]
pub enum Streamability {
    /// Access order is linear with the given stride — a reader/writer
    /// module can feed it through a FIFO.
    Streamable { stride: i64 },
    /// Not convertible to queue access, with the reason.
    Blocked(String),
}

impl Streamability {
    pub fn is_streamable(&self) -> bool {
        matches!(self, Streamability::Streamable { .. })
    }
}

/// Can a single traced access be converted to a stream, given the
/// scope's pipelined (innermost) parameter?
pub fn streamable_access(acc: &TracedAccess, inner_param: &str) -> Streamability {
    if acc.dynamic {
        return Streamability::Blocked(format!(
            "access to '{}' is data-dependent (dynamic memlet)",
            acc.data
        ));
    }
    match acc.subset.linear_in(inner_param) {
        Some(stride) => Streamability::Streamable { stride },
        None => Streamability::Blocked(format!(
            "access {}{} is not linear in pipeline parameter '{inner_param}'",
            acc.data, acc.subset
        )),
    }
}

/// Can the memory between a producer scope (writing `data`) and a
/// consumer scope (reading `data`) be pipelined into a FIFO? Both must
/// access `data` linearly, with the same stride, and the subsets must
/// coincide under renaming of their respective parameters.
pub fn streamable_between(
    g: &Sdfg,
    producer: &ScopeMovement,
    consumer: &ScopeMovement,
    data: &str,
) -> Streamability {
    // streams are already streams
    if let Some(decl) = g.container(data) {
        if decl.kind == ContainerKind::Stream {
            return Streamability::Streamable { stride: 1 };
        }
    }
    let w = match producer.writes.iter().find(|a| a.data == data) {
        Some(w) => w,
        None => return Streamability::Blocked(format!("producer does not write '{data}'")),
    };
    let r = match consumer.reads.iter().find(|a| a.data == data) {
        Some(r) => r,
        None => return Streamability::Blocked(format!("consumer does not read '{data}'")),
    };
    let sw = streamable_access(w, producer.inner_param());
    if let Streamability::Blocked(reason) = sw {
        return Streamability::Blocked(format!("producer: {reason}"));
    }
    let sr = streamable_access(r, consumer.inner_param());
    if let Streamability::Blocked(reason) = sr {
        return Streamability::Blocked(format!("consumer: {reason}"));
    }
    // order intersection check: writer subset as f(p) must equal reader
    // subset as f(q) under p := q (same position in the sequence)
    let canon = Expr::sym("__seq");
    let wsub = w.subset.subst(producer.inner_param(), &canon);
    let rsub = r.subset.subst(consumer.inner_param(), &canon);
    match wsub.same_as(&rsub) {
        Some(true) => {
            let stride = match sw {
                Streamability::Streamable { stride } => stride,
                _ => unreachable!(),
            };
            Streamability::Streamable { stride }
        }
        Some(false) => Streamability::Blocked(format!(
            "write order {wsub} differs from read order {rsub} for '{data}'"
        )),
        None => Streamability::Blocked(format!(
            "cannot prove write/read order equality for '{data}' (opaque index)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::movement::scope_movement;
    use crate::ir::builder::vecadd_sdfg;
    use crate::symbolic::Subset;

    #[test]
    fn vecadd_accesses_streamable() {
        let g = vecadd_sdfg(1);
        let entry = g.find_map_entry("vadd").unwrap();
        let mv = scope_movement(&g, entry).unwrap();
        for acc in mv.all() {
            assert!(streamable_access(acc, "i").is_streamable(), "{acc:?}");
        }
    }

    #[test]
    fn reversed_access_blocked() {
        use crate::analysis::movement::TracedAccess;
        use crate::symbolic::Expr;
        // A[N-1-i] is not linear-increasing in i
        let acc = TracedAccess {
            data: "A".into(),
            subset: Subset::index1(Expr::sym("N").sub(&Expr::int(1)).sub(&Expr::sym("i"))),
            is_read: true,
            dynamic: false,
        };
        assert!(!streamable_access(&acc, "i").is_streamable());
    }

    #[test]
    fn dynamic_access_blocked_with_reason() {
        use crate::analysis::movement::TracedAccess;
        use crate::symbolic::Expr;
        let acc = TracedAccess {
            data: "A".into(),
            subset: Subset::index1(Expr::sym("i")),
            is_read: true,
            dynamic: true,
        };
        match streamable_access(&acc, "i") {
            Streamability::Blocked(r) => assert!(r.contains("data-dependent"), "{r}"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn producer_consumer_same_order_streams() {
        use crate::analysis::movement::{ScopeMovement, TracedAccess};
        use crate::ir::NodeId;
        use crate::symbolic::Expr;
        let g = vecadd_sdfg(1); // supplies container decls only
        let producer = ScopeMovement {
            entry: NodeId(0),
            params: vec!["p".into()],
            reads: vec![],
            writes: vec![TracedAccess {
                data: "z".into(),
                subset: Subset::index1(Expr::sym("p")),
                is_read: false,
                dynamic: false,
            }],
        };
        let consumer = ScopeMovement {
            entry: NodeId(1),
            params: vec!["q".into()],
            reads: vec![TracedAccess {
                data: "z".into(),
                subset: Subset::index1(Expr::sym("q")),
                is_read: true,
                dynamic: false,
            }],
            writes: vec![],
        };
        assert!(streamable_between(&g, &producer, &consumer, "z").is_streamable());
        // mismatched order: consumer reads z[2*q]
        let consumer2 = ScopeMovement {
            reads: vec![TracedAccess {
                data: "z".into(),
                subset: Subset::index1(Expr::sym("q").scale(2)),
                is_read: true,
                dynamic: false,
            }],
            ..consumer
        };
        match streamable_between(&g, &producer, &consumer2, "z") {
            Streamability::Blocked(r) => assert!(r.contains("order"), "{r}"),
            other => panic!("{other:?}"),
        }
    }
}
