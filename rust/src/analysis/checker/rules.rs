//! Structural design rules over the transformed SDFG: CDC plumbing
//! shape, width conservation, and post-transform mode legality.
//!
//! The rules re-derive, from first principles, what a *correct*
//! multi-pumping rewrite must have produced — mirroring the gear-ratio
//! table of DESIGN.md §12: every clock-domain crossing carries a
//! synchronizer, a packer iff the producer side's gear ratio exceeds 1,
//! and an issuer iff the consumer side's does. Gear per mode: resource
//! → the pump factor; throughput → the factor on external streams
//! (reader/writer-facing) and 1 on interior ones; bare-fast → always 1.
//! Two regions count as one domain exactly when their `RegionPump`s are
//! equal — same factor at a different mode is still a crossing.

use super::diag::{
    Diagnostic, TV001_CROSSING_UNPLUMBED, TV002_PACKER_SET, TV003_ISSUER_SET,
    TV004_WIDTH_CONSERVATION, TV005_BAREFAST_GEARBOX, TV006_BAREFAST_NOT_DEPENDENT,
    TV007_THROUGHPUT_NO_FEED,
};
use crate::ir::{
    CdcKind, ContainerKind, LibraryOp, MapSchedule, MultipumpInfo, Node, NodeId, PumpMode,
    RegionPump, Sdfg,
};
use std::collections::BTreeMap;

/// Index of the pumped region containing `id`, if any.
fn region_of(mp: &MultipumpInfo, id: NodeId) -> Option<usize> {
    mp.regions.iter().position(|r| r.nodes.contains(&id))
}

/// The pump treatment a node presents on its streams (`None` = CL0).
fn pump_of(g: &Sdfg, id: NodeId) -> Option<RegionPump> {
    let mp = g.multipump.as_ref()?;
    let r = &mp.regions[region_of(mp, id)?];
    Some(RegionPump::new(r.factor, r.mode))
}

/// Is this node a compute-side anchor (part of some streamable region,
/// pumped or not)? Readers, writers and plain accesses are the CL0
/// "external world" instead — the distinction `CrossingSide::of` calls
/// `external` and throughput mode's gear ratio hinges on.
fn is_compute(n: &Node) -> bool {
    matches!(
        n,
        Node::MapEntry { .. } | Node::MapExit { .. } | Node::Tasklet(_) | Node::Library { .. }
    )
}

/// The gear ratio a side's gearbox must convert (1 = no gearbox) —
/// the checker's copy of the transform's `CrossingSide::of`.
fn expected_gear(pump: Option<RegionPump>, peer_external: bool) -> usize {
    match pump {
        None => 1,
        Some(p) => match p.mode {
            PumpMode::Resource => p.factor,
            PumpMode::Throughput if peer_external => p.factor,
            PumpMode::Throughput | PumpMode::BareFast => 1,
        },
    }
}

/// Module-level producers/consumers of every stream container, from
/// the edges at each stream's access node plus the explicit stream
/// fields of reader/writer/CDC nodes.
#[allow(clippy::type_complexity)]
fn stream_endpoints(g: &Sdfg) -> (BTreeMap<String, Vec<NodeId>>, BTreeMap<String, Vec<NodeId>>) {
    let mut producers: BTreeMap<String, Vec<NodeId>> = BTreeMap::new();
    let mut consumers: BTreeMap<String, Vec<NodeId>> = BTreeMap::new();
    let is_stream = |name: &str| {
        g.container(name).map(|d| d.kind == ContainerKind::Stream).unwrap_or(false)
    };
    for e in &g.edges {
        let d = &e.memlet.data;
        if !is_stream(d) {
            continue;
        }
        if matches!(g.node(e.dst), Node::Access { data } if data == d) {
            producers.entry(d.clone()).or_default().push(e.src);
        }
        if matches!(g.node(e.src), Node::Access { data } if data == d) {
            consumers.entry(d.clone()).or_default().push(e.dst);
        }
    }
    for id in g.node_ids() {
        match g.node(id) {
            Node::Reader { stream, .. } => producers.entry(stream.clone()).or_default().push(id),
            Node::Writer { stream, .. } => consumers.entry(stream.clone()).or_default().push(id),
            Node::Cdc { input, output, .. } => {
                consumers.entry(input.clone()).or_default().push(id);
                producers.entry(output.clone()).or_default().push(id);
            }
            _ => {}
        }
    }
    for m in [&mut producers, &mut consumers] {
        for v in m.values_mut() {
            v.sort();
            v.dedup();
        }
    }
    (producers, consumers)
}

/// Lanes of a stream container (None when undeclared — the validator's
/// problem, not ours).
fn lanes_of(g: &Sdfg, s: &str) -> Option<usize> {
    g.container(s).map(|d| d.vtype.lanes)
}

/// Run every SDFG-level rule. Returns diagnostics in discovery order
/// (the caller sorts for stable output).
pub fn check_structure(g: &Sdfg) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let (producers, consumers) = stream_endpoints(g);
    let first_module =
        |m: &BTreeMap<String, Vec<NodeId>>, s: &str| -> Option<NodeId> {
            m.get(s)?.iter().find(|id| !g.node(**id).is_cdc()).copied()
        };

    // TV001 — a stream may not connect two clock treatments directly:
    // whenever both module endpoints are visible (no plumbing on
    // either side), their region pumps must agree. Same factor at a
    // different mode is still a crossing.
    for (s, prods) in &producers {
        let (Some(p), Some(c)) = (
            prods.iter().find(|id| !g.node(**id).is_cdc()),
            consumers.get(s).and_then(|v| v.iter().find(|id| !g.node(**id).is_cdc())),
        ) else {
            continue;
        };
        let (pp, pc) = (pump_of(g, *p), pump_of(g, *c));
        if pp != pc {
            let show = |p: Option<RegionPump>| {
                p.map(|p| p.tag()).unwrap_or_else(|| "slow".to_string())
            };
            diags.push(Diagnostic::error(
                TV001_CROSSING_UNPLUMBED,
                s.clone(),
                format!(
                    "stream connects clock treatment {} (`{}`) to {} (`{}`) with no \
                     synchronizer between",
                    show(pp),
                    g.node(*p).label(),
                    show(pc),
                    g.node(*c).label(),
                ),
            ));
        }
    }

    // TV002/TV003 — walk each synchronizer's crossing chain
    // `[packer]? — sync — [issuer]?` and compare the gearbox set
    // against the gear the region modes require. Also record which
    // throughput regions see an external feed (for TV007).
    let nregions =
        g.multipump.as_ref().map(|mp| mp.regions.len()).unwrap_or(0);
    let mut throughput_fed = vec![false; nregions];
    for id in g.node_ids() {
        let Node::Cdc { name: sync_name, kind: CdcKind::Synchronizer, input, output, .. } =
            g.node(id)
        else {
            continue;
        };
        let packer = g.node_ids().find_map(|p| match g.node(p) {
            Node::Cdc { name, kind: CdcKind::Packer, input: pin, output: pout, factor }
                if pout == input =>
            {
                Some((name.clone(), pin.clone(), *factor))
            }
            _ => None,
        });
        let issuer = g.node_ids().find_map(|p| match g.node(p) {
            Node::Cdc { name, kind: CdcKind::Issuer, input: iin, output: iout, factor }
                if iin == output =>
            {
                Some((name.clone(), iout.clone(), *factor))
            }
            _ => None,
        });
        let head = packer.as_ref().map(|(_, pin, _)| pin.as_str()).unwrap_or(input);
        let tail = issuer.as_ref().map(|(_, iout, _)| iout.as_str()).unwrap_or(output);
        let src = first_module(&producers, head);
        let dst = first_module(&consumers, tail);
        let (src_pump, dst_pump) =
            (src.and_then(|n| pump_of(g, n)), dst.and_then(|n| pump_of(g, n)));
        let src_external = src.map(|n| !is_compute(g.node(n))).unwrap_or(true);
        let dst_external = dst.map(|n| !is_compute(g.node(n))).unwrap_or(true);
        // equal treatments need no crossing at all: expect no gearboxes
        let (want_src, want_dst) = if src_pump == dst_pump {
            (1, 1)
        } else {
            (expected_gear(src_pump, dst_external), expected_gear(dst_pump, src_external))
        };
        match (&packer, want_src) {
            (None, g_) if g_ > 1 => diags.push(Diagnostic::error(
                TV002_PACKER_SET,
                sync_name.clone(),
                format!("crossing on `{head}` needs a packer (gear {g_}) but has none"),
            )),
            (Some((name, _, f)), g_) if *f != g_ && g_ > 1 => diags.push(Diagnostic::error(
                TV002_PACKER_SET,
                name.clone(),
                format!("packer factor {f} but the producer side's gear ratio is {g_}"),
            )),
            (Some((name, _, _)), 1) => diags.push(Diagnostic::error(
                TV002_PACKER_SET,
                name.clone(),
                format!("spurious packer on `{head}`: the producer side crosses gearlessly"),
            )),
            _ => {}
        }
        match (&issuer, want_dst) {
            (None, g_) if g_ > 1 => diags.push(Diagnostic::error(
                TV003_ISSUER_SET,
                sync_name.clone(),
                format!("crossing on `{tail}` needs an issuer (gear {g_}) but has none"),
            )),
            (Some((name, _, f)), g_) if *f != g_ && g_ > 1 => diags.push(Diagnostic::error(
                TV003_ISSUER_SET,
                name.clone(),
                format!("issuer factor {f} but the consumer side's gear ratio is {g_}"),
            )),
            (Some((name, _, _)), 1) => diags.push(Diagnostic::error(
                TV003_ISSUER_SET,
                name.clone(),
                format!("spurious issuer on `{tail}`: the consumer side crosses gearlessly"),
            )),
            _ => {}
        }
        // external feed bookkeeping for throughput regions
        if let Some(mp) = g.multipump.as_ref() {
            if let (Some(p), true) = (src, dst_external) {
                if let Some(ri) = region_of(mp, p) {
                    throughput_fed[ri] = true;
                }
            }
            if let (Some(c), true) = (dst, src_external) {
                if let Some(ri) = region_of(mp, c) {
                    throughput_fed[ri] = true;
                }
            }
        }
    }

    // TV004 — width conservation across every gearbox and synchronizer:
    // bits-in must equal bits-out per slow-cycle transaction group.
    for id in g.node_ids() {
        let Node::Cdc { name, kind, input, output, factor } = g.node(id) else {
            continue;
        };
        let (Some(wi), Some(wo)) = (lanes_of(g, input), lanes_of(g, output)) else {
            continue;
        };
        let (eff_in, eff_out, law) = match kind {
            // packer: `factor` narrow in per wide out
            CdcKind::Packer => (wi * factor, wo, "lanes-in x factor == lanes-out"),
            // issuer: one wide in per `factor` narrow out
            CdcKind::Issuer => (wi, wo * factor, "lanes-in == lanes-out x factor"),
            CdcKind::Synchronizer => (wi, wo, "lanes-in == lanes-out"),
        };
        if eff_in != eff_out {
            diags.push(Diagnostic::error(
                TV004_WIDTH_CONSERVATION,
                name.clone(),
                format!(
                    "width not conserved: `{input}` ({wi} lanes) vs `{output}` ({wo} lanes) \
                     at factor {factor} violates {law}"
                ),
            ));
        }
    }

    // TV005/TV006/TV007 — post-transform mode legality per region.
    if let Some(mp) = g.multipump.as_ref() {
        for (ri, r) in mp.regions.iter().enumerate() {
            match r.mode {
                PumpMode::BareFast => {
                    for &n in &r.nodes {
                        match g.node(n) {
                            // bare-fast crosses gearlessly by definition
                            Node::Cdc { name, kind, .. }
                                if *kind != CdcKind::Synchronizer =>
                            {
                                diags.push(Diagnostic::error(
                                    TV005_BAREFAST_GEARBOX,
                                    name.clone(),
                                    format!(
                                        "bare-fast region (M={}) contains a {} gearbox — \
                                         widths must stay unchanged",
                                        r.factor,
                                        kind.name()
                                    ),
                                ));
                            }
                            // the fast clock only pays off on II > 1
                            // anchors; II = 1 pipelines gain nothing and
                            // break the mode's timing contract
                            Node::MapEntry { name, schedule, .. }
                                if *schedule != MapSchedule::Sequential =>
                            {
                                diags.push(Diagnostic::error(
                                    TV006_BAREFAST_NOT_DEPENDENT,
                                    name.clone(),
                                    format!(
                                        "bare-fast region (M={}) contains a non-dependent \
                                         {:?}-scheduled map",
                                        r.factor, schedule
                                    ),
                                ));
                            }
                            Node::Library { name, op }
                                if !matches!(op, LibraryOp::FloydWarshall { .. }) =>
                            {
                                diags.push(Diagnostic::error(
                                    TV006_BAREFAST_NOT_DEPENDENT,
                                    name.clone(),
                                    format!(
                                        "bare-fast region (M={}) contains the feed-forward \
                                         (II = 1) datapath `{}`",
                                        r.factor,
                                        op.name()
                                    ),
                                ));
                            }
                            _ => {}
                        }
                    }
                }
                PumpMode::Throughput => {
                    if !throughput_fed[ri] {
                        diags.push(Diagnostic::error(
                            TV007_THROUGHPUT_NO_FEED,
                            format!("region[{ri}]"),
                            format!(
                                "throughput region (M={}) has no external feed: no crossing \
                                 faces a CL0 reader/writer, so there is no interface to widen",
                                r.factor
                            ),
                        ));
                    }
                }
                PumpMode::Resource => {}
            }
        }
    }

    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::memlet::Memlet;
    use crate::ir::tasklet::{TaskExpr, Tasklet};
    use crate::ir::types::{ContainerKind, DType, DataDecl, Storage, VecType};
    use crate::ir::{MultipumpInfo, PumpedRegion};
    use crate::symbolic::{Expr, Range, Subset};

    fn stream(name: &str, lanes: usize) -> DataDecl {
        DataDecl {
            name: name.into(),
            kind: ContainerKind::Stream,
            vtype: VecType::of(DType::F32, lanes),
            shape: vec![],
            storage: Storage::Stream { depth: 16 },
            transient: true,
        }
    }

    fn tasklet(name: &str) -> Node {
        Node::Tasklet(Tasklet::new(name, vec![("out", TaskExpr::input("in"))]))
    }

    fn pop(d: &str) -> Memlet {
        Memlet::new(d, Subset::index1(Expr::int(0)))
    }

    fn region(factor: usize, mode: PumpMode, nodes: Vec<NodeId>) -> MultipumpInfo {
        MultipumpInfo { regions: vec![PumpedRegion { factor, mode, nodes }] }
    }

    fn only(diags: Vec<Diagnostic>, code: &str) {
        assert_eq!(diags.len(), 1, "expected exactly one diagnostic, got {diags:?}");
        assert_eq!(diags[0].code, code, "{diags:?}");
    }

    #[test]
    fn tv001_unplumbed_crossing() {
        let mut g = Sdfg::new("t");
        g.declare(stream("s", 4));
        let p = g.add_node(tasklet("prod"));
        let acc = g.add_node(Node::Access { data: "s".into() });
        let c = g.add_node(tasklet("cons"));
        g.add_edge(p, acc, pop("s"));
        g.add_edge(acc, c, pop("s"));
        // producer pumped, consumer left slow, no synchronizer between
        g.multipump = Some(region(2, PumpMode::Resource, vec![p]));
        only(check_structure(&g), "TV001");
    }

    #[test]
    fn tv002_missing_packer() {
        let mut g = Sdfg::new("t");
        g.declare(stream("s_fast", 4));
        g.declare(stream("s", 4));
        let p = g.add_node(tasklet("prod"));
        let acc = g.add_node(Node::Access { data: "s_fast".into() });
        let sync = g.add_node(Node::Cdc {
            name: "sync_s".into(),
            kind: CdcKind::Synchronizer,
            input: "s_fast".into(),
            output: "s".into(),
            factor: 2,
        });
        g.add_node(Node::Writer { name: "write_z".into(), data: "z".into(), stream: "s".into() });
        g.add_edge(p, acc, pop("s_fast"));
        g.add_edge(acc, sync, pop("s_fast"));
        // resource region leaving the domain must pack x2, but doesn't
        g.multipump = Some(region(2, PumpMode::Resource, vec![p]));
        only(check_structure(&g), "TV002");
    }

    #[test]
    fn tv003_wrong_issuer_factor() {
        let mut g = Sdfg::new("t");
        g.declare(stream("s", 8));
        g.declare(stream("s_cdc", 8));
        g.declare(stream("s_fast", 2));
        g.add_node(Node::Reader { name: "read_x".into(), data: "x".into(), stream: "s".into() });
        g.add_node(Node::Cdc {
            name: "sync_s".into(),
            kind: CdcKind::Synchronizer,
            input: "s".into(),
            output: "s_cdc".into(),
            factor: 2,
        });
        g.add_node(Node::Cdc {
            name: "issue_s".into(),
            kind: CdcKind::Issuer,
            input: "s_cdc".into(),
            output: "s_fast".into(),
            factor: 4, // region gear is 2 — wrong, though width-consistent
        });
        let acc = g.add_node(Node::Access { data: "s_fast".into() });
        let c = g.add_node(tasklet("cons"));
        g.add_edge(acc, c, pop("s_fast"));
        g.multipump = Some(region(2, PumpMode::Resource, vec![c]));
        only(check_structure(&g), "TV003");
    }

    #[test]
    fn tv004_width_not_conserved() {
        let mut g = Sdfg::new("t");
        g.declare(stream("a", 4));
        g.declare(stream("b", 4));
        // a packer that claims x2 but keeps the width: 256 bits in, 128 out
        g.add_node(Node::Cdc {
            name: "pack_a".into(),
            kind: CdcKind::Packer,
            input: "a".into(),
            output: "b".into(),
            factor: 2,
        });
        only(check_structure(&g), "TV004");
    }

    #[test]
    fn tv005_gearbox_in_barefast_region() {
        let mut g = Sdfg::new("t");
        g.declare(stream("a", 2));
        g.declare(stream("b", 4));
        let p = g.add_node(Node::Cdc {
            name: "pack_a".into(),
            kind: CdcKind::Packer,
            input: "a".into(),
            output: "b".into(),
            factor: 2, // width-consistent, so only the mode rule fires
        });
        g.multipump = Some(region(2, PumpMode::BareFast, vec![p]));
        only(check_structure(&g), "TV005");
    }

    #[test]
    fn tv006_barefast_region_not_dependent() {
        let mut g = Sdfg::new("t");
        let me = g.add_node(Node::MapEntry {
            name: "m".into(),
            params: vec!["i".into()],
            ranges: vec![Range::upto(4)],
            schedule: MapSchedule::Pipeline, // II = 1: bare-fast gains nothing
        });
        g.multipump = Some(region(2, PumpMode::BareFast, vec![me]));
        only(check_structure(&g), "TV006");
    }

    #[test]
    fn tv007_throughput_region_without_feed() {
        let mut g = Sdfg::new("t");
        let t = g.add_node(tasklet("interior"));
        g.multipump = Some(region(2, PumpMode::Throughput, vec![t]));
        only(check_structure(&g), "TV007");
    }
}
