//! Stable, machine-readable diagnostics.
//!
//! Every design-rule failure — from the static checker *and* from
//! `ir::validate` — carries a stable `TVxxx` code, a severity, and the
//! offending node/stream name. Tests, CI greps and downstream tooling
//! match on the code, never on the prose, so messages can be reworded
//! freely without breaking anything.
//!
//! Code ranges:
//!
//! * `TV001`–`TV099` — design-rule checker ([`super::check`]):
//!   CDC structure, width conservation, rate balance, FIFO sizing,
//!   post-transform mode legality;
//! * `TV101`–`TV199` — structural IR validation
//!   ([`crate::ir::validate`]).

use crate::util::table::Table;

/// Severity of a diagnostic. Errors fail `tvec check` (nonzero exit)
/// and reject DSE candidates; warnings are advisory.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Severity {
    Error,
    Warning,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warn",
        }
    }
}

// -- checker codes (TV0xx) ------------------------------------------------

/// A stream connects two clock treatments with no plumbing between.
pub const TV001_CROSSING_UNPLUMBED: &str = "TV001";
/// Packer set wrong at a crossing (missing / spurious / wrong factor).
pub const TV002_PACKER_SET: &str = "TV002";
/// Issuer set wrong at a crossing (missing / spurious / wrong factor).
pub const TV003_ISSUER_SET: &str = "TV003";
/// Bits-in != bits-out across a packer/issuer/synchronizer.
pub const TV004_WIDTH_CONSERVATION: &str = "TV004";
/// A bare-fast region contains a gearbox (must cross gearlessly).
pub const TV005_BAREFAST_GEARBOX: &str = "TV005";
/// A bare-fast region contains a non-dependent (II = 1) module.
pub const TV006_BAREFAST_NOT_DEPENDENT: &str = "TV006";
/// A throughput-mode region has no external feed to widen.
pub const TV007_THROUGHPUT_NO_FEED: &str = "TV007";
/// Steady-state token rates disagree on a channel.
pub const TV008_RATE_MISMATCH: &str = "TV008";
/// A token ratio does not divide — a partial-transaction wedge.
pub const TV009_PARTIAL_TRANSACTION: &str = "TV009";
/// A channel with no producer or no consumer.
pub const TV010_DANGLING_CHANNEL: &str = "TV010";
/// FIFO capacity below the minimum safe depth.
pub const TV011_FIFO_UNDERSIZED: &str = "TV011";
/// FIFO capacity more than 4x over the provisioning budget.
pub const TV012_FIFO_OVERPROVISIONED: &str = "TV012";

// -- validator codes (TV1xx) ----------------------------------------------

/// An edge endpoint is out of range.
pub const TV101_DANGLING_EDGE: &str = "TV101";
/// A memlet names an undeclared container.
pub const TV102_UNDECLARED_CONTAINER: &str = "TV102";
/// Map params/ranges arity mismatch.
pub const TV103_MAP_ARITY: &str = "TV103";
/// Map entry/exit pairing broken.
pub const TV104_MAP_PAIRING: &str = "TV104";
/// A tasklet connector is unconnected.
pub const TV105_UNCONNECTED_CONNECTOR: &str = "TV105";
/// An access node moves a foreign (non-stream) container.
pub const TV106_FOREIGN_CONTAINER: &str = "TV106";
/// The graph contains a cycle.
pub const TV107_GRAPH_CYCLE: &str = "TV107";
/// A map parameter shadows a program symbol.
pub const TV108_PARAM_SHADOWING: &str = "TV108";

/// One design-rule failure, pinned to a stable code and a location.
#[derive(Clone, PartialEq, Debug)]
pub struct Diagnostic {
    /// Stable code (`TV011`-style) — the only thing tests match on.
    pub code: &'static str,
    pub severity: Severity,
    /// Offending node / stream / channel name.
    pub loc: String,
    pub message: String,
}

impl Diagnostic {
    pub fn error(code: &'static str, loc: impl Into<String>, message: impl Into<String>) -> Self {
        Diagnostic { code, severity: Severity::Error, loc: loc.into(), message: message.into() }
    }

    pub fn warning(code: &'static str, loc: impl Into<String>, message: impl Into<String>) -> Self {
        Diagnostic { code, severity: Severity::Warning, loc: loc.into(), message: message.into() }
    }

    pub fn is_error(&self) -> bool {
        self.severity == Severity::Error
    }
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} {} `{}`: {}", self.code, self.severity.name(), self.loc, self.message)
    }
}

/// Render diagnostics as the aligned ASCII table `tvec check` prints
/// (shared formatter with every other report — `util::table`).
pub fn render_table(title: &str, diags: &[Diagnostic]) -> String {
    let mut t = Table::new(title, &["code", "severity", "location", "message"]);
    for d in diags {
        t.row(vec![
            d.code.to_string(),
            d.severity.name().to_string(),
            d.loc.clone(),
            d.message.clone(),
        ]);
    }
    let errors = diags.iter().filter(|d| d.is_error()).count();
    let warnings = diags.len() - errors;
    t.footnote(format!("{errors} error(s), {warnings} warning(s)"));
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_leads_with_code() {
        let d = Diagnostic::error(TV011_FIFO_UNDERSIZED, "x_to_vadd", "depth 1 below minimum 4");
        assert_eq!(format!("{d}"), "TV011 error `x_to_vadd`: depth 1 below minimum 4");
        assert!(d.is_error());
        let w = Diagnostic::warning(TV010_DANGLING_CHANNEL, "s", "no consumer");
        assert!(!w.is_error());
        assert_eq!(format!("{w}"), "TV010 warn `s`: no consumer");
    }

    #[test]
    fn table_renders_rows_and_counts() {
        let diags = vec![
            Diagnostic::error(TV008_RATE_MISMATCH, "a", "8 tokens vs 4"),
            Diagnostic::warning(TV012_FIFO_OVERPROVISIONED, "b", "depth 4096 over budget 64"),
        ];
        let r = render_table("design-rule check: demo", &diags);
        assert!(r.contains("design-rule check: demo"));
        assert!(r.contains("TV008"));
        assert!(r.contains("TV012"));
        assert!(r.contains("note: 1 error(s), 1 warning(s)"), "{r}");
    }
}
