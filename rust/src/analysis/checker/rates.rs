//! Steady-state token-rate balance and FIFO sizing over the lowered
//! design's channel graph — the static deadlock-freedom analysis
//! (after the PPN channel-sizing analyses, arXiv 1801.04821).
//!
//! Every module constrains the number of transactions its channels
//! carry per graph repetition:
//!
//! * hard counts — readers/writers (`elems`), compute (`iterations`),
//!   the behavioural cores (problem size / lanes);
//! * ratios — synchronizer 1:1, issuer 1:`factor`, packer
//!   `lanes`-driven (it accumulates narrow lanes until a wide
//!   transaction fills, exactly like the simulator's runtime).
//!
//! Propagating the hard counts through the ratios to a fixpoint either
//! assigns every reachable channel a consistent token count or exposes
//! a mismatch ([`TV008`]) / a non-integral ratio ([`TV009`]) — the two
//! static signatures of a runtime deadlock or wedge. On top of the
//! rates, each channel's FIFO capacity is compared against the minimum
//! safe depth (see [`min_depth`]) and a provisioning budget.

use super::diag::{
    Diagnostic, TV008_RATE_MISMATCH, TV009_PARTIAL_TRANSACTION, TV010_DANGLING_CHANNEL,
    TV011_FIFO_UNDERSIZED, TV012_FIFO_OVERPROVISIONED,
};
use crate::codegen::design::{Design, ModuleInst, ModuleSpec};

/// Burst slack: transactions of headroom a channel needs per unit of
/// rate imbalance so cross-domain jitter can never wedge the handshake.
const SLACK: usize = 4;

/// Peak transactions per *slow* cycle a module moves through one of its
/// ports. Full-rate ports run at their domain's clock ratio; the
/// wide sides of gearboxes and both sides of a synchronizer exchange at
/// most one transaction per slow cycle by construction (§12).
fn port_rate(m: &ModuleInst, chan: &str) -> usize {
    let f = m.domain.factor();
    match &m.spec {
        ModuleSpec::Sync { .. } => 1,
        ModuleSpec::Issuer { input, .. } if input == chan => 1,
        ModuleSpec::Packer { output, .. } if output == chan => 1,
        _ => f,
    }
}

/// Minimum safe FIFO depth for a channel whose producer/consumer peak
/// port rates are `rp`/`rc` (tokens per slow cycle):
/// `SLACK x max(1, ceil(rc / rp))`. A rate-balanced channel needs only
/// the constant slack; a channel feeding a fast consumer from a
/// once-per-slow-cycle source must buffer a slow cycle's worth of
/// fast-side demand or the consumer stalls into the crossing handshake.
fn min_depth(rp: usize, rc: usize) -> usize {
    SLACK * 1.max(rc.div_ceil(rp.max(1)))
}

/// One hard token count: `channel` carries exactly `tokens` per rep.
struct Hard {
    chan: usize,
    tokens: u128,
    by: String,
}

/// One ratio constraint: `tokens[a] * ma == tokens[b] * mb`.
struct Ratio {
    a: usize,
    ma: u128,
    b: usize,
    mb: u128,
    by: String,
}

/// Run the rate/depth rules over a lowered design.
pub fn check_rates(design: &Design) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    let chan_idx = |name: &str| design.channels.iter().position(|c| c.name == name);
    // the controller's pseudo-channels have no data-plane endpoints and
    // carry one token per repetition by construction — exempt throughout
    let is_ctrl = |name: &str| name.starts_with("__ctrl");

    // -- collect constraints ----------------------------------------------
    let mut hard: Vec<Hard> = Vec::new();
    let mut ratios: Vec<Ratio> = Vec::new();
    let fraction = |num: usize, den: usize, chan: &str, by: &str, diags: &mut Vec<Diagnostic>| {
        if den == 0 || num % den != 0 {
            diags.push(Diagnostic::error(
                TV009_PARTIAL_TRANSACTION,
                chan.to_string(),
                format!("{by} needs {num}/{den} transactions — a partial transaction wedges"),
            ));
            None
        } else {
            Some((num / den) as u128)
        }
    };
    for m in &design.modules {
        let label = m.spec.label();
        match &m.spec {
            ModuleSpec::Reader { stream, elems, .. }
            | ModuleSpec::Writer { stream, elems, .. } => {
                if let Some(c) = chan_idx(stream) {
                    hard.push(Hard { chan: c, tokens: *elems as u128, by: label.clone() });
                }
            }
            ModuleSpec::Compute { inputs, output, iterations, .. } => {
                for (s, _) in inputs {
                    if let Some(c) = chan_idx(s) {
                        hard.push(Hard { chan: c, tokens: *iterations as u128, by: label.clone() });
                    }
                }
                if let Some(c) = chan_idx(&output.0) {
                    hard.push(Hard { chan: c, tokens: *iterations as u128, by: label.clone() });
                }
            }
            ModuleSpec::Sync { input, output } => {
                if is_ctrl(input) || is_ctrl(output) {
                    continue;
                }
                if let (Some(a), Some(b)) = (chan_idx(input), chan_idx(output)) {
                    ratios.push(Ratio { a, ma: 1, b, mb: 1, by: label.clone() });
                }
            }
            ModuleSpec::Issuer { input, output, factor } => {
                // one wide in -> `factor` narrow out
                if let (Some(a), Some(b)) = (chan_idx(input), chan_idx(output)) {
                    ratios.push(Ratio { a, ma: *factor as u128, b, mb: 1, by: label.clone() });
                }
            }
            ModuleSpec::Packer { input, output, .. } => {
                // lanes-driven: narrow lanes accumulate until a wide
                // transaction fills (the runtime ignores `factor` too)
                if let (Some(a), Some(b)) = (chan_idx(input), chan_idx(output)) {
                    let (la, lb) =
                        (design.channels[a].lanes as u128, design.channels[b].lanes as u128);
                    ratios.push(Ratio { a, ma: la, b, mb: lb.max(1), by: label.clone() });
                }
            }
            ModuleSpec::GemmCore { a, b, c, n, m: mm, k, lanes, .. } => {
                for (stream, scalars) in [(a, n * k), (b, k * mm)] {
                    if let Some(ci) = chan_idx(stream) {
                        let l = design.channels[ci].lanes;
                        if let Some(t) = fraction(scalars, l, stream, &label, &mut diags) {
                            hard.push(Hard { chan: ci, tokens: t, by: label.clone() });
                        }
                    }
                }
                if let Some(ci) = chan_idx(c) {
                    if let Some(t) = fraction(n * mm, *lanes, c, &label, &mut diags) {
                        hard.push(Hard { chan: ci, tokens: t, by: label.clone() });
                    }
                }
            }
            ModuleSpec::StencilCore { input, output, nx, ny, nz, lanes, .. } => {
                let total = nx * ny * nz;
                for stream in [input, output] {
                    if let Some(ci) = chan_idx(stream) {
                        if let Some(t) = fraction(total, *lanes, stream, &label, &mut diags) {
                            hard.push(Hard { chan: ci, tokens: t, by: label.clone() });
                        }
                    }
                }
            }
            ModuleSpec::FwCore { input, output, n, .. } => {
                // n*n single-element transactions stream through per
                // outer (repeat) iteration, whatever the feed width
                for stream in [input, output] {
                    if let Some(ci) = chan_idx(stream) {
                        hard.push(Hard { chan: ci, tokens: (n * n) as u128, by: label.clone() });
                    }
                }
            }
        }
    }

    // -- solve to fixpoint -------------------------------------------------
    let mut tokens: Vec<Option<u128>> = vec![None; design.channels.len()];
    let mut setter: Vec<String> = vec![String::new(); design.channels.len()];
    for h in &hard {
        match tokens[h.chan] {
            None => {
                tokens[h.chan] = Some(h.tokens);
                setter[h.chan] = h.by.clone();
            }
            Some(t) if t != h.tokens => diags.push(Diagnostic::error(
                TV008_RATE_MISMATCH,
                design.channels[h.chan].name.clone(),
                format!(
                    "`{}` moves {} transactions/rep but `{}` expects {t}",
                    h.by, h.tokens, setter[h.chan]
                ),
            )),
            Some(_) => {}
        }
    }
    let mut bad_ratio = vec![false; ratios.len()];
    loop {
        let mut changed = false;
        for (i, r) in ratios.iter().enumerate() {
            if bad_ratio[i] {
                continue;
            }
            let derive = |t: u128, mul: u128, div: u128| -> Result<u128, ()> {
                let prod = t.checked_mul(mul).ok_or(())?;
                if div == 0 || prod % div != 0 {
                    return Err(());
                }
                Ok(prod / div)
            };
            match (tokens[r.a], tokens[r.b]) {
                (Some(ta), None) => match derive(ta, r.ma, r.mb) {
                    Ok(tb) => {
                        tokens[r.b] = Some(tb);
                        setter[r.b] = r.by.clone();
                        changed = true;
                    }
                    Err(()) => {
                        bad_ratio[i] = true;
                        diags.push(Diagnostic::error(
                            TV009_PARTIAL_TRANSACTION,
                            design.channels[r.b].name.clone(),
                            format!(
                                "`{}` turns {ta} transactions into {ta}x{}/{} — a partial \
                                 transaction wedges",
                                r.by, r.ma, r.mb
                            ),
                        ));
                    }
                },
                (None, Some(tb)) => match derive(tb, r.mb, r.ma) {
                    Ok(ta) => {
                        tokens[r.a] = Some(ta);
                        setter[r.a] = r.by.clone();
                        changed = true;
                    }
                    Err(()) => {
                        bad_ratio[i] = true;
                        diags.push(Diagnostic::error(
                            TV009_PARTIAL_TRANSACTION,
                            design.channels[r.a].name.clone(),
                            format!(
                                "`{}` needs {tb}x{}/{} input transactions — a partial \
                                 transaction wedges",
                                r.by, r.mb, r.ma
                            ),
                        ));
                    }
                },
                _ => {}
            }
        }
        if !changed {
            break;
        }
    }
    for (i, r) in ratios.iter().enumerate() {
        if bad_ratio[i] {
            continue;
        }
        if let (Some(ta), Some(tb)) = (tokens[r.a], tokens[r.b]) {
            if ta.checked_mul(r.ma) != tb.checked_mul(r.mb) {
                diags.push(Diagnostic::error(
                    TV008_RATE_MISMATCH,
                    design.channels[r.b].name.clone(),
                    format!(
                        "`{}` cannot balance: `{}` carries {ta} transactions/rep (per `{}`) \
                         vs `{}` {tb} (per `{}`)",
                        r.by,
                        design.channels[r.a].name,
                        setter[r.a],
                        design.channels[r.b].name,
                        setter[r.b]
                    ),
                ));
            }
        }
    }

    // -- endpoint / depth rules --------------------------------------------
    for ch in design.channels.iter() {
        if is_ctrl(&ch.name) {
            continue;
        }
        let prods: Vec<&ModuleInst> = design
            .modules
            .iter()
            .filter(|m| m.spec.outputs().iter().any(|s| s == &ch.name))
            .collect();
        let cons: Vec<&ModuleInst> = design
            .modules
            .iter()
            .filter(|m| m.spec.inputs().iter().any(|s| s == &ch.name))
            .collect();
        if prods.is_empty() || cons.is_empty() {
            diags.push(Diagnostic::warning(
                TV010_DANGLING_CHANNEL,
                ch.name.clone(),
                format!(
                    "dangling channel: {} producer(s), {} consumer(s)",
                    prods.len(),
                    cons.len()
                ),
            ));
            continue;
        }
        let rp = prods.iter().map(|m| port_rate(m, &ch.name)).max().unwrap_or(1);
        let rc = cons.iter().map(|m| port_rate(m, &ch.name)).max().unwrap_or(1);
        let need = min_depth(rp, rc);
        if ch.depth < need {
            diags.push(Diagnostic::error(
                TV011_FIFO_UNDERSIZED,
                ch.name.clone(),
                format!(
                    "capacity {} below minimum safe depth {need} (producer {rp} : consumer \
                     {rc} tokens/slow-cycle)",
                    ch.depth
                ),
            ));
        }
        // provisioning budget: 4x the domain-scaled slack — always at
        // least 4x the minimum safe depth, so the two rules never chase
        // each other
        let fmax = prods
            .iter()
            .chain(cons.iter())
            .map(|m| m.domain.factor())
            .max()
            .unwrap_or(1);
        let budget = 4 * SLACK * fmax;
        if ch.depth > budget {
            diags.push(Diagnostic::warning(
                TV012_FIFO_OVERPROVISIONED,
                ch.name.clone(),
                format!(
                    "capacity {} exceeds 4x the provisioning budget ({budget}) — dead BRAM",
                    ch.depth
                ),
            ));
        }
    }

    diags
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::design::ChannelSpec;
    use crate::hw::ResourceVec;
    use crate::ir::ClockDomain;

    fn chan(name: &str, lanes: usize, depth: usize) -> ChannelSpec {
        ChannelSpec { name: name.into(), lanes, depth, crosses_domains: false }
    }

    fn inst(spec: ModuleSpec) -> ModuleInst {
        ModuleInst { spec, domain: ClockDomain::Slow, resources: ResourceVec::ZERO }
    }

    fn reader(stream: &str, lanes: usize, elems: usize) -> ModuleInst {
        inst(ModuleSpec::Reader {
            data: "x".into(),
            stream: stream.into(),
            lanes,
            elems,
            bytes_per_cycle: 64,
        })
    }

    fn writer(stream: &str, lanes: usize, elems: usize) -> ModuleInst {
        inst(ModuleSpec::Writer {
            data: "z".into(),
            stream: stream.into(),
            lanes,
            elems,
            bytes_per_cycle: 64,
        })
    }

    fn design(channels: Vec<ChannelSpec>, modules: Vec<ModuleInst>) -> Design {
        Design {
            name: "t".into(),
            modules,
            channels,
            pump: None,
            domain_modes: vec![],
            arrays: vec![],
            repeat: 1,
            slr_replicas: 1,
            cl0_request_mhz: None,
        }
    }

    fn only(diags: Vec<Diagnostic>, code: &str) {
        assert_eq!(diags.len(), 1, "expected exactly one diagnostic, got {diags:?}");
        assert_eq!(diags[0].code, code, "{diags:?}");
    }

    #[test]
    fn tv008_rate_mismatch() {
        // writer wants more transactions than the reader produces — the
        // exact static signature of the simulator's deadlock oracle
        let d = design(
            vec![chan("s", 1, 16)],
            vec![reader("s", 1, 8), writer("s", 1, 12)],
        );
        only(check_rates(&d), "TV008");
    }

    #[test]
    fn tv009_partial_transaction() {
        // 2 narrow txns x 3 lanes = 6 elements never fill wide txns of
        // 4 lanes evenly: 6/4 wedges the packer half-full (the open
        // `w` tail also warns TV010 — the only other finding)
        let d = design(
            vec![chan("n", 3, 16), chan("w", 4, 16)],
            vec![
                reader("n", 3, 2),
                inst(ModuleSpec::Packer { input: "n".into(), output: "w".into(), factor: 2 }),
            ],
        );
        let diags = check_rates(&d);
        let errors: Vec<_> = diags.iter().filter(|g| g.is_error()).collect();
        assert_eq!(errors.len(), 1, "{diags:?}");
        assert_eq!(errors[0].code, "TV009", "{diags:?}");
        assert!(
            diags.iter().all(|g| g.code == "TV009" || g.code == "TV010"),
            "{diags:?}"
        );
    }

    #[test]
    fn tv008_ratio_conflict_with_both_ends_pinned() {
        // both packer ends hard-constrained to counts the lanes ratio
        // cannot reconcile: 2x3 elements in vs 1x4 out
        let d = design(
            vec![chan("n", 3, 16), chan("w", 4, 16)],
            vec![
                reader("n", 3, 2),
                inst(ModuleSpec::Packer { input: "n".into(), output: "w".into(), factor: 2 }),
                writer("w", 4, 1),
            ],
        );
        only(check_rates(&d), "TV008");
    }

    #[test]
    fn tv010_dangling_channel_warns() {
        let d = design(vec![chan("s", 1, 16)], vec![reader("s", 1, 8)]);
        let diags = check_rates(&d);
        only(diags.clone(), "TV010");
        assert!(!diags[0].is_error(), "dangling is advisory: {diags:?}");
    }

    #[test]
    fn tv011_undersized_fifo() {
        let d = design(
            vec![chan("s", 1, 1)],
            vec![reader("s", 1, 8), writer("s", 1, 8)],
        );
        only(check_rates(&d), "TV011");
    }

    #[test]
    fn tv012_overprovisioned_fifo() {
        let d = design(
            vec![chan("s", 1, 1000)],
            vec![reader("s", 1, 8), writer("s", 1, 8)],
        );
        let diags = check_rates(&d);
        only(diags.clone(), "TV012");
        assert!(!diags[0].is_error(), "overprovision is advisory: {diags:?}");
    }

    #[test]
    fn issuer_and_sync_ratios_balance() {
        // reader -> sync -> issuer(x4) -> writer: 4 wide in, 16 narrow out
        let d = design(
            vec![chan("s", 4, 16), chan("s_cdc", 4, 16), chan("s_fast", 1, 16)],
            vec![
                reader("s", 4, 4),
                inst(ModuleSpec::Sync { input: "s".into(), output: "s_cdc".into() }),
                inst(ModuleSpec::Issuer {
                    input: "s_cdc".into(),
                    output: "s_fast".into(),
                    factor: 4,
                }),
                writer("s_fast", 1, 16),
            ],
        );
        assert!(check_rates(&d).is_empty());
    }

    #[test]
    fn min_depth_scales_with_consumer_demand() {
        assert_eq!(min_depth(1, 1), SLACK);
        assert_eq!(min_depth(4, 4), SLACK); // rate-matched fast channel
        assert_eq!(min_depth(1, 4), 4 * SLACK); // slow feed, fast drain
        assert_eq!(min_depth(4, 1), SLACK); // backpressure, not deadlock
    }
}
