//! Static design-rule checker: CDC structure + deadlock freedom.
//!
//! The multi-pumping transform (DESIGN.md §12) injects packers,
//! issuers and synchronizers whose correctness used to be guarded only
//! dynamically — the exact simulator discovered a bad crossing or an
//! undersized FIFO as a runtime deadlock. This pass makes those
//! invariants static properties of the transformed [`Sdfg`] and its
//! lowered [`Design`] (after the HLS transformation-catalog view,
//! arXiv 1805.08288):
//!
//! * [`rules`] — graph-level structure: every clock-domain crossing
//!   carries exactly the gearbox set the gear-ratio table requires,
//!   widths are conserved across every gearbox, and region modes are
//!   re-checked post-transform (TV001–TV007);
//! * [`rates`] — design-level steady-state token-rate propagation and
//!   minimum-safe FIFO depths (TV008–TV012);
//! * [`diag`] — the stable `TVxxx` diagnostic vocabulary and the
//!   shared table renderer.
//!
//! Entry point: [`check`], used by the `tvec check` CLI subcommand and
//! as the pre-simulation gate inside `dse::Evaluator`.
//!
//! Soundness contract (pinned by `tests/properties.rs`): a design the
//! checker passes never deadlocks in `sim::run_exact`, and every
//! simulator-reported deadlock carries at least one checker error.

pub mod diag;
pub mod rates;
pub mod rules;

pub use diag::{render_table, Diagnostic, Severity};

use crate::codegen::design::Design;
use crate::ir::Sdfg;

/// The outcome of a design-rule check: every diagnostic, sorted by
/// (code, location, message) so output is stable across runs.
#[derive(Clone, Debug)]
pub struct CheckReport {
    pub diags: Vec<Diagnostic>,
}

impl CheckReport {
    pub fn errors(&self) -> usize {
        self.diags.iter().filter(|d| d.is_error()).count()
    }

    pub fn warnings(&self) -> usize {
        self.diags.len() - self.errors()
    }

    /// No errors (warnings allowed) — the gate `dse` and `tvec check`
    /// pass/fail on.
    pub fn is_clean(&self) -> bool {
        self.errors() == 0
    }

    pub fn first_error(&self) -> Option<&Diagnostic> {
        self.diags.iter().find(|d| d.is_error())
    }

    /// The aligned diagnostics table `tvec check` prints.
    pub fn render(&self, title: &str) -> String {
        diag::render_table(title, &self.diags)
    }
}

/// Run every design rule over a transformed graph and its lowered
/// design.
pub fn check(sdfg: &Sdfg, design: &Design) -> CheckReport {
    let mut diags = rules::check_structure(sdfg);
    diags.extend(rates::check_rates(design));
    diags.sort_by(|a, b| {
        (a.code, &a.loc, &a.message).cmp(&(b.code, &b.loc, &b.message))
    });
    CheckReport { diags }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps;
    use crate::coordinator::pipeline::{compile_staged, BuildSpec};
    use crate::ir::PumpMode;

    fn checked(spec: BuildSpec) -> CheckReport {
        let c = compile_staged(spec).unwrap();
        check(&c.sdfg, &c.design)
    }

    #[test]
    fn compiled_vecadd_is_clean_across_modes() {
        let n = 1 << 12;
        let base = || BuildSpec::new(apps::vecadd::build()).bind("N", n).seeded(3);
        for (label, spec) in [
            ("plain", base().vectorized("vadd", 8)),
            ("resource", base().vectorized("vadd", 8).pumped(2, PumpMode::Resource)),
            ("throughput", base().vectorized("vadd", 4).pumped(4, PumpMode::Throughput)),
        ] {
            let r = checked(spec);
            assert!(
                r.diags.is_empty(),
                "{label} vecadd must be checker-silent, got: {:?}",
                r.diags
            );
            assert!(r.is_clean() && r.first_error().is_none());
        }
    }

    #[test]
    fn golden_check_table() {
        let report = CheckReport {
            diags: vec![Diagnostic::error(
                diag::TV011_FIFO_UNDERSIZED,
                "s_fast",
                "depth 1 below minimum 4",
            )],
        };
        let expect = "\
design-rule check: demo
+-------+----------+----------+-------------------------+
| code  | severity | location | message                 |
+-------+----------+----------+-------------------------+
| TV011 | error    | s_fast   | depth 1 below minimum 4 |
+-------+----------+----------+-------------------------+
note: 1 error(s), 0 warning(s)
";
        assert_eq!(report.render("design-rule check: demo"), expect);
    }

    #[test]
    fn report_sorts_and_counts() {
        let mut diags = vec![
            Diagnostic::warning(diag::TV012_FIFO_OVERPROVISIONED, "b", "big"),
            Diagnostic::error(diag::TV008_RATE_MISMATCH, "a", "off"),
        ];
        diags.sort_by(|a, b| (a.code, &a.loc).cmp(&(b.code, &b.loc)));
        let r = CheckReport { diags };
        assert_eq!(r.errors(), 1);
        assert_eq!(r.warnings(), 1);
        assert!(!r.is_clean());
        assert_eq!(r.first_error().unwrap().code, "TV008");
        assert_eq!(r.diags[0].code, "TV008", "errors sort before the TV012 warn");
    }
}
