//! Small in-repo substrates for facilities whose crates are unavailable in
//! the offline build environment (rand, clap, criterion, proptest):
//! a seeded PRNG, a CLI argument parser, table formatting, a bench timing
//! harness and a miniature property-testing helper.

pub mod bench;
pub mod cli;
pub mod json;
pub mod quickcheck;
pub mod rng;
pub mod table;

pub use rng::Rng;
pub use table::Table;

/// Lock a mutex, recovering the guard if a previous holder panicked.
///
/// The DSE supervision layer catches candidate panics with
/// `catch_unwind`; any mutex the panicking closure held is poisoned as
/// a side effect even though the protected data (memo maps, arena free
/// lists) is still structurally valid — every critical section either
/// completes its insert or doesn't. Treating poison as fatal would turn
/// one quarantined candidate into a dead evaluator, so shared DSE state
/// locks through this helper instead of `.lock().unwrap()`.
pub fn lock_unpoisoned<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// The FNV-1a 64-bit offset basis: the seed every content hash in the
/// crate chains from (dse fingerprints, the cached SDFG print hash).
pub const FNV_OFFSET: u64 = 0xcbf29ce484222325;

/// FNV-1a over a byte slice, chained: `fnv1a(fnv1a(h, a), b)` hashes
/// the concatenation `a ++ b`.
pub fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a_chains_like_concatenation() {
        let ab = fnv1a(FNV_OFFSET, b"ab");
        let chained = fnv1a(fnv1a(FNV_OFFSET, b"a"), b"b");
        assert_eq!(ab, chained);
        assert_ne!(fnv1a(FNV_OFFSET, b"a"), fnv1a(FNV_OFFSET, b"b"));
    }
}
