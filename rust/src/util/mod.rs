//! Small in-repo substrates for facilities whose crates are unavailable in
//! the offline build environment (rand, clap, criterion, proptest):
//! a seeded PRNG, a CLI argument parser, table formatting, a bench timing
//! harness and a miniature property-testing helper.

pub mod bench;
pub mod cli;
pub mod quickcheck;
pub mod rng;
pub mod table;

pub use rng::Rng;
pub use table::Table;
