//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! [`forall`] runs a property over `n` generated cases from a seeded
//! [`Rng`](crate::util::Rng); on failure it reports the case index and
//! seed so the exact case can be replayed. Shrinking is intentionally
//! omitted — cases are generated small-biased instead (see [`Gen`]).

use crate::util::rng::Rng;

/// Case generator handed to properties: wraps the PRNG with size-biased
/// helpers so most generated cases are small (easier to debug) while the
/// tail still covers large inputs.
pub struct Gen<'a> {
    pub rng: &'a mut Rng,
}

impl<'a> Gen<'a> {
    /// usize in `[lo, hi)`, biased towards small values (~50% in the
    /// bottom eighth of the range).
    pub fn small_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        let span = hi - lo;
        if self.rng.f64() < 0.5 {
            lo + self.rng.below((span as u64 / 8).max(1)) as usize
        } else {
            lo + self.rng.below(span as u64) as usize
        }
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi)
    }

    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo < hi);
        lo + self.rng.below((hi - lo) as u64) as i64
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.f32_range(lo, hi)
    }

    pub fn choose<'b, T>(&mut self, xs: &'b [T]) -> &'b T {
        &xs[self.rng.range(0, xs.len())]
    }

    /// `Some(x)` half the time, `None` otherwise — for optional
    /// dimensions (a region's pump factor, an optional transform).
    pub fn option<T>(&mut self, x: T) -> Option<T> {
        if self.bool() {
            Some(x)
        } else {
            None
        }
    }

    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        self.rng.f32_vec(n)
    }
}

/// Run `prop` on `n` generated cases. Panics with seed + case index on the
/// first failure (a property returns `Err(reason)` or panics itself).
pub fn forall<F>(name: &str, seed: u64, n: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let mut root = Rng::new(seed);
    for case in 0..n {
        let mut rng = root.fork(case as u64);
        let mut g = Gen { rng: &mut rng };
        if let Err(reason) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{n} (seed {seed}): {reason}\n\
                 replay: forall(\"{name}\", {seed}, {}, ..) and inspect case {case}",
                case + 1
            );
        }
    }
}

/// Convenience: assert two f32 slices are element-wise close.
pub fn assert_allclose(a: &[f32], b: &[f32], rtol: f32, atol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol {
            return Err(format!("element {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("add-commutes", 1, 200, |g| {
            let a = g.i64(-1000, 1000);
            let b = g.i64(-1000, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math is broken".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        forall("always-fails", 2, 10, |_| Err("nope".into()));
    }

    #[test]
    fn option_produces_both_variants() {
        let mut rng = Rng::new(5);
        let mut g = Gen { rng: &mut rng };
        let xs: Vec<Option<u8>> = (0..100).map(|_| g.option(1u8)).collect();
        assert!(xs.iter().any(|x| x.is_some()));
        assert!(xs.iter().any(|x| x.is_none()));
    }

    #[test]
    fn small_bias_produces_small_and_large() {
        let mut rng = Rng::new(3);
        let mut g = Gen { rng: &mut rng };
        let xs: Vec<usize> = (0..500).map(|_| g.small_usize(0, 1000)).collect();
        assert!(xs.iter().filter(|&&x| x < 125).count() > 200);
        assert!(xs.iter().any(|&x| x > 500));
    }

    #[test]
    fn allclose_detects_mismatch() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 1e-6).is_ok());
        assert!(assert_allclose(&[1.0], &[1.1], 1e-6, 1e-6).is_err());
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1e-6, 1e-6).is_err());
    }
}
