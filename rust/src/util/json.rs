//! A miniature JSON reader/writer for the serve protocol.
//!
//! The crate is dependency-free by design (no serde in the offline
//! build environment), and until now every JSON in the repo was
//! write-only (`BENCH_sim.json`, Chrome traces) — hand-formatted
//! strings sufficed. `tvec dse --serve` *reads* newline-delimited JSON
//! requests, so this module adds the other direction: a small
//! recursive-descent parser covering the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, booleans, null) with a depth
//! limit, plus the string escaper the response writer uses.
//!
//! Numbers are held as `f64` — protocol fields are small integers and
//! the accessors ([`Json::as_u64`]) reject non-integral values.

/// A parsed JSON value. Object keys keep insertion order (a `Vec` of
/// pairs, not a map): request objects are tiny and order-preserving
/// echoes are friendlier to debug.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Parse one complete JSON document; trailing non-whitespace is an
    /// error (NDJSON framing splits on newlines before parsing).
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing characters at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup (first occurrence); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integral, non-negative numbers only: `12.0` yes, `12.5`/`-1` no.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= u64::MAX as f64 => {
                Some(*x as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Escape a string for embedding in a JSON document (quotes not
/// included). Control characters become `\u00XX`.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

const MAX_DEPTH: usize = 64;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH}"));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(b) => Err(format!("unexpected '{}' at byte {}", b as char, self.pos)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // surrogate pairs: accept, join when paired,
                            // replace a lone half (protocol strings are
                            // labels; lossy beats failing the request)
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let joined =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(joined).unwrap_or('\u{FFFD}')
                                } else {
                                    '\u{FFFD}'
                                }
                            } else {
                                char::from_u32(cp).unwrap_or('\u{FFFD}')
                            };
                            out.push(c);
                            continue; // hex4 advanced past the digits
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar (input is &str, so
                    // slicing at char boundaries is safe)
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let end = self.pos + 4;
        if end > self.bytes.len() {
            return Err("truncated \\u escape".into());
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..end])
            .map_err(|_| "bad \\u escape".to_string())?;
        let cp = u32::from_str_radix(s, 16).map_err(|_| "bad \\u escape".to_string())?;
        self.pos = end;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{text}' at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_serve_request() {
        let j = Json::parse(
            r#"{"op":"search","app":"vecadd","budget":30,"seed":7,"verify":false,"widths":[2,4]}"#,
        )
        .unwrap();
        assert_eq!(j.get("op").and_then(Json::as_str), Some("search"));
        assert_eq!(j.get("app").and_then(Json::as_str), Some("vecadd"));
        assert_eq!(j.get("budget").and_then(Json::as_u64), Some(30));
        assert_eq!(j.get("seed").and_then(Json::as_u64), Some(7));
        assert_eq!(j.get("verify").and_then(Json::as_bool), Some(false));
        assert_eq!(
            j.get("widths"),
            Some(&Json::Arr(vec![Json::Num(2.0), Json::Num(4.0)]))
        );
        assert_eq!(j.get("missing"), None);
    }

    #[test]
    fn round_trips_escapes() {
        let j = Json::parse(r#"{"msg":"a\tb\n\"q\" \\ A é"}"#).unwrap();
        assert_eq!(j.get("msg").and_then(Json::as_str), Some("a\tb\n\"q\" \\ A é"));
        assert_eq!(escape("a\tb\n\"q\" \\"), r#"a\tb\n\"q\" \\"#);
        // a control character round-trips through the escaper
        let enc = escape("\u{1}");
        assert_eq!(enc, "\\u0001");
        let back = Json::parse(&format!("\"{enc}\"")).unwrap();
        assert_eq!(back.as_str(), Some("\u{1}"));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[1,",
            "{\"a\":}",
            "tru",
            "\"unterminated",
            "1 2",
            "{\"a\":1,}",
            "nan",
        ] {
            assert!(Json::parse(bad).is_err(), "accepted malformed {bad:?}");
        }
    }

    #[test]
    fn numbers_and_integer_accessor() {
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse("12").unwrap().as_u64(), Some(12));
        assert_eq!(Json::parse("12.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
    }

    #[test]
    fn depth_limit_stops_stack_abuse() {
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(Json::parse(&deep).is_err());
    }
}
