//! Deterministic PRNG (splitmix64 seeding a xoshiro256**).
//!
//! Used for (a) workload data generation in tests/benches and (b) the
//! deterministic "place-and-route noise" term of the frequency model
//! (`hw::timing`). Seeded streams make every experiment reproducible.

/// xoshiro256** seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for sub-experiments).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. `n` must be non-zero.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.f64() as f32) * (hi - lo)
    }

    /// Approximately standard-normal (sum of 12 uniforms, CLT) — good
    /// enough for P&R noise; avoids transcendental calls in the hot path.
    pub fn gauss(&mut self) -> f64 {
        let mut acc = 0.0;
        for _ in 0..12 {
            acc += self.f64();
        }
        acc - 6.0
    }

    /// Vector of uniform f32 data in `[-1, 1)` for workloads.
    pub fn f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32_range(-1.0, 1.0)).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(7);
        for n in [1u64, 2, 3, 10, 1000, u64::MAX] {
            for _ in 0..50 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_is_centered() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gauss_has_roughly_unit_variance() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(21);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..16).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
