//! Bench timing harness (criterion is unavailable offline).
//!
//! `cargo bench` targets use `harness = false` and call [`bench`] /
//! [`BenchSuite`]: warmup, fixed-count timed runs, median + MAD, and a
//! one-line report compatible with quick eyeballing and the §Perf log.

use std::time::{Duration, Instant};

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub median: Duration,
    pub mad: Duration,
    pub iters: u32,
    /// Optional derived throughput (unit/s) if the caller supplied units.
    pub throughput: Option<f64>,
}

impl Measurement {
    pub fn report(&self) -> String {
        let thr = self
            .throughput
            .map(|t| format!("  {:>10}/s", human(t)))
            .unwrap_or_default();
        format!(
            "{:<44} {:>12}  ±{:>10}  ({} iters){}",
            self.name,
            human_dur(self.median),
            human_dur(self.mad),
            self.iters,
            thr
        )
    }
}

fn human_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

fn human(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

/// Time `f`, returning median/MAD over `iters` runs after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> Measurement {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters as usize);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let mut devs: Vec<Duration> = samples
        .iter()
        .map(|s| if *s > median { *s - median } else { median - *s })
        .collect();
    devs.sort();
    let mad = devs[devs.len() / 2];
    Measurement { name: name.to_string(), median, mad, iters: iters.max(1), throughput: None }
}

/// Time `f` and derive throughput from `units` work items per call.
pub fn bench_throughput<F: FnMut()>(
    name: &str,
    warmup: u32,
    iters: u32,
    units: f64,
    f: F,
) -> Measurement {
    let mut m = bench(name, warmup, iters, f);
    let secs = m.median.as_secs_f64();
    if secs > 0.0 {
        m.throughput = Some(units / secs);
    }
    m
}

/// A named collection of measurements printed as a block; bench binaries
/// build one suite and call [`BenchSuite::finish`].
#[derive(Default)]
pub struct BenchSuite {
    pub title: String,
    pub results: Vec<Measurement>,
}

impl BenchSuite {
    pub fn new(title: impl Into<String>) -> Self {
        BenchSuite { title: title.into(), results: Vec::new() }
    }

    pub fn add(&mut self, m: Measurement) {
        println!("  {}", m.report());
        self.results.push(m);
    }

    /// Print the footer. Returns the results for further processing.
    pub fn finish(self) -> Vec<Measurement> {
        println!("== {} : {} benchmarks ==", self.title, self.results.len());
        self.results
    }

    pub fn start(&self) {
        println!("== {} ==", self.title);
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench("spin", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert!(m.median > Duration::ZERO);
        assert_eq!(m.iters, 5);
    }

    #[test]
    fn throughput_derived() {
        let m = bench_throughput("t", 0, 3, 1e6, || {
            std::thread::sleep(Duration::from_micros(200));
        });
        let thr = m.throughput.unwrap();
        assert!(thr > 0.0 && thr < 1e10, "{thr}");
    }

    #[test]
    fn human_formats() {
        assert_eq!(human(500.0), "500.0");
        assert_eq!(human(2_000.0), "2.00k");
        assert_eq!(human(3e6), "3.00M");
        assert_eq!(human(4e9), "4.00G");
        assert!(human_dur(Duration::from_nanos(12)).contains("ns"));
        assert!(human_dur(Duration::from_micros(12)).contains("µs"));
        assert!(human_dur(Duration::from_millis(12)).contains("ms"));
        assert!(human_dur(Duration::from_secs(2)).contains(" s"));
    }
}
