//! ASCII table formatting for experiment reports — every table the
//! experiment runner prints (Tables 1–6, Figure 4 series) goes through
//! this formatter so benches and the CLI produce identical artifacts.

/// A simple column-aligned table with a title and optional footnote.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub footnote: Option<String>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            footnote: None,
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    pub fn footnote(&mut self, s: impl Into<String>) -> &mut Self {
        self.footnote = Some(s.into());
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as an aligned ASCII table.
    pub fn render(&self) -> String {
        let w = self.widths();
        let sep: String = w.iter().map(|n| format!("+{}", "-".repeat(n + 2))).collect::<String>() + "+";
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                // right-align numeric-looking cells, left-align labels
                let numeric = c.chars().next().map_or(false, |ch| ch.is_ascii_digit() || ch == '-' || ch == '+')
                    && c.parse::<f64>().is_ok();
                if numeric {
                    s.push_str(&format!("| {:>width$} ", c, width = w[i]));
                } else {
                    s.push_str(&format!("| {:<width$} ", c, width = w[i]));
                }
            }
            s.push('|');
            s
        };
        let mut out = String::new();
        out.push_str(&format!("{}\n", self.title));
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        if let Some(f) = &self.footnote {
            out.push_str(&format!("note: {f}\n"));
        }
        out
    }
}

/// Format a float with `d` decimals, trimming to a clean cell.
pub fn fnum(x: f64, d: usize) -> String {
    format!("{x:.d$}")
}

/// Format a percentage (already 0–100 scaled) with two decimals.
pub fn pct(x: f64) -> String {
    format!("{x:.2}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["name", "value"]);
        t.row(vec!["a".into(), "1.5".into()]);
        t.row(vec!["longer".into(), "20".into()]);
        let r = t.render();
        assert!(r.contains("| name   |"));
        assert!(r.contains("| longer |"));
        // numeric right-aligned within width 5 ("value")
        assert!(r.contains("|   1.5 |"), "{r}");
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn footnote_rendered() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into()]);
        t.footnote("hello");
        assert!(t.render().contains("note: hello"));
    }

    #[test]
    fn fnum_and_pct() {
        assert_eq!(fnum(1.23456, 2), "1.23");
        assert_eq!(pct(88.888), "88.89");
    }
}
