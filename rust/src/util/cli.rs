//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated `--help` text.

use std::collections::BTreeMap;

/// Declarative description of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// A parsed command line: subcommand, options and positionals.
#[derive(Clone, Debug, Default)]
pub struct Parsed {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn get_usize(&self, name: &str) -> Option<usize> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    pub fn get_u64(&self, name: &str) -> Option<u64> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|v| v.parse().ok())
    }
}

/// Command-line schema: named options + whether a subcommand is expected.
pub struct Cli {
    pub program: &'static str,
    pub about: &'static str,
    pub subcommands: Vec<(&'static str, &'static str)>,
    pub options: Vec<OptSpec>,
}

impl Cli {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Cli { program, about, subcommands: Vec::new(), options: Vec::new() }
    }

    pub fn subcommand(mut self, name: &'static str, help: &'static str) -> Self {
        self.subcommands.push((name, help));
        self
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.options.push(OptSpec { name, help, takes_value: true, default: None });
        self
    }

    pub fn opt_default(
        mut self,
        name: &'static str,
        help: &'static str,
        default: &'static str,
    ) -> Self {
        self.options.push(OptSpec { name, help, takes_value: true, default: Some(default) });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.options.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} ", self.program, self.about, self.program);
        if !self.subcommands.is_empty() {
            s.push_str("<SUBCOMMAND> ");
        }
        s.push_str("[OPTIONS] [ARGS...]\n");
        if !self.subcommands.is_empty() {
            s.push_str("\nSUBCOMMANDS:\n");
            for (name, help) in &self.subcommands {
                s.push_str(&format!("  {name:<14} {help}\n"));
            }
        }
        s.push_str("\nOPTIONS:\n");
        for o in &self.options {
            let v = if o.takes_value { " <VALUE>" } else { "" };
            let d = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  --{}{v:<9} {}{d}\n", o.name, o.help));
        }
        s.push_str("  --help       print this message\n");
        s
    }

    /// Parse a raw argument list (without argv[0]).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        let mut out = Parsed::default();
        for spec in &self.options {
            if let Some(d) = spec.default {
                out.opts.insert(spec.name.to_string(), d.to_string());
            }
        }
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(self.help_text());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .options
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| format!("unknown option --{name}\n\n{}", self.help_text()))?;
                if spec.takes_value {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| format!("option --{name} requires a value"))?,
                    };
                    out.opts.insert(name.to_string(), v);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("flag --{name} does not take a value"));
                    }
                    out.flags.insert(name.to_string(), true);
                }
            } else if out.subcommand.is_none()
                && !self.subcommands.is_empty()
                && out.positional.is_empty()
            {
                if !self.subcommands.iter().any(|(n, _)| n == a) {
                    return Err(format!("unknown subcommand '{a}'\n\n{}", self.help_text()));
                }
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    /// Parse `std::env::args()`, printing help/errors and exiting on failure.
    pub fn parse_env(&self) -> Parsed {
        let args: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&args) {
            Ok(p) => p,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(if msg.starts_with(self.program) { 0 } else { 2 });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("tvec", "test")
            .subcommand("run", "run it")
            .subcommand("report", "report it")
            .opt_default("size", "problem size", "16")
            .opt("config", "config file")
            .flag("verbose", "talk more")
    }

    fn parse(args: &[&str]) -> Result<Parsed, String> {
        cli().parse(&args.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn parses_subcommand_opts_flags_positionals() {
        let p = parse(&["run", "--size", "32", "--verbose", "extra"]).unwrap();
        assert_eq!(p.subcommand.as_deref(), Some("run"));
        assert_eq!(p.get_usize("size"), Some(32));
        assert!(p.flag("verbose"));
        assert_eq!(p.positional, vec!["extra"]);
    }

    #[test]
    fn equals_syntax() {
        let p = parse(&["run", "--size=64"]).unwrap();
        assert_eq!(p.get_usize("size"), Some(64));
    }

    #[test]
    fn defaults_apply() {
        let p = parse(&["report"]).unwrap();
        assert_eq!(p.get_or("size", "?"), "16");
        assert!(!p.flag("verbose"));
        assert_eq!(p.get("config"), None);
    }

    #[test]
    fn unknown_option_is_an_error() {
        assert!(parse(&["run", "--bogus"]).is_err());
    }

    #[test]
    fn unknown_subcommand_is_an_error() {
        assert!(parse(&["frobnicate"]).is_err());
    }

    #[test]
    fn missing_value_is_an_error() {
        assert!(parse(&["run", "--size"]).is_err());
    }

    #[test]
    fn help_lists_everything() {
        let h = cli().help_text();
        for needle in ["run", "report", "--size", "--config", "--verbose", "default: 16"] {
            assert!(h.contains(needle), "help missing {needle}: {h}");
        }
    }
}
