//! RTL emission: the four files of a multi-pumped RTL kernel
//! (paper §3.3):
//!
//! 1. a SystemVerilog controller communicating with the host,
//! 2. a SystemVerilog computation core (wrapping the HLS IP),
//! 3. a Verilog top-level instantiating controller + core(s) + the
//!    AXI4-Stream plumbing (clock converters, dwidth converters),
//! 4. a TCL script packaging the kernel.
//!
//! Plus the `link.cfg` connectivity file describing stream wiring and
//! the two clock signals supplied by the Vitis shell.

use super::design::{Design, ModuleSpec};

/// The four generated files plus the linker config.
#[derive(Clone, Debug)]
pub struct RtlKernel {
    pub controller_sv: String,
    pub core_sv: String,
    pub toplevel_v: String,
    pub package_tcl: String,
    pub link_cfg: String,
}

/// Emit the RTL kernel file set for a design.
pub fn emit_rtl(design: &Design) -> RtlKernel {
    let name = &design.name;
    let (factor, pumped) = match design.pump {
        Some((m, _)) => (m, true),
        None => (1, false),
    };

    let controller_sv = format!(
        "// {name}_controller.sv — host control (ap_ctrl_hs over AXI-Lite)\n\
         `timescale 1ns/1ps\n\
         module {name}_controller #(\n  parameter C_ADDR_WIDTH = 12\n) (\n\
         \x20 input  wire ap_clk,\n  input  wire ap_rst_n,\n\
         {}\
         \x20 input  wire s_axi_control_awvalid,\n  output wire ap_done,\n\
         \x20 output wire ap_idle,\n  output wire ap_start_out\n);\n\
         \x20 // state machine: IDLE -> RUN -> DONE, latching scalar args\n\
         endmodule\n",
        if pumped { "  input  wire ap_clk_2, // CL1 from the Vitis shell\n" } else { "" }
    );

    let core_sv = format!(
        "// {name}_core.sv — computation core wrapper (HLS IP inside)\n\
         `timescale 1ns/1ps\n\
         module {name}_core (\n  input wire ap_clk{},\n  input wire ap_rst_n,\n\
         \x20 // AXI4-Stream compute-side interfaces\n\
         \x20 input  wire [511:0] s_axis_in_tdata,\n\
         \x20 input  wire s_axis_in_tvalid,\n  output wire s_axis_in_tready,\n\
         \x20 output wire [511:0] m_axis_out_tdata,\n\
         \x20 output wire m_axis_out_tvalid,\n  input  wire m_axis_out_tready\n);\n\
         \x20 // instantiates the HLS-generated IP ({} compute modules)\n\
         endmodule\n",
        if pumped { "_2 // multi-pumped: core runs on CL1" } else { "" },
        design
            .modules
            .iter()
            .filter(|m| matches!(
                m.spec,
                ModuleSpec::Compute { .. } | ModuleSpec::GemmCore { .. } | ModuleSpec::StencilCore { .. }
            ))
            .count()
    );

    let mut plumbing = String::new();
    for m in &design.modules {
        match &m.spec {
            ModuleSpec::Sync { input, output } if !input.starts_with("__ctrl") => {
                plumbing.push_str(&format!(
                    "  axis_clock_converter #(.TDATA_WIDTH(512)) sync_{input} (\n\
                     \x20   .s_axis_aclk(ap_clk), .m_axis_aclk(ap_clk_2),\n\
                     \x20   .s_axis_tdata({input}_tdata), .m_axis_tdata({output}_tdata));\n"
                ));
            }
            ModuleSpec::Issuer { input, output, factor } => {
                plumbing.push_str(&format!(
                    "  axis_dwidth_converter #(.S_TDATA_NBYTES(64), .M_TDATA_NBYTES({})) issue_{input} (\n\
                     \x20   .aclk(ap_clk_2),\n\
                     \x20   .s_axis_tdata({input}_tdata), .m_axis_tdata({output}_tdata));\n",
                    64 / factor
                ));
            }
            ModuleSpec::Packer { input, output, factor } => {
                plumbing.push_str(&format!(
                    "  axis_dwidth_converter #(.S_TDATA_NBYTES({}), .M_TDATA_NBYTES(64)) pack_{input} (\n\
                     \x20   .aclk(ap_clk_2),\n\
                     \x20   .s_axis_tdata({input}_tdata), .m_axis_tdata({output}_tdata));\n",
                    64 / factor
                ));
            }
            _ => {}
        }
    }

    let toplevel_v = format!(
        "// {name}_top.v — top-level: controller + core(s) + plumbing\n\
         `timescale 1ns/1ps\n\
         module {name}_top (\n  input wire ap_clk,\n{}\
         \x20 input wire ap_rst_n\n);\n\
         \x20 {name}_controller ctrl (.ap_clk(ap_clk), .ap_rst_n(ap_rst_n));\n\
         \x20 {name}_core core (.ap_rst_n(ap_rst_n));\n\
         // AXI4-Stream infrastructure IP (paper §3.2 plumbing):\n{}\
         endmodule\n",
        if pumped {
            format!("  input wire ap_clk_2, // CL1 = {factor}×CL0 from the Vitis shell\n")
        } else {
            String::new()
        },
        plumbing
    );

    let package_tcl = format!(
        "# {name}_package.tcl — package the RTL kernel for Vitis\n\
         create_project -force {name}_kernel ./_x\n\
         add_files {{{name}_controller.sv {name}_core.sv {name}_top.v}}\n\
         ipx::package_project -root_dir ./pkg -vendor spcl -library tvec -taxonomy /KernelIP\n\
         set_property sdx_kernel true [ipx::current_core]\n\
         {}\
         ipx::save_core [ipx::current_core]\n",
        if pumped {
            "ipx::associate_bus_interfaces -clock ap_clk_2 -reset ap_rst_n_2 [ipx::current_core]\n"
        } else {
            ""
        }
    );

    let mut link_cfg = format!("# link.cfg — kernel connectivity for '{name}'\n[connectivity]\n");
    for (array, _, bank) in &design.arrays {
        link_cfg.push_str(&format!("sp={name}_1.{array}:HBM[{bank}]\n"));
    }
    if pumped {
        link_cfg.push_str(&format!(
            "\n[clock]\n# two clocks from the shell (consumes clocking resources once)\n\
             freqHz=300000000:{name}_1.ap_clk\nfreqHz={}:{name}_1.ap_clk_2\n",
            300_000_000u64 * factor as u64
        ));
    }

    RtlKernel { controller_sv, core_sv, toplevel_v, package_tcl, link_cfg }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::lower::lower;
    use crate::hw::cost::CostModel;
    use crate::ir::builder::vecadd_sdfg;
    use crate::transforms::{MultiPump, PassManager, StreamingComposition, Vectorize};

    fn pumped_design() -> Design {
        let mut g = vecadd_sdfg(1);
        let mut pm = PassManager::new();
        pm.run(&mut g, &Vectorize::new("vadd", 4)).unwrap();
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        pm.run(&mut g, &MultiPump::resource(2)).unwrap();
        let env = g.bind(&[("N", 256)]).unwrap();
        lower(&g, &env, &CostModel::default()).unwrap()
    }

    #[test]
    fn four_files_emitted_with_two_clocks() {
        let k = emit_rtl(&pumped_design());
        assert!(k.controller_sv.contains("ap_clk_2"));
        assert!(k.core_sv.contains("multi-pumped"));
        assert!(k.toplevel_v.contains("axis_clock_converter"));
        assert!(k.toplevel_v.contains("axis_dwidth_converter"));
        assert!(k.package_tcl.contains("sdx_kernel"));
        assert!(k.link_cfg.contains("HBM[0]"));
        assert!(k.link_cfg.contains("ap_clk_2"));
    }

    #[test]
    fn unpumped_design_has_single_clock() {
        let g = vecadd_sdfg(2);
        let env = g.bind(&[("N", 64)]).unwrap();
        let d = lower(&g, &env, &CostModel::default()).unwrap();
        let k = emit_rtl(&d);
        assert!(!k.toplevel_v.contains("ap_clk_2"));
        assert!(!k.link_cfg.contains("[clock]"));
    }
}
