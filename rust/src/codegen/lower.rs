//! Lowering: transformed SDFG + concrete bindings → design netlist.
//!
//! Every IR module kind maps 1:1 onto a netlist module; library nodes
//! expand into behavioural cores (the DaCe "library node expansion").
//! Module resources are priced with the [`CostModel`]; initiation
//! intervals of dependent pipelines come from the [`LatencyModel`]
//! (the HLS scheduler analog: a loop-carried dependency forces
//! II = length of the floating-point chain).

use super::design::{ChannelSpec, Design, ModuleInst, ModuleSpec};
use crate::analysis::movement::scope_movement;
use crate::analysis::vectorizability::has_loop_carried_dependency;
use crate::hw::cost::CostModel;
use crate::hw::ResourceVec;
use crate::ir::{
    CdcKind, ClockDomain, ContainerKind, LibraryOp, MapSchedule, Node, NodeId, PumpMode, Sdfg,
    Storage, Tasklet,
};
use crate::symbolic::SymbolTable;

/// Pipeline-stage latencies (cycles) for the fabric, HLS-scheduler
/// style. Used for pipeline fill and dependent-loop II.
#[derive(Clone, Debug)]
pub struct LatencyModel {
    pub fadd: u64,
    pub fmul: u64,
    pub fdiv: u64,
    pub fminmax: u64,
    /// Fixed pipeline overhead (load/store stages).
    pub base: u64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        LatencyModel { fadd: 8, fmul: 6, fdiv: 28, fminmax: 8, base: 5 }
    }
}

impl LatencyModel {
    /// Latency of one tasklet evaluation (serial op chain upper bound).
    pub fn tasklet_latency(&self, t: &Tasklet) -> u64 {
        let c = t.op_counts();
        self.base
            + c.adds as u64 * self.fadd
            + c.muls as u64 * self.fmul
            + c.divs as u64 * self.fdiv
            + c.minmax as u64 * self.fminmax
    }
}

fn container_scalars(g: &Sdfg, name: &str, env: &SymbolTable) -> Result<usize, String> {
    let decl = g.container(name).ok_or_else(|| format!("unknown container '{name}'"))?;
    let mut n: i64 = 1;
    for d in &decl.shape {
        n *= d
            .eval(env)
            .ok_or_else(|| format!("container '{name}': unbound dimension {d}"))?;
    }
    Ok(n as usize * decl.vtype.lanes)
}

fn stream_lanes(g: &Sdfg, name: &str) -> usize {
    g.container(name).map(|d| d.vtype.lanes).unwrap_or(1)
}

/// HBM port width in bytes per slow cycle (256-bit AXI).
pub const HBM_BYTES_PER_CYCLE: usize = 32;

/// Lower an SDFG to a design. The graph may be untransformed (original
/// single-kernel designs are modelled with fused reader/writer modules,
/// matching the AXI bursts any HLS kernel performs) or fully streamed
/// and multi-pumped.
pub fn lower(g: &Sdfg, env: &SymbolTable, cost: &CostModel) -> Result<Design, String> {
    let lat = LatencyModel::default();
    let mut modules: Vec<ModuleInst> = Vec::new();
    let mut channels: Vec<ChannelSpec> = Vec::new();
    let mut arrays: Vec<(String, usize, usize)> = Vec::new();
    // design-level pump tag: the *largest* factor (the fast time base)
    // and its region's mode; per-module domains below carry each
    // region's own factor, and `domain_modes` the per-factor modes
    let pump = g
        .multipump
        .as_ref()
        .map(|mp| (mp.max_factor(), mp.representative_mode()));
    let mut domain_modes: Vec<(usize, PumpMode)> = g
        .multipump
        .as_ref()
        .map(|mp| mp.regions.iter().map(|r| (r.factor, r.mode)).collect())
        .unwrap_or_default();
    domain_modes.sort_by_key(|&(f, m)| (f, m.letter()));
    domain_modes.dedup();

    // channels from stream containers
    for (name, decl) in &g.containers {
        match decl.storage {
            Storage::Stream { depth } => {
                let crosses = name.ends_with("_cdc");
                channels.push(ChannelSpec {
                    name: name.clone(),
                    lanes: decl.vtype.lanes,
                    depth,
                    crosses_domains: crosses,
                });
            }
            Storage::Hbm { bank } => {
                if !decl.transient {
                    let scalars = container_scalars(g, name, env)?;
                    arrays.push((name.clone(), scalars, bank));
                }
            }
            _ => {}
        }
    }

    let domain_of = |id: NodeId| -> ClockDomain {
        match g.fast_factor_of(id) {
            Some(f) => ClockDomain::Fast { factor: f },
            None => ClockDomain::Slow,
        }
    };
    // CDC halves: sync slow-side, issuer/packer fast-side at the
    // crossing's own ratio (regions may differ under mixed pumping)
    let cdc_domain = |kind: CdcKind, factor: usize| -> ClockDomain {
        match kind {
            CdcKind::Synchronizer => ClockDomain::Slow,
            _ => ClockDomain::Fast { factor },
        }
    };

    // non-streamed graphs get fused reader/writer modules
    let is_streamed = g.node_ids().any(|id| g.node(id).is_io_module());

    for id in g.node_ids() {
        match g.node(id) {
            Node::Reader { data, stream, .. } => {
                let lanes = stream_lanes(g, stream);
                let scalars = container_scalars(g, data, env)?;
                modules.push(ModuleInst {
                    spec: ModuleSpec::Reader {
                        data: data.clone(),
                        stream: stream.clone(),
                        lanes,
                        elems: scalars / lanes.max(1),
                        bytes_per_cycle: HBM_BYTES_PER_CYCLE,
                    },
                    domain: ClockDomain::Slow,
                    resources: cost.reader_writer(lanes * 4),
                });
            }
            Node::Writer { data, stream, .. } => {
                let lanes = stream_lanes(g, stream);
                let scalars = container_scalars(g, data, env)?;
                modules.push(ModuleInst {
                    spec: ModuleSpec::Writer {
                        data: data.clone(),
                        stream: stream.clone(),
                        lanes,
                        elems: scalars / lanes.max(1),
                        bytes_per_cycle: HBM_BYTES_PER_CYCLE,
                    },
                    domain: ClockDomain::Slow,
                    resources: cost.reader_writer(lanes * 4),
                });
            }
            Node::Cdc { kind, input, output, factor, .. } => {
                let wide = match kind {
                    CdcKind::Issuer => stream_lanes(g, input),
                    _ => stream_lanes(g, output),
                };
                let (spec, res) = match kind {
                    CdcKind::Synchronizer => (
                        ModuleSpec::Sync { input: input.clone(), output: output.clone() },
                        cost.synchronizer(wide * 4),
                    ),
                    CdcKind::Issuer => (
                        ModuleSpec::Issuer {
                            input: input.clone(),
                            output: output.clone(),
                            factor: *factor,
                        },
                        cost.width_converter(wide * 4, *factor),
                    ),
                    CdcKind::Packer => (
                        ModuleSpec::Packer {
                            input: input.clone(),
                            output: output.clone(),
                            factor: *factor,
                        },
                        cost.width_converter(wide * 4, *factor),
                    ),
                };
                modules.push(ModuleInst { spec, domain: cdc_domain(*kind, *factor), resources: res });
            }
            Node::MapEntry { name, schedule, .. } => {
                // find the tasklet inside the scope
                let scope = g.scope_nodes(id);
                let tasklet = scope
                    .iter()
                    .find_map(|n| match g.node(*n) {
                        Node::Tasklet(t) => Some((*n, t.clone())),
                        _ => None,
                    });
                let (tid, tasklet) = match tasklet {
                    Some(x) => x,
                    None => continue, // library-node scopes handled below
                };
                // inputs: edges entry → tasklet
                let mut inputs: Vec<(String, String)> = Vec::new();
                for e in g.out_edges(id) {
                    let edge = g.edge(e);
                    if edge.dst == tid {
                        if let Some(conn) = &edge.memlet.dst_conn {
                            inputs.push((edge.memlet.data.clone(), conn.clone()));
                        }
                    }
                }
                // output: edge tasklet → exit
                let exit = g.find_map_exit(name).expect("validated");
                let mut output = None;
                for e in g.in_edges(exit) {
                    let edge = g.edge(e);
                    if edge.src == tid {
                        if let Some(conn) = &edge.memlet.src_conn {
                            output = Some((edge.memlet.data.clone(), conn.clone()));
                        }
                    }
                }
                let output =
                    output.ok_or_else(|| format!("map '{name}': tasklet output unwired"))?;

                // lanes: width of the output stream if it is a stream,
                // else the container width
                let lanes = stream_lanes(g, &output.0);
                // total scalar work = written container scalars; for
                // stream outputs walk to the writer's container
                let out_scalars = if g.container(&output.0).map(|d| d.kind)
                    == Some(ContainerKind::Stream)
                {
                    // the stream eventually drains into an array of the
                    // same element production count; use map range × lanes
                    // of the *slow-side* equivalent: range count is in
                    // wide transactions
                    let mv = scope_movement(g, id)?;
                    let _ = mv;
                    // compute from the map range directly below
                    0
                } else {
                    container_scalars(g, &output.0, env)?
                };
                let iterations = if out_scalars > 0 {
                    out_scalars / lanes.max(1)
                } else {
                    // map range count × (pump narrowing factor)
                    let count = match g.node(id) {
                        Node::MapEntry { ranges, .. } => {
                            let mut c: i64 = 1;
                            for r in ranges {
                                c *= r
                                    .count(env)
                                    .ok_or_else(|| format!("map '{name}': unbound range"))?;
                            }
                            c as usize
                        }
                        _ => unreachable!(),
                    };
                    // the compute consumes narrow transactions in
                    // resource mode: range was defined on wide txns
                    // (each region narrows by its own factor; throughput
                    // and bare-fast regions keep the wide/original count)
                    let widen = match (g.fast_factor_of(id), g.fast_mode_of(id)) {
                        (Some(f), Some(PumpMode::Resource)) => f,
                        _ => 1,
                    };
                    count * widen
                };

                // II from dependencies
                let dependent = *schedule == MapSchedule::Sequential || {
                    let mv = scope_movement(g, id)?;
                    has_loop_carried_dependency(&mv, env)
                };
                let ii = if dependent { lat.tasklet_latency(&tasklet) } else { 1 };
                let latency = lat.tasklet_latency(&tasklet);
                let ops = tasklet.op_counts();
                let mut res = cost.compute_block(&ops, lanes);
                if !is_streamed {
                    // fused single-kernel design: the AXI movers live in
                    // the same module (same silicon, priced here)
                    res += ResourceVec::ZERO; // movers priced via implicit reader/writer below
                }
                modules.push(ModuleInst {
                    spec: ModuleSpec::Compute {
                        name: name.clone(),
                        tasklet,
                        inputs,
                        output,
                        lanes,
                        iterations,
                        ii,
                        latency,
                    },
                    domain: domain_of(id),
                    resources: res,
                });
            }
            Node::Library { name, op } => {
                let (inputs, outputs) = library_streams(g, id);
                match op {
                    LibraryOp::SystolicGemm { pes, vec_width, tile_m, tile_n } => {
                        let n = env.get("N").ok_or("GEMM needs symbol N")? as usize;
                        let m = env.get("M").ok_or("GEMM needs symbol M")? as usize;
                        let k = env.get("K").ok_or("GEMM needs symbol K")? as usize;
                        if inputs.len() < 2 || outputs.is_empty() {
                            return Err(format!("gemm '{name}': needs 2 inputs, 1 output"));
                        }
                        let mac = crate::ir::tasklet::OpCounts {
                            adds: 1,
                            muls: 1,
                            divs: 0,
                            minmax: 0,
                        };
                        let mut res = cost.compute_block(&mac, pes * vec_width);
                        // per-PE control overhead (forwarding, counters)
                        res += cost.systolic_pe_control(*vec_width).scaled(*pes as f64);
                        // per-PE double-buffered output tile partition,
                        // banked across the vector lanes
                        let tile_bytes = tile_m * tile_n * 4 / pes.max(&1);
                        res += cost.bram_buffer(2 * tile_bytes, *vec_width).scaled(*pes as f64);
                        // feeders/drainers
                        res += cost.reader_writer(vec_width * 4).scaled(3.0);
                        modules.push(ModuleInst {
                            spec: ModuleSpec::GemmCore {
                                name: name.clone(),
                                a: inputs[0].clone(),
                                b: inputs[1].clone(),
                                c: outputs[0].clone(),
                                n,
                                m,
                                k,
                                pes: *pes,
                                lanes: *vec_width,
                                tile_m: *tile_m,
                                tile_n: *tile_n,
                            },
                            domain: domain_of(id),
                            resources: res,
                        });
                    }
                    LibraryOp::FloydWarshall { .. } => {
                        let n = env.get("N").ok_or("FW needs symbol N")? as usize;
                        if inputs.is_empty() || outputs.is_empty() {
                            return Err(format!("fw '{name}': unwired"));
                        }
                        // external feed width (slow side) vs datapath width
                        let lanes = stream_lanes(g, &inputs[0]);
                        // II: conservative RAW handling of the in-place
                        // update — f32 add + min chain (paper Table 6
                        // cycle behaviour: n³·21 cycles at n=500)
                        let relax = Tasklet::new(
                            "relax",
                            vec![(
                                "out",
                                crate::ir::TaskExpr::input("dij").min(
                                    crate::ir::TaskExpr::input("dik")
                                        .add(crate::ir::TaskExpr::input("dkj")),
                                ),
                            )],
                        );
                        let ii = lat.tasklet_latency(&relax);
                        let ops = relax.op_counts();
                        // datapath replicated per external lane so the
                        // wide feed can be consumed at rate
                        let mut res = cost.compute_block(&ops, lanes);
                        // ping-pong row-block buffer (Table 6: ~34 %
                        // BRAM at n=500)
                        res += cost.bram_buffer(n * n * 8 / 5, 1);
                        modules.push(ModuleInst {
                            spec: ModuleSpec::FwCore {
                                name: name.clone(),
                                input: inputs[0].clone(),
                                output: outputs[0].clone(),
                                n,
                                lanes,
                                ii,
                            },
                            domain: domain_of(id),
                            resources: res,
                        });
                    }
                    LibraryOp::StencilStage { kind, vec_width } => {
                        let nx = env.get("NX").ok_or("stencil needs NX")? as usize;
                        let ny = env.get("NY").ok_or("stencil needs NY")? as usize;
                        let nz = env.get("NZ").ok_or("stencil needs NZ")? as usize;
                        if inputs.is_empty() || outputs.is_empty() {
                            return Err(format!("stencil '{name}': unwired"));
                        }
                        let ops = stencil_ops(*kind);
                        let mut res = cost.compute_block(&ops, *vec_width);
                        // two plane line buffers (ny×nz), banked per lane
                        let plane_bytes = ny * nz * 4;
                        res += cost.bram_buffer(2 * plane_bytes, (*vec_width).max(1) / 2 + 1);
                        modules.push(ModuleInst {
                            spec: ModuleSpec::StencilCore {
                                name: name.clone(),
                                kind: *kind,
                                input: inputs[0].clone(),
                                output: outputs[0].clone(),
                                nx,
                                ny,
                                nz,
                                lanes: *vec_width,
                            },
                            domain: domain_of(id),
                            resources: res,
                        });
                    }
                }
            }
            _ => {}
        }
    }

    // implicit AXI movers for non-streamed designs: price one
    // reader/writer per external array (they exist inside the fused
    // kernel on hardware)
    if !is_streamed {
        for (name, _, _) in &arrays {
            let lanes = g.container(name).map(|d| d.vtype.lanes).unwrap_or(1);
            // synthesize reader/writer modules so the simulator paces
            // memory exactly like the streamed design
            let scalars = container_scalars(g, name, env)?;
            let is_written = g.node_ids().any(|id| {
                matches!(g.node(id), Node::Access { data } if data == name)
                    && !g.in_edges(id).is_empty()
            });
            let is_read = g.node_ids().any(|id| {
                matches!(g.node(id), Node::Access { data } if data == name)
                    && !g.out_edges(id).is_empty()
            });
            let stream = format!("__mem_{name}");
            channels.push(ChannelSpec {
                name: stream.clone(),
                lanes,
                depth: 4,
                crosses_domains: false,
            });
            if is_read && !is_written {
                modules.push(ModuleInst {
                    spec: ModuleSpec::Reader {
                        data: name.clone(),
                        stream: stream.clone(),
                        lanes,
                        elems: scalars / lanes.max(1),
                        bytes_per_cycle: HBM_BYTES_PER_CYCLE,
                    },
                    domain: ClockDomain::Slow,
                    resources: cost.reader_writer(lanes * 4),
                });
            } else if is_written {
                modules.push(ModuleInst {
                    spec: ModuleSpec::Writer {
                        data: name.clone(),
                        stream: stream.clone(),
                        lanes,
                        elems: scalars / lanes.max(1),
                        bytes_per_cycle: HBM_BYTES_PER_CYCLE,
                    },
                    domain: ClockDomain::Slow,
                    resources: cost.reader_writer(lanes * 4),
                });
            }
        }
        // rewire compute inputs/outputs to the implicit memory streams
        for m in &mut modules {
            if let ModuleSpec::Compute { inputs, output, .. } = &mut m.spec {
                for (s, _) in inputs.iter_mut() {
                    if g.container(s).map(|d| d.kind) == Some(ContainerKind::Array) {
                        *s = format!("__mem_{s}");
                    }
                }
                if g.container(&output.0).map(|d| d.kind) == Some(ContainerKind::Array) {
                    output.0 = format!("__mem_{}", output.0);
                }
            }
        }
    }

    // one controller per kernel (paper §3.3) plus the platform
    // infrastructure every design pays once (shell glue, AXI
    // interconnect, DMA, HBM switch); multi-pumped designs add the
    // clock wizard + reset synchronizers.
    let mut controller = cost.controller() + cost.platform_infra();
    if pump.is_some() {
        controller += cost.controller().scaled(0.4); // clock wizard + resets
    }
    modules.push(ModuleInst {
        spec: ModuleSpec::Sync { input: "__ctrl_in".into(), output: "__ctrl_out".into() },
        domain: ClockDomain::Slow,
        resources: controller,
    });
    channels.push(ChannelSpec { name: "__ctrl_in".into(), lanes: 1, depth: 2, crosses_domains: false });
    channels.push(ChannelSpec { name: "__ctrl_out".into(), lanes: 1, depth: 2, crosses_domains: false });

    // FIFO resources
    let mut fifo_res = ResourceVec::ZERO;
    for c in &channels {
        if !c.name.starts_with("__ctrl") {
            fifo_res += cost.fifo(c.depth, c.lanes * 4);
        }
    }
    if let Some(m) = modules.last_mut() {
        m.resources += fifo_res;
    }

    let repeat = match &g.repeat {
        Some(r) => r
            .range
            .count(env)
            .ok_or_else(|| "unbound repeat range".to_string())? as usize,
        None => 1,
    };

    Ok(Design {
        name: g.name.clone(),
        modules,
        channels,
        pump,
        domain_modes,
        arrays,
        repeat,
        slr_replicas: 1,
        cl0_request_mhz: None,
    })
}

/// Input/output stream names of a library node.
fn library_streams(g: &Sdfg, id: NodeId) -> (Vec<String>, Vec<String>) {
    let mut inputs = Vec::new();
    for e in g.in_edges(id) {
        inputs.push(g.edge(e).memlet.data.clone());
    }
    let mut outputs = Vec::new();
    for e in g.out_edges(id) {
        outputs.push(g.edge(e).memlet.data.clone());
    }
    (inputs, outputs)
}

/// Op counts per output element for the stencil flavours (calibration
/// in DESIGN.md §8).
pub fn stencil_ops(kind: crate::ir::StencilKind) -> crate::ir::tasklet::OpCounts {
    match kind {
        // 5 adds to sum 6 neighbours + 1 const mul = 13 DSP/lane
        crate::ir::StencilKind::Jacobi3D => crate::ir::tasklet::OpCounts {
            adds: 5,
            muls: 1,
            divs: 0,
            minmax: 0,
        },
        // weighted update, unfactored datapath as the FPGA evaluates
        // it: 7 adds + 5 muls = 29 DSP/lane (Table 5: 31.67 % at
        // 4 lanes × 8 stages). GOp accounting follows the hardware
        // datapath, like the paper's.
        crate::ir::StencilKind::Diffusion3D => crate::ir::tasklet::OpCounts {
            adds: 7,
            muls: 5,
            divs: 0,
            minmax: 0,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::vecadd_sdfg;
    use crate::transforms::{MultiPump, PassManager, StreamingComposition, Vectorize};

    fn lower_vecadd(lanes: usize, pump: bool) -> Design {
        let mut g = vecadd_sdfg(1);
        let mut pm = PassManager::new();
        if lanes > 1 {
            pm.run(&mut g, &Vectorize::new("vadd", lanes)).unwrap();
        }
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        if pump {
            pm.run(&mut g, &MultiPump::resource(2)).unwrap();
        }
        let env = g.bind(&[("N", 1024)]).unwrap();
        lower(&g, &env, &CostModel::default()).unwrap()
    }

    #[test]
    fn vecadd_original_design() {
        let d = lower_vecadd(4, false);
        let readers = d
            .modules
            .iter()
            .filter(|m| matches!(m.spec, ModuleSpec::Reader { .. }))
            .count();
        assert_eq!(readers, 2);
        let comp = d
            .modules
            .iter()
            .find(|m| matches!(m.spec, ModuleSpec::Compute { .. }))
            .unwrap();
        if let ModuleSpec::Compute { lanes, iterations, ii, .. } = &comp.spec {
            assert_eq!(*lanes, 4);
            assert_eq!(*iterations, 256); // 1024/4 wide transactions
            assert_eq!(*ii, 1);
        }
        assert!(d.pump.is_none());
        // DSP: 4 lanes × 1 add × 2 = 8
        assert_eq!(d.total_resources().dsp, 8.0);
    }

    #[test]
    fn vecadd_double_pumped_design() {
        let d = lower_vecadd(4, true);
        assert_eq!(d.pump, Some((2, crate::ir::PumpMode::Resource)));
        // 6 CDC modules
        let syncs = d
            .modules
            .iter()
            .filter(|m| matches!(m.spec, ModuleSpec::Sync { .. }))
            .count();
        assert!(syncs >= 3, "{syncs}"); // 3 stream syncs + controller pseudo-sync
        // compute narrowed to 2 lanes, twice the firings, in fast domain
        let comp = d
            .modules
            .iter()
            .find(|m| matches!(m.spec, ModuleSpec::Compute { .. }))
            .unwrap();
        if let ModuleSpec::Compute { lanes, iterations, .. } = &comp.spec {
            assert_eq!(*lanes, 2);
            assert_eq!(*iterations, 512);
        }
        assert_eq!(comp.domain, ClockDomain::Fast { factor: 2 });
        // DSP halved: 2 lanes × 2 = 4
        assert_eq!(d.total_resources().dsp, 4.0);
    }

    #[test]
    fn dsp_halving_is_exact() {
        let o = lower_vecadd(8, false);
        let dp = lower_vecadd(8, true);
        assert_eq!(dp.total_resources().dsp, o.total_resources().dsp / 2.0);
        // LUT/register overhead is small but positive (paper: < 1 %)
        assert!(dp.total_resources().lut_logic > o.total_resources().lut_logic);
        let delta = (dp.total_resources().lut_logic - o.total_resources().lut_logic)
            / 439_000.0;
        assert!(delta < 0.01, "LUT overhead {delta}");
    }

    #[test]
    fn unstreamed_graph_gets_implicit_movers() {
        let g = vecadd_sdfg(2);
        let env = g.bind(&[("N", 64)]).unwrap();
        let d = lower(&g, &env, &CostModel::default()).unwrap();
        let readers = d
            .modules
            .iter()
            .filter(|m| matches!(m.spec, ModuleSpec::Reader { .. }))
            .count();
        let writers = d
            .modules
            .iter()
            .filter(|m| matches!(m.spec, ModuleSpec::Writer { .. }))
            .count();
        assert_eq!((readers, writers), (2, 1));
    }
}
