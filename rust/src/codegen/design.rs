//! The design netlist: what gets "placed on the FPGA".

use crate::hw::ResourceVec;
use crate::ir::{ClockDomain, PumpMode, StencilKind, Tasklet};

/// A FIFO channel instance.
#[derive(Clone, Debug)]
pub struct ChannelSpec {
    pub name: String,
    /// Elements per transaction.
    pub lanes: usize,
    /// Capacity in transactions.
    pub depth: usize,
    /// True when this channel connects two clock domains (implemented
    /// inside the synchronizer IP on hardware).
    pub crosses_domains: bool,
}

/// Behavioural content of a module.
#[derive(Clone, Debug)]
pub enum ModuleSpec {
    /// Streams `elems` elements of container `data` (lanes per txn)
    /// into `stream`, reading from its HBM bank at `bytes_per_cycle`.
    Reader { data: String, stream: String, lanes: usize, elems: usize, bytes_per_cycle: usize },
    /// Drains `stream` into container `data`.
    Writer { data: String, stream: String, lanes: usize, elems: usize, bytes_per_cycle: usize },
    /// Pipelined map: pops one txn from each input stream per firing,
    /// evaluates `tasklet` per lane, pushes one txn to `output`.
    Compute {
        name: String,
        tasklet: Tasklet,
        /// (stream, tasklet connector) per input.
        inputs: Vec<(String, String)>,
        output: (String, String),
        lanes: usize,
        /// Firings per graph execution.
        iterations: usize,
        /// Initiation interval (cycles between firings; >1 for
        /// dependent computations such as Floyd–Warshall).
        ii: u64,
        /// Pipeline latency (fill cycles).
        latency: u64,
    },
    /// Clock-domain synchronizer (1 txn/cycle passthrough).
    Sync { input: String, output: String },
    /// Wide→narrow converter: 1 wide txn in, `factor` narrow out.
    Issuer { input: String, output: String, factor: usize },
    /// Narrow→wide converter: `factor` narrow in, 1 wide out.
    Packer { input: String, output: String, factor: usize },
    /// Behavioural communication-avoiding systolic GEMM core [10]:
    /// `pes × lanes` MACs per cycle over an n×k · k×m problem.
    GemmCore {
        name: String,
        a: String,
        b: String,
        c: String,
        n: usize,
        m: usize,
        k: usize,
        pes: usize,
        lanes: usize,
        tile_m: usize,
        tile_n: usize,
    },
    /// Behavioural stencil stage: one txn in → one txn out per cycle
    /// after line-buffer warmup.
    StencilCore {
        name: String,
        kind: StencilKind,
        input: String,
        output: String,
        nx: usize,
        ny: usize,
        nz: usize,
        lanes: usize,
    },
    /// Streaming Floyd–Warshall datapath: per outer iteration `k`, the
    /// n×n distance matrix streams through in row-major order while row
    /// k+1 / column k+1 are captured into double buffers for the next
    /// iteration (the standard streaming-FW FPGA structure). The
    /// in-place read-modify-write forces a conservative II equal to the
    /// f32 add+min chain — the paper's Table 6 cycle behaviour.
    FwCore { name: String, input: String, output: String, n: usize, lanes: usize, ii: u64 },
}

impl ModuleSpec {
    pub fn label(&self) -> String {
        match self {
            ModuleSpec::Reader { data, .. } => format!("read_{data}"),
            ModuleSpec::Writer { data, .. } => format!("write_{data}"),
            ModuleSpec::Compute { name, .. } => name.clone(),
            ModuleSpec::Sync { output, .. } => format!("sync→{output}"),
            ModuleSpec::Issuer { output, .. } => format!("issue→{output}"),
            ModuleSpec::Packer { output, .. } => format!("pack→{output}"),
            ModuleSpec::GemmCore { name, .. } => name.clone(),
            ModuleSpec::StencilCore { name, .. } => name.clone(),
            ModuleSpec::FwCore { name, .. } => name.clone(),
        }
    }

    /// Input stream names.
    pub fn inputs(&self) -> Vec<String> {
        match self {
            ModuleSpec::Reader { .. } => vec![],
            ModuleSpec::Writer { stream, .. } => vec![stream.clone()],
            ModuleSpec::Compute { inputs, .. } => {
                inputs.iter().map(|(s, _)| s.clone()).collect()
            }
            ModuleSpec::Sync { input, .. }
            | ModuleSpec::Issuer { input, .. }
            | ModuleSpec::Packer { input, .. } => vec![input.clone()],
            ModuleSpec::GemmCore { a, b, .. } => vec![a.clone(), b.clone()],
            ModuleSpec::StencilCore { input, .. } => vec![input.clone()],
            ModuleSpec::FwCore { input, .. } => vec![input.clone()],
        }
    }

    /// Output stream names.
    pub fn outputs(&self) -> Vec<String> {
        match self {
            ModuleSpec::Reader { stream, .. } => vec![stream.clone()],
            ModuleSpec::Writer { .. } => vec![],
            ModuleSpec::Compute { output, .. } => vec![output.0.clone()],
            ModuleSpec::Sync { output, .. }
            | ModuleSpec::Issuer { output, .. }
            | ModuleSpec::Packer { output, .. } => vec![output.clone()],
            ModuleSpec::GemmCore { c, .. } => vec![c.clone()],
            ModuleSpec::StencilCore { output, .. } => vec![output.clone()],
            ModuleSpec::FwCore { output, .. } => vec![output.clone()],
        }
    }
}

/// A placed module.
#[derive(Clone, Debug)]
pub struct ModuleInst {
    pub spec: ModuleSpec,
    pub domain: ClockDomain,
    pub resources: ResourceVec,
}

/// The full design.
#[derive(Clone, Debug)]
pub struct Design {
    pub name: String,
    pub modules: Vec<ModuleInst>,
    pub channels: Vec<ChannelSpec>,
    /// Multi-pumping configuration, if applied: the largest factor and
    /// its region's mode — the representative tag reports print. Mixed
    /// designs carry the full per-domain picture in `domain_modes`.
    pub pump: Option<(usize, PumpMode)>,
    /// Pump mode per distinct fast-domain factor, `(factor, mode)` in
    /// ascending factor order. Empty when unpumped. The simulator's
    /// telemetry and `tvec top` label each fast domain with its mode
    /// from this table.
    pub domain_modes: Vec<(usize, PumpMode)>,
    /// External containers: (name, element count, HBM bank).
    pub arrays: Vec<(String, usize, usize)>,
    /// Whole-graph sequential repetitions (Floyd–Warshall's k loop).
    pub repeat: usize,
    /// Number of SLRs the design is replicated across (≥1).
    pub slr_replicas: usize,
    /// Requested CL0 in MHz (None → device default). Deeply pipelined
    /// small designs (Floyd–Warshall) request higher shell clocks.
    pub cl0_request_mhz: Option<f64>,
}

impl Design {
    pub fn channel(&self, name: &str) -> Option<&ChannelSpec> {
        self.channels.iter().find(|c| c.name == name)
    }

    pub fn fast_modules(&self) -> impl Iterator<Item = &ModuleInst> {
        self.modules.iter().filter(|m| m.domain != ClockDomain::Slow)
    }

    pub fn slow_modules(&self) -> impl Iterator<Item = &ModuleInst> {
        self.modules.iter().filter(|m| m.domain == ClockDomain::Slow)
    }

    /// Total resources of the design (one SLR replica).
    pub fn total_resources(&self) -> ResourceVec {
        let mut acc = ResourceVec::ZERO;
        for m in &self.modules {
            acc += m.resources;
        }
        acc
    }

    /// Resources summed over *all* fast domains. Mixed per-region
    /// designs carry several fast domains — `estimate` prices each
    /// distinct factor separately; this is the combined fast-side
    /// total (reporting/debug, not a timing input).
    pub fn fast_resources(&self) -> ResourceVec {
        let mut acc = ResourceVec::ZERO;
        for m in self.fast_modules() {
            acc += m.resources;
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::TaskExpr;

    #[test]
    fn spec_io_lists() {
        let c = ModuleSpec::Compute {
            name: "add".into(),
            tasklet: Tasklet::new("add", vec![("o", TaskExpr::input("a"))]),
            inputs: vec![("s1".into(), "a".into())],
            output: ("s2".into(), "o".into()),
            lanes: 4,
            iterations: 16,
            ii: 1,
            latency: 8,
        };
        assert_eq!(c.inputs(), vec!["s1"]);
        assert_eq!(c.outputs(), vec!["s2"]);
        let r = ModuleSpec::Reader {
            data: "x".into(),
            stream: "s1".into(),
            lanes: 4,
            elems: 64,
            bytes_per_cycle: 32,
        };
        assert!(r.inputs().is_empty());
        assert_eq!(r.outputs(), vec!["s1"]);
        assert_eq!(r.label(), "read_x");
    }

    #[test]
    fn design_resource_totals() {
        let mk = |dsp: f64, domain| ModuleInst {
            spec: ModuleSpec::Sync { input: "a".into(), output: "b".into() },
            domain,
            resources: ResourceVec { dsp, ..ResourceVec::ZERO },
        };
        let d = Design {
            name: "t".into(),
            modules: vec![
                mk(1.0, ClockDomain::Slow),
                mk(2.0, ClockDomain::Fast { factor: 2 }),
            ],
            channels: vec![],
            pump: Some((2, PumpMode::Resource)),
            domain_modes: vec![(2, PumpMode::Resource)],
            arrays: vec![],
            repeat: 1,
            slr_replicas: 1,
            cl0_request_mhz: None,
        };
        assert_eq!(d.total_resources().dsp, 3.0);
        assert_eq!(d.fast_resources().dsp, 2.0);
        assert_eq!(d.slow_modules().count(), 1);
    }
}
