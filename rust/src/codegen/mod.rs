//! Code generation: IR → design netlist → HLS/RTL text.
//!
//! [`design`] defines the netlist the rest of the system consumes: the
//! flat list of hardware modules (readers, writers, compute pipelines,
//! CDC plumbing, expanded library cores), the FIFO channels between
//! them, their clock-domain assignment and per-module resource cost.
//!
//! [`lower`] produces a [`design::Design`] from a (possibly transformed)
//! SDFG under concrete symbol bindings — the analog of DaCe's codegen
//! phase. [`estimate`] prices the design and runs the timing model,
//! yielding exactly the rows the paper's tables report. [`hls`]/[`rtl`]
//! emit the textual artifacts of paper §3.3 (HLS C++ per kernel; the
//! four RTL files: SystemVerilog controller, SystemVerilog core,
//! Verilog top-level, TCL packaging script).

pub mod design;
pub mod estimate;
pub mod hls;
pub mod lower;
pub mod rtl;

pub use design::{ChannelSpec, Design, ModuleInst, ModuleSpec};
pub use estimate::{estimate, DesignReport};
pub use lower::lower;
