//! Design pricing: utilization + clock reports (the "Vivado report"
//! the experiment tables read their resource/frequency rows from).

use super::design::Design;
use crate::hw::timing::{effective_clock, DomainProfile, TimingModel};
use crate::hw::{ClockReport, Device, ResourceVec, Utilization};
use crate::util::Rng;

/// Everything the paper reports per design variant.
#[derive(Clone, Debug)]
pub struct DesignReport {
    pub name: String,
    /// Whole-design resource vector (single SLR replica).
    pub resources: ResourceVec,
    pub util: Utilization,
    /// Slow-domain (shell) clock after P&R.
    pub cl0: ClockReport,
    /// Fastest fast-domain clock, if multi-pumped. Mixed per-region
    /// designs close one clock per distinct factor; this reports the
    /// largest-factor domain (CL1 in the uniform case), while
    /// `effective_mhz` already accounts for every domain.
    pub cl1: Option<ClockReport>,
    /// Effective clock rate min(CL0, min over domains of CLd/Md) in MHz.
    pub effective_mhz: f64,
    /// Largest pump factor (1 when unpumped).
    pub pump_factor: usize,
}

impl DesignReport {
    /// Utilization percentages in table order
    /// (LUT logic, LUT memory, registers, BRAM, DSP).
    pub fn util_percent(&self) -> [f64; 5] {
        self.util.percentages()
    }
}

/// Price a design on a device and run the timing model.
///
/// `seed` drives the deterministic P&R jitter — the same design and
/// seed always produce the same report.
pub fn estimate(design: &Design, device: &Device, tm: &TimingModel, seed: u64) -> DesignReport {
    let pool = device.slr0_pool();
    let total = design.total_resources();
    let util = total.utilization(&pool);

    // decorrelate jitter across design variants (O vs DP columns show
    // independent P&R scatter in the paper's tables)
    let mut h: u64 = 0xcbf29ce484222325;
    for b in design.name.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    h ^= design.pump.map(|(m, _)| m as u64).unwrap_or(0) << 32;
    h ^= design.modules.len() as u64;
    let mut rng = Rng::new(seed ^ h ^ 0x7e3a_91c5);

    let cl0_request = design.cl0_request_mhz.unwrap_or(device.shell_clock_mhz * 1.12);

    // SLR crossings when replicated beyond one SLR
    let crossings = design.slr_replicas.saturating_sub(1);

    match design.pump {
        None => {
            let profile = DomainProfile {
                util,
                design_util: util,
                touches_io: true,
                slr_crossings: crossings,
            };
            let cl0 = tm.achieve(cl0_request, &profile, &mut rng);
            DesignReport {
                name: design.name.clone(),
                resources: total,
                util,
                cl0,
                cl1: None,
                effective_mhz: effective_clock(cl0.achieved_mhz, None, 1),
                pump_factor: 1,
            }
        }
        Some((factor, _mode)) => {
            // slow domain: readers/writers + plumbing (IO span)
            let slow_res: ResourceVec = design
                .slow_modules()
                .fold(ResourceVec::ZERO, |acc, m| acc + m.resources);
            let slow_util = slow_res.utilization(&pool);
            let slow_profile = DomainProfile {
                util: slow_util,
                design_util: util,
                touches_io: true,
                slr_crossings: crossings,
            };
            let cl0 = tm.achieve(cl0_request, &slow_profile, &mut rng);

            // fast domains: one clock per distinct factor (uniform
            // pumping has exactly one — identical draws to the legacy
            // path). Each domain is an isolated compute subgraph —
            // short local paths only, no IO span — and each bounds the
            // effective rate by CLd / Md. The closure is mode-agnostic:
            // resource domains are narrow (÷M datapaths close high),
            // throughput domains carry the original width at M×, and
            // bare-fast domains carry the original width with zero
            // gearbox logic — their CLd / Md bound prices exactly the
            // "can the unchanged II>1 datapath really clock M× faster"
            // question. Leaner domains close higher MHz, which is how
            // mixed-mode assignments land on the frontier.
            let mut factors: Vec<usize> = design
                .modules
                .iter()
                .filter_map(|m| match m.domain {
                    crate::ir::ClockDomain::Fast { factor } => Some(factor),
                    crate::ir::ClockDomain::Slow => None,
                })
                .collect();
            factors.sort_unstable();
            factors.dedup();
            if factors.is_empty() {
                factors.push(factor); // degenerate: tagged pumped, no fast module
            }
            let mut cl1: Option<ClockReport> = None;
            let mut eff_fast = f64::INFINITY;
            for &f in &factors {
                let fast_res: ResourceVec = design
                    .modules
                    .iter()
                    .filter(|m| m.domain == crate::ir::ClockDomain::Fast { factor: f })
                    .fold(ResourceVec::ZERO, |acc, m| acc + m.resources);
                let fast_util = fast_res.utilization(&pool);
                let fast_profile = DomainProfile {
                    util: fast_util,
                    design_util: util,
                    touches_io: false,
                    slr_crossings: crossings,
                };
                let requested = (cl0.achieved_mhz * f as f64).min(device.max_requested_mhz);
                let cl = tm.achieve(requested, &fast_profile, &mut rng);
                eff_fast = eff_fast.min(cl.achieved_mhz / f as f64);
                // ascending factor order: the last report is the
                // fastest (largest-factor) domain — CL1 when uniform
                cl1 = Some(cl);
            }

            let eff = effective_clock(cl0.achieved_mhz, Some(eff_fast), 1);
            DesignReport {
                name: design.name.clone(),
                resources: total,
                util,
                cl0,
                cl1,
                effective_mhz: eff,
                pump_factor: factor,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::lower::lower;
    use crate::hw::cost::CostModel;
    use crate::ir::builder::vecadd_sdfg;
    use crate::transforms::{MultiPump, PassManager, StreamingComposition, Vectorize};

    fn reports(lanes: usize) -> (DesignReport, DesignReport) {
        let device = Device::u280();
        let tm = TimingModel::default();
        let cost = CostModel::default();

        let mut g = vecadd_sdfg(1);
        let mut pm = PassManager::new();
        pm.run(&mut g, &Vectorize::new("vadd", lanes)).unwrap();
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        let env = g.bind(&[("N", 1 << 20)]).unwrap();
        let o = estimate(&lower(&g, &env, &cost).unwrap(), &device, &tm, 7);

        pm.run(&mut g, &MultiPump::resource(2)).unwrap();
        let dp = estimate(&lower(&g, &env, &cost).unwrap(), &device, &tm, 7);
        (o, dp)
    }

    #[test]
    fn table2_shape_for_vecadd() {
        let (o, dp) = reports(8);
        // DSP halves
        assert!((dp.util.dsp - o.util.dsp / 2.0).abs() < 1e-9);
        // LUT/register overhead below 1 % of the pool
        assert!(dp.util.lut_logic - o.util.lut_logic < 0.01);
        assert!(dp.util.registers - o.util.registers < 0.01);
        // CL1 well above CL0
        let cl1 = dp.cl1.unwrap();
        assert!(cl1.achieved_mhz > 1.5 * dp.cl0.achieved_mhz);
        // effective clock close to CL0 (vecadd is tiny → CL1 ≈ 2×CL0)
        assert!(dp.effective_mhz > 0.85 * dp.cl0.achieved_mhz);
        // original runs at ~shell clock
        assert!(o.cl0.achieved_mhz > 290.0 && o.cl0.achieved_mhz < 372.0);
    }

    #[test]
    fn effective_clock_min_rule_applies() {
        let (_, dp) = reports(4);
        let cl1 = dp.cl1.unwrap();
        let expect = dp.cl0.achieved_mhz.min(cl1.achieved_mhz / 2.0);
        assert!((dp.effective_mhz - expect).abs() < 1e-9);
    }

    #[test]
    fn deterministic_reports() {
        let (a, _) = reports(2);
        let (b, _) = reports(2);
        assert_eq!(a.cl0.achieved_mhz, b.cl0.achieved_mhz);
    }
}
