//! The multi-pumping transformation (paper Figure 3, box ③) — the
//! paper's central contribution, as an automatic graph rewrite.
//!
//! Preconditions (checked by `can_apply`):
//! * the graph has been streamed ([`super::StreamingComposition`]) —
//!   the compute subgraph talks to readers/writers through streams;
//! * the compute scopes pass the *temporal* vectorizability check
//!   ([`crate::analysis::check_temporal`]): dependencies allowed, no
//!   data-dependent external I/O;
//! * resource mode additionally requires the internal vector width to
//!   divide by the pumping factor M.
//!
//! The rewrite constructs two clock domains (readers/writers stay in
//! CL0; the entire compute subgraph moves to CL1 = M·CL0) and injects
//! the three AXI4-Stream plumbing modules on every crossing stream:
//!
//! ```text
//!  into the domain:  s ──[synchronizer]── s_x ──[issuer ÷M]── s_fast
//!  out of the domain: s_fast ──[packer ×M]── s_x ──[synchronizer]── s
//! ```
//!
//! * **Resource mode** (waveform ③, §2.1): the fast-side streams carry
//!   `lanes/M` elements per transaction; the compute block needs only
//!   `V/M` lanes to sustain the same throughput — DSP/BRAM cut by M.
//! * **Throughput mode** (waveform ②, §2.1): the slow-side streams and
//!   reader/writer ports are widened to `lanes·M`; the compute block is
//!   unchanged and processes M transactions per slow cycle — M× the
//!   throughput at equal compute resources (Floyd–Warshall's mode).

use super::pass::{Transform, TransformReport};
use crate::analysis::movement::scope_movement;
use crate::analysis::vectorizability::check_temporal;
use crate::ir::{
    CdcKind, ContainerKind, DataDecl, LibraryOp, Memlet, MultipumpInfo, Node, NodeId, PumpMode,
    Sdfg, Storage,
};
use crate::symbolic::{Expr, Subset};

/// Apply multi-pumping at `factor` in the given mode.
pub struct MultiPump {
    pub factor: usize,
    pub mode: PumpMode,
}

impl MultiPump {
    pub fn resource(factor: usize) -> Self {
        MultiPump { factor, mode: PumpMode::Resource }
    }

    pub fn throughput(factor: usize) -> Self {
        MultiPump { factor, mode: PumpMode::Throughput }
    }
}

/// Streams that cross from the slow domain into the compute domain
/// (fed by a Reader) and out of it (drained by a Writer).
fn boundary_streams(g: &Sdfg) -> (Vec<String>, Vec<String>) {
    let mut into = Vec::new();
    let mut out_of = Vec::new();
    for id in g.node_ids() {
        match g.node(id) {
            Node::Reader { stream, .. } => into.push(stream.clone()),
            Node::Writer { stream, .. } => out_of.push(stream.clone()),
            _ => {}
        }
    }
    (into, out_of)
}

/// All compute-side nodes: everything that is not a reader/writer, not
/// an external access, and not a boundary-stream access.
fn compute_side(g: &Sdfg, boundary: &[String]) -> Vec<NodeId> {
    g.node_ids()
        .filter(|id| match g.node(*id) {
            Node::Reader { .. } | Node::Writer { .. } | Node::Cdc { .. } => false,
            Node::Access { data } => {
                let decl = g.container(data).expect("validated");
                // stream accesses inside the domain belong to it;
                // boundary streams and external arrays do not
                decl.kind == ContainerKind::Stream && !boundary.contains(data)
            }
            _ => true,
        })
        .collect()
}

impl Transform for MultiPump {
    fn name(&self) -> String {
        format!(
            "MultiPump[M={} {}]",
            self.factor,
            match self.mode {
                PumpMode::Resource => "resource",
                PumpMode::Throughput => "throughput",
            }
        )
    }

    fn can_apply(&self, g: &Sdfg) -> Result<(), String> {
        if self.factor < 2 {
            return Err("pumping factor must be ≥ 2".into());
        }
        if g.multipump.is_some() {
            return Err("already multi-pumped".into());
        }
        let (into, out_of) = boundary_streams(g);
        if into.is_empty() && out_of.is_empty() {
            return Err("graph is not streamed (run StreamingComposition first)".into());
        }
        // temporal vectorizability of every map scope
        for id in g.node_ids() {
            if matches!(g.node(id), Node::MapEntry { .. }) {
                let mv = scope_movement(g, id)?;
                let verdict = check_temporal(g, &mv, 1);
                if !verdict.is_ok() {
                    return Err(format!(
                        "scope '{}': {}",
                        g.node(id).label(),
                        verdict.reasons().join("; ")
                    ));
                }
            }
        }
        // resource mode: every stream the design carries — boundary
        // AND internal (stencil-chain inter-kernel streams) — must
        // narrow exactly, and every library datapath must keep an
        // integer lane count. Rejecting here keeps an illegal factor
        // from surfacing later as a confusing lower/estimate error on
        // a half-narrowed graph.
        if self.mode == PumpMode::Resource {
            for (name, decl) in &g.containers {
                if decl.kind != ContainerKind::Stream {
                    continue;
                }
                let lanes = decl.vtype.lanes;
                if lanes % self.factor != 0 {
                    return Err(format!(
                        "resource mode: stream '{name}' width {lanes} not divisible by M={} \
                         (choose a factor dividing the vectorized stream width)",
                        self.factor
                    ));
                }
            }
            for id in g.node_ids() {
                if let Node::Library { name, op } = g.node(id) {
                    let w = match op {
                        LibraryOp::SystolicGemm { vec_width, .. }
                        | LibraryOp::StencilStage { vec_width, .. } => *vec_width,
                        // FW keeps its datapath width in resource mode
                        LibraryOp::FloydWarshall { .. } => continue,
                    };
                    if w % self.factor != 0 {
                        return Err(format!(
                            "resource mode: library '{name}' vector width {w} not divisible \
                             by M={}",
                            self.factor
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    fn apply(&self, g: &mut Sdfg) -> Result<TransformReport, String> {
        let (into, out_of) = boundary_streams(g);
        let m = self.factor;
        let mut plumbing = 0usize;

        // the fast domain contains the compute subgraph
        let fast_nodes = compute_side(g, &[into.clone(), out_of.clone()].concat());

        for s in &into {
            let decl = g.container(s).unwrap().clone();
            let depth = match decl.storage {
                Storage::Stream { depth } => depth,
                _ => unreachable!("boundary stream has stream storage"),
            };
            let (slow_lanes, fast_lanes) = match self.mode {
                // wide outside stays, narrow inside
                PumpMode::Resource => (decl.vtype.lanes, decl.vtype.lanes / m),
                // widen outside, keep inside
                PumpMode::Throughput => (decl.vtype.lanes * m, decl.vtype.lanes),
            };
            // widen the slow-side stream (throughput mode) and its
            // source array port
            if self.mode == PumpMode::Throughput {
                g.containers.get_mut(s).unwrap().vtype.lanes = slow_lanes;
            }
            let mut vt_x = decl.vtype;
            vt_x.lanes = slow_lanes;
            let mut vt_fast = decl.vtype;
            vt_fast.lanes = fast_lanes;

            let sx = format!("{s}_cdc");
            let sfast = format!("{s}_fast");
            g.declare(DataDecl {
                name: sx.clone(),
                kind: ContainerKind::Stream,
                vtype: vt_x,
                shape: vec![],
                storage: Storage::Stream { depth },
                transient: true,
            });
            g.declare(DataDecl {
                name: sfast.clone(),
                kind: ContainerKind::Stream,
                vtype: vt_fast,
                shape: vec![],
                storage: Storage::Stream { depth: depth * m },
                transient: true,
            });
            let sync = g.add_node(Node::Cdc {
                name: format!("sync_{s}"),
                kind: CdcKind::Synchronizer,
                input: s.clone(),
                output: sx.clone(),
                factor: m,
            });
            let issuer = g.add_node(Node::Cdc {
                name: format!("issue_{s}"),
                kind: CdcKind::Issuer,
                input: sx.clone(),
                output: sfast.clone(),
                factor: m,
            });
            let sx_acc = g.add_node(Node::Access { data: sx.clone() });
            let sfast_acc = g.add_node(Node::Access { data: sfast.clone() });
            // original stream access node (slow side)
            let s_acc = g
                .node_ids()
                .find(|id| matches!(g.node(*id), Node::Access { data } if data == s))
                .expect("stream access node exists");
            // consumers of s (compute side) move to s_fast
            let consumer_edges: Vec<usize> = g
                .edge_ids()
                .filter(|e| {
                    let edge = g.edge(*e);
                    edge.src == s_acc && edge.memlet.data == *s
                })
                .map(|e| e.0)
                .collect();
            for eidx in consumer_edges {
                g.edges[eidx].src = sfast_acc;
                g.edges[eidx].memlet.data = sfast.clone();
            }
            // inner scope edges popping s move to s_fast
            for e in g.edge_ids().collect::<Vec<_>>() {
                if g.edge(e).memlet.data == *s && g.edge(e).src != s_acc && g.edge(e).dst != s_acc
                {
                    g.edge_mut(e).memlet.data = sfast.clone();
                }
            }
            let pop = |d: &str| Memlet::new(d, Subset::index1(Expr::int(0)));
            g.add_edge(s_acc, sync, pop(s));
            g.add_edge(sync, sx_acc, pop(&sx));
            g.add_edge(sx_acc, issuer, pop(&sx));
            g.add_edge(issuer, sfast_acc, pop(&sfast));
            plumbing += 2;
        }

        for s in &out_of {
            let decl = g.container(s).unwrap().clone();
            let depth = match decl.storage {
                Storage::Stream { depth } => depth,
                _ => unreachable!(),
            };
            let (slow_lanes, fast_lanes) = match self.mode {
                PumpMode::Resource => (decl.vtype.lanes, decl.vtype.lanes / m),
                PumpMode::Throughput => (decl.vtype.lanes * m, decl.vtype.lanes),
            };
            if self.mode == PumpMode::Throughput {
                g.containers.get_mut(s).unwrap().vtype.lanes = slow_lanes;
            }
            let mut vt_x = decl.vtype;
            vt_x.lanes = slow_lanes;
            let mut vt_fast = decl.vtype;
            vt_fast.lanes = fast_lanes;

            let sx = format!("{s}_cdc");
            let sfast = format!("{s}_fast");
            g.declare(DataDecl {
                name: sx.clone(),
                kind: ContainerKind::Stream,
                vtype: vt_x,
                shape: vec![],
                storage: Storage::Stream { depth },
                transient: true,
            });
            g.declare(DataDecl {
                name: sfast.clone(),
                kind: ContainerKind::Stream,
                vtype: vt_fast,
                shape: vec![],
                storage: Storage::Stream { depth: depth * m },
                transient: true,
            });
            let packer = g.add_node(Node::Cdc {
                name: format!("pack_{s}"),
                kind: CdcKind::Packer,
                input: sfast.clone(),
                output: sx.clone(),
                factor: m,
            });
            let sync = g.add_node(Node::Cdc {
                name: format!("sync_{s}"),
                kind: CdcKind::Synchronizer,
                input: sx.clone(),
                output: s.clone(),
                factor: m,
            });
            let sx_acc = g.add_node(Node::Access { data: sx.clone() });
            let sfast_acc = g.add_node(Node::Access { data: sfast.clone() });
            let s_acc = g
                .node_ids()
                .find(|id| matches!(g.node(*id), Node::Access { data } if data == s))
                .expect("stream access node exists");
            // producers into s (compute side) move to s_fast
            let producer_edges: Vec<usize> = g
                .edge_ids()
                .filter(|e| {
                    let edge = g.edge(*e);
                    edge.dst == s_acc && edge.memlet.data == *s
                })
                .map(|e| e.0)
                .collect();
            for eidx in producer_edges {
                g.edges[eidx].dst = sfast_acc;
                g.edges[eidx].memlet.data = sfast.clone();
            }
            for e in g.edge_ids().collect::<Vec<_>>() {
                if g.edge(e).memlet.data == *s && g.edge(e).src != s_acc && g.edge(e).dst != s_acc
                {
                    g.edge_mut(e).memlet.data = sfast.clone();
                }
            }
            let pop = |d: &str| Memlet::new(d, Subset::index1(Expr::int(0)));
            g.add_edge(sfast_acc, packer, pop(&sfast));
            g.add_edge(packer, sx_acc, pop(&sx));
            g.add_edge(sx_acc, sync, pop(&sx));
            g.add_edge(sync, s_acc, pop(s));
            plumbing += 2;
        }

        // resource mode: the compute block's internal width shrinks —
        // narrow every non-boundary stream and scale PE/lane counts
        if self.mode == PumpMode::Resource {
            let boundary: Vec<String> = into.iter().chain(out_of.iter()).cloned().collect();
            let names: Vec<String> = g.containers.keys().cloned().collect();
            for name in names {
                let decl = g.containers.get_mut(&name).unwrap();
                let is_fast_stream = decl.kind == ContainerKind::Stream
                    && !boundary.contains(&name)
                    && !name.ends_with("_cdc");
                if is_fast_stream && !name.ends_with("_fast") && decl.vtype.lanes % m == 0 {
                    decl.vtype.lanes /= m;
                }
            }
            // library nodes shrink their lane width (PE vectorization)
            for id in g.node_ids().collect::<Vec<_>>() {
                if let Node::Library { op, .. } = g.node_mut(id) {
                    match op {
                        crate::ir::LibraryOp::SystolicGemm { vec_width, .. } => {
                            if *vec_width % m == 0 {
                                *vec_width /= m;
                            }
                        }
                        crate::ir::LibraryOp::StencilStage { vec_width, .. } => {
                            if *vec_width % m == 0 {
                                *vec_width /= m;
                            }
                        }
                        // FW keeps its compute width: resource mode does
                        // not apply to an unvectorized datapath
                        crate::ir::LibraryOp::FloydWarshall { .. } => {}
                    }
                }
            }
        }

        g.multipump = Some(MultipumpInfo { factor: m, mode: self.mode, fast_nodes });

        Ok(TransformReport {
            transform: self.name(),
            summary: format!(
                "2 clock domains constructed; {plumbing} plumbing modules injected over {} in / {} out streams",
                into.len(),
                out_of.len()
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::vecadd_sdfg;
    use crate::ir::validate::validate;
    use crate::transforms::pass::PassManager;
    use crate::transforms::{StreamingComposition, Vectorize};

    fn streamed_vecadd(lanes: usize) -> Sdfg {
        let mut g = vecadd_sdfg(1);
        let mut pm = PassManager::new();
        if lanes > 1 {
            pm.run(&mut g, &Vectorize::new("vadd", lanes)).unwrap();
        }
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        g
    }

    #[test]
    fn requires_streaming_first() {
        let g = vecadd_sdfg(1);
        let err = MultiPump::resource(2).can_apply(&g).unwrap_err();
        assert!(err.contains("not streamed"), "{err}");
    }

    #[test]
    fn resource_mode_requires_divisible_width() {
        let g = streamed_vecadd(1); // scalar streams
        let err = MultiPump::resource(2).can_apply(&g).unwrap_err();
        assert!(err.contains("not divisible"), "{err}");
        // width 4 divides
        let g4 = streamed_vecadd(4);
        MultiPump::resource(2).can_apply(&g4).unwrap();
    }

    #[test]
    fn double_pump_vecadd_resource_mode() {
        let mut g = streamed_vecadd(4);
        let mut pm = PassManager::new();
        let report = pm.run(&mut g, &MultiPump::resource(2)).unwrap().clone();
        validate(&g).unwrap();
        assert!(report.summary.contains("2 clock domains"), "{}", report.summary);
        let mp = g.multipump.as_ref().unwrap();
        assert_eq!(mp.factor, 2);
        assert_eq!(mp.mode, PumpMode::Resource);
        // per boundary stream: sync+issuer or packer+sync
        let cdc = g.node_ids().filter(|i| g.node(*i).is_cdc()).count();
        assert_eq!(cdc, 6); // 3 streams × 2 modules
        // fast-side stream narrowed to 2 lanes, slow side stays 4
        assert_eq!(g.container("x_to_vadd[entry]").unwrap().vtype.lanes, 4);
        assert_eq!(g.container("x_to_vadd[entry]_fast").unwrap().vtype.lanes, 2);
        // compute scope is in the fast domain, readers are not
        let entry = g.find_map_entry("vadd").unwrap();
        assert!(g.in_fast_domain(entry));
        let rd = g
            .node_ids()
            .find(|i| matches!(g.node(*i), Node::Reader { .. }))
            .unwrap();
        assert!(!g.in_fast_domain(rd));
    }

    #[test]
    fn double_pump_throughput_mode_widens_boundary() {
        let mut g = streamed_vecadd(2);
        let mut pm = PassManager::new();
        pm.run(&mut g, &MultiPump::throughput(2)).unwrap();
        validate(&g).unwrap();
        // slow-side stream doubled to 4 lanes, fast side keeps 2
        assert_eq!(g.container("x_to_vadd[entry]").unwrap().vtype.lanes, 4);
        assert_eq!(g.container("x_to_vadd[entry]_fast").unwrap().vtype.lanes, 2);
    }

    #[test]
    fn resource_mode_rejects_indivisible_internal_stream() {
        // stencil chain: the inter-kernel tmp stream is internal (no
        // reader/writer touches it). Desynchronize its width so only
        // the *internal* check can catch the illegal factor — before
        // this check, the factor slipped through can_apply and left a
        // half-narrowed graph for lower() to choke on.
        let mut g = crate::apps::stencil::build(crate::ir::StencilKind::Jacobi3D, 2, 4);
        let mut pm = PassManager::new();
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        g.containers.get_mut("tmp0").unwrap().vtype.lanes = 2;
        let err = MultiPump::resource(4).can_apply(&g).unwrap_err();
        assert!(err.contains("tmp0") && err.contains("not divisible"), "{err}");
    }

    #[test]
    fn resource_mode_rejects_indivisible_library_width() {
        let mut g = crate::apps::stencil::build(crate::ir::StencilKind::Jacobi3D, 1, 4);
        let mut pm = PassManager::new();
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        // a datapath whose lane count would not stay an integer
        for id in g.node_ids().collect::<Vec<_>>() {
            if let Node::Library {
                op: crate::ir::LibraryOp::StencilStage { vec_width, .. },
                ..
            } = g.node_mut(id)
            {
                *vec_width = 3;
            }
        }
        let err = MultiPump::resource(2).can_apply(&g).unwrap_err();
        assert!(err.contains("library") && err.contains("not divisible"), "{err}");
    }

    #[test]
    fn cannot_pump_twice() {
        let mut g = streamed_vecadd(4);
        let mut pm = PassManager::new();
        pm.run(&mut g, &MultiPump::resource(2)).unwrap();
        let err = pm.run(&mut g, &MultiPump::resource(2)).unwrap_err();
        assert!(err.contains("already multi-pumped"), "{err}");
    }

    #[test]
    fn quad_pump_resource_mode() {
        let mut g = streamed_vecadd(8);
        let mut pm = PassManager::new();
        pm.run(&mut g, &MultiPump::resource(4)).unwrap();
        assert_eq!(g.container("x_to_vadd[entry]_fast").unwrap().vtype.lanes, 2);
        assert_eq!(g.multipump.as_ref().unwrap().factor, 4);
    }
}
