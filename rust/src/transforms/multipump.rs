//! The multi-pumping transformation (paper Figure 3, box ③) — the
//! paper's central contribution, as an automatic graph rewrite.
//!
//! Preconditions (checked by `can_apply`):
//! * the graph has been streamed ([`super::StreamingComposition`]) —
//!   the compute subgraph talks to readers/writers through streams;
//! * the compute scopes pass the *temporal* vectorizability check
//!   ([`crate::analysis::check_temporal`]): dependencies allowed, no
//!   data-dependent external I/O;
//! * resource mode additionally requires the internal vector width to
//!   divide by the pumping factor M.
//!
//! The rewrite constructs two clock domains (readers/writers stay in
//! CL0; the entire compute subgraph moves to CL1 = M·CL0) and injects
//! the three AXI4-Stream plumbing modules on every crossing stream:
//!
//! ```text
//!  into the domain:  s ──[synchronizer]── s_x ──[issuer ÷M]── s_fast
//!  out of the domain: s_fast ──[packer ×M]── s_x ──[synchronizer]── s
//! ```
//!
//! * **Resource mode** (waveform ③, §2.1): the fast-side streams carry
//!   `lanes/M` elements per transaction; the compute block needs only
//!   `V/M` lanes to sustain the same throughput — DSP/BRAM cut by M.
//! * **Throughput mode** (waveform ②, §2.1): the slow-side streams and
//!   reader/writer ports are widened to `lanes·M`; the compute block is
//!   unchanged and processes M transactions per slow cycle — M× the
//!   throughput at equal compute resources (Floyd–Warshall's mode).
//! * **Bare-fast mode** (dace's TODO'd "approach 3"): the compute
//!   subgraph is clocked M× faster with *unchanged* lane widths and
//!   **no gearboxes at all** — each crossing is a lone synchronizer.
//!   Only useful on a dependent pipeline (II > 1): an II=2 datapath in
//!   a 2× domain accepts one transaction per slow cycle, i.e. behaves
//!   as II=1 from CL0, at zero packer/issuer cost.
//!
//! # Mixed per-region assignments
//!
//! The paper (§3.4) pumps the *largest streamable subgraph* as a
//! whole; [`PumpFactors::PerRegion`] instead assigns one
//! [`RegionPump`] `{factor, mode}` per
//! [streamable region](crate::analysis::streamability::partition_streamable),
//! so one design can be `[R4-inwards | T2-outwards | bare-fast]`.
//! Adjacent regions with the *same* pump share one fast clock domain
//! with no extra plumbing; wherever the two sides of a stream disagree
//! the rewrite inserts a crossing whose gearboxes are determined by
//! each side's **gear ratio** (the width conversion its mode needs):
//!
//! ```text
//!  gear_src, gear_dst > 1:  fast A ──[packer ×g_a]── wide ──[sync]── wide ──[issuer ÷g_b]── fast B
//!  gear = 1 on a side:      that side's packer/issuer is simply omitted
//!  both gears = 1:          fast A ──[sync]── B          (bare-fast: sync-only)
//! ```
//!
//! Gear ratios per mode: resource → M (streams narrow by M inside),
//! throughput → M on *external* streams (the widened interface), 1 on
//! interior ones, bare-fast → always 1. Every domain still exchanges
//! at most one transaction per slow cycle through the synchronizer. A
//! region left at `None` stays in CL0.

use super::pass::{Transform, TransformReport};
use crate::analysis::movement::scope_movement;
use crate::analysis::streamability::{module_io, partition_streamable};
use crate::analysis::vectorizability::check_temporal;
use crate::ir::{
    CdcKind, ContainerKind, DataDecl, LibraryOp, Memlet, MultipumpInfo, Node, NodeId, PumpMode,
    PumpedRegion, RegionPump, Sdfg, Storage,
};
use crate::symbolic::{Expr, Subset};
use std::collections::HashMap;

/// How the pump assignment covers the streamable regions.
#[derive(Clone, Debug, PartialEq)]
pub enum PumpFactors {
    /// One `{factor, mode}` for the whole streamed compute subgraph —
    /// the paper's §3.4 largest-streamable-subgraph choice.
    Uniform(RegionPump),
    /// One pump per region, in [`partition_streamable`] order.
    /// `None` leaves that region in CL0.
    PerRegion(Vec<Option<RegionPump>>),
}

/// Compact run-length label of a per-region assignment,
/// e.g. `4x8+2x8` (8 regions at M=4 resource, then 8 at M=2) or
/// `t2x1+b2x1+-x1` (throughput, bare-fast, unpumped). Resource-mode
/// entries print as bare factors — the historical label format.
pub fn assignment_label(factors: &[Option<RegionPump>]) -> String {
    let mut segs: Vec<(Option<RegionPump>, usize)> = Vec::new();
    for f in factors {
        match segs.last_mut() {
            Some((v, n)) if v == f => *n += 1,
            _ => segs.push((*f, 1)),
        }
    }
    segs.iter()
        .map(|(f, n)| {
            let f = f.map(|p| p.tag()).unwrap_or_else(|| "-".into());
            format!("{f}x{n}")
        })
        .collect::<Vec<_>>()
        .join("+")
}

/// `Some(pump)` when every region gets the same concrete pump — such
/// an assignment is exactly the legacy whole-graph transformation and
/// is delegated to it, so single-region graphs (and all-equal
/// assignments) reproduce today's behaviour bit for bit.
fn uniform_pump(fs: &[Option<RegionPump>]) -> Option<RegionPump> {
    let first = *fs.first()?;
    let p = first?;
    fs.iter().all(|f| *f == Some(p)).then_some(p)
}

/// Which region produces / consumes each stream. Mixed pumping
/// rewires each crossing stream through a single `{s}_fast` per side,
/// so a stream shared by two producer or two consumer regions cannot
/// be split per-region — `Err` rejects the assignment loudly instead
/// of mis-rewiring it (used by `can_apply` and `apply` alike).
#[allow(clippy::type_complexity)]
fn stream_sides(
    g: &Sdfg,
    anchors: &[NodeId],
) -> Result<(HashMap<String, usize>, HashMap<String, usize>), String> {
    let mut producer: HashMap<String, usize> = HashMap::new();
    let mut consumer: HashMap<String, usize> = HashMap::new();
    for (ri, &m) in anchors.iter().enumerate() {
        let (inflow, outflow) = module_io(g, m);
        for e in g.in_edges(inflow) {
            let d = g.edge(e).memlet.data.clone();
            if g.container(&d).map(|c| c.kind) == Some(ContainerKind::Stream) {
                if let Some(prev) = consumer.insert(d.clone(), ri) {
                    if prev != ri {
                        return Err(format!(
                            "stream '{d}' is consumed by two regions — per-region \
                             factors cannot split a fan-out stream"
                        ));
                    }
                }
            }
        }
        for e in g.out_edges(outflow) {
            let d = g.edge(e).memlet.data.clone();
            if g.container(&d).map(|c| c.kind) == Some(ContainerKind::Stream) {
                if let Some(prev) = producer.insert(d.clone(), ri) {
                    if prev != ri {
                        return Err(format!(
                            "stream '{d}' is produced by two regions — per-region \
                             factors cannot split a fan-in stream"
                        ));
                    }
                }
            }
        }
    }
    // A crossing is rewired through the stream's single access node,
    // so any additional endpoint sharing it (a second region, or a
    // slow Reader/Writer next to a region consumer) would be silently
    // mis-wired or mis-narrowed. Every participating stream must have
    // exactly one producer edge and one consumer edge at its access
    // node.
    let mut seen: Vec<&String> = producer.keys().chain(consumer.keys()).collect();
    seen.sort();
    seen.dedup();
    for s in seen {
        let s_acc = g
            .node_ids()
            .find(|id| matches!(g.node(*id), Node::Access { data } if data == s))
            .ok_or_else(|| format!("stream '{s}' has no access node"))?;
        let ins = g.in_edges(s_acc).len();
        let outs = g.out_edges(s_acc).len();
        if ins > 1 || outs > 1 {
            return Err(format!(
                "stream '{s}' fans out ({ins} producer / {outs} consumer edges) — \
                 per-region factors cannot split a shared stream"
            ));
        }
    }
    Ok((producer, consumer))
}

/// One side of a clock-domain crossing.
#[derive(Clone, Copy, Debug)]
struct CrossingSide {
    /// Clock ratio of this side's domain (1 = CL0).
    clock: usize,
    /// Width ratio this side's gearbox converts (1 = no gearbox): the
    /// pump factor for resource mode — and for throughput mode on an
    /// external stream — but always 1 for bare-fast, which crosses
    /// gearlessly by definition, and for throughput on an interior
    /// stream, whose width nobody widens.
    gear: usize,
}

impl CrossingSide {
    fn slow() -> Self {
        CrossingSide { clock: 1, gear: 1 }
    }

    /// The side a region's pump presents on one of its streams;
    /// `external` says whether the stream's other endpoint is a CL0
    /// reader/writer rather than another region.
    fn of(pump: Option<RegionPump>, external: bool) -> Self {
        match pump {
            None => CrossingSide::slow(),
            Some(p) => CrossingSide {
                clock: p.factor,
                gear: match p.mode {
                    PumpMode::Resource => p.factor,
                    PumpMode::Throughput if external => p.factor,
                    PumpMode::Throughput | PumpMode::BareFast => 1,
                },
            },
        }
    }
}

/// Inject one clock-domain crossing on stream `s`, parameterized by
/// the two sides' (clock, gear) ratios. The general shape is
///
/// ```text
///   [packer ×g_src]? ── wide ── [sync] ── wide ── [issuer ÷g_dst]?
/// ```
///
/// with the packer present iff the producer side needs a gearbox
/// (`gear > 1`) and the issuer iff the consumer side does — which
/// specializes to the three former hand-written branches (slow→fast
/// sync+issuer, fast→slow packer+sync, fast→fast
/// packer+sync+issuer). Node and edge creation order reproduces each
/// branch exactly, so graphs (and their printed text) are bit-for-bit
/// what the specialized code produced — guarded by the
/// printer-equality and crossing-shape tests. When *neither* side
/// needs a gearbox (a bare-fast region, or throughput's interior
/// boundary) the crossing degenerates to a lone synchronizer at
/// unchanged width — zero packer/issuer modules. The fast-side
/// endpoints of `s` are rewired to `{s}_fast`; `producer`/`consumer`
/// name the owning regions so their node sets absorb the fast-side
/// plumbing. Returns the plumbing module count.
fn inject_crossing(
    g: &mut Sdfg,
    s: &str,
    src: CrossingSide,
    dst: CrossingSide,
    producer: Option<usize>,
    consumer: Option<usize>,
    region_nodes: &mut [Vec<NodeId>],
) -> usize {
    let has_pack = src.gear > 1;
    let has_issue = dst.gear > 1;
    debug_assert!(src.clock > 1 || dst.clock > 1, "no crossing between two slow sides");

    let decl = g.container(s).unwrap().clone();
    let depth = match decl.storage {
        Storage::Stream { depth } => depth,
        _ => unreachable!("stream container has stream storage"),
    };
    let w = decl.vtype.lanes;
    let s_acc = g
        .node_ids()
        .find(|id| matches!(g.node(*id), Node::Access { data } if data == s))
        .expect("stream access node exists");
    let declare_stream = |g: &mut Sdfg, name: &str, lanes: usize, depth: usize| {
        let mut vt = decl.vtype;
        vt.lanes = lanes;
        g.declare(DataDecl {
            name: name.to_string(),
            kind: ContainerKind::Stream,
            vtype: vt,
            shape: vec![],
            storage: Storage::Stream { depth },
            transient: true,
        });
    };
    // rename edges interior to a region (entry→tasklet pops)
    let rename_inner = |g: &mut Sdfg, region: &[NodeId], from: &str, to: &str| {
        for e in g.edge_ids().collect::<Vec<_>>() {
            let edge = g.edge(e);
            if edge.memlet.data == from
                && region.contains(&edge.src)
                && region.contains(&edge.dst)
            {
                g.edge_mut(e).memlet.data = to.to_string();
            }
        }
    };
    let pop = |d: &str| Memlet::new(d, Subset::index1(Expr::int(0)));

    if !has_pack && !has_issue {
        // gearless crossing: a lone synchronizer bridges the domains
        // at unchanged width. The fast side — the consumer's when it
        // is fast — takes `{s}_fast`.
        let sfast = format!("{s}_fast");
        let rewire_dst = dst.clock > 1;
        let fast_clk = if rewire_dst { dst.clock } else { src.clock };
        declare_stream(g, &sfast, w, depth * fast_clk);
        let sync = g.add_node(Node::Cdc {
            name: format!("sync_{s}"),
            kind: CdcKind::Synchronizer,
            input: if rewire_dst { s.to_string() } else { sfast.clone() },
            output: if rewire_dst { sfast.clone() } else { s.to_string() },
            factor: fast_clk,
        });
        let sfast_acc = g.add_node(Node::Access { data: sfast.clone() });
        for e in g.edge_ids().collect::<Vec<_>>() {
            let edge = g.edge(e);
            if rewire_dst {
                if edge.src == s_acc && edge.memlet.data == s {
                    g.edges[e.0].src = sfast_acc;
                    g.edges[e.0].memlet.data = sfast.clone();
                }
            } else if edge.dst == s_acc && edge.memlet.data == s {
                g.edges[e.0].dst = sfast_acc;
                g.edges[e.0].memlet.data = sfast.clone();
            }
        }
        if let Some(ri) = if rewire_dst { consumer } else { producer } {
            rename_inner(g, &region_nodes[ri], s, &sfast);
            region_nodes[ri].push(sfast_acc);
        }
        if rewire_dst {
            g.add_edge(s_acc, sync, pop(s));
            g.add_edge(sync, sfast_acc, pop(&sfast));
        } else {
            g.add_edge(sfast_acc, sync, pop(&sfast));
            g.add_edge(sync, s_acc, pop(s));
        }
        return 1;
    }

    // wide-rate streams: a fast→fast crossing packs into `{s}_pack_cdc`
    // before the synchronizer and re-issues from `{s}_cdc` after it;
    // one-sided crossings need a single wide `{s}_cdc`
    let pack_out = format!("{}{}", s, if has_pack && has_issue { "_pack_cdc" } else { "_cdc" });
    let sync_out = format!("{s}_cdc");
    let sfast = format!("{s}_fast");
    // the fast ratio `{s}_fast` carries: the consumer's when it is
    // fast, else the producer's
    let fast_f = if has_issue { dst.gear } else { src.gear };
    if has_pack && has_issue {
        declare_stream(g, &pack_out, w, depth);
    }
    declare_stream(g, &sync_out, w, depth);
    declare_stream(g, &sfast, w / fast_f, depth * fast_f);

    // plumbing modules, in chain order
    let packer = has_pack.then(|| {
        g.add_node(Node::Cdc {
            name: format!("pack_{s}"),
            kind: CdcKind::Packer,
            input: if has_issue { s.to_string() } else { sfast.clone() },
            output: pack_out.clone(),
            factor: src.gear,
        })
    });
    let sync = g.add_node(Node::Cdc {
        name: format!("sync_{s}"),
        kind: CdcKind::Synchronizer,
        input: if has_pack { pack_out.clone() } else { s.to_string() },
        output: if has_issue { sync_out.clone() } else { s.to_string() },
        factor: if has_issue { dst.gear } else { src.gear },
    });
    let issuer = has_issue.then(|| {
        g.add_node(Node::Cdc {
            name: format!("issue_{s}"),
            kind: CdcKind::Issuer,
            input: sync_out.clone(),
            output: sfast.clone(),
            factor: dst.gear,
        })
    });
    // access nodes, wide(s) then fast
    let pack_out_acc =
        (has_pack && has_issue).then(|| g.add_node(Node::Access { data: pack_out.clone() }));
    let sync_out_acc = g.add_node(Node::Access { data: sync_out.clone() });
    let sfast_acc = g.add_node(Node::Access { data: sfast.clone() });

    // rewire the fast-side endpoints of `s` to `{s}_fast`: its
    // consumers when the consumer side is fast, else its producers
    for e in g.edge_ids().collect::<Vec<_>>() {
        let edge = g.edge(e);
        if has_issue {
            if edge.src == s_acc && edge.memlet.data == s {
                g.edges[e.0].src = sfast_acc;
                g.edges[e.0].memlet.data = sfast.clone();
            }
        } else if edge.dst == s_acc && edge.memlet.data == s {
            g.edges[e.0].dst = sfast_acc;
            g.edges[e.0].memlet.data = sfast.clone();
        }
    }
    if has_issue {
        if let Some(ri) = consumer {
            rename_inner(g, &region_nodes[ri], s, &sfast);
            region_nodes[ri].extend([issuer.unwrap(), sfast_acc]);
        }
    }
    if has_pack {
        if let Some(ri) = producer {
            if has_issue {
                region_nodes[ri].push(packer.unwrap());
            } else {
                rename_inner(g, &region_nodes[ri], s, &sfast);
                region_nodes[ri].extend([packer.unwrap(), sfast_acc]);
            }
        }
    }

    // the crossing chain: head access → [packer] → wide(s)/sync → [issuer] → tail
    let head = if has_pack && !has_issue { (sfast_acc, sfast.clone()) } else { (s_acc, s.to_string()) };
    let mut prev = head;
    let mut chain: Vec<(NodeId, NodeId, String)> = Vec::new();
    if let Some(p) = packer {
        let acc = if has_issue { pack_out_acc.unwrap() } else { sync_out_acc };
        chain.push((p, acc, pack_out.clone()));
    }
    {
        let (acc, out) = if has_issue {
            (sync_out_acc, sync_out.clone())
        } else {
            (s_acc, s.to_string())
        };
        chain.push((sync, acc, out));
    }
    if let Some(i) = issuer {
        chain.push((i, sfast_acc, sfast.clone()));
    }
    for (module, out_acc, out_name) in chain {
        g.add_edge(prev.0, module, pop(&prev.1));
        g.add_edge(module, out_acc, pop(&out_name));
        prev = (out_acc, out_name);
    }

    1 + has_pack as usize + has_issue as usize
}

/// Uniform bare-fast boundary crossing on stream `s`: a lone
/// synchronizer at unchanged width, the compute-side endpoints
/// rewired to `{s}_fast`. `inward` = the stream flows from a reader
/// into the fast domain (else out of it, to a writer). Returns the
/// plumbing module count (always 1 — zero packer/issuer).
fn bare_fast_boundary(g: &mut Sdfg, s: &str, m: usize, inward: bool) -> usize {
    let decl = g.container(s).unwrap().clone();
    let depth = match decl.storage {
        Storage::Stream { depth } => depth,
        _ => unreachable!("boundary stream has stream storage"),
    };
    let sfast = format!("{s}_fast");
    g.declare(DataDecl {
        name: sfast.clone(),
        kind: ContainerKind::Stream,
        // width unchanged — bare-fast has no gearboxes
        vtype: decl.vtype,
        shape: vec![],
        storage: Storage::Stream { depth: depth * m },
        transient: true,
    });
    let sync = g.add_node(Node::Cdc {
        name: format!("sync_{s}"),
        kind: CdcKind::Synchronizer,
        input: if inward { s.to_string() } else { sfast.clone() },
        output: if inward { sfast.clone() } else { s.to_string() },
        factor: m,
    });
    let sfast_acc = g.add_node(Node::Access { data: sfast.clone() });
    let s_acc = g
        .node_ids()
        .find(|id| matches!(g.node(*id), Node::Access { data } if data.as_str() == s))
        .expect("stream access node exists");
    // the compute-side endpoints of s move to s_fast
    for e in g.edge_ids().collect::<Vec<_>>() {
        let edge = g.edge(e);
        if inward {
            if edge.src == s_acc && edge.memlet.data == s {
                g.edges[e.0].src = sfast_acc;
                g.edges[e.0].memlet.data = sfast.clone();
            }
        } else if edge.dst == s_acc && edge.memlet.data == s {
            g.edges[e.0].dst = sfast_acc;
            g.edges[e.0].memlet.data = sfast.clone();
        }
    }
    // inner scope edges popping s move to s_fast
    for e in g.edge_ids().collect::<Vec<_>>() {
        if g.edge(e).memlet.data == s && g.edge(e).src != s_acc && g.edge(e).dst != s_acc {
            g.edge_mut(e).memlet.data = sfast.clone();
        }
    }
    let pop = |d: &str| Memlet::new(d, Subset::index1(Expr::int(0)));
    if inward {
        g.add_edge(s_acc, sync, pop(s));
        g.add_edge(sync, sfast_acc, pop(&sfast));
    } else {
        g.add_edge(sfast_acc, sync, pop(&sfast));
        g.add_edge(sync, s_acc, pop(s));
    }
    1
}

/// Apply multi-pumping under the given per-region assignment.
pub struct MultiPump {
    pub factors: PumpFactors,
}

impl MultiPump {
    pub fn uniform(factor: usize, mode: PumpMode) -> Self {
        MultiPump { factors: PumpFactors::Uniform(RegionPump::new(factor, mode)) }
    }

    pub fn resource(factor: usize) -> Self {
        MultiPump::uniform(factor, PumpMode::Resource)
    }

    pub fn throughput(factor: usize) -> Self {
        MultiPump::uniform(factor, PumpMode::Throughput)
    }

    /// Gearbox-free fast clocking: dace's "approach 3" — only legal
    /// when the pumped regions pipeline at II > 1.
    pub fn bare_fast(factor: usize) -> Self {
        MultiPump::uniform(factor, PumpMode::BareFast)
    }

    /// Mixed per-region factors, all in the same `mode` (the historic
    /// entry point; see [`MultiPump::per_region`] for mixed modes).
    pub fn mixed(factors: Vec<Option<usize>>, mode: PumpMode) -> Self {
        let fs = factors
            .into_iter()
            .map(|f| f.map(|x| RegionPump::new(x, mode)))
            .collect();
        MultiPump::per_region(fs)
    }

    /// Fully general per-region assignment: each region carries its
    /// own `{factor, mode}`, `None` staying in CL0.
    pub fn per_region(pumps: Vec<Option<RegionPump>>) -> Self {
        MultiPump { factors: PumpFactors::PerRegion(pumps) }
    }

    /// Pump a single region of a `region_count`-region graph at
    /// `factor` (resource mode), leaving every other region in CL0.
    pub fn for_region(region: usize, region_count: usize, factor: usize) -> Self {
        let mut fs = vec![None; region_count];
        if region < region_count {
            fs[region] = Some(factor);
        }
        MultiPump::mixed(fs, PumpMode::Resource)
    }
}

/// Streams that cross from the slow domain into the compute domain
/// (fed by a Reader) and out of it (drained by a Writer).
fn boundary_streams(g: &Sdfg) -> (Vec<String>, Vec<String>) {
    let mut into = Vec::new();
    let mut out_of = Vec::new();
    for id in g.node_ids() {
        match g.node(id) {
            Node::Reader { stream, .. } => into.push(stream.clone()),
            Node::Writer { stream, .. } => out_of.push(stream.clone()),
            _ => {}
        }
    }
    (into, out_of)
}

/// All compute-side nodes: everything that is not a reader/writer, not
/// an external access, and not a boundary-stream access.
fn compute_side(g: &Sdfg, boundary: &[String]) -> Vec<NodeId> {
    g.node_ids()
        .filter(|id| match g.node(*id) {
            Node::Reader { .. } | Node::Writer { .. } | Node::Cdc { .. } => false,
            Node::Access { data } => {
                let decl = g.container(data).expect("validated");
                // stream accesses inside the domain belong to it;
                // boundary streams and external arrays do not
                decl.kind == ContainerKind::Stream && !boundary.contains(data)
            }
            _ => true,
        })
        .collect()
}

impl Transform for MultiPump {
    fn name(&self) -> String {
        match &self.factors {
            PumpFactors::Uniform(p) => format!("MultiPump[M={} {}]", p.factor, p.mode.name()),
            PumpFactors::PerRegion(fs) => {
                format!("MultiPump[mixed {}]", assignment_label(fs))
            }
        }
    }

    fn can_apply(&self, g: &Sdfg) -> Result<(), String> {
        match &self.factors {
            PumpFactors::Uniform(p) => self.can_apply_uniform(g, *p),
            PumpFactors::PerRegion(fs) => {
                let n = partition_streamable(g).len();
                if fs.len() != n {
                    return Err(format!(
                        "assignment has {} factors but the graph has {n} streamable regions",
                        fs.len()
                    ));
                }
                match uniform_pump(fs) {
                    Some(p) => self.can_apply_uniform(g, p),
                    None => self.can_apply_mixed(g, fs),
                }
            }
        }
    }

    fn apply(&self, g: &mut Sdfg) -> Result<TransformReport, String> {
        match &self.factors {
            PumpFactors::Uniform(p) => self.apply_uniform(g, *p),
            PumpFactors::PerRegion(fs) => match uniform_pump(fs) {
                Some(p) => self.apply_uniform(g, p),
                None => self.apply_mixed(g, fs),
            },
        }
    }
}

impl MultiPump {
    fn can_apply_uniform(&self, g: &Sdfg, pump: RegionPump) -> Result<(), String> {
        let factor = pump.factor;
        if factor < 2 {
            return Err("pumping factor must be ≥ 2".into());
        }
        if g.multipump.is_some() {
            return Err("already multi-pumped".into());
        }
        let (into, out_of) = boundary_streams(g);
        if into.is_empty() && out_of.is_empty() {
            return Err("graph is not streamed (run StreamingComposition first)".into());
        }
        // temporal vectorizability of every map scope
        for id in g.node_ids() {
            if matches!(g.node(id), Node::MapEntry { .. }) {
                let mv = scope_movement(g, id)?;
                let verdict = check_temporal(g, &mv, 1);
                if !verdict.is_ok() {
                    return Err(format!(
                        "scope '{}': {}",
                        g.node(id).label(),
                        verdict.reasons().join("; ")
                    ));
                }
            }
        }
        // bare-fast mode: without gearboxes the fast clock can only
        // recover initiation intervals — every pumped region must
        // actually pipeline at II > 1, or the extra clock buys nothing
        // and the crossing synchronizers throttle it back to CL0 rate.
        if pump.mode == PumpMode::BareFast {
            for r in partition_streamable(g) {
                if let Some(reason) = r.rejects(pump) {
                    return Err(reason);
                }
            }
        }
        // resource mode: every stream the design carries — boundary
        // AND internal (stencil-chain inter-kernel streams) — must
        // narrow exactly, and every library datapath must keep an
        // integer lane count. Rejecting here keeps an illegal factor
        // from surfacing later as a confusing lower/estimate error on
        // a half-narrowed graph.
        if pump.mode == PumpMode::Resource {
            for (name, decl) in &g.containers {
                if decl.kind != ContainerKind::Stream {
                    continue;
                }
                let lanes = decl.vtype.lanes;
                if lanes % factor != 0 {
                    return Err(format!(
                        "resource mode: stream '{name}' width {lanes} not divisible by M={} \
                         (choose a factor dividing the vectorized stream width)",
                        factor
                    ));
                }
            }
            for id in g.node_ids() {
                if let Node::Library { name, op } = g.node(id) {
                    let w = match op {
                        LibraryOp::SystolicGemm { vec_width, .. }
                        | LibraryOp::StencilStage { vec_width, .. } => *vec_width,
                        // FW keeps its datapath width in resource mode
                        LibraryOp::FloydWarshall { .. } => continue,
                    };
                    if w % factor != 0 {
                        return Err(format!(
                            "resource mode: library '{name}' vector width {w} not divisible \
                             by M={}",
                            factor
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Per-region legality: each pumped region's `{factor, mode}` must
    /// pass that mode's check ([`crate::analysis::streamability::StreamRegion::rejects`]
    /// — resource needs divisible widths, throughput an external
    /// stream, bare-fast a dependent pipeline), plus the temporal
    /// check on map scopes, and every factor must divide the largest
    /// one so all fast domains share the exact simulator's fast time
    /// base.
    fn can_apply_mixed(&self, g: &Sdfg, fs: &[Option<RegionPump>]) -> Result<(), String> {
        if g.multipump.is_some() {
            return Err("already multi-pumped".into());
        }
        let (into, out_of) = boundary_streams(g);
        if into.is_empty() && out_of.is_empty() {
            return Err("graph is not streamed (run StreamingComposition first)".into());
        }
        let regions = partition_streamable(g);
        let max_f = fs.iter().flatten().map(|p| p.factor).max().unwrap_or(0);
        if max_f == 0 {
            return Err("mixed assignment pumps no region (every factor is None)".into());
        }
        // reject fan-out/fan-in streams up front (see stream_sides)
        let anchors: Vec<NodeId> = regions.iter().map(|r| r.module).collect();
        stream_sides(g, &anchors)?;
        for (r, p) in regions.iter().zip(fs) {
            let p = match p {
                Some(p) => *p,
                None => continue,
            };
            let f = p.factor;
            if f < 2 {
                return Err(format!("region '{}': pumping factor must be ≥ 2", r.label));
            }
            // per-mode legality (width / external / dependent)
            if let Some(reason) = r.rejects(p) {
                return Err(reason);
            }
            // resource mode: every individual stream the region
            // touches must narrow (or re-issue) exactly — the minimum
            // width in the region summary does not cover a wider
            // sibling stream whose lane count M does not divide (the
            // uniform path errors per stream too)
            if p.mode == PumpMode::Resource {
                let (inflow, outflow) = module_io(g, r.module);
                for e in g.in_edges(inflow).into_iter().chain(g.out_edges(outflow)) {
                    let data = &g.edge(e).memlet.data;
                    if let Some(decl) = g.container(data) {
                        if decl.kind == ContainerKind::Stream && decl.vtype.lanes % f != 0 {
                            return Err(format!(
                                "region '{}': stream '{data}' width {} not divisible by M={f}",
                                r.label, decl.vtype.lanes
                            ));
                        }
                    }
                }
            }
            if max_f % f != 0 {
                return Err(format!(
                    "region '{}': factor {f} does not divide the assignment's largest \
                     factor {max_f} (fast domains must share one fast time base)",
                    r.label
                ));
            }
            if matches!(g.node(r.module), Node::MapEntry { .. }) {
                let mv = scope_movement(g, r.module)?;
                let verdict = check_temporal(g, &mv, 1);
                if !verdict.is_ok() {
                    return Err(format!(
                        "region '{}': {}",
                        r.label,
                        verdict.reasons().join("; ")
                    ));
                }
            }
        }
        Ok(())
    }

    fn apply_uniform(&self, g: &mut Sdfg, pump: RegionPump) -> Result<TransformReport, String> {
        let (into, out_of) = boundary_streams(g);
        let m = pump.factor;
        let mode = pump.mode;
        let mut plumbing = 0usize;

        // the fast domain contains the compute subgraph
        let fast_nodes = compute_side(g, &[into.clone(), out_of.clone()].concat());

        for s in &into {
            let decl = g.container(s).unwrap().clone();
            let depth = match decl.storage {
                Storage::Stream { depth } => depth,
                _ => unreachable!("boundary stream has stream storage"),
            };
            if mode == PumpMode::BareFast {
                // gearless: a lone synchronizer per boundary stream,
                // widths untouched — zero packer/issuer modules
                plumbing += bare_fast_boundary(g, s, m, true);
                continue;
            }
            let (slow_lanes, fast_lanes) = match mode {
                // wide outside stays, narrow inside
                PumpMode::Resource => (decl.vtype.lanes, decl.vtype.lanes / m),
                // widen outside, keep inside
                PumpMode::Throughput => (decl.vtype.lanes * m, decl.vtype.lanes),
                PumpMode::BareFast => unreachable!("handled above"),
            };
            // widen the slow-side stream (throughput mode) and its
            // source array port
            if mode == PumpMode::Throughput {
                g.containers.get_mut(s).unwrap().vtype.lanes = slow_lanes;
            }
            let mut vt_x = decl.vtype;
            vt_x.lanes = slow_lanes;
            let mut vt_fast = decl.vtype;
            vt_fast.lanes = fast_lanes;

            let sx = format!("{s}_cdc");
            let sfast = format!("{s}_fast");
            g.declare(DataDecl {
                name: sx.clone(),
                kind: ContainerKind::Stream,
                vtype: vt_x,
                shape: vec![],
                storage: Storage::Stream { depth },
                transient: true,
            });
            g.declare(DataDecl {
                name: sfast.clone(),
                kind: ContainerKind::Stream,
                vtype: vt_fast,
                shape: vec![],
                storage: Storage::Stream { depth: depth * m },
                transient: true,
            });
            let sync = g.add_node(Node::Cdc {
                name: format!("sync_{s}"),
                kind: CdcKind::Synchronizer,
                input: s.clone(),
                output: sx.clone(),
                factor: m,
            });
            let issuer = g.add_node(Node::Cdc {
                name: format!("issue_{s}"),
                kind: CdcKind::Issuer,
                input: sx.clone(),
                output: sfast.clone(),
                factor: m,
            });
            let sx_acc = g.add_node(Node::Access { data: sx.clone() });
            let sfast_acc = g.add_node(Node::Access { data: sfast.clone() });
            // original stream access node (slow side)
            let s_acc = g
                .node_ids()
                .find(|id| matches!(g.node(*id), Node::Access { data } if data == s))
                .expect("stream access node exists");
            // consumers of s (compute side) move to s_fast
            let consumer_edges: Vec<usize> = g
                .edge_ids()
                .filter(|e| {
                    let edge = g.edge(*e);
                    edge.src == s_acc && edge.memlet.data == *s
                })
                .map(|e| e.0)
                .collect();
            for eidx in consumer_edges {
                g.edges[eidx].src = sfast_acc;
                g.edges[eidx].memlet.data = sfast.clone();
            }
            // inner scope edges popping s move to s_fast
            for e in g.edge_ids().collect::<Vec<_>>() {
                if g.edge(e).memlet.data == *s && g.edge(e).src != s_acc && g.edge(e).dst != s_acc
                {
                    g.edge_mut(e).memlet.data = sfast.clone();
                }
            }
            let pop = |d: &str| Memlet::new(d, Subset::index1(Expr::int(0)));
            g.add_edge(s_acc, sync, pop(s));
            g.add_edge(sync, sx_acc, pop(&sx));
            g.add_edge(sx_acc, issuer, pop(&sx));
            g.add_edge(issuer, sfast_acc, pop(&sfast));
            plumbing += 2;
        }

        for s in &out_of {
            let decl = g.container(s).unwrap().clone();
            let depth = match decl.storage {
                Storage::Stream { depth } => depth,
                _ => unreachable!(),
            };
            if mode == PumpMode::BareFast {
                plumbing += bare_fast_boundary(g, s, m, false);
                continue;
            }
            let (slow_lanes, fast_lanes) = match mode {
                PumpMode::Resource => (decl.vtype.lanes, decl.vtype.lanes / m),
                PumpMode::Throughput => (decl.vtype.lanes * m, decl.vtype.lanes),
                PumpMode::BareFast => unreachable!("handled above"),
            };
            if mode == PumpMode::Throughput {
                g.containers.get_mut(s).unwrap().vtype.lanes = slow_lanes;
            }
            let mut vt_x = decl.vtype;
            vt_x.lanes = slow_lanes;
            let mut vt_fast = decl.vtype;
            vt_fast.lanes = fast_lanes;

            let sx = format!("{s}_cdc");
            let sfast = format!("{s}_fast");
            g.declare(DataDecl {
                name: sx.clone(),
                kind: ContainerKind::Stream,
                vtype: vt_x,
                shape: vec![],
                storage: Storage::Stream { depth },
                transient: true,
            });
            g.declare(DataDecl {
                name: sfast.clone(),
                kind: ContainerKind::Stream,
                vtype: vt_fast,
                shape: vec![],
                storage: Storage::Stream { depth: depth * m },
                transient: true,
            });
            let packer = g.add_node(Node::Cdc {
                name: format!("pack_{s}"),
                kind: CdcKind::Packer,
                input: sfast.clone(),
                output: sx.clone(),
                factor: m,
            });
            let sync = g.add_node(Node::Cdc {
                name: format!("sync_{s}"),
                kind: CdcKind::Synchronizer,
                input: sx.clone(),
                output: s.clone(),
                factor: m,
            });
            let sx_acc = g.add_node(Node::Access { data: sx.clone() });
            let sfast_acc = g.add_node(Node::Access { data: sfast.clone() });
            let s_acc = g
                .node_ids()
                .find(|id| matches!(g.node(*id), Node::Access { data } if data == s))
                .expect("stream access node exists");
            // producers into s (compute side) move to s_fast
            let producer_edges: Vec<usize> = g
                .edge_ids()
                .filter(|e| {
                    let edge = g.edge(*e);
                    edge.dst == s_acc && edge.memlet.data == *s
                })
                .map(|e| e.0)
                .collect();
            for eidx in producer_edges {
                g.edges[eidx].dst = sfast_acc;
                g.edges[eidx].memlet.data = sfast.clone();
            }
            for e in g.edge_ids().collect::<Vec<_>>() {
                if g.edge(e).memlet.data == *s && g.edge(e).src != s_acc && g.edge(e).dst != s_acc
                {
                    g.edge_mut(e).memlet.data = sfast.clone();
                }
            }
            let pop = |d: &str| Memlet::new(d, Subset::index1(Expr::int(0)));
            g.add_edge(sfast_acc, packer, pop(&sfast));
            g.add_edge(packer, sx_acc, pop(&sx));
            g.add_edge(sx_acc, sync, pop(&sx));
            g.add_edge(sync, s_acc, pop(s));
            plumbing += 2;
        }

        // resource mode: the compute block's internal width shrinks —
        // narrow every non-boundary stream and scale PE/lane counts
        if mode == PumpMode::Resource {
            let boundary: Vec<String> = into.iter().chain(out_of.iter()).cloned().collect();
            let names: Vec<String> = g.containers.keys().cloned().collect();
            for name in names {
                let decl = g.containers.get_mut(&name).unwrap();
                let is_fast_stream = decl.kind == ContainerKind::Stream
                    && !boundary.contains(&name)
                    && !name.ends_with("_cdc");
                if is_fast_stream && !name.ends_with("_fast") && decl.vtype.lanes % m == 0 {
                    decl.vtype.lanes /= m;
                }
            }
            // library nodes shrink their lane width (PE vectorization)
            for id in g.node_ids().collect::<Vec<_>>() {
                if let Node::Library { op, .. } = g.node_mut(id) {
                    match op {
                        crate::ir::LibraryOp::SystolicGemm { vec_width, .. } => {
                            if *vec_width % m == 0 {
                                *vec_width /= m;
                            }
                        }
                        crate::ir::LibraryOp::StencilStage { vec_width, .. } => {
                            if *vec_width % m == 0 {
                                *vec_width /= m;
                            }
                        }
                        // FW keeps its compute width: resource mode does
                        // not apply to an unvectorized datapath
                        crate::ir::LibraryOp::FloydWarshall { .. } => {}
                    }
                }
            }
        }

        g.multipump = Some(MultipumpInfo::uniform(m, mode, fast_nodes));

        Ok(TransformReport {
            transform: self.name(),
            summary: format!(
                "2 clock domains constructed; {plumbing} plumbing modules injected over {} in / {} out streams",
                into.len(),
                out_of.len()
            ),
        })
    }

    /// Mixed assignment: one fast domain per distinct `{factor, mode}`
    /// pump, crossings injected wherever the two sides of a stream
    /// disagree on their pump (including the slow side, `None`).
    fn apply_mixed(&self, g: &mut Sdfg, fs: &[Option<RegionPump>]) -> Result<TransformReport, String> {
        let regions = partition_streamable(g);
        let anchors: Vec<NodeId> = regions.iter().map(|r| r.module).collect();
        let pump_of = |ri: usize| fs[ri];

        // region node sets (anchor + scope internals)
        let mut region_nodes: Vec<Vec<NodeId>> = Vec::with_capacity(anchors.len());
        for &m in &anchors {
            let mut ns = vec![m];
            if let Node::MapEntry { name, .. } = g.node(m) {
                let name = name.clone();
                ns.extend(g.scope_nodes(m));
                if let Some(x) = g.find_map_exit(&name) {
                    ns.push(x);
                }
            }
            region_nodes.push(ns);
        }

        // which region produces / consumes each stream (fan-out was
        // rejected by can_apply)
        let (producer, consumer) = stream_sides(g, &anchors)?;
        let side_pumps = |s: &str| -> (Option<RegionPump>, Option<RegionPump>) {
            (
                producer.get(s).and_then(|&ri| pump_of(ri)),
                consumer.get(s).and_then(|&ri| pump_of(ri)),
            )
        };

        let mut plumbing = 0usize;
        let mut crossings = 0usize;

        let stream_names: Vec<String> = g
            .containers
            .iter()
            .filter(|(_, d)| d.kind == ContainerKind::Stream)
            .map(|(n, _)| n.clone())
            .collect();

        // throughput regions widen their external streams (the side
        // facing a CL0 reader/writer) before any crossing is injected,
        // so the crossing gearboxes see the widened slow-side width —
        // exactly as the uniform throughput apply does. Interior
        // streams are untouched: nobody upstream can feed them wider.
        for s in &stream_names {
            let (p_src, p_dst) = side_pumps(s);
            let widen = match (p_src, p_dst) {
                (Some(p), _) if p.mode == PumpMode::Throughput && !consumer.contains_key(s) => {
                    p.factor
                }
                (_, Some(p)) if p.mode == PumpMode::Throughput && !producer.contains_key(s) => {
                    p.factor
                }
                _ => 1,
            };
            if widen > 1 {
                g.containers.get_mut(s).unwrap().vtype.lanes *= widen;
            }
        }

        for s in &stream_names {
            let (p_src, p_dst) = side_pumps(s);
            if p_src == p_dst {
                continue; // same domain (or both slow): no crossing
            }
            let src = CrossingSide::of(p_src, !consumer.contains_key(s));
            let dst = CrossingSide::of(p_dst, !producer.contains_key(s));
            crossings += 1;
            plumbing += inject_crossing(
                g,
                s,
                src,
                dst,
                producer.get(s).copied(),
                consumer.get(s).copied(),
                &mut region_nodes,
            );
        }

        // narrow every stream interior to a resource-pumped domain
        // (both sides fast: either the same domain, or the producer
        // side of a geared crossing) by the producer's gear ratio —
        // the created `_cdc`/`_fast` plumbing streams are already at
        // their final widths, and bare-fast / throughput-interior
        // sides (gear 1) keep theirs
        let names: Vec<String> = g.containers.keys().cloned().collect();
        for name in names {
            if name.ends_with("_cdc") || name.ends_with("_fast") {
                continue;
            }
            let (p_src, p_dst) = side_pumps(&name);
            let (clk_src, clk_dst) = (
                p_src.map(|p| p.factor).unwrap_or(1),
                p_dst.map(|p| p.factor).unwrap_or(1),
            );
            if clk_src > 1 && clk_dst > 1 {
                let gear = CrossingSide::of(p_src, !consumer.contains_key(&name)).gear;
                let decl = g.containers.get_mut(&name).unwrap();
                if gear > 1 && decl.kind == ContainerKind::Stream && decl.vtype.lanes % gear == 0
                {
                    decl.vtype.lanes /= gear;
                }
            }
        }
        // narrow the resource-pumped regions' library datapaths —
        // throughput and bare-fast keep their compute width by design
        for (ri, &m) in anchors.iter().enumerate() {
            let p = match pump_of(ri) {
                Some(p) => p,
                None => continue,
            };
            if p.factor < 2 || p.mode != PumpMode::Resource {
                continue;
            }
            let f = p.factor;
            if let Node::Library { op, .. } = g.node_mut(m) {
                match op {
                    LibraryOp::SystolicGemm { vec_width, .. }
                    | LibraryOp::StencilStage { vec_width, .. } => {
                        if *vec_width % f == 0 {
                            *vec_width /= f;
                        }
                    }
                    LibraryOp::FloydWarshall { .. } => {}
                }
            }
        }

        let info_regions: Vec<PumpedRegion> = region_nodes
            .into_iter()
            .enumerate()
            .filter_map(|(ri, nodes)| {
                pump_of(ri).filter(|p| p.factor >= 2).map(|p| PumpedRegion {
                    factor: p.factor,
                    mode: p.mode,
                    nodes,
                })
            })
            .collect();
        let domains: usize = {
            let mut d: Vec<(usize, char)> =
                info_regions.iter().map(|r| (r.factor, r.mode.letter())).collect();
            d.sort_unstable();
            d.dedup();
            d.len()
        };
        g.multipump = Some(MultipumpInfo { regions: info_regions });

        Ok(TransformReport {
            transform: self.name(),
            summary: format!(
                "{} fast clock domain(s) over {} pumped region(s); {plumbing} plumbing \
                 modules injected over {crossings} crossings",
                domains,
                fs.iter().flatten().count(),
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::vecadd_sdfg;
    use crate::ir::validate::validate;
    use crate::ir::StencilKind;
    use crate::transforms::pass::PassManager;
    use crate::transforms::{StreamingComposition, Vectorize};

    fn streamed_vecadd(lanes: usize) -> Sdfg {
        let mut g = vecadd_sdfg(1);
        let mut pm = PassManager::new();
        if lanes > 1 {
            pm.run(&mut g, &Vectorize::new("vadd", lanes)).unwrap();
        }
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        g
    }

    fn streamed_stencil(stages: usize, w: usize) -> Sdfg {
        let mut g = crate::apps::stencil::build(StencilKind::Jacobi3D, stages, w);
        let mut pm = PassManager::new();
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        g
    }

    fn streamed_fw() -> Sdfg {
        let mut g = crate::apps::floyd_warshall::build();
        let mut pm = PassManager::new();
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        g
    }

    fn cdc_counts(g: &Sdfg) -> (usize, usize, usize) {
        let count = |pred: fn(&Node) -> bool| g.node_ids().filter(|i| pred(g.node(*i))).count();
        (
            count(|n| matches!(n, Node::Cdc { kind: CdcKind::Packer, .. })),
            count(|n| matches!(n, Node::Cdc { kind: CdcKind::Synchronizer, .. })),
            count(|n| matches!(n, Node::Cdc { kind: CdcKind::Issuer, .. })),
        )
    }

    #[test]
    fn requires_streaming_first() {
        let g = vecadd_sdfg(1);
        let err = MultiPump::resource(2).can_apply(&g).unwrap_err();
        assert!(err.contains("not streamed"), "{err}");
    }

    #[test]
    fn resource_mode_requires_divisible_width() {
        let g = streamed_vecadd(1); // scalar streams
        let err = MultiPump::resource(2).can_apply(&g).unwrap_err();
        assert!(err.contains("not divisible"), "{err}");
        // width 4 divides
        let g4 = streamed_vecadd(4);
        MultiPump::resource(2).can_apply(&g4).unwrap();
    }

    #[test]
    fn double_pump_vecadd_resource_mode() {
        let mut g = streamed_vecadd(4);
        let mut pm = PassManager::new();
        let report = pm.run(&mut g, &MultiPump::resource(2)).unwrap().clone();
        validate(&g).unwrap();
        assert!(report.summary.contains("2 clock domains"), "{}", report.summary);
        let mp = g.multipump.as_ref().unwrap();
        assert_eq!(mp.max_factor(), 2);
        assert_eq!(mp.representative_mode(), PumpMode::Resource);
        assert!(!mp.is_mixed());
        // per boundary stream: sync+issuer or packer+sync
        let cdc = g.node_ids().filter(|i| g.node(*i).is_cdc()).count();
        assert_eq!(cdc, 6); // 3 streams × 2 modules
        // fast-side stream narrowed to 2 lanes, slow side stays 4
        assert_eq!(g.container("x_to_vadd[entry]").unwrap().vtype.lanes, 4);
        assert_eq!(g.container("x_to_vadd[entry]_fast").unwrap().vtype.lanes, 2);
        // compute scope is in the fast domain, readers are not
        let entry = g.find_map_entry("vadd").unwrap();
        assert!(g.in_fast_domain(entry));
        assert_eq!(g.fast_factor_of(entry), Some(2));
        let rd = g
            .node_ids()
            .find(|i| matches!(g.node(*i), Node::Reader { .. }))
            .unwrap();
        assert!(!g.in_fast_domain(rd));
    }

    #[test]
    fn double_pump_throughput_mode_widens_boundary() {
        let mut g = streamed_vecadd(2);
        let mut pm = PassManager::new();
        pm.run(&mut g, &MultiPump::throughput(2)).unwrap();
        validate(&g).unwrap();
        // slow-side stream doubled to 4 lanes, fast side keeps 2
        assert_eq!(g.container("x_to_vadd[entry]").unwrap().vtype.lanes, 4);
        assert_eq!(g.container("x_to_vadd[entry]_fast").unwrap().vtype.lanes, 2);
    }

    #[test]
    fn resource_mode_rejects_indivisible_internal_stream() {
        // stencil chain: the inter-kernel tmp stream is internal (no
        // reader/writer touches it). Desynchronize its width so only
        // the *internal* check can catch the illegal factor — before
        // this check, the factor slipped through can_apply and left a
        // half-narrowed graph for lower() to choke on.
        let mut g = crate::apps::stencil::build(crate::ir::StencilKind::Jacobi3D, 2, 4);
        let mut pm = PassManager::new();
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        g.containers.get_mut("tmp0").unwrap().vtype.lanes = 2;
        let err = MultiPump::resource(4).can_apply(&g).unwrap_err();
        assert!(err.contains("tmp0") && err.contains("not divisible"), "{err}");
    }

    #[test]
    fn resource_mode_rejects_indivisible_library_width() {
        let mut g = crate::apps::stencil::build(crate::ir::StencilKind::Jacobi3D, 1, 4);
        let mut pm = PassManager::new();
        pm.run(&mut g, &StreamingComposition::default()).unwrap();
        // a datapath whose lane count would not stay an integer
        for id in g.node_ids().collect::<Vec<_>>() {
            if let Node::Library {
                op: crate::ir::LibraryOp::StencilStage { vec_width, .. },
                ..
            } = g.node_mut(id)
            {
                *vec_width = 3;
            }
        }
        let err = MultiPump::resource(2).can_apply(&g).unwrap_err();
        assert!(err.contains("library") && err.contains("not divisible"), "{err}");
    }

    #[test]
    fn cannot_pump_twice() {
        let mut g = streamed_vecadd(4);
        let mut pm = PassManager::new();
        pm.run(&mut g, &MultiPump::resource(2)).unwrap();
        let err = pm.run(&mut g, &MultiPump::resource(2)).unwrap_err();
        assert!(err.contains("already multi-pumped"), "{err}");
    }

    #[test]
    fn quad_pump_resource_mode() {
        let mut g = streamed_vecadd(8);
        let mut pm = PassManager::new();
        pm.run(&mut g, &MultiPump::resource(4)).unwrap();
        assert_eq!(g.container("x_to_vadd[entry]_fast").unwrap().vtype.lanes, 2);
        assert_eq!(g.multipump.as_ref().unwrap().max_factor(), 4);
    }

    // ---- mixed per-region assignments -------------------------------

    #[test]
    fn uniform_per_region_assignment_matches_whole_graph_transform() {
        // a single-region graph with a full assignment must reproduce
        // the legacy transformation bit for bit (delegation)
        let mut a = streamed_vecadd(4);
        let mut b = streamed_vecadd(4);
        let mut pm = PassManager::new();
        pm.run(&mut a, &MultiPump::resource(2)).unwrap();
        pm.run(&mut b, &MultiPump::mixed(vec![Some(2)], PumpMode::Resource)).unwrap();
        assert_eq!(
            crate::ir::printer::to_text(&a),
            crate::ir::printer::to_text(&b),
            "uniform assignment diverged from the whole-graph transform"
        );
        assert_eq!(
            a.multipump.as_ref().unwrap().max_factor(),
            b.multipump.as_ref().unwrap().max_factor()
        );
    }

    #[test]
    fn mixed_assignment_rejects_bad_shapes() {
        let g = streamed_stencil(4, 8);
        // wrong length
        let err = MultiPump::mixed(vec![Some(2); 3], PumpMode::Resource)
            .can_apply(&g)
            .unwrap_err();
        assert!(err.contains("4 streamable regions"), "{err}");
        // throughput mode on an interior region (stage 1 of 4 touches
        // no reader/writer-fed stream, so widening cannot feed it)
        let err = MultiPump::mixed(vec![Some(2), Some(4), None, None], PumpMode::Throughput)
            .can_apply(&g)
            .unwrap_err();
        assert!(err.contains("no external stream"), "{err}");
        // all None
        let err = MultiPump::mixed(vec![None; 4], PumpMode::Resource)
            .can_apply(&g)
            .unwrap_err();
        assert!(err.contains("pumps no region"), "{err}");
        // indivisible width (w=8, factor 3 illegal)
        let err = MultiPump::mixed(vec![Some(3), None, None, None], PumpMode::Resource)
            .can_apply(&g)
            .unwrap_err();
        assert!(err.contains("not divisible"), "{err}");
        // width-legal power-of-two pairs share a fast time base
        MultiPump::mixed(vec![Some(4), Some(8), None, None], PumpMode::Resource)
            .can_apply(&g)
            .unwrap();
    }

    #[test]
    fn mixed_assignment_rejects_incompatible_time_bases() {
        // widen everything to 12 lanes so factors 4 and 6 are both
        // width-legal — but 4 does not divide the assignment's largest
        // factor 6, so the fast domains cannot share one time base
        let mut g = streamed_stencil(2, 8);
        for id in g.node_ids().collect::<Vec<_>>() {
            if let Node::Library {
                op: LibraryOp::StencilStage { vec_width, .. },
                ..
            } = g.node_mut(id)
            {
                *vec_width = 12;
            }
        }
        for name in ["v_in_to_jacobi3d_stage0", "tmp0", "v_out_from_jacobi3d_stage1"] {
            if let Some(decl) = g.containers.get_mut(name) {
                decl.vtype.lanes = 12;
            }
        }
        let err = MultiPump::mixed(vec![Some(4), Some(6)], PumpMode::Resource)
            .can_apply(&g)
            .unwrap_err();
        assert!(err.contains("fast time base"), "{err}");
    }

    #[test]
    fn mixed_stencil_chain_builds_two_domains() {
        // 4-stage chain: first two stages at M=4, last two at M=2
        let mut g = streamed_stencil(4, 8);
        let mut pm = PassManager::new();
        let report = pm
            .run(&mut g, &MultiPump::mixed(vec![Some(4), Some(4), Some(2), Some(2)], PumpMode::Resource))
            .unwrap()
            .clone();
        validate(&g).unwrap();
        assert!(report.summary.contains("2 fast clock domain(s)"), "{}", report.summary);
        let mp = g.multipump.as_ref().unwrap();
        assert!(mp.is_mixed());
        assert_eq!(mp.max_factor(), 4);
        // per-stage factors via the IR query
        let regions = partition_streamable(&g);
        assert_eq!(
            regions.iter().map(|r| g.fast_factor_of(r.module)).collect::<Vec<_>>(),
            vec![Some(4), Some(4), Some(2), Some(2)]
        );
        // boundary crossings (in + out) + one interior 4→2 crossing:
        // 2 + 2 + 3 plumbing modules
        let cdc = g.node_ids().filter(|i| g.node(*i).is_cdc()).count();
        assert_eq!(cdc, 7, "expected sync+issuer, packer+sync and packer+sync+issuer");
        // stream interior to the M=4 domain narrowed to 2 lanes; the
        // crossing stream tmp1 is owned by its producer (M=4); interior
        // to the M=2 domain narrowed to 4
        assert_eq!(g.container("tmp0").unwrap().vtype.lanes, 2);
        assert_eq!(g.container("tmp1").unwrap().vtype.lanes, 2);
        assert_eq!(g.container("tmp1_pack_cdc").unwrap().vtype.lanes, 8);
        assert_eq!(g.container("tmp1_cdc").unwrap().vtype.lanes, 8);
        assert_eq!(g.container("tmp1_fast").unwrap().vtype.lanes, 4);
        assert_eq!(g.container("tmp2").unwrap().vtype.lanes, 4);
        // library datapaths narrowed per region
        let widths: Vec<usize> = g
            .node_ids()
            .filter_map(|id| match g.node(id) {
                Node::Library { op: LibraryOp::StencilStage { vec_width, .. }, .. } => {
                    Some(*vec_width)
                }
                _ => None,
            })
            .collect();
        assert_eq!(widths, vec![2, 2, 4, 4]);
    }

    #[test]
    fn for_region_pumps_exactly_one_region() {
        // pump only stage 1 of a 2-stage chain: stage 0 stays in CL0
        let mut g = streamed_stencil(2, 8);
        let mut pm = PassManager::new();
        pm.run(&mut g, &MultiPump::for_region(1, 2, 2)).unwrap();
        validate(&g).unwrap();
        let regions = partition_streamable(&g);
        assert_eq!(g.fast_factor_of(regions[0].module), None);
        assert_eq!(g.fast_factor_of(regions[1].module), Some(2));
        // tmp0 crosses slow → fast: sync + issuer; writer boundary
        // crosses fast → slow: packer + sync; reader boundary stays slow
        let cdc = g.node_ids().filter(|i| g.node(*i).is_cdc()).count();
        assert_eq!(cdc, 4);
        // stage 0 keeps its full width, stage 1 is narrowed
        let widths: Vec<usize> = g
            .node_ids()
            .filter_map(|id| match g.node(id) {
                Node::Library { op: LibraryOp::StencilStage { vec_width, .. }, .. } => {
                    Some(*vec_width)
                }
                _ => None,
            })
            .collect();
        assert_eq!(widths, vec![8, 4]);
    }

    #[test]
    fn mixed_chain_functional_results_match_unpumped() {
        // multi-pumping must never change results: run the mixed chain
        // and the original functionally on the same input
        use crate::codegen::lower::lower;
        use crate::hw::cost::CostModel;
        use crate::sim::{run_functional, Hbm};
        let bindings: [(&str, i64); 4] = [("NX", 8), ("NY", 8), ("NZ", 8), ("NZ_v", 1)];
        let build = |mixed: bool| {
            let mut g = crate::apps::stencil::build(StencilKind::Jacobi3D, 3, 8);
            let mut pm = PassManager::new();
            pm.run(&mut g, &StreamingComposition::default()).unwrap();
            if mixed {
                pm.run(
                    &mut g,
                    &MultiPump::mixed(vec![Some(4), Some(2), None], PumpMode::Resource),
                )
                .unwrap();
            }
            let env = g.bind(&bindings).unwrap();
            lower(&g, &env, &CostModel::default()).unwrap()
        };
        let mut rng = crate::util::Rng::new(11);
        let input = rng.f32_vec(8 * 8 * 8);
        let mut hbm = Hbm::new();
        hbm.load("v_in", input.clone());
        let plain = run_functional(&build(false), hbm.clone()).unwrap();
        let mixed = run_functional(&build(true), hbm).unwrap();
        assert_eq!(
            plain.hbm.read("v_out"),
            mixed.hbm.read("v_out"),
            "mixed multi-pumping changed results"
        );
    }

    // ---- per-region modes -------------------------------------------

    #[test]
    fn bare_fast_requires_dependent_pipeline() {
        // stencil stages pipeline at II = 1 — nothing to recover
        let g = streamed_stencil(2, 8);
        let err = MultiPump::bare_fast(2).can_apply(&g).unwrap_err();
        assert!(err.contains("II = 1"), "{err}");
        // Floyd–Warshall's in-place relaxation is dependent — legal
        MultiPump::bare_fast(2).can_apply(&streamed_fw()).unwrap();
    }

    #[test]
    fn uniform_bare_fast_is_sync_only() {
        let mut g = streamed_fw();
        let mut pm = PassManager::new();
        let report = pm.run(&mut g, &MultiPump::bare_fast(2)).unwrap().clone();
        validate(&g).unwrap();
        assert!(report.summary.contains("2 clock domains"), "{}", report.summary);
        // zero gearboxes: every crossing is a lone synchronizer
        let (packers, syncs, issuers) = cdc_counts(&g);
        assert_eq!((packers, issuers), (0, 0), "bare-fast must inject no gearboxes");
        assert_eq!(syncs, 2); // one per boundary stream (in + out)
        // widths untouched — the fast domain runs the same datapath
        for (name, decl) in &g.containers {
            if decl.kind == ContainerKind::Stream {
                assert_eq!(decl.vtype.lanes, 1, "stream '{name}' changed width");
            }
        }
        let mp = g.multipump.as_ref().unwrap();
        assert_eq!(mp.representative_mode(), PumpMode::BareFast);
        assert_eq!(mp.max_factor(), 2);
        // the relaxation datapath sits in the fast domain
        let lib = g
            .node_ids()
            .find(|i| matches!(g.node(*i), Node::Library { .. }))
            .unwrap();
        assert_eq!(g.fast_factor_of(lib), Some(2));
        assert_eq!(g.fast_mode_of(lib), Some(PumpMode::BareFast));
    }

    #[test]
    fn uniform_mode_assignments_delegate_bit_for_bit() {
        // all-same-mode per-region assignments must reproduce the
        // legacy whole-graph transform exactly, in every mode
        let mut pm = PassManager::new();
        // throughput on the (external) vecadd region
        let mut a = streamed_vecadd(2);
        let mut b = streamed_vecadd(2);
        pm.run(&mut a, &MultiPump::throughput(2)).unwrap();
        pm.run(
            &mut b,
            &MultiPump::per_region(vec![Some(RegionPump::new(2, PumpMode::Throughput))]),
        )
        .unwrap();
        assert_eq!(
            crate::ir::printer::to_text(&a),
            crate::ir::printer::to_text(&b),
            "throughput delegation diverged"
        );
        // bare-fast on the (dependent) Floyd–Warshall region
        let mut a = streamed_fw();
        let mut b = streamed_fw();
        pm.run(&mut a, &MultiPump::bare_fast(2)).unwrap();
        pm.run(
            &mut b,
            &MultiPump::per_region(vec![Some(RegionPump::new(2, PumpMode::BareFast))]),
        )
        .unwrap();
        assert_eq!(
            crate::ir::printer::to_text(&a),
            crate::ir::printer::to_text(&b),
            "bare-fast delegation diverged"
        );
    }

    #[test]
    fn mode_mixed_chain_throughput_head_resource_tail() {
        // 2-stage chain: stage 0 outwards (T2) — its reader-fed feed
        // widens ×2 — and stage 1 inwards (R2) — streams narrow ÷2
        let mut g = streamed_stencil(2, 8);
        let mut pm = PassManager::new();
        let report = pm
            .run(
                &mut g,
                &MultiPump::per_region(vec![
                    Some(RegionPump::new(2, PumpMode::Throughput)),
                    Some(RegionPump::new(2, PumpMode::Resource)),
                ]),
            )
            .unwrap()
            .clone();
        validate(&g).unwrap();
        assert!(report.summary.contains("2 fast clock domain(s)"), "{}", report.summary);
        // the throughput head's external feed is widened; its fast
        // side keeps the original width (issuer ÷2 re-issues)
        assert_eq!(g.container("v_in_to_jacobi3d_stage0").unwrap().vtype.lanes, 16);
        assert_eq!(g.container("v_in_to_jacobi3d_stage0_fast").unwrap().vtype.lanes, 8);
        // the T→R interior crossing is gearless on the producer side
        // (no packer — nothing widened tmp0) and issues ÷2 into the
        // resource tail
        assert_eq!(g.container("tmp0").unwrap().vtype.lanes, 8);
        assert_eq!(g.container("tmp0_fast").unwrap().vtype.lanes, 4);
        let (packers, syncs, issuers) = cdc_counts(&g);
        assert_eq!((packers, syncs, issuers), (1, 3, 2));
        // resource tail narrows its datapath; throughput head keeps it
        let widths: Vec<usize> = g
            .node_ids()
            .filter_map(|id| match g.node(id) {
                Node::Library { op: LibraryOp::StencilStage { vec_width, .. }, .. } => {
                    Some(*vec_width)
                }
                _ => None,
            })
            .collect();
        assert_eq!(widths, vec![8, 4]);
        // per-region modes land in the IR
        let regions = partition_streamable(&g);
        assert_eq!(g.fast_mode_of(regions[0].module), Some(PumpMode::Throughput));
        assert_eq!(g.fast_mode_of(regions[1].module), Some(PumpMode::Resource));
        assert!(g.multipump.as_ref().unwrap().is_mixed());
    }

    #[test]
    fn mode_mixed_chain_functional_results_match_unpumped() {
        use crate::codegen::lower::lower;
        use crate::hw::cost::CostModel;
        use crate::sim::{run_functional, Hbm};
        let bindings: [(&str, i64); 4] = [("NX", 8), ("NY", 8), ("NZ", 8), ("NZ_v", 1)];
        let build = |pumped: bool| {
            let mut g = crate::apps::stencil::build(StencilKind::Jacobi3D, 3, 8);
            let mut pm = PassManager::new();
            pm.run(&mut g, &StreamingComposition::default()).unwrap();
            if pumped {
                pm.run(
                    &mut g,
                    &MultiPump::per_region(vec![
                        Some(RegionPump::new(2, PumpMode::Throughput)),
                        Some(RegionPump::new(2, PumpMode::Resource)),
                        None,
                    ]),
                )
                .unwrap();
            }
            let env = g.bind(&bindings).unwrap();
            lower(&g, &env, &CostModel::default()).unwrap()
        };
        let mut rng = crate::util::Rng::new(13);
        let input = rng.f32_vec(8 * 8 * 8);
        let mut hbm = Hbm::new();
        hbm.load("v_in", input);
        let plain = run_functional(&build(false), hbm.clone()).unwrap();
        let mixed = run_functional(&build(true), hbm).unwrap();
        assert_eq!(
            plain.hbm.read("v_out"),
            mixed.hbm.read("v_out"),
            "mode-mixed multi-pumping changed results"
        );
    }

    #[test]
    fn bare_fast_fw_functional_results_match_unpumped() {
        use crate::codegen::lower::lower;
        use crate::hw::cost::CostModel;
        use crate::sim::{run_functional, Hbm};
        let n = 8usize;
        let build = |pumped: bool| {
            let mut g = crate::apps::floyd_warshall::build();
            let mut pm = PassManager::new();
            pm.run(&mut g, &StreamingComposition::default()).unwrap();
            if pumped {
                pm.run(&mut g, &MultiPump::bare_fast(2)).unwrap();
            }
            let env = g.bind(&[("N", n as i64)]).unwrap();
            lower(&g, &env, &CostModel::default()).unwrap()
        };
        let d = crate::apps::floyd_warshall::random_graph(n, 5, 0.4);
        let mut hbm = Hbm::new();
        hbm.load("dist", d);
        let plain = run_functional(&build(false), hbm.clone()).unwrap();
        let fast = run_functional(&build(true), hbm).unwrap();
        assert_eq!(
            plain.hbm.read("dist"),
            fast.hbm.read("dist"),
            "bare-fast pumping changed results"
        );
    }
}
