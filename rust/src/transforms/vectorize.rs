//! Traditional vectorization (paper Figure 3, box ①).
//!
//! *"it changes the range of the parametric scope by dividing them by
//! V, the applied vectorization factor; it converts the type of data
//! containers to a vector data type; and modifies the edges' addresses
//! accordingly."*

use super::pass::{Transform, TransformReport};
use crate::analysis::movement::scope_movement;
use crate::analysis::vectorizability::check_traditional;
use crate::ir::graph::DerivedSymbol;
use crate::ir::{Node, Sdfg};
use crate::symbolic::{Expr, SymbolTable};

/// Vectorize the map named `map_name` by `factor`.
pub struct Vectorize {
    pub map_name: String,
    pub factor: usize,
}

impl Vectorize {
    pub fn new(map_name: &str, factor: usize) -> Self {
        Vectorize { map_name: map_name.to_string(), factor }
    }
}

impl Transform for Vectorize {
    fn name(&self) -> String {
        format!("Vectorize[{} x{}]", self.map_name, self.factor)
    }

    fn can_apply(&self, g: &Sdfg) -> Result<(), String> {
        if self.factor < 2 {
            return Err("factor must be ≥ 2".into());
        }
        let entry = g
            .find_map_entry(&self.map_name)
            .ok_or_else(|| format!("no map '{}'", self.map_name))?;
        let mv = scope_movement(g, entry)?;
        // traditional rules; extent divisibility is established via a
        // derived symbol, so pass factor 1 to skip the symbolic check
        // and verify stride-1 linearity + dependence freedom here.
        let verdict = check_traditional(g, &mv, 1, &SymbolTable::new());
        if !verdict.is_ok() {
            return Err(verdict.reasons().join("; "));
        }
        // all accesses must be unit-stride (stride V access cannot be
        // re-vectorized without gather)
        for acc in mv.all() {
            match acc.subset.linear_in(mv.inner_param()) {
                Some(1) => {}
                Some(s) => return Err(format!("access to '{}' has stride {s} ≠ 1", acc.data)),
                None => return Err(format!("access to '{}' not linear", acc.data)),
            }
        }
        Ok(())
    }

    fn apply(&self, g: &mut Sdfg) -> Result<TransformReport, String> {
        let entry = g.find_map_entry(&self.map_name).unwrap();
        let mv = scope_movement(g, entry)?;
        let param = mv.inner_param().to_string();
        let v = self.factor as i64;

        // 1. divide the map range by V (introducing a derived symbol if
        //    the extent is symbolic)
        let mut widened_containers: Vec<String> = Vec::new();
        if let Node::MapEntry { ranges, .. } = g.node_mut(entry) {
            let inner = ranges.last_mut().unwrap();
            if let Some(divided) = inner.divide_extent(v) {
                *inner = divided;
            } else {
                // symbolic extent: N → N_div_V
                let extent = inner.extent().ok_or("non-affine extent")?;
                let base = match extent.symbols().as_slice() {
                    [s] if extent.coeff(s) == Some(1) && extent.as_const().is_none() => s.clone(),
                    _ => return Err(format!("cannot divide extent {extent} symbolically")),
                };
                let derived_name = format!("{base}_div_{v}");
                inner.end = inner.begin.add(&Expr::sym(&derived_name));
                g.derived.push(DerivedSymbol { name: derived_name.clone(), base, divisor: v });
                g.add_symbol(&derived_name);
            }
        }

        // 2. widen the vector type of every container the scope accesses
        for acc in mv.all() {
            if !widened_containers.contains(&acc.data) {
                widened_containers.push(acc.data.clone());
            }
        }
        let mut new_derived: Vec<(String, String)> = Vec::new();
        for name in &widened_containers {
            // decide the shape rewrite first (immutable), then mutate
            let last_dim = g.containers[name].shape.last().cloned();
            let rewritten = match &last_dim {
                Some(last) => {
                    if let Some(divided) = last.div_exact(v) {
                        Some(divided)
                    } else if let [s] = last.symbols().as_slice() {
                        let derived_name = format!("{s}_div_{v}");
                        if !g.symbols.contains(&derived_name)
                            && !new_derived.iter().any(|(n, _)| n == &derived_name)
                        {
                            new_derived.push((derived_name.clone(), s.clone()));
                        }
                        Some(Expr::sym(&derived_name))
                    } else {
                        None
                    }
                }
                None => None,
            };
            let decl = g.containers.get_mut(name).unwrap();
            decl.vtype.lanes *= self.factor;
            if let (Some(last), Some(new_dim)) = (decl.shape.last_mut(), rewritten) {
                *last = new_dim;
            }
        }
        for (name, base) in new_derived {
            g.derived.push(DerivedSymbol { name: name.clone(), base, divisor: v });
            g.add_symbol(&name);
        }

        // 3. memlet subsets keep their form: index `i` now addresses
        //    vector i (of V lanes). Outer full-range memlets shrink.
        let known_symbols = g.symbols.clone();
        for eid in g.edge_ids().collect::<Vec<_>>() {
            let e = g.edge_mut(eid);
            if widened_containers.contains(&e.memlet.data) {
                for dim in &mut e.memlet.subset.dims {
                    if dim.is_index() {
                        continue;
                    }
                    if let Some(divided) = dim.clone().divide_extent(v) {
                        *dim = divided;
                    } else if let Some(extent) = dim.extent() {
                        if let [s] = extent.symbols().as_slice() {
                            let derived_name = format!("{s}_div_{v}");
                            if known_symbols.contains(&derived_name) {
                                dim.end = dim.begin.add(&Expr::sym(&derived_name));
                            }
                        }
                    }
                }
            }
        }

        let _ = param;
        Ok(TransformReport {
            transform: self.name(),
            summary: format!(
                "map '{}' divided by {}, containers widened: {}",
                self.map_name,
                self.factor,
                widened_containers.join(", ")
            ),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::vecadd_sdfg;
    use crate::ir::validate::validate;
    use crate::transforms::pass::PassManager;

    #[test]
    fn vectorize_vecadd_by_4() {
        let mut g = vecadd_sdfg(1);
        let mut pm = PassManager::new();
        pm.run(&mut g, &Vectorize::new("vadd", 4)).unwrap();
        validate(&g).unwrap();
        // containers widened
        assert_eq!(g.container("x").unwrap().vtype.lanes, 4);
        assert_eq!(g.container("z").unwrap().vtype.lanes, 4);
        // derived symbol registered
        assert!(g.symbols.contains(&"N_div_4".to_string()));
        let env = g.bind(&[("N", 64)]).unwrap();
        assert_eq!(env.get("N_div_4"), Some(16));
        // map range divided
        let entry = g.find_map_entry("vadd").unwrap();
        if let Node::MapEntry { ranges, .. } = g.node(entry) {
            assert_eq!(ranges[0].count(&env), Some(16));
        } else {
            panic!()
        }
    }

    #[test]
    fn non_divisible_binding_rejected_at_bind_time() {
        let mut g = vecadd_sdfg(1);
        let mut pm = PassManager::new();
        pm.run(&mut g, &Vectorize::new("vadd", 4)).unwrap();
        assert!(g.bind(&[("N", 65)]).is_err());
    }

    #[test]
    fn factor_one_rejected() {
        let g = vecadd_sdfg(1);
        assert!(Vectorize::new("vadd", 1).can_apply(&g).is_err());
    }

    #[test]
    fn missing_map_rejected() {
        let g = vecadd_sdfg(1);
        assert!(Vectorize::new("nope", 2).can_apply(&g).is_err());
    }

    #[test]
    fn double_vectorization_compounds() {
        let mut g = vecadd_sdfg(1);
        let mut pm = PassManager::new();
        pm.run(&mut g, &Vectorize::new("vadd", 2)).unwrap();
        pm.run(&mut g, &Vectorize::new("vadd", 2)).unwrap();
        assert_eq!(g.container("x").unwrap().vtype.lanes, 4);
        let env = g.bind(&[("N", 64)]).unwrap();
        assert_eq!(env.get("N_div_2"), Some(32));
        assert_eq!(env.get("N_div_2_div_2"), Some(16));
    }
}
