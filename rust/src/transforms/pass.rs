//! The transformation pass manager.

use crate::ir::validate::validate;
use crate::ir::Sdfg;

/// Result summary of one applied transformation.
#[derive(Clone, Debug)]
pub struct TransformReport {
    pub transform: String,
    pub summary: String,
}

/// A checked graph rewrite.
pub trait Transform {
    fn name(&self) -> String;

    /// Feasibility check; Err carries the human-readable reason.
    fn can_apply(&self, g: &Sdfg) -> Result<(), String>;

    /// Mutate the graph. Only called after `can_apply` succeeded.
    fn apply(&self, g: &mut Sdfg) -> Result<TransformReport, String>;
}

/// Applies transformations in sequence with validation around each.
#[derive(Default)]
pub struct PassManager {
    pub reports: Vec<TransformReport>,
    /// Validate before/after each pass (always on in tests; kept
    /// switchable for the simulator's inner-loop benchmarks).
    pub validate: bool,
}

impl PassManager {
    pub fn new() -> Self {
        PassManager { reports: Vec::new(), validate: true }
    }

    /// Run one transformation, validating the graph before and after.
    pub fn run(&mut self, g: &mut Sdfg, t: &dyn Transform) -> Result<&TransformReport, String> {
        if self.validate {
            validate(g).map_err(|e| format!("pre-{}: {e}", t.name()))?;
        }
        t.can_apply(g).map_err(|e| format!("{} not applicable: {e}", t.name()))?;
        let report = t.apply(g).map_err(|e| format!("{} failed: {e}", t.name()))?;
        if self.validate {
            validate(g).map_err(|e| format!("post-{}: {e}", t.name()))?;
        }
        self.reports.push(report);
        Ok(self.reports.last().unwrap())
    }

    /// Try a transformation; Ok(false) when not applicable.
    pub fn try_run(&mut self, g: &mut Sdfg, t: &dyn Transform) -> Result<bool, String> {
        if self.validate {
            validate(g).map_err(|e| format!("pre-{}: {e}", t.name()))?;
        }
        if t.can_apply(g).is_err() {
            return Ok(false);
        }
        self.run(g, t)?;
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::builder::vecadd_sdfg;

    struct Rename;
    impl Transform for Rename {
        fn name(&self) -> String {
            "Rename".into()
        }
        fn can_apply(&self, g: &Sdfg) -> Result<(), String> {
            if g.name.is_empty() {
                Err("unnamed".into())
            } else {
                Ok(())
            }
        }
        fn apply(&self, g: &mut Sdfg) -> Result<TransformReport, String> {
            g.name = format!("{}_renamed", g.name);
            Ok(TransformReport { transform: self.name(), summary: g.name.clone() })
        }
    }

    struct Corrupt;
    impl Transform for Corrupt {
        fn name(&self) -> String {
            "Corrupt".into()
        }
        fn can_apply(&self, _: &Sdfg) -> Result<(), String> {
            Ok(())
        }
        fn apply(&self, g: &mut Sdfg) -> Result<TransformReport, String> {
            // introduce a cycle: last node → first node
            let a = crate::ir::NodeId(0);
            let b = crate::ir::NodeId(g.nodes.len() - 1);
            let data = g.containers.keys().next().unwrap().clone();
            g.add_edge(b, a, crate::ir::Memlet::new(&data, crate::symbolic::Subset::all1(1)));
            g.add_edge(a, b, crate::ir::Memlet::new(&data, crate::symbolic::Subset::all1(1)));
            Ok(TransformReport { transform: self.name(), summary: "corrupted".into() })
        }
    }

    #[test]
    fn run_applies_and_records() {
        let mut g = vecadd_sdfg(1);
        let mut pm = PassManager::new();
        pm.run(&mut g, &Rename).unwrap();
        assert_eq!(g.name, "vecadd_renamed");
        assert_eq!(pm.reports.len(), 1);
    }

    #[test]
    fn corrupting_transform_caught_by_post_validation() {
        let mut g = vecadd_sdfg(1);
        let mut pm = PassManager::new();
        let err = pm.run(&mut g, &Corrupt).unwrap_err();
        assert!(err.contains("post-Corrupt"), "{err}");
    }

    #[test]
    fn try_run_skips_inapplicable() {
        let mut g = vecadd_sdfg(1);
        g.name = String::new();
        // bypass: Rename.can_apply fails on empty name
        let mut pm = PassManager::new();
        assert!(!pm.try_run(&mut g, &Rename).unwrap());
        assert!(pm.reports.is_empty());
    }
}
